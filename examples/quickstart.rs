//! Quickstart: load the AOT artifacts, decode one grammar prompt with
//! tree speculation, and compare against teacher-only greedy decoding.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Falls back to the deterministic SimBackend when artifacts are missing,
//! so the example always runs.

use anyhow::Result;
use eagle_pangu::backend::ModelBackend;
use eagle_pangu::backend::sim::SimBackend;
use eagle_pangu::config::RunConfig;
use eagle_pangu::engine::Engine;
use eagle_pangu::runtime::PjrtBackend;
use eagle_pangu::workload::Grammar;

fn main() -> Result<()> {
    // 1. Pick a backend: real AOT artifacts if built, else the simulator.
    let mut backend: Box<dyn ModelBackend> = match PjrtBackend::load("artifacts") {
        Ok(b) => {
            println!("backend: PJRT CPU over artifacts/ (TinyPangu teacher + TinyEagle draft)");
            Box::new(b)
        }
        Err(e) => {
            println!("backend: SimBackend (artifacts unavailable: {e})");
            Box::new(SimBackend::new(85))
        }
    };

    // 2. Sample an in-distribution prompt from the code (HumanEval-style)
    //    grammar profile — the language the teacher was trained on.
    let prompt = Grammar::code().sample_sequence(64, 7, None);
    println!("prompt: {} tokens, topic token {}", prompt.len(), prompt[1]);

    // 3. Tree-speculative decoding (the paper's EA path, fused kernels).
    //    The engine owns per-conversation state only; the backend is
    //    passed per call (`StepScratch` outputs land in reusable arenas,
    //    and one warmed engine is reused across runs via `reset`).
    let cfg = RunConfig::default(); // M=16, D_max=10 — the paper's sweet spot
    let mut engine = Engine::new(&*backend, cfg.clone());
    engine.warmup(&mut *backend)?; // absorb lazy PJRT compilation before timing
    let ea = engine.generate_speculative(&mut *backend, &prompt, 96)?;
    engine.reset();

    // 4. Baseline: teacher-only greedy decoding of the same prompt, on
    //    the same warmed engine.
    let base = engine.generate_baseline(&mut *backend, &prompt, ea.tokens.len())?;

    // 5. Greedy tree speculation never changes the output — only the clock.
    assert_eq!(ea.tokens, base.tokens, "speculation must preserve the output");

    println!("\ngenerated {} tokens (EA output identical to baseline):", ea.tokens.len());
    println!("  first 16: {:?}", &ea.tokens[..16.min(ea.tokens.len())]);
    println!("\n                 baseline        EA");
    println!("  Tok/s      {:>10.2} {:>10.2}", base.tok_per_sec(), ea.tok_per_sec());
    println!("  teacher calls {:>7} {:>10}", base.teacher_calls, ea.teacher_calls);
    println!("  draft calls   {:>7} {:>10}", base.draft_calls, ea.draft_calls);
    println!("  accept_L mean        - {:>10.2}", ea.mean_accept_len());
    println!("\n  speedup: {:.2}x", ea.tok_per_sec() / base.tok_per_sec().max(1e-9));
    Ok(())
}
