//! Budget-sweep demo (paper E2): scan the tree node budget M and depth
//! bound D_max on a small code-profile workload and print the
//! throughput/acceptance trade-off — the non-monotonic "sweet spot"
//! behaviour of Table 2 / Fig 4, at example scale.
//!
//! ```bash
//! cargo run --release --example budget_sweep -- [conversations]
//! ```

use anyhow::Result;
use eagle_pangu::config::RunConfig;
use eagle_pangu::coordinator::{run_workload, AdmissionPolicy, BackendSpec, CoordinatorConfig};
use eagle_pangu::util::stats::Summary;
use eagle_pangu::workload::WorkloadSpec;
use std::path::PathBuf;

fn main() -> Result<()> {
    let conversations: usize =
        std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(6);
    let backend = if PathBuf::from("artifacts/manifest.json").exists() {
        BackendSpec::Pjrt { artifact_dir: "artifacts".into() }
    } else {
        BackendSpec::Sim { agree_pct: 85 }
    };
    let mut workload = WorkloadSpec::default();
    workload.code_conversations = conversations;
    workload.chat_conversations = 0;
    workload.prompt_mean = 48;

    let coord = |run: RunConfig, tag: String, baseline: bool, ea: bool| CoordinatorConfig {
        world_size: 2,
        run,
        workload: workload.clone(),
        backend: backend.clone(),
        trace_dir: PathBuf::from(format!("results/budget_sweep_example/{tag}")),
        run_baseline: baseline,
        run_ea: ea,
        max_batch: 1,
        scheduling: AdmissionPolicy::Continuous,
        verbose: false,
    };

    let mut base_run = RunConfig::default();
    base_run.max_new_tokens = 48;
    let recs = run_workload(&coord(base_run.clone(), "base".into(), true, false))?;
    let base = Summary::from(&recs.iter().map(|r| r.tok_s).collect::<Vec<_>>()).mean;
    println!("baseline: {base:.2} Tok/s\n");
    println!("{:>6} {:>6} | {:>10} {:>8} {:>10}", "M", "Dmax", "EA Tok/s", "speedup", "accept_L");

    for (m, d) in [(4usize, 4usize), (8, 6), (16, 10), (32, 10), (64, 10), (64, 4), (64, 16)] {
        let mut run = base_run.clone();
        run.tree.budget = m;
        run.tree.depth_max = d;
        let recs = run_workload(&coord(run, format!("m{m}_d{d}"), false, true))?;
        let tok = Summary::from(&recs.iter().map(|r| r.tok_s).collect::<Vec<_>>()).mean;
        let accepts: Vec<f64> = recs
            .iter()
            .flat_map(|r| r.accept_lens.iter().map(|a| *a as f64))
            .collect();
        println!("{:>6} {:>6} | {:>10.2} {:>7.2}x {:>10.2}",
                 m, d, tok, tok / base.max(1e-9), Summary::from(&accepts).mean);
    }
    println!("\nnon-monotonic in both axes — the paper's configuration-dependent sweet spot");
    Ok(())
}
