//! End-to-end serving driver — the EXPERIMENTS.md validation run.
//!
//! Loads the trained TinyPangu/TinyEagle artifacts and serves a real
//! multi-turn workload (MT-Bench-style 2-turn chats + HumanEval-style
//! code prompts) through the multi-worker coordinator, reporting
//! latency/throughput in the paper's Table-1 format.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve -- [conversations] [workers] [batch]
//! ```

use anyhow::Result;
use eagle_pangu::config::RunConfig;
use eagle_pangu::coordinator::{run_workload, AdmissionPolicy, BackendSpec, CoordinatorConfig};
use eagle_pangu::metrics::{pair_turns, ThroughputReport};
use eagle_pangu::util::stats::Summary;
use eagle_pangu::workload::WorkloadSpec;
use std::path::PathBuf;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let conversations: usize = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(12);
    let workers: usize = args.get(2).and_then(|a| a.parse().ok()).unwrap_or(2);
    // Conversations resident per worker: EA tree verifications are fused
    // across them into one padded teacher launch per tick (token-identical
    // to sequential serving — see docs/ARCHITECTURE.md). Defaults to 4 on
    // the sim backend (true fused teacher_step_batch); on PJRT the fused
    // call is still the sequential trait fallback, so batching buys
    // nothing there yet and the default stays 1.
    let explicit_batch: Option<usize> = args.get(3).and_then(|a| a.parse().ok());

    let backend = if PathBuf::from("artifacts/manifest.json").exists() {
        BackendSpec::Pjrt { artifact_dir: "artifacts".into() }
    } else {
        eprintln!("artifacts/ missing — using SimBackend (run `make artifacts` for the real model)");
        BackendSpec::Sim { agree_pct: 85 }
    };
    let max_batch = explicit_batch.unwrap_or(match &backend {
        BackendSpec::Sim { .. } => 4,
        BackendSpec::Pjrt { .. } => 1,
    });

    let mut run = RunConfig::default();
    run.max_new_tokens = 96;
    let mut workload = WorkloadSpec::default();
    workload.code_conversations = conversations / 2;
    workload.chat_conversations = conversations - conversations / 2;

    let cfg = CoordinatorConfig {
        world_size: workers,
        run,
        workload,
        backend,
        trace_dir: "results/serve_example".into(),
        run_baseline: true,
        run_ea: true,
        max_batch,
        // continuous admission: a retired conversation frees its slot for
        // the next queued one at the same tick (see docs/ARCHITECTURE.md)
        scheduling: AdmissionPolicy::Continuous,
        verbose: true,
    };
    println!("serving {} conversations ({} turns) across {} workers, \
              EA batch width {}...",
             conversations, cfg.workload.total_turns(), workers, max_batch);
    let records = run_workload(&cfg)?;

    let pairs = pair_turns(&records);
    let report = ThroughputReport::from_pairs(&pairs);
    println!("{}", report.table1());

    // latency view (TTFT ~ prefill-dominated first-token latency is folded
    // into wall-clock here; TPOT = wall / tokens)
    let tpot: Vec<f64> = pairs
        .iter()
        .map(|p| p.ea.wall_secs / p.ea.output_len.max(1) as f64 * 1e3)
        .collect();
    let s = Summary::from(&tpot);
    println!("EA TPOT (ms/token): mean {:.1}  p50 {:.1}  p90 {:.1}  p99 {:.1}",
             s.mean, s.p50, s.p90, s.p99);
    println!("traces: results/serve_example/trace_merged.jsonl");
    Ok(())
}
