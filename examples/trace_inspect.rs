//! Trace tooling demo: run a tiny workload, then post-process the
//! structured traces the way the paper's analysis pipeline does —
//! merge rank files, pair baseline/EA turns, and print the throughput
//! report plus a per-stage timing digest (paper §4.3's "reproducible
//! benchmarking and post-hoc diagnosis without ad-hoc logs").
//!
//! ```bash
//! cargo run --release --example trace_inspect
//! ```

use anyhow::Result;
use eagle_pangu::config::RunConfig;
use eagle_pangu::coordinator::{run_workload, AdmissionPolicy, BackendSpec, CoordinatorConfig};
use eagle_pangu::metrics::{pair_turns, ThroughputReport};
use eagle_pangu::trace::merge_rank_files;
use eagle_pangu::util::stats::Summary;
use eagle_pangu::workload::WorkloadSpec;
use std::collections::BTreeMap;
use std::path::PathBuf;

fn main() -> Result<()> {
    let dir = PathBuf::from("results/trace_inspect_example");
    let backend = if PathBuf::from("artifacts/manifest.json").exists() {
        BackendSpec::Pjrt { artifact_dir: "artifacts".into() }
    } else {
        BackendSpec::Sim { agree_pct: 85 }
    };
    let mut run = RunConfig::default();
    run.max_new_tokens = 32;
    run.instrument = true; // per-stage timers -> stage_seconds in traces
    let cfg = CoordinatorConfig {
        world_size: 3,
        run,
        workload: WorkloadSpec::smoke(),
        backend,
        trace_dir: dir.clone(),
        run_baseline: true,
        run_ea: true,
        max_batch: 1,
        scheduling: AdmissionPolicy::Continuous,
        verbose: false,
    };
    run_workload(&cfg)?;

    // --- post-hoc analysis purely from the trace files ---
    let records = merge_rank_files(&dir)?;
    println!("merged {} records from {} ranks\n", records.len(), cfg.world_size);

    let report = ThroughputReport::from_pairs(&pair_turns(&records));
    println!("{}", report.table1());

    // per-stage digest across EA turns (Fig-5-style, from traces alone)
    let mut stages: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for r in records.iter().filter(|r| r.kind == "ea") {
        for (k, v) in &r.stage_seconds {
            stages.entry(k.clone()).or_default().push(*v * 1e3);
        }
    }
    println!("per-stage ms/turn (EA):");
    for (stage, xs) in &stages {
        let s = Summary::from(xs);
        println!("  {:<14} mean {:>8.2}  p99 {:>8.2}", stage, s.mean, s.p99);
    }
    println!("\nraw traces: {}", dir.join("trace_merged.jsonl").display());
    Ok(())
}
