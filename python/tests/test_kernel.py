"""L1 correctness: Pallas fused tree-attention vs the pure-jnp oracle.

This is the core kernel-correctness signal: hypothesis sweeps shapes and
mask structures; every case asserts allclose against kernels.ref.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import NEG_INF, tree_attention_ref
from compile.kernels.tree_attention import KV_CHUNK, tree_attention_fused, vmem_estimate_bytes


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def _run_both(rng, s, h, dh, t, mask):
    q = _rand(rng, s, h, dh)
    k = _rand(rng, t, h, dh)
    v = _rand(rng, t, h, dh)
    ref = np.asarray(tree_attention_ref(q, k, v, mask))
    fused = np.asarray(tree_attention_fused(q, k, v, mask))
    return ref, fused


def test_unmasked_matches_ref():
    rng = np.random.default_rng(0)
    mask = jnp.zeros((16, 2 * KV_CHUNK), jnp.float32)
    ref, fused = _run_both(rng, 16, 4, 32, 2 * KV_CHUNK, mask)
    np.testing.assert_allclose(ref, fused, atol=1e-5)


def test_prefix_plus_causal_tree_mask():
    """The serving-shaped case: open prefix, causal speculative block."""
    rng = np.random.default_rng(1)
    s, t, prefix = 8, 2 * KV_CHUNK, 100
    m = np.full((s, t), NEG_INF, np.float32)
    m[:, :prefix] = 0.0
    base = t - s
    for i in range(s):
        m[i, base:base + i + 1] = 0.0
    ref, fused = _run_both(rng, s, 4, 32, t, jnp.asarray(m))
    np.testing.assert_allclose(ref, fused, atol=1e-5)


def test_fully_masked_rows_emit_zeros():
    rng = np.random.default_rng(2)
    s, t = 8, KV_CHUNK
    m = np.zeros((s, t), np.float32)
    m[3] = NEG_INF
    m[7] = NEG_INF
    ref, fused = _run_both(rng, s, 2, 32, t, jnp.asarray(m))
    assert np.all(fused[3] == 0.0) and np.all(fused[7] == 0.0)
    np.testing.assert_allclose(ref, fused, atol=1e-5)


def test_masked_kv_values_cannot_leak():
    """Poisoning masked KV rows must not change the output (no-leakage)."""
    rng = np.random.default_rng(3)
    s, h, dh, t = 8, 2, 32, 2 * KV_CHUNK
    q = _rand(rng, s, h, dh)
    k = np.array(_rand(rng, t, h, dh))
    v = np.array(_rand(rng, t, h, dh))
    m = np.zeros((s, t), np.float32)
    m[:, 64:] = NEG_INF
    out1 = np.asarray(tree_attention_fused(q, jnp.asarray(k), jnp.asarray(v), jnp.asarray(m)))
    k[64:] = 1e6  # poison hidden region
    v[64:] = -1e6
    out2 = np.asarray(tree_attention_fused(q, jnp.asarray(k), jnp.asarray(v), jnp.asarray(m)))
    np.testing.assert_allclose(out1, out2, atol=1e-5)


def test_rejects_unaligned_t():
    rng = np.random.default_rng(4)
    with pytest.raises(AssertionError):
        tree_attention_fused(
            _rand(rng, 4, 2, 32), _rand(rng, 100, 2, 32),
            _rand(rng, 100, 2, 32), jnp.zeros((4, 100), jnp.float32))


@settings(max_examples=25, deadline=None)
@given(
    s=st.sampled_from([1, 4, 8, 16, 32]),
    h=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16, 32]),
    nchunks=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_random_tree_masks_match_ref(s, h, dh, nchunks, seed):
    """Hypothesis sweep: random shapes x random ragged masks."""
    rng = np.random.default_rng(seed)
    t = nchunks * KV_CHUNK
    m = np.where(rng.random((s, t)) < 0.5, 0.0, NEG_INF).astype(np.float32)
    ref, fused = _run_both(rng, s, h, dh, t, jnp.asarray(m))
    np.testing.assert_allclose(ref, fused, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_softmax_stability_large_logits(seed):
    """Online softmax must survive large-magnitude logits."""
    rng = np.random.default_rng(seed)
    s, h, dh, t = 8, 2, 16, 2 * KV_CHUNK
    q = jnp.asarray(rng.normal(size=(s, h, dh)) * 30, jnp.float32)
    k = jnp.asarray(rng.normal(size=(t, h, dh)) * 30, jnp.float32)
    v = _rand(rng, t, h, dh)
    m = jnp.zeros((s, t), jnp.float32)
    ref = np.asarray(tree_attention_ref(q, k, v, m))
    fused = np.asarray(tree_attention_fused(q, k, v, m))
    assert np.isfinite(fused).all()
    np.testing.assert_allclose(ref, fused, atol=1e-4)


def test_vmem_estimate_within_budget():
    """Static VMEM footprint of the largest variant stays under 16 MiB/core."""
    worst = vmem_estimate_bytes(s=256, dh=32)
    assert worst < 16 * 1024 * 1024, worst
