"""L2 semantic tests on the serving (block) forward contract.

These validate — at the JAX level, with untrained weights — the properties
the paper's §3.3 "correctness guarantee" relies on:

  * chunked cache-in/KV-out execution == one-shot causal execution
    (the foundation of the rust cache manager's commit-equivalence);
  * batched tree evaluation under the tree mask == independent per-path
    chain evaluation (context correctness / no cross-branch leakage);
  * fused (Pallas) and eager paths agree numerically.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.config import CACHE_CAP, DRAFT, FEAT_DIM, TEACHER
from compile.kernels.ref import NEG_INF
from compile.model import (
    draft_block_forward,
    init_draft,
    init_teacher,
    teacher_block_forward,
    teacher_train_forward,
    flatten_params,
    unflatten_params,
)

TP = init_teacher(0)
DP = init_draft(1)


def causal_mask(s: int, t: int, cap: int = CACHE_CAP) -> jnp.ndarray:
    """Mask for a chain of s tokens appended after a committed prefix t."""
    m = np.full((s, cap + s), NEG_INF, np.float32)
    m[:, :t] = 0.0
    for i in range(s):
        m[i, cap:cap + i + 1] = 0.0
    return jnp.asarray(m)


def empty_cache(dims):
    shape = (dims.layers, CACHE_CAP, dims.heads, dims.d_head)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def write_rows(cache, rows, at):
    """Host-side scatter: mimic the rust cache manager's row writes.
    cache [L, C, H, Dh], rows [L, S, H, Dh]."""
    c = np.asarray(cache).copy()
    c[:, at:at + rows.shape[1]] = np.asarray(rows)
    return jnp.asarray(c)


def run_chain(tokens, chunk_sizes, fused=False):
    """Run tokens through teacher_block_forward in chunks, managing the
    cache host-side exactly the way the rust runtime does."""
    kc, vc = empty_cache(TEACHER)
    t = 0
    logits_all = []
    for cs in chunk_sizes:
        toks = jnp.asarray(tokens[t:t + cs], jnp.int32)
        pos = jnp.arange(t, t + cs, dtype=jnp.int32)
        mask = causal_mask(cs, t)
        logits, feats, k_new, v_new = teacher_block_forward(
            TP, toks, pos, mask, kc, vc, fused=fused)
        kc = write_rows(kc, k_new, t)
        vc = write_rows(vc, v_new, t)
        logits_all.append(np.asarray(logits))
        t += cs
    return np.concatenate(logits_all, axis=0)


@pytest.fixture(scope="module")
def chain_tokens():
    rng = np.random.default_rng(42)
    return rng.integers(2, 512, size=24).astype(np.int32)


def test_chunked_equals_oneshot(chain_tokens):
    """Commit equivalence at L2: [24] one-shot == [8,8,8] == [16,8] chunks."""
    full = run_chain(chain_tokens, [24])
    a = run_chain(chain_tokens, [8, 8, 8])
    b = run_chain(chain_tokens, [16, 8])
    np.testing.assert_allclose(full, a, atol=2e-4)
    np.testing.assert_allclose(full, b, atol=2e-4)


def test_block_matches_train_forward(chain_tokens):
    """Serving stack == training stack on the same causal chain."""
    serve = run_chain(chain_tokens, [24])
    train_logits, _ = teacher_train_forward(TP, jnp.asarray(chain_tokens)[None, :])
    np.testing.assert_allclose(serve, np.asarray(train_logits)[0], atol=2e-4)


def test_fused_equals_eager(chain_tokens):
    f = run_chain(chain_tokens, [8, 16], fused=True)
    e = run_chain(chain_tokens, [8, 16], fused=False)
    np.testing.assert_allclose(f, e, atol=2e-4)


def test_tree_eval_equals_per_path():
    """Batched tree verification == independent per-path chains (§3.3
    context correctness). Tree over prefix [p0,p1]:
        root(committed) -> a -> b -> c   (path 1: a,b,c)
                         \\-> d -> e      (path 2: d,e)
    """
    rng = np.random.default_rng(7)
    prefix = rng.integers(2, 512, size=6).astype(np.int32)

    # Prefill the committed prefix.
    kc, vc = empty_cache(TEACHER)
    pos = jnp.arange(6, dtype=jnp.int32)
    _, _, k_new, v_new = teacher_block_forward(
        TP, jnp.asarray(prefix), pos, causal_mask(6, 0), kc, vc, fused=False)
    kc = write_rows(kc, k_new, 0)
    vc = write_rows(vc, v_new, 0)
    t = 6

    # Tree nodes (linearized, dummy-root style): tokens + parent slots.
    node_tok = np.asarray([100, 101, 102, 200, 201], np.int32)  # a b c d e
    parent = np.asarray([-1, 0, 1, -1, 3])  # -1 = root(committed prefix)
    depth = np.asarray([1, 2, 3, 1, 2])
    s = 5
    mask = np.full((s, CACHE_CAP + s), NEG_INF, np.float32)
    mask[:, :t] = 0.0
    for k in range(s):
        mask[k, CACHE_CAP + k] = 0.0
        pnt = parent[k]
        while pnt != -1:
            mask[k, CACHE_CAP + pnt] = 0.0
            pnt = parent[pnt]
    positions = jnp.asarray(t + depth - 1, jnp.int32)
    tree_logits, _, _, _ = teacher_block_forward(
        TP, jnp.asarray(node_tok), positions, jnp.asarray(mask), kc, vc, fused=False)
    tree_logits = np.asarray(tree_logits)

    # Per-path chains.
    for path in ([0, 1, 2], [3, 4]):
        toks = jnp.asarray(node_tok[path])
        pos = jnp.arange(t, t + len(path), dtype=jnp.int32)
        chain_logits, _, _, _ = teacher_block_forward(
            TP, toks, pos, causal_mask(len(path), t), kc, vc, fused=False)
        np.testing.assert_allclose(
            tree_logits[path], np.asarray(chain_logits), atol=2e-4,
            err_msg=f"path {path} diverges from batched tree eval")


def test_tree_eval_fused_equals_eager_with_padding():
    """Same tree, fused kernel path, with padded (invalid) node slots."""
    rng = np.random.default_rng(8)
    prefix = rng.integers(2, 512, size=5).astype(np.int32)
    kc, vc = empty_cache(TEACHER)
    _, _, k_new, v_new = teacher_block_forward(
        TP, jnp.asarray(prefix), jnp.arange(5, dtype=jnp.int32),
        causal_mask(5, 0), kc, vc, fused=False)
    kc = write_rows(kc, k_new, 0)
    vc = write_rows(vc, v_new, 0)
    t = 5

    s = 8  # 5 live nodes + 3 padded slots
    node_tok = np.asarray([100, 101, 102, 200, 201, 0, 0, 0], np.int32)
    parent = [-1, 0, 1, -1, 3]
    mask = np.full((s, CACHE_CAP + s), NEG_INF, np.float32)
    mask[:5, :t] = 0.0
    for k in range(5):
        mask[k, CACHE_CAP + k] = 0.0
        pnt = parent[k]
        while pnt != -1:
            mask[k, CACHE_CAP + pnt] = 0.0
            pnt = parent[pnt]
    depth = np.asarray([1, 2, 3, 1, 2, 1, 1, 1])
    positions = jnp.asarray(t + depth - 1, jnp.int32)

    outs = {}
    for fused in (True, False):
        lg, _, _, _ = teacher_block_forward(
            TP, jnp.asarray(node_tok), positions, jnp.asarray(mask), kc, vc, fused=fused)
        outs[fused] = np.asarray(lg)
    np.testing.assert_allclose(outs[True][:5], outs[False][:5], atol=2e-4)
    assert np.isfinite(outs[True]).all()


def test_padded_slot_tokens_cannot_leak():
    """Changing the token id of a fully-masked pad slot must not change any
    live node's logits ('no leakage to padded slots', §3.3)."""
    rng = np.random.default_rng(9)
    kc, vc = empty_cache(TEACHER)
    t = 0
    s = 4
    mask = np.full((s, CACHE_CAP + s), NEG_INF, np.float32)
    for i in range(3):  # 3 live chain nodes, slot 3 is padding
        mask[i, CACHE_CAP:CACHE_CAP + i + 1] = 0.0
    positions = jnp.asarray([0, 1, 2, 0], jnp.int32)

    def run(pad_tok):
        toks = jnp.asarray([10, 11, 12, pad_tok], jnp.int32)
        lg, _, _, _ = teacher_block_forward(
            TP, toks, positions, jnp.asarray(mask), kc, vc, fused=True)
        return np.asarray(lg)

    np.testing.assert_allclose(run(0)[:3], run(499)[:3], atol=1e-5)


def test_draft_forward_shapes_and_feature_sensitivity():
    rng = np.random.default_rng(10)
    kc, vc = empty_cache(DRAFT)
    s = 8
    toks = jnp.asarray(rng.integers(2, 512, size=s), jnp.int32)
    feats = jnp.asarray(rng.normal(size=(s, FEAT_DIM)), jnp.float32)
    mask = causal_mask(s, 0)
    pos = jnp.arange(s, dtype=jnp.int32)
    logits, hidden, k_new, v_new = draft_block_forward(DP, toks, feats, pos, mask, kc, vc)
    assert logits.shape == (s, 512)
    assert hidden.shape == (s, FEAT_DIM)
    assert k_new.shape == (DRAFT.layers, s, DRAFT.heads, DRAFT.d_head)
    # Features must actually condition the logits (EAGLE coupling).
    logits2, _, _, _ = draft_block_forward(DP, toks, feats * 0.0, pos, mask, kc, vc)
    assert np.abs(np.asarray(logits) - np.asarray(logits2)).max() > 1e-3


def test_probe_argmax_points_into_visible_region():
    rng = np.random.default_rng(11)
    kc, vc = empty_cache(DRAFT)
    s = 8
    toks = jnp.asarray(rng.integers(2, 512, size=s), jnp.int32)
    feats = jnp.asarray(rng.normal(size=(s, FEAT_DIM)), jnp.float32)
    t = 0
    mask = causal_mask(s, t)
    pos = jnp.arange(s, dtype=jnp.int32)
    _, _, _, _, top1 = draft_block_forward(DP, toks, feats, pos, mask, kc, vc, with_probe=True)
    top1 = np.asarray(top1)
    assert top1.shape == (s, DRAFT.heads)
    for i in range(s):
        assert (top1[i] >= CACHE_CAP).all() and (top1[i] <= CACHE_CAP + i).all()


def test_params_roundtrip_flatten():
    flat = flatten_params(TP)
    rebuilt = unflatten_params(flat)
    for k, v in flatten_params(rebuilt).items():
        np.testing.assert_array_equal(v, flat[k])
