"""AOT export-path tests: HLO text properties, golden input parity, and
manifest completeness. Uses tiny random weights (never retrains)."""

import json
import os

import numpy as np
import jax
import pytest

from compile import aot, grammar
from compile.config import CACHE_CAP, DRAFT, FEAT_DIM, TEACHER, VOCAB
from compile.model import init_draft, init_teacher


def test_hlo_text_contains_full_constants():
    """The text round-trip must carry the checkpoint: elided constants
    (`constant({...})`) would silently destroy the weights."""
    params = init_teacher(0)
    lowered = jax.jit(aot.teacher_fn(params, fused=False, probe=False)).lower(
        *aot.teacher_specs(8))
    text = aot.to_hlo_text(lowered)
    assert "constant({...})" not in text
    assert len(text) > 5_000_000  # ~1.1M f32 weights in text form
    assert "ENTRY" in text


def test_teacher_specs_shapes():
    specs = aot.teacher_specs(16)
    assert specs[0].shape == (16,)
    assert specs[2].shape == (16, CACHE_CAP + 16)
    assert specs[3].shape == (TEACHER.layers, CACHE_CAP, TEACHER.heads, TEACHER.d_head)


def test_draft_specs_include_feats():
    specs = aot.draft_specs(8)
    assert specs[1].shape == (8, FEAT_DIM)
    assert specs[4].shape == (DRAFT.layers, CACHE_CAP, DRAFT.heads, DRAFT.d_head)


def test_golden_inputs_deterministic_stream():
    a = aot.golden_inputs("teacher")
    b = aot.golden_inputs("teacher")
    for x, y in zip(a, b):
        if x is None:
            assert y is None
        else:
            np.testing.assert_array_equal(x, y)
    # the stream constants are mirrored in rust/src/runtime/golden.rs
    st = aot.Stream(aot.GOLDEN_SEED)
    assert a[0][0] == 2 + st.next_u64() % (VOCAB - 2)


def test_stream_f32_matches_rust_convention():
    st = aot.Stream(1)
    v = st.f32()
    assert -1.0 <= v < 1.0
    # reproduce manually: (u >> 40) / 2^24 * 2 - 1
    st2 = aot.Stream(1)
    u = st2.next_u64()
    assert v == (u >> 40) / float(1 << 24) * 2.0 - 1.0


def test_probe_variant_has_fifth_output():
    params = init_draft(0)
    fn = aot.draft_fn(params, probe=True)
    gi = aot.golden_inputs("draft")
    outs = jax.jit(fn)(gi[0], gi[1], gi[2], gi[3], gi[4], gi[5])
    assert len(outs) == 5
    assert outs[4].shape == (aot.GOLDEN_S, DRAFT.heads)


ARTIFACTS = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
                    reason="artifacts not built")
def test_built_manifest_is_complete():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        m = json.load(f)
    assert m["contract"]["vocab"] == VOCAB
    assert m["contract"]["cache_cap"] == CACHE_CAP
    names = {a["name"] for a in m["artifacts"]}
    for s in m["contract"]["teacher_s_variants"]:
        assert f"teacher_fused_s{s}" in names
        assert f"teacher_eager_s{s}" in names
    for s in m["contract"]["draft_s_variants"]:
        assert f"draft_s{s}" in names
    # grammar parity vectors present for the rust mirror
    assert m["grammar_vectors"]["splitmix64"][0]["y"] == grammar.splitmix64(0)
    for f_ in m["artifacts"]:
        assert os.path.exists(os.path.join(ARTIFACTS, f_["file"]))
