"""Training-pipeline smoke tests: tiny step counts, real code paths."""

import numpy as np
import jax.numpy as jnp

from compile import train
from compile.config import FEAT_DIM, PAD_ID, VOCAB
from compile.model import init_draft, init_teacher


def test_make_batches_shape_and_vocab():
    data = train.make_batches(2, 4, 32, seed=1)
    assert data.shape == (2, 4, 32)
    assert data.min() >= 1 and data.max() < VOCAB
    assert (data[:, :, 0] == 1).all()  # BOS


def test_adam_reduces_quadratic_loss():
    import jax

    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = train.adam_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt = train.adam_update(params, g, opt, lr=0.1)
    assert float(loss(params)) < 1e-2


def test_cosine_lr_schedule_shape():
    base = 1e-3
    assert train.cosine_lr(base, 0, 100) < base  # warmup
    assert abs(train.cosine_lr(base, 20, 100) - base) < 1e-9
    assert train.cosine_lr(base, 99, 100) < base * 0.01


def test_teacher_short_training_reduces_loss():
    import jax

    params = init_teacher(0)

    def loss_fn(p, toks):
        logits, _ = train.teacher_train_forward(p, toks)
        tgt = toks[:, 1:]
        lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        msk = (tgt != PAD_ID).astype(jnp.float32)
        return jnp.sum(nll * msk) / jnp.sum(msk)

    data = train.make_batches(6, 4, 64, seed=3)
    opt = train.adam_init(params)

    @jax.jit
    def step(p, o, t):
        loss, grads = jax.value_and_grad(loss_fn)(p, t)
        p, o = train.adam_update(p, grads, o, 2e-3)
        return p, o, loss

    first = float(loss_fn(params, jnp.asarray(data[0])))
    for i in range(6):
        params, opt, _ = step(params, opt, jnp.asarray(data[i]))
    last = float(loss_fn(params, jnp.asarray(data[0])))
    assert last < first - 0.3, f"{first} -> {last}"


def test_draft_distill_step_runs_and_improves():
    import jax

    teacher = init_teacher(0)
    draft = init_draft(1)
    data = train.make_batches(4, 4, 48, seed=5)

    def dloss(dp, toks, feats_prev, t_logits):
        d_logits = train.draft_train_forward(dp, toks, feats_prev)
        t_lp = jax.nn.log_softmax(t_logits, axis=-1)
        d_lp = jax.nn.log_softmax(d_logits, axis=-1)
        return float(jnp.mean(-jnp.sum(jnp.exp(t_lp) * d_lp, axis=-1)))

    toks = jnp.asarray(data[0])
    t_logits, t_feats = train.teacher_train_forward(teacher, toks)
    feats_prev = jnp.concatenate(
        [jnp.zeros((4, 1, FEAT_DIM), jnp.float32), t_feats[:, :-1]], axis=1)

    opt = train.adam_init(draft)
    grad_fn = jax.jit(jax.value_and_grad(
        lambda dp: jnp.mean(-jnp.sum(
            jnp.exp(jax.nn.log_softmax(t_logits, axis=-1))
            * jax.nn.log_softmax(train.draft_train_forward(dp, toks, feats_prev), axis=-1),
            axis=-1))))
    first, _ = grad_fn(draft)
    for _ in range(8):
        loss, g = grad_fn(draft)
        draft, opt = train.adam_update(draft, g, opt, 3e-3)
    last, _ = grad_fn(draft)
    assert float(last) < float(first) - 0.05


def test_agreement_metric_bounds():
    teacher = init_teacher(0)
    draft = init_draft(1)
    agree = train.draft_agreement(teacher, draft, batch=4, seqlen=32, seed=9)
    assert 0.0 <= agree <= 1.0
