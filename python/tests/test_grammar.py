"""Grammar substrate tests: determinism, profile shape, topic (long-range)
structure, and cross-language parity vectors."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import grammar
from compile.config import BOS_ID, FIRST_TOKEN, VOCAB


def test_splitmix64_known_values():
    # Reference value from the canonical splitmix64 (input 0).
    assert grammar.splitmix64(0) == 0xE220A8397B1DCDAF


def test_dist_deterministic():
    assert grammar.dist(17, 305, 3, "code") == grammar.dist(17, 305, 3, "code")


def test_dist_in_vocab_unique_weights():
    for b in range(2, 120, 7):
        for tid in range(grammar.NUM_TOPICS):
            for p in ("code", "chat"):
                toks, w = grammar.dist(5, b, tid, p)
                assert len(toks) == len(set(toks))
                assert len(toks) == len(w)
                assert sum(w) == 256
                assert all(FIRST_TOKEN <= t < VOCAB for t in toks)


def test_rotation_depends_on_a():
    """Order-2 effect: for a branching context, the preferred continuation
    must change with the second-previous token."""
    found = False
    for b in range(2, 200):
        for tid in range(4):
            base = grammar.base_candidates(b, tid, "chat")
            if len(base) >= 2:
                t0 = grammar.greedy_next(0, b, tid, "chat")
                t1 = grammar.greedy_next(1, b, tid, "chat")
                assert t0 != t1
                found = True
                break
        if found:
            break
    assert found


def test_topic_changes_candidates():
    """Long-range effect: different topic => (usually) different candidates."""
    diffs = 0
    for b in range(2, 60):
        if grammar.base_candidates(b, 0, "code") != grammar.base_candidates(b, 1, "code"):
            diffs += 1
    assert diffs > 40  # almost every context differs across topics


def test_profiles_differ_in_branching():
    def mean_branching(profile):
        ns = [len(grammar.base_candidates(b, tid, profile))
              for b in range(2, 200) for tid in range(8)]
        return np.mean(ns)

    assert mean_branching("chat") > mean_branching("code") + 0.2


def test_sample_sequence_shape_and_bos():
    seq = grammar.sample_sequence(64, "chat", seed=7)
    assert len(seq) == 64
    assert seq[0] == BOS_ID
    assert all(FIRST_TOKEN <= t < VOCAB for t in seq[1:])


def test_sample_sequence_seeded_reproducible():
    assert grammar.sample_sequence(64, "code", 3) == grammar.sample_sequence(64, "code", 3)
    assert grammar.sample_sequence(64, "code", 3) != grammar.sample_sequence(64, "code", 4)


def test_sample_sequence_fixed_topic():
    seq = grammar.sample_sequence(32, "code", 5, topic_token=100)
    assert seq[1] == 100


@settings(max_examples=30, deadline=None)
@given(a=st.integers(1, VOCAB - 1), b=st.integers(2, VOCAB - 1),
       tid=st.integers(0, grammar.NUM_TOPICS - 1),
       p=st.sampled_from(["code", "chat"]), seed=st.integers(0, 2**62))
def test_sampled_token_is_a_candidate(a, b, tid, p, seed):
    toks, _ = grammar.dist(a, b, tid, p)
    t, _ = grammar.sample_next(a, b, tid, p, seed)
    assert t in toks


def test_greedy_continuation_follows_preference_order():
    pre = [BOS_ID, 50, 9]
    tid = grammar.topic_of(50)
    cont = grammar.greedy_continuation(pre, 5, "code")
    a, b = pre[-2], pre[-1]
    for t in cont:
        assert t == grammar.greedy_next(a, b, tid, "code")
        a, b = b, t


def test_continue_sequence_consistent_with_dist():
    pre = grammar.sample_sequence(16, "chat", 9)
    cont = grammar.continue_sequence(pre, 10, "chat", seed=3)
    tid = grammar.topic_of(pre[1])
    a, b = pre[-2], pre[-1]
    for t in cont:
        assert t in grammar.dist(a, b, tid, "chat")[0]
        a, b = b, t


def test_parity_vectors_stable():
    vec = grammar.grammar_test_vectors()
    assert vec["splitmix64"][0]["y"] == grammar.splitmix64(0)
    for c in vec["dist"]:
        toks, w = grammar.dist(c["a"], c["b"], c["topic"], c["profile"])
        assert toks == c["toks"] and w == c["w256"]
    for s in vec["sequence"]:
        assert grammar.sample_sequence(24, s["profile"], s["seed"]) == s["seq"]
