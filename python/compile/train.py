"""Build-time training: teacher CE on the grammar corpus, then EAGLE-style
draft distillation against teacher features + logits.

This is the reproduction's stand-in for "obtain a Pangu teacher checkpoint
and an EAGLE-3 draft checkpoint" (repro band 0: neither is available). A
*trained* teacher/draft pair is required — random weights would produce
near-zero acceptance and none of the paper's dynamics (accept_L ~ 3,
position-wise decay, truncation sensitivity) would be reproducible.

Runs once from `make artifacts`; checkpoints are cached in artifacts/ and
reused unless --force. Never imported at runtime.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from . import grammar
from .config import FEAT_DIM, PAD_ID, VOCAB
from .model import (
    draft_train_forward,
    init_draft,
    init_teacher,
    load_params,
    save_params,
    teacher_train_forward,
)


# ----------------------------------------------------------------------
# Hand-rolled Adam (optax is not available in this image)
# ----------------------------------------------------------------------

def adam_init(params):
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, zeros), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def cosine_lr(base: float, step: int, total: int, warmup: int = 20) -> float:
    if step < warmup:
        return base * (step + 1) / warmup
    p = (step - warmup) / max(1, total - warmup)
    return base * 0.5 * (1 + np.cos(np.pi * p))


# ----------------------------------------------------------------------
# Data
# ----------------------------------------------------------------------

def make_batches(num: int, batch: int, seqlen: int, seed: int):
    """Mixed-profile (code/chat) grammar batches, [num, batch, seqlen] i32."""
    out = np.zeros((num, batch, seqlen), np.int32)
    for i in range(num):
        for j in range(batch):
            profile = "code" if (i * batch + j) % 2 == 0 else "chat"
            seq = grammar.sample_sequence(seqlen, profile, grammar.splitmix64(seed) ^ (i * batch + j))
            out[i, j] = seq
    return out


# ----------------------------------------------------------------------
# Teacher
# ----------------------------------------------------------------------

def train_teacher(steps: int, batch: int, seqlen: int, lr: float, seed: int, log):
    params = init_teacher(seed)

    def loss_fn(p, toks):
        logits, _ = teacher_train_forward(p, toks)
        tgt = toks[:, 1:]
        lg = logits[:, :-1]
        lp = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        msk = (tgt != PAD_ID).astype(jnp.float32)
        return jnp.sum(nll * msk) / jnp.sum(msk)

    @jax.jit
    def step_fn(p, opt, toks, lr_now):
        loss, grads = jax.value_and_grad(loss_fn)(p, toks)
        p, opt = adam_update(p, grads, opt, lr_now)
        return p, opt, loss

    opt = adam_init(params)
    data = make_batches(steps, batch, seqlen, seed=seed * 7919 + 13)
    t0 = time.time()
    for i in range(steps):
        lr_now = cosine_lr(lr, i, steps)
        params, opt, loss = step_fn(params, opt, jnp.asarray(data[i]), lr_now)
        if i % 50 == 0 or i == steps - 1:
            log(f"[teacher] step {i:4d} loss {float(loss):.4f} lr {lr_now:.2e} "
                f"({time.time() - t0:.1f}s)")
    return params


def teacher_top1_accuracy(params, batch: int, seqlen: int, seed: int) -> float:
    """Fraction of positions where teacher argmax == grammar-likeliest token."""
    data = make_batches(1, batch, seqlen, seed)[0]
    logits, _ = jax.jit(teacher_train_forward)(params, jnp.asarray(data))
    pred = np.asarray(jnp.argmax(logits, axis=-1))
    hit = tot = 0
    for j in range(batch):
        profile = "code" if j % 2 == 0 else "chat"
        seq = data[j]
        tid = grammar.topic_of(int(seq[1]))
        # prediction at position p targets x_{p+1}, grammar context
        # (a=seq[p-1], b=seq[p], topic); skip p=0 (topic token is uniform).
        for p in range(1, seqlen - 1):
            best = grammar.greedy_next(int(seq[p - 1]), int(seq[p]), tid, profile)
            hit += int(pred[j, p] == best)
            tot += 1
    return hit / tot


# ----------------------------------------------------------------------
# Draft distillation
# ----------------------------------------------------------------------

def distill_draft(teacher_params, steps: int, batch: int, seqlen: int, lr: float, seed: int, log):
    params = init_draft(seed + 1)
    teacher_fwd = jax.jit(teacher_train_forward)

    def loss_fn(p, toks, feats_prev, t_logits):
        d_logits = draft_train_forward(p, toks, feats_prev)
        t_lp = jax.nn.log_softmax(t_logits, axis=-1)
        d_lp = jax.nn.log_softmax(d_logits, axis=-1)
        # soft CE (forward KL up to teacher-entropy constant), pad-masked
        ce = -jnp.sum(jnp.exp(t_lp) * d_lp, axis=-1)
        msk = (toks != PAD_ID).astype(jnp.float32)
        return jnp.sum(ce * msk) / jnp.sum(msk)

    @jax.jit
    def step_fn(p, opt, toks, feats_prev, t_logits, lr_now):
        loss, grads = jax.value_and_grad(loss_fn)(p, toks, feats_prev, t_logits)
        p, opt = adam_update(p, grads, opt, lr_now)
        return p, opt, loss

    opt = adam_init(params)
    data = make_batches(steps, batch, seqlen, seed=seed * 104729 + 17)
    t0 = time.time()
    for i in range(steps):
        toks = jnp.asarray(data[i])
        t_logits, t_feats = teacher_fwd(teacher_params, toks)
        # draft input at position p: (e(x_p), teacher feat of position p-1)
        feats_prev = jnp.concatenate(
            [jnp.zeros((batch, 1, FEAT_DIM), jnp.float32), t_feats[:, :-1]], axis=1)
        lr_now = cosine_lr(lr, i, steps)
        params, opt, loss = step_fn(params, opt, toks, feats_prev, t_logits, lr_now)
        if i % 50 == 0 or i == steps - 1:
            log(f"[draft]   step {i:4d} soft-CE {float(loss):.4f} lr {lr_now:.2e} "
                f"({time.time() - t0:.1f}s)")
    return params


def draft_agreement(teacher_params, draft_params, batch: int, seqlen: int, seed: int) -> float:
    """Argmax agreement between draft and teacher at distillation inputs —
    an upper-bound proxy for depth-1 acceptance probability."""
    data = make_batches(1, batch, seqlen, seed)[0]
    toks = jnp.asarray(data)
    t_logits, t_feats = jax.jit(teacher_train_forward)(teacher_params, toks)
    feats_prev = jnp.concatenate(
        [jnp.zeros((batch, 1, FEAT_DIM), jnp.float32), t_feats[:, :-1]], axis=1)
    d_logits = jax.jit(draft_train_forward)(draft_params, toks, feats_prev)
    ta = np.asarray(jnp.argmax(t_logits, axis=-1))
    da = np.asarray(jnp.argmax(d_logits, axis=-1))
    valid = np.asarray(toks) != PAD_ID
    return float((ta == da)[valid].mean())


# ----------------------------------------------------------------------
# Entry
# ----------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--teacher-steps", type=int, default=900)
    ap.add_argument("--draft-steps", type=int, default=500)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seqlen", type=int, default=128)
    ap.add_argument("--teacher-lr", type=float, default=2e-3)
    ap.add_argument("--draft-lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    t_path = os.path.join(args.out_dir, "weights_teacher.npz")
    d_path = os.path.join(args.out_dir, "weights_draft.npz")
    stats_path = os.path.join(args.out_dir, "train_stats.json")
    log = print

    if os.path.exists(t_path) and os.path.exists(d_path) and not args.force:
        log(f"checkpoints exist in {args.out_dir}; skipping training (--force to retrain)")
        return

    log("=== training TinyPangu teacher on grammar corpus ===")
    teacher = train_teacher(args.teacher_steps, args.batch, args.seqlen, args.teacher_lr, args.seed, log)
    acc = teacher_top1_accuracy(teacher, args.batch, args.seqlen, seed=999)
    log(f"[teacher] grammar-top1 accuracy: {acc:.3f}")
    save_params(t_path, teacher)

    log("=== distilling TinyEagle draft ===")
    draft = distill_draft(teacher, args.draft_steps, args.batch, args.seqlen, args.draft_lr, args.seed, log)
    agree = draft_agreement(teacher, draft, args.batch, args.seqlen, seed=998)
    log(f"[draft] teacher-argmax agreement: {agree:.3f}")
    save_params(d_path, draft)

    with open(stats_path, "w") as f:
        json.dump({"teacher_grammar_top1": acc, "draft_teacher_agreement": agree,
                   "teacher_steps": args.teacher_steps, "draft_steps": args.draft_steps,
                   "batch": args.batch, "seqlen": args.seqlen}, f, indent=2)
    log(f"wrote {t_path}, {d_path}, {stats_path}")


if __name__ == "__main__":
    main()


def _reload_checkpoints(out_dir: str):
    """Helper for tests/aot: load cached checkpoints."""
    return (load_params(os.path.join(out_dir, "weights_teacher.npz")),
            load_params(os.path.join(out_dir, "weights_draft.npz")))
