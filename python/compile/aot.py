"""AOT export: lower every (role, mode, S) model variant to HLO *text*.

HLO text — NOT `lowered.compiler_ir("hlo")` protos and NOT `.serialize()` —
is the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the rust `xla` crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/gen_hlo.py).

Checkpoint weights are baked into the HLO as constants: the rust runtime
then feeds only per-call tensors (tokens/positions/mask/caches), keeping
the FFI surface small and the request path free of parameter shuffling.

Also emits:
  * artifacts/manifest.json — dims/contract constants + artifact table
    (validated by the rust runtime at load time) + grammar parity vectors.
  * artifacts/golden.json — procedurally-seeded input/output fixtures for
    the rust runtime smoke tests (inputs are regenerated in rust from the
    same splitmix64 stream; outputs compared against these values).

Python runs ONCE at build time; never on the request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import grammar
from .config import (
    CACHE_CAP,
    DRAFT,
    DRAFT_S_VARIANTS,
    FEAT_DIM,
    TEACHER,
    TEACHER_S_VARIANTS,
    VOCAB,
)
from .kernels.ref import NEG_INF
from .model import draft_block_forward, load_params, teacher_block_forward

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default elides big weight constants as
    # `constant({...})`, which silently destroys the baked-in checkpoint on
    # the text round-trip. (Found the hard way; see DESIGN.md §7.)
    return comp.as_hlo_text(print_large_constants=True)


# ----------------------------------------------------------------------
# Module builders
# ----------------------------------------------------------------------

def _device_params(params):
    return jax.tree_util.tree_map(jnp.asarray, params)


def teacher_fn(params, fused: bool, probe: bool):
    params = _device_params(params)

    def fn(tokens, positions, mask, k_cache, v_cache):
        return teacher_block_forward(params, tokens, positions, mask, k_cache,
                                     v_cache, fused=fused, with_probe=probe)
    return fn


def draft_fn(params, probe: bool):
    params = _device_params(params)

    def fn(tokens, feats_in, positions, mask, k_cache, v_cache):
        return draft_block_forward(params, tokens, feats_in, positions, mask,
                                   k_cache, v_cache, with_probe=probe)
    return fn


def teacher_fused_batch_fn(params, b: int, s: int, fused: bool):
    """Fused [B, S] teacher verification: one launch verifies B requests.

    Input layout matches the rust FusedVerifier staging (ARCHITECTURE §10):
    tokens/positions are flat [B*S] (request b owns rows [b*S, (b+1)*S)),
    the mask is [B, S, cap+S], the caches are stacked per-request
    [B, L, cap, H, Dh]. Outputs are re-laid to the fused StepScratch
    layout: logits [B*S, V], feats [B*S, F], k/v_new [L, B*S, H, Dh].
    Cross-request isolation is structural (vmap over the batch axis).
    """
    params = _device_params(params)

    def fn(tokens, positions, mask, k_cache, v_cache):
        tk = tokens.reshape(b, s)
        ps = positions.reshape(b, s)

        def one(t, p, m, kc, vc):
            return teacher_block_forward(params, t, p, m, kc, vc,
                                         fused=fused, with_probe=False)

        logits, feats, k_new, v_new = jax.vmap(one)(tk, ps, mask, k_cache, v_cache)
        logits = logits.reshape(b * s, logits.shape[-1])
        feats = feats.reshape(b * s, feats.shape[-1])
        # [B, L, S, H, Dh] -> [L, B*S, H, Dh]
        layers, heads, d_head = k_new.shape[1], k_new.shape[3], k_new.shape[4]
        k_new = jnp.transpose(k_new, (1, 0, 2, 3, 4)).reshape(layers, b * s, heads, d_head)
        v_new = jnp.transpose(v_new, (1, 0, 2, 3, 4)).reshape(layers, b * s, heads, d_head)
        return logits, feats, k_new, v_new
    return fn


def kv_append_fn():
    """KV-session scatter update: write N delta rows into a resident cache.

    Inputs: (k_cache [L, cap, H, Dh], v_cache, rows [N] i32 logical row
    indices, delta_k [L, N, H, Dh], delta_v). Short deltas are padded by
    repeating their last (row, data) pair — duplicate indices re-write
    identical data, so padding is a no-op. Outputs the updated cache
    pair; the rust runtime retains the result buffers device-side
    (docs/ARCHITECTURE.md §10).
    """

    def fn(k_cache, v_cache, rows, delta_k, delta_v):
        k = k_cache.at[:, rows, :, :].set(delta_k)
        v = v_cache.at[:, rows, :, :].set(delta_v)
        return k, v

    return fn


def teacher_specs(s: int):
    d = TEACHER
    return (
        jax.ShapeDtypeStruct((s,), I32),                                 # tokens
        jax.ShapeDtypeStruct((s,), I32),                                 # positions
        jax.ShapeDtypeStruct((s, CACHE_CAP + s), F32),                   # mask
        jax.ShapeDtypeStruct((d.layers, CACHE_CAP, d.heads, d.d_head), F32),
        jax.ShapeDtypeStruct((d.layers, CACHE_CAP, d.heads, d.d_head), F32),
    )


def draft_specs(s: int):
    d = DRAFT
    return (
        jax.ShapeDtypeStruct((s,), I32),
        jax.ShapeDtypeStruct((s, FEAT_DIM), F32),
        jax.ShapeDtypeStruct((s,), I32),
        jax.ShapeDtypeStruct((s, CACHE_CAP + s), F32),
        jax.ShapeDtypeStruct((d.layers, CACHE_CAP, d.heads, d.d_head), F32),
        jax.ShapeDtypeStruct((d.layers, CACHE_CAP, d.heads, d.d_head), F32),
    )


def teacher_batch_specs(b: int, s: int):
    d = TEACHER
    return (
        jax.ShapeDtypeStruct((b * s,), I32),                              # tokens
        jax.ShapeDtypeStruct((b * s,), I32),                              # positions
        jax.ShapeDtypeStruct((b, s, CACHE_CAP + s), F32),                 # mask
        jax.ShapeDtypeStruct((b, d.layers, CACHE_CAP, d.heads, d.d_head), F32),
        jax.ShapeDtypeStruct((b, d.layers, CACHE_CAP, d.heads, d.d_head), F32),
    )


def kv_append_specs(dims, n: int):
    return (
        jax.ShapeDtypeStruct((dims.layers, CACHE_CAP, dims.heads, dims.d_head), F32),
        jax.ShapeDtypeStruct((dims.layers, CACHE_CAP, dims.heads, dims.d_head), F32),
        jax.ShapeDtypeStruct((n,), I32),                                  # row indices
        jax.ShapeDtypeStruct((dims.layers, n, dims.heads, dims.d_head), F32),
        jax.ShapeDtypeStruct((dims.layers, n, dims.heads, dims.d_head), F32),
    )


# Fused [B, S] teacher variants (rust: ModuleKey{b>1} -> teacher_fused_b{B}_s{S})
# and KV-session scatter widths. Small set: each module bakes the full
# weight constants (~MBs of HLO text), so only the serving sweet spots
# are compiled; the rust FusedVerifier splits wider groups.
FUSED_B_VARIANTS = [(2, 16), (4, 16), (4, 32)]
KV_APPEND_N = 64


# ----------------------------------------------------------------------
# Golden fixtures (rust smoke tests regenerate the same inputs)
# ----------------------------------------------------------------------

MASK64 = (1 << 64) - 1


class Stream:
    """splitmix64 stream; mirrored in rust/src/runtime/golden.rs."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def f32(self) -> float:
        return (self.next_u64() >> 40) / float(1 << 24) * 2.0 - 1.0

    def f32s(self, *shape) -> np.ndarray:
        n = int(np.prod(shape))
        return np.asarray([self.f32() for _ in range(n)], np.float32).reshape(shape)

    def token(self) -> int:
        return 2 + self.next_u64() % (VOCAB - 2)


GOLDEN_S = 8
GOLDEN_PREFIX = 16
GOLDEN_SEED = 0x5EED


def golden_inputs(role: str):
    """Procedural inputs for the S=8 golden case: committed prefix t=16,
    8 new tokens in a causal chain (a degenerate tree)."""
    st = Stream(GOLDEN_SEED)
    s, t = GOLDEN_S, GOLDEN_PREFIX
    d = TEACHER if role == "teacher" else DRAFT
    tokens = np.asarray([st.token() for _ in range(s)], np.int32)
    k_cache = st.f32s(d.layers, CACHE_CAP, d.heads, d.d_head)
    v_cache = st.f32s(d.layers, CACHE_CAP, d.heads, d.d_head)
    feats = st.f32s(s, FEAT_DIM) if role == "draft" else None
    positions = np.arange(t, t + s, dtype=np.int32)
    mask = np.full((s, CACHE_CAP + s), NEG_INF, np.float32)
    mask[:, :t] = 0.0
    for i in range(s):
        for j in range(i + 1):
            mask[i, CACHE_CAP + j] = 0.0
    return tokens, feats, positions, mask, k_cache, v_cache


def golden_record(name: str, fn, args) -> dict:
    outs = jax.jit(fn)(*args)
    logits = np.asarray(outs[0])
    feats = np.asarray(outs[1])
    k_new = np.asarray(outs[2])
    return {
        "module": name,
        "seed": GOLDEN_SEED,
        "prefix_len": GOLDEN_PREFIX,
        "s": GOLDEN_S,
        "logits_sample": [float(x) for x in logits[0, :8]],
        "logits_sum": float(logits.sum()),
        "logits_argmax_row0": int(logits[0].argmax()),
        "feats_sum": float(feats.sum()),
        "k_new_sum": float(k_new.sum()),
    }


# ----------------------------------------------------------------------
# Entry
# ----------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated module names")
    args = ap.parse_args()
    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    teacher = load_params(os.path.join(out_dir, "weights_teacher.npz"))
    draft = load_params(os.path.join(out_dir, "weights_draft.npz"))

    modules = {}
    for s in TEACHER_S_VARIANTS:
        modules[f"teacher_fused_s{s}"] = (teacher_fn(teacher, fused=True, probe=False), teacher_specs(s))
        modules[f"teacher_eager_s{s}"] = (teacher_fn(teacher, fused=False, probe=False), teacher_specs(s))
    for s in DRAFT_S_VARIANTS:
        modules[f"draft_s{s}"] = (draft_fn(draft, probe=False), draft_specs(s))
    # Analysis-only probe variants (paper Fig 7 attention evidence).
    modules["draft_probe_s8"] = (draft_fn(draft, probe=True), draft_specs(8))
    modules["draft_probe_s32"] = (draft_fn(draft, probe=True), draft_specs(32))
    # Fused [B, S] verification variants (one launch per batched group).
    for b, s in FUSED_B_VARIANTS:
        modules[f"teacher_fused_b{b}_s{s}"] = (
            teacher_fused_batch_fn(teacher, b, s, fused=True),
            teacher_batch_specs(b, s),
        )
    # KV-session scatter-update modules (device-resident cache appends).
    modules[f"kv_append_teacher_n{KV_APPEND_N}"] = (
        kv_append_fn(), kv_append_specs(TEACHER, KV_APPEND_N))
    modules[f"kv_append_draft_n{KV_APPEND_N}"] = (
        kv_append_fn(), kv_append_specs(DRAFT, KV_APPEND_N))

    only = set(args.only.split(",")) if args.only else None
    artifact_table = []
    for name, (fn, specs) in modules.items():
        if only and name not in only:
            continue
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        artifact_table.append({
            "name": name,
            "file": f"{name}.hlo.txt",
            "sha256_16": digest,
            "bytes": len(text),
            "inputs": [list(sp.shape) for sp in specs],
        })
        print(f"wrote {path} ({len(text) / 1e6:.1f} MB)")

    # Golden fixtures for the rust runtime smoke test.
    tk, _, pos, msk, kc, vc = golden_inputs("teacher")
    dtk, dfe, dpos, dmsk, dkc, dvc = golden_inputs("draft")
    goldens = [
        golden_record("teacher_fused_s8", teacher_fn(teacher, True, False), (tk, pos, msk, kc, vc)),
        golden_record("teacher_eager_s8", teacher_fn(teacher, False, False), (tk, pos, msk, kc, vc)),
        golden_record("draft_s8", draft_fn(draft, False), (dtk, dfe, dpos, dmsk, dkc, dvc)),
    ]
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(goldens, f, indent=2)

    manifest = {
        "contract": {
            "vocab": VOCAB,
            "cache_cap": CACHE_CAP,
            "feat_dim": FEAT_DIM,
            "teacher": {"layers": TEACHER.layers, "d_model": TEACHER.d_model,
                        "heads": TEACHER.heads, "d_head": TEACHER.d_head},
            "draft": {"layers": DRAFT.layers, "d_model": DRAFT.d_model,
                      "heads": DRAFT.heads, "d_head": DRAFT.d_head},
            "teacher_s_variants": list(TEACHER_S_VARIANTS),
            "draft_s_variants": list(DRAFT_S_VARIANTS),
            "neg_inf": NEG_INF,
            "teacher_inputs": ["tokens[s]i32", "positions[s]i32", "mask[s,cap+s]f32",
                               "k_cache[L,cap,H,Dh]f32", "v_cache[L,cap,H,Dh]f32"],
            "teacher_outputs": ["logits[s,V]", "feats[s,F]", "k_new[L,s,H,Dh]", "v_new[L,s,H,Dh]"],
            "draft_inputs": ["tokens[s]i32", "feats_in[s,F]f32", "positions[s]i32",
                             "mask[s,cap+s]f32", "k_cache[L,cap,H,Dh]f32", "v_cache[L,cap,H,Dh]f32"],
            "draft_outputs": ["logits[s,V]", "hidden[s,F]", "k_new[L,s,H,Dh]", "v_new[L,s,H,Dh]"],
        },
        "artifacts": artifact_table,
        "grammar_vectors": grammar.grammar_test_vectors(),
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest ({len(artifact_table)} modules) + golden fixtures")


if __name__ == "__main__":
    main()
