"""L1: fused tree-masked attention as a Pallas kernel.

This is the reproduction's analogue of the Ascend fused attention kernel the
paper targets (§3.3). The hardware adaptation (DESIGN.md §4) re-thinks the
Ascend kernel for a TPU-shaped memory system rather than porting it:

  * The speculative query block (S ≤ 256 rows) stays VMEM-resident for the
    whole kernel instance; KV and the additive tree mask are streamed in
    KV_CHUNK-column tiles via the grid + BlockSpec index maps — the
    BlockSpec analogue of the Ascend kernel's tiled mask consumption.
  * Softmax is computed online (flash-style): per-chunk partial max /
    normalizer / weighted-value accumulators are carried in VMEM scratch
    across the innermost (sequential) grid dimension.
  * Contractions are shaped [S, Dh] x [Dh, CHUNK] so the MXU sees wide lane
    tiles; Dh = 32 is padded into lanes by the compiler.

Strictness contract (what makes this the "fused" path): T must be a
multiple of KV_CHUNK, the mask must be pre-broadcast to [S, T], and every
gather feeding this kernel must be in-bounds — exactly the class of
requirements the paper attributes to fused kernels (§1, §2.5). The rust
tree tensorizer guarantees them by construction.

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO for execution while keeping
the Pallas block structure for the §Perf VMEM/MXU estimates.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
KV_CHUNK = 128


def _tree_attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, acc_ref, m_ref, l_ref):
    """One (head, kv-chunk) grid step of online-softmax tree attention.

    Refs (VMEM blocks):
      q_ref:    [1, S, Dh]     — query block for this head (grid-invariant).
      k_ref:    [1, CHUNK, Dh] — KV chunk j for this head.
      v_ref:    [1, CHUNK, Dh]
      mask_ref: [S, CHUNK]     — additive mask columns for chunk j.
      o_ref:    [1, S, Dh]     — output block (written on the last chunk).
      acc_ref:  [S, Dh] f32 scratch — running weighted-value accumulator.
      m_ref:    [S, 1]  f32 scratch — running row max.
      l_ref:    [S, 1]  f32 scratch — running normalizer.
    """
    j = pl.program_id(1)
    nchunks = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # [S, Dh]
    k = k_ref[0]  # [CHUNK, Dh]
    v = v_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=jnp.float32))

    # [S, CHUNK] chunk logits with the additive tree/prefix mask.
    s_chunk = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale + mask_ref[...]

    m_prev = m_ref[...]            # [S, 1]
    m_cur = jnp.max(s_chunk, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # Keep the running max finite for fully-masked rows (padded node slots)
    # so exp() below never sees (-inf) - (-inf).
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)

    p = jnp.exp(s_chunk - m_safe)                     # [S, CHUNK]
    alpha = jnp.exp(jnp.where(m_prev <= NEG_INF / 2, NEG_INF, m_prev) - m_safe)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)  # first contribution

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new

    @pl.when(j == nchunks - 1)
    def _finalize():
        # Fully-masked rows have l == 0; emit zeros (finite, discarded by
        # the validity mask on the rust side — "no leakage to padded slots").
        l = l_ref[...]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def tree_attention_fused(q, k, v, mask):
    """Fused tree attention: same contract as kernels.ref.tree_attention_ref.

    Args:
      q:    [S, H, Dh]
      k:    [T, H, Dh] with T % KV_CHUNK == 0 (caller pads, mask = NEG_INF).
      v:    [T, H, Dh]
      mask: [S, T] additive mask.
    Returns:
      [S, H, Dh]
    """
    s, h, dh = q.shape
    t = k.shape[0]
    assert t % KV_CHUNK == 0, f"fused kernel requires T % {KV_CHUNK} == 0, got {t}"
    nchunks = t // KV_CHUNK

    # Head-major layout so each grid step owns one head's tiles.
    qh = jnp.transpose(q, (1, 0, 2))  # [H, S, Dh]
    kh = jnp.transpose(k, (1, 0, 2))  # [H, T, Dh]
    vh = jnp.transpose(v, (1, 0, 2))

    out = pl.pallas_call(
        _tree_attn_kernel,
        grid=(h, nchunks),
        in_specs=[
            pl.BlockSpec((1, s, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, KV_CHUNK, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, KV_CHUNK, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((s, KV_CHUNK), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, s, dh), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((s, dh), jnp.float32),
            pltpu.VMEM((s, 1), jnp.float32),
            pltpu.VMEM((s, 1), jnp.float32),
        ],
        interpret=True,
    )(qh, kh, vh, mask)
    return jnp.transpose(out, (1, 0, 2))


def vmem_estimate_bytes(s: int, dh: int, chunk: int = KV_CHUNK) -> int:
    """Static VMEM footprint of one kernel instance (for DESIGN.md §Perf)."""
    f32 = 4
    q = s * dh * f32
    kv = 2 * chunk * dh * f32
    msk = s * chunk * f32
    scratch = (s * dh + 2 * s) * f32
    out = s * dh * f32
    return q + kv + msk + scratch + out
