"""Pure-jnp tree-masked attention — the "eager fallback" and pytest oracle.

This is the reproduction's analogue of the paper's eager attention path
(PANGU_DISABLE_NPU_FUSED=1): a forgiving reference implementation with no
tiling/alignment constraints, used (a) as the numeric oracle for the Pallas
kernel, and (b) lowered into the `teacher_eager_s*` artifacts that back the
rust runtime's `--mode eager` reference execution path.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def tree_attention_ref(q, k, v, mask):
    """Masked multi-head attention over a flat KV sequence.

    Args:
      q:    [S, H, Dh] queries for the S new (speculative) tokens.
      k:    [T, H, Dh] keys   (committed cache rows + the S new rows).
      v:    [T, H, Dh] values (same layout as k).
      mask: [S, T] additive mask (0 = visible, NEG_INF = hidden). Rows
            encode prefix visibility + the ancestor-only tree predicate.

    Returns:
      [S, H, Dh] attention outputs.
    """
    s, h, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    # [H, S, T]
    logits = jnp.einsum("shd,thd->hst", q, k) * scale
    logits = logits + mask[None, :, :]
    # Contract for fully-masked rows (padded node slots): emit zeros.
    # Softmax over an all -inf row would be NaN; padded slots are discarded
    # by the rust side via the validity mask, so their value only needs to
    # be finite and leak-free ("no leakage to padded slots", §3.3). The
    # fused kernel implements the same zero-row contract.
    row_max = jnp.max(logits, axis=-1, keepdims=True)
    dead = row_max <= NEG_INF / 2
    safe = jnp.where(dead, 0.0, logits - row_max)
    w = jnp.exp(safe)
    w = jnp.where(dead, 0.0, w)
    denom = jnp.sum(w, axis=-1, keepdims=True)
    w = w / jnp.where(denom == 0.0, 1.0, denom)
    return jnp.einsum("hst,thd->shd", w, v)
