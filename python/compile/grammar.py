"""Seeded stochastic token grammar — the synthetic corpus substrate.

The paper evaluates on MT-Bench conversational prompts and HumanEval-style
coding prompts. Neither is available here (repro band 0), so we substitute a
deterministic hash-derived grammar over token ids, engineered so that the
paper's *dynamics* are reproducible:

  * **Learnable**: the context space is small (~4k entries: previous token
    x 8 topics), so the 1.1M-param teacher memorizes it nearly perfectly
    while the 0.13M-param draft only partially does — producing the
    teacher/draft agreement gap that drives accept_L ~ 3.
  * **Local structure**: the candidate set for the next token depends on
    the previous token `b` and the sequence topic; the *preference order*
    additionally rotates with the second-previous token `a` — an order-2
    effect cheap to represent but impossible to ignore.
  * **Long-range structure**: the topic is carried by the single token at
    position 1 (right after BOS). A drafter whose context is truncated to
    a recent window loses the topic and its proposals collapse — the
    mechanism behind the paper's E4 negative result and the Fig-7
    "top-1 attention in far history" evidence.
  * Two profiles mirror the benchmark families: "code" (HumanEval-style,
    mostly deterministic) and "chat" (MT-Bench-style, broader branching).

Everything is derived from splitmix64 hashing so python (training corpus)
and rust (workload generator, rust/src/workload/grammar.rs) produce the
same language bit-for-bit; `grammar_test_vectors()` emits parity fixtures
checked by both test suites.
"""

from __future__ import annotations

from .config import BOS_ID, FIRST_TOKEN, VOCAB

MASK64 = (1 << 64) - 1
NUM_TOPICS = 8

# Per-profile seeds and branching tables. branch_w64[i] = weight (out of 64)
# of a context having (i+1) candidate continuations.
PROFILES = {
    "code": {"seed": 0x9E3779B97F4A7C15, "branch_w64": (44, 16, 4, 0)},
    "chat": {"seed": 0xC2B2AE3D27D4EB4F, "branch_w64": (22, 22, 13, 7)},
}

# Candidate probability profiles by candidate-set size, in 1/256 units,
# applied to the rotated preference order.
PROB_W256 = {
    1: (256,),
    2: (204, 52),
    3: (179, 51, 26),
    4: (153, 51, 31, 21),
}


def splitmix64(x: int) -> int:
    """Standard splitmix64 finalizer; mirrored exactly in rust."""
    x = (x + 0x9E3779B97F4A7C15) & MASK64
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return (z ^ (z >> 31)) & MASK64


def topic_of(topic_token: int) -> int:
    return topic_token % NUM_TOPICS


def context_hash(b: int, topic_id: int, profile: str) -> int:
    seed = PROFILES[profile]["seed"]
    return splitmix64((b * 0x100000001B3 ^ topic_id * 0x1000193 ^ seed) & MASK64)


def base_candidates(b: int, topic_id: int, profile: str) -> list[int]:
    """Unrotated candidate set for context (b, topic)."""
    h = context_hash(b, topic_id, profile)
    sel = h & 63
    n = 1
    acc = 0
    for i, w in enumerate(PROFILES[profile]["branch_w64"]):
        acc += w
        if sel < acc:
            n = i + 1
            break
    toks = []
    hh = h
    for i in range(n):
        hh = splitmix64(hh ^ (i + 1))
        t = FIRST_TOKEN + (hh % (VOCAB - FIRST_TOKEN))
        while t in toks:  # linear probe on collision
            t = FIRST_TOKEN + ((t - FIRST_TOKEN + 1) % (VOCAB - FIRST_TOKEN))
        toks.append(t)
    return toks


def dist(a: int, b: int, topic_id: int, profile: str) -> tuple[list[int], list[int]]:
    """Next-token candidates in preference order, with weights (1/256).

    The preference order is the base candidate list rotated by `a mod n`,
    so the most likely continuation depends on the second-previous token —
    an order-2 dependency over an order-1-sized context table.
    """
    toks = base_candidates(b, topic_id, profile)
    n = len(toks)
    rot = a % n
    toks = toks[rot:] + toks[:rot]
    return toks, list(PROB_W256[n])


def greedy_next(a: int, b: int, topic_id: int, profile: str) -> int:
    return dist(a, b, topic_id, profile)[0][0]


def sample_next(a: int, b: int, topic_id: int, profile: str, rng_state: int) -> tuple[int, int]:
    toks, w256 = dist(a, b, topic_id, profile)
    rng_state = splitmix64(rng_state)
    r = rng_state & 255
    acc = 0
    for t, w in zip(toks, w256):
        acc += w
        if r < acc:
            return t, rng_state
    return toks[-1], rng_state


def sample_topic_token(rng_state: int) -> tuple[int, int]:
    rng_state = splitmix64(rng_state)
    return FIRST_TOKEN + rng_state % (VOCAB - FIRST_TOKEN), rng_state


def sample_sequence(length: int, profile: str, seed: int,
                    topic_token: int | None = None) -> list[int]:
    """Sample `[BOS, topic, ...]` totalling `length` tokens."""
    state = splitmix64(seed ^ PROFILES[profile]["seed"])
    out = [BOS_ID]
    if topic_token is None:
        topic_token, state = sample_topic_token(state)
    if length > 1:
        out.append(topic_token)
    tid = topic_of(topic_token)
    a, b = BOS_ID, topic_token
    while len(out) < length:
        t, state = sample_next(a, b, tid, profile, state)
        out.append(t)
        a, b = b, t
    return out


def continue_sequence(prefix: list[int], n: int, profile: str, seed: int) -> list[int]:
    """Sample n more tokens continuing `prefix` (prefix[1] carries topic)."""
    assert len(prefix) >= 2, "need BOS + topic"
    tid = topic_of(prefix[1])
    a, b = prefix[-2], prefix[-1]
    state = splitmix64(seed ^ 0xA5A5A5A5)
    out = []
    for _ in range(n):
        t, state = sample_next(a, b, tid, profile, state)
        out.append(t)
        a, b = b, t
    return out


def greedy_continuation(prefix: list[int], n: int, profile: str) -> list[int]:
    """Most-likely continuation under the grammar (oracle for tests)."""
    assert len(prefix) >= 2, "need BOS + topic"
    tid = topic_of(prefix[1])
    a, b = prefix[-2], prefix[-1]
    out = []
    for _ in range(n):
        t = greedy_next(a, b, tid, profile)
        out.append(t)
        a, b = b, t
    return out


def corpus(num_seqs: int, seq_len: int, profile: str, seed: int) -> list[list[int]]:
    return [sample_sequence(seq_len, profile, splitmix64(seed ^ i)) for i in range(num_seqs)]


def grammar_test_vectors() -> dict:
    """Cross-language parity fixtures (also checked by rust unit tests)."""
    vec = {"splitmix64": [], "dist": [], "sequence": []}
    for x in (0, 1, 42, 0xDEADBEEF):
        vec["splitmix64"].append({"x": x, "y": splitmix64(x)})
    for (a, b, tid, p) in ((1, 2, 0, "code"), (1, 2, 0, "chat"),
                           (17, 305, 3, "code"), (444, 2, 7, "chat"),
                           (305, 17, 5, "chat")):
        toks, w = dist(a, b, tid, p)
        vec["dist"].append({"a": a, "b": b, "topic": tid, "profile": p,
                            "toks": toks, "w256": w})
    for (p, seed) in (("code", 11), ("chat", 12)):
        vec["sequence"].append({"profile": p, "seed": seed,
                                "seq": sample_sequence(24, p, seed)})
    return vec
