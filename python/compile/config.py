"""Shared numeric configuration for the EAGLE-Pangu reproduction.

These constants define the static-shape AOT contract between the python
compile path (L1/L2) and the rust coordinator (L3). The rust side mirrors
them in `rust/src/config/model.rs`; `aot.py` additionally dumps them into
`artifacts/manifest.json` so the rust runtime can validate at load time.
"""

import os
from dataclasses import dataclass


VOCAB = 512
PAD_ID = 0
BOS_ID = 1
# First "real" grammar token id (0 = pad, 1 = bos).
FIRST_TOKEN = 2

# KV-cache capacity (committed prefix + committed generation), per sequence.
# Baked into every artifact and recorded in the manifest; the rust runtime
# adopts whatever the manifest says. 512 fits the CPU-scaled two-turn
# workload while keeping the per-call KV literal transfer affordable
# (see DESIGN.md §Perf); must be a multiple of KV_CHUNK.
CACHE_CAP = int(os.environ.get("EAGLE_CACHE_CAP", "512"))

# Token-block (S) variants compiled per role. The teacher's largest variant
# must cover the largest speculative node budget in the paper's budget sweep
# (M = 256, Table 2) plus prefill chunking (S = 128).
TEACHER_S_VARIANTS = (8, 16, 32, 64, 128, 256)
DRAFT_S_VARIANTS = (8, 32, 64)

# KV columns fed to the fused kernel are padded up to a multiple of this so
# the Pallas kernel sees a uniform chunk grid.
KV_CHUNK = 128


@dataclass(frozen=True)
class ModelDims:
    """Transformer dimensions (decoder-only, RoPE, pre-LN)."""

    layers: int
    d_model: int
    heads: int
    d_head: int
    d_ff: int
    vocab: int = VOCAB

    @property
    def kv_heads(self) -> int:  # no GQA in this reproduction
        return self.heads


# Teacher ("TinyPangu"): stands in for the Pangu teacher backend.
TEACHER = ModelDims(layers=4, d_model=128, heads=4, d_head=32, d_ff=512)

# Draft ("TinyEagle"): EAGLE-style feature-conditioned drafter.
DRAFT = ModelDims(layers=1, d_model=64, heads=2, d_head=32, d_ff=256)

# Dimension of the feature channel the teacher exports for the draft
# (EAGLE's f_i). The teacher projects its last hidden state to this size;
# the draft consumes it alongside the token embedding and emits its own
# hidden state in the same space for depth >= 2 self-conditioning.
FEAT_DIM = DRAFT.d_model

ROPE_BASE = 10000.0


def padded_kv_len(s: int, cache_cap: int = CACHE_CAP, chunk: int = KV_CHUNK) -> int:
    """Total KV columns (cache + new tokens), padded to the kernel chunk."""
    t = cache_cap + s
    return ((t + chunk - 1) // chunk) * chunk
