"""L2: TinyPangu teacher + TinyEagle draft in JAX.

Two forward flavours share one set of per-layer weights:

  * `*_block_forward` — the **serving contract** lowered to HLO for the rust
    runtime: static token-block size S, cache capacity C, explicit
    `[S, C+S]` additive mask input, cache-in/KV-out (the model NEVER
    mutates a cache — the rust cache manager owns all writes; see
    DESIGN.md §2). Attention runs either through the fused Pallas kernel
    (kernels.tree_attention) or the eager jnp reference (kernels.ref),
    mirroring the paper's two-mode execution protocol (§4.1).

  * `*_train_forward` — batched causal forward used only by train.py.

Feature channel (EAGLE coupling): the teacher exports `feats[S, FEAT_DIM]`
(final hidden, layer-normed, projected D -> FEAT_DIM). The draft consumes a
feature per input token — the teacher feature of the *previous* position
for committed tokens, or the parent draft hidden for speculative depth >= 2
nodes — and emits its own hidden in the same space (EAGLE's recursive
feature surrogate).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .config import (
    DRAFT,
    FEAT_DIM,
    ModelDims,
    ROPE_BASE,
    TEACHER,
    padded_kv_len,
)
from .kernels.ref import NEG_INF, tree_attention_ref
from .kernels.tree_attention import tree_attention_fused


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def init_layer(rng: np.random.Generator, d: ModelDims) -> dict:
    s_attn = 1.0 / np.sqrt(d.d_model)
    s_ff = 1.0 / np.sqrt(d.d_ff)
    dm, nh, dh, ff = d.d_model, d.heads, d.d_head, d.d_ff
    return {
        "wq": rng.normal(0, s_attn, (dm, nh * dh)).astype(np.float32),
        "wk": rng.normal(0, s_attn, (dm, nh * dh)).astype(np.float32),
        "wv": rng.normal(0, s_attn, (dm, nh * dh)).astype(np.float32),
        "wo": rng.normal(0, s_attn, (nh * dh, dm)).astype(np.float32),
        "w1": rng.normal(0, s_attn, (dm, ff)).astype(np.float32),
        "w2": rng.normal(0, s_ff, (ff, dm)).astype(np.float32),
        "ln1": np.ones(dm, np.float32),
        "ln2": np.ones(dm, np.float32),
    }


def init_teacher(seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    d = TEACHER
    return {
        "embed": rng.normal(0, 0.02, (d.vocab, d.d_model)).astype(np.float32),
        "layers": [init_layer(rng, d) for _ in range(d.layers)],
        "ln_f": np.ones(d.d_model, np.float32),
        "head": rng.normal(0, 1 / np.sqrt(d.d_model), (d.d_model, d.vocab)).astype(np.float32),
        "w_feat": rng.normal(0, 1 / np.sqrt(d.d_model), (d.d_model, FEAT_DIM)).astype(np.float32),
    }


def init_draft(seed: int = 1) -> dict:
    rng = np.random.default_rng(seed)
    d = DRAFT
    return {
        "embed": rng.normal(0, 0.02, (d.vocab, d.d_model)).astype(np.float32),
        # fuse (token embedding, incoming feature) -> model width
        "w_in": rng.normal(0, 1 / np.sqrt(2 * d.d_model), (d.d_model + FEAT_DIM, d.d_model)).astype(np.float32),
        "layers": [init_layer(rng, d) for _ in range(d.layers)],
        "ln_f": np.ones(d.d_model, np.float32),
        "head": rng.normal(0, 1 / np.sqrt(d.d_model), (d.d_model, d.vocab)).astype(np.float32),
    }


def flatten_params(params, prefix="") -> dict:
    out = {}
    if isinstance(params, dict):
        for k, v in params.items():
            out.update(flatten_params(v, f"{prefix}{k}."))
    elif isinstance(params, list):
        for i, v in enumerate(params):
            out.update(flatten_params(v, f"{prefix}{i}."))
    else:
        out[prefix[:-1]] = np.asarray(params)
    return out


def unflatten_params(flat: dict):
    """Inverse of flatten_params (dict/list structure from key paths)."""
    root: dict = {}
    for key, val in flat.items():
        parts = key.split(".")
        cur = root
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.isdigit() for k in node):
            return [fix(node[str(i)]) for i in range(len(node))]
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def save_params(path: str, params) -> None:
    np.savez(path, **flatten_params(params))


def load_params(path: str):
    with np.load(path) as z:
        return unflatten_params({k: z[k] for k in z.files})


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------

def rms_norm(x, g):
    return x * g / jnp.sqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + 1e-6)


def rope(x, positions):
    """Rotary embedding. x: [..., S, H, Dh], positions: [S] int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (ROPE_BASE ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [S, half]
    cos = jnp.cos(ang)[..., None, :]  # [S, 1, half] broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _qkv(layer, x, d: ModelDims):
    s = x.shape[0]
    q = (x @ layer["wq"]).reshape(s, d.heads, d.d_head)
    k = (x @ layer["wk"]).reshape(s, d.heads, d.d_head)
    v = (x @ layer["wv"]).reshape(s, d.heads, d.d_head)
    return q, k, v


def _ffn(layer, x):
    return jax.nn.gelu(x @ layer["w1"]) @ layer["w2"]


# --------------------------------------------------------------------------
# Serving (block) forward — the AOT contract
# --------------------------------------------------------------------------

def _block_layers(params, d: ModelDims, h, positions, mask, k_cache, v_cache, fused: bool):
    """Shared cache-in / KV-out layer stack.

    h:        [S, D] input activations
    mask:     [S, C+S] additive
    k_cache:  [L, C, H, Dh] (post-RoPE keys; rows >= committed length are
              garbage but masked out by the rust-built mask)
    returns: (h_final, k_new [L,S,H,Dh], v_new [L,S,H,Dh], attn_top1 [S,H])
    """
    s = h.shape[0]
    cap = k_cache.shape[1]
    t_pad = padded_kv_len(s, cap)
    pad_cols = t_pad - (cap + s)
    attn_fn = tree_attention_fused if fused else tree_attention_ref
    k_news, v_news = [], []
    attn_top1 = None
    for li in range(d.layers):
        layer = params["layers"][li]
        xn = rms_norm(h, layer["ln1"])
        q, k, v = _qkv(layer, xn, d)
        q = rope(q, positions)
        k = rope(k, positions)
        k_news.append(k)
        v_news.append(v)
        k_full = jnp.concatenate([k_cache[li], k], axis=0)  # [C+S, H, Dh]
        v_full = jnp.concatenate([v_cache[li], v], axis=0)
        m = mask
        if fused and pad_cols > 0:
            # Fused kernel requires T % KV_CHUNK == 0: pad KV with zero rows
            # and the mask with NEG_INF columns (invisible by construction).
            k_in = jnp.pad(k_full, ((0, pad_cols), (0, 0), (0, 0)))
            v_in = jnp.pad(v_full, ((0, pad_cols), (0, 0), (0, 0)))
            m = jnp.pad(mask, ((0, 0), (0, pad_cols)), constant_values=NEG_INF)
        else:
            k_in, v_in = k_full, v_full
        o = attn_fn(q, k_in, v_in, m)  # [S, H, Dh]
        if li == d.layers - 1:
            # Analysis-only probe (paper Fig 7): per-head top-1 attention
            # column of the last layer, from masked logits (cheap argmax).
            scale = 1.0 / jnp.sqrt(jnp.asarray(d.d_head, jnp.float32))
            lg = jnp.einsum("shd,thd->sht", q, k_full) * scale
            lg = lg + mask[:, None, :]
            attn_top1 = jnp.argmax(lg, axis=-1).astype(jnp.int32)  # [S, H]
        h = h + o.reshape(s, d.heads * d.d_head) @ layer["wo"]
        h = h + _ffn(layer, rms_norm(h, layer["ln2"]))
    return h, jnp.stack(k_news), jnp.stack(v_news), attn_top1


def teacher_block_forward(params, tokens, positions, mask, k_cache, v_cache,
                          fused: bool, with_probe: bool = False):
    """Teacher serving step.

    tokens[S] i32, positions[S] i32, mask[S, C+S] f32,
    k_cache/v_cache [L, C, H, Dh] f32
    -> logits [S, V], feats [S, FEAT_DIM], k_new/v_new [L, S, H, Dh]
       (+ attn_top1 [S, H] when with_probe)
    """
    d = TEACHER
    h = params["embed"][tokens]
    h, k_new, v_new, top1 = _block_layers(params, d, h, positions, mask, k_cache, v_cache, fused)
    hn = rms_norm(h, params["ln_f"])
    logits = hn @ params["head"]
    feats = hn @ params["w_feat"]
    if with_probe:
        return logits, feats, k_new, v_new, top1
    return logits, feats, k_new, v_new


def draft_block_forward(params, tokens, feats_in, positions, mask, k_cache, v_cache,
                        with_probe: bool = False):
    """Draft serving step (eager attention only — the drafter is cheap).

    feats_in [S, FEAT_DIM]: teacher feature of the previous position
    (committed tokens) or parent draft hidden (speculative nodes).
    -> logits [S, V], hidden feats [S, FEAT_DIM], k_new/v_new [L, S, H, Dh]
    """
    d = DRAFT
    e = params["embed"][tokens]
    h = jnp.concatenate([e, feats_in], axis=-1) @ params["w_in"]
    h, k_new, v_new, top1 = _block_layers(params, d, h, positions, mask, k_cache, v_cache, fused=False)
    hn = rms_norm(h, params["ln_f"])
    logits = hn @ params["head"]
    if with_probe:
        return logits, hn, k_new, v_new, top1
    return logits, hn, k_new, v_new


# --------------------------------------------------------------------------
# Training forward (batched, causal) — build-time only
# --------------------------------------------------------------------------

def _train_layers(params, d: ModelDims, h):
    """Batched causal layer stack. h: [B, L, D] -> [B, L, D]."""
    b, l, _ = h.shape
    pos = jnp.arange(l, dtype=jnp.int32)
    causal = jnp.where(pos[None, :] <= pos[:, None], 0.0, NEG_INF)  # [L, L]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d.d_head, jnp.float32))
    for layer in params["layers"]:
        xn = rms_norm(h, layer["ln1"])
        q = (xn @ layer["wq"]).reshape(b, l, d.heads, d.d_head)
        k = (xn @ layer["wk"]).reshape(b, l, d.heads, d.d_head)
        v = (xn @ layer["wv"]).reshape(b, l, d.heads, d.d_head)
        q = rope(q, pos)
        k = rope(k, pos)
        lg = jnp.einsum("bshd,bthd->bhst", q, k) * scale + causal[None, None]
        w = jax.nn.softmax(lg, axis=-1)
        o = jnp.einsum("bhst,bthd->bshd", w, v).reshape(b, l, d.heads * d.d_head)
        h = h + o @ layer["wo"]
        h = h + _ffn(layer, rms_norm(h, layer["ln2"]))
    return h


def teacher_train_forward(params, tokens):
    """tokens [B, L] -> logits [B, L, V], feats [B, L, FEAT_DIM]."""
    h = params["embed"][tokens]
    h = _train_layers(params, TEACHER, h)
    hn = rms_norm(h, params["ln_f"])
    return hn @ params["head"], hn @ params["w_feat"]


def draft_train_forward(params, tokens, feats_prev):
    """tokens [B, L], feats_prev [B, L, FEAT_DIM] (teacher feat of position
    i-1, zeros at i=0) -> logits [B, L, V]."""
    e = params["embed"][tokens]
    h = jnp.concatenate([e, feats_prev], axis=-1) @ params["w_in"]
    h = _train_layers(params, DRAFT, h)
    hn = rms_norm(h, params["ln_f"])
    return hn @ params["head"]
