//! The branchable/commit KV-cache manager — paper §3.1, implemented as a
//! real memory-owning subsystem (the AOT models never write caches; every
//! KV row lands here).
//!
//! State machine per cache:
//!
//! ```text
//!   committed [0, len)  --begin_branch-->  branch region [len, len+b)
//!        ^                                        |
//!        |---- commit_length / commit_path <------|----- rollback
//! ```
//!
//! * [`crate::config::CacheStrategy::DeepCopy`] — the paper's conservative
//!   `Replicate(·) = deepcopy`: `begin_branch` clones the full committed
//!   buffers and all speculative writes and reads go through the clone.
//!   Correct and isolated, but moves `2 * L*cap*H*Dh * 4` bytes per
//!   verification step (the ablation baseline).
//! * [`crate::config::CacheStrategy::SegmentShare`] — branches share the
//!   committed prefix read-only; speculative rows are appended *past*
//!   `len` in the main buffers. Isolation holds because `len` only
//!   advances at commit, and every row past `len` is invisible to
//!   committed-state readers.
//!
//! Commit modes (paper §3.1):
//! * **length-based** — adopt the first `A` branch rows;
//! * **path-index-based** — rebuild the sequence as
//!   `rows[path_indices[i]]`; with `fast_reorder`, a prefix-preserving
//!   `path_indices` (the common case) skips the full gather and copies
//!   only the accepted tail (the paper's `EA_FAST_CACHE_REORDER`),
//!   falling back to the general gather on any inconsistency.

use crate::cache::{KvGuard, KvStore};
use crate::config::{CacheStrategy, Dims};
use anyhow::{bail, Result};

/// Movement/commit counters for the §3.1 ablations and §Perf.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CacheStats {
    /// Branches opened (`begin_branch` calls).
    pub branches: u64,
    /// Commits of any mode.
    pub commits: u64,
    /// Branches discarded without committing.
    pub rollbacks: u64,
    /// Bytes copied by branch replication (deepcopy only).
    pub replicate_bytes: u64,
    /// Bytes copied by speculative row appends.
    pub append_bytes: u64,
    /// Bytes moved by commits.
    pub commit_bytes: u64,
    /// Path-index commits served by the prefix-sharing fast reorder.
    pub fast_reorders: u64,
    /// Fast-reorder attempts that fell back to the full gather.
    pub fast_fallbacks: u64,
    /// Full-gather path-index commits.
    pub full_reorders: u64,
    /// Shared blocks privatized by copy-on-write before a divergent
    /// write (paged layout under prefix sharing; always 0 for flat).
    pub cow_copies: u64,
    /// Bytes copied by those copy-on-write privatizations.
    pub cow_bytes: u64,
    /// Committed rows adopted from shared frozen prefix blocks instead
    /// of being prefilled (paged layout under prefix sharing).
    pub adopted_rows: u64,
}

/// One KV cache (teacher or draft side) with branch/commit semantics.
pub struct ManagedCache {
    /// Transformer dimensions of the role this cache serves.
    pub dims: Dims,
    /// Sequence capacity (rows per layer).
    pub cap: usize,
    strategy: CacheStrategy,
    fast_reorder: bool,
    /// Committed length t.
    len: usize,
    /// Main buffers `[L, cap, H, Dh]`.
    k: Vec<f32>,
    v: Vec<f32>,
    /// DeepCopy working replica (None when no branch is open or when the
    /// strategy is SegmentShare).
    branch_k: Option<Vec<f32>>,
    branch_v: Option<Vec<f32>>,
    /// Speculative rows appended in the open branch.
    branch_rows: usize,
    branch_open: bool,
    /// Reusable gather scratch for the general prefix-preserving fast
    /// reorder (tail rows are tiny: <= M per commit). Kept across commits
    /// so the steady-state round performs no heap allocation.
    gather_k: Vec<f32>,
    gather_v: Vec<f32>,
    /// KV-session dirty watermark: first readable row whose contents may
    /// have changed since `mark_synced` (`usize::MAX` = clean). Every
    /// mutation lowers it conservatively via [`ManagedCache::taint`].
    dirty_lo: usize,
    /// Movement/commit counters (§3.1 ablations; reset with the cache).
    pub stats: CacheStats,
}

impl ManagedCache {
    /// An empty cache of `cap` rows for a role with dimensions `dims`.
    pub fn new(dims: Dims, cap: usize, strategy: CacheStrategy, fast_reorder: bool) -> Self {
        let n = dims.cache_elems(cap);
        Self {
            dims,
            cap,
            strategy,
            fast_reorder,
            len: 0,
            k: vec![0.0; n],
            v: vec![0.0; n],
            branch_k: None,
            branch_v: None,
            branch_rows: 0,
            branch_open: false,
            gather_k: Vec::new(),
            gather_v: Vec::new(),
            dirty_lo: 0,
            stats: CacheStats::default(),
        }
    }

    /// Lower the session dirty watermark to `row`: a mutation may have
    /// changed readable contents at or after it.
    #[inline]
    fn taint(&mut self, row: usize) {
        self.dirty_lo = self.dirty_lo.min(row);
    }

    /// Committed sequence length `t`.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether nothing has been committed yet.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The configured branch-replication strategy.
    pub fn strategy(&self) -> CacheStrategy {
        self.strategy
    }

    /// Speculative rows appended in the currently open branch.
    pub fn branch_rows(&self) -> usize {
        self.branch_rows
    }

    /// Free committed capacity.
    pub fn headroom(&self) -> usize {
        self.cap - self.len
    }

    /// Reset to an empty committed state (new conversation). Also zeroes
    /// the stats counters: `GenOut` reports per-generation cache stats,
    /// and a reused engine must match a fresh one field for field.
    ///
    /// The persistent gather scratch is re-clamped to the *current*
    /// capacity here: after a [`ManagedCache::set_capacity`] shrink it
    /// may still hold rows laid out for the larger buffer, and a later
    /// fast reorder must never index those stale rows (regression-tested
    /// below). Truncation never allocates, so engine reuse stays
    /// allocation-free.
    pub fn reset(&mut self) {
        self.taint(0);
        self.len = 0;
        self.branch_rows = 0;
        self.branch_open = false;
        self.branch_k = None;
        self.branch_v = None;
        self.stats = CacheStats::default();
        let bound = self.dims.layers * self.cap * self.rstride();
        self.gather_k.truncate(bound);
        self.gather_v.truncate(bound);
    }

    /// Swap the branch strategy / reorder flag in place (continuous
    /// admission applies per-request configs to long-lived slot caches)
    /// and reset. Unlike reconstructing the cache, the multi-MB buffers
    /// are kept — an admission-boundary optimization, behaviourally
    /// identical because committed state is empty after the reset.
    pub fn reconfigure(&mut self, strategy: CacheStrategy, fast_reorder: bool) {
        self.strategy = strategy;
        self.fast_reorder = fast_reorder;
        self.reset();
    }

    /// Re-size the cache to `cap` rows per layer and reset. A shrink
    /// re-lays the `[L, cap, H, Dh]` buffers (stride changes), truncates
    /// the gather scratch to the new bound and drops any branch replica —
    /// a shrunk cache must not be able to index rows of the old layout.
    /// This is the operator-facing capacity knob (per-slot KV budget
    /// reconfiguration between conversations); nothing on the decode hot
    /// path calls it, but [`ManagedCache::reset`]'s scratch re-clamp
    /// exists precisely so a shrink through here can never leave stale
    /// larger-layout rows reachable.
    pub fn set_capacity(&mut self, cap: usize) {
        assert!(cap >= 1, "cache capacity must be >= 1");
        self.cap = cap;
        let n = self.dims.cache_elems(cap);
        self.k.clear();
        self.k.resize(n, 0.0);
        self.v.clear();
        self.v.resize(n, 0.0);
        self.reset();
    }

    /// Layer stride in elements within a `[L, cap, H, Dh]` buffer.
    #[inline]
    fn lstride(&self) -> usize {
        self.cap * self.dims.heads * self.dims.d_head
    }

    /// Row stride (one sequence position within a layer).
    #[inline]
    fn rstride(&self) -> usize {
        self.dims.heads * self.dims.d_head
    }

    // ------------------------------------------------------------------
    // Committed writes (prefill / baseline decode — no branching)
    // ------------------------------------------------------------------

    /// Append `count` committed rows directly from a step-output KV block
    /// (`rows` laid out `[L, s, H, Dh]`). Used by prefill and the
    /// baseline decoder where no speculation is in flight.
    pub fn append_committed(&mut self, k_rows: &[f32], v_rows: &[f32], s: usize, count: usize)
        -> Result<()> {
        if self.branch_open {
            bail!("append_committed while a branch is open");
        }
        if self.len + count > self.cap {
            bail!("cache overflow: len {} + {count} > cap {}", self.len, self.cap);
        }
        let at = self.len;
        self.taint(at);
        copy_rows_seq(&mut self.k, k_rows, self.dims, self.cap, s, at, count);
        copy_rows_seq(&mut self.v, v_rows, self.dims, self.cap, s, at, count);
        self.len += count;
        self.stats.append_bytes += (2 * count * self.rstride() * self.dims.layers * 4) as u64;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Branch lifecycle (speculative decode)
    // ------------------------------------------------------------------

    /// Open a branch. DeepCopy: replicate the committed buffers (the
    /// paper's `B_i <- Replicate(C*)`).
    pub fn begin_branch(&mut self) -> Result<()> {
        if self.branch_open {
            bail!("begin_branch: branch already open");
        }
        self.branch_open = true;
        self.branch_rows = 0;
        self.stats.branches += 1;
        if self.strategy == CacheStrategy::DeepCopy {
            // Full replica — measured, intentionally expensive.
            self.branch_k = Some(self.k.clone());
            self.branch_v = Some(self.v.clone());
            self.stats.replicate_bytes += (2 * self.k.len() * 4) as u64;
        }
        Ok(())
    }

    /// Append `count` speculative rows (from a step-output `[L, s, H, Dh]`
    /// block, taking rows `[0, count)`) into the open branch at offset
    /// `branch_rows`. The committed region `[0, len)` is never written.
    pub fn append_branch(&mut self, k_rows: &[f32], v_rows: &[f32], s: usize, count: usize)
        -> Result<()> {
        if !self.branch_open {
            bail!("append_branch without begin_branch");
        }
        let at = self.len + self.branch_rows;
        if at + count > self.cap {
            bail!("branch overflow: {} + {count} > cap {}", at, self.cap);
        }
        self.taint(at);
        let dims = self.dims;
        let cap = self.cap;
        let (kbuf, vbuf) = match (&mut self.branch_k, &mut self.branch_v) {
            (Some(bk), Some(bv)) => (bk, bv),
            _ => (&mut self.k, &mut self.v),
        };
        copy_rows_seq(kbuf, k_rows, dims, cap, s, at, count);
        copy_rows_seq(vbuf, v_rows, dims, cap, s, at, count);
        self.branch_rows += count;
        self.stats.append_bytes += (2 * count * self.rstride() * self.dims.layers * 4) as u64;
        Ok(())
    }

    /// The buffers a model step must read as its cache input: the branch
    /// replica when one exists (DeepCopy), else the shared main buffers.
    pub fn kv_view(&self) -> (&[f32], &[f32]) {
        match (&self.branch_k, &self.branch_v) {
            (Some(bk), Some(bv)) => (bk, bv),
            _ => (&self.k, &self.v),
        }
    }

    /// Discard the open branch (speculation rejected wholesale or round
    /// finished with the draft-side cache).
    pub fn rollback(&mut self) {
        if self.branch_open {
            self.taint(self.len);
            self.branch_open = false;
            self.branch_rows = 0;
            self.branch_k = None;
            self.branch_v = None;
            self.stats.rollbacks += 1;
        }
    }

    /// Length-based commit (paper §3.1): adopt the first `a` branch rows.
    pub fn commit_length(&mut self, a: usize) -> Result<()> {
        if !self.branch_open {
            bail!("commit_length without an open branch");
        }
        if a > self.branch_rows {
            bail!("commit_length: a = {a} > branch rows {}", self.branch_rows);
        }
        self.taint(self.len);
        match self.strategy {
            CacheStrategy::SegmentShare => {
                // Rows already sit at [len, len+a) in the main buffers —
                // zero copy; just advance the committed length.
            }
            CacheStrategy::DeepCopy => {
                let at = self.len;
                let n = a * self.rstride();
                let ls = self.lstride();
                let (Some(bk), Some(bv)) = (self.branch_k.take(), self.branch_v.take()) else {
                    bail!("DeepCopy branch is open but the replica buffers are missing");
                };
                for l in 0..self.dims.layers {
                    let off = l * ls + at * self.rstride();
                    self.k[off..off + n].copy_from_slice(&bk[off..off + n]);
                    self.v[off..off + n].copy_from_slice(&bv[off..off + n]);
                }
                self.stats.commit_bytes += (2 * self.dims.layers * n * 4) as u64;
            }
        }
        self.len += a;
        self.branch_open = false;
        self.branch_rows = 0;
        self.branch_k = None;
        self.branch_v = None;
        self.stats.commits += 1;
        Ok(())
    }

    /// Path-index commit (paper §3.1): the new committed sequence is
    /// `branch_view[path_indices[i]]` for `i in 0..path_indices.len()`.
    /// Indices address the branch view `[0, len + branch_rows)`.
    ///
    /// With `fast_reorder` and a prefix-preserving mapping
    /// (`path_indices[i] == i` for `i < len`), only the accepted tail is
    /// copied; any inconsistency falls back to the full gather.
    pub fn commit_path(&mut self, path_indices: &[usize]) -> Result<()> {
        if !self.branch_open {
            bail!("commit_path without an open branch");
        }
        let view_len = self.len + self.branch_rows;
        if path_indices.len() > view_len {
            bail!("commit_path: {} indices exceed branch view {view_len}", path_indices.len());
        }
        if let Some(bad) = path_indices.iter().find(|i| **i >= view_len) {
            bail!("commit_path: index {bad} out of branch view {view_len}");
        }
        let prefix_preserved =
            path_indices.len() >= self.len && (0..self.len).all(|i| path_indices[i] == i);

        // session watermark: a prefix-preserving commit rewrites only the
        // tail; the general gather may rebuild the whole sequence
        if self.fast_reorder && prefix_preserved {
            self.taint(self.len);
        } else {
            self.taint(0);
        }

        if self.fast_reorder && prefix_preserved {
            self.commit_path_fast(path_indices)?;
            self.stats.fast_reorders += 1;
        } else {
            if self.fast_reorder {
                self.stats.fast_fallbacks += 1;
            }
            self.commit_path_full(path_indices)?;
            self.stats.full_reorders += 1;
        }
        self.len = path_indices.len();
        self.branch_open = false;
        self.branch_rows = 0;
        self.branch_k = None;
        self.branch_v = None;
        self.stats.commits += 1;
        Ok(())
    }

    /// Prefix-sharing fast reorder: gather only rows `[len, new_len)`.
    /// Uses the persistent `gather_*` scratch (no per-commit allocation).
    fn commit_path_fast(&mut self, path_indices: &[usize]) -> Result<()> {
        let rs = self.rstride();
        let ls = self.lstride();
        let dims = self.dims;
        let tail = &path_indices[self.len..];
        let n = dims.layers * tail.len() * rs;
        self.gather_k.resize(n, 0.0);
        self.gather_v.resize(n, 0.0);
        {
            let (src_k, src_v) = match (&self.branch_k, &self.branch_v) {
                (Some(bk), Some(bv)) => (bk.as_slice(), bv.as_slice()),
                _ => (&self.k[..], &self.v[..]),
            };
            // Gather the accepted tail (tail is tiny: <= M rows).
            for l in 0..dims.layers {
                for (i, &src) in tail.iter().enumerate() {
                    let s_off = l * ls + src * rs;
                    let d_off = (l * tail.len() + i) * rs;
                    self.gather_k[d_off..d_off + rs].copy_from_slice(&src_k[s_off..s_off + rs]);
                    self.gather_v[d_off..d_off + rs].copy_from_slice(&src_v[s_off..s_off + rs]);
                }
            }
        }
        for l in 0..dims.layers {
            for i in 0..tail.len() {
                let d_off = l * ls + (self.len + i) * rs;
                let s_off = (l * tail.len() + i) * rs;
                self.k[d_off..d_off + rs].copy_from_slice(&self.gather_k[s_off..s_off + rs]);
                self.v[d_off..d_off + rs].copy_from_slice(&self.gather_v[s_off..s_off + rs]);
            }
        }
        self.stats.commit_bytes += (4 * dims.layers * tail.len() * rs * 4) as u64;
        Ok(())
    }

    /// Prefix-relative path commit — the steady-state fast path.
    ///
    /// `tail_offsets` are *branch-row* indices (0-based within the open
    /// branch, strictly increasing); the committed prefix `[0, len)` is
    /// implicitly preserved, so the caller never materializes the
    /// `(0..len).collect()` identity vector that the absolute-index
    /// [`ManagedCache::commit_path`] requires. Equivalent to
    /// `commit_path(&[0, 1, .., len-1, len+tail[0], len+tail[1], ..])` —
    /// property-tested against it.
    ///
    /// Because offsets are strictly increasing, every source row sits at
    /// or after its destination and the SegmentShare gather runs in-place
    /// front-to-back (`copy_within`), with no scratch at all.
    ///
    /// `commit_bytes` counts rows *actually moved* (already-in-place rows
    /// are free). Note this is lower than the legacy `commit_path` fast
    /// path reported for the same commit: that path double-moves every
    /// tail row through a gather scratch and counts both moves.
    pub fn commit_path_tail(&mut self, tail_offsets: &[usize]) -> Result<()> {
        if !self.branch_open {
            bail!("commit_path_tail without an open branch");
        }
        let mut prev: Option<usize> = None;
        for &o in tail_offsets {
            if o >= self.branch_rows {
                bail!("commit_path_tail: offset {o} out of branch rows {}", self.branch_rows);
            }
            if let Some(p) = prev {
                if o <= p {
                    bail!("commit_path_tail: offsets must be strictly increasing ({p} then {o})");
                }
            }
            prev = Some(o);
        }
        let rs = self.rstride();
        let ls = self.lstride();
        let dims = self.dims;
        let len = self.len;
        self.taint(len);
        let mut moved_rows = 0usize;
        match (&self.branch_k, &self.branch_v) {
            (Some(bk), Some(bv)) => {
                // DeepCopy: gather from the branch replica into the main
                // buffers — disjoint, plain copies (every row moves).
                for l in 0..dims.layers {
                    for (i, &o) in tail_offsets.iter().enumerate() {
                        let s_off = l * ls + (len + o) * rs;
                        let d_off = l * ls + (len + i) * rs;
                        self.k[d_off..d_off + rs].copy_from_slice(&bk[s_off..s_off + rs]);
                        self.v[d_off..d_off + rs].copy_from_slice(&bv[s_off..s_off + rs]);
                        moved_rows += 1;
                    }
                }
            }
            _ => {
                // SegmentShare: in-place forward gather. Strictly
                // increasing offsets give `o >= i`, so the source row is
                // never overwritten before it is read.
                for l in 0..dims.layers {
                    for (i, &o) in tail_offsets.iter().enumerate() {
                        if o == i {
                            continue;
                        }
                        let s_off = l * ls + (len + o) * rs;
                        let d_off = l * ls + (len + i) * rs;
                        self.k.copy_within(s_off..s_off + rs, d_off);
                        self.v.copy_within(s_off..s_off + rs, d_off);
                        moved_rows += 1;
                    }
                }
            }
        }
        self.stats.commit_bytes += (2 * moved_rows * rs * 4) as u64;
        self.stats.fast_reorders += 1;
        self.len += tail_offsets.len();
        self.branch_open = false;
        self.branch_rows = 0;
        self.branch_k = None;
        self.branch_v = None;
        self.stats.commits += 1;
        Ok(())
    }

    /// General full reorder: rebuild the entire committed sequence by
    /// gathering every row (the paper's to_legacy/from_legacy path).
    fn commit_path_full(&mut self, path_indices: &[usize]) -> Result<()> {
        let rs = self.rstride();
        let ls = self.lstride();
        let dims = self.dims;
        let (src_k, src_v) = match (&self.branch_k, &self.branch_v) {
            (Some(bk), Some(bv)) => (bk.clone(), bv.clone()),
            _ => (self.k.clone(), self.v.clone()),
        };
        for l in 0..dims.layers {
            for (i, &src) in path_indices.iter().enumerate() {
                let s_off = l * ls + src * rs;
                let d_off = l * ls + i * rs;
                self.k[d_off..d_off + rs].copy_from_slice(&src_k[s_off..s_off + rs]);
                self.v[d_off..d_off + rs].copy_from_slice(&src_v[s_off..s_off + rs]);
            }
        }
        // clone + gather, k and v
        self.stats.commit_bytes +=
            (2 * (src_k.len() + dims.layers * path_indices.len() * rs) * 4) as u64;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Introspection for tests ("commit equivalence", isolation)
    // ------------------------------------------------------------------

    /// Copy of a committed row `[L * H * Dh]` (k side), for equivalence
    /// tests and the SimBackend context reconstruction.
    pub fn committed_row_k(&self, row: usize) -> Vec<f32> {
        assert!(row < self.len);
        let rs = self.rstride();
        let ls = self.lstride();
        let mut out = Vec::with_capacity(self.dims.layers * rs);
        for l in 0..self.dims.layers {
            let off = l * ls + row * rs;
            out.extend_from_slice(&self.k[off..off + rs]);
        }
        out
    }

    /// Raw main-buffer checksum over the committed region (isolation tests).
    pub fn committed_checksum(&self) -> f64 {
        let rs = self.rstride();
        let ls = self.lstride();
        let mut acc = 0.0f64;
        for l in 0..self.dims.layers {
            for r in 0..self.len {
                let off = l * ls + r * rs;
                for x in &self.k[off..off + rs] {
                    acc += *x as f64;
                }
                for x in &self.v[off..off + rs] {
                    acc += *x as f64;
                }
            }
        }
        acc
    }
}

/// The layout-agnostic store contract, delegating to the inherent
/// methods above (the flat manager is the reference implementation the
/// paged cache is property-tested against).
impl KvStore for ManagedCache {
    fn len(&self) -> usize {
        ManagedCache::len(self)
    }

    fn branch_rows(&self) -> usize {
        ManagedCache::branch_rows(self)
    }

    fn headroom(&self) -> usize {
        ManagedCache::headroom(self)
    }

    fn strategy(&self) -> CacheStrategy {
        ManagedCache::strategy(self)
    }

    fn reset(&mut self) {
        ManagedCache::reset(self)
    }

    fn reconfigure(&mut self, strategy: CacheStrategy, fast_reorder: bool) {
        ManagedCache::reconfigure(self, strategy, fast_reorder)
    }

    fn append_committed(&mut self, k_rows: &[f32], v_rows: &[f32], s: usize, count: usize)
        -> Result<()> {
        ManagedCache::append_committed(self, k_rows, v_rows, s, count)
    }

    fn begin_branch(&mut self) -> Result<()> {
        ManagedCache::begin_branch(self)
    }

    fn append_branch(&mut self, k_rows: &[f32], v_rows: &[f32], s: usize, count: usize)
        -> Result<()> {
        ManagedCache::append_branch(self, k_rows, v_rows, s, count)
    }

    fn rollback(&mut self) {
        ManagedCache::rollback(self)
    }

    fn commit_length(&mut self, a: usize) -> Result<()> {
        ManagedCache::commit_length(self, a)
    }

    fn commit_path(&mut self, path_indices: &[usize]) -> Result<()> {
        ManagedCache::commit_path(self, path_indices)
    }

    fn commit_path_tail(&mut self, tail_offsets: &[usize]) -> Result<()> {
        ManagedCache::commit_path_tail(self, tail_offsets)
    }

    fn kv_guard(&self) -> KvGuard<'_> {
        let (k, v) = self.kv_view();
        KvGuard::Flat { k, v, rows: self.cap }
    }

    fn committed_row_k(&self, row: usize) -> Vec<f32> {
        ManagedCache::committed_row_k(self, row)
    }

    fn committed_checksum(&self) -> f64 {
        ManagedCache::committed_checksum(self)
    }

    fn stats(&self) -> &CacheStats {
        &self.stats
    }

    fn bytes_resident(&self) -> u64 {
        let branch = self.branch_k.as_ref().map_or(0, Vec::len)
            + self.branch_v.as_ref().map_or(0, Vec::len);
        ((self.k.len() + self.v.len() + branch) * 4) as u64
    }

    fn dirty_lo(&self) -> usize {
        self.dirty_lo
    }

    fn mark_synced(&mut self) {
        self.dirty_lo = usize::MAX;
    }
}

/// Copy rows `[0, count)` of a `[L, s, H, Dh]` step-output block into a
/// `[L, cap, H, Dh]` cache buffer at row offset `at`.
fn copy_rows_seq(
    dst: &mut [f32],
    rows: &[f32],
    dims: Dims,
    cap: usize,
    s: usize,
    at: usize,
    count: usize,
) {
    let rs = dims.heads * dims.d_head;
    debug_assert_eq!(rows.len(), dims.layers * s * rs);
    for l in 0..dims.layers {
        let src = l * s * rs;
        let dst_off = l * cap * rs + at * rs;
        dst[dst_off..dst_off + count * rs]
            .copy_from_slice(&rows[src..src + count * rs]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CacheStrategy;
    use crate::util::prop;

    const DIMS: Dims = Dims { layers: 2, d_model: 8, heads: 2, d_head: 2 };
    const CAP: usize = 16;

    /// A `[L, s, H, Dh]` block whose row r carries the value `base + r`
    /// in every element — rows are distinguishable and layer-consistent.
    fn block(s: usize, base: f32) -> Vec<f32> {
        let rs = DIMS.heads * DIMS.d_head;
        let mut out = vec![0.0; DIMS.layers * s * rs];
        for l in 0..DIMS.layers {
            for r in 0..s {
                for e in 0..rs {
                    out[(l * s + r) * rs + e] = base + r as f32;
                }
            }
        }
        out
    }

    fn row_value(c: &ManagedCache, row: usize) -> f32 {
        c.committed_row_k(row)[0]
    }

    fn mk(strategy: CacheStrategy, fast: bool) -> ManagedCache {
        ManagedCache::new(DIMS, CAP, strategy, fast)
    }

    #[test]
    fn append_committed_and_read_back() {
        let mut c = mk(CacheStrategy::SegmentShare, true);
        c.append_committed(&block(4, 100.0), &block(4, 200.0), 4, 3).unwrap();
        assert_eq!(c.len(), 3);
        assert_eq!(row_value(&c, 0), 100.0);
        assert_eq!(row_value(&c, 2), 102.0);
    }

    #[test]
    fn overflow_rejected() {
        let mut c = mk(CacheStrategy::SegmentShare, true);
        assert!(c.append_committed(&block(CAP + 1, 0.0), &block(CAP + 1, 0.0), CAP + 1, CAP + 1).is_err());
    }

    #[test]
    fn isolation_branch_never_mutates_committed() {
        for strategy in [CacheStrategy::DeepCopy, CacheStrategy::SegmentShare] {
            let mut c = mk(strategy, true);
            c.append_committed(&block(4, 10.0), &block(4, 10.0), 4, 4).unwrap();
            let before = c.committed_checksum();
            c.begin_branch().unwrap();
            c.append_branch(&block(8, 500.0), &block(8, 500.0), 8, 6).unwrap();
            assert_eq!(c.committed_checksum(), before, "{strategy:?}");
            c.rollback();
            assert_eq!(c.committed_checksum(), before, "{strategy:?} after rollback");
            assert_eq!(c.len(), 4);
        }
    }

    #[test]
    fn commit_length_adopts_prefix_rows() {
        for strategy in [CacheStrategy::DeepCopy, CacheStrategy::SegmentShare] {
            let mut c = mk(strategy, true);
            c.append_committed(&block(4, 10.0), &block(4, 10.0), 4, 2).unwrap();
            c.begin_branch().unwrap();
            c.append_branch(&block(8, 50.0), &block(8, 50.0), 8, 5).unwrap();
            c.commit_length(3).unwrap();
            assert_eq!(c.len(), 5, "{strategy:?}");
            assert_eq!(row_value(&c, 2), 50.0);
            assert_eq!(row_value(&c, 4), 52.0);
        }
    }

    #[test]
    fn commit_path_fast_and_full_agree() {
        // Same scenario committed through both reorder paths must produce
        // identical committed state ("commit equivalence").
        let build = |fast: bool, strategy: CacheStrategy| {
            let mut c = mk(strategy, fast);
            c.append_committed(&block(4, 10.0), &block(4, 10.0), 4, 3).unwrap();
            c.begin_branch().unwrap();
            c.append_branch(&block(8, 100.0), &block(8, 100.0), 8, 6).unwrap();
            // prefix preserved + accept branch rows 1 and 4 (slots 3+1, 3+4)
            c.commit_path(&[0, 1, 2, 4, 7]).unwrap();
            c
        };
        for strategy in [CacheStrategy::DeepCopy, CacheStrategy::SegmentShare] {
            let f = build(true, strategy);
            let g = build(false, strategy);
            assert_eq!(f.len(), 5);
            assert_eq!(g.len(), 5);
            for r in 0..5 {
                assert_eq!(f.committed_row_k(r), g.committed_row_k(r), "{strategy:?} row {r}");
            }
            assert_eq!(row_value(&f, 3), 101.0);
            assert_eq!(row_value(&f, 4), 104.0);
            assert_eq!(f.stats.fast_reorders, 1);
            assert_eq!(g.stats.full_reorders, 1);
        }
    }

    #[test]
    fn fast_reorder_falls_back_on_non_prefix_mapping() {
        let mut c = mk(CacheStrategy::SegmentShare, true);
        c.append_committed(&block(4, 10.0), &block(4, 10.0), 4, 3).unwrap();
        c.begin_branch().unwrap();
        c.append_branch(&block(8, 100.0), &block(8, 100.0), 8, 2).unwrap();
        // reorders the committed prefix itself -> must fall back
        c.commit_path(&[2, 1, 0, 3]).unwrap();
        assert_eq!(c.stats.fast_fallbacks, 1);
        assert_eq!(c.stats.full_reorders, 1);
        assert_eq!(row_value(&c, 0), 12.0);
        assert_eq!(row_value(&c, 3), 100.0);
    }

    #[test]
    fn commit_path_rejects_out_of_range() {
        let mut c = mk(CacheStrategy::SegmentShare, true);
        c.append_committed(&block(4, 0.0), &block(4, 0.0), 4, 2).unwrap();
        c.begin_branch().unwrap();
        assert!(c.commit_path(&[0, 1, 5]).is_err());
    }

    #[test]
    fn lifecycle_misuse_rejected() {
        let mut c = mk(CacheStrategy::SegmentShare, true);
        assert!(c.append_branch(&block(8, 0.0), &block(8, 0.0), 8, 1).is_err());
        assert!(c.commit_length(0).is_err());
        c.begin_branch().unwrap();
        assert!(c.begin_branch().is_err());
        assert!(c.append_committed(&block(4, 0.0), &block(4, 0.0), 4, 1).is_err());
    }

    #[test]
    fn deepcopy_counts_replication_bytes() {
        let mut c = mk(CacheStrategy::DeepCopy, true);
        c.begin_branch().unwrap();
        assert!(c.stats.replicate_bytes > 0);
        let mut s = mk(CacheStrategy::SegmentShare, true);
        s.begin_branch().unwrap();
        assert_eq!(s.stats.replicate_bytes, 0);
    }

    #[test]
    fn commit_path_tail_equals_identity_prefix_commit_path() {
        for strategy in [CacheStrategy::DeepCopy, CacheStrategy::SegmentShare] {
            let build = |tail: bool| {
                let mut c = mk(strategy, true);
                c.append_committed(&block(4, 10.0), &block(4, 10.0), 4, 3).unwrap();
                c.begin_branch().unwrap();
                c.append_branch(&block(8, 100.0), &block(8, 100.0), 8, 6).unwrap();
                if tail {
                    c.commit_path_tail(&[0, 2, 5]).unwrap();
                } else {
                    let path: Vec<usize> = vec![0, 1, 2, 3, 5, 8];
                    c.commit_path(&path).unwrap();
                }
                c
            };
            let a = build(true);
            let b = build(false);
            assert_eq!(a.len(), b.len(), "{strategy:?}");
            for r in 0..a.len() {
                assert_eq!(a.committed_row_k(r), b.committed_row_k(r), "{strategy:?} row {r}");
            }
            assert_eq!(a.stats.fast_reorders, 1);
        }
    }

    #[test]
    fn commit_path_tail_rejects_bad_offsets() {
        let mut c = mk(CacheStrategy::SegmentShare, true);
        c.append_committed(&block(4, 0.0), &block(4, 0.0), 4, 2).unwrap();
        assert!(c.commit_path_tail(&[0]).is_err(), "no branch open");
        c.begin_branch().unwrap();
        c.append_branch(&block(8, 1.0), &block(8, 1.0), 8, 3).unwrap();
        assert!(c.commit_path_tail(&[3]).is_err(), "offset out of branch");
        assert!(c.commit_path_tail(&[1, 1]).is_err(), "not strictly increasing");
        c.commit_path_tail(&[0, 2]).unwrap();
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn property_commit_path_tail_matches_commit_path() {
        // The tentpole fast path vs the reference oracle: for random
        // committed lengths, branch sizes and accepted subsets, the
        // prefix-relative tail commit must produce the exact committed
        // state of the absolute-index commit_path.
        prop::for_cases(120, 0x7A11, |g| {
            let strategy = *g.choose(&[CacheStrategy::DeepCopy, CacheStrategy::SegmentShare]);
            let t0 = g.usize_in(0, 6);
            let b = g.usize_in(1, 8);
            let mut offs = Vec::new();
            for i in 0..b {
                if g.bool_p(0.6) {
                    offs.push(i);
                }
            }
            let build = |tail: bool| {
                let mut c = mk(strategy, true);
                if t0 > 0 {
                    c.append_committed(&block(8, 10.0), &block(8, 10.0), 8, t0).unwrap();
                }
                c.begin_branch().unwrap();
                c.append_branch(&block(8, 100.0), &block(8, 100.0), 8, b).unwrap();
                if tail {
                    c.commit_path_tail(&offs).unwrap();
                } else {
                    let path: Vec<usize> =
                        (0..t0).chain(offs.iter().map(|o| t0 + o)).collect();
                    c.commit_path(&path).unwrap();
                }
                c
            };
            let x = build(true);
            let y = build(false);
            assert_eq!(x.len(), y.len(), "{strategy:?}");
            for r in 0..x.len() {
                assert_eq!(x.committed_row_k(r), y.committed_row_k(r), "{strategy:?} row {r}");
            }
        });
    }

    #[test]
    fn reset_reclamps_gather_scratch_after_capacity_shrink() {
        // Regression: a commit at the original capacity leaves the
        // persistent gather scratch sized for that layout; a set_capacity
        // shrink followed by reset must clamp it so no later fast reorder
        // can index stale rows of the old stride.
        let mut c = mk(CacheStrategy::SegmentShare, true);
        c.append_committed(&block(4, 10.0), &block(4, 10.0), 4, 3).unwrap();
        c.begin_branch().unwrap();
        c.append_branch(&block(8, 100.0), &block(8, 100.0), 8, 8).unwrap();
        // non-tail fast path -> populates gather_k/gather_v
        c.commit_path(&[0, 1, 2, 4, 3, 7, 10, 9]).unwrap();
        assert!(!c.gather_k.is_empty(), "fast reorder must have used the gather scratch");
        let shrunk_cap = 2usize;
        c.set_capacity(shrunk_cap);
        let bound = DIMS.layers * shrunk_cap * DIMS.heads * DIMS.d_head;
        assert!(
            c.gather_k.len() <= bound && c.gather_v.len() <= bound,
            "gather scratch not re-clamped: {} > bound {bound}",
            c.gather_k.len()
        );
        assert_eq!(c.cap, shrunk_cap);
        assert_eq!(c.len(), 0);
        // the shrunk cache enforces its new capacity and still commits
        assert!(c.append_committed(&block(4, 0.0), &block(4, 0.0), 4, 3).is_err());
        c.append_committed(&block(4, 5.0), &block(4, 5.0), 4, 1).unwrap();
        c.begin_branch().unwrap();
        c.append_branch(&block(8, 9.0), &block(8, 9.0), 8, 1).unwrap();
        c.commit_path(&[0, 1]).unwrap();
        assert_eq!(row_value(&c, 1), 9.0);
        // plain reset keeps the clamp invariant too
        c.reset();
        assert!(c.gather_k.len() <= bound);
    }

    #[test]
    fn reconfigure_matches_fresh_cache() {
        let mut c = mk(CacheStrategy::SegmentShare, true);
        c.append_committed(&block(4, 10.0), &block(4, 10.0), 4, 3).unwrap();
        c.reconfigure(CacheStrategy::DeepCopy, false);
        assert_eq!(c.len(), 0);
        assert_eq!(c.strategy(), CacheStrategy::DeepCopy);
        c.append_committed(&block(4, 1.0), &block(4, 1.0), 4, 2).unwrap();
        c.begin_branch().unwrap();
        assert!(c.stats.replicate_bytes > 0, "DeepCopy must replicate after reconfigure");
        c.rollback();
        let mut f = mk(CacheStrategy::DeepCopy, false);
        f.append_committed(&block(4, 1.0), &block(4, 1.0), 4, 2).unwrap();
        assert_eq!(c.committed_checksum(), f.committed_checksum());
    }

    #[test]
    fn property_commit_equivalence_random_paths() {
        // For random branch contents and random accepted subsets, the
        // committed state equals the sequential construction:
        // rows = [committed rows] ++ [branch rows at chosen offsets].
        prop::for_cases(120, 0xCAFE, |g| {
            let strategy = *g.choose(&[CacheStrategy::DeepCopy, CacheStrategy::SegmentShare]);
            let fast = g.bool_p(0.5);
            let t0 = g.usize_in(0, 6);
            let b = g.usize_in(1, 8);
            let mut c = mk(strategy, fast);
            if t0 > 0 {
                c.append_committed(&block(8, 10.0), &block(8, 10.0), 8, t0).unwrap();
            }
            c.begin_branch().unwrap();
            c.append_branch(&block(8, 100.0), &block(8, 100.0), 8, b).unwrap();
            // choose an increasing subset of branch rows
            let mut accepted = Vec::new();
            for i in 0..b {
                if g.bool_p(0.6) {
                    accepted.push(i);
                }
            }
            let path: Vec<usize> =
                (0..t0).chain(accepted.iter().map(|i| t0 + i)).collect();
            c.commit_path(&path).unwrap();
            assert_eq!(c.len(), t0 + accepted.len());
            for (j, &src) in accepted.iter().enumerate() {
                assert_eq!(
                    row_value(&c, t0 + j),
                    100.0 + src as f32,
                    "strategy {strategy:?} fast {fast}"
                );
            }
            for r in 0..t0 {
                assert_eq!(row_value(&c, r), 10.0 + r as f32);
            }
        });
    }
}
