//! Branchable KV-cache management (paper §3.1).

pub mod manager;

pub use manager::{CacheStats, ManagedCache};
