//! Branchable KV-cache management (paper §3.1) behind one layout-agnostic
//! store contract.
//!
//! Two physical layouts implement [`KvStore`]:
//!
//! * [`ManagedCache`] — flat `[L, cap, H, Dh]` buffers (the paper's
//!   original layout; every engine pins full capacity);
//! * [`PagedCache`] — fixed-size blocks drawn from a shared per-worker
//!   [`PagePool`], addressed through a block table (residency ∝ committed
//!   tokens; commits remap the table).
//!
//! The two are bit-identical under the branch/commit state machine
//! (property-tested in `tests/paged.rs`); [`crate::config::CacheLayout`]
//! selects between them per run.

pub mod manager;
pub mod paged;

use crate::backend::KvView;
use crate::config::CacheStrategy;
use anyhow::{bail, Result};
use std::sync::RwLockReadGuard;

pub use manager::{CacheStats, ManagedCache};
pub use paged::{
    pool_read, pool_write, prefix_lock, CachePools, PageError, PagePool, PagedCache,
    PrefixIndex, PrefixMatch, SharedPool, BLOCK_ROWS,
};

/// A live borrow of a store's readable KV state, held for the duration of
/// one backend step (or one fused launch across many requests).
///
/// Flat stores lend their buffers directly; paged stores hold a shared
/// read guard on the worker's [`PagePool`] — many guards may be alive at
/// once (a fused launch borrows every group member's cache), but **no
/// cache mutation on the same pool may happen while any guard lives**
/// (enforced by the pool's `RwLock`: readers exclude the writer). The
/// engine and scheduler scope guards strictly around backend calls.
pub enum KvGuard<'a> {
    /// Borrowed flat buffers (`rows` physical rows per layer).
    Flat {
        /// Key buffer.
        k: &'a [f32],
        /// Value buffer.
        v: &'a [f32],
        /// Physical rows per layer.
        rows: usize,
    },
    /// Shared pool borrow plus this conversation's block table.
    Paged {
        /// The pool read guard keeping the storage alive.
        pool: RwLockReadGuard<'a, PagePool>,
        /// Logical-block → physical-block table of the branch view.
        table: &'a [u32],
        /// Rows per block.
        block_size: usize,
    },
}

impl KvGuard<'_> {
    /// The backend-facing view of the guarded state.
    pub fn view(&self) -> KvView<'_> {
        match self {
            KvGuard::Flat { k, v, rows } => KvView::flat(k, v, *rows),
            KvGuard::Paged { pool, table, block_size } => {
                let (k, v) = pool.storage();
                KvView::paged(k, v, table, *block_size)
            }
        }
    }
}

/// The branch/commit KV-store contract (paper §3.1) every cache layout
/// implements. Semantics are defined by [`ManagedCache`] (the reference
/// implementation, documented there); [`PagedCache`] must match it
/// bit-for-bit on committed state for identical operation sequences.
///
/// `Send` is part of the contract: an engine (and therefore its caches)
/// must be movable onto a worker thread — the coordinator/worker split
/// runs one `EngineWorker` per thread. Paged stores satisfy this because
/// [`SharedPool`] is `Arc<RwLock<…>>`, not `Rc<RefCell<…>>`.
pub trait KvStore: Send {
    /// Committed sequence length `t` (logical rows — never a physical
    /// pool coordinate; mask prefix intervals derive from this).
    fn len(&self) -> usize;

    /// Whether nothing has been committed yet.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Speculative rows appended in the currently open branch.
    fn branch_rows(&self) -> usize;

    /// Free committed capacity (logical).
    fn headroom(&self) -> usize;

    /// The configured branch-replication strategy.
    fn strategy(&self) -> CacheStrategy;

    /// Reset to an empty committed state (new conversation); paged stores
    /// return every mapped block to the pool.
    fn reset(&mut self);

    /// Swap the branch strategy / reorder flag (continuous admission with
    /// heterogeneous configs) and reset. Keeps storage capacity.
    fn reconfigure(&mut self, strategy: CacheStrategy, fast_reorder: bool);

    /// Append `count` committed rows from a `[L, s, H, Dh]` step output.
    fn append_committed(&mut self, k_rows: &[f32], v_rows: &[f32], s: usize, count: usize)
        -> Result<()>;

    /// Open a speculative branch.
    fn begin_branch(&mut self) -> Result<()>;

    /// Append `count` speculative rows into the open branch.
    fn append_branch(&mut self, k_rows: &[f32], v_rows: &[f32], s: usize, count: usize)
        -> Result<()>;

    /// Discard the open branch.
    fn rollback(&mut self);

    /// Length-based commit: adopt the first `a` branch rows.
    fn commit_length(&mut self, a: usize) -> Result<()>;

    /// Path-index commit over the branch view (absolute indices).
    fn commit_path(&mut self, path_indices: &[usize]) -> Result<()>;

    /// Prefix-relative tail commit (strictly increasing branch-row
    /// offsets) — the steady-state fast path.
    fn commit_path_tail(&mut self, tail_offsets: &[usize]) -> Result<()>;

    /// Borrow the readable KV state for a backend step (branch view when
    /// a DeepCopy replica is open, else the main state).
    fn kv_guard(&self) -> KvGuard<'_>;

    /// Rows readable through [`KvStore::kv_guard`]: committed prefix plus
    /// open-branch rows. Session tickets carry this as the mirror length.
    fn view_rows(&self) -> usize {
        self.len() + self.branch_rows()
    }

    /// First readable row whose *contents* may have changed since
    /// [`KvStore::mark_synced`] (`usize::MAX` when nothing changed) — the
    /// dirty watermark backing device-resident KV sessions: a bound
    /// backend re-syncs only rows `[dirty_lo, view_rows)` per step
    /// instead of re-uploading the whole cache. Implementations must be
    /// conservative (taint at or below the lowest row a mutation could
    /// have touched); staleness here is a correctness bug the
    /// session-vs-full-view bit-identity suite exists to catch.
    fn dirty_lo(&self) -> usize;

    /// Declare the current readable state synced (a ticketed backend
    /// step consumed the watermark). Clears [`KvStore::dirty_lo`].
    fn mark_synced(&mut self);

    /// Copy of committed row `row` (`[L * H * Dh]`, k side) — tests and
    /// checksums.
    fn committed_row_k(&self, row: usize) -> Vec<f32>;

    /// Checksum over the committed region (bit-identity tests).
    fn committed_checksum(&self) -> f64;

    /// Movement/commit counters.
    fn stats(&self) -> &CacheStats;

    /// Bytes of KV memory this conversation keeps resident: full buffers
    /// (+ any open replica) for flat stores, mapped blocks for paged
    /// ones. The CI memory gate sums this across resident slots.
    fn bytes_resident(&self) -> u64;

    // ------------------------------------------------------------------
    // Prefix sharing (block-structured layouts only; flat stores keep
    // the defaults — there is no block table to share)
    // ------------------------------------------------------------------

    /// Rows per block for block-structured layouts; `None` for flat
    /// stores. Prefix-sharing registration aligns frozen runs to this.
    fn block_size(&self) -> Option<usize> {
        None
    }

    /// Physical block ids covering committed rows `[0, rows)`, for a
    /// block-aligned `rows <= len()` with no branch open — what the
    /// prefix index freezes at registration. `None` for flat stores, an
    /// unaligned request, or an open branch.
    fn committed_block_run(&self, rows: usize) -> Option<Vec<u32>> {
        let _ = rows;
        None
    }

    /// Map `blocks` (a frozen run from the prefix index, covering exactly
    /// `rows` block-aligned rows) as this store's committed prefix,
    /// taking one new reference per block. The store must be empty. Any
    /// later divergent write privatizes the touched block (copy-on-write)
    /// — the shared run itself is immutable. Errors for flat stores.
    fn adopt_shared_blocks(&mut self, blocks: &[u32], rows: usize) -> Result<()> {
        let _ = (blocks, rows);
        bail!("adopt_shared_blocks: this cache layout has no shareable blocks")
    }
}
