//! Paged KV cache: fixed-size KV blocks drawn from a per-worker
//! [`PagePool`] and addressed through a per-conversation block table.
//!
//! The flat [`crate::cache::ManagedCache`] pins a full `[L, cap, H, Dh]`
//! buffer pair per engine, so a worker holding `B` resident slots pays
//! `B * cap` rows of memory even when every conversation is a few dozen
//! tokens long — and `commit_path` physically gathers rows. Paging
//! (SpecInfer / vLLM-style) replaces both:
//!
//! ```text
//!            PagePool (one per role per worker)
//!   blocks:  [ 0 ][ 1 ][ 2 ][ 3 ][ 4 ][ 5 ] ...   (block = bs rows x L)
//!   free:    {2, 5}
//!
//!   conv A table: [0, 3]     logical rows 0..2bs  ->  blocks 0, 3
//!   conv B table: [1, 4]     (parked: blocks stay mapped, slot is free)
//! ```
//!
//! * residency is proportional to committed tokens (`mapped blocks * bs`),
//!   not capacity — measured as `kv_bytes_resident` and gated in CI;
//! * `commit_length` and the steady-state `commit_path_tail` touch only
//!   rows inside the partial boundary block (whole accepted blocks are
//!   already in place — the table *is* the commit);
//! * a retired-but-resumable conversation parks as a block table
//!   ([`crate::engine::Engine::park`]); its freed siblings' blocks return
//!   to the pool for new admissions.
//!
//! [`PagedCache`] implements the exact branch/begin/append/rollback/
//! commit contract of [`crate::cache::KvStore`] and is bit-identical to
//! [`crate::cache::ManagedCache`] under every strategy/commit mode
//! (property-tested in `tests/paged.rs` via `committed_checksum`).
//! Isolation carries over unchanged: SegmentShare appends speculative
//! rows past the committed length (the boundary block's tail is invisible
//! to committed readers), DeepCopy replicates the *mapped* blocks into a
//! branch replica table.
//!
//! Backends read through the gather-aware [`crate::backend::KvView`]
//! (block-table indirection); the tree mask is untouched — its prefix
//! columns address **logical** rows `[0, t)`, and `t` is the logical
//! committed length, never a physical pool coordinate.

use crate::cache::{CacheStats, KvGuard, KvStore};
use crate::config::{CacheStrategy, Contract, Dims};
use crate::util::idx::udx;
use anyhow::{bail, Result};
use std::fmt;
use std::sync::{Arc, Mutex, RwLock};

/// The shared handle to a per-worker [`PagePool`]: every slot engine of
/// one worker clones this handle, so all resident conversations draw
/// blocks from the same arena. `RwLock` (not `Mutex`) because a fused
/// verification launch holds one read guard per participating
/// conversation over the *same* pool concurrently
/// ([`crate::cache::KvGuard::Paged`]); writes (block mapping, commits)
/// are exclusive. `Send + Sync`, so an `EngineWorker` owning its pools
/// can run on its own thread.
pub type SharedPool = Arc<RwLock<PagePool>>;

/// Acquire shared read access to a pool. A poisoned lock means a
/// sibling engine panicked mid-mutation — pool storage may be torn, so
/// propagating the panic to the whole worker is the only safe option
/// (the coordinator surfaces the worker's death; it is never absorbed).
pub fn pool_read(pool: &SharedPool) -> std::sync::RwLockReadGuard<'_, PagePool> {
    // lint: allow(hot-unwrap) — poisoning means a sibling panicked mid-mutation; torn pool storage must take the worker down, not be absorbed
    pool.read().expect("pool lock poisoned")
}

/// Acquire exclusive write access to a pool (see [`pool_read`] for the
/// poisoning policy).
pub fn pool_write(pool: &SharedPool) -> std::sync::RwLockWriteGuard<'_, PagePool> {
    // lint: allow(hot-unwrap) — same poisoning policy as pool_read: propagate the sibling's panic worker-wide
    pool.write().expect("pool lock poisoned")
}

/// Lock a worker's prefix index (see [`pool_read`] for the poisoning
/// policy).
pub fn prefix_lock(index: &Arc<Mutex<PrefixIndex>>) -> std::sync::MutexGuard<'_, PrefixIndex> {
    // lint: allow(hot-unwrap) — same poisoning policy as pool_read: a torn index must not be absorbed
    index.lock().expect("prefix index lock poisoned")
}

/// Pool-bookkeeping corruption detected by a refcount/free-list check.
///
/// These checks were `debug_assert!`s; they now run in release builds
/// too — each is O(1) on a counter the operation already loads — because
/// a violation means physical KV rows are about to be aliased or leaked
/// *across conversations*, the one failure mode the shared arena must
/// never let through silently. Fallible call chains surface them as
/// typed errors; infallible cleanup paths (`reset`, `rollback`, drop)
/// escalate through [`pool_corrupt`] under the same policy as lock
/// poisoning.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PageError {
    /// A block popped off the free list still carried live references —
    /// the free list and the refcounts disagree.
    FreeListCorrupt {
        /// The corrupt block id.
        block: u32,
        /// Its (non-zero) reference count.
        refs: u32,
    },
    /// `release_block` on a block id the pool never created.
    ReleaseUnbacked {
        /// The out-of-range block id.
        block: u32,
    },
    /// `release_block` on a block with no live references (double free).
    DoubleFree {
        /// The already-free block id.
        block: u32,
    },
    /// [`PagePool::share_block`] on a free or unbacked block — sharing a
    /// dead block is a use-after-free.
    ShareFree {
        /// The dead block id.
        block: u32,
    },
}

impl fmt::Display for PageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PageError::FreeListCorrupt { block, refs } => write!(
                f,
                "free list corrupt: block {block} is on the free list but holds {refs} references"
            ),
            PageError::ReleaseUnbacked { block } => {
                write!(f, "release of unbacked block {block}")
            }
            PageError::DoubleFree { block } => write!(f, "double free of block {block}"),
            PageError::ShareFree { block } => {
                write!(f, "share_block on free block {block} (use-after-free)")
            }
        }
    }
}

impl std::error::Error for PageError {}

/// Escalate pool corruption found on an infallible cleanup path
/// (`reset`, `rollback`, drop). The arena is shared: continuing past a
/// refcount/free-list violation would hand aliased blocks to sibling
/// conversations, so the whole worker comes down — the same policy as a
/// poisoned pool lock ([`pool_read`]).
fn pool_corrupt(e: PageError) -> ! {
    panic!("paged pool corrupted: {e}")
}

/// Rows per KV block. 16 keeps the partial-boundary-block copy small
/// (a commit moves < bs rows) while keeping tables short (cap/16 entries).
pub const BLOCK_ROWS: usize = 16;

/// A fixed-block KV arena shared by every conversation of one worker
/// (one pool per model role — teacher and draft differ in `[L, H, Dh]`).
///
/// Storage is block-major: block `b` occupies
/// `[b * L * bs * H * Dh, (b+1) * ..)`, laid out `[L, bs, H, Dh]`, so the
/// pool grows by whole blocks without re-striding existing data.
/// [`PagePool::ensure_headroom`] pre-reserves storage capacity so
/// steady-state block mapping performs no heap allocation (the
/// zero-allocation decode contract, asserted by
/// `tests/alloc_regression.rs`).
pub struct PagePool {
    dims: Dims,
    block_size: usize,
    /// Total storage-backed blocks (mapped + free).
    blocks: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    /// LIFO free list of block ids.
    free: Vec<u32>,
    /// Per-block reference count (copy-on-write prefix sharing): 0 = on
    /// the free list, 1 = uniquely mapped, > 1 = shared between block
    /// tables and/or the worker's prefix index. A block returns to the
    /// free list only when its last reference is released, and any write
    /// through a table into a block with `refs > 1` must clone it first
    /// ([`PagedCache`]'s CoW paths).
    refs: Vec<u32>,
}

impl PagePool {
    /// An empty pool for a role with dimensions `dims` (no blocks yet;
    /// storage grows on demand and within reserved capacity).
    pub fn new(dims: Dims, block_size: usize) -> Self {
        assert!(block_size >= 1, "block_size must be >= 1");
        Self {
            dims,
            block_size,
            blocks: 0,
            k: Vec::new(),
            v: Vec::new(),
            free: Vec::new(),
            refs: Vec::new(),
        }
    }

    /// Rows per block.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Total blocks the pool has ever created (mapped + free).
    pub fn blocks(&self) -> usize {
        self.blocks
    }

    /// Blocks currently on the free list.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks with at least one live reference (uniquely mapped or
    /// shared). The refcounted free-list invariant is
    /// `blocks == free_blocks + referenced_blocks` after every operation
    /// — shared blocks count **once**, however many tables map them.
    pub fn referenced_blocks(&self) -> usize {
        self.refs.iter().filter(|r| **r > 0).count()
    }

    /// Current reference count of block `b` (0 = free).
    pub fn ref_count(&self, b: u32) -> u32 {
        self.refs[udx(b)]
    }

    /// Add a reference to a live block (prefix sharing: a second block
    /// table, or the worker's prefix index, now maps it). Sharing a free
    /// or unbacked block is a use-after-free and is rejected as
    /// [`PageError::ShareFree`].
    pub fn share_block(&mut self, b: u32) -> std::result::Result<(), PageError> {
        if udx(b) >= self.blocks || self.refs[udx(b)] == 0 {
            return Err(PageError::ShareFree { block: b });
        }
        self.refs[udx(b)] += 1;
        Ok(())
    }

    /// Bytes of KV storage held by referenced blocks (k + v) — the
    /// pool-level residency under prefix sharing, where per-conversation
    /// [`KvStore::bytes_resident`] sums would double-count shared blocks.
    pub fn referenced_bytes(&self) -> u64 {
        (2 * self.referenced_blocks() * self.block_elems() * 4) as u64
    }

    /// Elements of one block across all layers (`L * bs * H * Dh`).
    #[inline]
    pub fn block_elems(&self) -> usize {
        self.dims.layers * self.block_size * self.dims.heads * self.dims.d_head
    }

    /// Raw (k, v) block storage — what a paged [`KvView`] borrows.
    pub fn storage(&self) -> (&[f32], &[f32]) {
        (&self.k, &self.v)
    }

    /// Bytes of block storage the pool holds (k + v, high-water). This is
    /// the pool's *footprint*; per-conversation residency is
    /// [`KvStore::bytes_resident`] (mapped blocks only).
    pub fn bytes_resident(&self) -> u64 {
        ((self.k.len() + self.v.len()) * 4) as u64
    }

    /// Reserve storage so `rows` more logical rows can be mapped without
    /// a heap allocation (beyond blocks already free or unbacked
    /// capacity). Called by engine warmup so a warmed resident
    /// conversation's steady-state decode never grows the pool vectors.
    ///
    /// Reservation is per call, not cumulative: a multi-slot worker's
    /// pool grows (allocating) the first time its *combined* residency
    /// exceeds what was reserved, then sits at that high-water mark —
    /// the same warm-to-peak behaviour as every scratch arena. The
    /// zero-allocation assertion (`tests/alloc_regression.rs`) covers
    /// the single-resident case this guarantees outright.
    pub fn ensure_headroom(&mut self, rows: usize) {
        let need = rows.div_ceil(self.block_size);
        let be = self.block_elems();
        let capacity_blocks = self.k.capacity() / be.max(1);
        let avail = self.free.len() + capacity_blocks.saturating_sub(self.blocks);
        if avail < need {
            // `Vec::reserve` is relative to *len* (= backed blocks), so
            // the unbacked spare capacity must not be subtracted twice:
            // capacity must reach (blocks + need - free) blocks total.
            let extra = (need - self.free.len()) * be;
            self.k.reserve(extra);
            self.v.reserve(extra);
        }
        self.free.reserve(need);
        self.refs.reserve(need);
    }

    /// Take a block from the free list, growing storage if none is free.
    /// The block starts uniquely referenced (`refs == 1`). A free-list
    /// entry that still carries references means the bookkeeping is torn
    /// ([`PageError::FreeListCorrupt`]).
    fn alloc_block(&mut self) -> std::result::Result<u32, PageError> {
        if let Some(b) = self.free.pop() {
            let refs = self.refs[udx(b)];
            if refs != 0 {
                return Err(PageError::FreeListCorrupt { block: b, refs });
            }
            self.refs[udx(b)] = 1;
            return Ok(b);
        }
        let b = self.blocks as u32;
        self.blocks += 1;
        let n = self.blocks * self.block_elems();
        self.k.resize(n, 0.0);
        self.v.resize(n, 0.0);
        self.refs.push(1);
        Ok(b)
    }

    /// Drop one reference to a block; the last release returns it to the
    /// free list. Shared blocks survive their earlier releasers (a donor
    /// conversation retiring leaves the frozen prefix resident for the
    /// index and its adopters). Releasing an unbacked or already-free
    /// block is rejected ([`PageError::ReleaseUnbacked`] /
    /// [`PageError::DoubleFree`]).
    fn release_block(&mut self, b: u32) -> std::result::Result<(), PageError> {
        if udx(b) >= self.blocks {
            return Err(PageError::ReleaseUnbacked { block: b });
        }
        if self.refs[udx(b)] == 0 {
            return Err(PageError::DoubleFree { block: b });
        }
        self.refs[udx(b)] -= 1;
        if self.refs[udx(b)] == 0 {
            self.free.push(b);
        }
        Ok(())
    }

    /// Element offset of `(block, layer, in-block row)` in the storage.
    #[inline]
    fn row_off(&self, b: u32, layer: usize, within: usize) -> usize {
        let rs = self.dims.heads * self.dims.d_head;
        udx(b) * self.block_elems() + (layer * self.block_size + within) * rs
    }
}

/// Most frozen prefix runs the per-worker [`PrefixIndex`] retains; the
/// oldest entry is evicted (its block references released) past this.
pub const PREFIX_INDEX_CAP: usize = 32;

/// One frozen, block-aligned run of committed prefix rows registered for
/// sharing: the exact token sequence, the teacher- and draft-pool blocks
/// holding its KV rows (the index owns one reference per block, so the
/// run stays resident after its donor retires), and the donor's teacher
/// feature at every block end — the chain-feature a partial prefill
/// resumes from ([`crate::engine::Engine`]'s EAGLE input contract).
struct PrefixEntry {
    tokens: Vec<i32>,
    t_blocks: Vec<u32>,
    d_blocks: Vec<u32>,
    /// `feats[j]` = teacher feature of row `(j + 1) * block_size - 1`.
    feats: Vec<Vec<f32>>,
}

/// Per-worker index of frozen prefix runs, keyed on committed block
/// *content* (the token sequence the blocks hold — exact compare, no
/// hash-collision risk). Admission of a conversation whose prompt prefix
/// matches a resident run adopts the matched blocks directly and skips
/// prefill for the shared run ([`CachePools::lookup_prefix`]). Matches
/// may cover a block-aligned *prefix* of an entry, so conversations
/// diverging mid-run still share everything up to the divergent block.
#[derive(Default)]
pub struct PrefixIndex {
    entries: Vec<PrefixEntry>,
}

impl PrefixIndex {
    /// Registered runs currently resident.
    pub fn entries(&self) -> usize {
        self.entries.len()
    }
}

/// A prefix-index hit: the shared run to adopt. Block vectors are clones
/// of the index entry's tables (the adopter takes its own references via
/// [`crate::cache::KvStore::adopt_shared_blocks`]).
pub struct PrefixMatch {
    /// Matched rows (block-aligned, `> 0`).
    pub rows: usize,
    /// Teacher-pool blocks covering the run.
    pub t_blocks: Vec<u32>,
    /// Draft-pool blocks covering the run.
    pub d_blocks: Vec<u32>,
    /// Donor teacher feature at every block end of the run
    /// (`feats.last()` is the chain feature prefill resumes from).
    pub feats: Vec<Vec<f32>>,
}

/// The per-worker pool pair (teacher + draft roles) plus the shared
/// prefix index. Cloning shares all three (`Arc`): a worker creates one
/// `CachePools` and hands it to every slot engine so all resident
/// conversations draw from the same arenas. The handles are `Send +
/// Sync` — pools are guarded by `RwLock` (concurrent fused-launch
/// readers, exclusive writers) and the prefix index by a `Mutex` — so a
/// whole worker (engines + scheduler + pools) can move to its own
/// thread. Pools are still *per worker*: workers never share arenas,
/// the locks exist so one worker's slots can.
#[derive(Clone)]
pub struct CachePools {
    /// Teacher-role block pool.
    pub teacher: SharedPool,
    /// Draft-role block pool.
    pub draft: SharedPool,
    /// Frozen prefix runs shared across this worker's conversations
    /// (`--prefix-sharing`; empty and inert when sharing is off).
    pub prefix: Arc<Mutex<PrefixIndex>>,
}

impl CachePools {
    /// Fresh (empty) pools for a backend contract.
    pub fn new(contract: &Contract) -> Self {
        Self {
            teacher: Arc::new(RwLock::new(PagePool::new(contract.teacher, BLOCK_ROWS))),
            draft: Arc::new(RwLock::new(PagePool::new(contract.draft, BLOCK_ROWS))),
            prefix: Arc::new(Mutex::new(PrefixIndex::default())),
        }
    }

    /// Combined pool storage footprint in bytes (k + v, both roles).
    pub fn bytes_resident(&self) -> u64 {
        pool_read(&self.teacher).bytes_resident() + pool_read(&self.draft).bytes_resident()
    }

    /// Combined bytes of *referenced* blocks (both roles) — the honest
    /// residency under prefix sharing, where per-conversation sums would
    /// count a shared block once per mapper.
    pub fn referenced_bytes(&self) -> u64 {
        pool_read(&self.teacher).referenced_bytes() + pool_read(&self.draft).referenced_bytes()
    }

    /// Register a frozen run for sharing: `tokens` are the committed
    /// tokens of rows `[0, tokens.len())`, `t_blocks`/`d_blocks` the
    /// teacher/draft blocks covering them (block-aligned), and `feats`
    /// the donor's teacher feature at every block end. The index takes
    /// one reference per block so the run survives its donor. Runs
    /// already covered by a resident entry are skipped; a run extending
    /// a resident entry replaces it (releasing the shorter one); past
    /// [`PREFIX_INDEX_CAP`] the oldest entry is evicted. Errs only on
    /// pool corruption ([`PageError`]).
    pub fn register_prefix(
        &self,
        tokens: &[i32],
        t_blocks: &[u32],
        d_blocks: &[u32],
        feats: &[Vec<f32>],
    ) -> std::result::Result<(), PageError> {
        let bs = pool_read(&self.teacher).block_size();
        let rows = tokens.len();
        debug_assert!(rows > 0 && rows % bs == 0, "prefix run must be block-aligned");
        debug_assert_eq!(t_blocks.len(), rows / bs);
        debug_assert_eq!(d_blocks.len(), rows / bs);
        debug_assert_eq!(feats.len(), rows / bs);
        let mut index = prefix_lock(&self.prefix);
        // already covered by a resident entry (same tokens or a longer
        // run starting with them): nothing new to share
        if index
            .entries
            .iter()
            .any(|e| e.tokens.len() >= rows && e.tokens[..rows] == *tokens)
        {
            return Ok(());
        }
        // this run extends one or more resident entries: replace them
        let mut i = 0;
        while i < index.entries.len() {
            if tokens.starts_with(&index.entries[i].tokens) {
                let old = index.entries.remove(i);
                self.release_entry(&old)?;
            } else {
                i += 1;
            }
        }
        while index.entries.len() >= PREFIX_INDEX_CAP {
            let old = index.entries.remove(0);
            self.release_entry(&old)?;
        }
        {
            let mut tp = pool_write(&self.teacher);
            for &b in t_blocks {
                tp.share_block(b)?;
            }
        }
        {
            let mut dp = pool_write(&self.draft);
            for &b in d_blocks {
                dp.share_block(b)?;
            }
        }
        index.entries.push(PrefixEntry {
            tokens: tokens.to_vec(),
            t_blocks: t_blocks.to_vec(),
            d_blocks: d_blocks.to_vec(),
            feats: feats.to_vec(),
        });
        Ok(())
    }

    /// Longest block-aligned shared run matching a prefix of `prompt`,
    /// capped at `max_rows` (callers pass `prompt.len() - 1` so at least
    /// one tail token remains to regenerate the pending logits). Returns
    /// `None` when no resident run shares at least one whole block.
    pub fn lookup_prefix(&self, prompt: &[i32], max_rows: usize) -> Option<PrefixMatch> {
        let bs = pool_read(&self.teacher).block_size();
        let index = prefix_lock(&self.prefix);
        let mut best: Option<(usize, &PrefixEntry)> = None;
        for e in &index.entries {
            let lim = e.tokens.len().min(prompt.len()).min(max_rows);
            let common = e
                .tokens
                .iter()
                .zip(prompt)
                .take(lim)
                .take_while(|(a, b)| a == b)
                .count();
            let blocks = common / bs;
            if blocks > 0 && best.as_ref().map_or(true, |(br, _)| blocks * bs > *br) {
                best = Some((blocks * bs, e));
            }
        }
        best.map(|(rows, e)| {
            let nb = rows / bs;
            PrefixMatch {
                rows,
                t_blocks: e.t_blocks[..nb].to_vec(),
                d_blocks: e.d_blocks[..nb].to_vec(),
                feats: e.feats[..nb].to_vec(),
            }
        })
    }

    /// Drop every registered run, releasing the index's block references.
    /// Errs only on pool corruption ([`PageError`]).
    pub fn clear_prefix_index(&self) -> std::result::Result<(), PageError> {
        let entries = std::mem::take(&mut prefix_lock(&self.prefix).entries);
        for e in &entries {
            self.release_entry(e)?;
        }
        Ok(())
    }

    fn release_entry(&self, e: &PrefixEntry) -> std::result::Result<(), PageError> {
        let mut tp = pool_write(&self.teacher);
        for &b in &e.t_blocks {
            tp.release_block(b)?;
        }
        drop(tp);
        let mut dp = pool_write(&self.draft);
        for &b in &e.d_blocks {
            dp.release_block(b)?;
        }
        Ok(())
    }
}

/// One conversation's KV cache over a shared [`PagePool`]: a block table
/// plus the branch/commit state machine of the flat manager. See the
/// module docs for layout and the `KvStore` docs for the contract.
pub struct PagedCache {
    dims: Dims,
    cap: usize,
    strategy: CacheStrategy,
    fast_reorder: bool,
    block_size: usize,
    pool: SharedPool,
    /// Main block table: committed rows `[0, len)` plus (SegmentShare)
    /// the open branch's speculative rows.
    table: Vec<u32>,
    /// DeepCopy branch replica table (committed clone + branch appends);
    /// `None` when no branch is open or the strategy is SegmentShare.
    replica: Option<Vec<u32>>,
    len: usize,
    branch_rows: usize,
    branch_open: bool,
    /// Reusable row-gather scratch for the general commit paths (the
    /// ablation-grade full reorder; the steady-state tail commit is
    /// scratch-free).
    gather_k: Vec<f32>,
    gather_v: Vec<f32>,
    /// KV-session dirty watermark: first readable *logical* row whose
    /// contents may have changed since `mark_synced` (`usize::MAX` =
    /// clean). Logical-row indexed — block remaps that preserve logical
    /// content (table pushes) still taint conservatively at the commit
    /// base, like the flat manager.
    dirty_lo: usize,
    /// Movement/commit counters (same schema as the flat manager; byte
    /// counts reflect rows *actually moved*, which paging makes fewer).
    pub stats: CacheStats,
}

impl PagedCache {
    /// An empty paged cache of logical capacity `cap` rows drawing blocks
    /// from `pool` (which must serve the same role dimensions).
    pub fn new(
        dims: Dims,
        cap: usize,
        strategy: CacheStrategy,
        fast_reorder: bool,
        pool: SharedPool,
    ) -> Self {
        let block_size = {
            let p = pool_read(&pool);
            debug_assert_eq!(p.dims, dims, "pool role dimensions mismatch");
            p.block_size()
        };
        Self {
            dims,
            cap,
            strategy,
            fast_reorder,
            block_size,
            pool,
            table: Vec::new(),
            replica: None,
            len: 0,
            branch_rows: 0,
            branch_open: false,
            gather_k: Vec::new(),
            gather_v: Vec::new(),
            dirty_lo: 0,
            stats: CacheStats::default(),
        }
    }

    /// Lower the session dirty watermark to `row` (a mutation may have
    /// changed readable contents at or after it).
    #[inline]
    fn taint(&mut self, row: usize) {
        self.dirty_lo = self.dirty_lo.min(row);
    }

    /// Blocks this cache currently maps (main table + branch replica) —
    /// the free-list invariant `pool.blocks == pool.free + Σ mapped`
    /// holds after every operation (property-tested).
    pub fn mapped_blocks(&self) -> usize {
        self.table.len() + self.replica.as_ref().map_or(0, Vec::len)
    }

    /// Per-row element stride (`H * Dh`).
    #[inline]
    fn rstride(&self) -> usize {
        self.dims.heads * self.dims.d_head
    }

    /// Grow `table` (in `pool`) until it maps at least `rows` rows.
    fn map_rows(
        pool: &mut PagePool,
        table: &mut Vec<u32>,
        rows: usize,
    ) -> std::result::Result<(), PageError> {
        let bs = pool.block_size();
        while table.len() * bs < rows {
            let b = pool.alloc_block()?;
            table.push(b);
        }
        Ok(())
    }

    /// Shrink the main table to exactly cover `rows`, releasing trimmed
    /// blocks.
    fn trim_table(&mut self, rows: usize) -> std::result::Result<(), PageError> {
        let keep = rows.div_ceil(self.block_size);
        let mut pool = pool_write(&self.pool);
        while self.table.len() > keep {
            let Some(b) = self.table.pop() else { break };
            pool.release_block(b)?;
        }
        Ok(())
    }

    /// Release every replica block (branch close).
    fn drop_replica(&mut self) -> std::result::Result<(), PageError> {
        if let Some(rep) = self.replica.take() {
            let mut pool = pool_write(&self.pool);
            for b in rep {
                pool.release_block(b)?;
            }
        }
        Ok(())
    }

    /// Copy-on-write guard for logical rows `[lo, hi)` of `table`: any
    /// covered block with more than one reference (shared with another
    /// conversation's table or the prefix index) is cloned into a private
    /// block first, and the table remapped to the clone. Every in-pool
    /// write path calls this before touching storage, so shared frozen
    /// prefix blocks are immutable by construction — a divergent append
    /// at the boundary block privatizes exactly that block. No-op (and
    /// allocation-free) when nothing is shared.
    fn cow_rows(
        pool: &mut PagePool,
        table: &mut [u32],
        lo: usize,
        hi: usize,
        stats: &mut CacheStats,
    ) -> std::result::Result<(), PageError> {
        if hi <= lo {
            return Ok(());
        }
        let bs = pool.block_size();
        let be = pool.block_elems();
        for bi in (lo / bs)..=((hi - 1) / bs) {
            let b = table[bi];
            if pool.ref_count(b) <= 1 {
                continue;
            }
            let nb = pool.alloc_block()?;
            let s_off = udx(b) * be;
            let d_off = udx(nb) * be;
            pool.k.copy_within(s_off..s_off + be, d_off);
            pool.v.copy_within(s_off..s_off + be, d_off);
            pool.release_block(b)?; // drop this table's reference only
            table[bi] = nb;
            stats.cow_copies += 1;
            stats.cow_bytes += (2 * be * 4) as u64;
        }
        Ok(())
    }

    /// Copy `count` rows of a `[L, s, H, Dh]` step-output block into the
    /// chosen table at logical offset `at`, mapping blocks as needed.
    fn write_rows(
        &mut self,
        into_replica: bool,
        at: usize,
        k_rows: &[f32],
        v_rows: &[f32],
        s: usize,
        count: usize,
    ) -> Result<()> {
        let rs = self.rstride();
        debug_assert_eq!(k_rows.len(), self.dims.layers * s * rs);
        let mut pool = pool_write(&self.pool);
        let table = if into_replica {
            let Some(rep) = self.replica.as_mut() else {
                bail!("DeepCopy branch write with no replica table");
            };
            rep
        } else {
            &mut self.table
        };
        Self::map_rows(&mut pool, table, at + count)?;
        Self::cow_rows(&mut pool, table, at, at + count, &mut self.stats)?;
        let bs = pool.block_size();
        for l in 0..self.dims.layers {
            for r in 0..count {
                let row = at + r;
                let b = table[row / bs];
                let dst = pool.row_off(b, l, row % bs);
                let src = (l * s + r) * rs;
                pool.k[dst..dst + rs].copy_from_slice(&k_rows[src..src + rs]);
                pool.v[dst..dst + rs].copy_from_slice(&v_rows[src..src + rs]);
            }
        }
        Ok(())
    }

    /// In-pool row copy: logical `src_row` of `src_table` → logical
    /// `dst_row` of `dst_table` (tables may be the same; a row never
    /// overlaps itself unless identical, in which case this is a no-op
    /// for the caller to skip).
    fn copy_row(pool: &mut PagePool, src_table: &[u32], src_row: usize, dst_table: &[u32],
                dst_row: usize, layers: usize) {
        let bs = pool.block_size();
        for l in 0..layers {
            let s_off = pool.row_off(src_table[src_row / bs], l, src_row % bs);
            let d_off = pool.row_off(dst_table[dst_row / bs], l, dst_row % bs);
            let rs = pool.dims.heads * pool.dims.d_head;
            pool.k.copy_within(s_off..s_off + rs, d_off);
            pool.v.copy_within(s_off..s_off + rs, d_off);
        }
    }

    /// Close the branch state after a commit/rollback.
    fn close_branch(&mut self) -> std::result::Result<(), PageError> {
        self.branch_open = false;
        self.branch_rows = 0;
        self.drop_replica()
    }

    /// The table a branch-view read goes through (replica when DeepCopy
    /// has one open, else the main table).
    fn view_table(&self) -> &[u32] {
        match &self.replica {
            Some(rep) => rep,
            None => &self.table,
        }
    }

    /// Gather logical `rows` of the branch view into the reusable
    /// scratch, laid out `[L, rows.len(), H, Dh]`.
    fn gather_rows(&mut self, rows: &[usize]) {
        let rs = self.rstride();
        let n = self.dims.layers * rows.len() * rs;
        self.gather_k.resize(n, 0.0);
        self.gather_v.resize(n, 0.0);
        let pool = pool_read(&self.pool);
        let table = match &self.replica {
            Some(rep) => rep.as_slice(),
            None => self.table.as_slice(),
        };
        let bs = pool.block_size();
        for l in 0..self.dims.layers {
            for (i, &src) in rows.iter().enumerate() {
                let s_off = pool.row_off(table[src / bs], l, src % bs);
                let d_off = (l * rows.len() + i) * rs;
                self.gather_k[d_off..d_off + rs].copy_from_slice(&pool.k[s_off..s_off + rs]);
                self.gather_v[d_off..d_off + rs].copy_from_slice(&pool.v[s_off..s_off + rs]);
            }
        }
    }

    /// Write the gathered scratch back as committed rows `[at, at+n)` of
    /// the main table.
    fn scatter_gathered(&mut self, at: usize, n: usize) -> std::result::Result<(), PageError> {
        let rs = self.rstride();
        let mut pool = pool_write(&self.pool);
        Self::map_rows(&mut pool, &mut self.table, at + n)?;
        Self::cow_rows(&mut pool, &mut self.table, at, at + n, &mut self.stats)?;
        let bs = pool.block_size();
        for l in 0..self.dims.layers {
            for i in 0..n {
                let row = at + i;
                let dst = pool.row_off(self.table[row / bs], l, row % bs);
                let src = (l * n + i) * rs;
                pool.k[dst..dst + rs].copy_from_slice(&self.gather_k[src..src + rs]);
                pool.v[dst..dst + rs].copy_from_slice(&self.gather_v[src..src + rs]);
            }
        }
        Ok(())
    }
}

impl KvStore for PagedCache {
    fn len(&self) -> usize {
        self.len
    }

    fn branch_rows(&self) -> usize {
        self.branch_rows
    }

    fn headroom(&self) -> usize {
        self.cap - self.len
    }

    fn strategy(&self) -> CacheStrategy {
        self.strategy
    }

    fn reset(&mut self) {
        self.taint(0);
        // infallible by contract — corruption here escalates like lock
        // poisoning (see `pool_corrupt`)
        if let Err(e) = self.drop_replica().and_then(|()| self.trim_table(0)) {
            pool_corrupt(e);
        }
        self.len = 0;
        self.branch_rows = 0;
        self.branch_open = false;
        self.stats = CacheStats::default();
    }

    fn reconfigure(&mut self, strategy: CacheStrategy, fast_reorder: bool) {
        self.strategy = strategy;
        self.fast_reorder = fast_reorder;
        self.reset();
    }

    fn append_committed(&mut self, k_rows: &[f32], v_rows: &[f32], s: usize, count: usize)
        -> Result<()> {
        if self.branch_open {
            bail!("append_committed while a branch is open");
        }
        if self.len + count > self.cap {
            bail!("cache overflow: len {} + {count} > cap {}", self.len, self.cap);
        }
        let at = self.len;
        self.taint(at);
        self.write_rows(false, at, k_rows, v_rows, s, count)?;
        self.len += count;
        self.stats.append_bytes += (2 * count * self.rstride() * self.dims.layers * 4) as u64;
        Ok(())
    }

    fn begin_branch(&mut self) -> Result<()> {
        if self.branch_open {
            bail!("begin_branch: branch already open");
        }
        self.branch_open = true;
        self.branch_rows = 0;
        self.stats.branches += 1;
        if self.strategy == CacheStrategy::DeepCopy {
            // Replicate the *mapped* blocks (not full capacity — the
            // honest paged cost of the paper's conservative mode).
            let mut pool = pool_write(&self.pool);
            let be = pool.block_elems();
            let mut rep = Vec::with_capacity(self.table.len());
            for &src in &self.table {
                let dst = pool.alloc_block()?;
                let s_off = udx(src) * be;
                let d_off = udx(dst) * be;
                pool.k.copy_within(s_off..s_off + be, d_off);
                pool.v.copy_within(s_off..s_off + be, d_off);
                rep.push(dst);
            }
            self.stats.replicate_bytes += (2 * rep.len() * be * 4) as u64;
            self.replica = Some(rep);
        }
        Ok(())
    }

    fn append_branch(&mut self, k_rows: &[f32], v_rows: &[f32], s: usize, count: usize)
        -> Result<()> {
        if !self.branch_open {
            bail!("append_branch without begin_branch");
        }
        let at = self.len + self.branch_rows;
        if at + count > self.cap {
            bail!("branch overflow: {at} + {count} > cap {}", self.cap);
        }
        self.taint(at);
        let into_replica = self.replica.is_some();
        self.write_rows(into_replica, at, k_rows, v_rows, s, count)?;
        self.branch_rows += count;
        self.stats.append_bytes += (2 * count * self.rstride() * self.dims.layers * 4) as u64;
        Ok(())
    }

    fn rollback(&mut self) {
        if self.branch_open {
            self.taint(self.len);
            // infallible by contract — corruption escalates like lock
            // poisoning (see `pool_corrupt`)
            if let Err(e) = self.close_branch() {
                pool_corrupt(e);
            }
            // SegmentShare spec rows may have grown the main table past
            // the committed boundary — give those blocks back.
            let len = self.len;
            if let Err(e) = self.trim_table(len) {
                pool_corrupt(e);
            }
            self.stats.rollbacks += 1;
        }
    }

    fn commit_length(&mut self, a: usize) -> Result<()> {
        if !self.branch_open {
            bail!("commit_length without an open branch");
        }
        if a > self.branch_rows {
            bail!("commit_length: a = {a} > branch rows {}", self.branch_rows);
        }
        self.taint(self.len);
        if let Some(rep) = self.replica.take() {
            // DeepCopy: adopt rows [len, len+a) from the replica. Whole
            // blocks past the committed boundary are *remapped* (the
            // block-table commit); only rows sharing the partial boundary
            // block are copied.
            let len = self.len;
            let bs = self.block_size;
            let boundary = len.div_ceil(bs) * bs; // first whole-block row
            let mut moved_rows = 0usize;
            {
                let hi = (len + a).min(boundary);
                let mut pool = pool_write(&self.pool);
                if hi > len {
                    Self::map_rows(&mut pool, &mut self.table, hi)?;
                    Self::cow_rows(&mut pool, &mut self.table, len, hi, &mut self.stats)?;
                }
                for row in len..hi {
                    Self::copy_row(&mut pool, &rep, row, &self.table, row, self.dims.layers);
                    moved_rows += 1;
                }
            }
            // remap whole replica blocks holding rows [boundary, len+a):
            // the main table maps nothing past the boundary (DeepCopy
            // appends went to the replica), so adoption is a pure push —
            // the block-table commit, zero row movement
            let mut rep = rep;
            if len + a > boundary {
                let first_b = boundary / bs;
                let last_b = (len + a - 1) / bs;
                for bi in first_b..=last_b {
                    debug_assert_eq!(self.table.len(), bi, "boundary block accounting");
                    let blk = rep[bi];
                    rep[bi] = u32::MAX; // mark adopted
                    self.table.push(blk);
                }
            }
            // release the replica blocks not adopted
            {
                let mut pool = pool_write(&self.pool);
                for b in rep {
                    if b != u32::MAX {
                        pool.release_block(b)?;
                    }
                }
            }
            self.stats.commit_bytes += (2 * moved_rows * self.rstride() * self.dims.layers * 4) as u64;
            self.len += a;
        } else {
            // SegmentShare: rows already sit at [len, len+a) — advance
            // the length and free the blocks past it. Zero copy.
            self.len += a;
        }
        let len = self.len;
        self.trim_table(len)?;
        self.branch_open = false;
        self.branch_rows = 0;
        self.stats.commits += 1;
        Ok(())
    }

    fn commit_path(&mut self, path_indices: &[usize]) -> Result<()> {
        if !self.branch_open {
            bail!("commit_path without an open branch");
        }
        let view_len = self.len + self.branch_rows;
        if path_indices.len() > view_len {
            bail!("commit_path: {} indices exceed branch view {view_len}", path_indices.len());
        }
        if let Some(bad) = path_indices.iter().find(|i| **i >= view_len) {
            bail!("commit_path: index {bad} out of branch view {view_len}");
        }
        let prefix_preserved =
            path_indices.len() >= self.len && (0..self.len).all(|i| path_indices[i] == i);
        // session watermark: a prefix-preserving commit rewrites only the
        // tail; the general gather may rebuild the whole sequence
        if self.fast_reorder && prefix_preserved {
            self.taint(self.len);
        } else {
            self.taint(0);
        }
        if self.fast_reorder && prefix_preserved {
            // Gather only the accepted tail (arbitrary view indices are
            // allowed here, unlike the strictly-increasing tail commit).
            let tail: Vec<usize> = path_indices[self.len..].to_vec();
            self.gather_rows(&tail);
            let at = self.len;
            self.drop_replica()?;
            self.scatter_gathered(at, tail.len())?;
            self.stats.commit_bytes +=
                (4 * self.dims.layers * tail.len() * self.rstride() * 4) as u64;
            self.stats.fast_reorders += 1;
        } else {
            if self.fast_reorder {
                self.stats.fast_fallbacks += 1;
            }
            // Full reorder (ablation path): gather every accepted row,
            // then rewrite the committed sequence from row 0.
            self.gather_rows(path_indices);
            self.drop_replica()?;
            self.scatter_gathered(0, path_indices.len())?;
            self.stats.commit_bytes +=
                (4 * self.dims.layers * path_indices.len() * self.rstride() * 4) as u64;
            self.stats.full_reorders += 1;
        }
        self.len = path_indices.len();
        let len = self.len;
        self.branch_open = false;
        self.branch_rows = 0;
        self.trim_table(len)?;
        self.stats.commits += 1;
        Ok(())
    }

    fn commit_path_tail(&mut self, tail_offsets: &[usize]) -> Result<()> {
        if !self.branch_open {
            bail!("commit_path_tail without an open branch");
        }
        let mut prev: Option<usize> = None;
        for &o in tail_offsets {
            if o >= self.branch_rows {
                bail!("commit_path_tail: offset {o} out of branch rows {}", self.branch_rows);
            }
            if let Some(p) = prev {
                if o <= p {
                    bail!("commit_path_tail: offsets must be strictly increasing ({p} then {o})");
                }
            }
            prev = Some(o);
        }
        let len = self.len;
        self.taint(len);
        let layers = self.dims.layers;
        let mut moved_rows = 0usize;
        match self.replica.take() {
            Some(rep) => {
                // DeepCopy: copy accepted rows from the replica into the
                // main table (disjoint blocks — plain copies).
                let mut pool = pool_write(&self.pool);
                if !tail_offsets.is_empty() {
                    Self::map_rows(&mut pool, &mut self.table, len + tail_offsets.len())?;
                    Self::cow_rows(
                        &mut pool,
                        &mut self.table,
                        len,
                        len + tail_offsets.len(),
                        &mut self.stats,
                    )?;
                }
                for (i, &o) in tail_offsets.iter().enumerate() {
                    Self::copy_row(&mut pool, &rep, len + o, &self.table, len + i, layers);
                    moved_rows += 1;
                }
                for b in rep {
                    pool.release_block(b)?;
                }
            }
            None => {
                // SegmentShare: in-place forward gather through the block
                // table. Strictly increasing offsets give `o >= i`, so a
                // source row is never overwritten before it is read —
                // the same argument as the flat layout, independent of
                // which physical blocks the rows land in. CoW first: a
                // cloned destination block preserves its contents, so
                // sources that happen to live in it still read correctly.
                let mut pool = pool_write(&self.pool);
                Self::cow_rows(
                    &mut pool,
                    &mut self.table,
                    len,
                    len + tail_offsets.len(),
                    &mut self.stats,
                )?;
                for (i, &o) in tail_offsets.iter().enumerate() {
                    if o == i {
                        continue;
                    }
                    Self::copy_row(&mut pool, &self.table, len + o, &self.table, len + i, layers);
                    moved_rows += 1;
                }
            }
        }
        self.stats.commit_bytes += (2 * moved_rows * self.rstride() * layers * 4) as u64;
        self.stats.fast_reorders += 1;
        self.len += tail_offsets.len();
        let new_len = self.len;
        self.branch_open = false;
        self.branch_rows = 0;
        self.trim_table(new_len)?;
        self.stats.commits += 1;
        Ok(())
    }

    fn kv_guard(&self) -> KvGuard<'_> {
        KvGuard::Paged {
            pool: pool_read(&self.pool),
            table: self.view_table(),
            block_size: self.block_size,
        }
    }

    fn committed_row_k(&self, row: usize) -> Vec<f32> {
        assert!(row < self.len);
        let rs = self.rstride();
        let pool = pool_read(&self.pool);
        let bs = pool.block_size();
        let mut out = Vec::with_capacity(self.dims.layers * rs);
        for l in 0..self.dims.layers {
            let off = pool.row_off(self.table[row / bs], l, row % bs);
            out.extend_from_slice(&pool.k[off..off + rs]);
        }
        out
    }

    fn committed_checksum(&self) -> f64 {
        let rs = self.rstride();
        let pool = pool_read(&self.pool);
        let bs = pool.block_size();
        let mut acc = 0.0f64;
        for l in 0..self.dims.layers {
            for r in 0..self.len {
                let off = pool.row_off(self.table[r / bs], l, r % bs);
                for x in &pool.k[off..off + rs] {
                    acc += *x as f64;
                }
                for x in &pool.v[off..off + rs] {
                    acc += *x as f64;
                }
            }
        }
        acc
    }

    fn stats(&self) -> &CacheStats {
        &self.stats
    }

    fn bytes_resident(&self) -> u64 {
        let be = pool_read(&self.pool).block_elems();
        (2 * self.mapped_blocks() * be * 4) as u64
    }

    fn dirty_lo(&self) -> usize {
        self.dirty_lo
    }

    fn mark_synced(&mut self) {
        self.dirty_lo = usize::MAX;
    }

    fn block_size(&self) -> Option<usize> {
        Some(self.block_size)
    }

    fn committed_block_run(&self, rows: usize) -> Option<Vec<u32>> {
        if self.branch_open || rows == 0 || rows > self.len || rows % self.block_size != 0 {
            return None;
        }
        Some(self.table[..rows / self.block_size].to_vec())
    }

    fn adopt_shared_blocks(&mut self, blocks: &[u32], rows: usize) -> Result<()> {
        if self.branch_open || self.len != 0 || !self.table.is_empty() {
            bail!("adopt_shared_blocks requires an empty cache with no open branch");
        }
        if rows != blocks.len() * self.block_size {
            bail!(
                "adopt_shared_blocks: {rows} rows do not cover {} blocks of {} rows",
                blocks.len(),
                self.block_size
            );
        }
        if rows > self.cap {
            bail!("adopt_shared_blocks: {rows} rows exceed capacity {}", self.cap);
        }
        {
            let mut pool = pool_write(&self.pool);
            for &b in blocks {
                pool.share_block(b)?;
                self.table.push(b);
            }
        }
        self.len = rows;
        // the adopted rows are new content for any bound session mirror
        self.taint(0);
        self.stats.adopted_rows += rows as u64;
        Ok(())
    }
}

impl Drop for PagedCache {
    /// Return every mapped block to the pool — a dropped conversation
    /// must not leak blocks (the free-list invariant). Corruption found
    /// here escalates like lock poisoning, *unless* the thread is
    /// already unwinding — a second panic would abort the process
    /// before the original failure is reported.
    fn drop(&mut self) {
        let res = self.drop_replica().and_then(|()| self.trim_table(0));
        if let Err(e) = res {
            if !std::thread::panicking() {
                pool_corrupt(e);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DIMS: Dims = Dims { layers: 2, d_model: 8, heads: 2, d_head: 2 };
    const CAP: usize = 32;

    fn pool() -> SharedPool {
        Arc::new(RwLock::new(PagePool::new(DIMS, 4)))
    }

    fn mk(strategy: CacheStrategy, p: &SharedPool) -> PagedCache {
        PagedCache::new(DIMS, CAP, strategy, true, p.clone())
    }

    /// `[L, s, H, Dh]` block whose row r carries `base + r` everywhere.
    fn block(s: usize, base: f32) -> Vec<f32> {
        let rs = DIMS.heads * DIMS.d_head;
        let mut out = vec![0.0; DIMS.layers * s * rs];
        for l in 0..DIMS.layers {
            for r in 0..s {
                for e in 0..rs {
                    out[(l * s + r) * rs + e] = base + r as f32;
                }
            }
        }
        out
    }

    fn row_value(c: &PagedCache, row: usize) -> f32 {
        c.committed_row_k(row)[0]
    }

    fn pool_invariant(p: &SharedPool, caches: &[&PagedCache]) {
        let pl = pool_read(p);
        assert_eq!(
            pl.blocks(),
            pl.free_blocks() + pl.referenced_blocks(),
            "pool invariant broken: {} blocks != {} free + {} referenced",
            pl.blocks(),
            pl.free_blocks(),
            pl.referenced_blocks()
        );
        // these tests don't share blocks, so every referenced block is
        // mapped by exactly one table
        let mapped: usize = caches.iter().map(|c| c.mapped_blocks()).sum();
        assert_eq!(pl.referenced_blocks(), mapped, "unshared pools map 1:1");
    }

    #[test]
    fn append_commit_and_trim_blocks() {
        let p = pool();
        let mut c = mk(CacheStrategy::SegmentShare, &p);
        c.append_committed(&block(8, 100.0), &block(8, 200.0), 8, 6).unwrap();
        assert_eq!(c.len(), 6);
        assert_eq!(c.mapped_blocks(), 2); // 6 rows over bs=4
        assert_eq!(row_value(&c, 5), 105.0);
        pool_invariant(&p, &[&c]);

        c.begin_branch().unwrap();
        c.append_branch(&block(8, 500.0), &block(8, 500.0), 8, 7).unwrap();
        assert_eq!(c.mapped_blocks(), 4); // 13 rows
        c.commit_length(3).unwrap();
        assert_eq!(c.len(), 9);
        assert_eq!(c.mapped_blocks(), 3); // trimmed back to 9 rows
        assert_eq!(row_value(&c, 6), 500.0);
        assert_eq!(row_value(&c, 8), 502.0);
        pool_invariant(&p, &[&c]);
    }

    #[test]
    fn rollback_returns_spec_blocks() {
        let p = pool();
        let mut c = mk(CacheStrategy::SegmentShare, &p);
        c.append_committed(&block(8, 1.0), &block(8, 1.0), 8, 4).unwrap();
        let before = c.committed_checksum();
        c.begin_branch().unwrap();
        c.append_branch(&block(8, 9.0), &block(8, 9.0), 8, 8).unwrap();
        assert_eq!(c.committed_checksum(), before, "branch leaked into committed rows");
        c.rollback();
        assert_eq!(c.mapped_blocks(), 1);
        assert_eq!(c.committed_checksum(), before);
        pool_invariant(&p, &[&c]);
    }

    #[test]
    fn deepcopy_replicates_mapped_blocks_only() {
        let p = pool();
        let mut c = mk(CacheStrategy::DeepCopy, &p);
        c.append_committed(&block(8, 1.0), &block(8, 1.0), 8, 5).unwrap();
        c.begin_branch().unwrap();
        // replica of 2 mapped blocks, not cap/bs = 8
        assert_eq!(c.mapped_blocks(), 4);
        assert!(c.stats.replicate_bytes > 0);
        c.append_branch(&block(8, 50.0), &block(8, 50.0), 8, 4).unwrap();
        let before = c.committed_checksum();
        c.commit_path_tail(&[1, 3]).unwrap();
        assert_eq!(c.len(), 7);
        assert_eq!(row_value(&c, 5), 51.0);
        assert_eq!(row_value(&c, 6), 53.0);
        assert!(c.committed_checksum() != before);
        pool_invariant(&p, &[&c]);
    }

    #[test]
    fn commit_guards_match_flat_semantics() {
        let p = pool();
        let mut c = mk(CacheStrategy::SegmentShare, &p);
        assert!(c.commit_length(0).is_err());
        assert!(c.commit_path(&[0]).is_err());
        assert!(c.commit_path_tail(&[0]).is_err());
        c.append_committed(&block(8, 0.0), &block(8, 0.0), 8, 2).unwrap();
        c.begin_branch().unwrap();
        assert!(c.begin_branch().is_err());
        c.append_branch(&block(8, 1.0), &block(8, 1.0), 8, 3).unwrap();
        assert!(c.commit_path_tail(&[3]).is_err(), "offset out of branch");
        assert!(c.commit_path_tail(&[1, 1]).is_err(), "not strictly increasing");
        assert!(c.commit_path(&[0, 9]).is_err(), "index out of view");
        c.commit_path_tail(&[0, 2]).unwrap();
        assert_eq!(c.len(), 4);
        pool_invariant(&p, &[&c]);
    }

    #[test]
    fn two_residents_share_one_pool_without_crosstalk() {
        let p = pool();
        let mut a = mk(CacheStrategy::SegmentShare, &p);
        let mut b = mk(CacheStrategy::SegmentShare, &p);
        a.append_committed(&block(8, 10.0), &block(8, 10.0), 8, 5).unwrap();
        b.append_committed(&block(8, 90.0), &block(8, 90.0), 8, 3).unwrap();
        let ca = a.committed_checksum();
        b.begin_branch().unwrap();
        b.append_branch(&block(8, 70.0), &block(8, 70.0), 8, 6).unwrap();
        b.commit_length(6).unwrap();
        assert_eq!(a.committed_checksum(), ca, "sibling commit corrupted resident A");
        assert_eq!(row_value(&a, 4), 14.0);
        assert_eq!(row_value(&b, 3), 70.0);
        pool_invariant(&p, &[&a, &b]);
        // dropping one resident returns its blocks
        let blocks_before = pool_read(&p).blocks();
        drop(a);
        pool_invariant(&p, &[&b]);
        assert_eq!(pool_read(&p).blocks(), blocks_before, "drop must not create blocks");
        // freed blocks are reused, not regrown
        let mut c = mk(CacheStrategy::SegmentShare, &p);
        c.append_committed(&block(8, 5.0), &block(8, 5.0), 8, 4).unwrap();
        assert_eq!(pool_read(&p).blocks(), blocks_before);
        pool_invariant(&p, &[&b, &c]);
    }

    #[test]
    fn adopted_blocks_are_shared_then_copied_on_write() {
        let p = pool();
        let mut a = mk(CacheStrategy::SegmentShare, &p);
        a.append_committed(&block(8, 10.0), &block(8, 10.0), 8, 8).unwrap();
        let run = a.committed_block_run(8).expect("8 rows over bs=4 are block-aligned");
        assert_eq!(run.len(), 2);
        assert!(a.committed_block_run(6).is_none(), "unaligned runs are not shareable");

        // adopter maps the same physical blocks, refcounted once each
        let mut b = PagedCache::new(DIMS, CAP, CacheStrategy::SegmentShare, false, p.clone());
        b.adopt_shared_blocks(&run, 8).unwrap();
        assert_eq!(b.len(), 8);
        assert_eq!(row_value(&b, 3), 13.0, "adopter reads the donor's rows");
        {
            let pl = pool_read(&p);
            assert_eq!(pl.ref_count(run[0]), 2);
            assert_eq!(pl.referenced_blocks(), 2, "shared blocks count once");
            assert_eq!(pl.blocks(), pl.free_blocks() + pl.referenced_blocks());
        }

        // appends past the shared run never touch it (no copy)
        b.append_committed(&block(4, 80.0), &block(4, 80.0), 4, 2).unwrap();
        assert_eq!(b.stats.cow_copies, 0);
        assert_eq!(row_value(&a, 7), 17.0);

        // a full-reorder commit rewrites b from row 0 — the divergent
        // write must privatize the shared blocks, leaving a untouched
        b.begin_branch().unwrap();
        b.append_branch(&block(4, 90.0), &block(4, 90.0), 4, 2).unwrap();
        let keep: Vec<usize> = (0..11).collect();
        b.commit_path(&keep).unwrap(); // fast_reorder=false -> full reorder
        assert!(b.stats.cow_copies >= 2, "divergent write must clone the shared blocks");
        assert!(b.stats.cow_bytes > 0);
        assert_eq!(b.len(), 11);
        assert_eq!(row_value(&b, 3), 13.0, "cloned block preserved its contents");
        assert_eq!(row_value(&b, 10), 90.0);
        assert_eq!(row_value(&a, 3), 13.0, "donor rows must survive the divergence");
        assert_eq!(a.committed_block_run(8).unwrap(), run, "donor still maps its blocks");
        {
            let pl = pool_read(&p);
            assert_eq!(pl.ref_count(run[0]), 1, "only the donor references the old block");
            assert_eq!(pl.blocks(), pl.free_blocks() + pl.referenced_blocks());
        }
        drop(b);
        drop(a);
        let pl = pool_read(&p);
        assert_eq!(pl.free_blocks(), pl.blocks(), "all blocks return to the free list");
    }

    #[test]
    fn prefix_index_shares_dedups_and_evicts() {
        let pools = CachePools {
            teacher: Arc::new(RwLock::new(PagePool::new(DIMS, 4))),
            draft: Arc::new(RwLock::new(PagePool::new(DIMS, 4))),
            prefix: Arc::new(Mutex::new(PrefixIndex::default())),
        };
        let mk2 = |pools: &CachePools| {
            (
                PagedCache::new(DIMS, CAP, CacheStrategy::SegmentShare, true,
                                pools.teacher.clone()),
                PagedCache::new(DIMS, CAP, CacheStrategy::SegmentShare, true,
                                pools.draft.clone()),
            )
        };
        let (mut t, mut d) = mk2(&pools);
        t.append_committed(&block(8, 10.0), &block(8, 10.0), 8, 8).unwrap();
        d.append_committed(&block(8, 20.0), &block(8, 20.0), 8, 8).unwrap();
        let tokens: Vec<i32> = (0..8).collect();
        let (tb, db) = (t.committed_block_run(8).unwrap(), d.committed_block_run(8).unwrap());
        let feats = vec![vec![1.0; 4], vec![2.0; 4]];
        pools.register_prefix(&tokens, &tb, &db, &feats).unwrap();
        assert_eq!(prefix_lock(&pools.prefix).entries(), 1);
        // re-registering a covered run is a no-op
        pools.register_prefix(&tokens, &tb, &db, &feats).unwrap();
        assert_eq!(prefix_lock(&pools.prefix).entries(), 1);
        assert_eq!(pool_read(&pools.teacher).ref_count(tb[0]), 2, "table + index");

        // the index owns its references: the run survives its donor
        drop(t);
        drop(d);
        assert_eq!(pool_read(&pools.teacher).referenced_blocks(), 2);
        assert!(pools.referenced_bytes() > 0);

        // longest block-aligned match over the full prompt
        let mut prompt = tokens.clone();
        prompt.push(99);
        let hit = pools.lookup_prefix(&prompt, prompt.len() - 1).unwrap();
        assert_eq!(hit.rows, 8);
        assert_eq!(hit.t_blocks, tb);
        assert_eq!(hit.d_blocks, db);
        assert_eq!(hit.feats, feats);
        // divergence inside the second block still shares the first
        let hit = pools.lookup_prefix(&[0, 1, 2, 3, 4, 99], 5).unwrap();
        assert_eq!(hit.rows, 4);
        assert_eq!(hit.t_blocks, &tb[..1]);
        assert_eq!(hit.feats.len(), 1);
        // a sub-block match shares nothing
        assert!(pools.lookup_prefix(&[0, 1, 99], 2).is_none());
        // the max_rows cap always leaves a tail row to prefill
        let hit = pools.lookup_prefix(&tokens, tokens.len() - 1).unwrap();
        assert_eq!(hit.rows, 4);

        // an extending run replaces the shorter entry
        let (mut t2, mut d2) = mk2(&pools);
        t2.append_committed(&block(12, 30.0), &block(12, 30.0), 12, 12).unwrap();
        d2.append_committed(&block(12, 40.0), &block(12, 40.0), 12, 12).unwrap();
        let long: Vec<i32> = (0..12).collect();
        let (tb2, db2) =
            (t2.committed_block_run(12).unwrap(), d2.committed_block_run(12).unwrap());
        pools.register_prefix(&long, &tb2, &db2, &[vec![0.0], vec![0.0], vec![0.0]]).unwrap();
        assert_eq!(prefix_lock(&pools.prefix).entries(), 1, "extension replaces the shorter run");
        let hit = pools.lookup_prefix(&[0, 1, 2, 3, 4, 5, 6, 7, 8, 99], 9).unwrap();
        assert_eq!(hit.rows, 8, "the shorter prefix still matches through the longer run");
        drop(t2);
        drop(d2);

        // FIFO eviction past the cap releases the oldest run's blocks
        for i in 0..PREFIX_INDEX_CAP {
            let (mut t3, mut d3) = mk2(&pools);
            t3.append_committed(&block(4, 50.0), &block(4, 50.0), 4, 4).unwrap();
            d3.append_committed(&block(4, 60.0), &block(4, 60.0), 4, 4).unwrap();
            let toks = vec![1000 + i as i32, -1, -2, -3];
            pools.register_prefix(
                &toks,
                &t3.committed_block_run(4).unwrap(),
                &d3.committed_block_run(4).unwrap(),
                &[vec![0.0]],
            )
            .unwrap();
        }
        assert_eq!(prefix_lock(&pools.prefix).entries(), PREFIX_INDEX_CAP);
        assert!(pools.lookup_prefix(&long, 11).is_none(), "the oldest entry was evicted");
        {
            let pl = pool_read(&pools.teacher);
            assert_eq!(pl.blocks(), pl.free_blocks() + pl.referenced_blocks());
        }
        pools.clear_prefix_index().unwrap();
        assert_eq!(prefix_lock(&pools.prefix).entries(), 0);
        let pl = pool_read(&pools.teacher);
        assert_eq!(pl.free_blocks(), pl.blocks(), "clearing releases every reference");
        let pd = pool_read(&pools.draft);
        assert_eq!(pd.free_blocks(), pd.blocks());
    }

    #[test]
    fn refcount_violations_are_typed_errors_in_release_builds() {
        // These guards used to be debug_assert!s; they must now fire in
        // every build profile and name the exact violation.
        let p = pool();
        let mut pl = pool_write(&p);
        assert_eq!(
            pl.share_block(0),
            Err(PageError::ShareFree { block: 0 }),
            "sharing an unbacked block is a use-after-free"
        );
        let b = pl.alloc_block().unwrap();
        pl.share_block(b).unwrap();
        pl.release_block(b).unwrap();
        pl.release_block(b).unwrap();
        assert_eq!(pl.ref_count(b), 0);
        assert_eq!(
            pl.release_block(b),
            Err(PageError::DoubleFree { block: b }),
            "a third release of a twice-referenced block is a double free"
        );
        assert_eq!(
            pl.release_block(99),
            Err(PageError::ReleaseUnbacked { block: 99 }),
            "releasing a block the pool never created"
        );
        assert_eq!(
            pl.share_block(b),
            Err(PageError::ShareFree { block: b }),
            "sharing a freed block is a use-after-free"
        );
        // free-list corruption: hand-tear the bookkeeping, then alloc
        pl.refs[udx(b)] = 1; // b is still on the free list
        assert_eq!(pl.alloc_block(), Err(PageError::FreeListCorrupt { block: b, refs: 1 }));
        // every variant renders a message naming the block
        for e in [
            PageError::FreeListCorrupt { block: 7, refs: 2 },
            PageError::ReleaseUnbacked { block: 7 },
            PageError::DoubleFree { block: 7 },
            PageError::ShareFree { block: 7 },
        ] {
            assert!(e.to_string().contains('7'), "{e} should name the block");
        }
    }

    #[test]
    fn ensure_headroom_prevents_storage_growth() {
        let p = pool();
        pool_write(&p).ensure_headroom(CAP);
        let cap_before = pool_read(&p).k.capacity();
        assert!(cap_before >= CAP.div_ceil(4) * pool_read(&p).block_elems());
        let mut c = mk(CacheStrategy::SegmentShare, &p);
        c.append_committed(&block(8, 1.0), &block(8, 1.0), 8, 8).unwrap();
        c.begin_branch().unwrap();
        c.append_branch(&block(8, 2.0), &block(8, 2.0), 8, 8).unwrap();
        c.commit_length(8).unwrap();
        assert_eq!(
            pool_read(&p).k.capacity(),
            cap_before,
            "mapping within reserved headroom must not reallocate the pool"
        );
        // headroom already satisfied -> idempotent
        pool_write(&p).ensure_headroom(CAP - 16);
        assert_eq!(pool_read(&p).k.capacity(), cap_before);
    }

    #[test]
    fn ensure_headroom_accounts_unbacked_spare_capacity() {
        // Regression: `Vec::reserve` is relative to len, so unbacked
        // spare capacity (left behind by amortized growth) must not be
        // double-counted — after ensure_headroom(n), mapping n rows must
        // never reallocate, whatever the pool's growth history.
        let p = pool();
        let mut c = mk(CacheStrategy::SegmentShare, &p);
        // organic growth, one block at a time
        c.append_committed(&block(8, 1.0), &block(8, 1.0), 8, 8).unwrap(); // 2 blocks
        c.append_committed(&block(4, 2.0), &block(4, 2.0), 4, 4).unwrap(); // 3rd block
        pool_write(&p).ensure_headroom(8); // promise 2 more blocks
        let cap_before = pool_read(&p).k.capacity();
        c.begin_branch().unwrap();
        c.append_branch(&block(8, 3.0), &block(8, 3.0), 8, 8).unwrap(); // maps 2 blocks
        assert_eq!(
            pool_read(&p).k.capacity(),
            cap_before,
            "reserved headroom must cover the mapped blocks without reallocating"
        );
        c.commit_length(8).unwrap();
        pool_invariant(&p, &[&c]);
    }
}
