//! Throughput/acceptance report construction (paper Table 1 + Fig 2/3).

use crate::json::Json;
use crate::trace::TurnRecord;
use crate::util::stats::{AcceptPos, Summary};
use std::collections::BTreeMap;

/// A matched baseline/EA pair for one turn.
#[derive(Clone, Debug)]
pub struct TurnPair {
    /// `(conversation_id, turn_idx)` identifying the turn.
    pub key: (usize, usize),
    /// The teacher-only record of this turn.
    pub baseline: TurnRecord,
    /// The tree-speculation record of this turn.
    pub ea: TurnRecord,
}

impl TurnPair {
    /// EA-over-baseline throughput ratio of this turn.
    pub fn speedup(&self) -> f64 {
        if self.baseline.tok_s <= 0.0 {
            0.0
        } else {
            self.ea.tok_s / self.baseline.tok_s
        }
    }
}

/// Pair `kind == "baseline"` with `kind == "ea"` records per (conv, turn).
pub fn pair_turns(records: &[TurnRecord]) -> Vec<TurnPair> {
    let mut base: BTreeMap<(usize, usize), &TurnRecord> = BTreeMap::new();
    let mut ea: BTreeMap<(usize, usize), &TurnRecord> = BTreeMap::new();
    for r in records {
        let key = (r.conversation_id, r.turn_idx);
        match r.kind.as_str() {
            "baseline" => {
                base.insert(key, r);
            }
            "ea" => {
                ea.insert(key, r);
            }
            _ => {}
        }
    }
    base.iter()
        .filter_map(|(key, b)| {
            ea.get(key).map(|e| TurnPair {
                key: *key,
                baseline: (*b).clone(),
                ea: (*e).clone(),
            })
        })
        .collect()
}

/// Table-1-shaped report.
#[derive(Clone, Debug)]
pub struct ThroughputReport {
    /// Number of paired turns aggregated.
    pub turns: usize,
    /// Baseline tokens/second across turns.
    pub baseline_tok_s: Summary,
    /// EA tokens/second across turns.
    pub ea_tok_s: Summary,
    /// Per-turn speedup distribution.
    pub speedup: Summary,
    /// accept_L distribution across all verification rounds.
    pub accept_l: Summary,
    /// Position-wise acceptance counters (Fig 3).
    pub accept_pos: AcceptPos,
}

impl ThroughputReport {
    /// Aggregate matched pairs into the Table-1 statistics.
    pub fn from_pairs(pairs: &[TurnPair]) -> Self {
        let b: Vec<f64> = pairs.iter().map(|p| p.baseline.tok_s).collect();
        let e: Vec<f64> = pairs.iter().map(|p| p.ea.tok_s).collect();
        let s: Vec<f64> = pairs.iter().map(TurnPair::speedup).collect();
        // accept_L flattened across all EA verification steps (paper Table 1)
        let mut al: Vec<f64> = Vec::new();
        let mut pos = AcceptPos::default();
        for p in pairs {
            al.extend(p.ea.accept_lens.iter().map(|x| *x as f64));
            pos.merge(&AcceptPos {
                offered: p.ea.accept_offered.clone(),
                accepted: p.ea.accept_accepted.clone(),
            });
        }
        Self {
            turns: pairs.len(),
            baseline_tok_s: Summary::from(&b),
            ea_tok_s: Summary::from(&e),
            speedup: Summary::from(&s),
            accept_l: Summary::from(&al),
            accept_pos: pos,
        }
    }

    /// Render the paper's Table-1 layout.
    pub fn table1(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "Table 1: throughput microbenchmark ({} turns)\n", self.turns));
        out.push_str("| Metric          |     mean |      p50 |      p90 |      p99 |\n");
        out.push_str("|-----------------|----------|----------|----------|----------|\n");
        let row = |name: &str, s: &Summary| {
            format!(
                "| {:<15} | {:>8.2} | {:>8.2} | {:>8.2} | {:>8.2} |\n",
                name, s.mean, s.p50, s.p90, s.p99
            )
        };
        out.push_str(&row("Baseline Tok/s", &self.baseline_tok_s));
        out.push_str(&row("EA Tok/s", &self.ea_tok_s));
        out.push_str(&row("Speedup (x)", &self.speedup));
        out.push_str(&row("accept_L (L_k)", &self.accept_l));
        out
    }

    /// Machine-readable form of the report.
    pub fn to_json(&self) -> Json {
        let summary = |s: &Summary| {
            let mut o = Json::obj();
            o.push("mean", s.mean).push("p50", s.p50).push("p90", s.p90).push("p99", s.p99);
            o
        };
        let mut o = Json::obj();
        o.push("turns", self.turns)
            .push("baseline_tok_s", summary(&self.baseline_tok_s))
            .push("ea_tok_s", summary(&self.ea_tok_s))
            .push("speedup", summary(&self.speedup))
            .push("accept_l", summary(&self.accept_l))
            .push("accept_pos", Json::from_f64_slice(&self.accept_pos.rates()));
        o
    }
}

/// Fig-2b series: per-turn (mean L_k, speedup) pairs as CSV.
pub fn speedup_vs_lk_csv(pairs: &[TurnPair]) -> String {
    let mut out = String::from("conversation_id,turn_idx,mean_lk,speedup\n");
    for p in pairs {
        out.push_str(&format!(
            "{},{},{:.4},{:.4}\n",
            p.key.0,
            p.key.1,
            p.ea.mean_accept(),
            p.speedup()
        ));
    }
    out
}

/// Fig-2a series: speedup histogram as CSV (bucket, count).
pub fn speedup_hist_csv(pairs: &[TurnPair]) -> String {
    let mut buckets: BTreeMap<i64, u64> = BTreeMap::new();
    for p in pairs {
        let b = (p.speedup() / 0.1).floor() as i64;
        *buckets.entry(b).or_insert(0) += 1;
    }
    let mut out = String::from("speedup_bucket_low,count\n");
    for (b, c) in buckets {
        out.push_str(&format!("{:.1},{}\n", b as f64 * 0.1, c));
    }
    out
}

/// Fig-3 series: position-wise acceptance rates as CSV.
pub fn accept_pos_csv(report: &ThroughputReport) -> String {
    let mut out = String::from("draft_position,accept_rate,offered\n");
    for (i, r) in report.accept_pos.rates().iter().enumerate() {
        out.push_str(&format!("{},{:.4},{}\n", i + 1, r, report.accept_pos.offered[i]));
    }
    out
}

/// Fig-1 series: prompt/output length distributions as CSV.
pub fn lengths_csv(records: &[TurnRecord]) -> String {
    let mut out = String::from("kind,conversation_id,turn_idx,prompt_len,output_len\n");
    for r in records {
        out.push_str(&format!(
            "{},{},{},{},{}\n",
            r.kind, r.conversation_id, r.turn_idx, r.prompt_len, r.output_len
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap as Map;

    fn rec(conv: usize, kind: &str, tok_s: f64, accepts: Vec<usize>) -> TurnRecord {
        TurnRecord {
            conversation_id: conv,
            turn_idx: 0,
            rank: 0,
            profile: "code".into(),
            kind: kind.into(),
            prompt_len: 10,
            output_len: 20,
            wall_secs: 20.0 / tok_s,
            tok_s,
            teacher_calls: 10,
            draft_calls: 20,
            rounds: 10,
            accept_lens: accepts.clone(),
            accept_offered: vec![accepts.len() as u64; 3],
            accept_accepted: vec![accepts.iter().filter(|a| **a >= 1).count() as u64, 0, 0],
            stage_seconds: Map::new(),
            attn_buckets: vec![],
        }
    }

    #[test]
    fn pairing_and_speedup() {
        let records = vec![
            rec(0, "baseline", 10.0, vec![]),
            rec(0, "ea", 15.0, vec![2, 3]),
            rec(1, "baseline", 10.0, vec![]),
            rec(1, "ea", 20.0, vec![4]),
            rec(2, "ea", 99.0, vec![]), // unmatched — dropped
        ];
        let pairs = pair_turns(&records);
        assert_eq!(pairs.len(), 2);
        assert!((pairs[0].speedup() - 1.5).abs() < 1e-12);
        let rep = ThroughputReport::from_pairs(&pairs);
        assert_eq!(rep.turns, 2);
        assert!((rep.speedup.mean - 1.75).abs() < 1e-12);
        assert!((rep.accept_l.mean - 3.0).abs() < 1e-12);
        let t = rep.table1();
        assert!(t.contains("Baseline Tok/s") && t.contains("Speedup"));
    }

    #[test]
    fn csv_outputs_have_headers_and_rows() {
        let records =
            vec![rec(0, "baseline", 10.0, vec![]), rec(0, "ea", 12.0, vec![1])];
        let pairs = pair_turns(&records);
        let rep = ThroughputReport::from_pairs(&pairs);
        assert!(speedup_vs_lk_csv(&pairs).lines().count() == 2);
        assert!(speedup_hist_csv(&pairs).starts_with("speedup_bucket_low"));
        assert!(accept_pos_csv(&rep).lines().count() >= 2);
        assert!(lengths_csv(&records).lines().count() == 3);
    }

    #[test]
    fn report_json_shape() {
        let records =
            vec![rec(0, "baseline", 10.0, vec![]), rec(0, "ea", 12.0, vec![1])];
        let rep = ThroughputReport::from_pairs(&pair_turns(&records));
        let j = rep.to_json();
        assert!(j.at("speedup.mean").is_some());
        assert!(j.get("accept_pos").is_some());
    }
}
