//! Metric aggregation: turns paired baseline/EA trace records into the
//! paper's tables and figure series (Table 1-3, Fig 1-4, Fig 5-7 inputs).

pub mod report;

pub use report::{pair_turns, ThroughputReport};
