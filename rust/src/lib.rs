//! EAGLE-Pangu: accelerator-safe tree speculative decoding.
//!
//! Rust reproduction of "EAGLE-Pangu: Accelerator-Safe Tree Speculative
//! Decoding on Ascend NPUs" (Han, Hu, Liu, 2026). This crate is the L3
//! coordinator of a three-layer stack:
//!
//! * **L1** — a Pallas fused tree-attention kernel (build-time python,
//!   `python/compile/kernels/`), the stand-in for the Ascend fused kernel;
//! * **L2** — TinyPangu teacher + TinyEagle draft JAX models AOT-lowered to
//!   HLO text (`python/compile/`, `make artifacts`);
//! * **L3** — this crate: the paper's system contribution. It owns the
//!   branchable KV-cache manager ([`cache`]), accelerator-safe tree
//!   tensorization ([`tree`]), the speculative decode engine ([`engine`])
//!   and its policies ([`spec`]), the serving coordinator with
//!   cross-request batched verification ([`coordinator`]), plus every
//!   substrate the paper depends on (workload generation, tracing,
//!   metrics, a JSON codec, a CLI, and a property-testing harness — the
//!   image has no tokio/serde/clap/criterion, so these are built
//!   in-repo).
//!
//! Python never runs on the request path: after `make artifacts`, the rust
//! binary is self-contained, loading `artifacts/*.hlo.txt` through the PJRT
//! CPU client ([`runtime`]).
//!
//! # Dataflow in one paragraph
//!
//! A prompt is prefilled through the teacher in chunks; each speculative
//! round then drafts a token tree ([`tree::SpecTree`] →
//! [`tree::Tensorized`]), builds the tree-attention mask
//! ([`tree::MaskBuilder`]), verifies the whole tree in **one** teacher
//! call (per request — or one *fused* call for a whole batch of requests
//! through [`coordinator::ContinuousScheduler`]), walks acceptance
//! ([`spec::greedy_walk`]) and commits `1 + accept_L` tokens into the
//! managed KV cache ([`cache::ManagedCache`]). Under greedy acceptance
//! the committed text is bit-identical to teacher-only decoding; only the
//! wall-clock changes. `docs/ARCHITECTURE.md` walks the full pipeline
//! module by module, including the batching/padding contract;
//! `docs/TRACE_FORMAT.md` documents the structured trace schema.
//!
//! # Where to start reading
//!
//! * [`engine::Engine`] — the decode loop and the split-round API that
//!   batched serving drives;
//! * [`backend::ModelBackend`] — the scratch-buffer step contract (sim
//!   and PJRT implementations);
//! * [`coordinator::ContinuousScheduler`] — continuous cross-request
//!   batching: fused verification plus slot-based admission/retirement
//!   and park/resume multi-turn residency;
//! * [`cache::KvStore`] — branch/commit semantics (paper §3.1) behind a
//!   layout-agnostic contract: [`cache::ManagedCache`] (flat buffers)
//!   and [`cache::PagedCache`] (block tables over a shared per-worker
//!   [`cache::PagePool`]) decode bit-identically; `--cache-layout`
//!   selects.

// Checked invariant: the entire library is safe Rust. `forbid` (not
// `deny`) so no module can locally reopen it; the one unavoidable
// `unsafe impl GlobalAlloc` (the allocation-counting shim) lives in
// `tests/support/alloc_count.rs`, outside the library crate. The
// `unsafe-code` static-analysis rule keeps this attribute present.
#![forbid(unsafe_code)]
#![warn(missing_docs)]

pub mod analysis;
pub mod backend;
pub mod cache;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod harness;
pub mod json;
pub mod metrics;
pub mod rpc;
pub mod runtime;
pub mod spec;
pub mod trace;
pub mod tree;
pub mod util;
pub mod workload;
