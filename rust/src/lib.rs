//! EAGLE-Pangu: accelerator-safe tree speculative decoding.
//!
//! Rust reproduction of "EAGLE-Pangu: Accelerator-Safe Tree Speculative
//! Decoding on Ascend NPUs" (Han, Hu, Liu, 2026). This crate is the L3
//! coordinator of a three-layer stack:
//!
//! * **L1** — a Pallas fused tree-attention kernel (build-time python,
//!   `python/compile/kernels/`), the stand-in for the Ascend fused kernel;
//! * **L2** — TinyPangu teacher + TinyEagle draft JAX models AOT-lowered to
//!   HLO text (`python/compile/`, `make artifacts`);
//! * **L3** — this crate: the paper's system contribution. It owns the
//!   branchable KV-cache manager ([`cache`]), accelerator-safe tree
//!   tensorization ([`tree`]), the speculative decode engine ([`spec`]),
//!   the serving coordinator ([`coordinator`]), plus every substrate the
//!   paper depends on (workload generation, tracing, metrics, a JSON
//!   codec, a CLI, and a property-testing harness — the image has no
//!   tokio/serde/clap/criterion, so these are built in-repo).
//!
//! Python never runs on the request path: after `make artifacts`, the rust
//! binary is self-contained, loading `artifacts/*.hlo.txt` through the PJRT
//! CPU client ([`runtime`]).

pub mod backend;
pub mod cache;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod harness;
pub mod json;
pub mod metrics;
pub mod runtime;
pub mod spec;
pub mod trace;
pub mod tree;
pub mod util;
pub mod workload;
