//! Typed channel RPC between the serving coordinator and its engine
//! workers (remoc's model — multiplexed typed channels with built-in
//! backpressure — rebuilt on `std::thread` + `std::sync::mpsc`).
//!
//! Three layers, outermost first:
//!
//! * [`channel`] — [`WireSender`]/[`WireReceiver`]: bounded typed
//!   channels whose every message crosses as serialized bytes, codec
//!   chosen by type parameter.
//! * [`envelope`] — the protocol itself: [`Envelope`] and its command
//!   (coordinator → worker) and event (worker → coordinator) payloads.
//! * [`codec`] — the pluggable byte format: [`Wire`] (structure ↔ JSON)
//!   and [`Codec`] (JSON ↔ bytes), with [`JsonCodec`] as the default and
//!   [`FramedJsonCodec`] proving the seam.
//!
//! The serving split that uses these lives in `coordinator::front`
//! (routing front end) and `coordinator::worker` (per-thread engine
//! worker).

pub mod channel;
pub mod codec;
pub mod envelope;

pub use channel::{wire_channel, ChannelError, WireReceiver, WireSender};
pub use codec::{Codec, DeserializationError, FramedJsonCodec, JsonCodec, SerializationError, Wire};
pub use envelope::{
    Abort, Completion, Envelope, Park, RequestKind, Resume, ShedNotice, Submit, TokenDelta,
    TurnDone, WorkerStats,
};
