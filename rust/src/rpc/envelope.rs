//! The typed message envelopes of the coordinator/worker protocol.
//!
//! Direction and roles:
//!
//! * **Commands** (coordinator → worker): [`Submit`] a conversation
//!   turn, [`Resume`] a parked conversation with its follow-up prompt,
//!   [`Abort`] one conversation or everything in flight.
//! * **Events** (worker → coordinator): [`TokenDelta`] streams tokens
//!   committed since the last tick, [`Park`] reports a finished turn of
//!   a conversation kept resident for a later [`Resume`], [`Completion`]
//!   reports a finished final turn (slot released), [`ShedNotice`]
//!   reports an admission-queue shed, [`WorkerStats`] carries the
//!   worker's scheduler counters (and, flagged `is_final`, doubles as
//!   the drain handshake on shutdown — see `coordinator::front`).
//!
//! Everything crosses the channel through [`Wire`]/[`Codec`] — actual
//! serialized bytes, not shared memory — so the protocol would survive
//! relocating a worker behind a socket. [`Envelope`] is the tagged
//! union carried by both channel directions.

use crate::cache::CacheStats;
use crate::coordinator::{SchedulerStats, ShedNotice as SchedShedNotice, SloAction, SloPolicy};
use crate::engine::GenOut;
use crate::json::Json;
use crate::rpc::codec::{
    req, req_bool, req_f64, req_f64s, req_i32s, req_str, req_u64, req_u64s, req_usize,
    DeserializationError, Wire,
};
use crate::util::stats::{AcceptPos, Histogram};
use crate::util::StageTimer;

/// Which decoding path serves a submitted conversation turn.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestKind {
    /// Speculative (EAGLE) decoding through the scheduler.
    Ea,
    /// Autoregressive baseline decoding.
    Baseline,
}

impl RequestKind {
    /// Stable string form.
    pub fn as_str(&self) -> &'static str {
        match self {
            RequestKind::Ea => "ea",
            RequestKind::Baseline => "baseline",
        }
    }

    fn parse(s: &str) -> Result<Self, DeserializationError> {
        match s {
            "ea" => Ok(RequestKind::Ea),
            "baseline" => Ok(RequestKind::Baseline),
            other => Err(DeserializationError(format!("unknown request kind '{other}'"))),
        }
    }
}

/// Command: admit a conversation's first turn on the receiving worker.
#[derive(Clone, Debug)]
pub struct Submit {
    /// Global conversation id (consistent-hash routed; unique per run).
    pub id: u64,
    /// Prompt tokens of this turn.
    pub prompt: Vec<i32>,
    /// Output-token budget of this turn.
    pub max_new: usize,
    /// Trace arrival time (virtual ms); drives replay-mode admission.
    pub arrival_ms: f64,
    /// Decoding path for this conversation.
    pub kind: RequestKind,
    /// Keep the conversation resident after this turn finishes (a
    /// [`Resume`] will follow); emits [`Park`] instead of [`Completion`].
    pub park_on_complete: bool,
    /// Per-request latency SLO, if any.
    pub slo: Option<SloPolicy>,
    /// Marks the end of the initial submission batch: a replay-mode
    /// worker buffers arrivals until it sees `last`, then runs its shard
    /// on the virtual clock (deterministic regardless of channel timing).
    pub last: bool,
    /// Serve this turn on the sequential (slot-0, non-scheduler) path —
    /// the coordinator's retry lane for conversations that previously
    /// failed inside a scheduler group.
    pub isolated: bool,
}

/// Command: hand a parked conversation its next turn's prompt.
#[derive(Clone, Debug)]
pub struct Resume {
    /// Conversation id (must be parked on the receiving worker).
    pub id: u64,
    /// Follow-up prompt tokens.
    pub prompt: Vec<i32>,
    /// Output-token budget of this turn.
    pub max_new: usize,
    /// Keep resident again after this turn (another [`Resume`] follows).
    pub park_on_complete: bool,
}

/// Command: abandon one conversation (`id: Some`) or everything the
/// worker holds (`id: None` — queue, parked and in-flight state alike).
#[derive(Clone, Debug)]
pub struct Abort {
    /// The conversation to abort, or `None` for all.
    pub id: Option<u64>,
}

/// Event: tokens the conversation committed since the previous delta —
/// the per-request streaming surface. Deltas for one id concatenate to
/// exactly the turn's final `GenOut::tokens` (asserted in tests).
#[derive(Clone, Debug, PartialEq)]
pub struct TokenDelta {
    /// Conversation id.
    pub id: u64,
    /// Zero-based turn index the tokens belong to.
    pub turn: usize,
    /// Newly committed tokens, in order.
    pub tokens: Vec<i32>,
}

/// The shared body of [`Park`] and [`Completion`]: one finished turn
/// with its output and admission timeline.
#[derive(Clone, Debug)]
pub struct TurnDone {
    /// Conversation id.
    pub id: u64,
    /// Rank of the worker that served the turn.
    pub rank: usize,
    /// Zero-based turn index.
    pub turn: usize,
    /// The turn's full generation output.
    pub out: GenOut,
    /// Scheduler tick the request was submitted on.
    pub submitted_tick: u64,
    /// Scheduler tick the request was admitted to a slot.
    pub admitted_tick: u64,
    /// Scheduler tick the turn retired.
    pub finished_tick: u64,
    /// Ticks spent waiting in the admission queue.
    pub waited_ticks: u64,
    /// Worker virtual-clock time at retirement (ms) — the coordinator
    /// computes latency as `finished_ms - arrival_ms` without ever
    /// seeing the worker's clock object.
    pub finished_ms: f64,
}

/// Event: a turn finished and the conversation stays resident (parked
/// block tables + chain feature) awaiting [`Resume`].
#[derive(Clone, Debug)]
pub struct Park {
    /// The finished turn.
    pub done: TurnDone,
}

/// Event: a turn finished and the conversation is released.
#[derive(Clone, Debug)]
pub struct Completion {
    /// The finished turn.
    pub done: TurnDone,
}

/// Event: the worker's scheduler shed a queued request past its SLO
/// deadline. Wraps the scheduler-level notice with the worker's rank so
/// the coordinator can aggregate shed accounting per worker.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShedNotice {
    /// Rank of the shedding worker.
    pub rank: usize,
    /// The scheduler's shed record.
    pub notice: SchedShedNotice,
}

/// Event: a worker's cumulative scheduler counters. Sent with
/// `is_final: true` exactly once, as the last message before the worker
/// thread exits — the coordinator's drain barrier. A worker that dies
/// on an engine error still sends it, with `error: Some(..)`, so
/// failures surface instead of hanging the drain. Shed notices raised
/// *after* the coordinator stopped reading per-tick events ride along
/// in `shed` (the regression test for the silently-dropped-shed bug).
#[derive(Clone, Debug)]
pub struct WorkerStats {
    /// Worker rank.
    pub rank: usize,
    /// Cumulative scheduler counters.
    pub stats: SchedulerStats,
    /// Shed notices not yet surfaced through [`ShedNotice`] events.
    pub shed: Vec<SchedShedNotice>,
    /// True on the worker's last message (drain handshake).
    pub is_final: bool,
    /// Present when the worker is reporting a fatal error.
    pub error: Option<String>,
}

/// The tagged union both RPC directions carry: commands flow
/// coordinator → worker, events worker → coordinator. One type for both
/// keeps the channel layer simple; direction is enforced by which end
/// sends what (debug-asserted in `coordinator::worker`).
#[derive(Clone, Debug)]
pub enum Envelope {
    /// Admit a conversation turn.
    Submit(Submit),
    /// Resume a parked conversation.
    Resume(Resume),
    /// Abort one or all conversations.
    Abort(Abort),
    /// Stream newly committed tokens.
    TokenDelta(TokenDelta),
    /// A turn finished; conversation stays resident.
    Park(Park),
    /// A turn finished; conversation released.
    Completion(Completion),
    /// A queued request was shed past its SLO deadline.
    ShedNotice(ShedNotice),
    /// Worker scheduler counters (final = drain handshake).
    WorkerStats(WorkerStats),
}

impl Envelope {
    /// The stable tag string of this envelope's variant.
    pub fn kind_str(&self) -> &'static str {
        match self {
            Envelope::Submit(_) => "submit",
            Envelope::Resume(_) => "resume",
            Envelope::Abort(_) => "abort",
            Envelope::TokenDelta(_) => "token_delta",
            Envelope::Park(_) => "park",
            Envelope::Completion(_) => "completion",
            Envelope::ShedNotice(_) => "shed_notice",
            Envelope::WorkerStats(_) => "worker_stats",
        }
    }
}

// ---------------------------------------------------------------------
// Wire impls — building blocks first, envelopes after.
// ---------------------------------------------------------------------

impl Wire for SloPolicy {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("target_ms", self.target_ms).push("action", self.action.as_str());
        o
    }

    fn from_json(j: &Json) -> Result<Self, DeserializationError> {
        let action = SloAction::parse(&req_str(j, "SloPolicy", "action")?)
            .map_err(|e| DeserializationError(format!("{e:#}")))?;
        Ok(Self { target_ms: req_f64(j, "SloPolicy", "target_ms")?, action })
    }
}

impl Wire for SchedShedNotice {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("id", self.id)
            .push("submitted_tick", self.submitted_tick)
            .push("shed_tick", self.shed_tick)
            .push("waited_ms", self.waited_ms)
            .push("target_ms", self.target_ms);
        o
    }

    fn from_json(j: &Json) -> Result<Self, DeserializationError> {
        const TY: &str = "ShedNotice";
        Ok(Self {
            id: req_u64(j, TY, "id")?,
            submitted_tick: req_u64(j, TY, "submitted_tick")?,
            shed_tick: req_u64(j, TY, "shed_tick")?,
            waited_ms: req_f64(j, TY, "waited_ms")?,
            target_ms: req_f64(j, TY, "target_ms")?,
        })
    }
}

impl Wire for SchedulerStats {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("submitted", self.submitted)
            .push("admitted", self.admitted)
            .push("retired", self.retired)
            .push("parked", self.parked)
            .push("resumed", self.resumed)
            .push("ticks", self.ticks)
            .push("fused_launches", self.fused_launches)
            .push("max_wait_ticks", self.max_wait_ticks)
            .push("shed", self.shed)
            .push("prefill_teacher_calls", self.prefill_teacher_calls);
        o
    }

    fn from_json(j: &Json) -> Result<Self, DeserializationError> {
        const TY: &str = "SchedulerStats";
        Ok(Self {
            submitted: req_u64(j, TY, "submitted")?,
            admitted: req_u64(j, TY, "admitted")?,
            retired: req_u64(j, TY, "retired")?,
            parked: req_u64(j, TY, "parked")?,
            resumed: req_u64(j, TY, "resumed")?,
            ticks: req_u64(j, TY, "ticks")?,
            fused_launches: req_u64(j, TY, "fused_launches")?,
            max_wait_ticks: req_u64(j, TY, "max_wait_ticks")?,
            shed: req_u64(j, TY, "shed")?,
            prefill_teacher_calls: req_u64(j, TY, "prefill_teacher_calls")?,
        })
    }
}

impl Wire for CacheStats {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("branches", self.branches)
            .push("commits", self.commits)
            .push("rollbacks", self.rollbacks)
            .push("replicate_bytes", self.replicate_bytes)
            .push("append_bytes", self.append_bytes)
            .push("commit_bytes", self.commit_bytes)
            .push("fast_reorders", self.fast_reorders)
            .push("fast_fallbacks", self.fast_fallbacks)
            .push("full_reorders", self.full_reorders)
            .push("cow_copies", self.cow_copies)
            .push("cow_bytes", self.cow_bytes)
            .push("adopted_rows", self.adopted_rows);
        o
    }

    fn from_json(j: &Json) -> Result<Self, DeserializationError> {
        const TY: &str = "CacheStats";
        Ok(Self {
            branches: req_u64(j, TY, "branches")?,
            commits: req_u64(j, TY, "commits")?,
            rollbacks: req_u64(j, TY, "rollbacks")?,
            replicate_bytes: req_u64(j, TY, "replicate_bytes")?,
            append_bytes: req_u64(j, TY, "append_bytes")?,
            commit_bytes: req_u64(j, TY, "commit_bytes")?,
            fast_reorders: req_u64(j, TY, "fast_reorders")?,
            fast_fallbacks: req_u64(j, TY, "fast_fallbacks")?,
            full_reorders: req_u64(j, TY, "full_reorders")?,
            cow_copies: req_u64(j, TY, "cow_copies")?,
            cow_bytes: req_u64(j, TY, "cow_bytes")?,
            adopted_rows: req_u64(j, TY, "adopted_rows")?,
        })
    }
}

impl Wire for GenOut {
    fn to_json(&self) -> Json {
        let mut timers = Json::obj();
        timers
            .push("seconds", Json::from_str_map(&self.timers.seconds))
            .push(
                "calls",
                Json::Obj(
                    self.timers
                        .calls
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                        .collect(),
                ),
            );
        let mut hist = Json::obj();
        hist.push("edges", Json::from_f64_slice(&self.attn_hist.edges))
            .push("counts", Json::from_u64_slice(&self.attn_hist.counts))
            .push("total", self.attn_hist.total);
        let mut pos = Json::obj();
        pos.push("offered", Json::from_u64_slice(&self.accept_pos.offered))
            .push("accepted", Json::from_u64_slice(&self.accept_pos.accepted));
        let mut o = Json::obj();
        o.push("tokens", Json::Arr(self.tokens.iter().map(|t| Json::Num(*t as f64)).collect()))
            .push("wall_secs", self.wall_secs)
            .push("teacher_calls", self.teacher_calls)
            .push("draft_calls", self.draft_calls)
            .push("rounds", self.rounds)
            .push(
                "accept_lens",
                Json::Arr(self.accept_lens.iter().map(|a| Json::Num(*a as f64)).collect()),
            )
            .push("accept_pos", pos)
            .push("timers", timers)
            .push("attn_hist", hist)
            .push("teacher_cache", self.teacher_cache.to_json())
            .push("draft_cache", self.draft_cache.to_json())
            .push("prompt_len", self.prompt_len);
        o
    }

    fn from_json(j: &Json) -> Result<Self, DeserializationError> {
        const TY: &str = "GenOut";
        let pos = req(j, TY, "accept_pos")?;
        let accept_pos = AcceptPos {
            offered: req_u64s(pos, TY, "offered")?,
            accepted: req_u64s(pos, TY, "accepted")?,
        };
        let tj = req(j, TY, "timers")?;
        // A deserialized timer never times anything again — it is a
        // record of the worker-side run, so it rebuilds disabled with
        // the accumulated maps assigned directly.
        let mut timers = StageTimer::new(false);
        if let Some(pairs) = req(tj, TY, "seconds")?.as_obj() {
            for (k, v) in pairs {
                let x = v.as_f64().ok_or_else(|| DeserializationError::field(TY, "seconds"))?;
                timers.seconds.insert(k.clone(), x);
            }
        }
        if let Some(pairs) = req(tj, TY, "calls")?.as_obj() {
            for (k, v) in pairs {
                let x = v.as_f64().ok_or_else(|| DeserializationError::field(TY, "calls"))?;
                timers.calls.insert(k.clone(), x as u64);
            }
        }
        let hj = req(j, TY, "attn_hist")?;
        let attn_hist = Histogram {
            edges: req_f64s(hj, TY, "edges")?,
            counts: req_u64s(hj, TY, "counts")?,
            total: req_u64(hj, TY, "total")?,
        };
        Ok(Self {
            tokens: req_i32s(j, TY, "tokens")?,
            wall_secs: req_f64(j, TY, "wall_secs")?,
            teacher_calls: req_u64(j, TY, "teacher_calls")?,
            draft_calls: req_u64(j, TY, "draft_calls")?,
            rounds: req_u64(j, TY, "rounds")?,
            accept_lens: req_u64s(j, TY, "accept_lens")?
                .into_iter()
                .map(|x| x as usize)
                .collect(),
            accept_pos,
            timers,
            attn_hist,
            teacher_cache: CacheStats::from_json(req(j, TY, "teacher_cache")?)?,
            draft_cache: CacheStats::from_json(req(j, TY, "draft_cache")?)?,
            prompt_len: req_usize(j, TY, "prompt_len")?,
        })
    }
}

impl Wire for Submit {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("id", self.id)
            .push("prompt", Json::Arr(self.prompt.iter().map(|t| Json::Num(*t as f64)).collect()))
            .push("max_new", self.max_new)
            .push("arrival_ms", self.arrival_ms)
            .push("kind", self.kind.as_str())
            .push("park_on_complete", self.park_on_complete)
            .push("slo", self.slo.as_ref().map_or(Json::Null, |s| s.to_json()))
            .push("last", self.last)
            .push("isolated", self.isolated);
        o
    }

    fn from_json(j: &Json) -> Result<Self, DeserializationError> {
        const TY: &str = "Submit";
        let slo = match req(j, TY, "slo")? {
            Json::Null => None,
            s => Some(SloPolicy::from_json(s)?),
        };
        Ok(Self {
            id: req_u64(j, TY, "id")?,
            prompt: req_i32s(j, TY, "prompt")?,
            max_new: req_usize(j, TY, "max_new")?,
            arrival_ms: req_f64(j, TY, "arrival_ms")?,
            kind: RequestKind::parse(&req_str(j, TY, "kind")?)?,
            park_on_complete: req_bool(j, TY, "park_on_complete")?,
            slo,
            last: req_bool(j, TY, "last")?,
            isolated: req_bool(j, TY, "isolated")?,
        })
    }
}

impl Wire for Resume {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("id", self.id)
            .push("prompt", Json::Arr(self.prompt.iter().map(|t| Json::Num(*t as f64)).collect()))
            .push("max_new", self.max_new)
            .push("park_on_complete", self.park_on_complete);
        o
    }

    fn from_json(j: &Json) -> Result<Self, DeserializationError> {
        const TY: &str = "Resume";
        Ok(Self {
            id: req_u64(j, TY, "id")?,
            prompt: req_i32s(j, TY, "prompt")?,
            max_new: req_usize(j, TY, "max_new")?,
            park_on_complete: req_bool(j, TY, "park_on_complete")?,
        })
    }
}

impl Wire for Abort {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("id", self.id.map_or(Json::Null, |id| Json::Num(id as f64)));
        o
    }

    fn from_json(j: &Json) -> Result<Self, DeserializationError> {
        let id = match req(j, "Abort", "id")? {
            Json::Null => None,
            v => Some(v.as_f64().ok_or_else(|| DeserializationError::field("Abort", "id"))? as u64),
        };
        Ok(Self { id })
    }
}

impl Wire for TokenDelta {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("id", self.id)
            .push("turn", self.turn)
            .push("tokens", Json::Arr(self.tokens.iter().map(|t| Json::Num(*t as f64)).collect()));
        o
    }

    fn from_json(j: &Json) -> Result<Self, DeserializationError> {
        const TY: &str = "TokenDelta";
        Ok(Self {
            id: req_u64(j, TY, "id")?,
            turn: req_usize(j, TY, "turn")?,
            tokens: req_i32s(j, TY, "tokens")?,
        })
    }
}

impl Wire for TurnDone {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("id", self.id)
            .push("rank", self.rank)
            .push("turn", self.turn)
            .push("out", self.out.to_json())
            .push("submitted_tick", self.submitted_tick)
            .push("admitted_tick", self.admitted_tick)
            .push("finished_tick", self.finished_tick)
            .push("waited_ticks", self.waited_ticks)
            .push("finished_ms", self.finished_ms);
        o
    }

    fn from_json(j: &Json) -> Result<Self, DeserializationError> {
        const TY: &str = "TurnDone";
        Ok(Self {
            id: req_u64(j, TY, "id")?,
            rank: req_usize(j, TY, "rank")?,
            turn: req_usize(j, TY, "turn")?,
            out: GenOut::from_json(req(j, TY, "out")?)?,
            submitted_tick: req_u64(j, TY, "submitted_tick")?,
            admitted_tick: req_u64(j, TY, "admitted_tick")?,
            finished_tick: req_u64(j, TY, "finished_tick")?,
            waited_ticks: req_u64(j, TY, "waited_ticks")?,
            finished_ms: req_f64(j, TY, "finished_ms")?,
        })
    }
}

impl Wire for Park {
    fn to_json(&self) -> Json {
        self.done.to_json()
    }

    fn from_json(j: &Json) -> Result<Self, DeserializationError> {
        TurnDone::from_json(j).map(|done| Park { done })
    }
}

impl Wire for Completion {
    fn to_json(&self) -> Json {
        self.done.to_json()
    }

    fn from_json(j: &Json) -> Result<Self, DeserializationError> {
        TurnDone::from_json(j).map(|done| Completion { done })
    }
}

impl Wire for ShedNotice {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("rank", self.rank).push("notice", self.notice.to_json());
        o
    }

    fn from_json(j: &Json) -> Result<Self, DeserializationError> {
        const TY: &str = "ShedNotice";
        Ok(Self {
            rank: req_usize(j, TY, "rank")?,
            notice: SchedShedNotice::from_json(req(j, TY, "notice")?)?,
        })
    }
}

impl Wire for WorkerStats {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("rank", self.rank)
            .push("stats", self.stats.to_json())
            .push("shed", Json::Arr(self.shed.iter().map(Wire::to_json).collect()))
            .push("is_final", self.is_final)
            .push("error", self.error.as_deref().map_or(Json::Null, Json::from));
        o
    }

    fn from_json(j: &Json) -> Result<Self, DeserializationError> {
        const TY: &str = "WorkerStats";
        let shed = req(j, TY, "shed")?
            .as_arr()
            .ok_or_else(|| DeserializationError::field(TY, "shed"))?
            .iter()
            .map(SchedShedNotice::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let error = match req(j, TY, "error")? {
            Json::Null => None,
            v => Some(
                v.as_str().ok_or_else(|| DeserializationError::field(TY, "error"))?.to_string(),
            ),
        };
        Ok(Self {
            rank: req_usize(j, TY, "rank")?,
            stats: SchedulerStats::from_json(req(j, TY, "stats")?)?,
            shed,
            is_final: req_bool(j, TY, "is_final")?,
            error,
        })
    }
}

impl Wire for Envelope {
    fn to_json(&self) -> Json {
        let body = match self {
            Envelope::Submit(x) => x.to_json(),
            Envelope::Resume(x) => x.to_json(),
            Envelope::Abort(x) => x.to_json(),
            Envelope::TokenDelta(x) => x.to_json(),
            Envelope::Park(x) => x.to_json(),
            Envelope::Completion(x) => x.to_json(),
            Envelope::ShedNotice(x) => x.to_json(),
            Envelope::WorkerStats(x) => x.to_json(),
        };
        let mut o = Json::obj();
        o.push("type", self.kind_str()).push("body", body);
        o
    }

    fn from_json(j: &Json) -> Result<Self, DeserializationError> {
        const TY: &str = "Envelope";
        let tag = req_str(j, TY, "type")?;
        let body = req(j, TY, "body")?;
        match tag.as_str() {
            "submit" => Submit::from_json(body).map(Envelope::Submit),
            "resume" => Resume::from_json(body).map(Envelope::Resume),
            "abort" => Abort::from_json(body).map(Envelope::Abort),
            "token_delta" => TokenDelta::from_json(body).map(Envelope::TokenDelta),
            "park" => Park::from_json(body).map(Envelope::Park),
            "completion" => Completion::from_json(body).map(Envelope::Completion),
            "shed_notice" => ShedNotice::from_json(body).map(Envelope::ShedNotice),
            "worker_stats" => WorkerStats::from_json(body).map(Envelope::WorkerStats),
            other => Err(DeserializationError(format!("unknown envelope type '{other}'"))),
        }
    }
}
