//! The pluggable serialization boundary of the coordinator/worker RPC.
//!
//! Two traits split the concern the way remoc's codec layer does:
//!
//! * [`Wire`] — *what* a message looks like structurally: every envelope
//!   payload maps itself to and from the in-repo [`Json`] value model
//!   (the image provides no serde; `json.rs` is the substrate).
//! * [`Codec`] — *how* that structure becomes bytes on a transport:
//!   static `serialize`/`deserialize` over `io::Write`/`io::Read`, so a
//!   codec is chosen per channel as a type parameter and messages could
//!   later cross a real transport (socket, pipe) unchanged.
//!
//! [`JsonCodec`] is the default (compact JSON, one document per
//! message). [`FramedJsonCodec`] prepends an ASCII length header —
//! functionally redundant over `mpsc` (each `Vec<u8>` is already one
//! message) but it proves the codec is genuinely pluggable and gives
//! the truncated-input error paths a real implementation to bite on.

use crate::json::{self, Json};
use std::io;

/// Failure to serialize an item into a writer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SerializationError(pub String);

impl std::fmt::Display for SerializationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "serialization failed: {}", self.0)
    }
}

impl std::error::Error for SerializationError {}

/// Failure to deserialize an item from a reader: truncated input, bytes
/// that are not valid JSON, or JSON that is not a valid envelope.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeserializationError(pub String);

impl std::fmt::Display for DeserializationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "deserialization failed: {}", self.0)
    }
}

impl std::error::Error for DeserializationError {}

impl DeserializationError {
    /// A required field was missing or mistyped in an otherwise valid
    /// JSON document.
    pub fn field(ty: &str, field: &str) -> Self {
        Self(format!("{ty}: missing or mistyped field '{field}'"))
    }
}

/// Structural serialization contract of every RPC message: a lossless
/// round trip through the [`Json`] value model. `to_json` is total
/// (every in-memory value has a JSON form); `from_json` is partial and
/// must name what is missing.
pub trait Wire: Sized {
    /// The JSON form of this value.
    fn to_json(&self) -> Json;

    /// Rebuild a value from its JSON form.
    fn from_json(j: &Json) -> Result<Self, DeserializationError>;
}

/// A byte-level message codec (remoc-shaped): static methods so the
/// codec is a zero-sized type parameter of the channel, not a runtime
/// object. One call = one message; the reader side must tolerate (and
/// report) truncated input.
pub trait Codec: Send + Sync + 'static {
    /// Serialize `item` into `writer`.
    fn serialize<W, T>(writer: W, item: &T) -> Result<(), SerializationError>
    where
        W: io::Write,
        T: Wire;

    /// Deserialize one item from `reader`.
    fn deserialize<R, T>(reader: R) -> Result<T, DeserializationError>
    where
        R: io::Read,
        T: Wire;
}

/// The default codec: one compact JSON document per message, no
/// framing (the in-process channel frames by `Vec<u8>` boundaries).
#[derive(Debug, Clone, Copy, Default)]
pub struct JsonCodec;

impl Codec for JsonCodec {
    fn serialize<W, T>(mut writer: W, item: &T) -> Result<(), SerializationError>
    where
        W: io::Write,
        T: Wire,
    {
        let text = item.to_json().to_string();
        writer.write_all(text.as_bytes()).map_err(|e| SerializationError(e.to_string()))
    }

    fn deserialize<R, T>(mut reader: R) -> Result<T, DeserializationError>
    where
        R: io::Read,
        T: Wire,
    {
        let mut text = String::new();
        reader.read_to_string(&mut text).map_err(|e| DeserializationError(e.to_string()))?;
        let j = json::parse(&text).map_err(DeserializationError)?;
        T::from_json(&j)
    }
}

/// Bytes of the ASCII length header [`FramedJsonCodec`] prepends:
/// 8 hex digits + `\n`.
const FRAME_HEADER: usize = 9;

/// A second codec — JSON body behind an 8-hex-digit ASCII length header
/// (`"0000002a\n"` then 42 payload bytes). Exists to prove the codec
/// seam is real: channels are generic over [`Codec`], and the framed
/// form detects truncation outright instead of failing on a JSON parse.
#[derive(Debug, Clone, Copy, Default)]
pub struct FramedJsonCodec;

impl Codec for FramedJsonCodec {
    fn serialize<W, T>(mut writer: W, item: &T) -> Result<(), SerializationError>
    where
        W: io::Write,
        T: Wire,
    {
        let text = item.to_json().to_string();
        let header = format!("{:08x}\n", text.len());
        writer
            .write_all(header.as_bytes())
            .and_then(|_| writer.write_all(text.as_bytes()))
            .map_err(|e| SerializationError(e.to_string()))
    }

    fn deserialize<R, T>(mut reader: R) -> Result<T, DeserializationError>
    where
        R: io::Read,
        T: Wire,
    {
        let mut header = [0u8; FRAME_HEADER];
        reader
            .read_exact(&mut header)
            .map_err(|_| DeserializationError("truncated frame header".into()))?;
        let digits = std::str::from_utf8(&header[..FRAME_HEADER - 1])
            .ok()
            .filter(|_| header[FRAME_HEADER - 1] == b'\n')
            .ok_or_else(|| DeserializationError("malformed frame header".into()))?;
        let len = usize::from_str_radix(digits, 16)
            .map_err(|_| DeserializationError("malformed frame length".into()))?;
        let mut body = vec![0u8; len];
        reader
            .read_exact(&mut body)
            .map_err(|_| DeserializationError(format!("truncated frame body (want {len} bytes)")))?;
        let text = std::str::from_utf8(&body)
            .map_err(|e| DeserializationError(format!("frame body not UTF-8: {e}")))?;
        let j = json::parse(text).map_err(DeserializationError)?;
        T::from_json(&j)
    }
}

// ---------------------------------------------------------------------
// Wire helpers shared by the envelope impls: field extraction that
// names the type and field on failure.
// ---------------------------------------------------------------------

/// `j.get(field)` or a named [`DeserializationError`].
pub(crate) fn req<'a>(
    j: &'a Json,
    ty: &str,
    field: &str,
) -> Result<&'a Json, DeserializationError> {
    j.get(field).ok_or_else(|| DeserializationError::field(ty, field))
}

/// Required f64 field.
pub(crate) fn req_f64(j: &Json, ty: &str, field: &str) -> Result<f64, DeserializationError> {
    req(j, ty, field)?.as_f64().ok_or_else(|| DeserializationError::field(ty, field))
}

/// Required u64 field.
pub(crate) fn req_u64(j: &Json, ty: &str, field: &str) -> Result<u64, DeserializationError> {
    req_f64(j, ty, field).map(|x| x as u64)
}

/// Required usize field.
pub(crate) fn req_usize(j: &Json, ty: &str, field: &str) -> Result<usize, DeserializationError> {
    req_f64(j, ty, field).map(|x| x as usize)
}

/// Required bool field.
pub(crate) fn req_bool(j: &Json, ty: &str, field: &str) -> Result<bool, DeserializationError> {
    req(j, ty, field)?.as_bool().ok_or_else(|| DeserializationError::field(ty, field))
}

/// Required string field.
pub(crate) fn req_str(j: &Json, ty: &str, field: &str) -> Result<String, DeserializationError> {
    Ok(req(j, ty, field)?
        .as_str()
        .ok_or_else(|| DeserializationError::field(ty, field))?
        .to_string())
}

/// Required array-of-numbers field, as i32.
pub(crate) fn req_i32s(j: &Json, ty: &str, field: &str) -> Result<Vec<i32>, DeserializationError> {
    let arr =
        req(j, ty, field)?.as_arr().ok_or_else(|| DeserializationError::field(ty, field))?;
    arr.iter()
        .map(|x| x.as_f64().map(|v| v as i32).ok_or_else(|| DeserializationError::field(ty, field)))
        .collect()
}

/// Required array-of-numbers field, as u64.
pub(crate) fn req_u64s(j: &Json, ty: &str, field: &str) -> Result<Vec<u64>, DeserializationError> {
    let arr =
        req(j, ty, field)?.as_arr().ok_or_else(|| DeserializationError::field(ty, field))?;
    arr.iter()
        .map(|x| x.as_f64().map(|v| v as u64).ok_or_else(|| DeserializationError::field(ty, field)))
        .collect()
}

/// Required array-of-numbers field, as f64.
pub(crate) fn req_f64s(j: &Json, ty: &str, field: &str) -> Result<Vec<f64>, DeserializationError> {
    let arr =
        req(j, ty, field)?.as_arr().ok_or_else(|| DeserializationError::field(ty, field))?;
    arr.iter()
        .map(|x| x.as_f64().ok_or_else(|| DeserializationError::field(ty, field)))
        .collect()
}
