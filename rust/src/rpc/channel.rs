//! Typed, codec-parameterized channels over `std::sync::mpsc`.
//!
//! A [`WireSender`]/[`WireReceiver`] pair moves exactly one message type
//! `T: Wire`, serialized through a codec `C: Codec` into a `Vec<u8>` per
//! message — every value crossing threads passes through real bytes, so
//! swapping the `mpsc` transport for a socket later changes only this
//! file. Channels are **bounded** ([`wire_channel`] takes a depth):
//! `send` blocks when the peer lags, which is the backpressure story —
//! a slow coordinator throttles its workers instead of buffering
//! unboundedly.
//!
//! The codec is a zero-sized type parameter (remoc-style), so the
//! channel's wire format is part of its type: a
//! `WireSender<Envelope, JsonCodec>` cannot be connected to a
//! `FramedJsonCodec` receiver by accident.

use crate::rpc::codec::{Codec, Wire};
use std::marker::PhantomData;
use std::sync::mpsc;

/// Why a channel operation failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ChannelError {
    /// The peer end was dropped; no further messages can flow.
    Disconnected,
    /// The codec rejected a message (serialize or deserialize).
    Codec(String),
}

impl std::fmt::Display for ChannelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ChannelError::Disconnected => write!(f, "channel disconnected"),
            ChannelError::Codec(msg) => write!(f, "channel codec error: {msg}"),
        }
    }
}

impl std::error::Error for ChannelError {}

/// The sending half of a typed channel: serializes each `T` through `C`
/// and hands the bytes to a bounded `mpsc` queue (blocking when full).
pub struct WireSender<T: Wire, C: Codec> {
    tx: mpsc::SyncSender<Vec<u8>>,
    _marker: PhantomData<fn(T, C)>,
}

// `fn(T, C)` (not `(T, C)`) in the marker: the sender owns no T or C,
// so it is Send + Sync regardless of what T holds.
impl<T: Wire, C: Codec> Clone for WireSender<T, C> {
    fn clone(&self) -> Self {
        Self { tx: self.tx.clone(), _marker: PhantomData }
    }
}

impl<T: Wire, C: Codec> WireSender<T, C> {
    /// Serialize `item` and enqueue it, blocking while the channel is at
    /// capacity (backpressure).
    pub fn send(&self, item: &T) -> Result<(), ChannelError> {
        let mut bytes = Vec::new();
        C::serialize(&mut bytes, item).map_err(|e| ChannelError::Codec(e.to_string()))?;
        self.tx.send(bytes).map_err(|_| ChannelError::Disconnected)
    }

    /// Serialize `item` and enqueue it only if the channel has capacity:
    /// `Ok(true)` when enqueued, `Ok(false)` when the queue is full. The
    /// coordinator uses this while it must keep draining events — a
    /// blocking `send` from both sides of a bounded pair can deadlock.
    pub fn try_send(&self, item: &T) -> Result<bool, ChannelError> {
        let mut bytes = Vec::new();
        C::serialize(&mut bytes, item).map_err(|e| ChannelError::Codec(e.to_string()))?;
        match self.tx.try_send(bytes) {
            Ok(()) => Ok(true),
            Err(mpsc::TrySendError::Full(_)) => Ok(false),
            Err(mpsc::TrySendError::Disconnected(_)) => Err(ChannelError::Disconnected),
        }
    }
}

/// The receiving half of a typed channel: decodes each `Vec<u8>` back
/// into a `T` through `C`.
pub struct WireReceiver<T: Wire, C: Codec> {
    rx: mpsc::Receiver<Vec<u8>>,
    _marker: PhantomData<fn(T, C)>,
}

impl<T: Wire, C: Codec> WireReceiver<T, C> {
    /// Block until a message arrives (or the sender side is gone).
    pub fn recv(&self) -> Result<T, ChannelError> {
        let bytes = self.rx.recv().map_err(|_| ChannelError::Disconnected)?;
        C::deserialize(bytes.as_slice()).map_err(|e| ChannelError::Codec(e.to_string()))
    }

    /// Take a message if one is queued; `Ok(None)` when the channel is
    /// empty but senders remain.
    pub fn try_recv(&self) -> Result<Option<T>, ChannelError> {
        match self.rx.try_recv() {
            Ok(bytes) => C::deserialize(bytes.as_slice())
                .map(Some)
                .map_err(|e| ChannelError::Codec(e.to_string())),
            Err(mpsc::TryRecvError::Empty) => Ok(None),
            Err(mpsc::TryRecvError::Disconnected) => Err(ChannelError::Disconnected),
        }
    }
}

/// Create a connected typed channel of the given depth (messages the
/// queue holds before `send` blocks).
pub fn wire_channel<T: Wire, C: Codec>(depth: usize) -> (WireSender<T, C>, WireReceiver<T, C>) {
    let (tx, rx) = mpsc::sync_channel(depth);
    (WireSender { tx, _marker: PhantomData }, WireReceiver { rx, _marker: PhantomData })
}
