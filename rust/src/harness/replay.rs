//! Deterministic trace-replay load driver: replays a seeded arrival
//! trace ([`crate::workload::TraceSpec`]) through the multi-worker
//! serving stack — a [`Coordinator`] front end routing each request to
//! its consistent-hash home worker over typed channel RPC — under a
//! modeled device clock, and reports the per-request latency
//! distribution (p50/p95/p99) plus the shed rate.
//!
//! `--workers 1` is not a special case in the code, but it reproduces
//! the pre-split single-scheduler replay bit for bit: one worker
//! receives the whole trace and replays it on the identical virtual
//! clock protocol (property-tested in `tests/multiworker.rs`).
//!
//! # The virtual clock
//!
//! Latency here is *virtual* milliseconds, charged per worker: each
//! scheduler tick advances that worker's clock by a fixed host cost
//! plus a per-fused-launch device cost ([`ReplayConfig::tick_host_ms`]
//! / [`ReplayConfig::launch_ms`] — the sim backend's device-clock
//! model, scaled into milliseconds), and when a worker's scheduler
//! drains before its next arrival the clock jumps straight to that
//! arrival. No wall-clock reading ever enters a latency or a shed
//! decision, so the same trace replayed twice produces bit-identical
//! percentiles — which is what lets `bench_gate` hold a p99 SLO floor
//! without flaking (the paper's headline metric is a p99 speedup).
//!
//! # First token
//!
//! `first_token_tick` equals `admitted_tick`: admission prefills the
//! prompt and the conversation joins that very tick's fused round, and
//! every speculative round commits at least one token (the teacher's
//! next-token fallback), so the first output token lands on the
//! admission tick by construction.

use crate::coordinator::{
    BackendSpec, Coordinator, FrontConfig, SchedulerStats, SloPolicy,
};
use crate::config::RunConfig;
use crate::util::stats::percentile_sorted;
use crate::workload::TraceRequest;
use anyhow::{bail, Result};

/// Replay-driver configuration.
#[derive(Clone, Debug)]
pub struct ReplayConfig {
    /// Engine slots per worker (the serving batch width B).
    pub slots: usize,
    /// Engine workers the coordinator shards the trace across (`1` =
    /// the single-engine path, bit-identical to pre-split replay).
    pub workers: usize,
    /// Turns per conversation: above `1`, every conversation parks
    /// after each non-final turn and is resumed with a deterministic
    /// follow-up prompt ([`crate::coordinator::followup_prompt`]).
    pub turns: usize,
    /// Sim-backend draft/teacher agreement percentage.
    pub agree_pct: u64,
    /// SLO attached to every replayed request (`None` = no deadlines).
    pub slo: Option<SloPolicy>,
    /// Virtual milliseconds charged per scheduler tick (host half:
    /// retire/admit churn + draft expansion + staging).
    pub tick_host_ms: f64,
    /// Virtual milliseconds charged per fused launch issued (device
    /// half; wider traces pay for every split sub-launch).
    pub launch_ms: f64,
    /// Engine configuration for every slot.
    pub run: RunConfig,
}

impl ReplayConfig {
    /// A single-worker replay at batch width `slots` with the default
    /// cost model.
    pub fn new(slots: usize) -> Self {
        Self {
            slots,
            workers: 1,
            turns: 1,
            agree_pct: 90,
            slo: None,
            tick_host_ms: 1.0,
            launch_ms: 2.0,
            run: RunConfig::default(),
        }
    }

    /// Reject degenerate replay configs (config-contract errors naming
    /// the offending flag).
    pub fn validate(&self) -> Result<()> {
        if self.slots == 0 {
            bail!("config contract: --slots must be >= 1 (got 0) — one slot is sequential replay");
        }
        if self.workers == 0 {
            bail!(
                "config contract: --workers must be >= 1 (got 0) — \
                 one worker is the single-engine serving path"
            );
        }
        if self.turns == 0 {
            bail!(
                "config contract: --turns must be >= 1 (got 0) — \
                 a conversation has at least one turn"
            );
        }
        if let Some(slo) = &self.slo {
            slo.validate()?;
        }
        self.run.validate()?;
        Ok(())
    }
}

/// Per-request replay outcome (the latency-record fields of
/// `docs/TRACE_FORMAT.md`).
#[derive(Clone, Debug, PartialEq)]
pub struct RequestRecord {
    /// Trace request id.
    pub id: u64,
    /// Tick the request was submitted on (its home worker's clock).
    pub submitted_tick: u64,
    /// Tick the request was admitted on (`None` if shed pre-admission).
    pub admitted_tick: Option<u64>,
    /// Tick the first output token landed (== admitted tick; see the
    /// module docs). `None` if shed.
    pub first_token_tick: Option<u64>,
    /// Tick the request finished on — last turn's (`None` if shed).
    pub finished_tick: Option<u64>,
    /// End-to-end virtual latency, arrival → completion of the final
    /// turn (`None` if shed).
    pub latency_ms: Option<f64>,
    /// Whether the request was shed by its SLO policy (typed outcome —
    /// shed requests are counted, never silently dropped).
    pub shed: bool,
    /// Every token the conversation generated, turns concatenated —
    /// the reassembled [`crate::rpc::TokenDelta`] stream, verified
    /// against the per-turn completion records by the coordinator.
    pub tokens: Vec<i32>,
}

/// Aggregate replay result.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    /// Requests replayed (completed + shed).
    pub total: usize,
    /// Requests that completed decoding.
    pub completed: usize,
    /// Requests shed by their SLO policy.
    pub shed: usize,
    /// Shed fraction: `shed / total`.
    pub shed_rate: f64,
    /// Mean completion latency (virtual ms).
    pub mean_ms: f64,
    /// Median completion latency (virtual ms).
    pub p50_ms: f64,
    /// 95th-percentile completion latency (virtual ms).
    pub p95_ms: f64,
    /// 99th-percentile completion latency (virtual ms).
    pub p99_ms: f64,
    /// Per-request timeline records, in trace order.
    pub records: Vec<RequestRecord>,
    /// Per-worker scheduler counters at the end of the replay.
    pub stats: Vec<SchedulerStats>,
}

/// Replay `trace` through a coordinator with `cfg.workers` engine
/// workers (sim backend) under the virtual-clock model. Deterministic:
/// same trace + same config = bit-identical report, and each
/// conversation's token stream is independent of the worker count
/// (property-tested in `tests/trace_replay.rs` and
/// `tests/multiworker.rs`).
pub fn replay(trace: &[TraceRequest], cfg: &ReplayConfig) -> Result<ReplayReport> {
    cfg.validate()?;
    if trace.is_empty() {
        bail!("config contract: --requests must be >= 1 (an empty trace replays nothing)");
    }
    let front = FrontConfig {
        workers: cfg.workers,
        slots: cfg.slots,
        backend: BackendSpec::Sim { agree_pct: cfg.agree_pct },
        run: cfg.run.clone(),
        tick_host_ms: cfg.tick_host_ms,
        launch_ms: cfg.launch_ms,
        cmd_depth: 64,
        event_depth: 256,
    };
    let mut coord: Coordinator = Coordinator::start(&front)?;
    let run_result = coord.run_trace(trace, cfg.slo, cfg.turns);
    let shutdown_result = coord.shutdown();
    let outcome = run_result?;
    let shutdown = shutdown_result?;
    for (rank, err) in shutdown.errors.iter().enumerate() {
        if let Some(msg) = err {
            bail!("engine worker {rank} failed: {msg}");
        }
    }
    debug_assert!(
        shutdown.undrained_shed.is_empty(),
        "a fully drained replay leaves no undrained sheds behind"
    );

    let n = trace.len();
    let mut records: Vec<RequestRecord> = Vec::with_capacity(n);
    for (r, oc) in trace.iter().zip(outcome.outcomes) {
        debug_assert_eq!(r.id, oc.id, "outcomes arrive in trace order");
        if let Some(notice) = oc.shed {
            records.push(RequestRecord {
                id: r.id,
                submitted_tick: notice.submitted_tick,
                admitted_tick: None,
                first_token_tick: None,
                finished_tick: None,
                latency_ms: None,
                shed: true,
                tokens: Vec::new(),
            });
        } else {
            let first = oc.turns.first().expect("a served conversation has turns");
            let last = oc.turns.last().expect("a served conversation has turns");
            records.push(RequestRecord {
                id: r.id,
                submitted_tick: first.submitted_tick,
                admitted_tick: Some(first.admitted_tick),
                first_token_tick: Some(first.admitted_tick),
                finished_tick: Some(last.finished_tick),
                latency_ms: Some(last.finished_ms - r.arrival_ms),
                shed: false,
                tokens: oc.tokens,
            });
        }
    }
    let mut lats: Vec<f64> = records.iter().filter_map(|r| r.latency_ms).collect();
    lats.sort_by(|a, b| a.partial_cmp(b).expect("virtual latencies are finite"));
    let completed = lats.len();
    let shed = records.iter().filter(|r| r.shed).count();
    debug_assert_eq!(completed + shed, n, "every request completes or sheds, never vanishes");
    let mean_ms =
        if completed == 0 { 0.0 } else { lats.iter().sum::<f64>() / completed as f64 };
    Ok(ReplayReport {
        total: n,
        completed,
        shed,
        shed_rate: shed as f64 / n as f64,
        mean_ms,
        p50_ms: percentile_sorted(&lats, 0.50),
        p95_ms: percentile_sorted(&lats, 0.95),
        p99_ms: percentile_sorted(&lats, 0.99),
        records,
        stats: outcome.stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TraceSpec;

    #[test]
    fn replay_completes_every_request_without_slo() {
        let trace = TraceSpec::smoke_poisson(5).generate().unwrap();
        let rep = replay(&trace, &ReplayConfig::new(4)).unwrap();
        assert_eq!(rep.total, trace.len());
        assert_eq!(rep.completed, trace.len());
        assert_eq!(rep.shed, 0);
        assert_eq!(rep.shed_rate, 0.0);
        assert!(rep.p50_ms > 0.0 && rep.p99_ms >= rep.p95_ms && rep.p95_ms >= rep.p50_ms);
        assert_eq!(rep.stats.len(), 1);
        assert_eq!(rep.stats[0].retired as usize, trace.len());
        for r in &rep.records {
            assert!(!r.shed);
            assert_eq!(r.first_token_tick, r.admitted_tick);
            assert!(r.finished_tick.unwrap() >= r.admitted_tick.unwrap());
            assert!(!r.tokens.is_empty(), "a completed request streamed tokens");
        }
    }

    #[test]
    fn degenerate_replay_configs_are_rejected() {
        let trace = TraceSpec::smoke_poisson(5).generate().unwrap();
        let mut cfg = ReplayConfig::new(0);
        let err = replay(&trace, &cfg).unwrap_err().to_string();
        assert!(err.contains("--slots"), "error must name the flag: {err}");
        cfg.slots = 2;
        let err = replay(&[], &cfg).unwrap_err().to_string();
        assert!(err.contains("--requests"), "error must name the flag: {err}");
        cfg.workers = 0;
        let err = replay(&trace, &cfg).unwrap_err().to_string();
        assert!(err.contains("--workers"), "error must name the flag: {err}");
        cfg.workers = 2;
        cfg.turns = 0;
        let err = replay(&trace, &cfg).unwrap_err().to_string();
        assert!(err.contains("--turns"), "error must name the flag: {err}");
    }
}
