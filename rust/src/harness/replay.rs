//! Deterministic trace-replay load driver: replays a seeded arrival
//! trace ([`crate::workload::TraceSpec`]) through the
//! [`ContinuousScheduler`] under a modeled device clock, and reports the
//! per-request latency distribution (p50/p95/p99) plus the shed rate.
//!
//! # The virtual clock
//!
//! Latency here is *virtual* milliseconds: each scheduler tick advances
//! the clock by a fixed host cost plus a per-fused-launch device cost
//! ([`ReplayConfig::tick_host_ms`] / [`ReplayConfig::launch_ms`] — the
//! sim backend's device-clock model, scaled into milliseconds), and when
//! the scheduler drains before the next arrival the clock jumps straight
//! to that arrival. No wall-clock reading ever enters a latency or a
//! shed decision, so the same trace replayed twice produces bit-identical
//! percentiles — which is what lets `bench_gate` hold a p99 SLO floor
//! without flaking (the paper's headline metric is a p99 speedup).
//!
//! # First token
//!
//! `first_token_tick` equals `admitted_tick`: admission prefills the
//! prompt and the conversation joins that very tick's fused round, and
//! every speculative round commits at least one token (the teacher's
//! next-token fallback), so the first output token lands on the
//! admission tick by construction.

use crate::backend::sim::SimBackend;
use crate::backend::ModelBackend;
use crate::config::RunConfig;
use crate::coordinator::{Completion, ContinuousScheduler, Disposition, SloPolicy, SlotRequest};
use crate::engine::Engine;
use crate::util::stats::percentile_sorted;
use crate::workload::TraceRequest;
use anyhow::{bail, Result};

/// Replay-driver configuration.
#[derive(Clone, Debug)]
pub struct ReplayConfig {
    /// Engine slots (the serving batch width B).
    pub slots: usize,
    /// Sim-backend draft/teacher agreement percentage.
    pub agree_pct: u64,
    /// SLO attached to every replayed request (`None` = no deadlines).
    pub slo: Option<SloPolicy>,
    /// Virtual milliseconds charged per scheduler tick (host half:
    /// retire/admit churn + draft expansion + staging).
    pub tick_host_ms: f64,
    /// Virtual milliseconds charged per fused launch issued (device
    /// half; wider traces pay for every split sub-launch).
    pub launch_ms: f64,
    /// Engine configuration for every slot.
    pub run: RunConfig,
}

impl ReplayConfig {
    /// A replay at batch width `slots` with the default cost model.
    pub fn new(slots: usize) -> Self {
        Self {
            slots,
            agree_pct: 90,
            slo: None,
            tick_host_ms: 1.0,
            launch_ms: 2.0,
            run: RunConfig::default(),
        }
    }

    /// Reject degenerate replay configs (config-contract errors naming
    /// the offending flag).
    pub fn validate(&self) -> Result<()> {
        if self.slots == 0 {
            bail!("config contract: --slots must be >= 1 (got 0) — one slot is sequential replay");
        }
        if let Some(slo) = &self.slo {
            slo.validate()?;
        }
        self.run.validate()?;
        Ok(())
    }
}

/// Per-request replay outcome (the latency-record fields of
/// `docs/TRACE_FORMAT.md`).
#[derive(Clone, Debug, PartialEq)]
pub struct RequestRecord {
    /// Trace request id.
    pub id: u64,
    /// Tick the request was submitted on.
    pub submitted_tick: u64,
    /// Tick the request was admitted on (`None` if shed pre-admission).
    pub admitted_tick: Option<u64>,
    /// Tick the first output token landed (== admitted tick; see the
    /// module docs). `None` if shed.
    pub first_token_tick: Option<u64>,
    /// Tick the request finished on (`None` if shed).
    pub finished_tick: Option<u64>,
    /// End-to-end virtual latency, arrival → completion (`None` if shed).
    pub latency_ms: Option<f64>,
    /// Whether the request was shed by its SLO policy (typed outcome —
    /// shed requests are counted, never silently dropped).
    pub shed: bool,
}

/// Aggregate replay result.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    /// Requests replayed (completed + shed).
    pub total: usize,
    /// Requests that completed decoding.
    pub completed: usize,
    /// Requests shed by their SLO policy.
    pub shed: usize,
    /// Shed fraction: `shed / total`.
    pub shed_rate: f64,
    /// Mean completion latency (virtual ms).
    pub mean_ms: f64,
    /// Median completion latency (virtual ms).
    pub p50_ms: f64,
    /// 95th-percentile completion latency (virtual ms).
    pub p95_ms: f64,
    /// 99th-percentile completion latency (virtual ms).
    pub p99_ms: f64,
    /// Per-request timeline records, in trace order.
    pub records: Vec<RequestRecord>,
}

/// Replay `trace` through a fresh scheduler + sim backend under the
/// virtual-clock model. Deterministic: same trace + same config =
/// bit-identical report (property-tested in `tests/trace_replay.rs`).
pub fn replay(trace: &[TraceRequest], cfg: &ReplayConfig) -> Result<ReplayReport> {
    cfg.validate()?;
    if trace.is_empty() {
        bail!("config contract: --requests must be >= 1 (an empty trace replays nothing)");
    }
    let mut bk = SimBackend::new(cfg.agree_pct);
    let mut engines: Vec<Engine> =
        (0..cfg.slots).map(|_| Engine::new(&bk, cfg.run.clone())).collect();
    let cap = bk.contract().cache_cap;
    let mut sched = ContinuousScheduler::new(cfg.slots, cap);
    sched.set_pipelining(cfg.run.pipelining);

    let n = trace.len();
    let mut records: Vec<RequestRecord> = trace
        .iter()
        .map(|r| RequestRecord {
            id: r.id,
            submitted_tick: 0,
            admitted_tick: None,
            first_token_tick: None,
            finished_tick: None,
            latency_ms: None,
            shed: false,
        })
        .collect();
    let mut next = 0usize;
    let mut done = 0usize;
    let mut finished_this_tick: Vec<(usize, u64, u64, u64)> = Vec::new();
    let mut safety = 0u32;
    while done < n {
        // submit every arrival due at the current virtual time
        while next < n && trace[next].arrival_ms <= sched.now_ms() {
            let r = &trace[next];
            records[next].submitted_tick = sched.current_tick();
            sched.submit(SlotRequest {
                id: r.id,
                prompt: r.prompt.clone(),
                max_new: r.max_new,
                cfg: None,
                slo: cfg.slo,
            });
            next += 1;
        }
        // drained before the next arrival: jump the clock to it instead
        // of burning empty ticks
        if sched.is_idle() && next < n {
            let gap = trace[next].arrival_ms - sched.now_ms();
            sched.advance_clock(gap.max(0.0) + 1e-9);
            continue;
        }
        let launches_before = sched.stats.fused_launches;
        finished_this_tick.clear();
        sched.tick(&mut bk, &mut engines, &mut |c: Completion| {
            finished_this_tick.push((
                c.id as usize,
                c.submitted_tick,
                c.admitted_tick,
                c.finished_tick,
            ));
            Disposition::Release
        })?;
        // charge the tick: host half + every fused launch it issued
        let launches = sched.stats.fused_launches - launches_before;
        sched.advance_clock(cfg.tick_host_ms + launches as f64 * cfg.launch_ms);
        // stamp completions at the post-tick clock (the tick's work is
        // what produced them)
        for &(idx, submitted_tick, admitted_tick, finished_tick) in &finished_this_tick {
            let rec = &mut records[idx];
            rec.submitted_tick = submitted_tick;
            rec.admitted_tick = Some(admitted_tick);
            rec.first_token_tick = Some(admitted_tick);
            rec.finished_tick = Some(finished_tick);
            rec.latency_ms = Some(sched.now_ms() - trace[idx].arrival_ms);
            done += 1;
        }
        for s in sched.drain_shed() {
            let rec = &mut records[s.id as usize];
            rec.shed = true;
            done += 1;
        }
        safety += 1;
        if safety >= 1_000_000 {
            bail!("trace replay failed to converge after {safety} ticks");
        }
    }
    let mut lats: Vec<f64> = records.iter().filter_map(|r| r.latency_ms).collect();
    lats.sort_by(|a, b| a.partial_cmp(b).expect("virtual latencies are finite"));
    let completed = lats.len();
    let shed = records.iter().filter(|r| r.shed).count();
    debug_assert_eq!(completed + shed, n, "every request completes or sheds, never vanishes");
    let mean_ms =
        if completed == 0 { 0.0 } else { lats.iter().sum::<f64>() / completed as f64 };
    Ok(ReplayReport {
        total: n,
        completed,
        shed,
        shed_rate: shed as f64 / n as f64,
        mean_ms,
        p50_ms: percentile_sorted(&lats, 0.50),
        p95_ms: percentile_sorted(&lats, 0.95),
        p99_ms: percentile_sorted(&lats, 0.99),
        records,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TraceSpec;

    #[test]
    fn replay_completes_every_request_without_slo() {
        let trace = TraceSpec::smoke_poisson(5).generate().unwrap();
        let rep = replay(&trace, &ReplayConfig::new(4)).unwrap();
        assert_eq!(rep.total, trace.len());
        assert_eq!(rep.completed, trace.len());
        assert_eq!(rep.shed, 0);
        assert_eq!(rep.shed_rate, 0.0);
        assert!(rep.p50_ms > 0.0 && rep.p99_ms >= rep.p95_ms && rep.p95_ms >= rep.p50_ms);
        for r in &rep.records {
            assert!(!r.shed);
            assert_eq!(r.first_token_tick, r.admitted_tick);
            assert!(r.finished_tick.unwrap() >= r.admitted_tick.unwrap());
        }
    }

    #[test]
    fn degenerate_replay_configs_are_rejected() {
        let trace = TraceSpec::smoke_poisson(5).generate().unwrap();
        let mut cfg = ReplayConfig::new(0);
        let err = replay(&trace, &cfg).unwrap_err().to_string();
        assert!(err.contains("--slots"), "error must name the flag: {err}");
        cfg.slots = 2;
        let err = replay(&[], &cfg).unwrap_err().to_string();
        assert!(err.contains("--requests"), "error must name the flag: {err}");
    }
}
