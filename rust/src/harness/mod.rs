//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation section (§5) from live runs — see DESIGN.md §5 for the
//! experiment-to-artifact index.

pub mod experiments;
pub mod replay;

pub use experiments::{run_e1, run_e2, run_e3, run_e4, HarnessConfig};
pub use replay::{replay, ReplayConfig, ReplayReport, RequestRecord};
