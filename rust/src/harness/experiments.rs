//! E1-E4 experiment runners (paper §5).
//!
//! Each runner executes live decoding through the coordinator, prints the
//! paper-shaped table to stdout, and writes machine-readable series
//! (CSV/JSON) into the output directory:
//!
//! * **E1** (Table 1, Fig 1/2a/2b/3): end-to-end throughput, batch 1.
//! * **E2** (Table 2, Fig 4): budget sweep over M and D_max,
//!   HumanEval(code)-only, shorter generations.
//! * **E3** (Fig 5): instrumented stage breakdown (analysis-only).
//! * **E4** (Table 3, Fig 6, Fig 7): drafter context truncation.
//!
//! Lengths are CPU-scaled versions of the paper's settings (DESIGN.md §1):
//! max_new 1024 -> 128, sweep max_new 256 -> 64, windows {128,256,512} ->
//! {32,64,128} against the ~4x-shorter contexts.

use crate::coordinator::{run_workload, AdmissionPolicy, BackendSpec, CoordinatorConfig};
use crate::config::RunConfig;
use crate::engine::output::ATTN_BUCKET_LABELS;
use crate::json::Json;
use crate::metrics::report::{
    accept_pos_csv, lengths_csv, speedup_hist_csv, speedup_vs_lk_csv,
};
use crate::metrics::{pair_turns, ThroughputReport};
use crate::trace::TurnRecord;
use crate::util::stats::Summary;
use crate::workload::WorkloadSpec;
use anyhow::Result;
use std::fmt::Write as _;
use std::path::PathBuf;

/// Shared configuration of the E1-E4 experiment runners.
#[derive(Clone, Debug)]
pub struct HarnessConfig {
    /// Backend every worker builds.
    pub backend: BackendSpec,
    /// Directory receiving tables/CSVs/traces.
    pub out_dir: PathBuf,
    /// Coordinator worker count.
    pub world_size: usize,
    /// Base decode configuration (experiments override axes).
    pub run: RunConfig,
    /// Shrink the workload for smoke runs / CI.
    pub quick: bool,
    /// Print coordinator progress.
    pub verbose: bool,
}

impl HarnessConfig {
    fn workload(&self) -> WorkloadSpec {
        if self.quick {
            WorkloadSpec::smoke()
        } else {
            WorkloadSpec::default()
        }
    }

    /// Code(HumanEval)-only subset for E2 (paper: "humaneval-only sweep").
    fn workload_code_only(&self) -> WorkloadSpec {
        let mut w = self.workload();
        w.chat_conversations = 0;
        if !self.quick {
            w.code_conversations = 24; // sweep cost is (#settings x workload)
        }
        w
    }

    fn coord(&self, run: RunConfig, workload: WorkloadSpec, tag: &str,
             baseline: bool, ea: bool) -> CoordinatorConfig {
        CoordinatorConfig {
            world_size: self.world_size,
            run,
            workload,
            backend: self.backend.clone(),
            trace_dir: self.out_dir.join(tag),
            run_baseline: baseline,
            run_ea: ea,
            max_batch: 1,
            scheduling: AdmissionPolicy::Continuous,
            verbose: self.verbose,
        }
    }
}

fn write(dir: &PathBuf, name: &str, content: &str) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(name), content)?;
    Ok(())
}

// ----------------------------------------------------------------------
// E1 — end-to-end throughput (Table 1, Fig 1, 2a, 2b, 3)
// ----------------------------------------------------------------------

/// E1: end-to-end throughput (Table 1, Fig 1/2a/2b/3).
pub fn run_e1(cfg: &HarnessConfig) -> Result<ThroughputReport> {
    let mut run = cfg.run.clone();
    run.max_new_tokens = if cfg.quick { 24 } else { 128 };
    let coord = cfg.coord(run, cfg.workload(), "e1", true, true);
    let records = run_workload(&coord)?;
    let pairs = pair_turns(&records);
    let report = ThroughputReport::from_pairs(&pairs);
    println!("{}", report.table1());
    write(&cfg.out_dir, "e1_report.json", &report.to_json().to_string_pretty())?;
    write(&cfg.out_dir, "fig1_lengths.csv", &lengths_csv(&records))?;
    write(&cfg.out_dir, "fig2a_speedup_hist.csv", &speedup_hist_csv(&pairs))?;
    write(&cfg.out_dir, "fig2b_speedup_vs_lk.csv", &speedup_vs_lk_csv(&pairs))?;
    write(&cfg.out_dir, "fig3_accept_pos.csv", &accept_pos_csv(&report))?;
    Ok(report)
}

// ----------------------------------------------------------------------
// E2 — budget sensitivity sweep (Table 2, Fig 4)
// ----------------------------------------------------------------------

/// One row of the E2 budget-sweep table.
pub struct SweepRow {
    /// Sweep axis identifier (`scan_M` | `scan_Dmax`).
    pub sweep: &'static str,
    /// Human-readable setting (e.g. `M=32`).
    pub setting: String,
    /// Mean EA throughput at this setting.
    pub ea_tok_s: f64,
    /// Speedup over the shared baseline.
    pub speedup: f64,
}

/// E2: tree-budget sensitivity sweep (Table 2, Fig 4), code-only.
pub fn run_e2(cfg: &HarnessConfig) -> Result<Vec<SweepRow>> {
    let workload = cfg.workload_code_only();
    let max_new = if cfg.quick { 16 } else { 64 };

    // Baseline once (shared across sweep settings).
    let mut base_run = cfg.run.clone();
    base_run.max_new_tokens = max_new;
    let base_records =
        run_workload(&cfg.coord(base_run.clone(), workload.clone(), "e2_base", true, false))?;
    let base_tok: Vec<f64> =
        base_records.iter().filter(|r| r.kind == "baseline").map(|r| r.tok_s).collect();
    let base_mean = Summary::from(&base_tok).mean;

    let m_axis: Vec<usize> =
        if cfg.quick { vec![8, 16] } else { vec![16, 32, 64, 128, 256] };
    let d_axis: Vec<usize> = if cfg.quick { vec![4, 10] } else { vec![4, 8, 10, 12, 16] };

    let mut rows: Vec<SweepRow> = Vec::new();
    for m in &m_axis {
        let mut run = base_run.clone();
        run.tree.budget = *m;
        run.tree.depth_max = 10;
        let recs = run_workload(&cfg.coord(run, workload.clone(),
                                           &format!("e2_m{m}"), false, true))?;
        rows.push(sweep_row("scan_M", format!("M={m}"), &recs, base_mean));
    }
    for d in &d_axis {
        let mut run = base_run.clone();
        run.tree.budget = 64.min(if cfg.quick { 8 } else { 64 });
        run.tree.depth_max = *d;
        let recs = run_workload(&cfg.coord(run, workload.clone(),
                                           &format!("e2_d{d}"), false, true))?;
        rows.push(sweep_row("scan_Dmax", format!("Dmax={d}"), &recs, base_mean));
    }

    // Table 2
    let mut table = String::new();
    writeln!(table, "Table 2: budget sweep (code-only, max_new={max_new}, baseline {base_mean:.2} Tok/s)").ok();
    writeln!(table, "| Sweep     | Setting   | EA Tok/s (mean) | Speedup (mean) |").ok();
    writeln!(table, "|-----------|-----------|-----------------|----------------|").ok();
    for r in &rows {
        writeln!(table, "| {:<9} | {:<9} | {:>15.2} | {:>14.2} |",
                 r.sweep, r.setting, r.ea_tok_s, r.speedup).ok();
    }
    println!("{table}");
    write(&cfg.out_dir, "table2_budget_sweep.txt", &table)?;
    let mut csv = String::from("sweep,setting,ea_tok_s,speedup\n");
    for r in &rows {
        writeln!(csv, "{},{},{:.4},{:.4}", r.sweep, r.setting, r.ea_tok_s, r.speedup).ok();
    }
    write(&cfg.out_dir, "fig4_budget_sweep.csv", &csv)?;
    Ok(rows)
}

fn sweep_row(sweep: &'static str, setting: String, recs: &[TurnRecord], base_mean: f64)
    -> SweepRow {
    let tok: Vec<f64> = recs.iter().filter(|r| r.kind == "ea").map(|r| r.tok_s).collect();
    let mean = Summary::from(&tok).mean;
    SweepRow {
        sweep,
        setting,
        ea_tok_s: mean,
        speedup: if base_mean > 0.0 { mean / base_mean } else { 0.0 },
    }
}

// ----------------------------------------------------------------------
// E3 — stage breakdown (Fig 5; instrumented, analysis-only)
// ----------------------------------------------------------------------

/// E3: instrumented per-stage timing breakdown (Fig 5).
pub fn run_e3(cfg: &HarnessConfig) -> Result<Json> {
    let mut run = cfg.run.clone();
    run.instrument = true;
    run.max_new_tokens = if cfg.quick { 16 } else { 96 };
    let mut workload = cfg.workload();
    if !cfg.quick {
        // instrumentation perturbs timing; a subset suffices for diagnosis
        workload.code_conversations = 16;
        workload.chat_conversations = 16;
    }
    let records = run_workload(&cfg.coord(run, workload, "e3", false, true))?;

    // aggregate per-stage totals + per-call means across turns
    let mut totals: std::collections::BTreeMap<String, Vec<f64>> = Default::default();
    for r in &records {
        for (stage, secs) in &r.stage_seconds {
            totals.entry(stage.clone()).or_default().push(*secs * 1e3); // ms
        }
    }
    let mut table = String::from(
        "Fig 5: stage breakdown (instrumented, ms per turn)\n\
         | Stage        |     mean |      p50 |      p90 |      p99 |\n\
         |--------------|----------|----------|----------|----------|\n",
    );
    let mut j = Json::obj();
    for (stage, samples) in &totals {
        let s = Summary::from(samples);
        writeln!(table, "| {:<12} | {:>8.2} | {:>8.2} | {:>8.2} | {:>8.2} |",
                 stage, s.mean, s.p50, s.p90, s.p99).ok();
        let mut o = Json::obj();
        o.push("mean_ms", s.mean).push("p50_ms", s.p50).push("p90_ms", s.p90)
            .push("p99_ms", s.p99);
        j.push(stage, o);
    }
    println!("{table}");
    write(&cfg.out_dir, "fig5_stage_breakdown.txt", &table)?;
    write(&cfg.out_dir, "fig5_stage_breakdown.json", &j.to_string_pretty())?;
    Ok(j)
}

// ----------------------------------------------------------------------
// E4 — drafter truncation (Table 3, Fig 6, Fig 7)
// ----------------------------------------------------------------------

/// One row of the E4 drafter-truncation table.
pub struct TruncRow {
    /// Drafter window setting (`none` or the window size).
    pub window: String,
    /// Mean EA throughput under this window.
    pub ea_tok_s: f64,
    /// Speedup over the shared baseline.
    pub speedup: f64,
    /// Mean accept_L under this window.
    pub accept_mean: f64,
    /// p90 accept_L under this window.
    pub accept_p90: f64,
}

/// E4: drafter context truncation (Table 3, Fig 6, Fig 7).
pub fn run_e4(cfg: &HarnessConfig, attention_stats: bool) -> Result<Vec<TruncRow>> {
    let mut workload = cfg.workload();
    if !cfg.quick {
        // 4 windows x workload: a 96-turn subset keeps the sweep
        // affordable on this testbed while preserving effect sizes.
        workload.code_conversations = 32;
        workload.chat_conversations = 32;
    }
    let max_new = if cfg.quick { 24 } else { 128 };
    let mut base_run = cfg.run.clone();
    base_run.max_new_tokens = max_new;
    let base_records =
        run_workload(&cfg.coord(base_run.clone(), workload.clone(), "e4_base", true, false))?;
    let base_mean = Summary::from(
        &base_records.iter().filter(|r| r.kind == "baseline").map(|r| r.tok_s)
            .collect::<Vec<_>>(),
    )
    .mean;

    // paper windows {none,128,256,512} at context ~1400; CPU-scaled here.
    let windows: Vec<Option<usize>> = if cfg.quick {
        vec![None, Some(8)]
    } else {
        vec![None, Some(32), Some(64), Some(128)]
    };
    let mut rows = Vec::new();
    let mut attn_json = Json::obj();
    for w in &windows {
        let mut run = base_run.clone();
        run.draft_window = *w;
        run.attention_stats = attention_stats;
        let tag = match w {
            None => "e4_wnone".to_string(),
            Some(x) => format!("e4_w{x}"),
        };
        let recs = run_workload(&cfg.coord(run, workload.clone(), &tag, false, true))?;
        let ea: Vec<&TurnRecord> = recs.iter().filter(|r| r.kind == "ea").collect();
        let tok = Summary::from(&ea.iter().map(|r| r.tok_s).collect::<Vec<_>>());
        let accepts: Vec<f64> = ea
            .iter()
            .flat_map(|r| r.accept_lens.iter().map(|a| *a as f64))
            .collect();
        let acc = Summary::from(&accepts);
        let label = w.map_or("none".to_string(), |x| x.to_string());
        rows.push(TruncRow {
            window: label.clone(),
            ea_tok_s: tok.mean,
            speedup: if base_mean > 0.0 { tok.mean / base_mean } else { 0.0 },
            accept_mean: acc.mean,
            accept_p90: acc.p90,
        });
        if attention_stats {
            // Fig 7: aggregate attention-distance buckets
            let mut buckets = vec![0u64; ATTN_BUCKET_LABELS.len()];
            for r in &ea {
                for (i, c) in r.attn_buckets.iter().enumerate() {
                    if i < buckets.len() {
                        buckets[i] += c;
                    }
                }
            }
            let total: u64 = buckets.iter().sum::<u64>().max(1);
            let mut o = Json::obj();
            for (i, lab) in ATTN_BUCKET_LABELS.iter().enumerate() {
                o.push(lab, buckets[i] as f64 / total as f64);
            }
            attn_json.push(&format!("window_{label}"), o);
        }
    }

    let mut table = String::new();
    writeln!(table, "Table 3: drafter-only fixed-window truncation (max_new={max_new}, baseline {base_mean:.2} Tok/s)").ok();
    writeln!(table, "| Window W | EA Tok/s (mean) | Speedup (mean) | accept_L mean | accept_L p90 |").ok();
    writeln!(table, "|----------|-----------------|----------------|---------------|--------------|").ok();
    for r in &rows {
        writeln!(table, "| {:<8} | {:>15.2} | {:>14.2} | {:>13.2} | {:>12.2} |",
                 r.window, r.ea_tok_s, r.speedup, r.accept_mean, r.accept_p90).ok();
    }
    println!("{table}");
    write(&cfg.out_dir, "table3_truncation.txt", &table)?;
    let mut csv = String::from("window,ea_tok_s,speedup,accept_mean,accept_p90\n");
    for r in &rows {
        writeln!(csv, "{},{:.4},{:.4},{:.4},{:.4}",
                 r.window, r.ea_tok_s, r.speedup, r.accept_mean, r.accept_p90).ok();
    }
    write(&cfg.out_dir, "fig6_truncation.csv", &csv)?;
    if attention_stats {
        write(&cfg.out_dir, "fig7_attention_buckets.json", &attn_json.to_string_pretty())?;
        println!("Fig 7 attention buckets: {}", attn_json.to_string());
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(tag: &str) -> HarnessConfig {
        let d = std::env::temp_dir()
            .join(format!("eagle_harness_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        HarnessConfig {
            backend: BackendSpec::Sim { agree_pct: 90 },
            out_dir: d,
            world_size: 2,
            run: RunConfig::default(),
            quick: true,
            verbose: false,
        }
    }

    #[test]
    fn e1_quick_produces_all_artifacts() {
        let c = cfg("e1");
        let rep = run_e1(&c).unwrap();
        assert_eq!(rep.turns, 9);
        for f in ["e1_report.json", "fig1_lengths.csv", "fig2a_speedup_hist.csv",
                  "fig2b_speedup_vs_lk.csv", "fig3_accept_pos.csv"] {
            assert!(c.out_dir.join(f).exists(), "{f}");
        }
        let _ = std::fs::remove_dir_all(&c.out_dir);
    }

    #[test]
    fn e2_quick_sweeps_both_axes() {
        let c = cfg("e2");
        let rows = run_e2(&c).unwrap();
        assert_eq!(rows.len(), 4); // 2 M-settings + 2 D-settings
        assert!(rows.iter().all(|r| r.ea_tok_s > 0.0));
        assert!(c.out_dir.join("fig4_budget_sweep.csv").exists());
        let _ = std::fs::remove_dir_all(&c.out_dir);
    }

    #[test]
    fn e3_quick_reports_stages() {
        let c = cfg("e3");
        let j = run_e3(&c).unwrap();
        for stage in ["verify", "commit", "mask_build", "tensorize"] {
            assert!(j.get(stage).is_some(), "missing {stage}");
        }
        let _ = std::fs::remove_dir_all(&c.out_dir);
    }

    #[test]
    fn e4_quick_shows_truncation_damage() {
        let c = cfg("e4");
        let rows = run_e4(&c, true).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows[0].accept_mean > rows[1].accept_mean,
                "window must reduce acceptance: {} vs {}",
                rows[0].accept_mean, rows[1].accept_mean);
        assert!(c.out_dir.join("fig7_attention_buckets.json").exists());
        let _ = std::fs::remove_dir_all(&c.out_dir);
    }
}
