//! CI bench-regression gate: compare the bench's `BENCH_hotpath.json`
//! against the committed `BENCH_baseline.json` and fail (exit 1) on a
//! regression.
//!
//! ```text
//! bench_gate <BENCH_baseline.json> <BENCH_hotpath.json>
//! ```
//!
//! Rules, applied to every numeric leaf of the *baseline* (walked
//! recursively, so the `batch_sweep` and `straggler` entries are gated
//! per-B; baseline-only keys define the contract — new keys in the
//! current file are ignored until they are pinned):
//!
//! * throughput (`*rounds_per_sec`, `tokens_per_sec`): current must be
//!   `>= TOLERANCE * baseline` — i.e. a >15% rounds/s regression at any
//!   B fails the job under the default tolerance of 0.85;
//! * speedups (`*_speedup`, `b4_speedup_vs_b1`): current must be
//!   `>= max(1.0, TOLERANCE * baseline)` — batching/continuous admission
//!   must never *lose* to its baseline, regardless of runner speed;
//! * allocation traffic (`bytes_allocated_per_round`,
//!   `allocs_per_round`): current must be `<= baseline * 2 + slack` — a
//!   machine-independent tripwire for the zero-allocation hot path;
//! * KV occupancy (`paged_*_kv_bytes_resident`): deterministic bytes;
//!   current must be `<= 1.15 * baseline` (a >15% paged-residency
//!   regression fails regardless of runner speed), and — when the
//!   baseline pins a `kv_resident` section — the *current* file must
//!   show `paged <= flat` at B in {4, 8} (the cross-layout rule: paging
//!   must never cost more memory than the pinned flat buffers it
//!   replaces);
//! * KV-session upload (`upload.session_on_*_upload_bytes_per_token`):
//!   deterministic bytes; gated `<= 1.15 * baseline`, and — when the
//!   baseline pins an `upload` section — the *current* file must show
//!   session-on `<= 0.25x` session-off at B = 4 (the resident-session
//!   path must keep shipping deltas, not caches);
//! * trace-replay latency (`*_p50_ms` / `*_p95_ms` / `*_p99_ms`):
//!   deterministic virtual-clock percentiles; current must be
//!   `<= 1.15 * baseline`, and — when the baseline pins a
//!   `latency.slo_ms` — every `*_p99_ms` leaf of the *current* `latency`
//!   section must sit at or below that SLO (a hard p99 floor: virtual
//!   clocks don't flake, so the ceiling is absolute, not relative);
//! * CoW prefix sharing (`sharing.sharing_on_*`): deterministic prefill
//!   teacher-calls per admitted conversation and resident KV bytes;
//!   gated `<= 1.15 * baseline`, and — when the baseline pins a
//!   `sharing` section — the *current* file must show sharing-on
//!   `<=` sharing-off on both metrics at B = 4 (adoption must keep
//!   skipping prefill work and deduplicating resident blocks;
//!   `sharing_off_*` entries are the comparator, not gated themselves);
//! * multi-worker sharding (`multiworker.workers*_p99_ms`):
//!   deterministic virtual-clock percentiles, gated `<= 1.15 * baseline`
//!   per leaf like every latency metric, and — when the baseline pins a
//!   `multiworker` section — the *current* file must show workers=4 p99
//!   `<=` workers=1 p99 (sharding a fixed arrival rate across more
//!   workers must never inflate the tail; exact ties pass, since
//!   worker-count invisibility makes the percentiles coincide whenever
//!   no queueing occurs);
//! * shed rate (`*_shed_rate`): deterministic admission-layer outcome;
//!   current must be `<= baseline + 0.05` (absolute slack — shedding a
//!   few more requests under the pinned overload trace is creep, not
//!   noise);
//! * a metric present in the baseline but missing from the current file
//!   fails (dropping a gated metric is a coverage regression).
//!
//! Absolute rounds/s floors are machine-dependent: the committed
//! baseline pins *conservative floors* (well below a healthy run on any
//! recent runner) so the gate trips on catastrophic regressions without
//! flaking on runner variance. Re-pin by copying a green run's
//! `BENCH_hotpath.json` artifact over `BENCH_baseline.json` (and review
//! the diff like any other perf change). `BENCH_GATE_TOLERANCE`
//! overrides the 0.85 factor.

use eagle_pangu::json::{parse, Json};
use std::process::ExitCode;

/// Default regression tolerance: current >= 0.85 * baseline passes.
const DEFAULT_TOLERANCE: f64 = 0.85;

/// One gated comparison outcome.
struct Finding {
    path: String,
    ok: bool,
    detail: String,
}

/// Which gate rule a metric key falls under.
enum Rule {
    /// Higher is better; fail below `tolerance * baseline`.
    Throughput,
    /// Ratio that must stay a win: fail below `max(1.0, tol * baseline)`.
    Speedup,
    /// Lower is better; fail above `2 * baseline + slack`.
    Alloc {
        /// Absolute slack added on top of the doubled baseline.
        slack: f64,
    },
    /// KV residency (bytes, machine-independent and deterministic):
    /// lower is better; fail above `MEMORY_TOLERANCE * baseline` — a
    /// paged-occupancy regression beyond 15% fails regardless of runner
    /// speed.
    Memory,
    /// Trace-replay latency percentile (deterministic virtual-clock ms):
    /// lower is better; fail above `LATENCY_TOLERANCE * baseline`.
    Latency,
    /// Shed rate (deterministic admission outcome in [0, 1]): lower is
    /// better; fail above `baseline + slack` (absolute, not a ratio — a
    /// 0.0 baseline must still admit pinning).
    ShedRate {
        /// Absolute slack on top of the baseline rate.
        slack: f64,
    },
}

/// Memory-occupancy regression budget: current <= 1.15 * baseline.
const MEMORY_TOLERANCE: f64 = 1.15;

/// Latency regression budget: current <= 1.15 * baseline (virtual ms).
const LATENCY_TOLERANCE: f64 = 1.15;

/// Shed-rate creep budget: current <= baseline + 0.05 (absolute).
const SHED_RATE_SLACK: f64 = 0.05;

fn rule_for(leaf: &str) -> Option<Rule> {
    if leaf == "tokens_per_sec" || leaf.ends_with("rounds_per_sec") {
        return Some(Rule::Throughput);
    }
    if leaf.ends_with("_speedup") || leaf == "b4_speedup_vs_b1" {
        return Some(Rule::Speedup);
    }
    if leaf == "bytes_allocated_per_round" {
        return Some(Rule::Alloc { slack: 512.0 });
    }
    if leaf == "allocs_per_round" {
        return Some(Rule::Alloc { slack: 4.0 });
    }
    if leaf.starts_with("paged_") && leaf.ends_with("_kv_bytes_resident") {
        // flat_* entries are the comparator for the cross-layout rule,
        // not gated against the baseline themselves (pinned buffers are
        // a constant of the contract geometry).
        return Some(Rule::Memory);
    }
    if leaf.starts_with("session_on_") && leaf.ends_with("_upload_bytes_per_token") {
        // session_off_* entries are the comparator for the 0.25x cross
        // rule, not gated themselves (full upload is a constant of the
        // contract geometry).
        return Some(Rule::Memory);
    }
    if leaf.starts_with("sharing_on_")
        && (leaf.ends_with("_kv_bytes_resident")
            || leaf.ends_with("_prefill_teacher_calls_per_conv"))
    {
        // sharing_off_* entries are the comparator for the on-vs-off
        // cross rule, not gated themselves (the unshared cost is a
        // constant of the pinned workload).
        return Some(Rule::Memory);
    }
    if leaf.ends_with("_p50_ms") || leaf.ends_with("_p95_ms") || leaf.ends_with("_p99_ms") {
        // `slo_ms` / `overload_target` are contract constants, not gated
        // leaves — they parameterize the cross rule below.
        return Some(Rule::Latency);
    }
    if leaf.ends_with("_shed_rate") {
        return Some(Rule::ShedRate { slack: SHED_RATE_SLACK });
    }
    None
}

/// Walk every numeric leaf of `baseline`, compare against the same path
/// in `current` under the key's rule, and append findings.
fn gate(baseline: &Json, current: &Json, tol: f64, path: &str, out: &mut Vec<Finding>) {
    if let Some(obj) = baseline.as_obj() {
        for (k, v) in obj {
            let sub = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
            gate(v, current.get(k).unwrap_or(&Json::Null), tol, &sub, out);
        }
        return;
    }
    let leaf = path.rsplit('.').next().unwrap_or(path);
    let Some(rule) = rule_for(leaf) else { return };
    let Some(base) = baseline.as_f64() else { return };
    let Some(cur) = current.as_f64() else {
        out.push(Finding {
            path: path.to_string(),
            ok: false,
            detail: format!("missing from current bench output (baseline {base:.2})"),
        });
        return;
    };
    let (ok, detail) = match rule {
        Rule::Throughput => {
            let floor = tol * base;
            (cur >= floor, format!("{cur:.1} vs baseline {base:.1} (floor {floor:.1})"))
        }
        Rule::Speedup => {
            let floor = (tol * base).max(1.0);
            (cur >= floor, format!("{cur:.3}x vs baseline {base:.3}x (floor {floor:.3}x)"))
        }
        Rule::Alloc { slack } => {
            let ceil = base * 2.0 + slack;
            (cur <= ceil, format!("{cur:.1} vs baseline {base:.1} (ceiling {ceil:.1})"))
        }
        Rule::Memory => {
            let ceil = base * MEMORY_TOLERANCE;
            (cur <= ceil, format!("{cur:.0} B vs baseline {base:.0} B (ceiling {ceil:.0} B)"))
        }
        Rule::Latency => {
            let ceil = base * LATENCY_TOLERANCE;
            (cur <= ceil, format!("{cur:.2} ms vs baseline {base:.2} ms (ceiling {ceil:.2} ms)"))
        }
        Rule::ShedRate { slack } => {
            let ceil = base + slack;
            (cur <= ceil, format!("{cur:.3} vs baseline {base:.3} (ceiling {ceil:.3})"))
        }
    };
    out.push(Finding { path: path.to_string(), ok, detail });
}

/// Cross-layout memory rule, read from the *current* file (both numbers
/// are produced by the same bench run, deterministically): at B >= 4 the
/// paged layout must never hold more KV bytes resident than flat —
/// otherwise paging lost its reason to exist. Applied only when the
/// baseline pins a `kv_resident` section (baseline defines the
/// contract, like every other rule).
fn gate_kv_cross(baseline: &Json, current: &Json, out: &mut Vec<Finding>) {
    if baseline.get("kv_resident").is_none() {
        return;
    }
    let cur = current.get("kv_resident");
    for b in [4u32, 8] {
        let path = format!("kv_resident.paged_vs_flat_b{b}");
        let paged = cur
            .and_then(|k| k.get(&format!("paged_b{b}_kv_bytes_resident")))
            .and_then(Json::as_f64);
        let flat = cur
            .and_then(|k| k.get(&format!("flat_b{b}_kv_bytes_resident")))
            .and_then(Json::as_f64);
        let (ok, detail) = match (paged, flat) {
            (Some(p), Some(f)) => (
                p <= f,
                format!("paged {p:.0} B vs flat {f:.0} B at B={b}"),
            ),
            _ => (false, format!("kv_resident entries missing from current output at B={b}")),
        };
        out.push(Finding { path, ok, detail });
    }
}

/// KV-session upload rule, read from the *current* file (both numbers
/// come from the same deterministic bench section): at B >= 4 the
/// resident-session path must ship at most [`UPLOAD_RATIO`] of the
/// full-upload path's bytes per token — otherwise sessions stopped
/// paying for themselves. Applied only when the baseline pins an
/// `upload` section (baseline defines the contract).
fn gate_upload_cross(baseline: &Json, current: &Json, out: &mut Vec<Finding>) {
    if baseline.get("upload").is_none() {
        return;
    }
    let cur = current.get("upload");
    let path = "upload.session_on_vs_off_b4".to_string();
    let on = cur
        .and_then(|u| u.get("session_on_b4_upload_bytes_per_token"))
        .and_then(Json::as_f64);
    let off = cur
        .and_then(|u| u.get("session_off_b4_upload_bytes_per_token"))
        .and_then(Json::as_f64);
    let (ok, detail) = match (on, off) {
        (Some(on), Some(off)) => (
            on <= UPLOAD_RATIO * off,
            format!(
                "session-on {on:.0} B/tok vs session-off {off:.0} B/tok at B=4 \
                 (ceiling {:.0})",
                UPLOAD_RATIO * off
            ),
        ),
        _ => (false, "upload entries missing from current output at B=4".to_string()),
    };
    out.push(Finding { path, ok, detail });
}

/// Resident-session upload budget: session-on <= 0.25x session-off.
const UPLOAD_RATIO: f64 = 0.25;

/// CoW prefix-sharing rule, read from the *current* file (both sides
/// come from the same deterministic bench section): at B = 4 the
/// sharing-on path must spend no more prefill teacher calls per admitted
/// conversation and hold no more KV bytes resident than sharing-off —
/// otherwise adoption stopped paying for itself. Applied only when the
/// baseline pins a `sharing` section (baseline defines the contract,
/// like every other rule).
fn gate_sharing_cross(baseline: &Json, current: &Json, out: &mut Vec<Finding>) {
    if baseline.get("sharing").is_none() {
        return;
    }
    let cur = current.get("sharing");
    for (metric, unit) in
        [("prefill_teacher_calls_per_conv", "calls/conv"), ("kv_bytes_resident", "B")]
    {
        let path = format!("sharing.on_vs_off_b4_{metric}");
        let on = cur
            .and_then(|s| s.get(&format!("sharing_on_b4_{metric}")))
            .and_then(Json::as_f64);
        let off = cur
            .and_then(|s| s.get(&format!("sharing_off_b4_{metric}")))
            .and_then(Json::as_f64);
        let (ok, detail) = match (on, off) {
            (Some(on), Some(off)) => (
                on <= off,
                format!("sharing-on {on:.2} {unit} vs sharing-off {off:.2} {unit} at B=4"),
            ),
            _ => (false, "sharing entries missing from current output at B=4".to_string()),
        };
        out.push(Finding { path, ok, detail });
    }
}

/// Multi-worker sharding rule, read from the *current* file (every
/// worker count replays the same trace on the same virtual clock, so
/// both percentiles come out of one deterministic bench run): the
/// workers=4 replay must never show a higher virtual p99 than the
/// workers=1 replay — otherwise the coordinator split inflated tail
/// latency instead of dividing load. Exact ties pass: worker-count
/// invisibility makes the percentiles coincide whenever no queueing
/// occurs. Applied only when the baseline pins a `multiworker` section
/// (baseline defines the contract, like every other rule).
fn gate_multiworker_cross(baseline: &Json, current: &Json, out: &mut Vec<Finding>) {
    if baseline.get("multiworker").is_none() {
        return;
    }
    let cur = current.get("multiworker");
    let path = "multiworker.workers4_vs_workers1_p99".to_string();
    let w1 = cur.and_then(|m| m.get("workers1_p99_ms")).and_then(Json::as_f64);
    let w4 = cur.and_then(|m| m.get("workers4_p99_ms")).and_then(Json::as_f64);
    let (ok, detail) = match (w1, w4) {
        (Some(w1), Some(w4)) => (
            w4 <= w1 + 1e-9,
            format!("workers=4 p99 {w4:.2} ms vs workers=1 p99 {w1:.2} ms"),
        ),
        _ => (false, "multiworker entries missing from current output".to_string()),
    };
    out.push(Finding { path, ok, detail });
}

/// Hard p99 SLO floor over the *current* file's `latency` section: every
/// `*_p99_ms` leaf must sit at or below the baseline's pinned
/// `latency.slo_ms`. The percentiles are virtual-clock and deterministic,
/// so the ceiling is absolute — no runner-speed tolerance applies.
/// Applied only when the baseline pins `latency.slo_ms` (baseline
/// defines the contract, like every other rule).
fn gate_latency_slo(baseline: &Json, current: &Json, out: &mut Vec<Finding>) {
    let Some(slo) =
        baseline.get("latency").and_then(|l| l.get("slo_ms")).and_then(Json::as_f64)
    else {
        return;
    };
    let Some(cur) = current.get("latency").and_then(Json::as_obj) else {
        out.push(Finding {
            path: "latency.slo_floor".to_string(),
            ok: false,
            detail: format!("latency section missing from current output (SLO {slo:.0} ms)"),
        });
        return;
    };
    let mut seen = 0usize;
    for (k, v) in cur {
        if !k.ends_with("_p99_ms") {
            continue;
        }
        let Some(p99) = v.as_f64() else { continue };
        seen += 1;
        out.push(Finding {
            path: format!("latency.{k}.slo_floor"),
            ok: p99 <= slo,
            detail: format!("p99 {p99:.2} ms vs SLO floor {slo:.0} ms"),
        });
    }
    if seen == 0 {
        out.push(Finding {
            path: "latency.slo_floor".to_string(),
            ok: false,
            detail: format!("no *_p99_ms leaves in current latency section (SLO {slo:.0} ms)"),
        });
    }
}

/// Run the gate over two parsed bench files; returns the findings.
fn run_gate(baseline: &Json, current: &Json, tol: f64) -> Vec<Finding> {
    let mut out = Vec::new();
    gate(baseline, current, tol, "", &mut out);
    gate_kv_cross(baseline, current, &mut out);
    gate_upload_cross(baseline, current, &mut out);
    gate_sharing_cross(baseline, current, &mut out);
    gate_multiworker_cross(baseline, current, &mut out);
    gate_latency_slo(baseline, current, &mut out);
    out
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().collect();
    if args.len() != 3 {
        eprintln!("usage: bench_gate <BENCH_baseline.json> <BENCH_hotpath.json>");
        return ExitCode::from(2);
    }
    let tol = std::env::var("BENCH_GATE_TOLERANCE")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(DEFAULT_TOLERANCE);
    let read = |p: &str| -> Result<Json, String> {
        let text = std::fs::read_to_string(p).map_err(|e| format!("{p}: {e}"))?;
        parse(&text).map_err(|e| format!("{p}: {e}"))
    };
    let (baseline, current) = match (read(&args[1]), read(&args[2])) {
        (Ok(b), Ok(c)) => (b, c),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("bench_gate: {e}");
            return ExitCode::from(2);
        }
    };
    let findings = run_gate(&baseline, &current, tol);
    if findings.is_empty() {
        eprintln!("bench_gate: baseline {} defines no gated metrics", args[1]);
        return ExitCode::from(2);
    }
    let mut failed = 0usize;
    for f in &findings {
        let mark = if f.ok { "OK  " } else { "FAIL" };
        println!("{mark} {}: {}", f.path, f.detail);
        if !f.ok {
            failed += 1;
        }
    }
    if failed > 0 {
        eprintln!(
            "bench_gate: {failed}/{} gated metrics regressed beyond tolerance {tol}",
            findings.len()
        );
        return ExitCode::FAILURE;
    }
    println!("bench_gate: all {} gated metrics within tolerance {tol}", findings.len());
    ExitCode::SUCCESS
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bench_json(rps: f64, b8: f64, speedup: f64, bytes: f64) -> Json {
        bench_json_kv(rps, b8, speedup, bytes, 400_000.0, 19_000_000.0)
    }

    fn bench_json_kv(rps: f64, b8: f64, speedup: f64, bytes: f64, paged_b4: f64, flat_b4: f64)
        -> Json {
        let mut sweep = Json::obj();
        sweep.push("B1_rounds_per_sec", 400.0).push("B8_rounds_per_sec", b8);
        let mut kv = Json::obj();
        kv.push("flat_b4_kv_bytes_resident", flat_b4)
            .push("paged_b4_kv_bytes_resident", paged_b4)
            .push("flat_b8_kv_bytes_resident", flat_b4 * 2.0)
            .push("paged_b8_kv_bytes_resident", paged_b4 * 2.0);
        let mut j = Json::obj();
        j.push("rounds_per_sec", rps)
            .push("tokens_per_sec", rps * 3.0)
            .push("bytes_allocated_per_round", bytes)
            .push("batch_sweep", sweep)
            .push("kv_resident", kv)
            .push("straggler_continuous_speedup", speedup)
            .push("backend", "sim"); // non-numeric: ignored
        j
    }

    #[test]
    fn equal_runs_pass() {
        let b = bench_json(1000.0, 2000.0, 1.3, 100.0);
        let findings = run_gate(&b, &b, 0.85);
        assert!(findings.iter().all(|f| f.ok), "identical run must pass");
        // every gated key visited, including the nested sweep
        assert!(findings.iter().any(|f| f.path == "batch_sweep.B8_rounds_per_sec"));
        assert!(findings.iter().any(|f| f.path == "straggler_continuous_speedup"));
    }

    #[test]
    fn fifteen_percent_regression_at_any_b_fails() {
        let base = bench_json(1000.0, 2000.0, 1.3, 100.0);
        // >15% down at B=8 only
        let cur = bench_json(1000.0, 1600.0, 1.3, 100.0);
        let findings = run_gate(&base, &cur, 0.85);
        let b8 = findings.iter().find(|f| f.path == "batch_sweep.B8_rounds_per_sec").unwrap();
        assert!(!b8.ok, "16%+ regression at B=8 must fail");
        // a 10% dip elsewhere stays green
        let cur2 = bench_json(920.0, 2000.0, 1.3, 100.0);
        let findings2 = run_gate(&base, &cur2, 0.85);
        assert!(findings2.iter().all(|f| f.ok), "10% is within tolerance");
    }

    #[test]
    fn speedup_must_stay_a_win() {
        let base = bench_json(1000.0, 2000.0, 1.1, 100.0);
        // tolerance would allow 0.93, but a speedup below 1.0 means
        // continuous admission lost to fixed grouping — always a failure
        let cur = bench_json(1000.0, 2000.0, 0.97, 100.0);
        let findings = run_gate(&base, &cur, 0.85);
        let s = findings.iter().find(|f| f.path == "straggler_continuous_speedup").unwrap();
        assert!(!s.ok, "sub-1.0 speedup must fail");
    }

    #[test]
    fn missing_gated_metric_fails() {
        let base = bench_json(1000.0, 2000.0, 1.3, 100.0);
        let mut cur = Json::obj();
        cur.push("rounds_per_sec", 1000.0);
        let findings = run_gate(&base, &cur, 0.85);
        assert!(
            findings.iter().any(|f| !f.ok && f.detail.contains("missing")),
            "dropped metrics must fail the gate"
        );
    }

    #[test]
    fn alloc_tripwire_catches_regrowth() {
        let base = bench_json(1000.0, 2000.0, 1.3, 100.0);
        let cur = bench_json(1000.0, 2000.0, 1.3, 10_000.0);
        let findings = run_gate(&base, &cur, 0.85);
        let a = findings.iter().find(|f| f.path == "bytes_allocated_per_round").unwrap();
        assert!(!a.ok, "alloc regrowth must fail");
    }

    #[test]
    fn paged_occupancy_regression_beyond_fifteen_percent_fails() {
        let base = bench_json(1000.0, 2000.0, 1.3, 100.0); // paged_b4 = 400k
        // +10% stays green
        let ok = bench_json_kv(1000.0, 2000.0, 1.3, 100.0, 440_000.0, 19_000_000.0);
        let findings = run_gate(&base, &ok, 0.85);
        let f = findings
            .iter()
            .find(|f| f.path == "kv_resident.paged_b4_kv_bytes_resident")
            .unwrap();
        assert!(f.ok, "10% residency growth is within the 15% budget: {}", f.detail);
        // +20% fails
        let bad = bench_json_kv(1000.0, 2000.0, 1.3, 100.0, 480_000.0, 19_000_000.0);
        let findings = run_gate(&base, &bad, 0.85);
        let f = findings
            .iter()
            .find(|f| f.path == "kv_resident.paged_b4_kv_bytes_resident")
            .unwrap();
        assert!(!f.ok, "20% residency growth must fail");
        // flat entries are comparators, never gated per-leaf
        assert!(
            !findings.iter().any(|f| f.path == "kv_resident.flat_b4_kv_bytes_resident"),
            "flat residency must not be baseline-gated"
        );
    }

    #[test]
    fn session_upload_must_stay_below_quarter_of_full() {
        let mut up = Json::obj();
        up.push("session_on_b1_upload_bytes_per_token", 100_000.0)
            .push("session_off_b1_upload_bytes_per_token", 3_000_000.0)
            .push("session_on_b4_upload_bytes_per_token", 100_000.0)
            .push("session_off_b4_upload_bytes_per_token", 3_000_000.0);
        let mut base = bench_json(1000.0, 2000.0, 1.3, 100.0);
        base.push("upload", up.clone());
        let mut good = bench_json(1000.0, 2000.0, 1.3, 100.0);
        good.push("upload", up);
        let findings = run_gate(&base, &good, 0.85);
        let f = findings.iter().find(|f| f.path == "upload.session_on_vs_off_b4").unwrap();
        assert!(f.ok, "{}", f.detail);
        // session_on entries are baseline-gated (deterministic bytes);
        // session_off is the comparator, never gated per-leaf
        assert!(findings
            .iter()
            .any(|f| f.path == "upload.session_on_b4_upload_bytes_per_token"));
        assert!(!findings
            .iter()
            .any(|f| f.path == "upload.session_off_b4_upload_bytes_per_token"));
        // a run where the session path regressed to 0.5x full fails
        let mut bad_up = Json::obj();
        bad_up
            .push("session_on_b1_upload_bytes_per_token", 100_000.0)
            .push("session_off_b1_upload_bytes_per_token", 3_000_000.0)
            .push("session_on_b4_upload_bytes_per_token", 1_500_000.0)
            .push("session_off_b4_upload_bytes_per_token", 3_000_000.0);
        let mut bad = bench_json(1000.0, 2000.0, 1.3, 100.0);
        bad.push("upload", bad_up);
        let findings = run_gate(&base, &bad, 0.85);
        let f = findings.iter().find(|f| f.path == "upload.session_on_vs_off_b4").unwrap();
        assert!(!f.ok, "0.5x of full upload must fail the 0.25x rule");
        // a legacy baseline without an upload section skips the rule
        let legacy = bench_json(1000.0, 2000.0, 1.3, 100.0);
        let findings = run_gate(&legacy, &good, 0.85);
        assert!(!findings.iter().any(|f| f.path.starts_with("upload.")));
    }

    fn sharing_json(on_calls: f64, on_bytes: f64, off_calls: f64, off_bytes: f64) -> Json {
        let mut sh = Json::obj();
        sh.push("sharing_off_b4_prefill_teacher_calls_per_conv", off_calls)
            .push("sharing_on_b4_prefill_teacher_calls_per_conv", on_calls)
            .push("sharing_off_b4_kv_bytes_resident", off_bytes)
            .push("sharing_on_b4_kv_bytes_resident", on_bytes)
            .push("prefix_len", 160.0); // contract constant: never a gated leaf
        let mut j = bench_json(1000.0, 2000.0, 1.3, 100.0);
        j.push("sharing", sh);
        j
    }

    #[test]
    fn sharing_on_must_not_lose_to_sharing_off() {
        let base = sharing_json(2.1, 900_000.0, 3.0, 2_000_000.0);
        let findings = run_gate(&base, &base, 0.85);
        for metric in ["prefill_teacher_calls_per_conv", "kv_bytes_resident"] {
            let f = findings
                .iter()
                .find(|f| f.path == format!("sharing.on_vs_off_b4_{metric}"))
                .unwrap();
            assert!(f.ok, "{}", f.detail);
        }
        // sharing_on leaves are baseline-gated (deterministic numbers);
        // sharing_off is the comparator, never gated per-leaf — and the
        // workload constants are not leaves at all
        assert!(findings.iter().any(|f| f.path == "sharing.sharing_on_b4_kv_bytes_resident"));
        assert!(findings
            .iter()
            .any(|f| f.path == "sharing.sharing_on_b4_prefill_teacher_calls_per_conv"));
        assert!(!findings.iter().any(|f| f.path == "sharing.sharing_off_b4_kv_bytes_resident"));
        assert!(!findings.iter().any(|f| f.path == "sharing.prefix_len"));
        // an inverted run (sharing-on costing more than off on either
        // metric) fails the cross rule even with loose per-leaf ceilings
        let base_loose = sharing_json(4.0, 3_000_000.0, 3.0, 2_000_000.0);
        let bad = sharing_json(3.2, 2_100_000.0, 3.0, 2_000_000.0);
        let findings = run_gate(&base_loose, &bad, 0.85);
        for metric in ["prefill_teacher_calls_per_conv", "kv_bytes_resident"] {
            let f = findings
                .iter()
                .find(|f| f.path == format!("sharing.on_vs_off_b4_{metric}"))
                .unwrap();
            assert!(!f.ok, "sharing-on above sharing-off must fail at B=4: {}", f.detail);
        }
        // a legacy baseline without a sharing section skips the rule
        let legacy = bench_json(1000.0, 2000.0, 1.3, 100.0);
        let findings = run_gate(&legacy, &base, 0.85);
        assert!(!findings.iter().any(|f| f.path.starts_with("sharing.")));
        // ... and a current file that dropped the section fails coverage
        let findings = run_gate(&base, &legacy, 0.85);
        assert!(findings
            .iter()
            .any(|f| f.path == "sharing.on_vs_off_b4_kv_bytes_resident" && !f.ok));
    }

    fn multiworker_json(w1: f64, w4: f64) -> Json {
        let mut mw = Json::obj();
        mw.push("workers1_p99_ms", w1)
            .push("workers1_rounds_per_sec", 900.0)
            .push("workers2_p99_ms", (w1 + w4) / 2.0)
            .push("workers2_rounds_per_sec", 900.0)
            .push("workers4_p99_ms", w4)
            .push("workers4_rounds_per_sec", 900.0);
        let mut j = bench_json(1000.0, 2000.0, 1.3, 100.0);
        j.push("multiworker", mw);
        j
    }

    #[test]
    fn workers4_p99_must_not_exceed_workers1() {
        let base = multiworker_json(80.0, 80.0);
        // exact ties pass: worker-count invisibility makes the
        // percentiles coincide whenever no queueing occurs
        let findings = run_gate(&base, &base, 0.85);
        let f = findings
            .iter()
            .find(|f| f.path == "multiworker.workers4_vs_workers1_p99")
            .unwrap();
        assert!(f.ok, "{}", f.detail);
        // per-leaf gating covers the multiworker section too: the
        // percentiles under Latency, rounds/s under Throughput
        assert!(findings.iter().any(|f| f.path == "multiworker.workers4_p99_ms"));
        assert!(findings.iter().any(|f| f.path == "multiworker.workers1_rounds_per_sec"));
        // an inverted run (sharding inflating the tail) fails the cross
        // rule even when loose per-leaf ceilings would let it through
        let base_loose = multiworker_json(80.0, 120.0);
        let bad = multiworker_json(80.0, 90.0);
        let findings = run_gate(&base_loose, &bad, 0.85);
        let f = findings
            .iter()
            .find(|f| f.path == "multiworker.workers4_vs_workers1_p99")
            .unwrap();
        assert!(!f.ok, "workers=4 p99 above workers=1 must fail: {}", f.detail);
        // a legacy baseline without a multiworker section skips the rule
        let legacy = bench_json(1000.0, 2000.0, 1.3, 100.0);
        let findings = run_gate(&legacy, &base, 0.85);
        assert!(!findings.iter().any(|f| f.path.starts_with("multiworker.")));
        // ... and a current file that dropped the section fails coverage
        let findings = run_gate(&base, &legacy, 0.85);
        assert!(findings
            .iter()
            .any(|f| f.path == "multiworker.workers4_vs_workers1_p99" && !f.ok));
    }

    fn latency_json(p99: f64, shed: f64, slo: f64) -> Json {
        let mut lat = Json::obj();
        lat.push("poisson_b4_p50_ms", p99 * 0.4)
            .push("poisson_b4_p95_ms", p99 * 0.8)
            .push("poisson_b4_p99_ms", p99)
            .push("poisson_b4_shed_rate", 0.0)
            .push("overload_shed_rate", shed)
            .push("overload_target", 30.0)
            .push("slo_ms", slo);
        let mut j = bench_json(1000.0, 2000.0, 1.3, 100.0);
        j.push("latency", lat);
        j
    }

    #[test]
    fn latency_regression_beyond_fifteen_percent_fails() {
        let base = latency_json(80.0, 0.4, 250.0);
        // +10% stays green
        let findings = run_gate(&base, &latency_json(88.0, 0.4, 250.0), 0.85);
        let f = findings.iter().find(|f| f.path == "latency.poisson_b4_p99_ms").unwrap();
        assert!(f.ok, "10% latency growth is within the 15% budget: {}", f.detail);
        // +20% fails
        let findings = run_gate(&base, &latency_json(96.0, 0.4, 250.0), 0.85);
        let f = findings.iter().find(|f| f.path == "latency.poisson_b4_p99_ms").unwrap();
        assert!(!f.ok, "20% latency growth must fail");
        // p50/p95 leaves are gated too
        assert!(findings.iter().any(|f| f.path == "latency.poisson_b4_p50_ms"));
        assert!(findings.iter().any(|f| f.path == "latency.poisson_b4_p95_ms"));
        // the SLO constant itself is a contract parameter, never a leaf
        assert!(!findings.iter().any(|f| f.path == "latency.slo_ms"));
    }

    #[test]
    fn shed_rate_creep_beyond_absolute_slack_fails() {
        let base = latency_json(80.0, 0.4, 250.0);
        let findings = run_gate(&base, &latency_json(80.0, 0.44, 250.0), 0.85);
        let f = findings.iter().find(|f| f.path == "latency.overload_shed_rate").unwrap();
        assert!(f.ok, "+0.04 shed rate is within the 0.05 slack: {}", f.detail);
        let findings = run_gate(&base, &latency_json(80.0, 0.46, 250.0), 0.85);
        let f = findings.iter().find(|f| f.path == "latency.overload_shed_rate").unwrap();
        assert!(!f.ok, "+0.06 shed rate must fail");
    }

    #[test]
    fn p99_slo_floor_is_absolute() {
        let base = latency_json(80.0, 0.4, 90.0);
        // under the floor: passes
        let findings = run_gate(&base, &latency_json(85.0, 0.4, 90.0), 0.85);
        let f = findings
            .iter()
            .find(|f| f.path == "latency.poisson_b4_p99_ms.slo_floor")
            .unwrap();
        assert!(f.ok, "{}", f.detail);
        // over the floor: fails even though it is within 1.15x of its own
        // baseline (the SLO ceiling is absolute)
        let findings = run_gate(&base, &latency_json(91.0, 0.4, 90.0), 0.85);
        let f = findings
            .iter()
            .find(|f| f.path == "latency.poisson_b4_p99_ms.slo_floor")
            .unwrap();
        assert!(!f.ok, "p99 above the SLO floor must fail: {}", f.detail);
        // a current file that dropped the latency section fails coverage
        let stale = bench_json(1000.0, 2000.0, 1.3, 100.0);
        let findings = run_gate(&base, &stale, 0.85);
        assert!(findings.iter().any(|f| f.path == "latency.slo_floor" && !f.ok));
        // a legacy baseline without latency.slo_ms skips the rule
        let legacy = bench_json(1000.0, 2000.0, 1.3, 100.0);
        let findings = run_gate(&legacy, &latency_json(85.0, 0.4, 90.0), 0.85);
        assert!(!findings.iter().any(|f| f.path.contains("slo_floor")));
    }

    #[test]
    fn paged_must_not_exceed_flat_at_b4_or_b8() {
        let base = bench_json(1000.0, 2000.0, 1.3, 100.0);
        let good = bench_json(1000.0, 2000.0, 1.3, 100.0);
        let findings = run_gate(&base, &good, 0.85);
        for b in [4, 8] {
            let f = findings
                .iter()
                .find(|f| f.path == format!("kv_resident.paged_vs_flat_b{b}"))
                .unwrap();
            assert!(f.ok, "paged below flat must pass at B={b}: {}", f.detail);
        }
        // paged above flat at B=4 fails even if it beats its own baseline
        // tolerance x flat... (cross rule is absolute)
        let inverted = bench_json_kv(1000.0, 2000.0, 1.3, 100.0, 20_000_000.0, 19_000_000.0);
        let base_loose = bench_json_kv(1000.0, 2000.0, 1.3, 100.0, 30_000_000.0, 19_000_000.0);
        let findings = run_gate(&base_loose, &inverted, 0.85);
        let f = findings
            .iter()
            .find(|f| f.path == "kv_resident.paged_vs_flat_b4")
            .unwrap();
        assert!(!f.ok, "paged above flat at B=4 must fail");
        // a baseline without a kv_resident section skips the cross rule
        // (legacy baselines keep working)
        let mut legacy = Json::obj();
        legacy.push("rounds_per_sec", 1000.0);
        let findings = run_gate(&legacy, &good, 0.85);
        assert!(
            !findings.iter().any(|f| f.path.starts_with("kv_resident.paged_vs_flat")),
            "cross rule must be baseline-opt-in"
        );
        // ... and a current file missing the entries fails coverage
        let mut stale = Json::obj();
        stale.push("rounds_per_sec", 1000.0);
        let findings = run_gate(&base, &stale, 0.85);
        assert!(
            findings
                .iter()
                .any(|f| f.path == "kv_resident.paged_vs_flat_b4" && !f.ok),
            "missing kv entries in the current file must fail"
        );
    }
}
