//! `static_check` — the repo's own lint driver (see
//! `docs/STATIC_ANALYSIS.md` for the rule catalog).
//!
//! ```text
//! static_check [--root DIR] [--json FILE] [--list-rules]
//! ```
//!
//! Scans `rust/src/**/*.rs` plus the sibling artifacts each rule
//! cross-checks (`python/compile/aot.py`, `rust/tests/rpc.rs`,
//! `README.md`), prints one `file:line  RULE_ID  severity  message`
//! line per finding, and exits non-zero if any finding is not waived
//! by an audited `lint: allow(...)` pragma. `--json` additionally
//! writes the machine-readable report (consumed by CI's
//! `static-analysis` job artifact).

use anyhow::{bail, Result};
use eagle_pangu::analysis::{self, RULES};
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> Result<ExitCode> {
    let mut root = PathBuf::from(".");
    let mut json_out: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(v) => root = PathBuf::from(v),
                None => bail!("--root needs a directory"),
            },
            "--json" => match args.next() {
                Some(v) => json_out = Some(PathBuf::from(v)),
                None => bail!("--json needs a file path"),
            },
            "--list-rules" => {
                for r in RULES {
                    println!("{:<16} {:<6} {}", r.id, r.severity.as_str(), r.summary);
                }
                return Ok(ExitCode::SUCCESS);
            }
            other => bail!("unknown argument '{other}' (try --root, --json, --list-rules)"),
        }
    }

    let report = analysis::run(&root)?;
    for f in &report.findings {
        println!("{}", f.render());
    }
    if let Some(path) = json_out {
        std::fs::write(&path, report.to_json().to_string_pretty())?;
    }
    let (active, allowed) = (report.active(), report.allowed());
    println!(
        "static_check: {} files scanned, {} findings ({} active, {} allowed)",
        report.files_scanned,
        report.findings.len(),
        active,
        allowed
    );
    Ok(if active == 0 { ExitCode::SUCCESS } else { ExitCode::FAILURE })
}
