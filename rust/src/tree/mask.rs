//! Tree attention mask construction (paper §2.4, §3.3).
//!
//! Produces the `[S, cap + S]` additive row mask the AOT modules consume:
//! columns `[0, cap)` address the committed-prefix cache, columns
//! `[cap, cap+S)` the speculative block. Row `k` opens:
//!
//!   * prefix columns `[lo, t)` where `lo = max(0, t - W)` under a drafter
//!     window `W` (E4 truncation; teacher masks always use `lo = 0`);
//!   * speculative column `j` iff `Anc(j, k)` and both slots are valid.
//!
//! Padded slots are force-masked in *both* directions ("no leakage to
//! padded slots", §3.3). Two builders produce bit-identical output:
//! the dense ancestor-walk (reference) and the ancestor-table builder
//! (used for larger budgets) — mirroring the paper's dense-vs-structured
//! mask note; `verify_path` benches compare their cost.

use super::tensorize::Tensorized;
use crate::config::contract::NEG_INF;

/// Reusable mask buffer + build strategies.
pub struct MaskBuilder {
    pub cache_cap: usize,
    /// Budget threshold above which the ancestor-table builder is used
    /// by [`MaskBuilder::build_auto`] (paper: "selects the mask
    /// construction strategy based on the speculative budget").
    pub table_threshold: usize,
}

impl MaskBuilder {
    pub fn new(cache_cap: usize) -> Self {
        Self { cache_cap, table_threshold: 64 }
    }

    /// Row width of a mask for block size `s`.
    pub fn width(&self, s: usize) -> usize {
        self.cache_cap + s
    }

    /// Reset + size `out` for block size `s`, all columns masked.
    fn prepare<'a>(&self, out: &'a mut Vec<f32>, s: usize) -> &'a mut [f32] {
        let n = s * self.width(s);
        out.clear();
        out.resize(n, NEG_INF);
        &mut out[..]
    }

    /// Open prefix columns `[lo, t)` for every valid row.
    fn open_prefix(&self, m: &mut [f32], tens: &Tensorized, t: usize, window: Option<usize>) {
        let w = self.width(tens.s);
        let lo = window.map_or(0, |win| t.saturating_sub(win));
        for k in 0..tens.live {
            if tens.valid[k] {
                m[k * w + lo..k * w + t].fill(0.0);
            }
        }
    }

    /// Dense builder: per-row ancestor walk (O(M * D_max) opens).
    pub fn build_dense(
        &self,
        out: &mut Vec<f32>,
        tens: &Tensorized,
        t: usize,
        window: Option<usize>,
    ) {
        let s = tens.s;
        let w = self.width(s);
        let m = self.prepare(out, s);
        self.open_prefix(m, tens, t, window);
        for k in 0..tens.live {
            if !tens.valid[k] {
                continue;
            }
            // walk the parent chain: self, parent, ..., root
            let mut cur = k;
            loop {
                if tens.valid[cur] {
                    m[k * w + self.cache_cap + cur] = 0.0;
                }
                if cur == 0 {
                    break;
                }
                cur = tens.parent[cur] as usize;
            }
        }
    }

    /// Ancestor-table builder: bitset visibility propagated parent->child
    /// in linearization order (O(M * S/64) words), then expanded to f32.
    pub fn build_table(
        &self,
        out: &mut Vec<f32>,
        tens: &Tensorized,
        t: usize,
        window: Option<usize>,
    ) {
        let s = tens.s;
        let w = self.width(s);
        let words = s.div_ceil(64);
        // visibility bitsets: vis[k] = vis[parent[k]] | bit(k)
        let mut vis = vec![0u64; tens.live * words];
        for k in 0..tens.live {
            if k > 0 {
                let p = tens.parent[k] as usize;
                let (lo, rest) = vis.split_at_mut(k * words);
                rest[..words].copy_from_slice(&lo[p * words..p * words + words]);
            }
            vis[k * words + k / 64] |= 1u64 << (k % 64);
        }
        let m = self.prepare(out, s);
        self.open_prefix(m, tens, t, window);
        for k in 0..tens.live {
            if !tens.valid[k] {
                continue;
            }
            let row = &mut m[k * w + self.cache_cap..k * w + self.cache_cap + s];
            for wd in 0..words {
                let mut bits = vis[k * words + wd];
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    let j = wd * 64 + b;
                    if tens.valid[j] {
                        row[j] = 0.0;
                    }
                    bits &= bits - 1;
                }
            }
        }
    }

    /// Strategy selection by budget (the paper's implementation note).
    pub fn build_auto(
        &self,
        out: &mut Vec<f32>,
        tens: &Tensorized,
        t: usize,
        window: Option<usize>,
    ) {
        if tens.live > self.table_threshold {
            self.build_table(out, tens, t, window)
        } else {
            self.build_dense(out, tens, t, window)
        }
    }

    /// Mask for a *causal chain* block (prefill chunks, baseline decode,
    /// draft chain refresh): `live` rows appended after prefix `t`, row i
    /// sees `[lo, t)` + chain slots `0..=i`.
    pub fn build_chain(
        &self,
        out: &mut Vec<f32>,
        s: usize,
        live: usize,
        t: usize,
        window: Option<usize>,
    ) {
        let w = self.width(s);
        let n = s * w;
        out.clear();
        out.resize(n, NEG_INF);
        let lo = window.map_or(0, |win| t.saturating_sub(win));
        for i in 0..live {
            out[i * w + lo..i * w + t].fill(0.0);
            for j in 0..=i {
                out[i * w + self.cache_cap + j] = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::build::SpecTree;
    use crate::util::prop;

    const CAP: usize = 64; // small cap for test readability

    fn sample() -> Tensorized {
        let mut t = SpecTree::with_root(10);
        let a = t.add_child(0, 11, -0.1);
        let c = t.add_child(0, 13, -0.4);
        let b = t.add_child(a, 12, -0.2);
        t.add_child(c, 14, -0.6);
        let _ = b;
        Tensorized::from_tree(&t, 8, true).unwrap()
    }

    fn open(m: &[f32], w: usize, k: usize, col: usize) -> bool {
        m[k * w + col] == 0.0
    }

    #[test]
    fn dense_mask_semantics() {
        let mb = MaskBuilder::new(CAP);
        let tens = sample();
        let mut m = Vec::new();
        mb.build_dense(&mut m, &tens, 10, None);
        let w = mb.width(8);
        // prefix open for valid rows
        assert!(open(&m, w, 0, 0) && open(&m, w, 0, 9));
        assert!(!open(&m, w, 0, 10)); // beyond committed length
        // root sees itself only in the spec block
        assert!(open(&m, w, 0, CAP));
        assert!(!open(&m, w, 0, CAP + 1));
        // node 3 (b, child of a) sees root, a, itself; not c
        assert!(open(&m, w, 3, CAP) && open(&m, w, 3, CAP + 1) && open(&m, w, 3, CAP + 3));
        assert!(!open(&m, w, 3, CAP + 2));
        // sibling isolation: c doesn't see a
        assert!(!open(&m, w, 2, CAP + 1));
        // padded rows fully masked
        for col in 0..w {
            assert!(!open(&m, w, 6, col));
        }
        // padded columns masked for all rows
        for k in 0..5 {
            assert!(!open(&m, w, k, CAP + 6));
        }
    }

    #[test]
    fn table_matches_dense() {
        let mb = MaskBuilder::new(CAP);
        let tens = sample();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        mb.build_dense(&mut a, &tens, 7, None);
        mb.build_table(&mut b, &tens, 7, None);
        assert_eq!(a, b);
    }

    #[test]
    fn window_truncates_prefix_only() {
        let mb = MaskBuilder::new(CAP);
        let tens = sample();
        let mut m = Vec::new();
        mb.build_dense(&mut m, &tens, 20, Some(5));
        let w = mb.width(8);
        assert!(!open(&m, w, 0, 14)); // outside window
        assert!(open(&m, w, 0, 15) && open(&m, w, 0, 19));
        assert!(open(&m, w, 0, CAP)); // spec self still open
    }

    #[test]
    fn chain_mask_causal() {
        let mb = MaskBuilder::new(CAP);
        let mut m = Vec::new();
        mb.build_chain(&mut m, 4, 3, 6, None);
        let w = mb.width(4);
        assert!(open(&m, w, 2, CAP + 2) && open(&m, w, 2, CAP) && !open(&m, w, 2, CAP + 3));
        assert!(!open(&m, w, 0, CAP + 1));
        // padded row 3 fully closed
        for col in 0..w {
            assert!(!open(&m, w, 3, col));
        }
    }

    #[test]
    fn property_builders_agree_on_random_trees() {
        let mb = MaskBuilder::new(CAP);
        prop::for_cases(100, 0xA5C3, |g| {
            let mut tree = SpecTree::with_root(3);
            let mut frontier = vec![0usize];
            let budget = g.usize_in(1, 20);
            let mut added = 0;
            while added < budget && !frontier.is_empty() {
                let mut next = Vec::new();
                for &p in &frontier.clone() {
                    for _ in 0..g.usize_in(0, 4) {
                        if added >= budget {
                            break;
                        }
                        next.push(tree.add_child(p, 5, 0.0));
                        added += 1;
                    }
                }
                frontier = next;
            }
            let s = tree.num_slots().next_power_of_two().max(8);
            let tens = Tensorized::from_tree(&tree, s, true).unwrap();
            let t = g.usize_in(0, CAP);
            let win = if g.bool_p(0.5) { Some(g.usize_in(4, CAP)) } else { None };
            let (mut a, mut b) = (Vec::new(), Vec::new());
            mb.build_dense(&mut a, &tens, t, win);
            mb.build_table(&mut b, &tens, t, win);
            assert_eq!(a, b, "builders diverged");
            // ancestor predicate cross-check against tree walk
            let w = mb.width(s);
            for k in 0..tens.live {
                for j in 0..tens.live {
                    let expect = tree.ancestors(k).contains(&j);
                    assert_eq!(a[k * w + CAP + j] == 0.0, expect, "anc({j},{k})");
                }
            }
        });
    }
}
