//! Tree attention mask construction (paper §2.4, §3.3).
//!
//! Produces the `[S, cap + S]` additive row mask the AOT modules consume:
//! columns `[0, cap)` address the committed-prefix cache, columns
//! `[cap, cap+S)` the speculative block. Cache columns are **logical**
//! sequence rows and the prefix length `t` is the logical committed
//! length ([`crate::cache::KvStore::len`]) — never a physical storage
//! coordinate: under the paged layout the backend resolves each open
//! column through the block table
//! ([`crate::backend::KvView::row_start`]), so mask construction is
//! layout-agnostic by design. Row `k` opens:
//!
//!   * prefix columns `[lo, t)` where `lo = max(0, t - W)` under a drafter
//!     window `W` (E4 truncation; teacher masks always use `lo = 0`);
//!   * speculative column `j` iff `Anc(j, k)` and both slots are valid.
//!
//! Padded slots are force-masked in *both* directions ("no leakage to
//! padded slots", §3.3). Two full builders produce bit-identical output:
//! the dense ancestor-walk (reference) and the ancestor-table builder
//! (used for larger budgets) — mirroring the paper's dense-vs-structured
//! mask note; `verify_path` benches compare their cost.
//!
//! # Incremental construction
//!
//! Rebuilding the full `[S, cap+S]` buffer every round costs
//! `O(S * (cap + S))` writes even though, between rounds, only two things
//! change: the committed prefix length `t` grows by the accepted tokens,
//! and the (small) speculative block takes a new tree shape. The
//! incremental path ([`MaskBuilder::chain_incremental`],
//! [`MaskBuilder::tree_incremental`], and the [`IncrementalMask`] slots
//! backing them) keeps one persistent buffer per (stream, S) and edits
//! only the delta:
//!
//!   * per-row prefix intervals `[lo, t)` are diffed against the previous
//!     round — cost `O(S * Δt)`;
//!   * the spec block is rewritten per round — cost `O(S * S)` (or `O(1)`
//!     for chain masks whose causal triangle shape repeats);
//!
//! turning per-round mask cost from `O(S * (cap + S))` into
//! `O(S * Δt + S * S)`. `build_dense`/`build_table` remain the reference
//! oracle; property tests assert bit-identical equivalence over random
//! build sequences (growing *and* shrinking prefixes, window toggling).
//!
//! # Batched mask block
//!
//! [`BatchMask`] assembles `B` per-request masks into one padded
//! `[B, S_max, cap + S_max]` block for a fused verification launch
//! (`docs/ARCHITECTURE.md` has the full contract). Key invariants:
//!
//! * request `b` owns rows `[b*S_max, (b+1)*S_max)`; each of its rows
//!   addresses *that request's own* KV cache in columns `[0, cap)` and
//!   its own speculative block in columns `[cap, cap + S_max)` — the
//!   block has no cross-request column space, so isolation is structural;
//! * a request padded from `S_req < S_max` keeps rows `[S_req, S_max)`
//!   and columns `[cap + S_req, cap + S_max)` fully closed ("padding is
//!   never attended"): [`BatchMask::begin`] closes everything, and
//!   [`BatchMask::fill_request`] only copies the request's own
//!   `[S_req, cap + S_req]` rows (a re-stride, since per-request row
//!   width is `cap + S_req` but the fused row width is `cap + S_max`);
//! * per-request masks keep coming from the *incremental* slots — the
//!   fused block is a bounded per-round copy on top, not a rebuild of
//!   the per-request masks.

use super::tensorize::Tensorized;
use crate::util::idx::udx;
use crate::config::contract::NEG_INF;
use std::collections::HashMap;
use std::fmt;

/// Independent incremental-state streams. Masks for different purposes
/// (teacher vs draft, chain vs tree vs custom frontier rows) evolve
/// against different prefix clocks; keying slots by stream keeps each
/// delta small.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MaskStream {
    /// Teacher chain masks (prefill chunks, baseline decode steps).
    TeacherChain,
    /// Teacher tree-verification masks.
    TeacherTree,
    /// Draft chain-refresh masks.
    DraftChain,
    /// Draft tree-frontier masks (custom per-row opens).
    DraftFrontier,
}

/// One persistent `[s, cap+s]` mask buffer with enough bookkeeping to be
/// edited incrementally and reverted exactly.
#[derive(Clone, Debug)]
pub struct IncrementalMask {
    cap: usize,
    s: usize,
    w: usize,
    buf: Vec<f32>,
    /// Open prefix interval `[row_lo[k], row_hi[k])` per row (prefix
    /// columns only, `< cap`).
    row_lo: Vec<usize>,
    row_hi: Vec<usize>,
    /// Rows whose spec block may contain opens written in "block" mode.
    spec_rows: usize,
    /// Signature of the current spec-block content, when it was produced
    /// by a shape-cacheable writer (chain triangles): `Some(live)`.
    spec_sig: Option<u64>,
    /// Individually recorded spec opens (custom/frontier mode).
    spec_opens: Vec<(u32, u32)>,
    /// Individually recorded extra opens at absolute row columns — used
    /// by the frontier mask for ancestor *branch rows*, which live in the
    /// cache region past the committed prefix. Must stay outside every
    /// row's tracked prefix interval (asserted in debug builds).
    extra_opens: Vec<(u32, u32)>,
}

impl IncrementalMask {
    fn new(cap: usize, s: usize) -> Self {
        Self {
            cap,
            s,
            w: cap + s,
            buf: vec![NEG_INF; s * (cap + s)],
            row_lo: vec![0; s],
            row_hi: vec![0; s],
            spec_rows: 0,
            spec_sig: None,
            // worst case per round: every row opens its full ancestor
            // chain — reserve once so recording never reallocates mid-run
            spec_opens: Vec::with_capacity(1024),
            extra_opens: Vec::with_capacity(1024),
        }
    }

    /// Block size this slot serves.
    pub fn s(&self) -> usize {
        self.s
    }

    /// The current mask contents.
    pub fn as_slice(&self) -> &[f32] {
        &self.buf
    }

    /// Set row `k`'s open prefix interval to `[lo, hi)` (`hi <= cap`),
    /// writing only the diff against the row's previous interval.
    pub fn set_prefix(&mut self, k: usize, lo: usize, hi: usize) {
        debug_assert!(hi <= self.cap && lo <= hi, "prefix interval [{lo},{hi}) out of range");
        let (olo, ohi) = (self.row_lo[k], self.row_hi[k]);
        if olo == lo && ohi == hi {
            return;
        }
        let row = &mut self.buf[k * self.w..k * self.w + self.cap];
        if lo >= ohi || hi <= olo {
            // disjoint (covers either side being empty)
            row[olo..ohi].fill(NEG_INF);
            row[lo..hi].fill(0.0);
        } else {
            // overlapping: adjust the two edges only
            match olo.cmp(&lo) {
                std::cmp::Ordering::Less => row[olo..lo].fill(NEG_INF),
                std::cmp::Ordering::Greater => row[lo..olo].fill(0.0),
                std::cmp::Ordering::Equal => {}
            }
            match ohi.cmp(&hi) {
                std::cmp::Ordering::Less => row[ohi..hi].fill(0.0),
                std::cmp::Ordering::Greater => row[hi..ohi].fill(NEG_INF),
                std::cmp::Ordering::Equal => {}
            }
        }
        self.row_lo[k] = lo;
        self.row_hi[k] = hi;
    }

    /// Close every recorded open outside the prefix intervals (block-mode
    /// spec rows, custom spec opens, and extra cache-column opens),
    /// restoring the mask to "prefix intervals only".
    pub fn clear_spec(&mut self) {
        for k in 0..self.spec_rows {
            self.buf[k * self.w + self.cap..(k + 1) * self.w].fill(NEG_INF);
        }
        self.spec_rows = 0;
        self.spec_sig = None;
        for &(k, j) in &self.spec_opens {
            self.buf[udx(k) * self.w + self.cap + udx(j)] = NEG_INF;
        }
        self.spec_opens.clear();
        for &(k, col) in &self.extra_opens {
            self.buf[udx(k) * self.w + udx(col)] = NEG_INF;
        }
        self.extra_opens.clear();
    }

    /// Open spec column `j` for row `k`, recording the edit for exact
    /// reversal by the next [`IncrementalMask::clear_spec`].
    pub fn open_spec(&mut self, k: usize, j: usize) {
        debug_assert!(k < self.s && j < self.s);
        self.buf[k * self.w + self.cap + j] = 0.0;
        self.spec_opens.push((k as u32, j as u32));
    }

    /// Open an absolute column `col` of row `k` (cache region), recording
    /// the edit for exact reversal. The column must lie outside the row's
    /// tracked prefix interval, or the revert would punch a hole in it.
    pub fn open_col(&mut self, k: usize, col: usize) {
        debug_assert!(k < self.s && col < self.w);
        debug_assert!(
            col >= self.row_hi[k] || col < self.row_lo[k],
            "extra open at {col} inside tracked prefix [{}, {})",
            self.row_lo[k],
            self.row_hi[k]
        );
        self.buf[k * self.w + col] = 0.0;
        self.extra_opens.push((k as u32, col as u32));
    }

    /// Write the causal chain triangle (row `i` sees spec slots `0..=i`)
    /// for `live` rows. Shape-cached: a repeated `live` is free.
    fn set_spec_chain(&mut self, live: usize) {
        if self.spec_sig == Some(live as u64)
            && self.spec_opens.is_empty()
            && self.extra_opens.is_empty()
        {
            return;
        }
        self.clear_spec();
        for i in 0..live {
            let off = i * self.w + self.cap;
            self.buf[off..off + i + 1].fill(0.0);
        }
        self.spec_rows = live;
        self.spec_sig = Some(live as u64);
    }

    /// Write the spec block for a tensorized tree: row `k` opens every
    /// valid ancestor column (per-row parent walk, `O(live * D_max)`).
    fn set_spec_tree(&mut self, tens: &Tensorized) {
        self.clear_spec();
        for k in 0..tens.live {
            if !tens.valid[k] {
                continue;
            }
            let off = k * self.w + self.cap;
            let mut cur = k;
            loop {
                if tens.valid[cur] {
                    self.buf[off + cur] = 0.0;
                }
                if cur == 0 {
                    break;
                }
                cur = udx(tens.parent[cur]);
            }
        }
        self.spec_rows = tens.live;
        self.spec_sig = None;
    }
}

/// One padded `[B, S_max, cap + S_max]` fused mask block (see the module
/// docs for the batching invariants). The buffer persists across rounds
/// and only ever grows, so steady-state assembly is allocation-free.
#[derive(Clone, Debug)]
pub struct BatchMask {
    cap: usize,
    batch: usize,
    s_max: usize,
    buf: Vec<f32>,
}

impl BatchMask {
    /// An empty block for caches of capacity `cap`.
    pub fn new(cap: usize) -> Self {
        Self { cap, batch: 0, s_max: 0, buf: Vec::new() }
    }

    /// Start a round: size the block for `batch` requests padded to
    /// `s_max` slots and close every column ("padding is never attended"
    /// holds for anything `fill_request` does not explicitly reopen).
    pub fn begin(&mut self, batch: usize, s_max: usize) {
        self.batch = batch;
        self.s_max = s_max;
        let n = batch * s_max * (self.cap + s_max);
        // clear + resize writes NEG_INF into every live element while
        // reusing the existing capacity (no allocation once warmed).
        self.buf.clear();
        self.buf.resize(n, NEG_INF);
    }

    /// Copy request `b`'s own `[s_req, cap + s_req]` mask into its row
    /// block, re-striding from per-request row width `cap + s_req` to the
    /// fused row width `cap + s_max`. Rows `[s_req, s_max)` and columns
    /// `[cap + s_req, cap + s_max)` stay closed from [`BatchMask::begin`].
    pub fn fill_request(&mut self, b: usize, req_mask: &[f32], s_req: usize) {
        assert!(b < self.batch, "request {b} out of batch {}", self.batch);
        assert!(s_req <= self.s_max, "s_req {s_req} exceeds s_max {}", self.s_max);
        let w_req = self.cap + s_req;
        assert_eq!(req_mask.len(), s_req * w_req, "request mask shape mismatch");
        let w = self.cap + self.s_max;
        for k in 0..s_req {
            let dst = (b * self.s_max + k) * w;
            let src = k * w_req;
            self.buf[dst..dst + w_req].copy_from_slice(&req_mask[src..src + w_req]);
        }
    }

    /// The assembled `[batch * s_max, cap + s_max]` block.
    pub fn as_slice(&self) -> &[f32] {
        &self.buf
    }

    /// Check the "padding is never attended" invariant for the current
    /// round: given each request's live padded variant `s_reqs[b]`, every
    /// padding row `[s_reqs[b], s_max)` and every padding column
    /// `[cap + s_reqs[b], cap + s_max)` of request `b`'s block must be
    /// fully closed. Continuous batching re-pads the block every tick as
    /// group membership changes; the fused verifier runs this check in
    /// release builds too (it is cheap: cost scales with the *padded*
    /// region, which is empty for a homogeneous group) so a stale open
    /// from a previous, larger round can never survive a
    /// [`BatchMask::begin`] — the first leak is reported as a typed
    /// [`PaddingLeak`] instead of corrupting a fused launch.
    pub fn check_padding_closed(&self, s_reqs: &[usize]) -> Result<(), PaddingLeak> {
        if s_reqs.len() != self.batch {
            return Err(PaddingLeak::BatchMismatch { expected: self.batch, got: s_reqs.len() });
        }
        let w = self.cap + self.s_max;
        for (b, &sr) in s_reqs.iter().enumerate() {
            if sr > self.s_max {
                return Err(PaddingLeak::WidthOverflow { b, s_req: sr, s_max: self.s_max });
            }
            for k in 0..self.s_max {
                let row = &self.buf[(b * self.s_max + k) * w..(b * self.s_max + k + 1) * w];
                // padding rows must be fully closed in both directions;
                // live rows only in their padded spec columns
                let (check, base) =
                    if k >= sr { (row, 0) } else { (&row[self.cap + sr..], self.cap + sr) };
                if let Some(j) = check.iter().position(|x| *x != NEG_INF) {
                    return Err(PaddingLeak::OpenCell {
                        b,
                        row: k,
                        col: base + j,
                        live_row: k < sr,
                    });
                }
            }
        }
        Ok(())
    }

    /// Boolean form of [`BatchMask::check_padding_closed`].
    pub fn padding_closed(&self, s_reqs: &[usize]) -> bool {
        self.check_padding_closed(s_reqs).is_ok()
    }

    /// Fused row width `cap + s_max` of the current round.
    pub fn width(&self) -> usize {
        self.cap + self.s_max
    }
}

/// A violated "padding is never attended" invariant
/// ([`BatchMask::check_padding_closed`]), located precisely enough to
/// debug the staging bug that caused it. Promoted from a debug-only
/// assert: an open padding cell in a fused launch corrupts *another
/// request's* logits, which is exactly the class of failure that must
/// not ship silently in release builds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PaddingLeak {
    /// The live-width list does not match the block's batch size.
    BatchMismatch {
        /// Batch size the block was begun with.
        expected: usize,
        /// Length of the `s_reqs` list handed to the check.
        got: usize,
    },
    /// A request claims more live slots than the block's padded width.
    WidthOverflow {
        /// Request index within the fused block.
        b: usize,
        /// The request's claimed live padded variant.
        s_req: usize,
        /// The block's padded width.
        s_max: usize,
    },
    /// A cell that must stay closed is open.
    OpenCell {
        /// Request index within the fused block.
        b: usize,
        /// Row within the request's `[s_max, cap + s_max]` block.
        row: usize,
        /// Column within that row (flat, `0..cap + s_max`).
        col: usize,
        /// Whether the row itself is live (leak in its padded spec
        /// columns) or a padding row (must be fully closed).
        live_row: bool,
    },
}

impl fmt::Display for PaddingLeak {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PaddingLeak::BatchMismatch { expected, got } => {
                write!(f, "s_reqs lists {got} requests but the block was begun with {expected}")
            }
            PaddingLeak::WidthOverflow { b, s_req, s_max } => {
                write!(f, "request {b} claims {s_req} live slots in a {s_max}-wide block")
            }
            PaddingLeak::OpenCell { b, row, col, live_row } => write!(
                f,
                "request {b} {} row {row} has an open cell at column {col}",
                if *live_row { "live" } else { "padding" }
            ),
        }
    }
}

impl std::error::Error for PaddingLeak {}

/// Reusable mask buffers + build strategies.
pub struct MaskBuilder {
    /// Committed-cache capacity (prefix column count of every mask).
    pub cache_cap: usize,
    /// Budget threshold above which the ancestor-table builder is used
    /// by [`MaskBuilder::build_auto`] (paper: "selects the mask
    /// construction strategy based on the speculative budget").
    pub table_threshold: usize,
    /// Persistent incremental slots, keyed by (stream, block size).
    slots: HashMap<(MaskStream, usize), IncrementalMask>,
}

impl MaskBuilder {
    /// A builder for caches of capacity `cache_cap` (no slots yet).
    pub fn new(cache_cap: usize) -> Self {
        Self { cache_cap, table_threshold: 64, slots: HashMap::new() }
    }

    /// Row width of a mask for block size `s`.
    pub fn width(&self, s: usize) -> usize {
        self.cache_cap + s
    }

    /// Reset + size `out` for block size `s`, all columns masked.
    fn prepare<'a>(&self, out: &'a mut Vec<f32>, s: usize) -> &'a mut [f32] {
        let n = s * self.width(s);
        out.clear();
        out.resize(n, NEG_INF);
        &mut out[..]
    }

    /// Open prefix columns `[lo, t)` for every valid row.
    fn open_prefix(&self, m: &mut [f32], tens: &Tensorized, t: usize, window: Option<usize>) {
        let w = self.width(tens.s);
        let lo = window.map_or(0, |win| t.saturating_sub(win));
        for k in 0..tens.live {
            if tens.valid[k] {
                m[k * w + lo..k * w + t].fill(0.0);
            }
        }
    }

    /// Dense builder: per-row ancestor walk (O(M * D_max) opens).
    pub fn build_dense(
        &self,
        out: &mut Vec<f32>,
        tens: &Tensorized,
        t: usize,
        window: Option<usize>,
    ) {
        let s = tens.s;
        let w = self.width(s);
        let m = self.prepare(out, s);
        self.open_prefix(m, tens, t, window);
        for k in 0..tens.live {
            if !tens.valid[k] {
                continue;
            }
            // walk the parent chain: self, parent, ..., root
            let mut cur = k;
            loop {
                if tens.valid[cur] {
                    m[k * w + self.cache_cap + cur] = 0.0;
                }
                if cur == 0 {
                    break;
                }
                cur = udx(tens.parent[cur]);
            }
        }
    }

    /// Ancestor-table builder: bitset visibility propagated parent->child
    /// in linearization order (O(M * S/64) words), then expanded to f32.
    pub fn build_table(
        &self,
        out: &mut Vec<f32>,
        tens: &Tensorized,
        t: usize,
        window: Option<usize>,
    ) {
        let s = tens.s;
        let w = self.width(s);
        let words = s.div_ceil(64);
        // visibility bitsets: vis[k] = vis[parent[k]] | bit(k)
        let mut vis = vec![0u64; tens.live * words];
        for k in 0..tens.live {
            if k > 0 {
                let p = udx(tens.parent[k]);
                let (lo, rest) = vis.split_at_mut(k * words);
                rest[..words].copy_from_slice(&lo[p * words..p * words + words]);
            }
            vis[k * words + k / 64] |= 1u64 << (k % 64);
        }
        let m = self.prepare(out, s);
        self.open_prefix(m, tens, t, window);
        for k in 0..tens.live {
            if !tens.valid[k] {
                continue;
            }
            let row = &mut m[k * w + self.cache_cap..k * w + self.cache_cap + s];
            for wd in 0..words {
                let mut bits = vis[k * words + wd];
                while bits != 0 {
                    let b = udx(bits.trailing_zeros());
                    let j = wd * 64 + b;
                    if tens.valid[j] {
                        row[j] = 0.0;
                    }
                    bits &= bits - 1;
                }
            }
        }
    }

    /// Strategy selection by budget (the paper's implementation note).
    pub fn build_auto(
        &self,
        out: &mut Vec<f32>,
        tens: &Tensorized,
        t: usize,
        window: Option<usize>,
    ) {
        if tens.live > self.table_threshold {
            self.build_table(out, tens, t, window)
        } else {
            self.build_dense(out, tens, t, window)
        }
    }

    /// Mask for a *causal chain* block (prefill chunks, baseline decode,
    /// draft chain refresh): `live` rows appended after prefix `t`, row i
    /// sees `[lo, t)` + chain slots `0..=i`. Full (non-incremental)
    /// reference form.
    pub fn build_chain(
        &self,
        out: &mut Vec<f32>,
        s: usize,
        live: usize,
        t: usize,
        window: Option<usize>,
    ) {
        let w = self.width(s);
        let n = s * w;
        out.clear();
        out.resize(n, NEG_INF);
        let lo = window.map_or(0, |win| t.saturating_sub(win));
        for i in 0..live {
            out[i * w + lo..i * w + t].fill(0.0);
            for j in 0..=i {
                out[i * w + self.cache_cap + j] = 0.0;
            }
        }
    }

    /// Persistent incremental slot for `(stream, s)`, created on first use.
    pub fn incremental(&mut self, stream: MaskStream, s: usize) -> &mut IncrementalMask {
        let cap = self.cache_cap;
        self.slots.entry((stream, s)).or_insert_with(|| IncrementalMask::new(cap, s))
    }

    /// Read-only view of an existing incremental slot (None if the
    /// `(stream, s)` slot was never built). Used by the batch scheduler
    /// to gather a request's current mask without mutating it.
    pub fn peek(&self, stream: MaskStream, s: usize) -> Option<&IncrementalMask> {
        self.slots.get(&(stream, s))
    }

    /// Incremental chain mask — bit-identical to [`MaskBuilder::build_chain`],
    /// at `O(live * Δt)` steady-state cost.
    pub fn chain_incremental(
        &mut self,
        stream: MaskStream,
        s: usize,
        live: usize,
        t: usize,
        window: Option<usize>,
    ) -> &[f32] {
        let lo = window.map_or(0, |win| t.saturating_sub(win));
        let slot = self.incremental(stream, s);
        for i in 0..s {
            if i < live {
                slot.set_prefix(i, lo, t);
            } else {
                slot.set_prefix(i, 0, 0);
            }
        }
        slot.set_spec_chain(live);
        slot.as_slice()
    }

    /// Incremental tree mask — bit-identical to [`MaskBuilder::build_dense`]
    /// (and [`build_auto`](MaskBuilder::build_auto)), at
    /// `O(S * Δt + S * S)` steady-state cost.
    pub fn tree_incremental(
        &mut self,
        stream: MaskStream,
        tens: &Tensorized,
        t: usize,
        window: Option<usize>,
    ) -> &[f32] {
        let lo = window.map_or(0, |win| t.saturating_sub(win));
        let slot = self.incremental(stream, tens.s);
        for k in 0..tens.s {
            if k < tens.live && tens.valid[k] {
                slot.set_prefix(k, lo, t);
            } else {
                slot.set_prefix(k, 0, 0);
            }
        }
        slot.set_spec_tree(tens);
        slot.as_slice()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::build::SpecTree;
    use crate::util::prop;

    const CAP: usize = 64; // small cap for test readability

    fn sample() -> Tensorized {
        let mut t = SpecTree::with_root(10);
        let a = t.add_child(0, 11, -0.1);
        let c = t.add_child(0, 13, -0.4);
        let b = t.add_child(a, 12, -0.2);
        t.add_child(c, 14, -0.6);
        let _ = b;
        Tensorized::from_tree(&t, 8, true).unwrap()
    }

    fn random_tree(g: &mut prop::Gen, budget: usize) -> SpecTree {
        let mut tree = SpecTree::with_root(3);
        let mut frontier = vec![0usize];
        let mut added = 0;
        while added < budget && !frontier.is_empty() {
            let mut next = Vec::new();
            for &p in &frontier.clone() {
                for _ in 0..g.usize_in(0, 4) {
                    if added >= budget {
                        break;
                    }
                    next.push(tree.add_child(p, 5, 0.0));
                    added += 1;
                }
            }
            frontier = next;
        }
        tree
    }

    fn open(m: &[f32], w: usize, k: usize, col: usize) -> bool {
        m[k * w + col] == 0.0
    }

    #[test]
    fn dense_mask_semantics() {
        let mb = MaskBuilder::new(CAP);
        let tens = sample();
        let mut m = Vec::new();
        mb.build_dense(&mut m, &tens, 10, None);
        let w = mb.width(8);
        // prefix open for valid rows
        assert!(open(&m, w, 0, 0) && open(&m, w, 0, 9));
        assert!(!open(&m, w, 0, 10)); // beyond committed length
        // root sees itself only in the spec block
        assert!(open(&m, w, 0, CAP));
        assert!(!open(&m, w, 0, CAP + 1));
        // node 3 (b, child of a) sees root, a, itself; not c
        assert!(open(&m, w, 3, CAP) && open(&m, w, 3, CAP + 1) && open(&m, w, 3, CAP + 3));
        assert!(!open(&m, w, 3, CAP + 2));
        // sibling isolation: c doesn't see a
        assert!(!open(&m, w, 2, CAP + 1));
        // padded rows fully masked
        for col in 0..w {
            assert!(!open(&m, w, 6, col));
        }
        // padded columns masked for all rows
        for k in 0..5 {
            assert!(!open(&m, w, k, CAP + 6));
        }
    }

    #[test]
    fn table_matches_dense() {
        let mb = MaskBuilder::new(CAP);
        let tens = sample();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        mb.build_dense(&mut a, &tens, 7, None);
        mb.build_table(&mut b, &tens, 7, None);
        assert_eq!(a, b);
    }

    #[test]
    fn window_truncates_prefix_only() {
        let mb = MaskBuilder::new(CAP);
        let tens = sample();
        let mut m = Vec::new();
        mb.build_dense(&mut m, &tens, 20, Some(5));
        let w = mb.width(8);
        assert!(!open(&m, w, 0, 14)); // outside window
        assert!(open(&m, w, 0, 15) && open(&m, w, 0, 19));
        assert!(open(&m, w, 0, CAP)); // spec self still open
    }

    #[test]
    fn chain_mask_causal() {
        let mb = MaskBuilder::new(CAP);
        let mut m = Vec::new();
        mb.build_chain(&mut m, 4, 3, 6, None);
        let w = mb.width(4);
        assert!(open(&m, w, 2, CAP + 2) && open(&m, w, 2, CAP) && !open(&m, w, 2, CAP + 3));
        assert!(!open(&m, w, 0, CAP + 1));
        // padded row 3 fully closed
        for col in 0..w {
            assert!(!open(&m, w, 3, col));
        }
    }

    #[test]
    fn incremental_chain_matches_full_across_growth() {
        let mut mb = MaskBuilder::new(CAP);
        let mut full = Vec::new();
        // grow t, vary live, toggle window, then shrink t (new conversation)
        for (s, live, t, win) in [
            (8usize, 1usize, 0usize, None),
            (8, 1, 5, None),
            (8, 3, 9, None),
            (8, 3, 9, Some(4)),
            (8, 2, 20, Some(4)),
            (8, 1, 2, None), // shrinking prefix (reset)
            (8, 8, 40, None),
        ] {
            mb.build_chain(&mut full, s, live, t, win);
            let inc = mb.chain_incremental(MaskStream::DraftChain, s, live, t, win);
            assert_eq!(inc, &full[..], "s={s} live={live} t={t} win={win:?}");
        }
    }

    #[test]
    fn incremental_tree_matches_dense() {
        let mut mb = MaskBuilder::new(CAP);
        let tens = sample();
        let mut full = Vec::new();
        for t in [3usize, 10, 10, 25, 4] {
            mb.build_dense(&mut full, &tens, t, None);
            let inc = mb.tree_incremental(MaskStream::TeacherTree, &tens, t, None);
            assert_eq!(inc, &full[..], "t={t}");
        }
    }

    #[test]
    fn incremental_custom_opens_revert_exactly() {
        let mut mb = MaskBuilder::new(CAP);
        let slot = mb.incremental(MaskStream::DraftFrontier, 4);
        slot.set_prefix(0, 0, 6);
        slot.open_spec(0, 0);
        slot.open_spec(0, 2);
        slot.open_col(0, 9); // ancestor branch row in the cache region
        assert!(slot.as_slice()[CAP] == 0.0 && slot.as_slice()[CAP + 2] == 0.0);
        assert!(slot.as_slice()[9] == 0.0);
        slot.clear_spec();
        slot.set_prefix(0, 0, 0);
        assert!(slot.as_slice().iter().all(|x| *x == NEG_INF));
    }

    #[test]
    fn property_builders_agree_on_random_trees() {
        let mb = MaskBuilder::new(CAP);
        prop::for_cases(100, 0xA5C3, |g| {
            let budget = g.usize_in(1, 20);
            let tree = random_tree(g, budget);
            let s = tree.num_slots().next_power_of_two().max(8);
            let tens = Tensorized::from_tree(&tree, s, true).unwrap();
            let t = g.usize_in(0, CAP);
            let win = if g.bool_p(0.5) { Some(g.usize_in(4, CAP)) } else { None };
            let (mut a, mut b) = (Vec::new(), Vec::new());
            mb.build_dense(&mut a, &tens, t, win);
            mb.build_table(&mut b, &tens, t, win);
            assert_eq!(a, b, "builders diverged");
            // ancestor predicate cross-check against tree walk
            let w = mb.width(s);
            for k in 0..tens.live {
                for j in 0..tens.live {
                    let expect = tree.ancestors(k).contains(&j);
                    assert_eq!(a[k * w + CAP + j] == 0.0, expect, "anc({j},{k})");
                }
            }
        });
    }

    #[test]
    fn property_incremental_matches_dense_on_random_sequences() {
        // The tentpole equivalence claim: against ONE long-lived builder,
        // a random sequence of tree builds (random shapes, growing and
        // shrinking prefixes, window changes) is bit-identical to a fresh
        // full rebuild at every step. >= 100 random trees total.
        let mut mb = MaskBuilder::new(CAP);
        let mut t_cur = 0usize;
        let mut full = Vec::new();
        prop::for_cases(120, 0x1C4E, |g| {
            let budget = g.usize_in(1, 20);
            let tree = random_tree(g, budget);
            let s = tree.num_slots().next_power_of_two().max(8);
            let tens = Tensorized::from_tree(&tree, s, true).unwrap();
            // mostly-growing prefix with occasional resets (new conv)
            t_cur = if g.bool_p(0.15) {
                g.usize_in(0, 8)
            } else {
                (t_cur + g.usize_in(0, 6)).min(CAP)
            };
            let win = if g.bool_p(0.3) { Some(g.usize_in(4, CAP)) } else { None };
            mb.build_dense(&mut full, &tens, t_cur, win);
            let inc = mb.tree_incremental(MaskStream::TeacherTree, &tens, t_cur, win);
            assert_eq!(inc, &full[..], "s={s} t={t_cur} win={win:?}");
        });
    }

    #[test]
    fn peek_returns_existing_slot_only() {
        let mut mb = MaskBuilder::new(CAP);
        assert!(mb.peek(MaskStream::TeacherTree, 8).is_none());
        mb.incremental(MaskStream::TeacherTree, 8);
        assert_eq!(mb.peek(MaskStream::TeacherTree, 8).unwrap().s(), 8);
        assert!(mb.peek(MaskStream::TeacherChain, 8).is_none());
    }

    #[test]
    fn batch_mask_restrides_requests_and_closes_padding() {
        let mut mb = MaskBuilder::new(CAP);
        let tens = sample(); // s_req = 8
        let mut req8 = Vec::new();
        mb.build_dense(&mut req8, &tens, 10, None);
        let mut req_chain = Vec::new();
        mb.build_chain(&mut req_chain, 8, 2, 3, None);

        let mut bm = BatchMask::new(CAP);
        bm.begin(2, 16); // pad both to S_max = 16
        bm.fill_request(0, &req8, 8);
        bm.fill_request(1, &req_chain, 8);
        let w = bm.width();
        assert_eq!(w, CAP + 16);
        let m = bm.as_slice();
        assert_eq!(m.len(), 2 * 16 * w);

        // request 0 rows/cols map exactly onto the per-request mask
        let w_req = CAP + 8;
        for k in 0..8 {
            for c in 0..w_req {
                assert_eq!(m[k * w + c], req8[k * w_req + c], "req0 row {k} col {c}");
            }
            // padded spec columns [cap+8, cap+16) stay closed
            for c in CAP + 8..w {
                assert_eq!(m[k * w + c], NEG_INF, "req0 padded col {c}");
            }
        }
        // padding rows [8, 16) of request 0 fully closed
        for k in 8..16 {
            assert!(m[k * w..(k + 1) * w].iter().all(|x| *x == NEG_INF), "req0 pad row {k}");
        }
        // request 1 block starts at row 16
        for k in 0..8 {
            for c in 0..w_req {
                assert_eq!(m[(16 + k) * w + c], req_chain[k * w_req + c], "req1 row {k} col {c}");
            }
        }
        for k in 8..16 {
            assert!(
                m[(16 + k) * w..(16 + k + 1) * w].iter().all(|x| *x == NEG_INF),
                "req1 pad row {k}"
            );
        }
    }

    #[test]
    fn batch_mask_padding_closed_tracks_membership_changes() {
        // Continuous batching: group membership (and with it B and S_max)
        // changes between rounds; every re-pad must leave padding fully
        // closed, and the checker must catch a leaked open.
        let mut mb = MaskBuilder::new(CAP);
        let tens = sample(); // s_req = 8
        let mut req8 = Vec::new();
        mb.build_dense(&mut req8, &tens, 10, None);
        let mut req_chain = Vec::new();
        mb.build_chain(&mut req_chain, 8, 3, 5, None);

        let mut bm = BatchMask::new(CAP);
        // round 1: wide group, everything open somewhere
        bm.begin(3, 16);
        bm.fill_request(0, &req8, 8);
        bm.fill_request(1, &req_chain, 8);
        bm.fill_request(2, &req8, 8);
        assert!(bm.padding_closed(&[8, 8, 8]));
        // round 2: a straggler retired and a new conversation admitted —
        // smaller batch, same re-padded width
        bm.begin(2, 16);
        bm.fill_request(0, &req_chain, 8);
        bm.fill_request(1, &req8, 8);
        assert!(bm.padding_closed(&[8, 8]));
        // wrong live counts are rejected
        assert!(!bm.padding_closed(&[8]), "batch size mismatch must fail");
        assert!(!bm.padding_closed(&[8, 17]), "s_req > s_max must fail");
        // a leaked open in a padding row must be caught
        let w = bm.width();
        let idx = (16 + 12) * w + 3; // request 1, padding row 12
        let mut leaked = bm.clone();
        leaked.buf[idx] = 0.0;
        assert!(!leaked.padding_closed(&[8, 8]), "leaked padding row open not caught");
        // ... and a leaked open in a live row's padded spec columns too
        let idx2 = (16 + 2) * w + CAP + 10; // request 1, live row 2, col cap+10
        let mut leaked2 = bm;
        leaked2.buf[idx2] = 0.0;
        assert!(!leaked2.padding_closed(&[8, 8]), "leaked padded column open not caught");
    }

    #[test]
    fn batch_mask_begin_resets_previous_round() {
        let mut mb = MaskBuilder::new(CAP);
        let mut req = Vec::new();
        mb.build_chain(&mut req, 8, 8, CAP, None); // everything open
        let mut bm = BatchMask::new(CAP);
        bm.begin(1, 8);
        bm.fill_request(0, &req, 8);
        assert!(bm.as_slice().iter().any(|x| *x == 0.0));
        // next round, smaller batch: every element closed again
        bm.begin(1, 8);
        assert!(bm.as_slice().iter().all(|x| *x == NEG_INF));
    }

    #[test]
    fn property_incremental_chain_random_sequences() {
        let mut mb = MaskBuilder::new(CAP);
        let mut t_cur = 0usize;
        let mut full = Vec::new();
        prop::for_cases(120, 0xC4A1, |g| {
            let s = *g.choose(&[4usize, 8, 16]);
            let live = g.usize_in(1, s + 1);
            t_cur = if g.bool_p(0.15) {
                0
            } else {
                (t_cur + g.usize_in(0, 5)).min(CAP)
            };
            let win = if g.bool_p(0.3) { Some(g.usize_in(4, CAP)) } else { None };
            mb.build_chain(&mut full, s, live, t_cur, win);
            let inc = mb.chain_incremental(MaskStream::DraftChain, s, live, t_cur, win);
            assert_eq!(inc, &full[..], "s={s} live={live} t={t_cur} win={win:?}");
        });
    }
}
