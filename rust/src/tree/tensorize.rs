//! Tree tensorization with accelerator-safe (sentinel-free) indexing —
//! the paper's §3.2 contribution, verbatim:
//!
//! * **Dummy-root shift**: the root occupies index 0 and every parent
//!   pointer lives in `[0, M]`; a sentinel `-1` value never exists, so
//!   every device-side gather is in-bounds *by construction*.
//! * **Ancestor table** `A[l, k]`: `A[0,k] = k`, `A[l+1,k] = parent(A[l,k])`
//!   — bounded, in-range, and reusable for mask construction and
//!   path-feature gathers.
//! * **Padding + validity**: slots `>= live` carry device-defined values
//!   (`parent = 0`, `depth = 0`, `token = pad`) and `valid = false`; the
//!   mask builder force-masks them so they cannot influence acceptance.
//! * **Structural invariants** (§3.2 items 1-3) checked before launch:
//!   range, acyclicity/depth-consistency, validity closure. Violations
//!   return a structured error that flows into a trace failure dump
//!   instead of undefined device behaviour.

use super::build::SpecTree;
use crate::config::contract::PAD_ID;
use crate::util::idx::udx;
use std::fmt;

/// Structured §3.2 invariant violations (unit-testable, dump-friendly).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum InvariantViolation {
    /// parent[k] outside [0, live).
    Range { slot: usize, parent: usize, live: usize },
    /// depth[parent[k]] >= depth[k] for a non-root slot.
    DepthOrder { slot: usize, depth: usize, parent_depth: usize },
    /// Repeated parent application failed to reach the root within
    /// depth[k] steps.
    Unrooted { slot: usize },
    /// A valid slot has an invalid (padded) parent.
    ValidityClosure { slot: usize, parent: usize },
    /// Root slot malformed (depth != 0 or parent != 0).
    BadRoot,
    /// A token id outside the vocabulary.
    TokenRange { slot: usize, token: i32 },
}

impl fmt::Display for InvariantViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Range { slot, parent, live } => {
                write!(f, "range: parent[{slot}] = {parent} outside [0, {live})")
            }
            Self::DepthOrder { slot, depth, parent_depth } => write!(
                f,
                "depth-order: depth[parent[{slot}]] = {parent_depth} >= depth[{slot}] = {depth}"
            ),
            Self::Unrooted { slot } => {
                write!(f, "acyclicity: slot {slot} does not reach root within depth steps")
            }
            Self::ValidityClosure { slot, parent } => {
                write!(f, "validity-closure: valid slot {slot} has padded parent {parent}")
            }
            Self::BadRoot => write!(f, "slot 0 is not a well-formed root"),
            Self::TokenRange { slot, token } => {
                write!(f, "token-range: tokens[{slot}] = {token} outside vocab")
            }
        }
    }
}

impl std::error::Error for InvariantViolation {}

/// Linearized, padded, gather-safe tree arrays (paper §3.2).
#[derive(Clone, Debug)]
pub struct Tensorized {
    /// Padded slot count (a compiled S variant).
    pub s: usize,
    /// Live slots (root + M nodes); `live <= s`.
    pub live: usize,
    /// `[s]` token ids; padded slots hold `PAD_ID`.
    pub tokens: Vec<i32>,
    /// `[s]` shifted parent indices in `[0, live)`; `parent[0] == 0`
    /// (dummy-root self-reference) and padded slots point at 0.
    pub parent: Vec<u32>,
    /// `[s]` depths; root 0, padded slots 0.
    pub depth: Vec<u32>,
    /// `[s]` validity mask.
    pub valid: Vec<bool>,
    /// Ancestor table, row-major `[(dmax+1) * s]`: `anc[l*s + k] = A[l,k]`.
    /// Entries saturate at the root (0), staying in-range everywhere.
    pub ancestors: Vec<u32>,
    /// Max live depth D_max.
    pub dmax: usize,
}

impl Tensorized {
    /// Tensorize `tree` into `s_pad` slots. `s_pad` must be a compiled
    /// variant >= `tree.num_slots()`; `checked` runs the §3.2 invariant
    /// validation (the production default — benches may disable it to
    /// measure its cost).
    pub fn from_tree(tree: &SpecTree, s_pad: usize, checked: bool)
        -> Result<Self, InvariantViolation> {
        let live = tree.num_slots();
        assert!(live <= s_pad, "tree has {live} slots, variant holds {s_pad}");
        let mut tokens = vec![PAD_ID; s_pad];
        let mut parent = vec![0u32; s_pad];
        let mut depth = vec![0u32; s_pad];
        let mut valid = vec![false; s_pad];
        let mut dmax = 0usize;
        for (k, n) in tree.slots().iter().enumerate() {
            tokens[k] = n.token;
            parent[k] = n.parent as u32;
            depth[k] = n.depth as u32;
            valid[k] = true;
            dmax = dmax.max(n.depth);
        }
        // Ancestor table A: A[0,k] = k; A[l+1,k] = parent(A[l,k]).
        let rows = dmax + 1;
        let mut ancestors = vec![0u32; rows * s_pad];
        for k in 0..s_pad {
            ancestors[k] = k as u32;
        }
        for l in 0..dmax {
            for k in 0..s_pad {
                let up = udx(ancestors[l * s_pad + k]);
                ancestors[(l + 1) * s_pad + k] = parent[up.min(s_pad - 1)];
            }
        }
        let t = Self { s: s_pad, live, tokens, parent, depth, valid, ancestors, dmax };
        if checked {
            t.check_invariants()?;
        }
        Ok(t)
    }

    /// §3.2 structural invariants. Cheap relative to a teacher forward
    /// (O(M * D_max)); run before every launch in production mode.
    pub fn check_invariants(&self) -> Result<(), InvariantViolation> {
        if self.live == 0 || self.depth[0] != 0 || self.parent[0] != 0 {
            return Err(InvariantViolation::BadRoot);
        }
        for k in 0..self.s {
            let p = udx(self.parent[k]);
            // 1. Range: every parent pointer in-bounds (live region).
            if p >= self.live.max(1) {
                return Err(InvariantViolation::Range { slot: k, parent: p, live: self.live });
            }
            if k >= self.live {
                // Padded slots: device-defined values only.
                if self.valid[k] {
                    return Err(InvariantViolation::ValidityClosure { slot: k, parent: p });
                }
                continue;
            }
            if !(0..512).contains(&self.tokens[k]) {
                return Err(InvariantViolation::TokenRange { slot: k, token: self.tokens[k] });
            }
            if k == 0 {
                continue;
            }
            // 2. Depth consistency + acyclicity.
            if self.depth[p] >= self.depth[k] {
                return Err(InvariantViolation::DepthOrder {
                    slot: k,
                    depth: udx(self.depth[k]),
                    parent_depth: udx(self.depth[p]),
                });
            }
            let mut cur = k;
            let mut steps = 0usize;
            while cur != 0 {
                cur = udx(self.parent[cur]);
                steps += 1;
                if steps > udx(self.depth[k]) {
                    return Err(InvariantViolation::Unrooted { slot: k });
                }
            }
            // 3. Validity closure.
            if self.valid[k] && !self.valid[p] {
                return Err(InvariantViolation::ValidityClosure { slot: k, parent: p });
            }
        }
        Ok(())
    }

    /// Ancestor predicate via the table: is `j` an ancestor of `k`
    /// (including `j == k`)? Mirrors the paper's Anc(j, k) definition.
    pub fn is_ancestor(&self, j: usize, k: usize) -> bool {
        for l in 0..=self.dmax {
            if udx(self.ancestors[l * self.s + k]) == j {
                return true;
            }
        }
        false
    }

    /// Per-slot RoPE positions for a committed prefix of length `t`:
    /// root sits at `t`, a depth-d node at `t + d`. Padded slots get `t`
    /// (masked, value irrelevant but in-range — device-defined padding).
    pub fn positions(&self, t: usize) -> Vec<i32> {
        let mut out = Vec::new();
        self.positions_into(t, &mut out);
        out
    }

    /// Allocation-free form of [`Tensorized::positions`]: writes into a
    /// caller-reused buffer (the engine's hot path).
    pub fn positions_into(&self, t: usize, out: &mut Vec<i32>) {
        out.clear();
        out.extend((0..self.s).map(|k| {
            if self.valid[k] {
                (t + udx(self.depth[k])) as i32
            } else {
                t as i32
            }
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::build::SpecTree;
    use crate::util::prop;

    fn sample_tree() -> SpecTree {
        let mut t = SpecTree::with_root(10);
        let a = t.add_child(0, 11, -0.1);
        let c = t.add_child(0, 13, -0.4);
        let b = t.add_child(a, 12, -0.2);
        t.add_child(c, 14, -0.6);
        t.add_child(b, 15, -0.8);
        t
    }

    #[test]
    fn arrays_are_sentinel_free() {
        let t = Tensorized::from_tree(&sample_tree(), 8, true).unwrap();
        assert_eq!(t.live, 6);
        assert!(t.parent.iter().all(|p| (*p as usize) < t.live));
        assert!(t.ancestors.iter().all(|a| (*a as usize) < t.s));
        assert_eq!(t.tokens[6], PAD_ID);
        assert!(!t.valid[6]);
    }

    #[test]
    fn ancestor_table_matches_walk() {
        let tree = sample_tree();
        let t = Tensorized::from_tree(&tree, 8, true).unwrap();
        for k in 0..t.live {
            for j in 0..t.live {
                let walk = tree.ancestors(k).contains(&j);
                assert_eq!(t.is_ancestor(j, k), walk, "anc({j},{k})");
            }
        }
        // padded slot is its own ancestor chain to root
        assert!(t.is_ancestor(0, 7) || t.is_ancestor(7, 7));
    }

    #[test]
    fn positions_offset_by_depth() {
        let t = Tensorized::from_tree(&sample_tree(), 8, true).unwrap();
        let pos = t.positions(100);
        assert_eq!(pos[0], 100); // root
        assert_eq!(pos[1], 101); // depth 1
        assert_eq!(pos[5], 103); // depth 3
        assert_eq!(pos[7], 100); // padded
    }

    #[test]
    fn detects_range_violation() {
        let mut t = Tensorized::from_tree(&sample_tree(), 8, true).unwrap();
        t.parent[2] = 7; // points into padding
        assert!(matches!(t.check_invariants(), Err(InvariantViolation::Range { .. })));
    }

    #[test]
    fn detects_cycle_as_depth_violation() {
        let mut t = Tensorized::from_tree(&sample_tree(), 8, true).unwrap();
        // 3 <-> 1 cycle: parent[1] = 3 while depth says 1 is shallower
        t.parent[1] = 3;
        assert!(matches!(t.check_invariants(), Err(InvariantViolation::DepthOrder { .. })));
    }

    #[test]
    fn detects_validity_closure_violation() {
        let mut t = Tensorized::from_tree(&sample_tree(), 8, true).unwrap();
        t.valid[7] = true; // padded slot claims validity
        assert!(matches!(t.check_invariants(), Err(InvariantViolation::ValidityClosure { .. })));
    }

    #[test]
    fn detects_bad_root() {
        let mut t = Tensorized::from_tree(&sample_tree(), 8, true).unwrap();
        t.depth[0] = 1;
        assert_eq!(t.check_invariants(), Err(InvariantViolation::BadRoot));
    }

    #[test]
    fn property_random_trees_always_pass_checks() {
        prop::for_cases(200, 0x7ee1, |g| {
            let mut tree = SpecTree::with_root(g.usize_in(2, 512) as i32);
            let budget = g.usize_in(1, 24);
            // depth-synchronous random growth
            let mut frontier = vec![0usize];
            let mut added = 0;
            while added < budget && !frontier.is_empty() {
                let mut next = Vec::new();
                for &p in &frontier {
                    let kids = g.usize_in(0, 4);
                    for _ in 0..kids {
                        if added >= budget {
                            break;
                        }
                        let slot = tree.add_child(p, g.usize_in(2, 512) as i32, -0.5);
                        next.push(slot);
                        added += 1;
                    }
                }
                frontier = next;
            }
            let s_pad = tree.num_slots().next_power_of_two().max(8);
            let t = Tensorized::from_tree(&tree, s_pad, true).unwrap();
            t.check_invariants().unwrap();
            // dummy-root: all gathers in range
            assert!(t.parent.iter().all(|p| (*p as usize) < t.live));
        });
    }
}
