//! Speculative tree structure (paper §2.3).
//!
//! Slots are linearized parent-before-child (the draft expands depth-
//! synchronously, so BFS order holds by construction). Slot 0 is the
//! *root*: the pending token whose KV the teacher has not yet computed —
//! it rides along in the verification call at depth 0. Draft proposals
//! occupy slots `1..=M`.

/// One node of the speculative tree.
#[derive(Clone, Debug, PartialEq)]
pub struct SpecNode {
    /// Proposed token id (root: the pending committed token).
    pub token: i32,
    /// Parent slot index. The root self-references 0 — the paper's
    /// "dummy-root" convention: no sentinel value ever exists.
    pub parent: usize,
    /// Edges from the root (root = 0).
    pub depth: usize,
    /// Cumulative draft log-probability along the path (root = 0).
    pub logprob: f64,
}

/// Rooted speculative tree with BFS-ordered slots.
#[derive(Clone, Debug)]
pub struct SpecTree {
    slots: Vec<SpecNode>,
}

impl SpecTree {
    /// A tree holding only the pending root token.
    pub fn with_root(token: i32) -> Self {
        Self { slots: vec![SpecNode { token, parent: 0, depth: 0, logprob: 0.0 }] }
    }

    /// Append a child under `parent` (must already exist and respect BFS
    /// order — children are only added to the current deepest frontier).
    pub fn add_child(&mut self, parent: usize, token: i32, logprob: f64) -> usize {
        assert!(parent < self.slots.len(), "parent slot {parent} out of range");
        let depth = self.slots[parent].depth + 1;
        assert!(
            self.slots.last().map_or(true, |last| depth >= last.depth),
            "children must be appended depth-synchronously (BFS order)"
        );
        self.slots.push(SpecNode { token, parent, depth, logprob });
        self.slots.len() - 1
    }

    /// All slots including the root.
    pub fn slots(&self) -> &[SpecNode] {
        &self.slots
    }

    /// Number of speculative nodes M (excluding the root).
    pub fn num_nodes(&self) -> usize {
        self.slots.len() - 1
    }

    /// Total slots (root + nodes) — the S the verification call must hold.
    pub fn num_slots(&self) -> usize {
        self.slots.len()
    }

    /// Deepest node depth in the tree (root = 0).
    pub fn max_depth(&self) -> usize {
        self.slots.iter().map(|n| n.depth).max().unwrap_or(0)
    }

    /// Child slots of `slot`, in insertion (= draft preference) order.
    pub fn children(&self, slot: usize) -> impl Iterator<Item = usize> + '_ {
        self.slots
            .iter()
            .enumerate()
            .skip(1)
            .filter(move |(i, n)| n.parent == slot && *i != slot)
            .map(|(i, _)| i)
    }

    /// Ancestor chain of `slot` up to (and including) the root, nearest
    /// first. The root yields `[0]`.
    pub fn ancestors(&self, slot: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.slots[slot].depth + 1);
        let mut cur = slot;
        loop {
            out.push(cur);
            if cur == 0 {
                break;
            }
            cur = self.slots[cur].parent;
        }
        out
    }

    /// Root-to-slot token path (paper's `path(u)`), excluding the root.
    pub fn token_path(&self, slot: usize) -> Vec<i32> {
        let mut chain = self.ancestors(slot);
        chain.reverse();
        chain.into_iter().skip(1).map(|s| self.slots[s].token).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SpecTree {
        // root -> a(1) -> b(2) ; root -> c(3) ; b -> d(4)
        let mut t = SpecTree::with_root(10);
        let a = t.add_child(0, 11, -0.1);
        let c = t.add_child(0, 13, -0.5);
        let b = t.add_child(a, 12, -0.3);
        let _d = t.add_child(b, 14, -0.9);
        assert_eq!(c, 2);
        t
    }

    #[test]
    fn bfs_order_and_depths() {
        let t = sample();
        assert_eq!(t.num_nodes(), 4);
        assert_eq!(t.slots()[3].depth, 2);
        assert_eq!(t.max_depth(), 3);
    }

    #[test]
    fn ancestors_nearest_first() {
        let t = sample();
        assert_eq!(t.ancestors(4), vec![4, 3, 1, 0]);
        assert_eq!(t.ancestors(0), vec![0]);
    }

    #[test]
    fn token_path_excludes_root() {
        let t = sample();
        assert_eq!(t.token_path(4), vec![11, 12, 14]);
        assert_eq!(t.token_path(0), Vec::<i32>::new());
    }

    #[test]
    fn children_in_insertion_order() {
        let t = sample();
        let kids: Vec<usize> = t.children(0).collect();
        assert_eq!(kids, vec![1, 2]);
    }

    #[test]
    #[should_panic(expected = "BFS order")]
    fn rejects_out_of_order_insertion() {
        let mut t = SpecTree::with_root(1);
        let a = t.add_child(0, 2, 0.0);
        let b = t.add_child(a, 3, 0.0);
        let _ = b;
        // depth-1 child after a depth-2 child violates BFS
        t.add_child(0, 4, 0.0);
    }
}
