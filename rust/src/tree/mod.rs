//! Accelerator-safe speculative-tree machinery (paper §3.2).
//!
//! * [`build`] — the speculative tree the draft expands (slot 0 is the
//!   pending root token; nodes 1..=M are draft proposals);
//! * [`tensorize`] — linearization into device arrays with dummy-root
//!   (sentinel-free) indexing, ancestor tables, padding/validity, and the
//!   unit-testable structural invariants of §3.2;
//! * [`mask`] — tree attention mask construction (§2.4/§3.3): dense
//!   ancestor-walk builder and the ancestor-table/bitset builder for
//!   large budgets, both emitting the `[S, cap+S]` additive row layout
//!   the AOT modules expect.

pub mod build;
pub mod mask;
pub mod tensorize;

pub use build::{SpecNode, SpecTree};
pub use mask::{BatchMask, IncrementalMask, MaskBuilder, MaskStream, PaddingLeak};
pub use tensorize::{InvariantViolation, Tensorized};
