//! Typed configuration-contract errors.
//!
//! Every config-contract violation the CLI can produce used to be a
//! stringly `anyhow::bail!`; callers could only match on substrings.
//! [`ConfigError`] gives each contract a variant — tests match on the
//! variant, humans read the same message text as before (the `Display`
//! impl preserves the exact historical strings, which the flag-naming
//! regression tests in `cli::commands` pin down).
//!
//! The enum converts into `anyhow::Error` through `std::error::Error`,
//! so existing `?`-based plumbing is unchanged.

/// A configuration contract violation (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub enum ConfigError {
    /// The speculative tree shape is out of range (budget, depth or
    /// branching); carries the specific message.
    Tree(String),
    /// `max_new_tokens` was 0.
    ZeroMaxNew,
    /// `--draft-window` below the 4-token grammar-context minimum.
    DraftWindowTooSmall {
        /// The rejected window.
        window: usize,
    },
    /// `--temperature` outside `0.0..=2.0`.
    TemperatureOutOfRange {
        /// The rejected temperature.
        temperature: f64,
    },
    /// `--prefix-sharing on` without `--cache-layout paged`.
    PrefixSharingRequiresPaged,
    /// `--adaptive-occupancy on` without `--adaptive`.
    OccupancyRequiresAdaptive,
    /// `--slo-action` given without `--slo-ms`.
    SloActionWithoutDeadline,
    /// An `on|off` toggle flag received something else.
    BadToggle {
        /// Flag name without the leading dashes (e.g. `pipelining`).
        flag: &'static str,
        /// The rejected value.
        got: String,
    },
    /// `--workers 0` (a topology needs at least one engine worker).
    ZeroWorkers,
    /// `--turns 0` (a conversation has at least one turn).
    ZeroTurns,
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Tree(msg) => write!(f, "{msg}"),
            ConfigError::ZeroMaxNew => write!(f, "max_new_tokens must be > 0"),
            ConfigError::DraftWindowTooSmall { .. } => {
                write!(f, "draft window below 4 tokens cannot carry grammar context")
            }
            ConfigError::TemperatureOutOfRange { temperature } => {
                write!(f, "temperature out of range: {temperature}")
            }
            ConfigError::PrefixSharingRequiresPaged => write!(
                f,
                "config contract: --prefix-sharing requires --cache-layout paged \
                 (sharing maps pool blocks through block tables; flat buffers \
                 have no blocks to share)"
            ),
            ConfigError::OccupancyRequiresAdaptive => write!(
                f,
                "config contract: --adaptive-occupancy requires --adaptive \
                 (occupancy caps the adaptive controller; there is no \
                 controller to cap without it)"
            ),
            ConfigError::SloActionWithoutDeadline => write!(
                f,
                "config contract: --slo-action requires --slo-ms \
                 (an action without a deadline does nothing)"
            ),
            ConfigError::BadToggle { flag, got } => {
                write!(f, "unknown --{flag} value '{got}' (expected on|off)")
            }
            ConfigError::ZeroWorkers => write!(
                f,
                "config contract: --workers must be >= 1 (got 0) — \
                 one worker is the single-engine serving path"
            ),
            ConfigError::ZeroTurns => write!(
                f,
                "config contract: --turns must be >= 1 (got 0) — \
                 a conversation has at least one turn"
            ),
        }
    }
}

impl std::error::Error for ConfigError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_preserves_flag_naming_contracts() {
        // Flag-naming substrings are API: scripts and the CLI regression
        // tests grep for them.
        let cases: &[(ConfigError, &str)] = &[
            (ConfigError::PrefixSharingRequiresPaged, "--prefix-sharing"),
            (ConfigError::OccupancyRequiresAdaptive, "--adaptive-occupancy"),
            (ConfigError::SloActionWithoutDeadline, "--slo-action"),
            (ConfigError::SloActionWithoutDeadline, "--slo-ms"),
            (ConfigError::ZeroWorkers, "--workers"),
            (ConfigError::ZeroTurns, "--turns"),
            (
                ConfigError::BadToggle { flag: "pipelining", got: "maybe".into() },
                "--pipelining",
            ),
        ];
        for (err, needle) in cases {
            assert!(
                err.to_string().contains(needle),
                "{err:?} must name {needle}: {err}"
            );
        }
    }

    #[test]
    fn converts_into_anyhow() {
        fn takes_anyhow() -> anyhow::Result<()> {
            Err(ConfigError::ZeroMaxNew.into())
        }
        let err = takes_anyhow().unwrap_err();
        assert!(err.downcast_ref::<ConfigError>().is_some());
        assert_eq!(
            *err.downcast_ref::<ConfigError>().unwrap(),
            ConfigError::ZeroMaxNew
        );
    }
}
