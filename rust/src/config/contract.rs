//! The static-shape AOT contract shared with `python/compile/config.py`.

use crate::json::Json;
use anyhow::{bail, Context, Result};

/// Vocabulary size of the tiny models.
pub const VOCAB: usize = 512;
/// Padding token id.
pub const PAD_ID: i32 = 0;
/// Beginning-of-sequence token id.
pub const BOS_ID: i32 = 1;
/// First ordinary (non-special) token id.
pub const FIRST_TOKEN: i32 = 2;
/// Default KV-cache capacity (rows per layer).
pub const CACHE_CAP: usize = 1024;
/// EAGLE feature dimension (draft conditioning rows).
pub const FEAT_DIM: usize = 64;
/// Additive-mask "closed" value (matches the AOT modules).
pub const NEG_INF: f32 = -1.0e30;
/// Compiled teacher block sizes S.
pub const TEACHER_S_VARIANTS: &[usize] = &[8, 16, 32, 64, 128, 256];
/// Compiled draft block sizes S.
pub const DRAFT_S_VARIANTS: &[usize] = &[8, 32, 64];

/// Transformer dimensions of one role (teacher/draft).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Dims {
    /// Transformer layer count L.
    pub layers: usize,
    /// Model width (not used by the cache math; kept for the manifest).
    pub d_model: usize,
    /// Attention head count H.
    pub heads: usize,
    /// Per-head dimension Dh.
    pub d_head: usize,
}

impl Dims {
    /// Flat element count of a full KV cache buffer [L, C, H, Dh].
    pub fn cache_elems(&self, cap: usize) -> usize {
        self.layers * cap * self.heads * self.d_head
    }

    /// Elements of one sequence row across all layers [L, 1, H, Dh].
    pub fn row_elems(&self) -> usize {
        self.layers * self.heads * self.d_head
    }
}

/// Execution mode — the paper's two-mode protocol (§4.1):
/// `Fused` loads the Pallas-kernel artifacts (performance path),
/// `Eager` the pure-jnp ones (reference/debug path).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ExecMode {
    /// Pallas fused-kernel artifacts (performance path).
    Fused,
    /// Pure-jnp artifacts (reference/debug path).
    Eager,
}

impl ExecMode {
    /// Stable string form (manifests, artifact names).
    pub fn as_str(&self) -> &'static str {
        match self {
            ExecMode::Fused => "fused",
            ExecMode::Eager => "eager",
        }
    }

    /// Parse the string form (`fused` | `eager`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "fused" => Ok(ExecMode::Fused),
            "eager" => Ok(ExecMode::Eager),
            other => bail!("unknown exec mode '{other}' (expected fused|eager)"),
        }
    }
}

/// The full L2/L3 contract. `default()` mirrors python's config.py; when
/// artifacts are present, `from_manifest` cross-checks every field.
#[derive(Clone, Debug, PartialEq)]
pub struct Contract {
    /// Vocabulary size V.
    pub vocab: usize,
    /// KV-cache capacity (rows per layer).
    pub cache_cap: usize,
    /// EAGLE feature dimension F.
    pub feat_dim: usize,
    /// Teacher model dimensions.
    pub teacher: Dims,
    /// Draft model dimensions.
    pub draft: Dims,
    /// Compiled teacher block sizes, ascending.
    pub teacher_s: Vec<usize>,
    /// Compiled draft block sizes, ascending.
    pub draft_s: Vec<usize>,
    /// Additive-mask "closed" value the modules were compiled with.
    pub neg_inf: f32,
}

impl Default for Contract {
    fn default() -> Self {
        Self {
            vocab: VOCAB,
            cache_cap: CACHE_CAP,
            feat_dim: FEAT_DIM,
            teacher: Dims { layers: 4, d_model: 128, heads: 4, d_head: 32 },
            draft: Dims { layers: 1, d_model: 64, heads: 2, d_head: 32 },
            teacher_s: TEACHER_S_VARIANTS.to_vec(),
            draft_s: DRAFT_S_VARIANTS.to_vec(),
            neg_inf: NEG_INF,
        }
    }
}

impl Contract {
    /// Parse + validate the `contract` section of artifacts/manifest.json.
    pub fn from_manifest(manifest: &Json) -> Result<Self> {
        let c = manifest.get("contract").context("manifest missing 'contract'")?;
        let dims = |key: &str| -> Result<Dims> {
            let d = c.get(key).with_context(|| format!("contract missing '{key}'"))?;
            Ok(Dims {
                layers: d.get("layers").and_then(Json::as_usize).context("layers")?,
                d_model: d.get("d_model").and_then(Json::as_usize).context("d_model")?,
                heads: d.get("heads").and_then(Json::as_usize).context("heads")?,
                d_head: d.get("d_head").and_then(Json::as_usize).context("d_head")?,
            })
        };
        let usizes = |key: &str| -> Result<Vec<usize>> {
            Ok(c.get(key)
                .and_then(Json::as_arr)
                .with_context(|| format!("contract missing '{key}'"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect())
        };
        let got = Self {
            vocab: c.get("vocab").and_then(Json::as_usize).context("vocab")?,
            cache_cap: c.get("cache_cap").and_then(Json::as_usize).context("cache_cap")?,
            feat_dim: c.get("feat_dim").and_then(Json::as_usize).context("feat_dim")?,
            teacher: dims("teacher")?,
            draft: dims("draft")?,
            teacher_s: usizes("teacher_s_variants")?,
            draft_s: usizes("draft_s_variants")?,
            neg_inf: c.get("neg_inf").and_then(Json::as_f64).context("neg_inf")? as f32,
        };
        if got.teacher_s.is_empty() || got.draft_s.is_empty() {
            bail!(
                "manifest contract must list at least one compiled S variant per role \
                 (teacher_s_variants: {:?}, draft_s_variants: {:?})",
                got.teacher_s,
                got.draft_s
            );
        }
        // cache capacity is a build-time knob carried by the manifest
        // (EAGLE_CACHE_CAP); everything else must match this crate.
        if got.cache_cap < 256 || got.cache_cap % 128 != 0 {
            bail!("manifest cache_cap {} must be a multiple of 128 and >= 256", got.cache_cap);
        }
        let expect = Self { cache_cap: got.cache_cap, ..Self::default() };
        if got != expect {
            bail!(
                "artifact manifest contract does not match the compiled-in contract:\n  \
                 manifest: {got:?}\n  expected: {expect:?}\n  \
                 (rebuild artifacts with `make artifacts` or update rust/src/config/contract.rs)"
            );
        }
        // Validate the artifact table against the typed naming schema
        // (`teacher_{mode}[_b{B}]_s{S}`, `draft[_probe]_s{S}`,
        // `kv_append_{role}_n{N}` — docs/ARCHITECTURE.md §10): a
        // malformed name fails here, listing the variants that did
        // parse, instead of surfacing as an unresolvable launch plan
        // mid-decode. Every variant's S must be a compiled block size of
        // this contract.
        let caps = crate::config::modules::Capabilities::from_manifest(manifest)?;
        for key in caps.keys() {
            let variants = match key.role {
                crate::config::modules::ModuleRole::Teacher => &got.teacher_s,
                crate::config::modules::ModuleRole::Draft => &got.draft_s,
            };
            if !variants.contains(&key.s) {
                bail!(
                    "artifact '{key}' uses S={} which is not a compiled {} block size \
                     (contract has {variants:?}); discovered variants: {}",
                    key.s,
                    key.role.as_str(),
                    caps.describe()
                );
            }
        }
        Ok(got)
    }

    /// Smallest compiled S variant that can hold `n` tokens for a role.
    pub fn pick_s(&self, variants: &[usize], n: usize) -> Result<usize> {
        variants
            .iter()
            .copied()
            .filter(|s| *s >= n)
            .min()
            .with_context(|| format!("no compiled S variant holds {n} tokens (have {variants:?})"))
    }

    /// Smallest compiled teacher variant holding `n` tokens.
    pub fn teacher_variant(&self, n: usize) -> Result<usize> {
        self.pick_s(&self.teacher_s, n)
    }

    /// Smallest compiled draft variant holding `n` tokens.
    pub fn draft_variant(&self, n: usize) -> Result<usize> {
        self.pick_s(&self.draft_s, n)
    }

    /// Largest compiled draft block size — the widest chunk one draft
    /// launch can refresh. Variant lists are ascending and validated
    /// non-empty ([`Contract::from_manifest`]; the compiled-in default
    /// is non-empty too), so this is total; the fallback only covers a
    /// hand-built empty contract.
    pub fn max_draft_s(&self) -> usize {
        self.draft_s.last().copied().unwrap_or(DRAFT_S_VARIANTS[0])
    }

    /// Smallest compiled teacher block size — the baseline (one token
    /// per call) step width. Total for the same reason as
    /// [`Contract::max_draft_s`].
    pub fn min_teacher_s(&self) -> usize {
        self.teacher_s.first().copied().unwrap_or(TEACHER_S_VARIANTS[0])
    }

    /// Largest teacher block = prefill chunk size.
    pub fn prefill_chunk(&self) -> usize {
        128
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    #[test]
    fn pick_s_rounds_up() {
        let c = Contract::default();
        assert_eq!(c.teacher_variant(1).unwrap(), 8);
        assert_eq!(c.teacher_variant(8).unwrap(), 8);
        assert_eq!(c.teacher_variant(9).unwrap(), 16);
        assert_eq!(c.teacher_variant(200).unwrap(), 256);
        assert!(c.teacher_variant(300).is_err());
        assert_eq!(c.draft_variant(20).unwrap(), 32);
    }

    #[test]
    fn manifest_roundtrip_matches_default() {
        // A manifest fragment identical to what aot.py writes.
        let text = r#"{"contract": {
            "vocab": 512, "cache_cap": 1024, "feat_dim": 64,
            "teacher": {"layers": 4, "d_model": 128, "heads": 4, "d_head": 32},
            "draft": {"layers": 1, "d_model": 64, "heads": 2, "d_head": 32},
            "teacher_s_variants": [8, 16, 32, 64, 128, 256],
            "draft_s_variants": [8, 32, 64],
            "neg_inf": -1e+30}}"#;
        let m = json::parse(text).unwrap();
        let c = Contract::from_manifest(&m).unwrap();
        assert_eq!(c, Contract::default());
    }

    #[test]
    fn manifest_mismatch_fails() {
        let text = r#"{"contract": {
            "vocab": 1024, "cache_cap": 1024, "feat_dim": 64,
            "teacher": {"layers": 4, "d_model": 128, "heads": 4, "d_head": 32},
            "draft": {"layers": 1, "d_model": 64, "heads": 2, "d_head": 32},
            "teacher_s_variants": [8], "draft_s_variants": [8],
            "neg_inf": -1e+30}}"#;
        let m = json::parse(text).unwrap();
        assert!(Contract::from_manifest(&m).is_err());
    }

    #[test]
    fn manifest_artifact_names_are_validated() {
        let base = r#""contract": {
            "vocab": 512, "cache_cap": 1024, "feat_dim": 64,
            "teacher": {"layers": 4, "d_model": 128, "heads": 4, "d_head": 32},
            "draft": {"layers": 1, "d_model": 64, "heads": 2, "d_head": 32},
            "teacher_s_variants": [8, 16, 32, 64, 128, 256],
            "draft_s_variants": [8, 32, 64],
            "neg_inf": -1e+30}"#;
        // well-formed names (incl. a fused batch variant) pass
        let ok = format!(
            r#"{{{base}, "artifacts": [
                {{"name": "teacher_fused_s8"}},
                {{"name": "teacher_fused_b4_s16"}},
                {{"name": "kv_append_teacher_n64"}}
            ]}}"#
        );
        assert!(Contract::from_manifest(&json::parse(&ok).unwrap()).is_ok());
        // a malformed name fails with a schema pointer
        let bad = format!(r#"{{{base}, "artifacts": [{{"name": "teacher_turbo_s8"}}]}}"#);
        let err = Contract::from_manifest(&json::parse(&bad).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("naming schema"), "{err:#}");
        // a fused variant outside the compiled S set fails
        let off = format!(r#"{{{base}, "artifacts": [{{"name": "teacher_fused_b4_s24"}}]}}"#);
        let err = Contract::from_manifest(&json::parse(&off).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("not a compiled teacher block size"), "{err:#}");
    }

    #[test]
    fn cache_elems() {
        let c = Contract::default();
        assert_eq!(c.teacher.cache_elems(c.cache_cap), 4 * 1024 * 4 * 32);
        assert_eq!(c.teacher.row_elems(), 4 * 4 * 32);
    }

    #[test]
    fn mode_parse() {
        assert_eq!(ExecMode::parse("fused").unwrap(), ExecMode::Fused);
        assert_eq!(ExecMode::parse("eager").unwrap(), ExecMode::Eager);
        assert!(ExecMode::parse("npu").is_err());
    }
}
