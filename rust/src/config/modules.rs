//! Typed compiled-module identity: the artifact naming contract shared
//! with `python/compile/aot.py`, parsed into [`ModuleKey`]s and collected
//! into a backend [`Capabilities`] table.
//!
//! Before this layer existed, backends addressed compiled variants with
//! ad-hoc `format!("teacher_{mode}_s{s}")` strings and `bail!`-ed on a
//! miss. Now every compiled artifact is a typed key, the full set of keys
//! a backend can launch is its capabilities table, and variant selection
//! is a *negotiation* over that table
//! ([`crate::backend::plan::negotiate`]) returning typed
//! [`crate::backend::PlanError`]s.
//!
//! # Artifact naming schema
//!
//! ```text
//! teacher_{fused|eager}_s{S}          single-request teacher step
//! teacher_{fused|eager}_b{B}_s{S}     fused B-request teacher step
//! draft_s{S}                          draft step
//! draft_probe_s{S}                    draft step + attention probe output
//! <any of the above>_paged            gather-aware variant (takes the
//!                                     block table as an input; ROADMAP)
//! kv_append_{teacher|draft}_n{N}      KV-session scatter-update module
//!                                     (device-resident cache append)
//! ```
//!
//! `kv_append_*` modules are *session* utilities, not step variants: they
//! are validated here but tracked outside [`ModuleKey`] (their I/O
//! signature is cache-update, not step). See `docs/ARCHITECTURE.md` §10.

use super::contract::{Contract, ExecMode};
use crate::json::Json;
use anyhow::{bail, Context, Result};
use std::fmt;

/// Which model a compiled module serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ModuleRole {
    /// The verification (teacher) model.
    Teacher,
    /// The speculation (EAGLE draft) model.
    Draft,
}

impl ModuleRole {
    /// Stable string form (artifact names, errors).
    pub fn as_str(&self) -> &'static str {
        match self {
            ModuleRole::Teacher => "teacher",
            ModuleRole::Draft => "draft",
        }
    }
}

/// Physical cache layout a compiled module consumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ModuleLayout {
    /// Contiguous `[L, cap, H, Dh]` cache inputs (every module compiled
    /// today): paged callers materialize a flat view host-side first.
    Flat,
    /// Gather-aware module taking the block table as an input (paged
    /// attention reads on-device; none compiled yet — ROADMAP).
    Paged,
}

impl ModuleLayout {
    /// Stable string form (artifact names, errors).
    pub fn as_str(&self) -> &'static str {
        match self {
            ModuleLayout::Flat => "flat",
            ModuleLayout::Paged => "paged",
        }
    }
}

/// Typed identity of one compiled module variant — replaces the old
/// string keys (`"teacher_fused_s16"`). The key round-trips through the
/// artifact naming schema via [`ModuleKey::artifact_name`] /
/// [`ModuleKey::parse`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ModuleKey {
    /// Teacher or draft.
    pub role: ModuleRole,
    /// Fused-kernel vs eager artifact flavor (draft modules are compiled
    /// in one flavor only; their canonical key uses [`ExecMode::Fused`]).
    pub mode: ExecMode,
    /// Whether the module emits the attention-probe output.
    pub probe: bool,
    /// Cache layout the module consumes.
    pub layout: ModuleLayout,
    /// Fused request width B (1 for single-request modules).
    pub b: usize,
    /// Padded slot count S per request.
    pub s: usize,
}

impl ModuleKey {
    /// Key of a single-request teacher variant.
    pub fn teacher(mode: ExecMode, s: usize) -> Self {
        Self { role: ModuleRole::Teacher, mode, s, b: 1, probe: false, layout: ModuleLayout::Flat }
    }

    /// Key of a fused `b`-request teacher variant.
    pub fn teacher_batch(mode: ExecMode, b: usize, s: usize) -> Self {
        Self { role: ModuleRole::Teacher, mode, s, b, probe: false, layout: ModuleLayout::Flat }
    }

    /// Key of a draft variant (optionally probe-capable).
    pub fn draft(s: usize, probe: bool) -> Self {
        Self {
            role: ModuleRole::Draft,
            mode: ExecMode::Fused,
            s,
            b: 1,
            probe,
            layout: ModuleLayout::Flat,
        }
    }

    /// Canonical artifact name of this key (the naming schema in the
    /// module docs): inverse of [`ModuleKey::parse`].
    pub fn artifact_name(&self) -> String {
        let mut name = match self.role {
            ModuleRole::Teacher => {
                if self.b > 1 {
                    format!("teacher_{}_b{}_s{}", self.mode.as_str(), self.b, self.s)
                } else {
                    format!("teacher_{}_s{}", self.mode.as_str(), self.s)
                }
            }
            ModuleRole::Draft => {
                if self.probe {
                    format!("draft_probe_s{}", self.s)
                } else {
                    format!("draft_s{}", self.s)
                }
            }
        };
        if self.layout == ModuleLayout::Paged {
            name.push_str("_paged");
        }
        name
    }

    /// Parse an artifact name into a key. Returns `None` for names
    /// outside the step-module schema (e.g. `kv_append_*`, weights).
    pub fn parse(name: &str) -> Option<Self> {
        let (body, layout) = match name.strip_suffix("_paged") {
            Some(b) => (b, ModuleLayout::Paged),
            None => (name, ModuleLayout::Flat),
        };
        if let Some(rest) = body.strip_prefix("draft_probe_s") {
            let s = rest.parse().ok()?;
            return Some(Self { layout, ..Self::draft(s, true) });
        }
        if let Some(rest) = body.strip_prefix("draft_s") {
            let s = rest.parse().ok()?;
            return Some(Self { layout, ..Self::draft(s, false) });
        }
        let rest = body.strip_prefix("teacher_")?;
        let (mode, rest) = if let Some(r) = rest.strip_prefix("fused_") {
            (ExecMode::Fused, r)
        } else if let Some(r) = rest.strip_prefix("eager_") {
            (ExecMode::Eager, r)
        } else {
            return None;
        };
        let (b, rest) = if let Some(r) = rest.strip_prefix("b") {
            let (num, tail) = r.split_once('_')?;
            (num.parse().ok()?, tail)
        } else {
            (1usize, rest)
        };
        let s = rest.strip_prefix("s")?.parse().ok()?;
        if b == 0 || s == 0 {
            return None;
        }
        Some(Self { role: ModuleRole::Teacher, mode, s, b, probe: false, layout })
    }
}

impl fmt::Display for ModuleKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.artifact_name())
    }
}

/// The set of compiled module variants a backend can launch, plus the
/// session scatter-update modules present (`kv_append_{role}_n{N}`).
/// Built from the artifact manifest ([`Capabilities::from_manifest`]) or
/// synthesized for simulator backends ([`Capabilities::synthetic`]);
/// consumed by [`crate::backend::plan::negotiate`].
#[derive(Clone, Debug, Default)]
pub struct Capabilities {
    /// Sorted, deduplicated step-module keys.
    entries: Vec<ModuleKey>,
    /// Available `kv_append` delta widths N per role: `(role, n)` pairs,
    /// sorted ascending by `n` within a role.
    kv_append: Vec<(ModuleRole, usize)>,
}

impl Capabilities {
    /// Build a table from explicit keys (sorted + deduplicated).
    pub fn from_keys(mut entries: Vec<ModuleKey>) -> Self {
        entries.sort_unstable();
        entries.dedup();
        Self { entries, kv_append: Vec::new() }
    }

    /// Parse + validate the `artifacts` table of a manifest. Every entry
    /// whose name starts with `teacher`, `draft` or `kv_append` must
    /// follow the naming schema; a malformed name fails loudly, listing
    /// the variants that did parse. Entries outside those prefixes
    /// (weights, fixtures) are ignored. An absent `artifacts` table
    /// yields an empty capabilities set.
    pub fn from_manifest(manifest: &Json) -> Result<Self> {
        let mut entries = Vec::new();
        let mut kv_append = Vec::new();
        let arts = match manifest.get("artifacts").and_then(Json::as_arr) {
            Some(a) => a,
            None => return Ok(Self::default()),
        };
        for a in arts {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .context("artifact entry missing 'name'")?;
            if let Some(rest) = name.strip_prefix("kv_append_") {
                let parsed = rest
                    .split_once("_n")
                    .and_then(|(role, n)| {
                        let role = match role {
                            "teacher" => ModuleRole::Teacher,
                            "draft" => ModuleRole::Draft,
                            _ => return None,
                        };
                        n.parse::<usize>().ok().filter(|n| *n > 0).map(|n| (role, n))
                    });
                match parsed {
                    Some(p) => kv_append.push(p),
                    None => bail!(
                        "artifact '{name}' does not match the kv_append naming schema \
                         kv_append_{{teacher|draft}}_n{{N}} (see docs/ARCHITECTURE.md §10)"
                    ),
                }
                continue;
            }
            if name.starts_with("teacher") || name.starts_with("draft") {
                match ModuleKey::parse(name) {
                    Some(key) => entries.push(key),
                    None => {
                        let known: Vec<String> = arts
                            .iter()
                            .filter_map(|x| x.get("name").and_then(Json::as_str))
                            .filter(|n| ModuleKey::parse(n).is_some())
                            .map(str::to_string)
                            .collect();
                        bail!(
                            "artifact '{name}' does not match the module naming schema \
                             teacher_{{fused|eager}}[_b{{B}}]_s{{S}} | draft[_probe]_s{{S}} \
                             [+ _paged] (see docs/ARCHITECTURE.md §10); \
                             variants that did parse: [{}]",
                            known.join(", ")
                        );
                    }
                }
            }
        }
        let mut caps = Self::from_keys(entries);
        kv_append.sort_unstable();
        kv_append.dedup();
        caps.kv_append = kv_append;
        Ok(caps)
    }

    /// Synthesize the capabilities of a simulator backend: every compiled
    /// S variant of the contract, both teacher modes, fused widths up to
    /// `max_fused_b`, probe variants for every draft S, and `kv_append`
    /// at every width (a simulator appends host-side, so no N constraint
    /// applies — modeled as `n = cache_cap`).
    pub fn synthetic(contract: &Contract, max_fused_b: usize) -> Self {
        let mut entries = Vec::new();
        for &s in &contract.teacher_s {
            for mode in [ExecMode::Fused, ExecMode::Eager] {
                for b in 1..=max_fused_b.max(1) {
                    entries.push(ModuleKey::teacher_batch(mode, b, s));
                }
            }
        }
        for &s in &contract.draft_s {
            entries.push(ModuleKey::draft(s, false));
            entries.push(ModuleKey::draft(s, true));
        }
        let mut caps = Self::from_keys(entries);
        caps.kv_append = vec![
            (ModuleRole::Teacher, contract.cache_cap),
            (ModuleRole::Draft, contract.cache_cap),
        ];
        caps
    }

    /// Whether this exact key is compiled.
    pub fn contains(&self, key: &ModuleKey) -> bool {
        self.entries.binary_search(key).is_ok()
    }

    /// Iterate every compiled step-module key.
    pub fn keys(&self) -> impl Iterator<Item = &ModuleKey> {
        self.entries.iter()
    }

    /// Number of compiled step-module variants.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether no step-module variants are known.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Largest fused width `b` for which some `(role, mode, layout)`
    /// variant covers `rows` padded slots (0 when nothing covers).
    pub fn max_batch(
        &self,
        role: ModuleRole,
        mode: ExecMode,
        layout: ModuleLayout,
        rows: usize,
    ) -> usize {
        self.entries
            .iter()
            .filter(|k| {
                k.role == role && k.mode == mode && k.layout == layout && !k.probe && k.s >= rows
            })
            .map(|k| k.b)
            .max()
            .unwrap_or(0)
    }

    /// Smallest `kv_append` delta width covering `n` rows for `role`
    /// (`None` when the role has no scatter-update module — sessions are
    /// then unsupported on artifact backends).
    pub fn kv_append_width(&self, role: ModuleRole, n: usize) -> Option<usize> {
        self.kv_append
            .iter()
            .filter(|(r, w)| *r == role && *w >= n)
            .map(|(_, w)| *w)
            .min()
            .or_else(|| {
                // fall back to the largest width (caller chunks the delta)
                self.kv_append.iter().filter(|(r, _)| *r == role).map(|(_, w)| *w).max()
            })
    }

    /// Whether `role` has any session scatter-update module.
    pub fn supports_kv_append(&self, role: ModuleRole) -> bool {
        self.kv_append.iter().any(|(r, _)| *r == role)
    }

    /// Compact human-readable summary of the compiled variants, for
    /// [`crate::backend::PlanError`] messages: one line per
    /// `(role, mode, layout, probe)` group with its S and B sets.
    pub fn describe(&self) -> String {
        let mut lines: Vec<String> = Vec::new();
        let mut i = 0;
        while i < self.entries.len() {
            let head = self.entries[i];
            let group_of = |k: &ModuleKey| (k.role, k.mode, k.probe, k.layout);
            let mut ss: Vec<usize> = Vec::new();
            let mut bs: Vec<usize> = Vec::new();
            let mut j = i;
            while j < self.entries.len() && group_of(&self.entries[j]) == group_of(&head) {
                ss.push(self.entries[j].s);
                bs.push(self.entries[j].b);
                j += 1;
            }
            ss.sort_unstable();
            ss.dedup();
            bs.sort_unstable();
            bs.dedup();
            let fmt_set = |v: &[usize]| -> String {
                let strs: Vec<String> = v.iter().map(|x| x.to_string()).collect();
                strs.join(",")
            };
            lines.push(format!(
                "{}/{}{}{}: S{{{}}} B{{{}}}",
                head.role.as_str(),
                head.mode.as_str(),
                if head.probe { "/probe" } else { "" },
                if head.layout == ModuleLayout::Paged { "/paged" } else { "" },
                fmt_set(&ss),
                fmt_set(&bs),
            ));
            i = j;
        }
        if lines.is_empty() {
            "no compiled variants".to_string()
        } else {
            lines.join("; ")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    #[test]
    fn key_name_roundtrip() {
        let keys = [
            ModuleKey::teacher(ExecMode::Fused, 16),
            ModuleKey::teacher(ExecMode::Eager, 256),
            ModuleKey::teacher_batch(ExecMode::Fused, 4, 32),
            ModuleKey::draft(8, false),
            ModuleKey::draft(32, true),
            ModuleKey { layout: ModuleLayout::Paged, ..ModuleKey::teacher(ExecMode::Fused, 16) },
        ];
        for k in keys {
            let name = k.artifact_name();
            assert_eq!(ModuleKey::parse(&name), Some(k), "{name} must round-trip");
        }
        assert_eq!(ModuleKey::teacher(ExecMode::Fused, 16).artifact_name(), "teacher_fused_s16");
        assert_eq!(
            ModuleKey::teacher_batch(ExecMode::Fused, 4, 32).artifact_name(),
            "teacher_fused_b4_s32"
        );
    }

    #[test]
    fn parse_rejects_malformed_names() {
        for bad in [
            "teacher_s16",
            "teacher_fused_sX",
            "teacher_fused_b0_s16",
            "teacher_fused_b4s16",
            "draft_probe_s",
            "weights_teacher",
        ] {
            assert_eq!(ModuleKey::parse(bad), None, "{bad} must not parse");
        }
    }

    #[test]
    fn manifest_capabilities_parse_and_validate() {
        let text = r#"{"artifacts": [
            {"name": "teacher_fused_s8"},
            {"name": "teacher_fused_b4_s16"},
            {"name": "teacher_eager_s8"},
            {"name": "draft_s8"},
            {"name": "draft_probe_s8"},
            {"name": "kv_append_teacher_n64"},
            {"name": "weights_teacher"}
        ]}"#;
        let caps = Capabilities::from_manifest(&json::parse(text).unwrap()).unwrap();
        assert_eq!(caps.len(), 5);
        assert!(caps.contains(&ModuleKey::teacher_batch(ExecMode::Fused, 4, 16)));
        assert!(caps.contains(&ModuleKey::draft(8, true)));
        assert!(caps.supports_kv_append(ModuleRole::Teacher));
        assert!(!caps.supports_kv_append(ModuleRole::Draft));
        assert_eq!(caps.kv_append_width(ModuleRole::Teacher, 10), Some(64));
        assert_eq!(caps.kv_append_width(ModuleRole::Teacher, 100), Some(64));
    }

    #[test]
    fn malformed_artifact_name_fails_listing_valid_ones() {
        let text = r#"{"artifacts": [
            {"name": "teacher_fused_s8"},
            {"name": "teacher_warp_s8"}
        ]}"#;
        let err = Capabilities::from_manifest(&json::parse(text).unwrap()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("teacher_warp_s8"), "{msg}");
        assert!(msg.contains("teacher_fused_s8"), "must list parsed variants: {msg}");
    }

    #[test]
    fn synthetic_covers_contract_and_widths() {
        let c = Contract::default();
        let caps = Capabilities::synthetic(&c, 8);
        assert!(caps.contains(&ModuleKey::teacher_batch(ExecMode::Fused, 8, 256)));
        assert!(caps.contains(&ModuleKey::teacher_batch(ExecMode::Eager, 3, 8)));
        assert!(caps.contains(&ModuleKey::draft(64, true)));
        assert!(!caps.contains(&ModuleKey::teacher_batch(ExecMode::Fused, 9, 8)));
        assert_eq!(caps.max_batch(ModuleRole::Teacher, ExecMode::Fused, ModuleLayout::Flat, 16), 8);
        assert_eq!(caps.max_batch(ModuleRole::Teacher, ExecMode::Fused, ModuleLayout::Flat, 300), 0);
        assert!(caps.supports_kv_append(ModuleRole::Draft));
    }

    #[test]
    fn describe_is_compact_and_nonempty() {
        let caps = Capabilities::synthetic(&Contract::default(), 2);
        let d = caps.describe();
        assert!(d.contains("teacher/fused"), "{d}");
        assert!(d.contains("draft/fused/probe"), "{d}");
        assert!(Capabilities::default().describe().contains("no compiled variants"));
    }
}
