//! Run-level configuration: tree budgets, cache strategy, execution flags.
//!
//! These knobs correspond 1:1 to the paper's experiment axes:
//!   * `TreeConfig { budget, depth_max, topk }` — E2 budget sweeps;
//!   * `CacheStrategy` / `CommitMode` / `fast_reorder` — §3.1 ablations
//!     (deepcopy-replicate vs segment-share, length vs path-index commit,
//!     prefix-sharing fast reorder == EA_FAST_CACHE_REORDER);
//!   * `ExecMode` — §4.1 two-mode protocol (fused vs eager artifacts);
//!   * `draft_window` — E4 drafter-context truncation;
//!   * `check_invariants` — §3.2 structural invariant enforcement.

use super::contract::ExecMode;
use super::error::ConfigError;
use crate::json::Json;
use anyhow::{bail, Result};

/// Speculative tree budget (paper §2.3, E2 sweep axes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TreeConfig {
    /// Node budget M: max speculative nodes per verification (excl. root).
    pub budget: usize,
    /// Depth bound D_max.
    pub depth_max: usize,
    /// Top-k children considered per expanded node.
    pub topk: usize,
}

impl Default for TreeConfig {
    fn default() -> Self {
        // The paper's measured sweet spot: M=16, D_max=10 (Table 2).
        Self { budget: 16, depth_max: 10, topk: 4 }
    }
}

impl TreeConfig {
    /// Reject out-of-range budgets/depths/branching.
    pub fn validate(&self) -> Result<()> {
        if self.budget == 0 || self.budget > 256 {
            bail!("tree budget M must be in 1..=256 (largest compiled variant), got {}", self.budget);
        }
        if self.depth_max == 0 || self.depth_max > 64 {
            bail!("depth_max must be in 1..=64, got {}", self.depth_max);
        }
        if self.topk == 0 || self.topk > 16 {
            bail!("topk must be in 1..=16, got {}", self.topk);
        }
        Ok(())
    }
}

/// Branch-cache replication strategy (paper §3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheStrategy {
    /// `Replicate(·) = deepcopy` — the paper's robust/conservative mode:
    /// every verification works on a full copy of the committed buffers.
    DeepCopy,
    /// Branches share the committed prefix read-only; speculative KV rows
    /// live in a per-branch segment buffer (fast path).
    SegmentShare,
}

impl CacheStrategy {
    /// Stable string form (flags, manifests).
    pub fn as_str(&self) -> &'static str {
        match self {
            CacheStrategy::DeepCopy => "deepcopy",
            CacheStrategy::SegmentShare => "segment",
        }
    }

    /// Parse the string form (`deepcopy` | `segment`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "deepcopy" => Ok(CacheStrategy::DeepCopy),
            "segment" => Ok(CacheStrategy::SegmentShare),
            other => bail!("unknown cache strategy '{other}' (expected deepcopy|segment)"),
        }
    }
}

/// Physical KV-cache layout behind the branch/commit contract.
///
/// Both layouts implement the same [`crate::cache::KvStore`] contract and
/// decode bit-identically (property-tested in `tests/paged.rs`); they
/// differ only in memory shape and commit cost:
///
/// * [`CacheLayout::Flat`] — one `[L, cap, H, Dh]` buffer pair per role
///   per engine ([`crate::cache::ManagedCache`]): every slot pins full
///   capacity even while its conversation idles.
/// * [`CacheLayout::Paged`] — fixed-size KV blocks drawn from a
///   per-worker [`crate::cache::PagePool`] and addressed through a block
///   table ([`crate::cache::PagedCache`]): residency is proportional to
///   the tokens actually committed, freed blocks return to the pool for
///   other conversations, and path commits remap the table instead of
///   gathering full rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheLayout {
    /// Flat full-capacity buffers (the paper's original layout).
    Flat,
    /// Block-table paging over a shared per-worker pool.
    Paged,
}

impl CacheLayout {
    /// Stable string form (flags, manifests).
    pub fn as_str(&self) -> &'static str {
        match self {
            CacheLayout::Flat => "flat",
            CacheLayout::Paged => "paged",
        }
    }

    /// Parse the string form (`flat` | `paged`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "flat" => Ok(CacheLayout::Flat),
            "paged" => Ok(CacheLayout::Paged),
            other => bail!("unknown cache layout '{other}' (expected flat|paged)"),
        }
    }
}

/// Commit mode after acceptance (paper §3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommitMode {
    /// Keep the first A new rows of the selected branch.
    Length,
    /// Rebuild by gathering rows according to explicit path indices.
    PathIndex,
}

impl CommitMode {
    /// Stable string form (flags, manifests).
    pub fn as_str(&self) -> &'static str {
        match self {
            CommitMode::Length => "length",
            CommitMode::PathIndex => "path-index",
        }
    }

    /// Parse the string form (`length` | `path-index`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "length" => Ok(CommitMode::Length),
            "path-index" => Ok(CommitMode::PathIndex),
            other => bail!("unknown commit mode '{other}' (expected length|path-index)"),
        }
    }
}

/// Everything a decode run needs to know.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Artifact flavor: fused kernels vs eager reference.
    pub mode: ExecMode,
    /// Speculative tree shape (budget M, depth bound, branching).
    pub tree: TreeConfig,
    /// Branch replication strategy (§3.1 ablation axis).
    pub cache_strategy: CacheStrategy,
    /// Physical KV layout: flat full-capacity buffers or block-table
    /// paging over a shared per-worker pool (`--cache-layout`).
    pub cache_layout: CacheLayout,
    /// Commit mode after acceptance (§3.1 ablation axis).
    pub commit_mode: CommitMode,
    /// Prefix-sharing fast reorder (paper's EA_FAST_CACHE_REORDER flag).
    pub fast_reorder: bool,
    /// Device-resident KV sessions (`--kv-sessions`): bind each
    /// conversation cache on the backend once and stream only dirty-row
    /// deltas per step, instead of re-uploading the full
    /// `[L, cap, H, Dh]` buffers every call. Applies to the fused
    /// performance path only — the eager/debug path always uploads full
    /// views (the paper's two-mode design); backends without session
    /// support fall back to full upload transparently.
    pub kv_sessions: bool,
    /// Software-pipelined serve loop (`--pipelining`): overlap the host
    /// half of a verification round (retire/admit + draft expansion +
    /// staging) with the previous fused launch still in flight on the
    /// device, via [`crate::backend::ModelBackend::begin_execute_batch`]
    /// / [`crate::backend::ModelBackend::await_batch`]. Off keeps the
    /// depth-synchronous reference path — bit-identical outputs either
    /// way (acceptance and commits never cross requests), so this is a
    /// pure wall-clock A/B axis.
    pub pipelining: bool,
    /// Copy-on-write prefix sharing (`--prefix-sharing`): freeze each
    /// conversation's committed, block-aligned prompt prefix into a
    /// per-worker [`crate::cache::PrefixIndex`] so a later admission whose
    /// prompt starts with a resident run adopts those blocks directly —
    /// refcounted, copy-on-write on divergence — and skips prefill for the
    /// shared run entirely. Requires the paged cache layout (flat buffers
    /// have no block table to share). Off by default; the off path is
    /// bit-identical to builds without the feature.
    pub prefix_sharing: bool,
    /// §3.2 structural invariant checks before every launch.
    pub check_invariants: bool,
    /// Adaptive tree-budget policy (paper E2 takeaway / future work):
    /// MIMD controller on M driven by recent budget utilization.
    pub adaptive_budget: bool,
    /// Occupancy-aware extension of the adaptive policy
    /// (`--adaptive-occupancy`): the scheduler feeds live-slot occupancy
    /// into the controller each tick, shrinking the budget cap as the
    /// batch fills, and a per-slot acceptance-rate EWMA replaces the raw
    /// window average. Requires `adaptive_budget`; off by default so the
    /// existing controller (and the non-adaptive path) stays
    /// bit-identical.
    pub adaptive_occupancy: bool,
    /// Drafter context window W (None = untruncated) — E4.
    pub draft_window: Option<usize>,
    /// Greedy (temperature=0) vs stochastic acceptance.
    pub temperature: f64,
    /// Tokens generated per turn (soft cap for EA — see the engine docs).
    pub max_new_tokens: usize,
    /// Per-stage timing instrumentation (perturbs wall-clock; E3 only).
    pub instrument: bool,
    /// Collect last-layer attention top-1 statistics via probe artifacts
    /// (analysis-only; Fig 7).
    pub attention_stats: bool,
    /// Seed for stochastic acceptance and workload sampling.
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            mode: ExecMode::Fused,
            tree: TreeConfig::default(),
            cache_strategy: CacheStrategy::SegmentShare,
            cache_layout: CacheLayout::Flat,
            commit_mode: CommitMode::PathIndex,
            fast_reorder: true,
            kv_sessions: true,
            pipelining: true,
            prefix_sharing: false,
            check_invariants: true,
            adaptive_budget: false,
            adaptive_occupancy: false,
            draft_window: None,
            temperature: 0.0,
            max_new_tokens: 256,
            instrument: false,
            attention_stats: false,
            seed: 0,
        }
    }
}

impl RunConfig {
    /// Reject invalid combinations before any decoding starts. Each
    /// contract gets a typed [`ConfigError`] variant (the `Display`
    /// strings are unchanged — callers matching on text keep working,
    /// callers matching on variants no longer have to).
    pub fn validate(&self) -> Result<(), ConfigError> {
        if let Err(e) = self.tree.validate() {
            return Err(ConfigError::Tree(format!("{e:#}")));
        }
        if self.max_new_tokens == 0 {
            return Err(ConfigError::ZeroMaxNew);
        }
        if let Some(w) = self.draft_window {
            if w < 4 {
                return Err(ConfigError::DraftWindowTooSmall { window: w });
            }
        }
        if !(0.0..=2.0).contains(&self.temperature) {
            return Err(ConfigError::TemperatureOutOfRange { temperature: self.temperature });
        }
        if self.prefix_sharing && self.cache_layout != CacheLayout::Paged {
            return Err(ConfigError::PrefixSharingRequiresPaged);
        }
        if self.adaptive_occupancy && !self.adaptive_budget {
            return Err(ConfigError::OccupancyRequiresAdaptive);
        }
        Ok(())
    }

    /// Manifest fragment for traces (§4.3).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("mode", self.mode.as_str())
            .push("tree_budget", self.tree.budget)
            .push("tree_depth_max", self.tree.depth_max)
            .push("tree_topk", self.tree.topk)
            .push("cache_strategy", self.cache_strategy.as_str())
            .push("cache_layout", self.cache_layout.as_str())
            .push("commit_mode", self.commit_mode.as_str())
            .push("fast_reorder", self.fast_reorder)
            .push("kv_sessions", self.kv_sessions)
            .push("pipelining", self.pipelining)
            .push("prefix_sharing", self.prefix_sharing)
            .push("check_invariants", self.check_invariants)
            .push("adaptive_budget", self.adaptive_budget)
            .push("adaptive_occupancy", self.adaptive_occupancy)
            .push(
                "draft_window",
                self.draft_window.map(|w| Json::Num(w as f64)).unwrap_or(Json::Null),
            )
            .push("temperature", self.temperature)
            .push("max_new_tokens", self.max_new_tokens)
            .push("instrument", self.instrument)
            .push("attention_stats", self.attention_stats)
            .push("seed", self.seed);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid_and_paper_sweet_spot() {
        let c = RunConfig::default();
        c.validate().unwrap();
        assert_eq!(c.tree.budget, 16);
        assert_eq!(c.tree.depth_max, 10);
    }

    #[test]
    fn rejects_bad_budgets() {
        let mut c = RunConfig::default();
        c.tree.budget = 0;
        assert!(c.validate().is_err());
        c.tree.budget = 257;
        assert!(c.validate().is_err());
        c.tree.budget = 256;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn rejects_tiny_draft_window() {
        let mut c = RunConfig::default();
        c.draft_window = Some(2);
        assert!(c.validate().is_err());
        c.draft_window = Some(32);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn json_includes_every_axis() {
        let j = RunConfig::default().to_json();
        for key in ["mode", "tree_budget", "cache_strategy", "cache_layout", "commit_mode",
                    "fast_reorder", "kv_sessions", "pipelining", "draft_window",
                    "max_new_tokens"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn pipelining_defaults_on() {
        assert!(RunConfig::default().pipelining, "pipelining must default on");
    }

    #[test]
    fn validate_errors_are_typed_variants() {
        // Tests (and callers) match on the variant, not the message.
        let mut c = RunConfig::default();
        c.max_new_tokens = 0;
        assert_eq!(c.validate().unwrap_err(), ConfigError::ZeroMaxNew);
        let mut c = RunConfig::default();
        c.draft_window = Some(2);
        assert_eq!(c.validate().unwrap_err(), ConfigError::DraftWindowTooSmall { window: 2 });
        let mut c = RunConfig::default();
        c.temperature = 3.5;
        assert_eq!(
            c.validate().unwrap_err(),
            ConfigError::TemperatureOutOfRange { temperature: 3.5 }
        );
        let mut c = RunConfig::default();
        c.prefix_sharing = true;
        assert_eq!(c.validate().unwrap_err(), ConfigError::PrefixSharingRequiresPaged);
        let mut c = RunConfig::default();
        c.adaptive_occupancy = true;
        assert_eq!(c.validate().unwrap_err(), ConfigError::OccupancyRequiresAdaptive);
        let mut c = RunConfig::default();
        c.tree.budget = 0;
        assert!(matches!(c.validate().unwrap_err(), ConfigError::Tree(_)));
    }

    #[test]
    fn occupancy_requires_the_adaptive_controller() {
        let mut c = RunConfig::default();
        c.adaptive_occupancy = true;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("--adaptive-occupancy"), "error must name the flag: {err}");
        c.adaptive_budget = true;
        assert!(c.validate().is_ok());
        assert!(!RunConfig::default().adaptive_occupancy, "occupancy must default off");
    }

    #[test]
    fn prefix_sharing_requires_the_paged_layout() {
        let mut c = RunConfig::default();
        c.prefix_sharing = true;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("--prefix-sharing"), "error must name the flag: {err}");
        c.cache_layout = CacheLayout::Paged;
        assert!(c.validate().is_ok());
        assert!(!RunConfig::default().prefix_sharing, "sharing must default off");
        assert!(RunConfig::default().to_json().get("prefix_sharing").is_some());
    }

    #[test]
    fn strategy_and_commit_parse() {
        assert_eq!(CacheStrategy::parse("deepcopy").unwrap(), CacheStrategy::DeepCopy);
        assert_eq!(CommitMode::parse("path-index").unwrap(), CommitMode::PathIndex);
        assert!(CacheStrategy::parse("x").is_err());
        assert!(CommitMode::parse("x").is_err());
    }

    #[test]
    fn cache_layout_parses_and_defaults_flat() {
        assert_eq!(CacheLayout::parse("flat").unwrap(), CacheLayout::Flat);
        assert_eq!(CacheLayout::parse("paged").unwrap(), CacheLayout::Paged);
        assert!(CacheLayout::parse("sparse").is_err());
        assert_eq!(RunConfig::default().cache_layout, CacheLayout::Flat);
        assert_eq!(CacheLayout::Paged.as_str(), "paged");
    }
}
