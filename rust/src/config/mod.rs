//! Typed configuration system: the L2/L3 shape contract, engine/runtime
//! options, and validation. Mirrors `python/compile/config.py`; the values
//! baked into `artifacts/manifest.json` are validated against this at load
//! time so a stale artifact set fails fast instead of miscomputing.

pub mod contract;
pub mod error;
pub mod modules;
pub mod run;

pub use contract::{Contract, Dims, ExecMode};
pub use error::ConfigError;
pub use modules::{Capabilities, ModuleKey, ModuleLayout, ModuleRole};
pub use run::{CacheLayout, CacheStrategy, CommitMode, RunConfig, TreeConfig};
