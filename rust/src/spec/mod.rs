//! Speculative-decoding policy: tree acceptance (paper §2.2/§2.3) and
//! draft candidate selection (EAGLE-style dynamic tree growth).
//!
//! These are pure functions over [`crate::tree::SpecTree`] + logits
//! accessors, so every decision rule is unit-testable without a backend;
//! [`crate::engine`] wires them to real model calls.

pub mod accept;
pub mod adaptive;
pub mod select;

pub use accept::{greedy_walk, stochastic_walk, Acceptance};
pub use adaptive::AdaptiveBudget;
pub use select::{select_children, Candidate};
