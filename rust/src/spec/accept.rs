//! Tree acceptance rules.
//!
//! The verification call produced, for every tree slot, the teacher's
//! next-token distribution *under that slot's ancestral context* (the tree
//! mask guarantees this — paper §3.3 "context correctness"). Acceptance
//! walks the tree from the root:
//!
//! * **greedy** (temperature = 0, all paper benchmarks): descend into the
//!   child whose token equals the teacher argmax at the current slot;
//!   stop otherwise. The committed sequence is therefore *identical* to
//!   teacher-only greedy decoding — speculation changes wall-clock, never
//!   output (asserted by engine tests).
//! * **stochastic**: sample from the teacher softmax at the current slot;
//!   descend if the sample matches a child. Because every committed token
//!   is an exact teacher-distribution sample given its prefix, the output
//!   marginal matches ancestral teacher sampling (the lossless property
//!   of [1]'s scheme specialized to sampled-token matching).
//!
//! Both rules return the *bonus* token — the teacher's own prediction at
//! the deepest accepted slot — which is committed "for free" each round.
//!
//! `logits_of` hands out **borrowed rows** (slices into the verification
//! scratch) rather than cloned `Vec`s, and the softmax sampler runs
//! two-pass without a weights buffer, so acceptance is allocation-free
//! beyond the (depth-bounded) path vector.

use crate::backend::argmax;
use crate::tree::SpecTree;
use crate::util::SplitMix64;

/// Result of an acceptance walk.
#[derive(Clone, Debug, PartialEq)]
pub struct Acceptance {
    /// Accepted tree slots in root-to-leaf order (excluding the root).
    pub path: Vec<usize>,
    /// The teacher's next token at the deepest accepted slot.
    pub bonus_token: i32,
    /// Slot whose logits predicted the bonus (root if nothing accepted).
    pub bonus_slot: usize,
    /// Number of walk steps where the tree *offered* candidates
    /// (denominator for the Fig-3 position-wise acceptance curve).
    pub offered: usize,
}

impl Acceptance {
    /// accept_L: number of accepted draft tokens (paper Table 1).
    pub fn accept_len(&self) -> usize {
        self.path.len()
    }
}

/// Shared walk skeleton: `pick(slot)` returns the teacher's token choice
/// at a slot (argmax or a softmax sample).
fn walk(tree: &SpecTree, mut pick: impl FnMut(usize) -> i32) -> Acceptance {
    let mut cur = 0usize;
    let mut path = Vec::new();
    let mut offered = 0usize;
    loop {
        let teacher_tok = pick(cur);
        let mut hit = None;
        let mut has_children = false;
        for child in tree.children(cur) {
            has_children = true;
            if tree.slots()[child].token == teacher_tok {
                hit = Some(child);
                break;
            }
        }
        if has_children {
            offered += 1;
        }
        match hit {
            Some(h) => {
                path.push(h);
                cur = h;
            }
            None => {
                return Acceptance { path, bonus_token: teacher_tok, bonus_slot: cur, offered };
            }
        }
    }
}

/// Greedy acceptance (temperature = 0).
///
/// `logits_of(slot)` returns the teacher logits row for a tree slot
/// (a borrowed slice — typically into the verification scratch).
pub fn greedy_walk<'a>(tree: &SpecTree, logits_of: &dyn Fn(usize) -> &'a [f32]) -> Acceptance {
    walk(tree, |slot| argmax(logits_of(slot)) as i32)
}

/// Stochastic acceptance: at each slot, sample from the teacher softmax
/// (with `temperature`); accept a child iff the sample equals its token.
pub fn stochastic_walk<'a>(
    tree: &SpecTree,
    logits_of: &dyn Fn(usize) -> &'a [f32],
    temperature: f64,
    rng: &mut SplitMix64,
) -> Acceptance {
    let temp = temperature.max(1e-6);
    walk(tree, |slot| sample_softmax(logits_of(slot), temp, rng) as i32)
}

/// Sample an index from softmax(logits / temp). Two-pass (normalizer,
/// then cumulative scan against one uniform draw) — no weights buffer.
/// The second pass recomputes each `exp` rather than caching it: that
/// doubles the transcendental work per sampled slot, a deliberate trade
/// for keeping the stochastic path (off the paper's greedy hot path)
/// allocation-free without threading a scratch buffer through the walk.
/// Consumes exactly one RNG draw, bit-identical to `rng.weighted` over a
/// materialized weights vector.
pub fn sample_softmax(row: &[f32], temp: f64, rng: &mut SplitMix64) -> usize {
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, b| a.max(*b)) as f64;
    let total: f64 = row.iter().map(|x| ((*x as f64 - mx) / temp).exp()).sum();
    let mut r = rng.f64_unit() * total;
    for (i, x) in row.iter().enumerate() {
        r -= ((*x as f64 - mx) / temp).exp();
        if r < 0.0 {
            return i;
        }
    }
    row.len().saturating_sub(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// Tree: root -> a(5) -> b(7); root -> c(9).
    fn tree() -> SpecTree {
        let mut t = SpecTree::with_root(1);
        let a = t.add_child(0, 5, -0.1);
        t.add_child(0, 9, -0.9);
        t.add_child(a, 7, -0.2);
        t
    }

    /// Materialized per-slot rows where slot s's argmax is `winner[s]`.
    fn const_rows(winner: &[i32]) -> Vec<Vec<f32>> {
        winner
            .iter()
            .map(|w| {
                let mut row = vec![0.0f32; 16];
                row[*w as usize] = 10.0;
                row
            })
            .collect()
    }

    #[test]
    fn greedy_accepts_full_chain() {
        // teacher at root predicts 5, at a predicts 7, at b predicts 3
        let rows = const_rows(&[5, 7, 0, 3]);
        let walk = greedy_walk(&tree(), &|s| rows[s].as_slice());
        assert_eq!(walk.path, vec![1, 3]);
        assert_eq!(walk.bonus_token, 3);
        assert_eq!(walk.bonus_slot, 3);
        assert_eq!(walk.offered, 2);
        assert_eq!(walk.accept_len(), 2);
    }

    #[test]
    fn greedy_stops_on_mismatch_with_bonus() {
        // teacher at root predicts 9 (sibling branch), at c predicts 2
        let rows = const_rows(&[9, 0, 2, 0]);
        let walk = greedy_walk(&tree(), &|s| rows[s].as_slice());
        assert_eq!(walk.path, vec![2]);
        assert_eq!(walk.bonus_token, 2);
        assert_eq!(walk.offered, 1); // only the root had candidates (c is a leaf)
    }

    #[test]
    fn greedy_rejects_everything_cleanly() {
        let rows = const_rows(&[4, 0, 0, 0]);
        let walk = greedy_walk(&tree(), &|s| rows[s].as_slice());
        assert!(walk.path.is_empty());
        assert_eq!(walk.bonus_token, 4);
        assert_eq!(walk.bonus_slot, 0);
        assert_eq!(walk.offered, 1);
    }

    #[test]
    fn stochastic_low_temp_equals_greedy() {
        let rows = const_rows(&[5, 7, 0, 3]);
        let logits = |s: usize| rows[s].as_slice();
        let mut rng = SplitMix64::new(1);
        let s = stochastic_walk(&tree(), &logits, 1e-6, &mut rng);
        let g = greedy_walk(&tree(), &logits);
        assert_eq!(s.path, g.path);
        assert_eq!(s.bonus_token, g.bonus_token);
    }

    #[test]
    fn stochastic_matches_softmax_marginals_at_root() {
        // Root logits put ~73%/27% on tokens 5 and 9; acceptance of child
        // `a` should track the softmax probability of token 5.
        let mut row = vec![-30.0f32; 16];
        row[5] = 1.0;
        row[9] = 0.0;
        let logits = |_slot: usize| row.as_slice();
        let mut rng = SplitMix64::new(7);
        let n = 4000;
        let mut hits = 0;
        for _ in 0..n {
            let w = stochastic_walk(&tree(), &logits, 1.0, &mut rng);
            if w.path.first() == Some(&1) {
                hits += 1;
            }
        }
        let p = hits as f64 / n as f64;
        let expect = (1.0f64).exp() / ((1.0f64).exp() + 1.0);
        assert!((p - expect).abs() < 0.03, "p = {p}, expect {expect}");
    }

    #[test]
    fn property_path_is_always_a_valid_chain() {
        prop::for_cases(100, 0xACCE, |g| {
            // random tree + random teacher predictions
            let mut t = SpecTree::with_root(1);
            let mut frontier = vec![0usize];
            for _ in 0..g.usize_in(1, 12) {
                let mut next = Vec::new();
                for &p in &frontier.clone() {
                    for _ in 0..g.usize_in(0, 3) {
                        next.push(t.add_child(p, g.usize_in(2, 14) as i32, 0.0));
                    }
                }
                if next.is_empty() {
                    break;
                }
                frontier = next;
            }
            let rows = const_rows(
                &(0..t.num_slots()).map(|_| g.usize_in(2, 14) as i32).collect::<Vec<_>>(),
            );
            let walk = greedy_walk(&t, &|s| rows[s].as_slice());
            // path must be a parent-linked chain starting under the root
            let mut cur = 0usize;
            for &s in &walk.path {
                assert_eq!(t.slots()[s].parent, cur);
                cur = s;
            }
            assert_eq!(walk.bonus_slot, cur);
        });
    }
}
