//! Tree acceptance rules.
//!
//! The verification call produced, for every tree slot, the teacher's
//! next-token distribution *under that slot's ancestral context* (the tree
//! mask guarantees this — paper §3.3 "context correctness"). Acceptance
//! walks the tree from the root:
//!
//! * **greedy** (temperature = 0, all paper benchmarks): descend into the
//!   child whose token equals the teacher argmax at the current slot;
//!   stop otherwise. The committed sequence is therefore *identical* to
//!   teacher-only greedy decoding — speculation changes wall-clock, never
//!   output (asserted by engine tests).
//! * **stochastic**: sample from the teacher softmax at the current slot;
//!   descend if the sample matches a child. Because every committed token
//!   is an exact teacher-distribution sample given its prefix, the output
//!   marginal matches ancestral teacher sampling (the lossless property
//!   of [1]'s scheme specialized to sampled-token matching).
//!
//! Both rules return the *bonus* token — the teacher's own prediction at
//! the deepest accepted slot — which is committed "for free" each round.

use crate::backend::argmax;
use crate::tree::SpecTree;
use crate::util::SplitMix64;

/// Result of an acceptance walk.
#[derive(Clone, Debug, PartialEq)]
pub struct Acceptance {
    /// Accepted tree slots in root-to-leaf order (excluding the root).
    pub path: Vec<usize>,
    /// The teacher's next token at the deepest accepted slot.
    pub bonus_token: i32,
    /// Slot whose logits predicted the bonus (root if nothing accepted).
    pub bonus_slot: usize,
    /// Number of walk steps where the tree *offered* candidates
    /// (denominator for the Fig-3 position-wise acceptance curve).
    pub offered: usize,
}

impl Acceptance {
    /// accept_L: number of accepted draft tokens (paper Table 1).
    pub fn accept_len(&self) -> usize {
        self.path.len()
    }
}

/// Greedy acceptance (temperature = 0).
///
/// `logits_of(slot)` returns the teacher logits row for a tree slot.
pub fn greedy_walk(tree: &SpecTree, logits_of: &dyn Fn(usize) -> Vec<f32>) -> Acceptance {
    let mut cur = 0usize;
    let mut path = Vec::new();
    let mut offered = 0usize;
    loop {
        let teacher_tok = argmax(&logits_of(cur)) as i32;
        let children: Vec<usize> = tree.children(cur).collect();
        if children.is_empty() {
            return Acceptance { path, bonus_token: teacher_tok, bonus_slot: cur, offered };
        }
        offered += 1;
        match children.iter().find(|c| tree.slots()[**c].token == teacher_tok) {
            Some(&hit) => {
                path.push(hit);
                cur = hit;
            }
            None => {
                return Acceptance { path, bonus_token: teacher_tok, bonus_slot: cur, offered };
            }
        }
    }
}

/// Stochastic acceptance: at each slot, sample from the teacher softmax
/// (with `temperature`); accept a child iff the sample equals its token.
pub fn stochastic_walk(
    tree: &SpecTree,
    logits_of: &dyn Fn(usize) -> Vec<f32>,
    temperature: f64,
    rng: &mut SplitMix64,
) -> Acceptance {
    let temp = temperature.max(1e-6);
    let mut cur = 0usize;
    let mut path = Vec::new();
    let mut offered = 0usize;
    loop {
        let row = logits_of(cur);
        let sampled = sample_softmax(&row, temp, rng) as i32;
        let children: Vec<usize> = tree.children(cur).collect();
        if children.is_empty() {
            return Acceptance { path, bonus_token: sampled, bonus_slot: cur, offered };
        }
        offered += 1;
        match children.iter().find(|c| tree.slots()[**c].token == sampled) {
            Some(&hit) => {
                path.push(hit);
                cur = hit;
            }
            None => {
                return Acceptance { path, bonus_token: sampled, bonus_slot: cur, offered };
            }
        }
    }
}

/// Sample an index from softmax(logits / temp).
pub fn sample_softmax(row: &[f32], temp: f64, rng: &mut SplitMix64) -> usize {
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, b| a.max(*b)) as f64;
    let weights: Vec<f64> = row.iter().map(|x| ((*x as f64 - mx) / temp).exp()).collect();
    rng.weighted(&weights)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// Tree: root -> a(5) -> b(7); root -> c(9).
    fn tree() -> SpecTree {
        let mut t = SpecTree::with_root(1);
        let a = t.add_child(0, 5, -0.1);
        t.add_child(0, 9, -0.9);
        t.add_child(a, 7, -0.2);
        t
    }

    fn const_logits(winner: &'static [i32]) -> impl Fn(usize) -> Vec<f32> {
        move |slot| {
            let mut row = vec![0.0f32; 16];
            row[winner[slot] as usize] = 10.0;
            row
        }
    }

    #[test]
    fn greedy_accepts_full_chain() {
        // teacher at root predicts 5, at a predicts 7, at b predicts 3
        let walk = greedy_walk(&tree(), &const_logits(&[5, 7, 0, 3]));
        assert_eq!(walk.path, vec![1, 3]);
        assert_eq!(walk.bonus_token, 3);
        assert_eq!(walk.bonus_slot, 3);
        assert_eq!(walk.offered, 2);
        assert_eq!(walk.accept_len(), 2);
    }

    #[test]
    fn greedy_stops_on_mismatch_with_bonus() {
        // teacher at root predicts 9 (sibling branch), at c predicts 2
        let walk = greedy_walk(&tree(), &const_logits(&[9, 0, 2, 0]));
        assert_eq!(walk.path, vec![2]);
        assert_eq!(walk.bonus_token, 2);
        assert_eq!(walk.offered, 1); // only the root had candidates (c is a leaf)
    }

    #[test]
    fn greedy_rejects_everything_cleanly() {
        let walk = greedy_walk(&tree(), &const_logits(&[4, 0, 0, 0]));
        assert!(walk.path.is_empty());
        assert_eq!(walk.bonus_token, 4);
        assert_eq!(walk.bonus_slot, 0);
        assert_eq!(walk.offered, 1);
    }

    #[test]
    fn stochastic_low_temp_equals_greedy() {
        let logits = const_logits(&[5, 7, 0, 3]);
        let mut rng = SplitMix64::new(1);
        let s = stochastic_walk(&tree(), &logits, 1e-6, &mut rng);
        let g = greedy_walk(&tree(), &logits);
        assert_eq!(s.path, g.path);
        assert_eq!(s.bonus_token, g.bonus_token);
    }

    #[test]
    fn stochastic_matches_softmax_marginals_at_root() {
        // Root logits put ~73%/27% on tokens 5 and 9; acceptance of child
        // `a` should track the softmax probability of token 5.
        let logits = |_slot: usize| {
            let mut row = vec![-30.0f32; 16];
            row[5] = 1.0;
            row[9] = 0.0;
            row
        };
        let mut rng = SplitMix64::new(7);
        let n = 4000;
        let mut hits = 0;
        for _ in 0..n {
            let w = stochastic_walk(&tree(), &logits, 1.0, &mut rng);
            if w.path.first() == Some(&1) {
                hits += 1;
            }
        }
        let p = hits as f64 / n as f64;
        let expect = (1.0f64).exp() / ((1.0f64).exp() + 1.0);
        assert!((p - expect).abs() < 0.03, "p = {p}, expect {expect}");
    }

    #[test]
    fn property_path_is_always_a_valid_chain() {
        prop::for_cases(100, 0xACCE, |g| {
            // random tree + random teacher predictions
            let mut t = SpecTree::with_root(1);
            let mut frontier = vec![0usize];
            for _ in 0..g.usize_in(1, 12) {
                let mut next = Vec::new();
                for &p in &frontier.clone() {
                    for _ in 0..g.usize_in(0, 3) {
                        next.push(t.add_child(p, g.usize_in(2, 14) as i32, 0.0));
                    }
                }
                if next.is_empty() {
                    break;
                }
                frontier = next;
            }
            let preds: Vec<i32> =
                (0..t.num_slots()).map(|_| g.usize_in(2, 14) as i32).collect();
            let walk = greedy_walk(&t, &move |s| {
                let mut row = vec![0.0f32; 16];
                row[preds[s] as usize] = 1.0;
                row
            });
            // path must be a parent-linked chain starting under the root
            let mut cur = 0usize;
            for &s in &walk.path {
                assert_eq!(t.slots()[s].parent, cur);
                cur = s;
            }
            assert_eq!(walk.bonus_slot, cur);
        });
    }
}
