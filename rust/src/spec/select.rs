//! Draft candidate selection — EAGLE-style dynamic tree growth.
//!
//! At each expansion depth the draft scored top-k continuations per
//! frontier node; the global policy keeps the best candidates by
//! *cumulative* draft log-probability, subject to the remaining node
//! budget and the frontier cap (the largest compiled draft S variant).

/// One scored child candidate.
#[derive(Clone, Debug, PartialEq)]
pub struct Candidate {
    /// Parent tree slot.
    pub parent: usize,
    pub token: i32,
    /// Cumulative draft log-prob along the root path.
    pub cum_logprob: f64,
    /// Row index of the parent in the draft eval batch (for feature
    /// chaining: the child's feats_in = parent's hidden row).
    pub parent_row: usize,
}

/// Keep the globally best candidates: at most `budget` and at most
/// `frontier_cap`, sorted by cumulative log-prob descending. Duplicate
/// (parent, token) pairs are rejected (defense-in-depth: a draft should
/// not propose them, but a malformed top-k must not corrupt the tree).
pub fn select_children(
    mut pool: Vec<Candidate>,
    budget: usize,
    frontier_cap: usize,
) -> Vec<Candidate> {
    pool.sort_by(|a, b| {
        b.cum_logprob
            .partial_cmp(&a.cum_logprob)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.parent.cmp(&b.parent))
            .then(a.token.cmp(&b.token))
    });
    let mut out: Vec<Candidate> = Vec::new();
    for c in pool {
        if out.len() >= budget.min(frontier_cap) {
            break;
        }
        if out.iter().any(|o| o.parent == c.parent && o.token == c.token) {
            continue;
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn c(parent: usize, token: i32, lp: f64) -> Candidate {
        Candidate { parent, token, cum_logprob: lp, parent_row: parent }
    }

    #[test]
    fn keeps_best_by_cumulative_logprob() {
        let sel = select_children(
            vec![c(0, 5, -0.5), c(0, 6, -0.1), c(1, 7, -0.3)],
            2,
            16,
        );
        assert_eq!(sel.len(), 2);
        assert_eq!(sel[0].token, 6);
        assert_eq!(sel[1].token, 7);
    }

    #[test]
    fn respects_frontier_cap() {
        let pool = (0..10).map(|i| c(0, i as i32 + 2, -(i as f64))).collect();
        let sel = select_children(pool, 100, 3);
        assert_eq!(sel.len(), 3);
    }

    #[test]
    fn rejects_duplicate_parent_token() {
        let sel = select_children(
            vec![c(0, 5, -0.1), c(0, 5, -0.2), c(0, 6, -0.3)],
            8,
            8,
        );
        assert_eq!(sel.len(), 2);
    }

    #[test]
    fn deterministic_order_on_ties() {
        let a = select_children(vec![c(1, 9, -0.5), c(0, 3, -0.5)], 2, 2);
        let b = select_children(vec![c(0, 3, -0.5), c(1, 9, -0.5)], 2, 2);
        assert_eq!(a, b);
        assert_eq!(a[0].parent, 0);
    }

    #[test]
    fn property_selection_sorted_and_bounded() {
        prop::for_cases(100, 0x5E1E, |g| {
            let n = g.usize_in(0, 40);
            let pool: Vec<Candidate> = (0..n)
                .map(|_| c(g.usize_in(0, 6), g.usize_in(2, 50) as i32, -(g.f32_pm1().abs() as f64)))
                .collect();
            let budget = g.usize_in(1, 20);
            let cap = g.usize_in(1, 20);
            let sel = select_children(pool, budget, cap);
            assert!(sel.len() <= budget.min(cap));
            for w in sel.windows(2) {
                assert!(w[0].cum_logprob >= w[1].cum_logprob);
            }
        });
    }
}
