//! Draft candidate selection — EAGLE-style dynamic tree growth.
//!
//! At each expansion depth the draft scored top-k continuations per
//! frontier node; the global policy keeps the best candidates by
//! *cumulative* draft log-probability, subject to the remaining node
//! budget and the frontier cap (the largest compiled draft S variant).

/// One scored child candidate.
#[derive(Clone, Debug, PartialEq)]
pub struct Candidate {
    /// Parent tree slot.
    pub parent: usize,
    /// Proposed token id.
    pub token: i32,
    /// Cumulative draft log-prob along the root path.
    pub cum_logprob: f64,
    /// Row index of the parent in the draft eval batch (for feature
    /// chaining: the child's feats_in = parent's hidden row).
    pub parent_row: usize,
}

/// Keep the globally best candidates **in place**: at most `budget` and
/// at most `frontier_cap`, sorted by cumulative log-prob descending.
/// Duplicate (parent, token) pairs are rejected (defense-in-depth: a
/// draft should not propose them, but a malformed top-k must not corrupt
/// the tree). In-place so the engine's reusable candidate pool never
/// reallocates in steady state.
pub fn select_children(pool: &mut Vec<Candidate>, budget: usize, frontier_cap: usize) {
    // unstable sort: no merge buffer, and the (logprob, parent, token)
    // key is total up to exact duplicates (which dedup removes below),
    // so the result is deterministic.
    pool.sort_unstable_by(|a, b| {
        b.cum_logprob
            .partial_cmp(&a.cum_logprob)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.parent.cmp(&b.parent))
            .then(a.token.cmp(&b.token))
    });
    let limit = budget.min(frontier_cap);
    let mut kept = 0usize;
    for i in 0..pool.len() {
        if kept >= limit {
            break;
        }
        if pool[..kept].iter().any(|o| o.parent == pool[i].parent && o.token == pool[i].token) {
            continue;
        }
        pool.swap(kept, i);
        kept += 1;
    }
    pool.truncate(kept);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn c(parent: usize, token: i32, lp: f64) -> Candidate {
        Candidate { parent, token, cum_logprob: lp, parent_row: parent }
    }

    fn select(mut pool: Vec<Candidate>, budget: usize, cap: usize) -> Vec<Candidate> {
        select_children(&mut pool, budget, cap);
        pool
    }

    #[test]
    fn keeps_best_by_cumulative_logprob() {
        let sel = select(vec![c(0, 5, -0.5), c(0, 6, -0.1), c(1, 7, -0.3)], 2, 16);
        assert_eq!(sel.len(), 2);
        assert_eq!(sel[0].token, 6);
        assert_eq!(sel[1].token, 7);
    }

    #[test]
    fn respects_frontier_cap() {
        let pool = (0..10).map(|i| c(0, i as i32 + 2, -(i as f64))).collect();
        let sel = select(pool, 100, 3);
        assert_eq!(sel.len(), 3);
    }

    #[test]
    fn rejects_duplicate_parent_token() {
        let sel = select(vec![c(0, 5, -0.1), c(0, 5, -0.2), c(0, 6, -0.3)], 8, 8);
        assert_eq!(sel.len(), 2);
    }

    #[test]
    fn deterministic_order_on_ties() {
        let a = select(vec![c(1, 9, -0.5), c(0, 3, -0.5)], 2, 2);
        let b = select(vec![c(0, 3, -0.5), c(1, 9, -0.5)], 2, 2);
        assert_eq!(a, b);
        assert_eq!(a[0].parent, 0);
    }

    #[test]
    fn selection_reuses_the_pool_allocation() {
        let mut pool: Vec<Candidate> = (0..10).map(|i| c(0, i as i32 + 2, -(i as f64))).collect();
        let ptr = pool.as_ptr();
        let cap = pool.capacity();
        select_children(&mut pool, 4, 16);
        assert_eq!(pool.len(), 4);
        assert_eq!(pool.as_ptr(), ptr, "selection must not reallocate");
        assert_eq!(pool.capacity(), cap);
    }

    #[test]
    fn property_selection_sorted_and_bounded() {
        prop::for_cases(100, 0x5E1E, |g| {
            let n = g.usize_in(0, 40);
            let pool: Vec<Candidate> = (0..n)
                .map(|_| c(g.usize_in(0, 6), g.usize_in(2, 50) as i32, -(g.f32_pm1().abs() as f64)))
                .collect();
            let budget = g.usize_in(1, 20);
            let cap = g.usize_in(1, 20);
            let sel = select(pool, budget, cap);
            assert!(sel.len() <= budget.min(cap));
            for w in sel.windows(2) {
                assert!(w[0].cum_logprob >= w[1].cum_logprob);
            }
        });
    }
}
