//! Adaptive tree-budget policy.
//!
//! The paper's E2 takeaway: "tree speculation has a configuration-
//! dependent sweet spot; lightweight budget sweeps **or adaptive
//! policies** are necessary for stable performance in deployment", and
//! its conclusion lists adaptive branching policies as future work. This
//! module implements that policy: a multiplicative-increase /
//! multiplicative-decrease controller on the node budget M driven by the
//! recent *budget utilization* (accepted draft tokens per offered node).
//!
//! Rationale from the E2 economics: the marginal verification cost grows
//! with the padded S variant while the marginal benefit is the extra
//! acceptance probability at deeper/wider positions. When recent rounds
//! accept a large fraction of the offered budget, a larger tree likely
//! pays for itself; when acceptance is sparse, a smaller tree cuts
//! mask/tensorize/verify overhead without losing accepted tokens.
//!
//! The occupancy-aware extension (Meta's at-scale result, PAPERS.md)
//! composes a second signal: at fixed utilization, speculation loses its
//! win as batch occupancy rises, because verification FLOPs for rejected
//! nodes crowd out the other slots' throughput. In occupancy mode the
//! controller (a) replaces the raw window average with a per-slot
//! acceptance-rate EWMA, and (b) caps the MIMD operating point by a
//! linear occupancy schedule: a lone slot may use the full budget range,
//! a full batch is pinned near `min_budget`.

use std::collections::VecDeque;

/// Multiplicative-increase / multiplicative-decrease controller on the
/// tree node budget M, driven by recent budget utilization.
#[derive(Clone, Debug)]
pub struct AdaptiveBudget {
    /// Smallest budget the controller may choose.
    pub min_budget: usize,
    /// Largest budget the controller may choose.
    pub max_budget: usize,
    /// Utilization above this doubles the budget.
    pub grow_at: f64,
    /// Utilization below this halves the budget.
    pub shrink_at: f64,
    /// Rounds averaged per decision.
    pub window: usize,
    current: usize,
    history: VecDeque<(usize, usize)>, // (accept_len, budget_offered)
    // --- occupancy-aware mode (`adaptive_occupancy on`) ---
    occupancy_aware: bool,
    /// EWMA of per-round utilization (accept_len / budget_offered);
    /// None until the first occupancy-mode observation.
    ewma: Option<f64>,
    /// EWMA smoothing factor (weight of the newest round).
    ewma_alpha: f64,
    /// Latest occupancy fraction in [0, 1]: 0 = lone slot, 1 = full batch.
    occ_frac: f64,
    /// Rounds since the last MIMD decision (occupancy mode decides on a
    /// fixed cadence of `window` rounds instead of a sliding window).
    since_decision: usize,
}

impl AdaptiveBudget {
    /// A controller starting at `initial`, clamped to the given bounds.
    pub fn new(initial: usize, min_budget: usize, max_budget: usize) -> Self {
        Self {
            min_budget,
            max_budget,
            grow_at: 0.22,
            shrink_at: 0.06,
            window: 8,
            current: initial.clamp(min_budget, max_budget),
            history: VecDeque::new(),
            occupancy_aware: false,
            ewma: None,
            ewma_alpha: 0.25,
            occ_frac: 0.0,
            since_decision: 0,
        }
    }

    /// Enable the occupancy-aware mode: per-slot acceptance-rate EWMA
    /// replaces the raw window average, and [`AdaptiveBudget::budget`] is
    /// capped by the latest occupancy fraction fed through
    /// [`AdaptiveBudget::observe_occupancy`].
    pub fn with_occupancy(mut self) -> Self {
        self.occupancy_aware = true;
        self
    }

    /// Whether the occupancy-aware mode is enabled.
    pub fn occupancy_aware(&self) -> bool {
        self.occupancy_aware
    }

    /// Feed the scheduler's occupancy signal: `live` slots currently
    /// decoding out of `slots` total. No-op unless occupancy mode is on.
    pub fn observe_occupancy(&mut self, live: usize, slots: usize) {
        if !self.occupancy_aware {
            return;
        }
        self.occ_frac = if slots <= 1 || live <= 1 {
            0.0
        } else {
            ((live - 1) as f64 / (slots - 1) as f64).clamp(0.0, 1.0)
        };
    }

    /// Largest budget the occupancy schedule allows right now: the full
    /// `[min_budget, max_budget]` range for a lone slot, shrinking
    /// linearly to `min_budget` at full occupancy.
    fn occupancy_cap(&self) -> usize {
        let span = (self.max_budget - self.min_budget) as f64;
        let cut = (self.occ_frac * span).floor() as usize;
        self.max_budget.saturating_sub(cut).max(self.min_budget)
    }

    /// Budget to use for the next round.
    pub fn budget(&self) -> usize {
        if self.occupancy_aware {
            self.current.min(self.occupancy_cap()).max(self.min_budget)
        } else {
            self.current
        }
    }

    /// Record a round's outcome and possibly adapt.
    pub fn observe(&mut self, accept_len: usize, budget_offered: usize) {
        if self.occupancy_aware {
            self.observe_ewma(accept_len, budget_offered);
            return;
        }
        self.history.push_back((accept_len, budget_offered));
        if self.history.len() < self.window {
            return;
        }
        while self.history.len() > self.window {
            self.history.pop_front();
        }
        let (acc, off): (usize, usize) = self
            .history
            .iter()
            .fold((0, 0), |(a, o), (ai, oi)| (a + ai, o + oi));
        if off == 0 {
            return;
        }
        let utilization = acc as f64 / off as f64;
        let next = if utilization > self.grow_at {
            (self.current * 2).min(self.max_budget)
        } else if utilization < self.shrink_at {
            (self.current / 2).max(self.min_budget)
        } else {
            self.current
        };
        if next != self.current {
            self.current = next;
            self.history.clear(); // fresh evidence at the new operating point
        }
    }

    /// Occupancy-mode observation path: exponentially-weighted per-slot
    /// acceptance rate, MIMD decision every `window` rounds.
    fn observe_ewma(&mut self, accept_len: usize, budget_offered: usize) {
        if budget_offered == 0 {
            return;
        }
        let u = accept_len as f64 / budget_offered as f64;
        self.ewma = Some(match self.ewma {
            None => u, // seed with the first sample
            Some(prev) => self.ewma_alpha * u + (1.0 - self.ewma_alpha) * prev,
        });
        self.since_decision += 1;
        if self.since_decision < self.window {
            return;
        }
        self.since_decision = 0;
        let utilization = self.ewma.unwrap_or(0.0);
        let next = if utilization > self.grow_at {
            (self.current * 2).min(self.max_budget)
        } else if utilization < self.shrink_at {
            (self.current / 2).max(self.min_budget)
        } else {
            self.current
        };
        self.current = next;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_under_high_utilization() {
        let mut a = AdaptiveBudget::new(8, 4, 64);
        for _ in 0..16 {
            a.observe(4, a.budget()); // 50% utilization at M=8
        }
        assert!(a.budget() > 8, "should grow: {}", a.budget());
        assert!(a.budget() <= 64);
    }

    #[test]
    fn shrinks_under_sparse_acceptance() {
        let mut a = AdaptiveBudget::new(64, 4, 64);
        for _ in 0..32 {
            a.observe(0, a.budget());
        }
        assert_eq!(a.budget(), 4);
    }

    #[test]
    fn stable_in_the_dead_band() {
        let mut a = AdaptiveBudget::new(16, 4, 64);
        for _ in 0..32 {
            a.observe(2, 16); // 12.5% — between shrink_at and grow_at
        }
        assert_eq!(a.budget(), 16);
    }

    #[test]
    fn respects_bounds() {
        let mut a = AdaptiveBudget::new(64, 4, 64);
        for _ in 0..64 {
            a.observe(40, a.budget());
        }
        assert_eq!(a.budget(), 64);
        let mut b = AdaptiveBudget::new(4, 4, 64);
        for _ in 0..64 {
            b.observe(0, b.budget());
        }
        assert_eq!(b.budget(), 4);
    }

    #[test]
    fn decisions_wait_for_a_full_window() {
        let mut a = AdaptiveBudget::new(16, 4, 64);
        for _ in 0..7 {
            a.observe(16, 16);
        }
        assert_eq!(a.budget(), 16, "no decision before the window fills");
        a.observe(16, 16);
        assert!(a.budget() > 16);
    }

    #[test]
    fn occupancy_caps_budget_at_fixed_utilization() {
        // high utilization would drive the MIMD point to max; rising
        // occupancy must still pull the effective budget down
        let mut a = AdaptiveBudget::new(16, 4, 64).with_occupancy();
        for _ in 0..32 {
            a.observe(32, 64); // 50% utilization — grow regime
        }
        a.observe_occupancy(1, 8);
        let lone = a.budget();
        a.observe_occupancy(4, 8);
        let mid = a.budget();
        a.observe_occupancy(8, 8);
        let full = a.budget();
        assert!(
            lone >= mid && mid >= full,
            "budget must be monotone non-increasing in occupancy: {lone} {mid} {full}"
        );
        assert_eq!(full, 4, "full occupancy pins the budget at min_budget");
        assert_eq!(lone, 64, "a lone slot keeps the full MIMD operating point");
    }

    #[test]
    fn occupancy_mode_respects_bounds() {
        let mut a = AdaptiveBudget::new(8, 4, 64).with_occupancy();
        a.observe_occupancy(8, 8);
        for _ in 0..64 {
            a.observe(40, a.budget().max(1));
            assert!((4..=64).contains(&a.budget()));
        }
        a.observe_occupancy(1, 8);
        for _ in 0..64 {
            a.observe(0, a.budget().max(1));
            assert!((4..=64).contains(&a.budget()));
        }
    }

    #[test]
    fn occupancy_signal_is_inert_without_the_mode() {
        let mut a = AdaptiveBudget::new(16, 4, 64);
        a.observe_occupancy(8, 8); // no-op: occupancy mode off
        assert_eq!(a.budget(), 16);
        assert!(!a.occupancy_aware());
    }
}
