//! Adaptive tree-budget policy.
//!
//! The paper's E2 takeaway: "tree speculation has a configuration-
//! dependent sweet spot; lightweight budget sweeps **or adaptive
//! policies** are necessary for stable performance in deployment", and
//! its conclusion lists adaptive branching policies as future work. This
//! module implements that policy: a multiplicative-increase /
//! multiplicative-decrease controller on the node budget M driven by the
//! recent *budget utilization* (accepted draft tokens per offered node).
//!
//! Rationale from the E2 economics: the marginal verification cost grows
//! with the padded S variant while the marginal benefit is the extra
//! acceptance probability at deeper/wider positions. When recent rounds
//! accept a large fraction of the offered budget, a larger tree likely
//! pays for itself; when acceptance is sparse, a smaller tree cuts
//! mask/tensorize/verify overhead without losing accepted tokens.

use std::collections::VecDeque;

/// Multiplicative-increase / multiplicative-decrease controller on the
/// tree node budget M, driven by recent budget utilization.
#[derive(Clone, Debug)]
pub struct AdaptiveBudget {
    /// Smallest budget the controller may choose.
    pub min_budget: usize,
    /// Largest budget the controller may choose.
    pub max_budget: usize,
    /// Utilization above this doubles the budget.
    pub grow_at: f64,
    /// Utilization below this halves the budget.
    pub shrink_at: f64,
    /// Rounds averaged per decision.
    pub window: usize,
    current: usize,
    history: VecDeque<(usize, usize)>, // (accept_len, budget_offered)
}

impl AdaptiveBudget {
    /// A controller starting at `initial`, clamped to the given bounds.
    pub fn new(initial: usize, min_budget: usize, max_budget: usize) -> Self {
        Self {
            min_budget,
            max_budget,
            grow_at: 0.22,
            shrink_at: 0.06,
            window: 8,
            current: initial.clamp(min_budget, max_budget),
            history: VecDeque::new(),
        }
    }

    /// Budget to use for the next round.
    pub fn budget(&self) -> usize {
        self.current
    }

    /// Record a round's outcome and possibly adapt.
    pub fn observe(&mut self, accept_len: usize, budget_offered: usize) {
        self.history.push_back((accept_len, budget_offered));
        if self.history.len() < self.window {
            return;
        }
        while self.history.len() > self.window {
            self.history.pop_front();
        }
        let (acc, off): (usize, usize) = self
            .history
            .iter()
            .fold((0, 0), |(a, o), (ai, oi)| (a + ai, o + oi));
        if off == 0 {
            return;
        }
        let utilization = acc as f64 / off as f64;
        let next = if utilization > self.grow_at {
            (self.current * 2).min(self.max_budget)
        } else if utilization < self.shrink_at {
            (self.current / 2).max(self.min_budget)
        } else {
            self.current
        };
        if next != self.current {
            self.current = next;
            self.history.clear(); // fresh evidence at the new operating point
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_under_high_utilization() {
        let mut a = AdaptiveBudget::new(8, 4, 64);
        for _ in 0..16 {
            a.observe(4, a.budget()); // 50% utilization at M=8
        }
        assert!(a.budget() > 8, "should grow: {}", a.budget());
        assert!(a.budget() <= 64);
    }

    #[test]
    fn shrinks_under_sparse_acceptance() {
        let mut a = AdaptiveBudget::new(64, 4, 64);
        for _ in 0..32 {
            a.observe(0, a.budget());
        }
        assert_eq!(a.budget(), 4);
    }

    #[test]
    fn stable_in_the_dead_band() {
        let mut a = AdaptiveBudget::new(16, 4, 64);
        for _ in 0..32 {
            a.observe(2, 16); // 12.5% — between shrink_at and grow_at
        }
        assert_eq!(a.budget(), 16);
    }

    #[test]
    fn respects_bounds() {
        let mut a = AdaptiveBudget::new(64, 4, 64);
        for _ in 0..64 {
            a.observe(40, a.budget());
        }
        assert_eq!(a.budget(), 64);
        let mut b = AdaptiveBudget::new(4, 4, 64);
        for _ in 0..64 {
            b.observe(0, b.budget());
        }
        assert_eq!(b.budget(), 4);
    }

    #[test]
    fn decisions_wait_for_a_full_window() {
        let mut a = AdaptiveBudget::new(16, 4, 64);
        for _ in 0..7 {
            a.observe(16, 16);
        }
        assert_eq!(a.budget(), 16, "no decision before the window fills");
        a.observe(16, 16);
        assert!(a.budget() > 16);
    }
}
