fn main() -> anyhow::Result<()> {
    eagle_pangu::cli::main_entry()
}
