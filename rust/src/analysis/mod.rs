//! `static_check`: a repo-specific, dependency-free static-analysis
//! driver that enforces the invariants this codebase's correctness
//! arguments lean on — at the source level, where they erode.
//!
//! The paper's determinism and accelerator-safety claims are carried by
//! conventions no compiler checks: scheduler/replay code must stay on
//! the virtual clock (PR 7/9's bit-identical replay contract), index
//! paths must not smuggle sentinels through `as usize`, the serve path
//! must not panic, and sibling artifacts (the Python AOT exporter, the
//! RPC wire-tag test, the README flag tables) must not drift from the
//! Rust schemas they mirror. Each rule here turns one such convention
//! into a build-gating check; `docs/STATIC_ANALYSIS.md` is the rule
//! catalog with rationale and worked examples.
//!
//! Deliberate exceptions are *audited*, not silent: a
//! `// lint: allow(rule-id) — reason` pragma on (or directly above)
//! the offending line waives the finding, and a pragma without a
//! reason is itself a finding (`bad-pragma`). The driver exits
//! non-zero on any unwaived finding, so CI gates on it (the
//! `static-analysis` job).
//!
//! Everything is lexer-level — see [`lexer`] — because the image
//! vendors no `syn`/`proc-macro2`; rules in [`rules`] take in-memory
//! scanned inputs so the fixture suite can drive each one directly.

pub mod lexer;
pub mod rules;

use crate::json::Json;
use anyhow::{Context, Result};
use lexer::ScannedFile;
use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

/// How bad a finding is. Both severities gate the exit code — `Warn`
/// marks rules where the fix is documentation, not code.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    /// Violates a correctness/determinism invariant.
    Error,
    /// Violates a documentation-parity invariant.
    Warn,
}

impl Severity {
    /// Stable lower-case name used in text and JSON output.
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warn => "warn",
        }
    }
}

/// One rule violation at a source location.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Repo-relative, `/`-separated path.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Rule id from [`RULES`].
    pub rule: &'static str,
    /// Severity (from the rule).
    pub severity: Severity,
    /// Human-readable explanation, one line.
    pub message: String,
    /// Whether an audited pragma waives this finding.
    pub allowed: bool,
    /// The pragma's reason, when waived.
    pub reason: Option<String>,
}

impl Finding {
    /// The driver's one-line text rendering:
    /// `file:line  RULE_ID  severity  message`.
    pub fn render(&self) -> String {
        let allowed = if self.allowed { "  [allowed]" } else { "" };
        format!(
            "{}:{}  {}  {}  {}{}",
            self.file,
            self.line,
            self.rule,
            self.severity.as_str(),
            self.message,
            allowed
        )
    }
}

/// Catalog entry for one rule.
#[derive(Clone, Copy, Debug)]
pub struct RuleInfo {
    /// Stable id, used in pragmas and output.
    pub id: &'static str,
    /// Severity of this rule's findings.
    pub severity: Severity,
    /// One-line summary (mirrored in `docs/STATIC_ANALYSIS.md`).
    pub summary: &'static str,
}

/// The rule catalog. Ids are stable: pragmas, CI logs and the docs all
/// key on them.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "wall-clock",
        severity: Severity::Error,
        summary: "Instant::now/SystemTime::now outside the audited timing modules",
    },
    RuleInfo {
        id: "signed-cast",
        severity: Severity::Error,
        summary: "raw `as usize` in index paths (tree/, cache/); use util::idx",
    },
    RuleInfo {
        id: "hot-unwrap",
        severity: Severity::Error,
        summary: ".unwrap()/.expect( in non-test serve-path modules",
    },
    RuleInfo {
        id: "unsafe-code",
        severity: Severity::Error,
        summary: "unsafe blocks/impls in the library (crate forbids unsafe_code)",
    },
    RuleInfo {
        id: "artifact-drift",
        severity: Severity::Error,
        summary: "aot.py module-name strings that break the ModuleKey round-trip",
    },
    RuleInfo {
        id: "wire-tag",
        severity: Severity::Error,
        summary: "Envelope variants whose wire tag is not pinned in tests/rpc.rs",
    },
    RuleInfo {
        id: "flag-doc",
        severity: Severity::Warn,
        summary: "CLI flags registered in args.rs but absent from README tables",
    },
    RuleInfo {
        id: "bad-pragma",
        severity: Severity::Error,
        summary: "lint pragma with no reason, or naming an unknown rule",
    },
];

/// Look up a rule's catalog entry.
pub fn rule_info(id: &str) -> Option<&'static RuleInfo> {
    RULES.iter().find(|r| r.id == id)
}

/// Files where wall-clock reads are legitimate: the stage timer, the
/// bench harness, and the device runtime (launch timestamps).
pub const WALL_CLOCK_ALLOW: &[&str] =
    &["rust/src/util/timer.rs", "rust/src/util/bench.rs", "rust/src/runtime/pjrt.rs"];

/// Index-path scope for `signed-cast`: modules whose `usize` values
/// index tensors/pools and historically smuggled `-1` sentinels.
pub const SIGNED_CAST_SCOPE: &[&str] = &["rust/src/tree/", "rust/src/cache/"];

/// Serve-path scope for `hot-unwrap`: everything a request traverses
/// between submit and completion.
pub const HOT_UNWRAP_SCOPE: &[&str] = &[
    "rust/src/engine/",
    "rust/src/coordinator/",
    "rust/src/cache/",
    "rust/src/tree/",
    "rust/src/backend/",
    "rust/src/rpc/",
];

/// A completed check run: every finding (waived or not) plus scan
/// statistics, renderable as text lines or the JSON report.
#[derive(Debug, Default)]
pub struct Report {
    /// All findings, file/line ordered.
    pub findings: Vec<Finding>,
    /// Number of source files scanned.
    pub files_scanned: usize,
}

impl Report {
    /// Findings not waived by a pragma — the exit-code gate.
    pub fn active(&self) -> usize {
        self.findings.iter().filter(|f| !f.allowed).count()
    }

    /// Findings waived by an audited pragma.
    pub fn allowed(&self) -> usize {
        self.findings.iter().filter(|f| f.allowed).count()
    }

    /// The machine-readable report (schema documented in
    /// `docs/STATIC_ANALYSIS.md`; shape-checked by `tests/static_check.rs`).
    pub fn to_json(&self) -> Json {
        let rules = Json::Arr(
            RULES
                .iter()
                .map(|r| {
                    let mut o = Json::obj();
                    o.push("id", r.id)
                        .push("severity", r.severity.as_str())
                        .push("summary", r.summary);
                    o
                })
                .collect(),
        );
        let findings = Json::Arr(
            self.findings
                .iter()
                .map(|f| {
                    let mut o = Json::obj();
                    o.push("file", f.file.clone())
                        .push("line", f.line)
                        .push("rule", f.rule)
                        .push("severity", f.severity.as_str())
                        .push("message", f.message.clone())
                        .push("allowed", f.allowed)
                        .push("reason", f.reason.clone().map(Json::Str).unwrap_or(Json::Null));
                    o
                })
                .collect(),
        );
        let mut counts: BTreeMap<&'static str, (usize, usize)> = BTreeMap::new();
        for f in &self.findings {
            let e = counts.entry(f.rule).or_insert((0, 0));
            if f.allowed {
                e.1 += 1;
            } else {
                e.0 += 1;
            }
        }
        let mut per_rule = Json::obj();
        for (rule, (active, allowed)) in counts {
            let mut o = Json::obj();
            o.push("active", active).push("allowed", allowed);
            per_rule.push(rule, o);
        }
        let mut summary = Json::obj();
        summary
            .push("files_scanned", self.files_scanned)
            .push("total", self.findings.len())
            .push("allowed", self.allowed())
            .push("active", self.active())
            .push("per_rule", per_rule);
        let mut root = Json::obj();
        root.push("tool", "static_check")
            .push("rules", rules)
            .push("findings", findings)
            .push("summary", summary);
        root
    }
}

/// Run every rule against the repo rooted at `root` (the directory
/// holding `rust/`, `python/`, `README.md`). Missing sibling artifacts
/// (e.g. no `python/` checkout) skip their rules rather than failing:
/// the checker gates what exists.
pub fn run(root: &Path) -> Result<Report> {
    let mut files: Vec<(String, String)> = Vec::new();
    collect_rust_sources(root, Path::new("rust/src"), &mut files)?;
    files.sort_by(|a, b| a.0.cmp(&b.0));

    let mut scans: Vec<ScannedFile> = Vec::new();
    for (rel, src) in &files {
        scans.push(lexer::scan_rust(rel, src));
    }

    let mut findings: Vec<Finding> = Vec::new();
    for scan in &scans {
        if !WALL_CLOCK_ALLOW.contains(&scan.path.as_str()) {
            findings.extend(rules::wall_clock(scan));
        }
        if SIGNED_CAST_SCOPE.iter().any(|p| scan.path.starts_with(p)) {
            findings.extend(rules::signed_cast(scan));
        }
        if HOT_UNWRAP_SCOPE.iter().any(|p| scan.path.starts_with(p)) {
            findings.extend(rules::hot_unwrap(scan));
        }
        findings.extend(rules::unsafe_code(scan));
    }
    if let Some(lib) = scans.iter().find(|s| s.path == "rust/src/lib.rs") {
        findings.extend(rules::forbid_attr_present(lib));
    }

    // Cross-artifact rules: each needs the raw text of its sibling
    // (string literals survive only in raw text).
    let aot_path = root.join("python/compile/aot.py");
    let aot_scan = match fs::read_to_string(&aot_path) {
        Ok(src) => {
            let scan = lexer::scan_python("python/compile/aot.py", &src);
            findings.extend(rules::artifact_drift(&scan));
            Some(scan)
        }
        Err(_) => None,
    };
    if let Some((rel, raw)) = files.iter().find(|(r, _)| r == "rust/src/rpc/envelope.rs") {
        let tests = fs::read_to_string(root.join("rust/tests/rpc.rs")).unwrap_or_default();
        findings.extend(rules::wire_tag(rel, raw, &tests));
    }
    if let Some((rel, raw)) = files.iter().find(|(r, _)| r == "rust/src/cli/args.rs") {
        let readme = fs::read_to_string(root.join("README.md")).unwrap_or_default();
        findings.extend(rules::flag_doc(rel, raw, &readme));
    }

    // Pragma application + audit. A pragma waives findings of its rule
    // on its own line or the next; a reasonless or unknown-rule pragma
    // is a finding in its own right.
    let mut all_scans: Vec<&ScannedFile> = scans.iter().collect();
    if let Some(s) = aot_scan.as_ref() {
        all_scans.push(s);
    }
    for f in findings.iter_mut() {
        if let Some(scan) = all_scans.iter().find(|s| s.path == f.file) {
            if let Some(p) = scan.pragma_for(f.rule, f.line) {
                if p.reason.is_some() {
                    f.allowed = true;
                    f.reason = p.reason.clone();
                }
            }
        }
    }
    for scan in &all_scans {
        findings.extend(rules::audit_pragmas(scan));
    }

    findings.sort_by(|a, b| (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule)));
    Ok(Report { findings, files_scanned: scans.len() })
}

/// Recursively collect `.rs` files under `root/sub` as
/// `(repo-relative path, contents)`.
fn collect_rust_sources(
    root: &Path,
    sub: &Path,
    out: &mut Vec<(String, String)>,
) -> Result<()> {
    let dir = root.join(sub);
    let entries =
        fs::read_dir(&dir).with_context(|| format!("scanning {}", dir.display()))?;
    for entry in entries {
        let entry = entry?;
        let path = entry.path();
        let rel = sub.join(entry.file_name());
        if path.is_dir() {
            collect_rust_sources(root, &rel, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let src = fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            let rel_str = rel
                .to_string_lossy()
                .replace(std::path::MAIN_SEPARATOR, "/");
            out.push((rel_str, src));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_ids_are_unique_and_known() {
        let mut ids: Vec<_> = RULES.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        let n = ids.len();
        ids.dedup();
        assert_eq!(ids.len(), n, "duplicate rule id in catalog");
        assert!(rule_info("wall-clock").is_some());
        assert!(rule_info("nope").is_none());
    }

    #[test]
    fn render_is_the_documented_line_format() {
        let f = Finding {
            file: "rust/src/x.rs".into(),
            line: 7,
            rule: "wall-clock",
            severity: Severity::Error,
            message: "m".into(),
            allowed: false,
            reason: None,
        };
        assert_eq!(f.render(), "rust/src/x.rs:7  wall-clock  error  m");
    }
}
