//! The rule implementations behind `static_check`. Each rule is a pure
//! function over in-memory scanned input (see [`crate::analysis::lexer`])
//! so the fixture suite can drive every rule directly, without touching
//! the real tree. Scoping (which files a rule sees) lives in the driver
//! ([`crate::analysis::run`]); the rules themselves only match.
//!
//! Rationale, worked examples and the waiver policy for every rule are
//! in `docs/STATIC_ANALYSIS.md`.

use super::lexer::ScannedFile;
use super::{rule_info, Finding};
use crate::config::modules::ModuleKey;

/// Build a finding for `rule` (severity comes from the catalog).
fn mk(file: &str, line: usize, rule: &'static str, message: String) -> Finding {
    let info = rule_info(rule).unwrap_or_else(|| panic!("rule {rule} missing from catalog"));
    Finding {
        file: file.to_string(),
        line,
        rule,
        severity: info.severity,
        message,
        allowed: false,
        reason: None,
    }
}

/// `wall-clock`: `Instant::now` / `SystemTime::now` anywhere outside
/// the audited timing modules. Scheduler, replay and worker logic must
/// stay on the virtual clock ([`ContinuousScheduler::advance_clock`])
/// or measure via [`crate::util::timer::Stopwatch`]; a raw wall-clock
/// read is how bit-identical replay (PR 9) silently breaks.
///
/// [`ContinuousScheduler::advance_clock`]: crate::coordinator::ContinuousScheduler::advance_clock
pub fn wall_clock(f: &ScannedFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for pat in ["Instant::now", "SystemTime::now"] {
            if line.code.contains(pat) {
                out.push(mk(
                    &f.path,
                    i + 1,
                    "wall-clock",
                    format!(
                        "{pat} outside the audited timing modules; measure with \
                         util::timer::Stopwatch or stay on the virtual clock"
                    ),
                ));
            }
        }
    }
    out
}

/// `signed-cast`: raw `as usize` in index paths. A widening `u32 ->
/// usize` is fine but indistinguishable at a glance from an `i64 ->
/// usize` that wraps a `-1` sentinel into `2^64-1`; `util::idx` gives
/// both shapes a name (`udx` proves the source unsigned,
/// `checked_row`/`checked_col` fail typed at external boundaries).
pub fn signed_cast(f: &ScannedFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        if line.code.contains("as usize") {
            out.push(mk(
                &f.path,
                i + 1,
                "signed-cast",
                "raw `as usize` in an index path; use util::idx::udx (unsigned \
                 widening) or checked_row/checked_col (fallible boundary)"
                    .to_string(),
            ));
        }
    }
    out
}

/// `hot-unwrap`: `.unwrap()` / `.expect(` in non-test serve-path
/// modules. A panic mid-request poisons locks and kills the worker;
/// serve-path code returns typed errors. Lock-poisoning `.expect`s and
/// other deliberate panic policies carry a reasoned pragma.
pub fn hot_unwrap(f: &ScannedFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for pat in [".unwrap()", ".expect("] {
            if line.code.contains(pat) {
                out.push(mk(
                    &f.path,
                    i + 1,
                    "hot-unwrap",
                    format!(
                        "{pat} on the serve path; return a typed error (deliberate \
                         panic policies need a reasoned pragma)"
                    ),
                ));
            }
        }
    }
    out
}

/// `unsafe-code`: any `unsafe` token in library source. The crate root
/// carries `#![forbid(unsafe_code)]`, so this can only trip in code the
/// compiler has not seen yet (a new bin/test crate wired outside the
/// lib) — the rule keeps the invariant visible at review time.
pub fn unsafe_code(f: &ScannedFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let has_unsafe_token = line
            .code
            .split(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
            .any(|tok| tok == "unsafe");
        if has_unsafe_token {
            out.push(mk(
                &f.path,
                i + 1,
                "unsafe-code",
                "`unsafe` in library source; the crate forbids unsafe_code (move \
                 allocator-style shims to tests/support/)"
                    .to_string(),
            ));
        }
    }
    out
}

/// `unsafe-code` (companion): the crate root must carry
/// `#![forbid(unsafe_code)]` — `forbid`, not `deny`, so no inner
/// `#[allow]` can reopen it.
pub fn forbid_attr_present(lib: &ScannedFile) -> Vec<Finding> {
    let present = lib
        .lines
        .iter()
        .any(|l| l.code.replace(' ', "").contains("#![forbid(unsafe_code)]"));
    if present {
        Vec::new()
    } else {
        vec![mk(
            &lib.path,
            1,
            "unsafe-code",
            "crate root is missing #![forbid(unsafe_code)]".to_string(),
        )]
    }
}

/// `artifact-drift`: every module-name string the Python AOT exporter
/// builds must round-trip through the `ModuleKey` schema
/// (`rust/src/config/modules.rs`) — the Rust loader resolves artifacts
/// by parsing exactly these names, so an unparseable f-string is a
/// module that compiles on the Python side and silently never loads.
pub fn artifact_drift(aot: &ScannedFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, line) in aot.lines.iter().enumerate() {
        for s in extract_quoted(&line.code) {
            let name = subst_placeholders(&s);
            if !is_module_name_candidate(&name) {
                continue;
            }
            if !valid_module_name(&name) {
                out.push(mk(
                    &aot.path,
                    i + 1,
                    "artifact-drift",
                    format!(
                        "module-name string \"{s}\" does not round-trip through the \
                         ModuleKey schema (rust/src/config/modules.rs)"
                    ),
                ));
            }
        }
    }
    out
}

/// All single-line quoted string contents in a line of Python code.
fn extract_quoted(code: &str) -> Vec<String> {
    let mut out = Vec::new();
    let b = code.as_bytes();
    let mut j = 0;
    while j < b.len() {
        let q = b[j];
        if q == b'"' || q == b'\'' {
            let mut k = j + 1;
            let mut s = String::new();
            let mut closed = false;
            while k < b.len() {
                if b[k] == b'\\' && k + 1 < b.len() {
                    s.push(b[k + 1] as char);
                    k += 2;
                    continue;
                }
                if b[k] == q {
                    closed = true;
                    break;
                }
                s.push(b[k] as char);
                k += 1;
            }
            if closed {
                out.push(s);
                j = k + 1;
                continue;
            }
        }
        j += 1;
    }
    out
}

/// Replace every `{placeholder}` in an f-string body with a digit, so
/// shape validation sees a concrete name (`teacher_fused_s{s}` ->
/// `teacher_fused_s8`).
fn subst_placeholders(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut depth = 0u32;
    for c in s.chars() {
        match c {
            '{' => {
                if depth == 0 {
                    out.push('8');
                }
                depth += 1;
            }
            '}' => depth = depth.saturating_sub(1),
            _ if depth == 0 => out.push(c),
            _ => {}
        }
    }
    out
}

/// Whether a (placeholder-substituted) string is shaped like a module
/// name: schema prefix plus an `_s<digits>` / `_n<digits>` size spec.
/// Role strings (`"teacher"`), manifest keys (`"teacher_s_variants"`)
/// and file names (`"weights_teacher.npz"`) all fail this shape test.
fn is_module_name_candidate(name: &str) -> bool {
    let prefixed = ["teacher_", "draft_", "kv_append_"]
        .iter()
        .any(|p| name.starts_with(p));
    if !prefixed {
        return false;
    }
    name.as_bytes().windows(3).any(|w| {
        w[0] == b'_' && (w[1] == b's' || w[1] == b'n') && w[2].is_ascii_digit()
    })
}

/// Whether a concrete name belongs to the artifact schema: a step
/// module (`ModuleKey` round-trip) or a session scatter-update module
/// (`kv_append_{teacher|draft}_n{N}`, parsed by `Capabilities`).
fn valid_module_name(name: &str) -> bool {
    if let Some(rest) = name.strip_prefix("kv_append_") {
        return ["teacher", "draft"].iter().any(|role| {
            rest.strip_prefix(role)
                .and_then(|r| r.strip_prefix("_n"))
                .is_some_and(|n| !n.is_empty() && n.bytes().all(|b| b.is_ascii_digit()))
        });
    }
    ModuleKey::parse(name).is_some_and(|k| k.artifact_name() == name)
}

/// `wire-tag`: every `Envelope` variant must map to a distinct wire tag
/// in `kind_str()`, and every tag must be pinned (appear as a string
/// literal) in `rust/tests/rpc.rs` — the channel codec is replaceable
/// (PR 8), so the tags, not the Rust enum, are the compatibility
/// surface. Works on raw source: string literals are the payload here.
pub fn wire_tag(envelope_path: &str, envelope_raw: &str, rpc_tests_raw: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let lines: Vec<&str> = envelope_raw.lines().collect();

    // Variants of `pub enum Envelope { ... }`.
    let mut variants: Vec<(String, usize)> = Vec::new();
    if let Some(start) = lines.iter().position(|l| l.contains("pub enum Envelope")) {
        for (off, l) in lines[start + 1..].iter().enumerate() {
            let t = l.trim();
            if t == "}" {
                break;
            }
            if t.starts_with("//") || t.starts_with('#') || t.is_empty() {
                continue;
            }
            if t.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
                let name: String = t
                    .chars()
                    .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                    .collect();
                variants.push((name, start + 2 + off));
            }
        }
    } else {
        out.push(mk(
            envelope_path,
            1,
            "wire-tag",
            "no `pub enum Envelope` found to check".to_string(),
        ));
        return out;
    }

    // `Envelope::Variant(..) => "tag"` arms (in kind_str).
    let mut arms: Vec<(String, String, usize)> = Vec::new();
    for (i, l) in lines.iter().enumerate() {
        if let Some(pos) = l.find("Envelope::") {
            let rest = &l[pos + "Envelope::".len()..];
            let name: String = rest
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            if let Some(arrow) = rest.find("=>") {
                let after = &rest[arrow + 2..];
                if let Some(q0) = after.find('"') {
                    if let Some(q1) = after[q0 + 1..].find('"') {
                        let tag = after[q0 + 1..q0 + 1 + q1].to_string();
                        arms.push((name, tag, i + 1));
                    }
                }
            }
        }
    }

    for (variant, vline) in &variants {
        let arm = arms.iter().find(|(v, _, _)| v == variant);
        match arm {
            None => out.push(mk(
                envelope_path,
                *vline,
                "wire-tag",
                format!("Envelope::{variant} has no wire tag in kind_str()"),
            )),
            Some((_, tag, aline)) => {
                if arms.iter().filter(|(_, t, _)| t == tag).count() > 1 {
                    out.push(mk(
                        envelope_path,
                        *aline,
                        "wire-tag",
                        format!("wire tag \"{tag}\" is assigned to more than one variant"),
                    ));
                }
                if !rpc_tests_raw.contains(&format!("\"{tag}\"")) {
                    out.push(mk(
                        envelope_path,
                        *aline,
                        "wire-tag",
                        format!(
                            "wire tag \"{tag}\" (Envelope::{variant}) is not pinned in \
                             rust/tests/rpc.rs"
                        ),
                    ));
                }
            }
        }
    }
    out
}

/// `flag-doc`: every flag registered in the `args.rs` registries
/// (`TOGGLE_FLAGS`, `VALUED`) must appear as `--flag` somewhere in the
/// README — an undocumented flag is a contract users can only discover
/// by reading source. Works on raw source (the registry is literals).
pub fn flag_doc(args_path: &str, args_raw: &str, readme: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut in_registry = false;
    for (i, l) in args_raw.lines().enumerate() {
        let t = l.trim();
        if t.starts_with("pub const TOGGLE_FLAGS") || t.starts_with("const VALUED") {
            in_registry = true;
        }
        if in_registry {
            for flag in extract_quoted(l) {
                if !readme.contains(&format!("--{flag}")) {
                    out.push(mk(
                        args_path,
                        i + 1,
                        "flag-doc",
                        format!(
                            "flag --{flag} is registered in cli/args.rs but missing \
                             from the README flag tables"
                        ),
                    ));
                }
            }
            if t.contains("];") {
                in_registry = false;
            }
        }
    }
    out
}

/// `bad-pragma`: every waiver must be audited — a reason is mandatory,
/// and the rule id must exist (a typo'd id would otherwise waive
/// nothing *silently*).
pub fn audit_pragmas(f: &ScannedFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for p in &f.pragmas {
        if rule_info(&p.rule).is_none() {
            out.push(mk(
                &f.path,
                p.line,
                "bad-pragma",
                format!("pragma names unknown rule \"{}\"", p.rule),
            ));
        } else if p.reason.is_none() {
            out.push(mk(
                &f.path,
                p.line,
                "bad-pragma",
                format!(
                    "pragma allow({}) carries no reason; write `lint: allow({}) — <why>`",
                    p.rule, p.rule
                ),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::{scan_python, scan_rust};

    #[test]
    fn wall_clock_flags_reads_not_mentions() {
        let src = "use std::time::Instant;\nlet t = Instant::now();\n// Instant::now in prose\n#[cfg(test)]\nmod t { fn f() { let x = Instant::now(); } }";
        let f = scan_rust("rust/src/x.rs", src);
        let got = wall_clock(&f);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].line, 2);
    }

    #[test]
    fn signed_cast_ignores_strings_and_tests() {
        let src = "let i = j as usize;\nlet s = \"as usize\";";
        let f = scan_rust("rust/src/tree/x.rs", src);
        let got = signed_cast(&f);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].line, 1);
    }

    #[test]
    fn hot_unwrap_distinguishes_unwrap_or() {
        let src = "let a = x.unwrap_or(0);\nlet b = y.unwrap();\nlet c = z.expect(\"m\");";
        let f = scan_rust("rust/src/engine/x.rs", src);
        let got = hot_unwrap(&f);
        assert_eq!(got.len(), 2);
        assert_eq!((got[0].line, got[1].line), (2, 3));
    }

    #[test]
    fn unsafe_token_matches_word_not_ident() {
        let src = "#![forbid(unsafe_code)]\nunsafe impl Send for X {}";
        let f = scan_rust("rust/src/x.rs", src);
        let got = unsafe_code(&f);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].line, 2);
        assert!(forbid_attr_present(&f).is_empty());
        let g = scan_rust("rust/src/lib.rs", "pub mod x;");
        assert_eq!(forbid_attr_present(&g).len(), 1);
    }

    #[test]
    fn artifact_drift_validates_module_shapes() {
        let src = "\n".to_string()
            + "m[f\"teacher_fused_s{s}\"] = 1\n"
            + "m[f\"teacher_fused_b{b}_s{s}\"] = 1\n"
            + "m[f\"kv_append_draft_n{N}\"] = 1\n"
            + "role = \"teacher\"\n"
            + "key = \"teacher_s_variants\"\n"
            + "path = f\"{name}.hlo.txt\"\n"
            + "bad = f\"teacher_fussed_s{s}\"\n"
            + "bad2 = f\"kv_append_coach_n{N}\"\n";
        let f = scan_python("python/compile/aot.py", &src);
        let got = artifact_drift(&f);
        let lines: Vec<usize> = got.iter().map(|g| g.line).collect();
        assert_eq!(lines, vec![8, 9], "only the two drifted names: {got:?}");
    }

    #[test]
    fn wire_tag_checks_pinning_and_uniqueness() {
        let envelope = "pub enum Envelope {\n    Submit(S),\n    Abort(A),\n}\nimpl Envelope {\n    pub fn kind_str(&self) -> &'static str {\n        match self {\n            Envelope::Submit(_) => \"submit\",\n            Envelope::Abort(_) => \"abort\",\n        }\n    }\n}";
        let ok = wire_tag("e.rs", envelope, "let t = [\"submit\", \"abort\"];");
        assert!(ok.is_empty(), "{ok:?}");
        let missing = wire_tag("e.rs", envelope, "let t = [\"submit\"];");
        assert_eq!(missing.len(), 1);
        assert!(missing[0].message.contains("\"abort\""));
        let dup = envelope.replace("\"abort\"", "\"submit\"");
        let dupped = wire_tag("e.rs", &dup, "let t = [\"submit\"];");
        assert!(dupped.iter().any(|f| f.message.contains("more than one")));
    }

    #[test]
    fn flag_doc_reports_undocumented_flags() {
        let args = "pub const TOGGLE_FLAGS: &[&str] = &[\"pipelining\"];\nconst VALUED: &[&str] = &[\n    \"seed\", \"workers\",\n];\nfn other() { let x = \"not-a-flag\"; }";
        let readme = "Use `--pipelining on` and `--seed 7`.";
        let got = flag_doc("a.rs", args, readme);
        assert_eq!(got.len(), 1);
        assert!(got[0].message.contains("--workers"));
    }

    #[test]
    fn pragma_audit_requires_reason_and_known_rule() {
        let src = "fn f() {}\n// lint: allow(wall-clock)\n// lint: allow(not-a-rule) — because\n// lint: allow(hot-unwrap) — lock poisoning is fatal here\n";
        let f = scan_rust("rust/src/x.rs", src);
        let got = audit_pragmas(&f);
        assert_eq!(got.len(), 2);
        assert!(got[0].message.contains("no reason"));
        assert!(got[1].message.contains("unknown rule"));
    }
}
