//! A dependency-free, lexer-level scanner for the `static_check` driver.
//!
//! The rules in [`crate::analysis::rules`] match on *sanitized* source:
//! comment bodies and string/char-literal contents are blanked so that a
//! doc comment mentioning `Instant::now` or a log message containing
//! `.unwrap()` can never produce a finding. The scanner is a small
//! state machine — not a parser — which is exactly the level the rules
//! need (token presence, brace depth, attribute adjacency) and keeps
//! the checker free of `syn`/`proc-macro2` (the image vendors no such
//! crates; see ISSUE/ROADMAP).
//!
//! Beyond sanitizing, the scanner tracks two pieces of line-level
//! context the rules depend on:
//!
//! * **test spans** — brace spans introduced by a `#[cfg(test)]` or
//!   `#[test]` attribute are flagged `in_test`, so rules can exempt
//!   test code without path heuristics;
//! * **pragmas** — audited waivers of the form
//!   `// lint: allow(RULE_ID) — <reason>` (or `# lint: ...` in Python),
//!   attached to the same line and the line immediately after, so a
//!   pragma can sit on its own line above the finding it waives.

/// One audited `lint: allow(...)` waiver extracted from a comment.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Pragma {
    /// 1-based line the pragma comment sits on.
    pub line: usize,
    /// Rule id being waived, e.g. `wall-clock`.
    pub rule: String,
    /// The justification after the rule id; `None` when the author
    /// omitted it (which is itself a `bad-pragma` finding).
    pub reason: Option<String>,
}

/// One source line after sanitizing.
#[derive(Clone, Debug)]
pub struct ScannedLine {
    /// The line with comment bodies and literal contents blanked.
    /// Byte offsets are *not* preserved (blanked spans collapse), but
    /// token adjacency is.
    pub code: String,
    /// Whether the line sits inside a `#[cfg(test)]` / `#[test]` span.
    pub in_test: bool,
}

/// A scanned source file: sanitized lines plus extracted pragmas.
#[derive(Clone, Debug)]
pub struct ScannedFile {
    /// Repo-relative path, `/`-separated.
    pub path: String,
    /// Sanitized lines, index 0 = line 1.
    pub lines: Vec<ScannedLine>,
    /// All pragmas found in comments, in line order.
    pub pragmas: Vec<Pragma>,
}

impl ScannedFile {
    /// The pragma (if any) waiving `rule` at 1-based `line`: same-line
    /// or immediately-preceding-line pragmas apply.
    pub fn pragma_for(&self, rule: &str, line: usize) -> Option<&Pragma> {
        self.pragmas
            .iter()
            .find(|p| p.rule == rule && (p.line == line || p.line + 1 == line))
    }
}

/// Parse a comment body (text after `//` or `#`) as a lint pragma.
/// Accepts `lint: allow(rule-id) — reason`, with `-`, `--` or `—` as
/// the reason separator; returns `(rule, reason)`.
pub fn parse_pragma(comment: &str) -> Option<(String, Option<String>)> {
    let t = comment.trim();
    let rest = t.strip_prefix("lint:")?.trim_start();
    let rest = rest.strip_prefix("allow")?.trim_start();
    let rest = rest.strip_prefix('(')?;
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    if rule.is_empty() {
        return None;
    }
    let tail = rest[close + 1..].trim();
    let reason = ["—", "--", "-"]
        .iter()
        .find_map(|sep| tail.strip_prefix(sep))
        .map(|r| r.trim())
        .filter(|r| !r.is_empty())
        .map(|r| r.to_string());
    Some((rule, reason))
}

/// Lexer states for the Rust scanner.
enum St {
    Code,
    /// Inside `/* ... */`, with nesting depth.
    Block(u32),
    /// Inside `"..."`.
    Str,
    /// Inside `r##"..."##` with the given hash count.
    RawStr(u32),
}

/// Scan Rust source: strip comments and literals, track test spans,
/// collect pragmas. `path` is recorded verbatim in the result.
pub fn scan_rust(path: &str, src: &str) -> ScannedFile {
    let mut lines: Vec<ScannedLine> = Vec::new();
    let mut pragmas: Vec<Pragma> = Vec::new();
    let mut st = St::Code;

    // Test-span tracking: brace depth, and the stack of depths at which
    // a test-attributed item opened. `pending_test` is set when a
    // `#[cfg(test)]` / `#[test]` attribute is seen and consumed by the
    // next `{` at the then-current depth.
    let mut depth: i64 = 0;
    let mut test_open_depths: Vec<i64> = Vec::new();
    let mut pending_test = false;

    for (i, raw) in src.lines().enumerate() {
        let lineno = i + 1;
        let mut code = String::with_capacity(raw.len());
        let mut comment_text = String::new();
        let in_test_at_start = !test_open_depths.is_empty();

        // An attribute at line start must arm `pending_test` *before*
        // brace processing, so `#[cfg(test)] mod tests {` on one line
        // still opens a test span. `line_test` latches if the line was
        // inside a test span at *any* point (a span that opens and
        // closes within the line still marks it).
        let lead = raw.trim_start();
        if lead.starts_with("#[cfg(test)]") || lead.starts_with("#[test]") {
            pending_test = true;
        }
        let mut line_test = in_test_at_start;

        let b = raw.as_bytes();
        let mut j = 0;
        while j < b.len() {
            match st {
                St::Block(ref mut d) => {
                    if b[j] == b'/' && j + 1 < b.len() && b[j + 1] == b'*' {
                        *d += 1;
                        j += 2;
                    } else if b[j] == b'*' && j + 1 < b.len() && b[j + 1] == b'/' {
                        *d -= 1;
                        j += 2;
                        if *d == 0 {
                            st = St::Code;
                            code.push(' ');
                        }
                    } else {
                        j += 1;
                    }
                }
                St::Str => {
                    if b[j] == b'\\' {
                        j += 2;
                    } else if b[j] == b'"' {
                        st = St::Code;
                        code.push('"');
                        j += 1;
                    } else {
                        j += 1;
                    }
                }
                St::RawStr(h) => {
                    if b[j] == b'"' {
                        let hs = b[j + 1..].iter().take_while(|&&c| c == b'#').count();
                        if hs as u32 >= h {
                            st = St::Code;
                            code.push('"');
                            j += 1 + h as usize;
                        } else {
                            j += 1;
                        }
                    } else {
                        j += 1;
                    }
                }
                St::Code => {
                    let c = b[j];
                    if c == b'/' && j + 1 < b.len() && b[j + 1] == b'/' {
                        comment_text.push_str(&raw[j + 2..]);
                        break; // rest of line is a comment
                    } else if c == b'/' && j + 1 < b.len() && b[j + 1] == b'*' {
                        st = St::Block(1);
                        j += 2;
                    } else if c == b'"' {
                        // maybe a raw string start already consumed `r#*`?
                        code.push('"');
                        st = St::Str;
                        j += 1;
                    } else if (c == b'r' || c == b'b')
                        && !prev_is_ident(&code)
                        && raw_str_hashes(&b[j..]).is_some()
                    {
                        let (skip, h) = raw_str_hashes(&b[j..]).expect("checked above");
                        code.push('"');
                        st = St::RawStr(h);
                        j += skip;
                    } else if c == b'\'' {
                        // char literal vs lifetime
                        if let Some(adv) = char_literal_len(&b[j..]) {
                            code.push('\'');
                            code.push('\'');
                            j += adv;
                        } else {
                            code.push('\'');
                            j += 1;
                        }
                    } else {
                        if c == b'{' {
                            if pending_test {
                                test_open_depths.push(depth);
                                pending_test = false;
                                line_test = true;
                            }
                            depth += 1;
                        } else if c == b'}' {
                            depth -= 1;
                            if test_open_depths.last() == Some(&depth) {
                                test_open_depths.pop();
                            }
                        } else if c == b';' && pending_test && depth_clear(&code) {
                            // attribute applied to a braceless item
                            pending_test = false;
                        }
                        code.push(c as char);
                        j += 1;
                    }
                }
            }
        }

        let trimmed = code.trim();
        if trimmed.contains("#[cfg(test)]") || trimmed.contains("#[test]") {
            pending_test = true;
        }

        if let Some((rule, reason)) = parse_pragma(&comment_text) {
            pragmas.push(Pragma { line: lineno, rule, reason });
        }

        let in_test = line_test || !test_open_depths.is_empty();
        lines.push(ScannedLine { code, in_test });
    }

    ScannedFile { path: path.to_string(), lines, pragmas }
}

/// Scan Python source. Single-line string literals keep their contents
/// (the `artifact-drift` rule reads f-string text), but triple-quoted
/// docstrings are blanked — prose about the naming schema must not be
/// mistaken for a module-name literal. `# lint: ...` pragmas are
/// collected from comments that are genuinely comments (not `#` inside
/// a string).
pub fn scan_python(path: &str, src: &str) -> ScannedFile {
    let mut lines = Vec::new();
    let mut pragmas = Vec::new();
    let mut in_triple = false;
    for (i, raw) in src.lines().enumerate() {
        let lineno = i + 1;
        let mut code = String::with_capacity(raw.len());
        let mut in_str: Option<u8> = None;
        let b = raw.as_bytes();
        let mut comment = None;
        let mut j = 0;
        while j < b.len() {
            if in_triple {
                if raw[j..].starts_with("\"\"\"") {
                    in_triple = false;
                    j += 3;
                } else {
                    j += 1;
                }
                continue;
            }
            let c = b[j];
            match in_str {
                Some(q) => {
                    code.push(c as char);
                    if c == b'\\' && j + 1 < b.len() {
                        code.push(b[j + 1] as char);
                        j += 1;
                    } else if c == q {
                        in_str = None;
                    }
                }
                None => {
                    if raw[j..].starts_with("\"\"\"") {
                        in_triple = true;
                        j += 2; // plus the shared increment below
                    } else if c == b'"' || c == b'\'' {
                        in_str = Some(c);
                        code.push(c as char);
                    } else if c == b'#' {
                        comment = Some(&raw[j + 1..]);
                        break;
                    } else {
                        code.push(c as char);
                    }
                }
            }
            j += 1;
        }
        if let Some((rule, reason)) = comment.and_then(parse_pragma) {
            pragmas.push(Pragma { line: lineno, rule, reason });
        }
        lines.push(ScannedLine { code, in_test: false });
    }
    ScannedFile { path: path.to_string(), lines, pragmas }
}

/// Whether the sanitized text so far ends in an identifier character
/// (so a following `r"` is part of an ident like `for r` — not a raw
/// string — only when the `r` itself starts a fresh token).
fn prev_is_ident(code: &str) -> bool {
    code.chars().last().is_some_and(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// If `b` starts a raw (byte) string `r#*"` / `br#*"`, return
/// `(bytes to skip through the opening quote, hash count)`.
fn raw_str_hashes(b: &[u8]) -> Option<(usize, u32)> {
    let mut k = 0;
    if b[k] == b'b' {
        k += 1;
    }
    if k >= b.len() || b[k] != b'r' {
        return None;
    }
    k += 1;
    let h = b[k..].iter().take_while(|&&c| c == b'#').count();
    k += h;
    if k < b.len() && b[k] == b'"' {
        Some((k + 1, h as u32))
    } else {
        None
    }
}

/// If `b` (starting at `'`) is a char literal, return its byte length;
/// `None` means it is a lifetime. Handles `'x'`, `'\n'`, `'\u{1F600}'`.
fn char_literal_len(b: &[u8]) -> Option<usize> {
    debug_assert_eq!(b[0], b'\'');
    if b.len() < 3 {
        return None;
    }
    if b[1] == b'\\' {
        // escape: scan to the closing quote
        let mut k = 2;
        while k < b.len() {
            if b[k] == b'\\' {
                k += 2;
                continue;
            }
            if b[k] == b'\'' {
                return Some(k + 1);
            }
            k += 1;
        }
        None
    } else if b[1] != b'\'' {
        // `'X'` (any single non-quote byte, incl. UTF-8 lead — a
        // multibyte char still ends with a `'` within a few bytes)
        let mut k = 2;
        while k < b.len() && k <= 5 {
            if b[k] == b'\'' {
                return Some(k + 1);
            }
            k += 1;
        }
        None
    } else {
        None
    }
}

/// Whether the attribute's item has not yet opened a brace on this line
/// prefix (used to clear `pending_test` on braceless items like
/// `#[test] use ...;` — rare, but keeps depth bookkeeping honest).
fn depth_clear(code: &str) -> bool {
    !code.contains('{')
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_line_and_block_comments() {
        let s = scan_rust("x.rs", "let a = 1; // Instant::now()\n/* SystemTime::now */ let b;");
        assert!(!s.lines[0].code.contains("Instant"));
        assert!(!s.lines[1].code.contains("SystemTime"));
        assert!(s.lines[1].code.contains("let b;"));
    }

    #[test]
    fn strips_string_contents_but_not_code() {
        let s = scan_rust("x.rs", r#"let m = "call .unwrap() now"; x.unwrap();"#);
        let code = &s.lines[0].code;
        assert_eq!(code.matches(".unwrap()").count(), 1);
        assert!(code.contains(r#"let m = "";"#));
    }

    #[test]
    fn raw_strings_and_char_literals() {
        let s = scan_rust(
            "x.rs",
            "let r = r#\"as usize\"#; let c = '{'; let lt: &'static str = \"}\";",
        );
        let code = &s.lines[0].code;
        assert!(!code.contains("as usize"));
        // the brace inside the char literal must not skew depth
        let s2 = scan_rust("x.rs", "#[cfg(test)]\nmod t {\n let c = '{';\n}\nfn live() {}");
        assert!(s2.lines[2].in_test);
        assert!(!s2.lines[4].in_test);
    }

    #[test]
    fn cfg_test_spans_flag_lines() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n  fn b() { x.unwrap(); }\n}\nfn c() {}";
        let s = scan_rust("x.rs", src);
        assert!(!s.lines[0].in_test);
        assert!(s.lines[3].in_test);
        assert!(!s.lines[5].in_test);
    }

    #[test]
    fn pragma_parsing_and_attachment() {
        let src = "// lint: allow(wall-clock) — bench harness measures real time\nlet t = x;\nlet u = y; // lint: allow(hot-unwrap)";
        let s = scan_rust("x.rs", src);
        assert_eq!(s.pragmas.len(), 2);
        let p = s.pragma_for("wall-clock", 2).expect("preceding-line pragma applies");
        assert!(p.reason.as_deref().unwrap().contains("bench"));
        let q = s.pragma_for("hot-unwrap", 3).expect("same-line pragma applies");
        assert!(q.reason.is_none(), "missing reason is preserved as None");
        assert!(s.pragma_for("wall-clock", 4).is_none());
    }

    #[test]
    fn python_scan_finds_hash_pragmas_not_in_strings() {
        let src = "name = f\"teacher_fused_s{s}\"  # lint: allow(artifact-drift) — probe only\nx = \"# not a comment\"";
        let s = scan_python("aot.py", src);
        assert_eq!(s.pragmas.len(), 1);
        assert_eq!(s.pragmas[0].rule, "artifact-drift");
        assert!(s.lines[1].code.contains("# not a comment"));
    }
}
