//! Model-backend abstraction: the engine talks to the teacher/draft through
//! this trait, so the speculative engine, cache manager and coordinator are
//! testable against a deterministic simulator ([`sim::SimBackend`]) and run
//! in production against AOT artifacts ([`crate::runtime::PjrtBackend`]).
//!
//! # Plan → bind → execute
//!
//! The contract has three phases, replacing the old string-keyed module
//! addressing and `bail!`-on-shape entry points:
//!
//! 1. **Plan** — the caller states what it needs as a
//!    [`plan::PlanRequest`]; [`ModelBackend::plan_step`] negotiates the
//!    cheapest compiled variant from the backend's
//!    [`ModelBackend::capabilities`] table (parsed from the artifact
//!    manifest) into a typed [`plan::LaunchPlan`], or a typed
//!    [`plan::PlanError`] ([`plan::PlanError::SplitRequired`] tells the
//!    fused verifier to chunk a group; [`plan::PlanError::NoVariant`]
//!    lists every variant the backend has).
//! 2. **Bind** (optional) — [`ModelBackend::bind_kv`] creates a
//!    backend-resident KV session mirroring one conversation cache;
//!    subsequent steps carry a [`plan::SessionTicket`] and the backend
//!    syncs only the rows past the cache's dirty watermark, so
//!    steady-state per-step transfer no longer scales with the cache
//!    capacity. Backends without session support return
//!    [`plan::PlanError::SessionUnsupported`] and callers fall back to
//!    full-view upload (the eager/debug path stays full-upload by
//!    design).
//! 3. **Execute** — [`ModelBackend::execute`] /
//!    [`ModelBackend::execute_batch`] launch a resolved plan. The
//!    classic [`ModelBackend::teacher_step`] /
//!    [`ModelBackend::draft_step`] /
//!    [`ModelBackend::teacher_step_batch`] entry points survive as thin
//!    provided wrappers (plan, then execute), so call sites stay
//!    ergonomic while every variant selection flows through the
//!    negotiation.
//!
//! The call contract mirrors the AOT modules (DESIGN.md §2): the backend
//! *reads* a committed-prefix KV cache and *writes* the logits/features/KV
//! rows of the S new tokens into a caller-provided [`StepScratch`]; it
//! never writes any cache — all cache mutation is owned by the
//! [`crate::cache::KvStore`] implementations ("state safety", paper §3.3).
//! Cache reads go through the gather-aware [`KvView`]: mask columns are
//! **logical** sequence rows, and [`KvView::row_start`] resolves them
//! against flat `[L, rows, H, Dh]` buffers or a paged block table
//! ([`KvIndex`]) — backends must never assume contiguous row storage.
//!
//! # Scratch-buffer output contract
//!
//! Steps used to return a freshly allocated `StepOut` (four vocab- or
//! cache-row-sized `Vec`s per call — dozens of heap allocations per
//! speculative round). They now fill a reusable [`StepScratch`] arena:
//!
//! * **Ownership** — the caller owns the scratch and its lifetime; the
//!   backend must call [`StepScratch::prepare`] with the step's `s` and
//!   its role dimensions, then overwrite every element it reports.
//!   Buffers only grow to the high-water mark of the largest compiled S
//!   variant; steady-state rounds are allocation-free.
//! * **Aliasing** — `args` (tokens/positions/mask/KV views) and the
//!   scratch are disjoint by construction: `StepArgs` holds shared
//!   borrows, the scratch an exclusive one, so a backend can never read
//!   its own partial outputs. The engine keeps *two* draft scratches and
//!   ping-pongs them across tree-expansion depths because a frontier
//!   call's inputs (parent hidden rows) live in the previous call's
//!   scratch.
//! * **Validity** — contents are defined only for the `s` slots of the
//!   *most recent* step, and only until the next `prepare`. Padded-slot
//!   values are backend-defined garbage; the tree mask force-masks them.
//! * **PJRT** — module outputs land through `Literal::read_into`
//!   directly into the prepared scratch slices (output donation to host
//!   scratch): no intermediate per-output `Vec` is materialized. The
//!   only remaining per-launch heap traffic is handle-sized (the tuple
//!   literal handles and the artifact-name key), never vocab- or
//!   cap-sized.
//!
//! # Batched verification contract
//!
//! [`ModelBackend::teacher_step_batch`] fuses the tree-verification steps
//! of `B` independent requests into **one launch** (the serving-layer
//! batching of SpecInfer-style systems: teacher invocation cost is
//! amortized across requests as well as across speculated tokens). The
//! fused input layout is documented in `docs/ARCHITECTURE.md`; in brief:
//!
//! * every request is padded to the group's largest compiled variant
//!   `S_max`; `tokens`/`positions` are `[B * S_max]` with request `b`
//!   owning rows `[b*S_max, (b+1)*S_max)`;
//! * the additive mask is `[B, S_max, cap + S_max]`: each request's rows
//!   address **its own** KV cache (`reqs[b].kv`) in the first `cap`
//!   columns and its own speculative block in the last `S_max` columns —
//!   there is no cross-request column space, so cross-request isolation
//!   is structural, not a masking convention;
//! * **padding rows are never attended**: a request padded from
//!   `S_req < S_max` has rows `[S_req, S_max)` fully masked in both
//!   directions, and callers never read those output rows back
//!   ([`StepScratch::scatter_from`] copies only `S_req` rows);
//! * outputs land in a scratch prepared with
//!   [`StepScratch::prepare_batch`]; live rows must be **bit-identical**
//!   to `B` sequential [`ModelBackend::teacher_step`] calls on the same
//!   per-request inputs (property-tested in `tests/batched.rs`).
//!
//! The default implementation is that sequential loop (correct for every
//! backend, one launch per request, allocates a temporary scratch);
//! [`sim::SimBackend`] overrides it with a true single-pass fused step.

pub mod plan;
pub mod sim;

use crate::config::{Contract, ExecMode};
use anyhow::Result;

pub use crate::config::{Capabilities, ModuleKey, ModuleLayout, ModuleRole};
pub use crate::util::arena::StepScratch;
pub use plan::{negotiate, KvSession, LaunchPlan, PlanError, PlanRequest, SessionTicket};

/// How logical sequence rows map onto the physical storage of a
/// [`KvView`] — the gather-aware half of the paged-KV contract.
#[derive(Clone, Copy)]
pub enum KvIndex<'a> {
    /// Contiguous `[L, rows, H, Dh]` storage; logical row == physical
    /// row. `rows` is the buffer's row capacity per layer (the cache
    /// capacity for flat committed caches).
    Flat {
        /// Physical rows per layer in the buffers.
        rows: usize,
    },
    /// Block-major pool storage: block `b` occupies
    /// `[b * L * bs * H * Dh, ..)` laid out `[L, bs, H, Dh]`, and logical
    /// row `j` lives in block `table[j / bs]` at in-block row `j % bs`.
    Paged {
        /// Logical-block → physical-block indirection.
        table: &'a [u32],
        /// Rows per block (`bs`).
        block_size: usize,
    },
}

/// Read-only, gather-aware view of a KV cache buffer pair. Flat views
/// are the classic `[L, cap, H, Dh]` buffers; paged views address a
/// shared block pool through a block table (see [`KvIndex`]). Backends
/// must read rows through [`KvView::row_start`] instead of assuming a
/// contiguous layout.
#[derive(Clone, Copy)]
pub struct KvView<'a> {
    /// Key storage (flat buffer or block pool).
    pub k: &'a [f32],
    /// Value storage (flat buffer or block pool).
    pub v: &'a [f32],
    /// Logical-row → physical-offset mapping.
    pub index: KvIndex<'a>,
}

impl<'a> KvView<'a> {
    /// A flat `[L, rows, H, Dh]` view.
    pub fn flat(k: &'a [f32], v: &'a [f32], rows: usize) -> Self {
        Self { k, v, index: KvIndex::Flat { rows } }
    }

    /// A paged view over block-major pool storage.
    pub fn paged(k: &'a [f32], v: &'a [f32], table: &'a [u32], block_size: usize) -> Self {
        Self { k, v, index: KvIndex::Paged { table, block_size } }
    }

    /// Element offset of `(layer, logical row)` in `k`/`v`, for a role
    /// with `layers` layers and per-row stride `rstride = H * Dh`.
    /// Logical rows past the mapped region are a caller bug (the mask
    /// must close them); debug builds assert.
    #[inline]
    pub fn row_start(&self, layers: usize, rstride: usize, layer: usize, row: usize) -> usize {
        match self.index {
            KvIndex::Flat { rows } => {
                debug_assert!(row < rows, "logical row {row} out of flat rows {rows}");
                (layer * rows + row) * rstride
            }
            KvIndex::Paged { table, block_size } => {
                debug_assert!(
                    row / block_size < table.len(),
                    "logical row {row} beyond mapped blocks {}",
                    table.len()
                );
                let b = table[row / block_size] as usize;
                ((b * layers + layer) * block_size + row % block_size) * rstride
            }
        }
    }

    /// Logical rows the view can address (flat row capacity, or mapped
    /// block rows for paged views).
    pub fn mapped_rows(&self) -> usize {
        match self.index {
            KvIndex::Flat { rows } => rows,
            KvIndex::Paged { table, block_size } => table.len() * block_size,
        }
    }
}

/// Inputs of one step. `tokens/positions` have exactly `s` entries
/// (padded by the caller); `mask` is the `[s, cap+s]` additive mask.
pub struct StepArgs<'a> {
    /// Token ids of the `s` (padded) slots.
    pub tokens: &'a [i32],
    /// RoPE positions of the `s` slots.
    pub positions: &'a [i32],
    /// `[s, cap + s]` additive attention mask (0 = open, `NEG_INF` = closed).
    pub mask: &'a [f32],
    /// The committed-prefix KV cache the step reads.
    pub kv: KvView<'a>,
    /// Draft only: `[s, F]` incoming feature rows (EAGLE conditioning).
    pub feats_in: Option<&'a [f32]>,
    /// Request last-layer attention statistics (analysis-only).
    pub probe: bool,
    /// Resident-session binding of `kv`, when the conversation cache is
    /// bound on this backend (see the *plan → bind → execute* protocol
    /// in the module docs). `None` → the backend reads/uploads the full
    /// view.
    pub session: Option<SessionTicket>,
}

/// One request inside a fused batched verification step.
#[derive(Clone, Copy)]
pub struct BatchRequest<'a> {
    /// This request's own committed-prefix KV cache.
    pub kv: KvView<'a>,
    /// Rows the caller will read back (the request's own padded variant
    /// `S_req <= S_max`); rows `[live, S_max)` are padding the backend
    /// may skip entirely. Group-padding requests have `live == 0` (and
    /// an empty cache view — their mask rows/columns are fully closed).
    pub live: usize,
    /// Resident-session binding of `kv` (same contract as
    /// [`StepArgs::session`]).
    pub session: Option<SessionTicket>,
}

/// The [`ModuleLayout`] a cache view presents (paged views negotiate a
/// host-side gather when only flat modules are compiled).
pub fn layout_of(kv: &KvView) -> ModuleLayout {
    match kv.index {
        KvIndex::Flat { .. } => ModuleLayout::Flat,
        KvIndex::Paged { .. } => ModuleLayout::Paged,
    }
}

/// Handle to an in-flight fused launch started by
/// [`ModelBackend::begin_execute_batch`] and completed by
/// [`ModelBackend::await_batch`].
///
/// # Contract
///
/// * A token is single-use: exactly one `await_batch` call per token, on
///   the backend that issued it. Backends reject unknown ids.
/// * `id == 0` means the launch already completed inside `begin` (the
///   synchronous default); `await_batch` on it is a no-op.
/// * The output scratch passed to `begin` holds **undefined** contents
///   until `await_batch` returns for that token — overlapped backends
///   may defer both the device wait and the result readback to the
///   await. Callers must not read the scratch, and must not reuse it
///   for another launch, while the token is outstanding.
/// * All borrowed inputs (`tokens`/`positions`/`mask`/KV views) are
///   consumed — copied or uploaded — before `begin` returns, so the
///   caller's borrows end with the `begin` call even though the launch
///   is still in flight.
#[derive(Debug)]
#[must_use = "an in-flight launch must be completed with await_batch"]
pub struct LaunchToken {
    /// Backend-assigned launch id (`0` = completed eagerly at begin).
    pub id: u64,
}

impl LaunchToken {
    /// The token of a launch that completed inside `begin` (the
    /// synchronous default path).
    pub fn completed() -> Self {
        Self { id: 0 }
    }

    /// Whether the launch already completed inside `begin` (awaiting it
    /// is a no-op).
    pub fn is_completed(&self) -> bool {
        self.id == 0
    }
}

/// Inputs of one fused `B`-request verification step (see the *Batched
/// verification contract* in the module docs for the layout invariants).
pub struct BatchStepArgs<'a, 'b> {
    /// Padded slots per request (the group's largest compiled S variant).
    pub s_max: usize,
    /// `[B * s_max]` token ids; request `b` owns `[b*s_max, (b+1)*s_max)`.
    pub tokens: &'a [i32],
    /// `[B * s_max]` RoPE positions, same row ownership.
    pub positions: &'a [i32],
    /// `[B, s_max, cap + s_max]` additive mask block; each request's rows
    /// address that request's own cache columns and spec block.
    pub mask: &'a [f32],
    /// Per-request cache views + live row counts, length `B`.
    pub reqs: &'b [BatchRequest<'a>],
}

/// A teacher+draft pair the engine can decode with.
///
/// Implementations are single-threaded (PJRT handles are !Send); each
/// coordinator worker owns its own backend instance (DESIGN.md §3.4).
///
/// Required methods are the *plan → bind → execute* primitives
/// ([`ModelBackend::capabilities`], [`ModelBackend::execute`]); the
/// classic step entry points are provided wrappers that negotiate a
/// [`LaunchPlan`] first, so no implementation selects variants by string
/// or fails on shape with an untyped error.
pub trait ModelBackend {
    /// The static shape contract this backend was built for.
    fn contract(&self) -> &Contract;

    /// The compiled module variants this backend can launch (parsed from
    /// the artifact manifest, or synthesized for simulators).
    fn capabilities(&self) -> &Capabilities;

    /// Negotiate the cheapest compiled variant covering `req` (see
    /// [`plan::negotiate`] for the cost model and fallback rules).
    /// Backends with dynamic constraints may override.
    fn plan_step(&self, req: &PlanRequest) -> Result<LaunchPlan, PlanError> {
        negotiate(self.capabilities(), req)
    }

    /// Launch a resolved single-request plan. Outputs land in `out` per
    /// the scratch-buffer contract above; the scratch must be prepared
    /// for `plan.key.s` slots (with the probe output iff
    /// `plan.key.probe`).
    fn execute(&mut self, plan: &LaunchPlan, args: StepArgs, out: &mut StepScratch) -> Result<()>;

    /// Launch a resolved fused plan over `args.reqs.len()` requests
    /// (`<= plan.key.b`; a backend launching a wider compiled variant
    /// pads the missing request blocks itself) in **one** launch; live
    /// output rows must be bit-identical to sequential
    /// [`ModelBackend::execute`] calls on the same per-request inputs
    /// (see the batching contract above).
    ///
    /// The default emulates sequentially (correct for any backend, one
    /// launch per live request); backends with true fused modules
    /// override it.
    fn execute_batch(
        &mut self,
        plan: &LaunchPlan,
        args: BatchStepArgs,
        out: &mut StepScratch,
    ) -> Result<()> {
        self.emulate_batch(plan.key.mode, args, out)
    }

    /// Start a resolved fused launch **without waiting for it**: consume
    /// every borrowed input (copy or upload), dispatch the device work,
    /// and return a [`LaunchToken`] the caller later passes to
    /// [`ModelBackend::await_batch`]. Between begin and await the caller
    /// may run arbitrary host work — including staging the *next* launch
    /// into a different scratch — which an overlapped backend hides
    /// behind the in-flight device time.
    ///
    /// The default is synchronous: it runs
    /// [`ModelBackend::execute_batch`] eagerly and returns
    /// [`LaunchToken::completed`], so third-party backends are correct
    /// without opting in. Overlapped implementations:
    /// [`sim::SimBackend`] (device-clock model, reports
    /// `overlap_saved_secs`) and [`crate::runtime::PjrtBackend`]
    /// (buffered execution, readback deferred to await).
    fn begin_execute_batch(
        &mut self,
        plan: &LaunchPlan,
        args: BatchStepArgs,
        out: &mut StepScratch,
    ) -> Result<LaunchToken> {
        self.execute_batch(plan, args, out)?;
        Ok(LaunchToken::completed())
    }

    /// Complete a launch started by [`ModelBackend::begin_execute_batch`]:
    /// wait for the device and land the outputs in `out` (the same
    /// scratch passed to begin — its contents are defined only after
    /// this returns). A [`LaunchToken::completed`] token is a no-op;
    /// that is the entire default implementation.
    fn await_batch(&mut self, token: LaunchToken, out: &mut StepScratch) -> Result<()> {
        let _ = out;
        anyhow::ensure!(
            token.is_completed(),
            "await_batch: backend '{}' issued no overlapped launch token {}",
            self.name(),
            token.id
        );
        Ok(())
    }

    /// Sequential emulation of a fused step: one single-request launch
    /// per live request through a temporary scratch, copied into the
    /// fused layout. Correct for every backend (used as the
    /// [`ModelBackend::execute_batch`] default and as the
    /// [`ModelBackend::teacher_step_batch`] fallback when no fused
    /// variant covers the group at all); does not amortize launches and
    /// allocates the temporary.
    fn emulate_batch(
        &mut self,
        mode: ExecMode,
        args: BatchStepArgs,
        out: &mut StepScratch,
    ) -> Result<()> {
        let (vocab, feat_dim, d, cap) = {
            let c = self.contract();
            (c.vocab, c.feat_dim, c.teacher, c.cache_cap)
        };
        let b = args.reqs.len();
        let s = args.s_max;
        let w = cap + s;
        out.prepare_batch(b, s, vocab, feat_dim, d.layers, d.heads, d.d_head, false);
        let mut tmp = StepScratch::new();
        for (bi, req) in args.reqs.iter().enumerate() {
            if req.live == 0 {
                continue; // group padding: rows are never read back
            }
            self.teacher_step(
                mode,
                StepArgs {
                    tokens: &args.tokens[bi * s..(bi + 1) * s],
                    positions: &args.positions[bi * s..(bi + 1) * s],
                    mask: &args.mask[bi * s * w..(bi + 1) * s * w],
                    kv: req.kv,
                    feats_in: None,
                    probe: false,
                    session: req.session,
                },
                &mut tmp,
            )?;
            out.copy_request_from(bi, &tmp);
        }
        Ok(())
    }

    /// Bind one conversation cache into a backend-resident KV session
    /// (the *bind* phase): the backend copies rows `[0, rows)` of `view`
    /// into its mirror once; later steps carrying a [`SessionTicket`]
    /// sync only the dirty delta. Backends without session support
    /// return [`PlanError::SessionUnsupported`] (the default) and
    /// callers fall back to full-view steps.
    fn bind_kv(
        &mut self,
        role: ModuleRole,
        view: KvView,
        rows: usize,
    ) -> Result<KvSession, PlanError> {
        let _ = (role, view, rows);
        Err(PlanError::SessionUnsupported { backend: self.name() })
    }

    /// Re-synchronize an existing session from scratch (rows `[0, rows)`
    /// of `view`), reusing its mirror storage — the admission-boundary
    /// path when a slot engine switches conversations.
    fn rebind_kv(
        &mut self,
        session: &KvSession,
        view: KvView,
        rows: usize,
    ) -> Result<(), PlanError> {
        let _ = (view, rows);
        Err(PlanError::UnknownSession { id: session.id })
    }

    /// Release a session and its mirror storage.
    fn unbind_kv(&mut self, session: KvSession) {
        let _ = session;
    }

    /// Teacher verification/prefill step under `mode` (fused or eager
    /// artifact — the paper's two-mode protocol): plans the smallest
    /// covering variant, then executes it. Outputs land in `out` per the
    /// scratch-buffer contract above.
    fn teacher_step(
        &mut self,
        mode: ExecMode,
        args: StepArgs,
        out: &mut StepScratch,
    ) -> Result<()> {
        let req = PlanRequest {
            role: ModuleRole::Teacher,
            mode,
            rows: args.tokens.len(),
            batch: 1,
            probe: args.probe,
            layout: layout_of(&args.kv),
        };
        let plan = self.plan_step(&req)?;
        self.execute(&plan, args, out)
    }

    /// Draft step (chain refresh or tree-frontier expansion): plans,
    /// then executes. A probe request silently falls back to the
    /// probe-less variant of the same shape when none is compiled
    /// (probe output is analysis-only).
    fn draft_step(&mut self, args: StepArgs, out: &mut StepScratch) -> Result<()> {
        let req = PlanRequest {
            role: ModuleRole::Draft,
            mode: ExecMode::Fused,
            rows: args.tokens.len(),
            batch: 1,
            probe: args.probe,
            layout: layout_of(&args.kv),
        };
        let plan = self.plan_step(&req)?;
        self.execute(&plan, args, out)
    }

    /// Fused teacher verification over `B` requests: plans the smallest
    /// covering `(B, S)` variant and executes it as **one** launch; when
    /// no fused variant exists at any width, falls back to the
    /// sequential emulation (one launch per request — the
    /// pre-fused-artifact behaviour). Callers that want to *split*
    /// rather than emulate (keeping launches wide) should
    /// [`ModelBackend::plan_step`] first and handle
    /// [`PlanError::SplitRequired`] themselves, as the
    /// [`crate::coordinator::FusedVerifier`] does.
    fn teacher_step_batch(
        &mut self,
        mode: ExecMode,
        args: BatchStepArgs,
        out: &mut StepScratch,
    ) -> Result<()> {
        anyhow::ensure!(!args.reqs.is_empty(), "teacher_step_batch with an empty group");
        let req = PlanRequest {
            role: ModuleRole::Teacher,
            mode,
            rows: args.s_max,
            batch: args.reqs.len(),
            probe: false,
            layout: layout_of(&args.reqs[0].kv),
        };
        match self.plan_step(&req) {
            Ok(plan) => self.execute_batch(&plan, args, out),
            Err(PlanError::SplitRequired { .. }) => self.emulate_batch(mode, args, out),
            Err(e) => Err(e.into()),
        }
    }

    /// Human-readable backend id for manifests/traces.
    fn name(&self) -> &'static str;
}

/// Greedy argmax over a logits row.
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, v) in row.iter().enumerate() {
        if *v > best_v {
            best_v = *v;
            best = i;
        }
    }
    best
}

/// Top-k (index, value) pairs of a logits row, descending by value (ties:
/// lowest index first). Single pass with a k-sized insertion buffer — no
/// vocab-sized index scratch, so the hot expansion loop stays
/// allocation-small (k <= 16). A NaN logit panics loudly (backend numeric
/// corruption must not silently degrade the speculation tree).
pub fn topk(row: &[f32], k: usize) -> Vec<(usize, f32)> {
    // (index i, value v) ranks above (oi, ov): higher value, ties by
    // lower index. Total order; panics on NaN like the old sort did.
    fn beats(i: usize, v: f32, oi: usize, ov: f32) -> bool {
        // lint: allow(hot-unwrap) — NaN here is backend numeric corruption; the documented policy (see the doc comment above) is to panic loudly rather than silently degrade the speculation tree
        match v.partial_cmp(&ov).expect("NaN in logits row") {
            std::cmp::Ordering::Greater => true,
            std::cmp::Ordering::Equal => i < oi,
            std::cmp::Ordering::Less => false,
        }
    }
    let k = k.min(row.len());
    if k == 0 {
        return Vec::new();
    }
    let mut out: Vec<(usize, f32)> = Vec::with_capacity(k);
    for (i, &v) in row.iter().enumerate() {
        if out.len() == k {
            let (wi, wv) = out[k - 1];
            if !beats(i, v, wi, wv) {
                continue;
            }
            out.pop();
        }
        // insertion position: after every strictly-better entry
        let pos = out
            .iter()
            .position(|&(oi, ov)| beats(i, v, oi, ov))
            .unwrap_or(out.len());
        out.insert(pos, (i, v));
    }
    out
}

/// log-softmax value of index `i` within a logits row.
pub fn log_softmax_at(row: &[f32], i: usize) -> f64 {
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, b| a.max(*b)) as f64;
    let z: f64 = row.iter().map(|x| ((*x as f64) - mx).exp()).sum();
    (row[i] as f64 - mx) - z.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_topk() {
        let row = [0.1f32, 3.0, -1.0, 2.0];
        assert_eq!(argmax(&row), 1);
        let t = topk(&row, 2);
        assert_eq!(t[0].0, 1);
        assert_eq!(t[1].0, 3);
    }

    #[test]
    fn topk_full_row() {
        let row = [1.0f32, 2.0];
        let t = topk(&row, 5.min(row.len()));
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].0, 1);
    }

    #[test]
    #[should_panic(expected = "NaN in logits row")]
    fn topk_panics_on_nan() {
        topk(&[1.0f32, f32::NAN, 2.0], 2);
    }

    #[test]
    fn topk_breaks_ties_by_lowest_index() {
        let row = [1.0f32, 2.0, 2.0, 1.0, 2.0];
        let t = topk(&row, 3);
        assert_eq!(t, vec![(1, 2.0), (2, 2.0), (4, 2.0)]);
    }

    #[test]
    fn log_softmax_normalizes() {
        let row = [1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|i| log_softmax_at(&row, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn kv_view_row_math_flat_and_paged() {
        // flat [L=2, rows=4, rs=3]
        let buf = vec![0.0f32; 2 * 4 * 3];
        let flat = KvView::flat(&buf, &buf, 4);
        assert_eq!(flat.row_start(2, 3, 0, 1), 3);
        assert_eq!(flat.row_start(2, 3, 1, 2), (4 + 2) * 3);
        assert_eq!(flat.mapped_rows(), 4);
        // paged: bs=2, blocks [3, 0] -> logical row 2 lives in block 0
        let pool = vec![0.0f32; 4 * 2 * 2 * 3]; // 4 blocks, L=2, bs=2, rs=3
        let table = [3u32, 0];
        let paged = KvView::paged(&pool, &pool, &table, 2);
        // logical row 0 -> block 3, in-block row 0, layer 0
        assert_eq!(paged.row_start(2, 3, 0, 0), 3 * 2 * 2 * 3);
        // logical row 3 -> block 0, in-block row 1, layer 1
        assert_eq!(paged.row_start(2, 3, 1, 3), (2 + 1) * 3);
        assert_eq!(paged.mapped_rows(), 4);
    }

    #[test]
    fn scratch_row_accessors() {
        let mut out = StepScratch::new();
        out.prepare(2, 2, 1, 1, 1, 1, false);
        out.logits.copy_from_slice(&[0.0, 1.0, 2.0, 3.0]);
        out.feats.copy_from_slice(&[9.0, 8.0]);
        assert_eq!(out.logits_row(1), &[2.0, 3.0]);
        assert_eq!(out.feat_row(0), &[9.0]);
    }
}
