//! Model-backend abstraction: the engine talks to the teacher/draft through
//! this trait, so the speculative engine, cache manager and coordinator are
//! testable against a deterministic simulator ([`sim::SimBackend`]) and run
//! in production against AOT artifacts ([`crate::runtime::PjrtBackend`]).
//!
//! The call contract mirrors the AOT modules (DESIGN.md §2): the backend
//! *reads* a committed-prefix KV cache and *returns* the KV rows of the S
//! new tokens; it never writes any cache — all cache mutation is owned by
//! [`crate::cache::ManagedCache`] ("state safety", paper §3.3).

pub mod sim;

use crate::config::{Contract, ExecMode};
use anyhow::Result;

/// Read-only view of a KV cache buffer pair, layout `[L, cap, H, Dh]`.
#[derive(Clone, Copy)]
pub struct KvView<'a> {
    pub k: &'a [f32],
    pub v: &'a [f32],
}

/// Outputs of one teacher/draft step over an S-token block.
#[derive(Clone, Debug)]
pub struct StepOut {
    /// Compiled block size of the call (padded slot count).
    pub s: usize,
    /// `[S, V]` next-token logits per slot.
    pub logits: Vec<f32>,
    /// `[S, F]` feature rows (teacher: exported EAGLE features; draft: its
    /// own hidden states, used as parent features for deeper nodes).
    pub feats: Vec<f32>,
    /// `[L, S, H, Dh]` KV rows for the S new tokens.
    pub k_new: Vec<f32>,
    pub v_new: Vec<f32>,
    /// `[S, H]` last-layer top-1 attention column per head (probe runs only).
    pub attn_top1: Option<Vec<i32>>,
}

impl StepOut {
    /// Logits row for slot `i`.
    pub fn logits_row(&self, i: usize, vocab: usize) -> &[f32] {
        &self.logits[i * vocab..(i + 1) * vocab]
    }

    /// Feature row for slot `i`.
    pub fn feat_row(&self, i: usize, feat_dim: usize) -> &[f32] {
        &self.feats[i * feat_dim..(i + 1) * feat_dim]
    }
}

/// Inputs of one step. `tokens/positions` have exactly `s` entries
/// (padded by the caller); `mask` is the `[s, cap+s]` additive mask.
pub struct StepArgs<'a> {
    pub tokens: &'a [i32],
    pub positions: &'a [i32],
    pub mask: &'a [f32],
    pub kv: KvView<'a>,
    /// Draft only: `[s, F]` incoming feature rows (EAGLE conditioning).
    pub feats_in: Option<&'a [f32]>,
    /// Request last-layer attention statistics (analysis-only).
    pub probe: bool,
}

/// A teacher+draft pair the engine can decode with.
///
/// Implementations are single-threaded (PJRT handles are !Send); each
/// coordinator worker owns its own backend instance (DESIGN.md §3.4).
pub trait ModelBackend {
    fn contract(&self) -> &Contract;

    /// Teacher verification/prefill step under `mode` (fused or eager
    /// artifact — the paper's two-mode protocol).
    fn teacher_step(&mut self, mode: ExecMode, args: StepArgs) -> Result<StepOut>;

    /// Draft step (chain refresh or tree-frontier expansion).
    fn draft_step(&mut self, args: StepArgs) -> Result<StepOut>;

    /// Human-readable backend id for manifests/traces.
    fn name(&self) -> &'static str;
}

/// Greedy argmax over a logits row.
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, v) in row.iter().enumerate() {
        if *v > best_v {
            best_v = *v;
            best = i;
        }
    }
    best
}

/// Top-k (index, value) pairs of a logits row, descending.
pub fn topk(row: &[f32], k: usize) -> Vec<(usize, f32)> {
    let mut idx: Vec<usize> = (0..row.len()).collect();
    // partial selection: k is tiny (<= 16) vs V=512 — simple sort is fine,
    // but avoid full sort: select_nth then sort the head.
    if k < row.len() {
        idx.select_nth_unstable_by(k, |a, b| row[*b].partial_cmp(&row[*a]).unwrap());
        idx.truncate(k);
    }
    idx.sort_by(|a, b| row[*b].partial_cmp(&row[*a]).unwrap());
    idx.into_iter().map(|i| (i, row[i])).collect()
}

/// log-softmax value of index `i` within a logits row.
pub fn log_softmax_at(row: &[f32], i: usize) -> f64 {
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, b| a.max(*b)) as f64;
    let z: f64 = row.iter().map(|x| ((*x as f64) - mx).exp()).sum();
    (row[i] as f64 - mx) - z.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_topk() {
        let row = [0.1f32, 3.0, -1.0, 2.0];
        assert_eq!(argmax(&row), 1);
        let t = topk(&row, 2);
        assert_eq!(t[0].0, 1);
        assert_eq!(t[1].0, 3);
    }

    #[test]
    fn topk_full_row() {
        let row = [1.0f32, 2.0];
        let t = topk(&row, 5.min(row.len()));
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].0, 1);
    }

    #[test]
    fn log_softmax_normalizes() {
        let row = [1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|i| log_softmax_at(&row, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn step_out_row_accessors() {
        let out = StepOut {
            s: 2,
            logits: vec![0.0, 1.0, 2.0, 3.0],
            feats: vec![9.0, 8.0],
            k_new: vec![],
            v_new: vec![],
            attn_top1: None,
        };
        assert_eq!(out.logits_row(1, 2), &[2.0, 3.0]);
        assert_eq!(out.feat_row(0, 1), &[9.0]);
    }
}
