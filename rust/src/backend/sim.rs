//! SimBackend — a deterministic "hash language model" implementing
//! [`ModelBackend`] with *exact* context semantics.
//!
//! Purpose: every engine-level property the paper cares about — branch
//! isolation, commit equivalence, greedy output equivalence between EA and
//! baseline decoding, mask leakage, truncation sensitivity — can be tested
//! in microseconds without PJRT or artifacts.
//!
//! Semantics: a step's logits for slot `i` depend **only** on the visible
//! context of that slot — reconstructed the way real attention would see
//! it: tokens are read from the KV cache through the additive mask (the
//! sim writes each row's token id and position into its KV row), plus the
//! visible speculative slots of the current call. The context is hashed
//! and the hash determines a deterministic top-candidate list.
//!
//! * The sim **teacher**'s candidates come from the context hash.
//! * The sim **draft** computes the same hash on *its own* visible
//!   context (so a truncated drafter window changes its context and
//!   collapses agreement, reproducing E4), then agrees with the teacher's
//!   top-1 with probability `agree_pct` (a per-context deterministic
//!   coin), else swaps its top two candidates.
//!
//! Because the sim reads context strictly through mask + cache, any
//! masking bug, cache-write bug or commit bug in the engine changes its
//! outputs and is caught by the equivalence tests.
//!
//! Like every backend, the sim writes its outputs into the caller's
//! [`StepScratch`]; the only per-call state it owns is a reusable
//! context-reconstruction buffer, so steady-state calls allocate nothing.
//!
//! # Plan / capabilities
//!
//! The sim's [`ModelBackend::capabilities`] table is synthesized from the
//! contract: every compiled S variant, both teacher modes, fused widths
//! up to a configurable bound ([`SimBackend::with_max_fused`], default
//! [`DEFAULT_MAX_FUSED_B`]), and probe variants at every draft S. The
//! width bound exists so tests can force the verifier's group-splitting
//! path ([`crate::backend::PlanError::SplitRequired`]) on a simulator.
//!
//! # KV sessions and the upload model
//!
//! The sim implements the full session API over host slices: `bind_kv`
//! copies the bound rows into a per-session mirror, each ticketed step
//! syncs only the rows past the cache's dirty watermark, and the step
//! then reads context **through the mirror** — so any stale-mirror bug
//! (a missed dirty range on commit/rollback) changes the context hash
//! and is caught by the session-vs-full-view bit-identity suite
//! (`tests/backend_contract.rs`).
//!
//! [`SimBackend::upload_bytes`] models the host→device transfer a PJRT
//! launch would ship for the same step: without a session the full
//! `[L, cap, H, Dh]` cache pair plus the per-call tensors; with a
//! session only the dirty delta rows plus the per-call tensors. The
//! end-to-end bench reads this to report `upload_bytes_per_token` for
//! the session-on vs session-off serving paths (gated in CI).
//!
//! # Fused batched verification
//!
//! The sim's [`ModelBackend::execute_batch`] is a true fused
//! implementation: one pass over all `B` requests' live rows, **one**
//! launch counted and **one** launch-cost charge. Because each row's
//! logits depend only on that row's visible context (own cache + own
//! spec block — the fused mask has no cross-request columns), the fused
//! outputs are bit-identical to `B` sequential single-request steps;
//! padding rows (`i >= reqs[b].live`) are skipped entirely and left
//! backend-defined.
//!
//! # Launch-cost model
//!
//! Real accelerators charge a fixed host-side dispatch + kernel-launch
//! latency per teacher invocation — the quantity that cross-request
//! batching amortizes (and that the paper's per-round "one teacher call"
//! economics rest on) — plus compute that scales with the rows actually
//! evaluated. The sim models both as a busy-wait charged per teacher
//! *launch* (fused or not):
//!
//! ```text
//! cost(launch) = teacher_launch  +  teacher_row_cost * padded_rows
//! ```
//!
//! where `padded_rows` is `S` for a single step and the launched
//! variant's `B_key * S_key` for a fused step — a real padded launch
//! computes every row, so a ragged mixed-budget group is charged for its
//! padding.
//!
//! The fixed part is what batching amortizes (one charge per fused
//! group); the per-row part is what batching can *not* amortize (the
//! rows still have to be computed), so speedups measured under the model
//! stay honest instead of scaling like `B`. [`SimBackend::launches_by_width`]
//! histograms every teacher launch by its **executed** width — the number
//! of live requests the dispatch actually verified, not the padded width
//! of the compiled variant (a single-request step that negotiates a wider
//! variant still counts under width 1, matching the PJRT single-request
//! fallback dispatch) — which is how the bench shows continuous admission
//! sustaining full-width launches where fixed grouping degrades to narrow
//! ones. Both costs default to zero so equivalence tests stay instant;
//! the end-to-end bench sets them to measure the B-sweep and the
//! straggler workload honestly.
//!
//! # Overlapped launches (device-clock model)
//!
//! [`ModelBackend::begin_execute_batch`] / [`ModelBackend::await_batch`]
//! are implemented over a **device clock**: a begun launch occupies the
//! simulated accelerator from `max(now, device_free_at)` for its modeled
//! cost, and the host spin is deferred to the await — which only spins
//! for the *remaining* time to the device deadline. Host work performed
//! between begin and await (draft expansion, staging the next launch) is
//! therefore provably hidden; [`SimBackend::overlap_saved_secs`]
//! accumulates exactly the device seconds the host did not have to wait,
//! so benches and tests can assert the pipeline win instead of inferring
//! it from wall clocks. The synchronous [`ModelBackend::execute_batch`]
//! path charges the same clock eagerly, so mixing the two stays
//! consistent. [`SimBackend::with_draft_cost`] gives the draft module a
//! nonzero host-side dispatch cost — the work the pipelined scheduler
//! hides.

use super::{
    BatchStepArgs, KvSession, KvView, LaunchPlan, LaunchToken, ModelBackend, ModuleRole,
    PlanError, SessionTicket, StepArgs, StepScratch,
};
use crate::config::contract::{FIRST_TOKEN, VOCAB};
use crate::config::{Capabilities, Contract, Dims};
use crate::util::rng::splitmix64;
use crate::util::timer::Stopwatch;
use anyhow::Result;
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Number of distinguished candidates per context.
const TOP_N: usize = 8;

/// Default fused-width bound of the synthetic capabilities table (wide
/// enough that no default-configured group ever splits).
pub const DEFAULT_MAX_FUSED_B: usize = 64;

/// Host-side mirror of one bound conversation cache (flat
/// `[L, cap, H, Dh]`, logical-row indexed).
struct SimSession {
    role: ModuleRole,
    k: Vec<f32>,
    v: Vec<f32>,
    rows: usize,
}

/// Copy rows `[lo, rows)` of `kv` (gather-aware) into the mirror and
/// record the mirror's new readable length.
fn sync_rows(
    sess: &mut SimSession,
    kv: &KvView,
    lo: usize,
    rows: usize,
    layers: usize,
    rs: usize,
    cap: usize,
) {
    for r in lo.min(rows)..rows {
        for l in 0..layers {
            let src = kv.row_start(layers, rs, l, r);
            let dst = (l * cap + r) * rs;
            sess.k[dst..dst + rs].copy_from_slice(&kv.k[src..src + rs]);
            sess.v[dst..dst + rs].copy_from_slice(&kv.v[src..src + rs]);
        }
    }
    sess.rows = rows;
}

/// Context hash of one row: fold (position, token) pairs of every
/// visible column, sorted by position (stable on column order).
/// `mask_row` is that row's `[cap + s]` mask slice, `tokens` /
/// `positions` the `s` speculative slots of the row's own request,
/// `kv` that request's gather-aware cache view (flat, paged, or a
/// session mirror), and `(layers, rstride)` the role's layer count and
/// per-row stride. Mask columns are **logical** rows; the paged layout
/// resolves each open column through the block table
/// ([`KvView::row_start`]), so any block-table bug changes the hash and
/// is caught by the flat-vs-paged bit-identity suite.
#[allow(clippy::too_many_arguments)]
fn hash_ctx(
    seen: &mut Vec<(i64, i64)>,
    cap: usize,
    mask_row: &[f32],
    tokens: &[i32],
    positions: &[i32],
    kv: &KvView,
    layers: usize,
    rstride: usize,
) -> u64 {
    let s = tokens.len();
    debug_assert_eq!(mask_row.len(), cap + s, "mask row width mismatch");
    seen.clear();
    // cache columns: token at element 0, position at element 1 of the
    // layer-0 row (the sim's own KV encoding).
    for (j, mval) in mask_row.iter().take(cap).enumerate() {
        if *mval == 0.0 {
            let off = kv.row_start(layers, rstride, 0, j);
            let tok = kv.k[off] as i64;
            let pos = kv.k[off + 1] as i64;
            seen.push((pos, tok));
        }
    }
    for (j, mval) in mask_row[cap..cap + s].iter().enumerate() {
        if *mval == 0.0 {
            seen.push((positions[j] as i64, tokens[j] as i64));
        }
    }
    // positions are unique across visible columns (committed prefix,
    // tree ancestors and chain slots are all position-distinct), so
    // the unstable sort is deterministic — and allocation-free, unlike
    // the stable sort's merge buffer.
    seen.sort_unstable_by_key(|(p, _)| *p);
    let mut h = 0x5151_5151u64;
    for (p, t) in seen.iter() {
        h = splitmix64(h.wrapping_mul(31) ^ ((*t as u64) << 16) ^ (*p as u64));
    }
    h
}

/// Deterministic simulator backend (see the module docs).
pub struct SimBackend {
    contract: Contract,
    caps: Capabilities,
    /// Probability (percent) that the draft's top-1 equals the teacher's.
    pub agree_pct: u64,
    /// Teacher *launches* observed (a fused batched step counts once).
    pub teacher_calls: u64,
    /// Draft launches observed.
    pub draft_calls: u64,
    /// Modeled host→device bytes shipped (full view per step without a
    /// session; dirty-delta rows with one — see the module docs).
    pub upload_bytes: u64,
    /// Simulated per-launch dispatch cost of the teacher module (spin-
    /// waited once per launch, fused or not). Zero (the default) disables
    /// the model.
    pub teacher_launch: Duration,
    /// Simulated per-live-row compute cost of a teacher launch — the
    /// share of launch cost batching cannot amortize. Zero by default.
    pub teacher_row_cost: Duration,
    /// Histogram of teacher launches by **executed** fused width:
    /// `launches_by_width[b]` counts launches that verified `b` live
    /// requests (single-request steps count under width 1, even when the
    /// negotiated variant is padded wider). Continuous-batching benches
    /// read this to show admission sustaining full-width launches.
    pub launches_by_width: Vec<u64>,
    /// Device seconds hidden behind host work between
    /// [`ModelBackend::begin_execute_batch`] and
    /// [`ModelBackend::await_batch`] — the measured overlap win of the
    /// pipelined scheduler (see the device-clock model in the module
    /// docs).
    pub overlap_saved_secs: f64,
    /// Simulated per-launch host dispatch cost of the draft module.
    /// Zero (the default) disables it; overlap tests/benches set it
    /// nonzero so the host has real draft work to hide behind an
    /// in-flight teacher launch.
    pub draft_launch: Duration,
    /// Device-clock model: when the simulated accelerator next becomes
    /// free (`None` until the first costed launch).
    device_free_at: Option<Instant>,
    /// In-flight overlapped launches: (token id, device deadline,
    /// modeled launch cost).
    pending: Vec<(u64, Instant, Duration)>,
    /// Monotonic overlapped-launch id source (0 is reserved for
    /// [`LaunchToken::completed`]).
    next_launch: u64,
    /// Reusable (position, token) scratch for context reconstruction —
    /// grows once to the visible-context high-water mark.
    seen: Vec<(i64, i64)>,
    /// Bound KV-session mirrors, keyed by session id.
    sessions: HashMap<u64, SimSession>,
    next_session: u64,
}

impl SimBackend {
    /// A sim with the given draft/teacher agreement percentage and no
    /// launch-cost model.
    pub fn new(agree_pct: u64) -> Self {
        let contract = Contract::default();
        let caps = Capabilities::synthetic(&contract, DEFAULT_MAX_FUSED_B);
        let seen = Vec::with_capacity(contract.cache_cap + 64);
        Self {
            contract,
            caps,
            agree_pct,
            teacher_calls: 0,
            draft_calls: 0,
            upload_bytes: 0,
            teacher_launch: Duration::ZERO,
            teacher_row_cost: Duration::ZERO,
            launches_by_width: Vec::new(),
            overlap_saved_secs: 0.0,
            draft_launch: Duration::ZERO,
            device_free_at: None,
            pending: Vec::new(),
            next_launch: 0,
            seen,
            sessions: HashMap::new(),
            next_session: 0,
        }
    }

    /// Builder: set the simulated per-launch teacher dispatch cost.
    pub fn with_teacher_launch(mut self, cost: Duration) -> Self {
        self.teacher_launch = cost;
        self
    }

    /// Builder: set the simulated per-live-row teacher compute cost.
    pub fn with_row_cost(mut self, cost: Duration) -> Self {
        self.teacher_row_cost = cost;
        self
    }

    /// Builder: set the simulated per-launch draft dispatch cost — the
    /// host-side work a pipelined scheduler hides behind an in-flight
    /// teacher launch.
    pub fn with_draft_cost(mut self, cost: Duration) -> Self {
        self.draft_launch = cost;
        self
    }

    /// Builder: bound the synthetic capabilities table to fused widths
    /// `<= max_b` — the way tests force the verifier's group-splitting
    /// path on a simulator.
    pub fn with_max_fused(mut self, max_b: usize) -> Self {
        self.caps = Capabilities::synthetic(&self.contract, max_b);
        self
    }

    /// Account one teacher launch of `width` executed fused requests
    /// computing `rows` padded rows, and place it on the device clock:
    /// the launch occupies the simulated accelerator from
    /// `max(now, device_free_at)` for its modeled cost. Returns the
    /// device deadline and the modeled cost; the caller decides whether
    /// to spin now (synchronous path) or at await (overlapped path).
    fn schedule_launch(&mut self, width: usize, rows: usize) -> (Instant, Duration) {
        self.teacher_calls += 1;
        if self.launches_by_width.len() <= width {
            self.launches_by_width.resize(width + 1, 0);
        }
        self.launches_by_width[width] += 1;
        let cost = self.teacher_launch + self.teacher_row_cost * rows as u32;
        // lint: allow(wall-clock) — the sim *is* the modeled device clock: deadlines are future Instants the Stopwatch API deliberately cannot express
        let now = Instant::now();
        let start = self.device_free_at.map_or(now, |free| free.max(now));
        let deadline = start + cost;
        self.device_free_at = Some(deadline);
        (deadline, cost)
    }

    /// Synchronous launch accounting: schedule on the device clock and
    /// spin until the deadline (no syscall, so the wait is accurate at
    /// microsecond scale and deterministic in ordering).
    fn record_launch(&mut self, width: usize, rows: usize) {
        let (deadline, cost) = self.schedule_launch(width, rows);
        if !cost.is_zero() {
            Self::spin_until(deadline);
        }
    }

    /// Busy-wait until the device-clock deadline.
    fn spin_until(deadline: Instant) {
        // lint: allow(wall-clock) — spinning to a future device-clock deadline; elapsed-only timers cannot model this
        while Instant::now() < deadline {
            std::hint::spin_loop();
        }
    }

    /// The executed width of a fused dispatch: the live requests it
    /// actually verifies. Group-padding requests (`live == 0`) appended
    /// to fill a wider compiled variant are not part of the executed
    /// width — a single-request launch padded to a `[4, S]` variant is
    /// still a width-1 dispatch (the PJRT fallback literally routes it
    /// through the single-request `execute`).
    fn executed_width(reqs: &[super::BatchRequest]) -> usize {
        reqs.iter().filter(|r| r.live > 0).count().max(1)
    }

    /// The fused "device" compute of one batched step — everything but
    /// the launch-cost accounting, shared by the synchronous
    /// `execute_batch` and the overlapped `begin_execute_batch` paths.
    /// One pass over all live rows; outputs are bit-identical to
    /// sequential single-request steps (see the module docs).
    fn fused_compute(&mut self, args: BatchStepArgs, out: &mut StepScratch) -> Result<()> {
        let b = args.reqs.len();
        let s = args.s_max;
        let cap = self.contract.cache_cap;
        let w = cap + s;
        let d = self.contract.teacher;
        let f = self.contract.feat_dim;
        let rs = d.heads * d.d_head;
        // transfer model: per-call tensors once, each request's cache by
        // its own session state (padding requests have no session and an
        // empty view — a real padded launch still ships a full-size zero
        // cache block for them)
        let mut upload = (args.tokens.len() * 8 + args.mask.len() * 4) as u64;
        for req in args.reqs.iter() {
            upload += self.sync_from_ticket(req.session, &req.kv, ModuleRole::Teacher, d)?;
        }
        self.upload_bytes += upload;
        out.prepare_batch(b, s, self.contract.vocab, f, d.layers, d.heads, d.d_head, false);
        debug_assert_eq!(args.tokens.len(), b * s, "fused tokens length");
        debug_assert_eq!(args.positions.len(), b * s, "fused positions length");
        debug_assert_eq!(args.mask.len(), b * s * w, "fused mask length");
        let rows = b * s;
        let mut seen = std::mem::take(&mut self.seen);
        for (bi, req) in args.reqs.iter().enumerate() {
            let base = bi * s;
            let kv = Self::read_view(&self.sessions, req.session, req.kv, cap);
            for i in 0..req.live.min(s) {
                let row = base + i;
                let ctx = hash_ctx(
                    &mut seen,
                    cap,
                    &args.mask[row * w..(row + 1) * w],
                    &args.tokens[base..base + s],
                    &args.positions[base..base + s],
                    &kv,
                    d.layers,
                    rs,
                );
                let cands = Self::candidates(ctx);
                Self::write_logits(out.logits_row_mut(row), &cands);
                let (tok, pos) = (args.tokens[row] as f32, args.positions[row] as f32);
                let fr = out.feat_row_mut(row);
                fr.fill(0.0);
                fr[0] = tok;
                fr[1] = pos;
                for l in 0..d.layers {
                    let off = (l * rows + row) * rs;
                    out.k_new[off..off + rs].fill(0.0);
                    out.v_new[off..off + rs].fill(0.0);
                    out.k_new[off] = tok;
                    out.k_new[off + 1] = pos;
                    out.v_new[off] = tok;
                    out.v_new[off + 1] = pos;
                }
            }
        }
        self.seen = seen;
        Ok(())
    }

    /// Deterministic candidate list for a context.
    fn candidates(ctx: u64) -> [i32; TOP_N] {
        let span = (VOCAB - FIRST_TOKEN as usize) as u64;
        let mut out = [0i32; TOP_N];
        for i in 0..TOP_N {
            let mut t = FIRST_TOKEN + (splitmix64(ctx ^ ((i as u64 + 1) * 0x9E37)) % span) as i32;
            while out[..i].contains(&t) {
                t = FIRST_TOKEN + ((t - FIRST_TOKEN + 1) % span as i32);
            }
            out[i] = t;
        }
        out
    }

    fn write_logits(row: &mut [f32], cands: &[i32; TOP_N]) {
        row.fill(-4.0);
        for (i, c) in cands.iter().enumerate() {
            row[*c as usize] = 6.0 - i as f32 * 0.75;
        }
    }

    fn write_kv(args: &StepArgs, layers: usize, rs: usize, k_new: &mut [f32], v_new: &mut [f32]) {
        let s = args.tokens.len();
        k_new.fill(0.0);
        v_new.fill(0.0);
        for l in 0..layers {
            for i in 0..s {
                let off = (l * s + i) * rs;
                k_new[off] = args.tokens[i] as f32;
                k_new[off + 1] = args.positions[i] as f32;
                v_new[off] = args.tokens[i] as f32;
                v_new[off + 1] = args.positions[i] as f32;
            }
        }
    }

    fn write_feats(&self, args: &StepArgs, out: &mut StepScratch) {
        let s = args.tokens.len();
        let f = self.contract.feat_dim;
        out.feats.fill(0.0);
        for i in 0..s {
            out.feats[i * f] = args.tokens[i] as f32;
            out.feats[i * f + 1] = args.positions[i] as f32;
        }
    }

    fn write_probe(&self, args: &StepArgs, heads: usize, probe: bool, out: &mut StepScratch) {
        if !probe {
            return;
        }
        let cap = self.contract.cache_cap;
        let s = args.tokens.len();
        let w = cap + s;
        for i in 0..s {
            let row = &args.mask[i * w..(i + 1) * w];
            let first = row.iter().position(|m| *m == 0.0).unwrap_or(0);
            let last = w - 1 - row.iter().rev().position(|m| *m == 0.0).unwrap_or(0);
            for h in 0..heads {
                // even heads look far back (the "topic" dependency that
                // Fig 7 surfaces), odd heads look local.
                out.attn_top1[i * heads + h] = if h % 2 == 0 { first as i32 } else { last as i32 };
            }
        }
    }

    /// Sync the ticketed session (if any) from the step's cache view and
    /// return the modeled host→device cache transfer of this step: the
    /// dirty-delta rows with a session, the full `[L, cap, H, Dh]` pair
    /// without one.
    fn sync_from_ticket(
        &mut self,
        ticket: Option<SessionTicket>,
        kv: &KvView,
        expect_role: ModuleRole,
        dims: Dims,
    ) -> Result<u64> {
        let cap = self.contract.cache_cap;
        let rs = dims.heads * dims.d_head;
        let Some(t) = ticket else {
            return Ok((2 * dims.cache_elems(cap) * 4) as u64);
        };
        let sess =
            self.sessions.get_mut(&t.id).ok_or(PlanError::UnknownSession { id: t.id })?;
        if sess.role != expect_role {
            return Err(
                PlanError::RoleMismatch { bound: sess.role, requested: expect_role }.into()
            );
        }
        let range = t.sync_range();
        let delta = range.len();
        sync_rows(sess, kv, range.start, t.rows, dims.layers, rs, cap);
        Ok((delta * 2 * dims.layers * rs * 4) as u64)
    }

    /// Resolve the cache view a step's context reads go through: the
    /// session mirror when the step is ticketed, else the caller's view.
    fn read_view<'a>(
        sessions: &'a HashMap<u64, SimSession>,
        ticket: Option<SessionTicket>,
        fallback: KvView<'a>,
        cap: usize,
    ) -> KvView<'a> {
        match ticket.and_then(|t| sessions.get(&t.id)) {
            Some(sess) => KvView::flat(&sess.k, &sess.v, cap),
            None => fallback,
        }
    }

    fn step(
        &mut self,
        plan: &LaunchPlan,
        args: StepArgs,
        teacher: bool,
        out: &mut StepScratch,
    ) -> Result<()> {
        let s = args.tokens.len();
        let v = self.contract.vocab;
        let d = if teacher { self.contract.teacher } else { self.contract.draft };
        let probe = plan.key.probe && args.probe;
        out.prepare(s, v, self.contract.feat_dim, d.layers, d.heads, d.d_head, probe);
        let rstride = d.heads * d.d_head;
        let cap = self.contract.cache_cap;
        let w = cap + s;
        let agree = self.agree_pct;
        let mut seen = std::mem::take(&mut self.seen);
        {
            let kv = Self::read_view(&self.sessions, args.session, args.kv, cap);
            for i in 0..s {
                let ctx = hash_ctx(
                    &mut seen,
                    cap,
                    &args.mask[i * w..(i + 1) * w],
                    args.tokens,
                    args.positions,
                    &kv,
                    d.layers,
                    rstride,
                );
                let cands = if teacher {
                    Self::candidates(ctx)
                } else if splitmix64(ctx ^ 0xD15A_6EE2) % 100 < agree {
                    // Deterministic agreement coin per context: an agreeing
                    // draft proposes the teacher's own candidate list; a
                    // disagreeing one proposes an unrelated list (a *bad*
                    // draft — merely swapping the top-2 would be rescued by
                    // the tree's top-k children, which is exactly the point
                    // of tree speculation).
                    Self::candidates(ctx)
                } else {
                    Self::candidates(splitmix64(ctx ^ 0xBAD_D4AF7))
                };
                Self::write_logits(out.logits_row_mut(i), &cands);
            }
        }
        self.seen = seen;
        self.write_feats(&args, out);
        Self::write_kv(&args, d.layers, d.heads * d.d_head, &mut out.k_new, &mut out.v_new);
        self.write_probe(&args, d.heads, probe, out);
        Ok(())
    }
}

impl ModelBackend for SimBackend {
    fn contract(&self) -> &Contract {
        &self.contract
    }

    fn capabilities(&self) -> &Capabilities {
        &self.caps
    }

    fn execute(&mut self, plan: &LaunchPlan, args: StepArgs, out: &mut StepScratch) -> Result<()> {
        let teacher = plan.key.role == ModuleRole::Teacher;
        let s = args.tokens.len();
        let d = if teacher { self.contract.teacher } else { self.contract.draft };
        if teacher {
            self.record_launch(1, s);
        } else {
            self.draft_calls += 1;
            // draft dispatch is host-side work under the overlap model:
            // spin on the host clock, never on the device clock
            if !self.draft_launch.is_zero() {
                let t0 = Stopwatch::start();
                let budget = self.draft_launch.as_secs_f64();
                while t0.elapsed_secs() < budget {
                    std::hint::spin_loop();
                }
            }
        }
        let small = (s * 8 + args.mask.len() * 4 + args.feats_in.map_or(0, |f| f.len() * 4))
            as u64;
        let role = plan.key.role;
        let cache = self.sync_from_ticket(args.session, &args.kv, role, d)?;
        self.upload_bytes += small + cache;
        self.step(plan, args, teacher, out)
    }

    /// True fused implementation: one pass, one launch counted, one
    /// launch-cost charge. Live rows are bit-identical to sequential
    /// single-request steps; padding rows (`i >= live`) are skipped and
    /// left backend-defined (never read back by contract).
    fn execute_batch(
        &mut self,
        plan: &LaunchPlan,
        args: BatchStepArgs,
        out: &mut StepScratch,
    ) -> Result<()> {
        // a real fused [B, S] launch computes every padded row of the
        // *compiled* variant, not just the live ones — charge what the
        // hardware would charge, so ragged mixed-budget groups don't
        // look cheaper than they are; the histogram, by contrast,
        // records the width actually dispatched (live requests only)
        self.record_launch(Self::executed_width(args.reqs), plan.padded_rows());
        self.fused_compute(args, out)
    }

    /// Start a fused launch on the device clock without waiting for it:
    /// the outputs are computed host-side eagerly (the sim's "device
    /// work" is pure accounting), but the launch-cost spin is deferred
    /// to [`ModelBackend::await_batch`], which only waits out the time
    /// remaining to the device deadline.
    fn begin_execute_batch(
        &mut self,
        plan: &LaunchPlan,
        args: BatchStepArgs,
        out: &mut StepScratch,
    ) -> Result<LaunchToken> {
        let (deadline, cost) =
            self.schedule_launch(Self::executed_width(args.reqs), plan.padded_rows());
        self.fused_compute(args, out)?;
        self.next_launch += 1;
        let id = self.next_launch;
        self.pending.push((id, deadline, cost));
        Ok(LaunchToken { id })
    }

    /// Complete an overlapped launch: spin only for the time remaining
    /// to its device deadline, and bank the device seconds the host did
    /// not have to wait into [`SimBackend::overlap_saved_secs`].
    fn await_batch(&mut self, token: LaunchToken, out: &mut StepScratch) -> Result<()> {
        let _ = out; // outputs landed host-side at begin
        if token.is_completed() {
            return Ok(());
        }
        let idx = self
            .pending
            .iter()
            .position(|(id, _, _)| *id == token.id)
            .ok_or_else(|| anyhow::anyhow!("await_batch: unknown sim launch token {}", token.id))?;
        let (_, deadline, cost) = self.pending.swap_remove(idx);
        // lint: allow(wall-clock) — overlap accounting against a future device-clock deadline (see schedule_launch)
        let waited = deadline.saturating_duration_since(Instant::now());
        self.overlap_saved_secs += cost.saturating_sub(waited).as_secs_f64();
        Self::spin_until(deadline);
        Ok(())
    }
    fn bind_kv(
        &mut self,
        role: ModuleRole,
        view: KvView,
        rows: usize,
    ) -> Result<KvSession, PlanError> {
        let d = match role {
            ModuleRole::Teacher => self.contract.teacher,
            ModuleRole::Draft => self.contract.draft,
        };
        let cap = self.contract.cache_cap;
        let rs = d.heads * d.d_head;
        let n = d.cache_elems(cap);
        let mut sess = SimSession { role, k: vec![0.0; n], v: vec![0.0; n], rows: 0 };
        sync_rows(&mut sess, &view, 0, rows, d.layers, rs, cap);
        self.upload_bytes += (rows * 2 * d.layers * rs * 4) as u64;
        let id = self.next_session;
        self.next_session += 1;
        self.sessions.insert(id, sess);
        Ok(KvSession { id, role })
    }

    fn rebind_kv(
        &mut self,
        session: &KvSession,
        view: KvView,
        rows: usize,
    ) -> Result<(), PlanError> {
        let d = match session.role {
            ModuleRole::Teacher => self.contract.teacher,
            ModuleRole::Draft => self.contract.draft,
        };
        let cap = self.contract.cache_cap;
        let rs = d.heads * d.d_head;
        let sess = self
            .sessions
            .get_mut(&session.id)
            .ok_or(PlanError::UnknownSession { id: session.id })?;
        sync_rows(sess, &view, 0, rows, d.layers, rs, cap);
        self.upload_bytes += (rows * 2 * d.layers * rs * 4) as u64;
        Ok(())
    }

    fn unbind_kv(&mut self, session: KvSession) {
        self.sessions.remove(&session.id);
    }

    fn name(&self) -> &'static str {
        "sim"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{argmax, BatchRequest, KvView};
    use crate::config::contract::{CACHE_CAP, NEG_INF};
    use crate::config::ExecMode;

    fn empty_cache(c: &Contract) -> (Vec<f32>, Vec<f32>) {
        let n = c.teacher.cache_elems(c.cache_cap);
        (vec![0.0; n], vec![0.0; n])
    }

    fn chain_mask(s: usize, live: usize, t: usize) -> Vec<f32> {
        let w = CACHE_CAP + s;
        let mut m = vec![NEG_INF; s * w];
        for i in 0..live {
            for j in 0..t {
                m[i * w + j] = 0.0;
            }
            for j in 0..=i {
                m[i * w + CACHE_CAP + j] = 0.0;
            }
        }
        m
    }

    #[test]
    fn teacher_is_deterministic_and_context_sensitive() {
        let mut b = SimBackend::new(100);
        let (k, v) = empty_cache(b.contract());
        let mask = chain_mask(8, 3, 0);
        let pos = [0i32, 1, 2, 0, 0, 0, 0, 0];
        let run = |b: &mut SimBackend, mode: ExecMode, tokens: [i32; 8]| {
            let mut out = StepScratch::new();
            b.teacher_step(mode, StepArgs {
                tokens: &tokens, positions: &pos, mask: &mask,
                kv: KvView::flat(&k, &v, CACHE_CAP), feats_in: None, probe: false,
                session: None,
            }, &mut out)
            .unwrap();
            out
        };
        let o1 = run(&mut b, ExecMode::Fused, [5, 6, 7, 0, 0, 0, 0, 0]);
        let o2 = run(&mut b, ExecMode::Eager, [5, 6, 7, 0, 0, 0, 0, 0]);
        assert_eq!(o1.logits, o2.logits, "mode must not change sim semantics");
        let o3 = run(&mut b, ExecMode::Fused, [5, 6, 9, 0, 0, 0, 0, 0]);
        assert_ne!(
            argmax(o1.logits_row(2)),
            argmax(o3.logits_row(2)),
            "changing a visible token must change the slot's distribution"
        );
    }

    #[test]
    fn masked_slots_do_not_influence_context() {
        let mut b = SimBackend::new(100);
        let (k, v) = empty_cache(b.contract());
        let mask = chain_mask(8, 2, 0);
        let pos = [0i32, 1, 0, 0, 0, 0, 0, 0];
        let run = |b: &mut SimBackend, t2: i32| {
            let tokens = [5, 6, t2, 0, 0, 0, 0, 0];
            let mut out = StepScratch::new();
            b.teacher_step(ExecMode::Fused, StepArgs {
                tokens: &tokens, positions: &pos, mask: &mask,
                kv: KvView::flat(&k, &v, CACHE_CAP), feats_in: None, probe: false,
                session: None,
            }, &mut out)
            .unwrap();
            out.logits_row(1).to_vec()
        };
        assert_eq!(run(&mut b, 100), run(&mut b, 200), "masked slot token leaked");
    }

    #[test]
    fn draft_agreement_controls_top1_match() {
        let mut t = SimBackend::new(100);
        let mut d_always = SimBackend::new(100);
        let mut d_never = SimBackend::new(0);
        let (k, v) = empty_cache(t.contract());
        let mask = chain_mask(8, 4, 0);
        let tokens = [5i32, 9, 3, 7, 0, 0, 0, 0];
        let pos = [0i32, 1, 2, 3, 0, 0, 0, 0];
        let args = || StepArgs {
            tokens: &tokens, positions: &pos, mask: &mask,
            kv: KvView::flat(&k, &v, CACHE_CAP), feats_in: None, probe: false,
            session: None,
        };
        let mut to = StepScratch::new();
        t.teacher_step(ExecMode::Fused, args(), &mut to).unwrap();
        let mut da = StepScratch::new();
        d_always.draft_step(args(), &mut da).unwrap();
        let mut dn = StepScratch::new();
        d_never.draft_step(args(), &mut dn).unwrap();
        for i in 0..4 {
            assert_eq!(
                argmax(to.logits_row(i)),
                argmax(da.logits_row(i)),
                "agree_pct=100 must match teacher"
            );
            assert_ne!(
                argmax(to.logits_row(i)),
                argmax(dn.logits_row(i)),
                "agree_pct=0 must differ"
            );
        }
    }

    #[test]
    fn kv_rows_encode_token_and_position() {
        let mut b = SimBackend::new(100);
        let (k, v) = empty_cache(b.contract());
        let mask = chain_mask(8, 2, 0);
        let tokens = [42i32, 43, 0, 0, 0, 0, 0, 0];
        let pos = [7i32, 8, 0, 0, 0, 0, 0, 0];
        let mut out = StepScratch::new();
        b.teacher_step(ExecMode::Fused, StepArgs {
            tokens: &tokens, positions: &pos, mask: &mask,
            kv: KvView::flat(&k, &v, CACHE_CAP), feats_in: None, probe: false,
            session: None,
        }, &mut out)
        .unwrap();
        let rs = b.contract().teacher.heads * b.contract().teacher.d_head;
        assert_eq!(out.k_new[0], 42.0);
        assert_eq!(out.k_new[1], 7.0);
        assert_eq!(out.k_new[rs], 43.0);
        assert_eq!(out.k_new[rs + 1], 8.0);
    }

    #[test]
    fn probe_reports_far_and_near_columns() {
        let mut b = SimBackend::new(100);
        let (k, v) = empty_cache(b.contract());
        let mask = chain_mask(8, 2, 5); // prefix of 5 visible
        let tokens = [1i32, 2, 0, 0, 0, 0, 0, 0];
        let pos = [5i32, 6, 0, 0, 0, 0, 0, 0];
        let mut out = StepScratch::new();
        b.draft_step(StepArgs {
            tokens: &tokens, positions: &pos, mask: &mask,
            kv: KvView::flat(&k, &v, CACHE_CAP), feats_in: None, probe: true,
            session: None,
        }, &mut out)
        .unwrap();
        let top1 = out.attn_top1().unwrap();
        assert_eq!(top1[0], 0, "even head looks at the far history (topic)");
        assert_eq!(top1[1], CACHE_CAP as i32, "odd head looks local");
    }

    #[test]
    fn repeated_calls_reuse_scratch_capacity() {
        let mut b = SimBackend::new(90);
        let (k, v) = empty_cache(b.contract());
        let mask = chain_mask(8, 3, 0);
        let tokens = [5i32, 6, 7, 0, 0, 0, 0, 0];
        let pos = [0i32, 1, 2, 0, 0, 0, 0, 0];
        let mut out = StepScratch::new();
        for _ in 0..3 {
            b.teacher_step(ExecMode::Fused, StepArgs {
                tokens: &tokens, positions: &pos, mask: &mask,
                kv: KvView::flat(&k, &v, CACHE_CAP), feats_in: None, probe: false,
                session: None,
            }, &mut out)
            .unwrap();
        }
        assert_eq!(out.s(), 8);
        assert_eq!(out.logits.len(), 8 * VOCAB);
    }

    /// The backend-level bit-identity claim: a fused 2-request step (with
    /// ragged per-request variants padded to S_max) reproduces the exact
    /// live output rows of two sequential single-request steps, and is
    /// counted as ONE teacher launch.
    #[test]
    fn fused_batch_matches_sequential_rows_exactly() {
        let contract = Contract::default();
        let (k0, v0) = {
            let n = contract.teacher.cache_elems(contract.cache_cap);
            // distinct caches: encode (token, position) rows the sim reads
            let mut k = vec![0.0; n];
            let mut v = vec![0.0; n];
            let rs = contract.teacher.heads * contract.teacher.d_head;
            for row in 0..4 {
                k[row * rs] = (10 + row) as f32; // token
                k[row * rs + 1] = row as f32; // position
                v[row * rs] = (10 + row) as f32;
                v[row * rs + 1] = row as f32;
            }
            (k, v)
        };
        let (k1, v1) = empty_cache(&contract);

        // request 0: s_req = 8, prefix of 4, 3 live chain slots
        let tok0 = [5i32, 6, 7, 0, 0, 0, 0, 0];
        let pos0 = [4i32, 5, 6, 4, 4, 4, 4, 4];
        let mask0 = chain_mask(8, 3, 4);
        // request 1: s_req = 8, no prefix, 2 live slots
        let tok1 = [9i32, 3, 0, 0, 0, 0, 0, 0];
        let pos1 = [0i32, 1, 0, 0, 0, 0, 0, 0];
        let mask1 = chain_mask(8, 2, 0);

        // sequential reference
        let mut seq = SimBackend::new(100);
        let mut out0 = StepScratch::new();
        seq.teacher_step(ExecMode::Fused, StepArgs {
            tokens: &tok0, positions: &pos0, mask: &mask0,
            kv: KvView::flat(&k0, &v0, CACHE_CAP), feats_in: None, probe: false,
            session: None,
        }, &mut out0).unwrap();
        let mut out1 = StepScratch::new();
        seq.teacher_step(ExecMode::Fused, StepArgs {
            tokens: &tok1, positions: &pos1, mask: &mask1,
            kv: KvView::flat(&k1, &v1, CACHE_CAP), feats_in: None, probe: false,
            session: None,
        }, &mut out1).unwrap();
        assert_eq!(seq.teacher_calls, 2);

        // fused: both requests in one [2, 8, cap+8] block
        let s = 8usize;
        let w = CACHE_CAP + s;
        let mut tokens = vec![0i32; 2 * s];
        tokens[..s].copy_from_slice(&tok0);
        tokens[s..].copy_from_slice(&tok1);
        let mut positions = vec![0i32; 2 * s];
        positions[..s].copy_from_slice(&pos0);
        positions[s..].copy_from_slice(&pos1);
        let mut mask = vec![NEG_INF; 2 * s * w];
        mask[..s * w].copy_from_slice(&mask0);
        mask[s * w..].copy_from_slice(&mask1);
        let reqs = [
            BatchRequest { kv: KvView::flat(&k0, &v0, CACHE_CAP), live: 8, session: None },
            BatchRequest { kv: KvView::flat(&k1, &v1, CACHE_CAP), live: 8, session: None },
        ];
        let mut fused_b = SimBackend::new(100);
        let mut fused = StepScratch::new();
        fused_b.teacher_step_batch(ExecMode::Fused, BatchStepArgs {
            s_max: s, tokens: &tokens, positions: &positions, mask: &mask, reqs: &reqs,
        }, &mut fused).unwrap();
        assert_eq!(fused_b.teacher_calls, 1, "fused batch is one launch");

        let mut got0 = StepScratch::new();
        got0.scatter_from(&fused, 0, 8);
        let mut got1 = StepScratch::new();
        got1.scatter_from(&fused, 1, 8);
        assert_eq!(got0.logits, out0.logits, "request 0 logits diverged");
        assert_eq!(got1.logits, out1.logits, "request 1 logits diverged");
        assert_eq!(got0.feats, out0.feats);
        assert_eq!(got1.feats, out1.feats);
        assert_eq!(got0.k_new, out0.k_new);
        assert_eq!(got1.k_new, out1.k_new);
        assert_eq!(got0.v_new, out0.v_new);
        assert_eq!(got1.v_new, out1.v_new);
    }

    #[test]
    fn launch_width_histogram_and_row_cost() {
        let mut b = SimBackend::new(100).with_row_cost(Duration::from_micros(50));
        let (k, v) = empty_cache(b.contract());
        let mask = chain_mask(8, 2, 0);
        let tokens = [5i32, 6, 0, 0, 0, 0, 0, 0];
        let pos = [0i32, 1, 0, 0, 0, 0, 0, 0];
        let mut out = StepScratch::new();
        let t0 = Instant::now();
        b.teacher_step(ExecMode::Fused, StepArgs {
            tokens: &tokens, positions: &pos, mask: &mask,
            kv: KvView::flat(&k, &v, CACHE_CAP), feats_in: None, probe: false,
            session: None,
        }, &mut out)
        .unwrap();
        // 8 padded rows at 50us each
        assert!(t0.elapsed() >= Duration::from_micros(8 * 50), "row cost must be spent");
        assert_eq!(b.launches_by_width.get(1), Some(&1));

        // a fused width-2 launch lands in bucket 2
        let w = CACHE_CAP + 8;
        let mut m2 = vec![NEG_INF; 2 * 8 * w];
        m2[..8 * w].copy_from_slice(&mask);
        m2[8 * w..].copy_from_slice(&mask);
        let mut t2 = vec![0i32; 16];
        t2[..8].copy_from_slice(&tokens);
        t2[8..].copy_from_slice(&tokens);
        let mut p2 = vec![0i32; 16];
        p2[..8].copy_from_slice(&pos);
        p2[8..].copy_from_slice(&pos);
        let reqs = [
            BatchRequest { kv: KvView::flat(&k, &v, CACHE_CAP), live: 2, session: None },
            BatchRequest { kv: KvView::flat(&k, &v, CACHE_CAP), live: 2, session: None },
        ];
        let mut fused = StepScratch::new();
        b.teacher_step_batch(ExecMode::Fused, BatchStepArgs {
            s_max: 8, tokens: &t2, positions: &p2, mask: &m2, reqs: &reqs,
        }, &mut fused)
        .unwrap();
        assert_eq!(b.launches_by_width.get(2), Some(&1));
        assert_eq!(b.teacher_calls, 2);
    }

    #[test]
    fn launch_cost_is_charged_per_launch() {
        let cost = Duration::from_millis(2);
        let mut b = SimBackend::new(100).with_teacher_launch(cost);
        let (k, v) = empty_cache(b.contract());
        let mask = chain_mask(8, 1, 0);
        let tokens = [5i32, 0, 0, 0, 0, 0, 0, 0];
        let pos = [0i32; 8];
        let mut out = StepScratch::new();
        let t0 = Instant::now();
        b.teacher_step(ExecMode::Fused, StepArgs {
            tokens: &tokens, positions: &pos, mask: &mask,
            kv: KvView::flat(&k, &v, CACHE_CAP), feats_in: None, probe: false,
            session: None,
        }, &mut out)
        .unwrap();
        assert!(t0.elapsed() >= cost, "launch cost must be spent");
        // draft launches are free under the model (the tiny draft's
        // dispatch is negligible next to the fused teacher module)
        let t1 = Instant::now();
        let feats = vec![0.0f32; 8 * b.contract().feat_dim];
        b.draft_step(StepArgs {
            tokens: &tokens, positions: &pos, mask: &mask,
            kv: KvView::flat(&k, &v, CACHE_CAP), feats_in: Some(&feats), probe: false,
            session: None,
        }, &mut out)
        .unwrap();
        assert!(t1.elapsed() < cost, "draft must not pay the teacher launch cost");
    }

    /// A ticketed step reading through a bound session mirror is
    /// bit-identical to the same step reading the live view, and the
    /// modeled upload drops from cap-scaled to delta-scaled.
    #[test]
    fn session_step_matches_full_view_and_shrinks_upload() {
        let contract = Contract::default();
        let rs = contract.teacher.heads * contract.teacher.d_head;
        let n = contract.teacher.cache_elems(contract.cache_cap);
        let mut k = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        for row in 0..6 {
            k[row * rs] = (20 + row) as f32;
            k[row * rs + 1] = row as f32;
            v[row * rs] = (20 + row) as f32;
            v[row * rs + 1] = row as f32;
        }
        let mask = chain_mask(8, 2, 6);
        let tokens = [3i32, 4, 0, 0, 0, 0, 0, 0];
        let pos = [6i32, 7, 0, 0, 0, 0, 0, 0];

        let mut plain = SimBackend::new(100);
        let mut out_plain = StepScratch::new();
        plain.teacher_step(ExecMode::Fused, StepArgs {
            tokens: &tokens, positions: &pos, mask: &mask,
            kv: KvView::flat(&k, &v, CACHE_CAP), feats_in: None, probe: false,
            session: None,
        }, &mut out_plain)
        .unwrap();
        let full_upload = plain.upload_bytes;

        let mut sess_b = SimBackend::new(100);
        let sess = sess_b
            .bind_kv(ModuleRole::Teacher, KvView::flat(&k, &v, CACHE_CAP), 6)
            .unwrap();
        let bind_upload = sess_b.upload_bytes;
        let mut out_sess = StepScratch::new();
        sess_b.teacher_step(ExecMode::Fused, StepArgs {
            tokens: &tokens, positions: &pos, mask: &mask,
            kv: KvView::flat(&k, &v, CACHE_CAP), feats_in: None, probe: false,
            session: Some(SessionTicket { id: sess.id, dirty_lo: usize::MAX, rows: 6 }),
        }, &mut out_sess)
        .unwrap();
        assert_eq!(out_sess.logits, out_plain.logits, "mirror context diverged");
        let step_upload = sess_b.upload_bytes - bind_upload;
        assert!(
            step_upload * 4 < full_upload,
            "clean-session step must upload far less than a full view: \
             {step_upload} vs {full_upload}"
        );
        sess_b.unbind_kv(sess);
        // a dangling ticket fails typed
        let err = sess_b
            .teacher_step(ExecMode::Fused, StepArgs {
                tokens: &tokens, positions: &pos, mask: &mask,
                kv: KvView::flat(&k, &v, CACHE_CAP), feats_in: None, probe: false,
                session: Some(SessionTicket { id: 99, dirty_lo: 0, rows: 6 }),
            }, &mut out_sess)
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown KV session"), "{err:#}");
    }

    /// A stale mirror row must change the context hash until the dirty
    /// watermark re-syncs it — the property the engine's watermark
    /// plumbing is tested against.
    #[test]
    fn session_dirty_watermark_resyncs_changed_rows() {
        let contract = Contract::default();
        let rs = contract.teacher.heads * contract.teacher.d_head;
        let n = contract.teacher.cache_elems(contract.cache_cap);
        let mut k = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        k[0] = 7.0; // token of committed row 0
        v[0] = 7.0;
        let mask = chain_mask(8, 1, 1);
        let tokens = [3i32, 0, 0, 0, 0, 0, 0, 0];
        let pos = [1i32, 0, 0, 0, 0, 0, 0, 0];
        let mut b = SimBackend::new(100);
        let sess = b.bind_kv(ModuleRole::Teacher, KvView::flat(&k, &v, CACHE_CAP), 1).unwrap();
        let run = |b: &mut SimBackend, k: &[f32], v: &[f32], dirty_lo: usize| {
            let mut out = StepScratch::new();
            b.teacher_step(ExecMode::Fused, StepArgs {
                tokens: &tokens, positions: &pos, mask: &mask,
                kv: KvView::flat(k, v, CACHE_CAP), feats_in: None, probe: false,
                session: Some(SessionTicket { id: sess.id, dirty_lo, rows: 1 }),
            }, &mut out)
            .unwrap();
            out.logits_row(0).to_vec()
        };
        let before = run(&mut b, &k, &v, usize::MAX);
        // mutate the committed row host-side; a clean ticket keeps the
        // stale mirror, a dirty one re-syncs
        k[0] = 9.0;
        let stale = run(&mut b, &k, &v, usize::MAX);
        assert_eq!(stale, before, "clean ticket must read the mirror, not the live view");
        let synced = run(&mut b, &k, &v, 0);
        assert_ne!(synced, before, "dirty ticket must re-sync the changed row");
    }

    #[test]
    fn capped_fused_width_reports_split() {
        let b = SimBackend::new(100).with_max_fused(2);
        use crate::backend::{ModuleLayout, PlanRequest};
        let err = b
            .plan_step(&PlanRequest::teacher_batch(ExecMode::Fused, 8, 4, ModuleLayout::Flat))
            .unwrap_err();
        assert_eq!(err, PlanError::SplitRequired { batch: 4, max_batch: 2 });
    }

    /// An overlapped begin/await pair must (a) produce the same outputs
    /// as the synchronous fused step, (b) spin only the device time the
    /// host did not already cover, and (c) report the hidden seconds.
    #[test]
    fn begin_await_overlap_hides_host_work_and_reports_it() {
        use crate::backend::{ModuleLayout, PlanRequest};
        let launch = Duration::from_millis(20);
        let mut b = SimBackend::new(100).with_teacher_launch(launch);
        let (k, v) = empty_cache(b.contract());
        let mask1 = chain_mask(8, 2, 0);
        let w = CACHE_CAP + 8;
        let mut mask = vec![NEG_INF; 2 * 8 * w];
        mask[..8 * w].copy_from_slice(&mask1);
        mask[8 * w..].copy_from_slice(&mask1);
        let mut tokens = vec![0i32; 16];
        tokens[..2].copy_from_slice(&[5, 6]);
        tokens[8..10].copy_from_slice(&[5, 6]);
        let mut positions = vec![0i32; 16];
        positions[..2].copy_from_slice(&[0, 1]);
        positions[8..10].copy_from_slice(&[0, 1]);
        let s = 8usize;
        let reqs = [
            BatchRequest { kv: KvView::flat(&k, &v, CACHE_CAP), live: 2, session: None },
            BatchRequest { kv: KvView::flat(&k, &v, CACHE_CAP), live: 2, session: None },
        ];
        let plan = b
            .plan_step(&PlanRequest::teacher_batch(ExecMode::Fused, 8, 2, ModuleLayout::Flat))
            .unwrap();

        // synchronous reference
        let mut sync_out = StepScratch::new();
        b.execute_batch(&plan, BatchStepArgs {
            s_max: s, tokens: &tokens, positions: &positions, mask: &mask, reqs: &reqs,
        }, &mut sync_out)
        .unwrap();

        // overlapped: begin, do "host work" for half the launch cost,
        // then await — the spin at await covers only the remainder
        let mut out = StepScratch::new();
        let t0 = Instant::now();
        let token = b
            .begin_execute_batch(&plan, BatchStepArgs {
                s_max: s, tokens: &tokens, positions: &positions, mask: &mask, reqs: &reqs,
            }, &mut out)
            .unwrap();
        assert!(!token.is_completed(), "sim must issue a real overlapped token");
        let host0 = Instant::now();
        while host0.elapsed() < launch / 2 {
            std::hint::spin_loop();
        }
        b.await_batch(token, &mut out).unwrap();
        assert!(t0.elapsed() >= launch, "device cost must still be fully paid");
        assert!(
            b.overlap_saved_secs >= launch.as_secs_f64() * 0.25,
            "host work must be hidden behind the in-flight launch: saved {}",
            b.overlap_saved_secs
        );
        assert_eq!(out.logits, sync_out.logits, "overlapped outputs diverged");
        assert_eq!(out.k_new, sync_out.k_new);
    }

    #[test]
    fn await_with_unknown_token_fails_typed() {
        let mut b = SimBackend::new(100);
        let mut out = StepScratch::new();
        let err = b.await_batch(LaunchToken { id: 99 }, &mut out).unwrap_err();
        assert!(format!("{err:#}").contains("unknown sim launch token"), "{err:#}");
    }

    /// A single live request padded to a wider compiled variant is still
    /// a width-1 dispatch: the histogram records the executed width, not
    /// the plan's padded width.
    #[test]
    fn histogram_records_executed_width_not_padded_plan_width() {
        use crate::backend::{ModuleLayout, PlanRequest};
        let mut b = SimBackend::new(100);
        let (k, v) = empty_cache(b.contract());
        let mask1 = chain_mask(8, 2, 0);
        let w = CACHE_CAP + 8;
        let mut mask = vec![NEG_INF; 2 * 8 * w];
        mask[..8 * w].copy_from_slice(&mask1);
        let mut tokens = vec![0i32; 16];
        tokens[..2].copy_from_slice(&[5, 6]);
        let mut positions = vec![0i32; 16];
        positions[..2].copy_from_slice(&[0, 1]);
        // request 1 is group padding (live == 0, empty view) filling a
        // [2, 8] compiled variant around one live request
        let reqs = [
            BatchRequest { kv: KvView::flat(&k, &v, CACHE_CAP), live: 2, session: None },
            BatchRequest { kv: KvView::flat(&[], &[], 0), live: 0, session: None },
        ];
        let plan = b
            .plan_step(&PlanRequest::teacher_batch(ExecMode::Fused, 8, 2, ModuleLayout::Flat))
            .unwrap();
        let mut out = StepScratch::new();
        b.execute_batch(&plan, BatchStepArgs {
            s_max: 8, tokens: &tokens, positions: &positions, mask: &mask, reqs: &reqs,
        }, &mut out)
        .unwrap();
        assert_eq!(plan.key.b, 2, "plan is padded to width 2");
        assert_eq!(b.launches_by_width.get(1), Some(&1), "executed width is 1");
        assert_eq!(b.launches_by_width.get(2).copied().unwrap_or(0), 0);
    }
}
