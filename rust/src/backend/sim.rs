//! SimBackend — a deterministic "hash language model" implementing
//! [`ModelBackend`] with *exact* context semantics.
//!
//! Purpose: every engine-level property the paper cares about — branch
//! isolation, commit equivalence, greedy output equivalence between EA and
//! baseline decoding, mask leakage, truncation sensitivity — can be tested
//! in microseconds without PJRT or artifacts.
//!
//! Semantics: a step's logits for slot `i` depend **only** on the visible
//! context of that slot — reconstructed the way real attention would see
//! it: tokens are read from the KV cache through the additive mask (the
//! sim writes each row's token id and position into its KV row), plus the
//! visible speculative slots of the current call. The context is hashed
//! and the hash determines a deterministic top-candidate list.
//!
//! * The sim **teacher**'s candidates come from the context hash.
//! * The sim **draft** computes the same hash on *its own* visible
//!   context (so a truncated drafter window changes its context and
//!   collapses agreement, reproducing E4), then agrees with the teacher's
//!   top-1 with probability `agree_pct` (a per-context deterministic
//!   coin), else swaps its top two candidates.
//!
//! Because the sim reads context strictly through mask + cache, any
//! masking bug, cache-write bug or commit bug in the engine changes its
//! outputs and is caught by the equivalence tests.
//!
//! Like every backend, the sim writes its outputs into the caller's
//! [`StepScratch`]; the only per-call state it owns is a reusable
//! context-reconstruction buffer, so steady-state calls allocate nothing.

use super::{ModelBackend, StepArgs, StepScratch};
use crate::config::contract::{FIRST_TOKEN, VOCAB};
use crate::config::{Contract, ExecMode};
use crate::util::rng::splitmix64;
use anyhow::Result;

/// Number of distinguished candidates per context.
const TOP_N: usize = 8;

pub struct SimBackend {
    contract: Contract,
    /// Probability (percent) that the draft's top-1 equals the teacher's.
    pub agree_pct: u64,
    /// Calls observed (per role) — used by tests and the harness.
    pub teacher_calls: u64,
    pub draft_calls: u64,
    /// Reusable (position, token) scratch for context reconstruction —
    /// grows once to the visible-context high-water mark.
    seen: Vec<(i64, i64)>,
}

impl SimBackend {
    pub fn new(agree_pct: u64) -> Self {
        let contract = Contract::default();
        let seen = Vec::with_capacity(contract.cache_cap + 64);
        Self { contract, agree_pct, teacher_calls: 0, draft_calls: 0, seen }
    }

    /// Context hash for slot `i`: fold (position, token) pairs of every
    /// visible column, sorted by position (stable on column order).
    /// `stride` is the per-row element stride of the KV buffer's layer 0
    /// (hoisted out of the per-column loop by the caller).
    fn context_hash(&mut self, i: usize, args: &StepArgs, stride: usize) -> u64 {
        let cap = self.contract.cache_cap;
        let s = args.tokens.len();
        let w = cap + s;
        let row = &args.mask[i * w..(i + 1) * w];
        self.seen.clear();
        // cache columns: token at element 0, position at element 1 of the
        // layer-0 row (the sim's own KV encoding).
        for (j, mval) in row.iter().take(cap).enumerate() {
            if *mval == 0.0 {
                let tok = args.kv.k[j * stride] as i64;
                let pos = args.kv.k[j * stride + 1] as i64;
                self.seen.push((pos, tok));
            }
        }
        for (j, mval) in row[cap..cap + s].iter().enumerate() {
            if *mval == 0.0 {
                self.seen.push((args.positions[j] as i64, args.tokens[j] as i64));
            }
        }
        // positions are unique across visible columns (committed prefix,
        // tree ancestors and chain slots are all position-distinct), so
        // the unstable sort is deterministic — and allocation-free, unlike
        // the stable sort's merge buffer.
        self.seen.sort_unstable_by_key(|(p, _)| *p);
        let mut h = 0x5151_5151u64;
        for (p, t) in &self.seen {
            h = splitmix64(h.wrapping_mul(31) ^ ((*t as u64) << 16) ^ (*p as u64));
        }
        h
    }

    /// Element stride of one cache row in layer 0 — derived from buffer
    /// size so the same code serves teacher- and draft-shaped caches.
    fn row_stride(&self, args: &StepArgs) -> usize {
        // kv buffer is [L, cap, H, Dh]; we address layer 0 rows only.
        let per_layer = args.kv.k.len()
            / match args.kv.k.len() {
                n if n == self.contract.teacher.cache_elems(self.contract.cache_cap) => {
                    self.contract.teacher.layers
                }
                _ => self.contract.draft.layers,
            };
        per_layer / self.contract.cache_cap
    }

    /// Deterministic candidate list for a context.
    fn candidates(ctx: u64) -> [i32; TOP_N] {
        let span = (VOCAB - FIRST_TOKEN as usize) as u64;
        let mut out = [0i32; TOP_N];
        for i in 0..TOP_N {
            let mut t = FIRST_TOKEN + (splitmix64(ctx ^ ((i as u64 + 1) * 0x9E37)) % span) as i32;
            while out[..i].contains(&t) {
                t = FIRST_TOKEN + ((t - FIRST_TOKEN + 1) % span as i32);
            }
            out[i] = t;
        }
        out
    }

    fn write_logits(row: &mut [f32], cands: &[i32; TOP_N]) {
        row.fill(-4.0);
        for (i, c) in cands.iter().enumerate() {
            row[*c as usize] = 6.0 - i as f32 * 0.75;
        }
    }

    fn write_kv(args: &StepArgs, layers: usize, rs: usize, k_new: &mut [f32], v_new: &mut [f32]) {
        let s = args.tokens.len();
        k_new.fill(0.0);
        v_new.fill(0.0);
        for l in 0..layers {
            for i in 0..s {
                let off = (l * s + i) * rs;
                k_new[off] = args.tokens[i] as f32;
                k_new[off + 1] = args.positions[i] as f32;
                v_new[off] = args.tokens[i] as f32;
                v_new[off + 1] = args.positions[i] as f32;
            }
        }
    }

    fn write_feats(&self, args: &StepArgs, out: &mut StepScratch) {
        let s = args.tokens.len();
        let f = self.contract.feat_dim;
        out.feats.fill(0.0);
        for i in 0..s {
            out.feats[i * f] = args.tokens[i] as f32;
            out.feats[i * f + 1] = args.positions[i] as f32;
        }
    }

    fn write_probe(&self, args: &StepArgs, heads: usize, out: &mut StepScratch) {
        if !args.probe {
            return;
        }
        let cap = self.contract.cache_cap;
        let s = args.tokens.len();
        let w = cap + s;
        for i in 0..s {
            let row = &args.mask[i * w..(i + 1) * w];
            let first = row.iter().position(|m| *m == 0.0).unwrap_or(0);
            let last = w - 1 - row.iter().rev().position(|m| *m == 0.0).unwrap_or(0);
            for h in 0..heads {
                // even heads look far back (the "topic" dependency that
                // Fig 7 surfaces), odd heads look local.
                out.attn_top1[i * heads + h] = if h % 2 == 0 { first as i32 } else { last as i32 };
            }
        }
    }

    fn step(&mut self, args: StepArgs, teacher: bool, out: &mut StepScratch) -> Result<()> {
        let s = args.tokens.len();
        let v = self.contract.vocab;
        let d = if teacher { self.contract.teacher } else { self.contract.draft };
        out.prepare(s, v, self.contract.feat_dim, d.layers, d.heads, d.d_head, args.probe);
        let stride = self.row_stride(&args);
        for i in 0..s {
            let ctx = self.context_hash(i, &args, stride);
            let cands = if teacher {
                Self::candidates(ctx)
            } else if splitmix64(ctx ^ 0xD15A_6EE2) % 100 < self.agree_pct {
                // Deterministic agreement coin per context: an agreeing
                // draft proposes the teacher's own candidate list; a
                // disagreeing one proposes an unrelated list (a *bad*
                // draft — merely swapping the top-2 would be rescued by
                // the tree's top-k children, which is exactly the point
                // of tree speculation).
                Self::candidates(ctx)
            } else {
                Self::candidates(splitmix64(ctx ^ 0xBAD_D4AF7))
            };
            Self::write_logits(out.logits_row_mut(i), &cands);
        }
        self.write_feats(&args, out);
        Self::write_kv(&args, d.layers, d.heads * d.d_head, &mut out.k_new, &mut out.v_new);
        self.write_probe(&args, d.heads, out);
        Ok(())
    }
}

impl ModelBackend for SimBackend {
    fn contract(&self) -> &Contract {
        &self.contract
    }

    fn teacher_step(&mut self, _mode: ExecMode, args: StepArgs, out: &mut StepScratch)
        -> Result<()> {
        self.teacher_calls += 1;
        self.step(args, true, out)
    }

    fn draft_step(&mut self, args: StepArgs, out: &mut StepScratch) -> Result<()> {
        self.draft_calls += 1;
        self.step(args, false, out)
    }

    fn name(&self) -> &'static str {
        "sim"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{argmax, KvView};
    use crate::config::contract::{CACHE_CAP, NEG_INF};

    fn empty_cache(c: &Contract) -> (Vec<f32>, Vec<f32>) {
        let n = c.teacher.cache_elems(c.cache_cap);
        (vec![0.0; n], vec![0.0; n])
    }

    fn chain_mask(s: usize, live: usize, t: usize) -> Vec<f32> {
        let w = CACHE_CAP + s;
        let mut m = vec![NEG_INF; s * w];
        for i in 0..live {
            for j in 0..t {
                m[i * w + j] = 0.0;
            }
            for j in 0..=i {
                m[i * w + CACHE_CAP + j] = 0.0;
            }
        }
        m
    }

    #[test]
    fn teacher_is_deterministic_and_context_sensitive() {
        let mut b = SimBackend::new(100);
        let (k, v) = empty_cache(b.contract());
        let mask = chain_mask(8, 3, 0);
        let pos = [0i32, 1, 2, 0, 0, 0, 0, 0];
        let run = |b: &mut SimBackend, mode: ExecMode, tokens: [i32; 8]| {
            let mut out = StepScratch::new();
            b.teacher_step(mode, StepArgs {
                tokens: &tokens, positions: &pos, mask: &mask,
                kv: KvView { k: &k, v: &v }, feats_in: None, probe: false,
            }, &mut out)
            .unwrap();
            out
        };
        let o1 = run(&mut b, ExecMode::Fused, [5, 6, 7, 0, 0, 0, 0, 0]);
        let o2 = run(&mut b, ExecMode::Eager, [5, 6, 7, 0, 0, 0, 0, 0]);
        assert_eq!(o1.logits, o2.logits, "mode must not change sim semantics");
        let o3 = run(&mut b, ExecMode::Fused, [5, 6, 9, 0, 0, 0, 0, 0]);
        assert_ne!(
            argmax(o1.logits_row(2)),
            argmax(o3.logits_row(2)),
            "changing a visible token must change the slot's distribution"
        );
    }

    #[test]
    fn masked_slots_do_not_influence_context() {
        let mut b = SimBackend::new(100);
        let (k, v) = empty_cache(b.contract());
        let mask = chain_mask(8, 2, 0);
        let pos = [0i32, 1, 0, 0, 0, 0, 0, 0];
        let run = |b: &mut SimBackend, t2: i32| {
            let tokens = [5, 6, t2, 0, 0, 0, 0, 0];
            let mut out = StepScratch::new();
            b.teacher_step(ExecMode::Fused, StepArgs {
                tokens: &tokens, positions: &pos, mask: &mask,
                kv: KvView { k: &k, v: &v }, feats_in: None, probe: false,
            }, &mut out)
            .unwrap();
            out.logits_row(1).to_vec()
        };
        assert_eq!(run(&mut b, 100), run(&mut b, 200), "masked slot token leaked");
    }

    #[test]
    fn draft_agreement_controls_top1_match() {
        let mut t = SimBackend::new(100);
        let mut d_always = SimBackend::new(100);
        let mut d_never = SimBackend::new(0);
        let (k, v) = empty_cache(t.contract());
        let mask = chain_mask(8, 4, 0);
        let tokens = [5i32, 9, 3, 7, 0, 0, 0, 0];
        let pos = [0i32, 1, 2, 3, 0, 0, 0, 0];
        let args = || StepArgs {
            tokens: &tokens, positions: &pos, mask: &mask,
            kv: KvView { k: &k, v: &v }, feats_in: None, probe: false,
        };
        let mut to = StepScratch::new();
        t.teacher_step(ExecMode::Fused, args(), &mut to).unwrap();
        let mut da = StepScratch::new();
        d_always.draft_step(args(), &mut da).unwrap();
        let mut dn = StepScratch::new();
        d_never.draft_step(args(), &mut dn).unwrap();
        for i in 0..4 {
            assert_eq!(
                argmax(to.logits_row(i)),
                argmax(da.logits_row(i)),
                "agree_pct=100 must match teacher"
            );
            assert_ne!(
                argmax(to.logits_row(i)),
                argmax(dn.logits_row(i)),
                "agree_pct=0 must differ"
            );
        }
    }

    #[test]
    fn kv_rows_encode_token_and_position() {
        let mut b = SimBackend::new(100);
        let (k, v) = empty_cache(b.contract());
        let mask = chain_mask(8, 2, 0);
        let tokens = [42i32, 43, 0, 0, 0, 0, 0, 0];
        let pos = [7i32, 8, 0, 0, 0, 0, 0, 0];
        let mut out = StepScratch::new();
        b.teacher_step(ExecMode::Fused, StepArgs {
            tokens: &tokens, positions: &pos, mask: &mask,
            kv: KvView { k: &k, v: &v }, feats_in: None, probe: false,
        }, &mut out)
        .unwrap();
        let rs = b.contract().teacher.heads * b.contract().teacher.d_head;
        assert_eq!(out.k_new[0], 42.0);
        assert_eq!(out.k_new[1], 7.0);
        assert_eq!(out.k_new[rs], 43.0);
        assert_eq!(out.k_new[rs + 1], 8.0);
    }

    #[test]
    fn probe_reports_far_and_near_columns() {
        let mut b = SimBackend::new(100);
        let (k, v) = empty_cache(b.contract());
        let mask = chain_mask(8, 2, 5); // prefix of 5 visible
        let tokens = [1i32, 2, 0, 0, 0, 0, 0, 0];
        let pos = [5i32, 6, 0, 0, 0, 0, 0, 0];
        let mut out = StepScratch::new();
        b.draft_step(StepArgs {
            tokens: &tokens, positions: &pos, mask: &mask,
            kv: KvView { k: &k, v: &v }, feats_in: None, probe: true,
        }, &mut out)
        .unwrap();
        let top1 = out.attn_top1().unwrap();
        assert_eq!(top1[0], 0, "even head looks at the far history (topic)");
        assert_eq!(top1[1], CACHE_CAP as i32, "odd head looks local");
    }

    #[test]
    fn repeated_calls_reuse_scratch_capacity() {
        let mut b = SimBackend::new(90);
        let (k, v) = empty_cache(b.contract());
        let mask = chain_mask(8, 3, 0);
        let tokens = [5i32, 6, 7, 0, 0, 0, 0, 0];
        let pos = [0i32, 1, 2, 0, 0, 0, 0, 0];
        let mut out = StepScratch::new();
        for _ in 0..3 {
            b.teacher_step(ExecMode::Fused, StepArgs {
                tokens: &tokens, positions: &pos, mask: &mask,
                kv: KvView { k: &k, v: &v }, feats_in: None, probe: false,
            }, &mut out)
            .unwrap();
        }
        assert_eq!(out.s(), 8);
        assert_eq!(out.logits.len(), 8 * VOCAB);
    }
}
