//! Launch planning: the *plan → bind → execute* half of the backend
//! contract.
//!
//! A caller never names a compiled module. It states what it needs —
//! role, mode, live rows, live requests, probe, cache layout — as a
//! [`PlanRequest`]; [`negotiate`] resolves the cheapest compiled variant
//! from the backend's [`Capabilities`] into a [`LaunchPlan`], or returns
//! a typed [`PlanError`] that the caller can act on:
//!
//! * [`PlanError::SplitRequired`] — no fused variant covers the whole
//!   group, but narrower ones exist: the
//!   [`crate::coordinator::FusedVerifier`] splits the group into
//!   `max_batch`-wide launches instead of failing;
//! * [`PlanError::NoVariant`] — nothing covers the request at any width;
//!   the error lists every variant the backend *does* have, so "no
//!   compiled S variant" failures are diagnosable without rerunning.
//!
//! "Cheapest" = fewest padded rows `b * s` (the accelerator computes
//! every padded row of a launch, so padded rows are the honest cost
//! proxy), ties broken toward the smaller `b` then smaller `s`.
//!
//! # KV sessions
//!
//! [`KvSession`] is the *bind* half: an opaque handle to a
//! backend-resident mirror of one conversation cache
//! ([`crate::backend::ModelBackend::bind_kv`]). Each step carries a
//! [`SessionTicket`] — the session id plus the cache's dirty watermark —
//! and the backend syncs only rows `[dirty_lo, rows)` before launching,
//! so steady-state per-step transfer no longer scales with the cache
//! capacity. See the session lifecycle in `docs/ARCHITECTURE.md` §10.

use crate::config::{Capabilities, ExecMode, ModuleKey, ModuleLayout, ModuleRole};
use std::fmt;

/// What a caller needs from one launch (the input of [`negotiate`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanRequest {
    /// Teacher or draft step.
    pub role: ModuleRole,
    /// Artifact flavor (the paper's two-mode protocol). Draft requests
    /// canonically use [`ExecMode::Fused`].
    pub mode: ExecMode,
    /// Padded slots the launch must hold per request (the caller's
    /// token-block size; the plan's `s` is the smallest covering
    /// variant).
    pub rows: usize,
    /// Live requests the launch must cover (1 for single-request steps).
    pub batch: usize,
    /// Whether the caller wants the attention-probe output. Negotiation
    /// falls back to the probe-less variant of the same shape when no
    /// probe variant is compiled (probe output is analysis-only).
    pub probe: bool,
    /// Physical layout of the caller's cache view. When no gather-aware
    /// module is compiled, negotiation falls back to a
    /// [`ModuleLayout::Flat`] module and sets
    /// [`LaunchPlan::host_gather`].
    pub layout: ModuleLayout,
}

impl PlanRequest {
    /// A single-request teacher step request.
    pub fn teacher(mode: ExecMode, rows: usize, layout: ModuleLayout) -> Self {
        Self { role: ModuleRole::Teacher, mode, rows, batch: 1, probe: false, layout }
    }

    /// A fused `batch`-request teacher verification request.
    pub fn teacher_batch(mode: ExecMode, rows: usize, batch: usize, layout: ModuleLayout) -> Self {
        Self { role: ModuleRole::Teacher, mode, rows, batch, probe: false, layout }
    }

    /// A draft step request.
    pub fn draft(rows: usize, probe: bool, layout: ModuleLayout) -> Self {
        Self { role: ModuleRole::Draft, mode: ExecMode::Fused, rows, batch: 1, probe, layout }
    }
}

impl fmt::Display for PlanRequest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}/{} rows={} batch={}{}{}",
            self.role.as_str(),
            self.mode.as_str(),
            self.rows,
            self.batch,
            if self.probe { " probe" } else { "" },
            if self.layout == ModuleLayout::Paged { " paged" } else { "" },
        )
    }
}

/// A resolved launch: which compiled variant to run and how the request
/// maps onto it (the output of [`negotiate`], consumed by
/// [`crate::backend::ModelBackend::execute`] /
/// [`crate::backend::ModelBackend::execute_batch`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LaunchPlan {
    /// The compiled variant to launch (`key.s >= rows`,
    /// `key.b >= batch`).
    pub key: ModuleKey,
    /// Live padded slots per request the caller asked for.
    pub rows: usize,
    /// Live requests the caller asked for; rows of requests
    /// `[batch, key.b)` are padding.
    pub batch: usize,
    /// The caller's cache is paged but the module consumes a flat cache:
    /// the backend must materialize (gather) the view host-side before
    /// upload.
    pub host_gather: bool,
}

impl LaunchPlan {
    /// Total padded rows the launch computes (`key.b * key.s`).
    pub fn padded_rows(&self) -> usize {
        self.key.b * self.key.s
    }
}

/// Typed launch-planning / session errors — the replacement for the old
/// string-keyed `bail!("… is not a compiled S variant")` paths.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlanError {
    /// No compiled variant covers the request at any fused width. The
    /// message lists every variant the backend has.
    NoVariant {
        /// The request that failed to resolve.
        req: PlanRequest,
        /// Compact summary of the compiled variants
        /// ([`Capabilities::describe`]).
        available: String,
    },
    /// No fused variant covers the whole group, but variants up to
    /// `max_batch` wide do: the caller should split the group into
    /// `max_batch`-sized launches.
    SplitRequired {
        /// Requested group width.
        batch: usize,
        /// Largest covering width the backend has compiled.
        max_batch: usize,
    },
    /// The backend keeps no device-resident KV sessions (e.g. the
    /// artifact set has no `kv_append` scatter-update module). Callers
    /// fall back to full-view upload per step.
    SessionUnsupported {
        /// Backend name, for the error message.
        backend: &'static str,
    },
    /// A [`SessionTicket`] referenced a session this backend does not
    /// hold (stale handle or cross-backend mixup).
    UnknownSession {
        /// The unresolved session id.
        id: u64,
    },
    /// A session operation was issued for the wrong role's session
    /// (teacher ticket against a draft mirror or vice versa).
    RoleMismatch {
        /// The session's bound role.
        bound: ModuleRole,
        /// The role of the step that presented the ticket.
        requested: ModuleRole,
    },
    /// Session initialization failed backend-side (device allocation or
    /// upload error) — a hard error, not a capability gap.
    SessionInit {
        /// Backend-reported failure detail.
        reason: String,
    },
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::NoVariant { req, available } => {
                write!(f, "no compiled variant covers [{req}]; available: {available}")
            }
            PlanError::SplitRequired { batch, max_batch } => write!(
                f,
                "no fused variant covers {batch} requests; split the group \
                 (widest compiled variant: {max_batch})"
            ),
            PlanError::SessionUnsupported { backend } => {
                write!(f, "backend '{backend}' does not support device-resident KV sessions")
            }
            PlanError::UnknownSession { id } => write!(f, "unknown KV session {id}"),
            PlanError::RoleMismatch { bound, requested } => write!(
                f,
                "KV session bound for role {} used by a {} step",
                bound.as_str(),
                requested.as_str()
            ),
            PlanError::SessionInit { reason } => {
                write!(f, "KV session initialization failed: {reason}")
            }
        }
    }
}

impl std::error::Error for PlanError {}

/// Resolve the cheapest compiled variant covering `req` (see the module
/// docs for the cost model and fallback rules).
pub fn negotiate(caps: &Capabilities, req: &PlanRequest) -> Result<LaunchPlan, PlanError> {
    // Draft requests are canonically Fused-mode (draft modules have one
    // flavor); normalize so callers can pass either.
    let mode = if req.role == ModuleRole::Draft { ExecMode::Fused } else { req.mode };
    // Layout preference: exact match first, flat fallback with a
    // host-side gather second.
    let layouts: &[ModuleLayout] = if req.layout == ModuleLayout::Paged {
        &[ModuleLayout::Paged, ModuleLayout::Flat]
    } else {
        &[ModuleLayout::Flat]
    };
    for &layout in layouts {
        let best = caps
            .keys()
            .filter(|k| {
                k.role == req.role
                    && k.mode == mode
                    && k.layout == layout
                    && !k.probe
                    && k.s >= req.rows
                    && k.b >= req.batch
            })
            .min_by_key(|k| (k.b * k.s, k.b, k.s));
        if let Some(&key) = best {
            // Upgrade to the probe variant of the *same* shape when
            // requested and compiled (never a different shape: probe is
            // analysis-only and must not change padding).
            let key = if req.probe && caps.contains(&ModuleKey { probe: true, ..key }) {
                ModuleKey { probe: true, ..key }
            } else {
                key
            };
            return Ok(LaunchPlan {
                key,
                rows: req.rows,
                batch: req.batch,
                host_gather: req.layout == ModuleLayout::Paged && layout == ModuleLayout::Flat,
            });
        }
    }
    // No layout covers the full width — can narrower variants cover the
    // rows? (Checked only after every layout failed, so a flat full-width
    // plan always wins over a paged split.)
    let max_b = layouts
        .iter()
        .map(|&l| caps.max_batch(req.role, mode, l, req.rows))
        .max()
        .unwrap_or(0);
    if max_b >= 1 && req.batch > max_b {
        return Err(PlanError::SplitRequired { batch: req.batch, max_batch: max_b });
    }
    Err(PlanError::NoVariant { req: *req, available: caps.describe() })
}

/// Opaque handle to a backend-resident KV session (a device/mirror copy
/// of one conversation cache, bound via
/// [`crate::backend::ModelBackend::bind_kv`]). The engine owns the
/// handle; steps reference it through [`SessionTicket`]s.
#[derive(Debug)]
pub struct KvSession {
    /// Backend-assigned session id.
    pub id: u64,
    /// The role whose cache this session mirrors.
    pub role: ModuleRole,
}

/// Per-step session sync descriptor: which session a step's cache view is
/// bound to, and which rows the backend must (re-)sync before launching.
/// Built by the engine from the cache's dirty watermark
/// ([`crate::cache::KvStore::dirty_lo`]).
#[derive(Clone, Copy, Debug)]
pub struct SessionTicket {
    /// The bound session's id.
    pub id: u64,
    /// First readable row whose contents changed since the backend last
    /// synced this session (`>= rows` when nothing changed).
    pub dirty_lo: usize,
    /// Rows readable through the step's cache view (committed +
    /// open-branch rows); the mirror truncates/extends to this length.
    pub rows: usize,
}

impl SessionTicket {
    /// The half-open row range the backend must sync.
    pub fn sync_range(&self) -> std::ops::Range<usize> {
        self.dirty_lo.min(self.rows)..self.rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Contract;

    fn caps() -> Capabilities {
        Capabilities::synthetic(&Contract::default(), 4)
    }

    #[test]
    fn negotiate_picks_smallest_covering_variant() {
        let c = caps();
        let p = negotiate(&c, &PlanRequest::teacher(ExecMode::Fused, 9, ModuleLayout::Flat))
            .unwrap();
        assert_eq!(p.key.s, 16);
        assert_eq!(p.key.b, 1);
        assert!(!p.host_gather);
        let p = negotiate(
            &c,
            &PlanRequest::teacher_batch(ExecMode::Fused, 8, 3, ModuleLayout::Flat),
        )
        .unwrap();
        assert_eq!((p.key.b, p.key.s), (3, 8));
        assert_eq!(p.padded_rows(), 24);
    }

    #[test]
    fn negotiate_reports_no_variant_with_listing() {
        let c = caps();
        let err = negotiate(&c, &PlanRequest::teacher(ExecMode::Fused, 300, ModuleLayout::Flat))
            .unwrap_err();
        match &err {
            PlanError::NoVariant { available, .. } => {
                assert!(available.contains("teacher/fused"), "{available}")
            }
            other => panic!("expected NoVariant, got {other:?}"),
        }
        assert!(format!("{err}").contains("rows=300"), "{err}");
    }

    #[test]
    fn negotiate_requests_split_when_width_exceeds_variants() {
        let c = caps(); // widths 1..=4
        let err = negotiate(
            &c,
            &PlanRequest::teacher_batch(ExecMode::Fused, 8, 6, ModuleLayout::Flat),
        )
        .unwrap_err();
        assert_eq!(err, PlanError::SplitRequired { batch: 6, max_batch: 4 });
    }

    #[test]
    fn negotiate_probe_upgrades_same_shape_only() {
        let c = caps();
        let p = negotiate(&c, &PlanRequest::draft(9, true, ModuleLayout::Flat)).unwrap();
        assert_eq!(p.key.s, 32);
        assert!(p.key.probe, "synthetic caps have probe at every draft S");
        // a table without probe variants falls back silently
        let bare = Capabilities::from_keys(vec![ModuleKey::draft(32, false)]);
        let p = negotiate(&bare, &PlanRequest::draft(9, true, ModuleLayout::Flat)).unwrap();
        assert!(!p.key.probe);
    }

    #[test]
    fn negotiate_paged_falls_back_to_flat_with_host_gather() {
        let c = caps(); // flat-only table
        let p = negotiate(&c, &PlanRequest::teacher(ExecMode::Fused, 8, ModuleLayout::Paged))
            .unwrap();
        assert_eq!(p.key.layout, ModuleLayout::Flat);
        assert!(p.host_gather);
        // a compiled gather-aware variant wins exactly
        let mut keys: Vec<ModuleKey> = c.keys().copied().collect();
        keys.push(ModuleKey {
            layout: ModuleLayout::Paged,
            ..ModuleKey::teacher(ExecMode::Fused, 8)
        });
        let c2 = Capabilities::from_keys(keys);
        let p = negotiate(&c2, &PlanRequest::teacher(ExecMode::Fused, 8, ModuleLayout::Paged))
            .unwrap();
        assert_eq!(p.key.layout, ModuleLayout::Paged);
        assert!(!p.host_gather);
    }

    #[test]
    fn ticket_sync_range_clamps() {
        let t = SessionTicket { id: 1, dirty_lo: usize::MAX, rows: 10 };
        assert!(t.sync_range().is_empty());
        let t = SessionTicket { id: 1, dirty_lo: 4, rows: 10 };
        assert_eq!(t.sync_range(), 4..10);
        let t = SessionTicket { id: 1, dirty_lo: 12, rows: 10 };
        assert!(t.sync_range().is_empty());
    }
}
