//! The serving coordinator (paper §4.4): continuous cross-request
//! batched verification ([`ContinuousScheduler`]), consistent-hash
//! prompt sharding, per-rank trace files, rank-0 merge — and the
//! multi-worker serving split: a routing [`Coordinator`] front end
//! ([`front`]) driving N per-thread engine workers ([`worker`]) over
//! typed channel RPC ([`crate::rpc`]).

pub mod batch;
pub mod front;
pub mod load;
pub mod runner;
pub mod worker;

pub use batch::{
    decode_speculative_batch, Completion, ContinuousScheduler, Disposition, FusedVerifier,
    InFlightLaunch, SchedulerStats, ShedNotice, SloAction, SloPolicy, SlotRequest, StageOutcome,
    StagedLaunch,
};
pub use front::{
    followup_prompt, ConversationOutcome, Coordinator, FrontConfig, HashRing, ShutdownReport,
    TraceOutcome,
};
pub use load::{run_load, LoadReport, LoadSpec};
pub use runner::{run_workload, AdmissionPolicy, BackendSpec, CoordinatorConfig};
pub use worker::{run_worker, EngineWorker, WorkerConfig};
