//! The serving coordinator (paper §4.4): deterministic prompt sharding
//! across worker threads, continuous cross-request batched verification
//! ([`ContinuousScheduler`]), per-rank trace files, rank-0 merge.

pub mod batch;
pub mod load;
pub mod runner;

pub use batch::{
    decode_speculative_batch, Completion, ContinuousScheduler, Disposition, FusedVerifier,
    InFlightLaunch, SchedulerStats, ShedNotice, SloAction, SloPolicy, SlotRequest, StageOutcome,
    StagedLaunch,
};
pub use load::{run_load, LoadReport, LoadSpec};
pub use runner::{run_workload, AdmissionPolicy, BackendSpec, CoordinatorConfig};
