//! Multi-worker workload runner.
//!
//! Mirrors the paper's distributed evaluation protocol (§4.4):
//! * conversations are sharded deterministically by
//!   `conversation_id % world_size` (the paper's `prompt_id mod
//!   world_size` on 8 NPUs — here: worker threads, each owning its own
//!   PJRT client/executables, since PJRT handles are not Send);
//! * each rank writes an independent `trace_rank{r}.jsonl`;
//! * rank 0 merges them into a globally sorted `trace_merged.jsonl`.
//!
//! Each conversation is decoded under the requested kinds ("baseline",
//! "ea") on **one warmed engine per worker**, `Engine::reset` between
//! (conversation, kind) pairs: constructing a fresh engine per
//! conversation re-allocated both multi-MB KV cache buffers, every
//! scratch arena and the incremental mask slots, which dominated
//! short-turn serving cost. Reset restores bit-identical fresh-engine
//! behaviour (asserted by the engine's reuse-equivalence test), so the
//! records are unchanged. Two-turn conversations keep cache state across
//! turns and materialize follow-up prompts from the live context
//! (MT-Bench protocol). Abnormal turns produce a failure dump and the run
//! continues (§4.3).

use crate::backend::{sim::SimBackend, ModelBackend};
use crate::config::RunConfig;
use crate::engine::Engine;
use crate::json::Json;
use crate::runtime::PjrtBackend;
use crate::trace::{merge_rank_files, FailureDump, TraceWriter, TurnRecord};
use crate::workload::{ConversationSpec, WorkloadSpec};
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

/// How each worker constructs its backend (built *inside* the worker
/// thread — PJRT handles are !Send).
#[derive(Clone, Debug)]
pub enum BackendSpec {
    /// Deterministic simulator (tests, CI, harness dry runs).
    Sim { agree_pct: u64 },
    /// Real AOT artifacts through PJRT.
    Pjrt { artifact_dir: PathBuf },
}

impl BackendSpec {
    fn build(&self) -> Result<Box<dyn ModelBackend>> {
        Ok(match self {
            BackendSpec::Sim { agree_pct } => Box::new(SimBackend::new(*agree_pct)),
            BackendSpec::Pjrt { artifact_dir } => Box::new(PjrtBackend::load(artifact_dir)?),
        })
    }

    pub fn describe(&self) -> String {
        match self {
            BackendSpec::Sim { agree_pct } => format!("sim(agree={agree_pct})"),
            BackendSpec::Pjrt { artifact_dir } => format!("pjrt({})", artifact_dir.display()),
        }
    }
}

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub world_size: usize,
    pub run: RunConfig,
    pub workload: WorkloadSpec,
    pub backend: BackendSpec,
    pub trace_dir: PathBuf,
    pub run_baseline: bool,
    pub run_ea: bool,
    /// Print progress lines to stderr.
    pub verbose: bool,
}

impl CoordinatorConfig {
    pub fn manifest(&self) -> Json {
        let mut o = Json::obj();
        o.push("world_size", self.world_size)
            .push("backend", self.backend.describe())
            .push("run", self.run.to_json())
            .push("turns", self.workload.total_turns())
            .push("run_baseline", self.run_baseline)
            .push("run_ea", self.run_ea)
            .push("workload_seed", self.workload.seed);
        o
    }
}

/// Run the workload across `world_size` workers; returns the merged,
/// globally sorted records.
pub fn run_workload(cfg: &CoordinatorConfig) -> Result<Vec<TurnRecord>> {
    anyhow::ensure!(cfg.world_size >= 1, "world_size must be >= 1");
    std::fs::create_dir_all(&cfg.trace_dir)?;
    crate::trace::writer::write_manifest(&cfg.trace_dir, cfg.manifest())?;
    let conversations = cfg.workload.conversations();
    let done = AtomicUsize::new(0);
    let total = conversations.len();

    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for rank in 0..cfg.world_size {
            let convs: Vec<ConversationSpec> = conversations
                .iter()
                .filter(|c| c.id % cfg.world_size == rank)
                .cloned()
                .collect();
            let cfg_ref = &*cfg;
            let done_ref = &done;
            handles.push(scope.spawn(move || -> Result<()> {
                worker(rank, cfg_ref, convs, done_ref, total)
            }));
        }
        for h in handles {
            h.join().expect("worker panicked")?;
        }
        Ok(())
    })?;

    merge_rank_files(&cfg.trace_dir)
}

fn worker(
    rank: usize,
    cfg: &CoordinatorConfig,
    convs: Vec<ConversationSpec>,
    done: &AtomicUsize,
    total: usize,
) -> Result<()> {
    let mut backend = cfg.backend.build().with_context(|| format!("rank {rank} backend"))?;
    // One engine per worker, reused across every (conversation, kind):
    // warmup absorbs lazy PJRT module compilation AND brings every
    // reusable buffer (KV caches, scratch arenas, mask slots) to its
    // high-water capacity before any timed turn.
    let mut engine = Engine::new(&mut *backend, cfg.run.clone());
    engine.warmup()?;
    let mut writer = TraceWriter::create(&cfg.trace_dir, rank)?;
    let kinds: Vec<&str> = [("baseline", cfg.run_baseline), ("ea", cfg.run_ea)]
        .iter()
        .filter(|(_, on)| *on)
        .map(|(k, _)| *k)
        .collect();
    for conv in convs {
        for kind in &kinds {
            engine.reset();
            if let Err(e) = run_conversation(&mut engine, cfg, &conv, kind, rank, &mut writer) {
                let dump = FailureDump {
                    conversation_id: conv.id,
                    turn_idx: 0,
                    rank,
                    error: format!("{e:#}"),
                    prompt: conv.first_prompt(),
                    context_len: 0,
                    config: cfg.run.to_json(),
                };
                let path = writer.failure(&dump)?;
                eprintln!("[rank {rank}] conversation {} ({kind}) failed: {e:#} (dump: {})",
                          conv.id, path.display());
            }
        }
        let n = done.fetch_add(1, Ordering::Relaxed) + 1;
        if cfg.verbose && (n % 10 == 0 || n == total) {
            eprintln!("[coordinator] {n}/{total} conversations done");
        }
    }
    writer.flush()?;
    Ok(())
}

fn run_conversation(
    engine: &mut Engine,
    cfg: &CoordinatorConfig,
    conv: &ConversationSpec,
    kind: &str,
    rank: usize,
    writer: &mut TraceWriter,
) -> Result<()> {
    // committed text so far (prompts + generations) for follow-up prompts
    let mut ctx: Vec<i32> = Vec::new();
    for turn in 0..conv.turns() {
        let prompt = if turn == 0 {
            conv.first_prompt()
        } else {
            let a = ctx[ctx.len() - 2];
            let b = ctx[ctx.len() - 1];
            conv.followup_prompt(turn, a, b)
        };
        let out = if kind == "baseline" {
            engine.generate_baseline(&prompt, cfg.run.max_new_tokens)?
        } else {
            engine.generate_speculative(&prompt, cfg.run.max_new_tokens)?
        };
        ctx.extend(&prompt);
        ctx.extend(&out.tokens);
        let rec = TurnRecord::from_gen(conv.id, turn, rank, conv.profile.as_str(), kind, &out);
        writer.write(&rec)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{pair_turns, ThroughputReport};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("eagle_coord_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn base_cfg(tag: &str) -> CoordinatorConfig {
        let mut run = RunConfig::default();
        run.max_new_tokens = 12;
        CoordinatorConfig {
            world_size: 2,
            run,
            workload: WorkloadSpec::smoke(),
            backend: BackendSpec::Sim { agree_pct: 90 },
            trace_dir: tmpdir(tag),
            run_baseline: true,
            run_ea: true,
            verbose: false,
        }
    }

    #[test]
    fn smoke_workload_produces_paired_records() {
        let cfg = base_cfg("smoke");
        let records = run_workload(&cfg).unwrap();
        // 3 code (1 turn) + 3 chat (2 turns) = 9 turns x 2 kinds
        assert_eq!(records.len(), 18);
        let pairs = pair_turns(&records);
        assert_eq!(pairs.len(), 9);
        let rep = ThroughputReport::from_pairs(&pairs);
        assert_eq!(rep.turns, 9);
        // the sim is fast in both modes; just sanity-check shapes
        assert!(rep.accept_l.n > 0);
        let _ = std::fs::remove_dir_all(&cfg.trace_dir);
    }

    #[test]
    fn sharding_is_deterministic_and_disjoint() {
        let mut cfg = base_cfg("shard1");
        let r1 = run_workload(&cfg).unwrap();
        cfg.trace_dir = tmpdir("shard2");
        cfg.world_size = 3;
        let r3 = run_workload(&cfg).unwrap();
        // same records regardless of world size (rank differs, data equal)
        assert_eq!(r1.len(), r3.len());
        for (a, b) in r1.iter().zip(&r3) {
            assert_eq!(a.conversation_id, b.conversation_id);
            assert_eq!(a.turn_idx, b.turn_idx);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.output_len, b.output_len);
            assert_eq!(a.accept_lens, b.accept_lens);
        }
        let _ = std::fs::remove_dir_all(&cfg.trace_dir);
    }

    #[test]
    fn manifest_written_with_config() {
        let cfg = base_cfg("manifest");
        run_workload(&cfg).unwrap();
        let text =
            std::fs::read_to_string(cfg.trace_dir.join("run_manifest.json")).unwrap();
        let j = crate::json::parse(&text).unwrap();
        assert_eq!(j.get("world_size").unwrap().as_usize(), Some(2));
        assert!(j.at("run.tree_budget").is_some());
        let _ = std::fs::remove_dir_all(&cfg.trace_dir);
    }
}
