//! Multi-worker workload runner.
//!
//! Mirrors the paper's distributed evaluation protocol (§4.4):
//! * conversations are sharded deterministically by **consistent hash**
//!   of the conversation id (the same [`crate::coordinator::HashRing`]
//!   the channel-RPC front end routes with, so both serving modes agree
//!   on every conversation's home rank — the paper shards `prompt_id`
//!   across 8 NPUs; here ranks are worker threads, each owning its own
//!   PJRT client/executables, since PJRT handles are not Send);
//! * each rank writes an independent `trace_rank{r}.jsonl`;
//! * rank 0 merges them into a globally sorted `trace_merged.jsonl`.
//!
//! Each conversation is decoded under the requested kinds ("baseline",
//! "ea") on **warmed, reused engines**: constructing a fresh engine per
//! conversation re-allocated both multi-MB KV cache buffers, every
//! scratch arena and the incremental mask slots, which dominated
//! short-turn serving cost. `Engine::reset` between conversations
//! restores bit-identical fresh-engine behaviour (asserted by the
//! engine's reuse-equivalence test), so the records are unchanged.
//!
//! With `max_batch > 1` a worker holds that many conversations resident
//! (one engine slot each) and the EA kind decodes them **concurrently**
//! through the [`ContinuousScheduler`]: each tick fuses the live group's
//! tree verifications into one padded teacher launch, retired
//! conversations free their slot, and the next queued conversation is
//! admitted at the same tick — so ragged traffic (one-token stragglers
//! next to long turns) keeps launches at full width instead of draining
//! the group (the batching contract + slot lifecycle in
//! `docs/ARCHITECTURE.md`). `CoordinatorConfig::scheduling` selects
//! [`AdmissionPolicy::Continuous`] (default) or
//! [`AdmissionPolicy::Chunked`] fixed admission groups for A/B
//! comparison.
//! Token-level records are bit-identical to the sequential path either
//! way — only wall-clock changes (asserted by a test below) — so
//! `max_batch`/`scheduling` are purely throughput knobs. Memory cost:
//! one teacher + draft KV cache pair per slot.
//!
//! Two-turn conversations keep cache state across turns: a retiring turn
//! *continues* on its slot (engine context preserved) instead of
//! releasing it, and materializes its follow-up prompt from the live
//! context (MT-Bench protocol). Abnormal turns produce a failure dump
//! and the run continues (§4.3); a scheduler-level error dumps every
//! conversation still in flight, each dump naming the error.

use crate::backend::{sim::SimBackend, ModelBackend};
use crate::cache::CachePools;
use crate::config::RunConfig;
use crate::coordinator::batch::{Completion, ContinuousScheduler, Disposition, SlotRequest};
use crate::engine::Engine;
use crate::json::Json;
use crate::runtime::PjrtBackend;
use crate::trace::{merge_rank_files, FailureDump, TraceWriter, TurnRecord};
use crate::workload::{ConversationSpec, WorkloadSpec};
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

/// How each worker constructs its backend (built *inside* the worker
/// thread — PJRT handles are !Send).
#[derive(Clone, Debug)]
pub enum BackendSpec {
    /// Deterministic simulator (tests, CI, harness dry runs).
    Sim {
        /// Draft/teacher top-1 agreement percentage.
        agree_pct: u64,
    },
    /// Real AOT artifacts through PJRT.
    Pjrt {
        /// Directory holding `manifest.json` + `*.hlo.txt` artifacts.
        artifact_dir: PathBuf,
    },
}

impl BackendSpec {
    fn build(&self) -> Result<Box<dyn ModelBackend>> {
        Ok(match self {
            BackendSpec::Sim { agree_pct } => Box::new(SimBackend::new(*agree_pct)),
            BackendSpec::Pjrt { artifact_dir } => Box::new(PjrtBackend::load(artifact_dir)?),
        })
    }

    /// Human-readable description for manifests and logs.
    pub fn describe(&self) -> String {
        match self {
            BackendSpec::Sim { agree_pct } => format!("sim(agree={agree_pct})"),
            BackendSpec::Pjrt { artifact_dir } => format!("pjrt({})", artifact_dir.display()),
        }
    }
}

/// How a worker forms EA verification groups when `max_batch > 1`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Slot-based continuous batching (the default): a retired
    /// conversation frees its slot and the next queued conversation is
    /// admitted at the same tick, so fused launches stay at full width
    /// under ragged traffic.
    Continuous,
    /// Fixed admission groups (the A/B reference the bench measures
    /// against): conversations are admitted in chunks of `max_batch`
    /// and the next chunk starts only after the whole chunk retires —
    /// a straggler-heavy chunk drains to narrow launches. Note this
    /// reproduces PR-2's *admission* barrier, not its per-turn barrier:
    /// within a chunk a finished turn continues into its next turn
    /// immediately instead of waiting for slot-mates' current turns
    /// (tokens are identical either way; only launch grouping differs).
    Chunked,
}

impl AdmissionPolicy {
    /// Parse a `--scheduling` flag value (`continuous` | `chunked`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "continuous" => Ok(Self::Continuous),
            "chunked" => Ok(Self::Chunked),
            other => anyhow::bail!("unknown scheduling policy '{other}' (continuous|chunked)"),
        }
    }

    /// Stable name for manifests and logs.
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Continuous => "continuous",
            Self::Chunked => "chunked",
        }
    }
}

/// Everything a coordinator run needs to know.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker thread count (the paper's world size).
    pub world_size: usize,
    /// Per-engine decode configuration.
    pub run: RunConfig,
    /// The conversation workload to decode.
    pub workload: WorkloadSpec,
    /// Backend each worker builds.
    pub backend: BackendSpec,
    /// Directory receiving trace files + run manifest.
    pub trace_dir: PathBuf,
    /// Decode every conversation with teacher-only greedy ("baseline").
    pub run_baseline: bool,
    /// Decode every conversation with tree speculation ("ea").
    pub run_ea: bool,
    /// Engine slots resident per worker (the fused launch width); must be
    /// `>= 1` — `run_workload` rejects 0 with a config-contract error
    /// instead of silently degenerating to sequential serving.
    pub max_batch: usize,
    /// Group-formation policy for the EA kind when `max_batch > 1`.
    pub scheduling: AdmissionPolicy,
    /// Print progress lines to stderr.
    pub verbose: bool,
}

impl CoordinatorConfig {
    /// The run-manifest fragment written next to the traces.
    pub fn manifest(&self) -> Json {
        let mut o = Json::obj();
        o.push("world_size", self.world_size)
            .push("backend", self.backend.describe())
            .push("run", self.run.to_json())
            .push("turns", self.workload.total_turns())
            .push("run_baseline", self.run_baseline)
            .push("run_ea", self.run_ea)
            .push("max_batch", self.max_batch)
            .push("scheduling", self.scheduling.as_str())
            .push("workload_seed", self.workload.seed);
        o
    }
}

/// Run the workload across `world_size` workers; returns the merged,
/// globally sorted records.
pub fn run_workload(cfg: &CoordinatorConfig) -> Result<Vec<TurnRecord>> {
    anyhow::ensure!(cfg.world_size >= 1, "world_size must be >= 1");
    anyhow::ensure!(
        cfg.max_batch >= 1,
        "config contract: max_batch must be >= 1 (got {}) — pass --batch 1 for sequential serving",
        cfg.max_batch
    );
    std::fs::create_dir_all(&cfg.trace_dir)?;
    crate::trace::writer::write_manifest(&cfg.trace_dir, cfg.manifest())?;
    let conversations = cfg.workload.conversations();
    let done = AtomicUsize::new(0);
    let total = conversations.len();

    // Same consistent-hash ring as the channel-RPC front end
    // (`coordinator::front`): a conversation's home rank is a stable
    // function of its id alone, for any world size.
    let ring = crate::coordinator::front::HashRing::new(cfg.world_size);

    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for rank in 0..cfg.world_size {
            let convs: Vec<ConversationSpec> = conversations
                .iter()
                .filter(|c| ring.route(c.id as u64) == rank)
                .cloned()
                .collect();
            let cfg_ref = &*cfg;
            let done_ref = &done;
            handles.push(scope.spawn(move || -> Result<()> {
                worker(rank, cfg_ref, convs, done_ref, total)
            }));
        }
        for h in handles {
            h.join().map_err(|_| anyhow::anyhow!("worker thread panicked"))??;
        }
        Ok(())
    })?;

    merge_rank_files(&cfg.trace_dir)
}

fn worker(
    rank: usize,
    cfg: &CoordinatorConfig,
    convs: Vec<ConversationSpec>,
    done: &AtomicUsize,
    total: usize,
) -> Result<()> {
    let mut backend = cfg.backend.build().with_context(|| format!("rank {rank} backend"))?;
    // One engine per resident-conversation slot, reused across every
    // (conversation, kind): warmup absorbs lazy PJRT module compilation
    // AND brings every reusable buffer (KV caches, scratch arenas, mask
    // slots) to its high-water capacity before any timed turn. All slots
    // share one per-worker pool pair, so under the paged layout the
    // worker's KV memory is one arena sized by actual residency, not
    // `slots * cap` pinned buffers.
    let slots = cfg.max_batch;
    let pools = CachePools::new(backend.contract());
    let mut engines: Vec<Engine> =
        (0..slots).map(|_| Engine::with_pools(&*backend, cfg.run.clone(), &pools)).collect();
    for e in engines.iter_mut() {
        e.warmup(&mut *backend)?;
    }
    let mut sched = ContinuousScheduler::new(slots, backend.contract().cache_cap);
    sched.set_pipelining(cfg.run.pipelining);
    let mut writer = TraceWriter::create(&cfg.trace_dir, rank)?;
    let progress = || {
        let n = done.fetch_add(1, Ordering::Relaxed) + 1;
        if cfg.verbose && (n % 10 == 0 || n == total) {
            eprintln!("[coordinator] {n}/{total} conversations done");
        }
    };
    if cfg.run_baseline {
        for conv in &convs {
            engines[0].reset();
            if let Err(e) = run_conversation(
                &mut *backend, &mut engines[0], cfg, conv, "baseline", rank, &mut writer)
            {
                dump_failure(&writer, conv, "baseline", rank, cfg, &e);
            }
            if !cfg.run_ea {
                progress();
            }
        }
    }
    if cfg.run_ea {
        let mut progress = progress;
        if slots <= 1 {
            for conv in &convs {
                engines[0].reset();
                if let Err(e) = run_conversation(
                    &mut *backend, &mut engines[0], cfg, conv, "ea", rank, &mut writer)
                {
                    dump_failure(&writer, conv, "ea", rank, cfg, &e);
                }
                progress();
            }
        } else {
            match cfg.scheduling {
                AdmissionPolicy::Continuous => {
                    // every conversation of this rank enters one admission
                    // queue; slots refill as conversations retire
                    run_group_ea(
                        &mut *backend, &mut engines, &mut sched, cfg, &convs, rank,
                        &mut writer, &mut progress,
                    );
                }
                AdmissionPolicy::Chunked => {
                    for chunk in convs.chunks(slots) {
                        run_group_ea(
                            &mut *backend, &mut engines, &mut sched, cfg, chunk, rank,
                            &mut writer, &mut progress,
                        );
                    }
                }
            }
        }
    } else if !cfg.run_baseline {
        for _ in &convs {
            progress();
        }
    }
    writer.flush()?;
    Ok(())
}

fn dump_failure(
    writer: &TraceWriter,
    conv: &ConversationSpec,
    kind: &str,
    rank: usize,
    cfg: &CoordinatorConfig,
    err: &anyhow::Error,
) {
    let dump = FailureDump {
        conversation_id: conv.id,
        turn_idx: 0,
        rank,
        error: format!("{err:#}"),
        prompt: conv.first_prompt(),
        context_len: 0,
        config: cfg.run.to_json(),
    };
    match writer.failure(&dump) {
        Ok(path) => eprintln!(
            "[rank {rank}] conversation {} ({kind}) failed: {err:#} (dump: {})",
            conv.id,
            path.display()
        ),
        Err(we) => eprintln!(
            "[rank {rank}] conversation {} ({kind}) failed: {err:#} (dump write failed: {we:#})",
            conv.id
        ),
    }
}

/// Decode one conversation (all turns) with one kind on one engine —
/// the sequential path.
fn run_conversation(
    backend: &mut dyn ModelBackend,
    engine: &mut Engine,
    cfg: &CoordinatorConfig,
    conv: &ConversationSpec,
    kind: &str,
    rank: usize,
    writer: &mut TraceWriter,
) -> Result<()> {
    // committed text so far (prompts + generations) for follow-up prompts
    let mut ctx: Vec<i32> = Vec::new();
    for turn in 0..conv.turns() {
        let prompt = if turn == 0 {
            conv.first_prompt()
        } else {
            let a = ctx[ctx.len() - 2];
            let b = ctx[ctx.len() - 1];
            conv.followup_prompt(turn, a, b)
        };
        let out = if kind == "baseline" {
            engine.generate_baseline(backend, &prompt, cfg.run.max_new_tokens)?
        } else {
            engine.generate_speculative(backend, &prompt, cfg.run.max_new_tokens)?
        };
        ctx.extend(&prompt);
        ctx.extend(&out.tokens);
        let rec = TurnRecord::from_gen(conv.id, turn, rank, conv.profile.as_str(), kind, &out);
        writer.write(&rec)?;
    }
    Ok(())
}

/// Decode a set of conversations concurrently under the EA kind through
/// the continuous scheduler: all members enter the admission queue, a
/// retired conversation frees its slot for the next queued one at the
/// same tick, and multi-turn conversations *continue* on their slot
/// (engine context preserved) until their last turn retires. Token-level
/// records are bit-identical to the sequential path.
///
/// Failure protocol (§4.3): a record-write failure dumps that
/// conversation and releases its slot; a scheduler-drive error dumps
/// every conversation that had not completed, and the worker continues
/// with whatever comes next.
#[allow(clippy::too_many_arguments)]
fn run_group_ea(
    backend: &mut dyn ModelBackend,
    engines: &mut [Engine],
    sched: &mut ContinuousScheduler,
    cfg: &CoordinatorConfig,
    convs: &[ConversationSpec],
    rank: usize,
    writer: &mut TraceWriter,
    progress: &mut dyn FnMut(),
) {
    let n = convs.len();
    let mut ctxs: Vec<Vec<i32>> = vec![Vec::new(); n];
    let mut turn_of: Vec<usize> = vec![0; n];
    let mut completed: Vec<bool> = vec![false; n];
    for (i, conv) in convs.iter().enumerate() {
        let p = conv.first_prompt();
        ctxs[i].extend(&p);
        sched.submit(SlotRequest {
            id: i as u64,
            prompt: p,
            max_new: cfg.run.max_new_tokens,
            cfg: None,
            slo: None,
        });
    }
    let res = sched.run_to_idle(backend, engines, &mut |comp: Completion| {
        let i = comp.id as usize;
        ctxs[i].extend(&comp.out.tokens);
        let turn = turn_of[i];
        let rec = TurnRecord::from_gen(
            convs[i].id, turn, rank, convs[i].profile.as_str(), "ea", &comp.out);
        if let Err(e) = writer.write(&rec) {
            completed[i] = true;
            dump_failure(writer, &convs[i], "ea", rank, cfg, &e);
            progress();
            return Disposition::Release;
        }
        turn_of[i] += 1;
        if turn_of[i] < convs[i].turns() {
            let c = &ctxs[i];
            let prompt = convs[i].followup_prompt(turn_of[i], c[c.len() - 2], c[c.len() - 1]);
            ctxs[i].extend(&prompt);
            Disposition::Continue { prompt, max_new: cfg.run.max_new_tokens }
        } else {
            completed[i] = true;
            progress();
            Disposition::Release
        }
    });
    if let Err(e) = res {
        // The fused drive is shared, so one bad request aborts the whole
        // group drive. Bound the blast radius: clear the scheduler and
        // engines, then retry every conversation that had written NO
        // records yet in isolation on the sequential path (its own
        // errors dump only itself). Conversations with partial records
        // cannot be replayed without duplicating turns — dump those.
        for shed in sched.abort_all() {
            // Sheds are externally visible accounting even when the
            // epoch that raised them is being torn down — surface them
            // instead of dropping them with the aborted group.
            eprintln!(
                "rank {rank}: conversation {} shed before group abort \
                 (waited {:.2} virtual ms past a {:.0} ms target)",
                shed.id, shed.waited_ms, shed.target_ms
            );
        }
        for eng in engines.iter_mut() {
            eng.reset();
        }
        for (i, conv) in convs.iter().enumerate() {
            if completed[i] {
                continue;
            }
            if turn_of[i] > 0 {
                dump_failure(writer, conv, "ea", rank, cfg, &e);
                progress();
            } else {
                engines[0].reset();
                if let Err(e2) =
                    run_conversation(backend, &mut engines[0], cfg, conv, "ea", rank, writer)
                {
                    dump_failure(writer, conv, "ea", rank, cfg, &e2);
                }
                progress();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{pair_turns, ThroughputReport};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("eagle_coord_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn base_cfg(tag: &str) -> CoordinatorConfig {
        let mut run = RunConfig::default();
        run.max_new_tokens = 12;
        CoordinatorConfig {
            world_size: 2,
            run,
            workload: WorkloadSpec::smoke(),
            backend: BackendSpec::Sim { agree_pct: 90 },
            trace_dir: tmpdir(tag),
            run_baseline: true,
            run_ea: true,
            max_batch: 1,
            scheduling: AdmissionPolicy::Continuous,
            verbose: false,
        }
    }

    #[test]
    fn smoke_workload_produces_paired_records() {
        let cfg = base_cfg("smoke");
        let records = run_workload(&cfg).unwrap();
        // 3 code (1 turn) + 3 chat (2 turns) = 9 turns x 2 kinds
        assert_eq!(records.len(), 18);
        let pairs = pair_turns(&records);
        assert_eq!(pairs.len(), 9);
        let rep = ThroughputReport::from_pairs(&pairs);
        assert_eq!(rep.turns, 9);
        // the sim is fast in both modes; just sanity-check shapes
        assert!(rep.accept_l.n > 0);
        let _ = std::fs::remove_dir_all(&cfg.trace_dir);
    }

    #[test]
    fn sharding_is_deterministic_and_disjoint() {
        let mut cfg = base_cfg("shard1");
        let r1 = run_workload(&cfg).unwrap();
        cfg.trace_dir = tmpdir("shard2");
        cfg.world_size = 3;
        let r3 = run_workload(&cfg).unwrap();
        // same records regardless of world size (rank differs, data equal)
        assert_eq!(r1.len(), r3.len());
        for (a, b) in r1.iter().zip(&r3) {
            assert_eq!(a.conversation_id, b.conversation_id);
            assert_eq!(a.turn_idx, b.turn_idx);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.output_len, b.output_len);
            assert_eq!(a.accept_lens, b.accept_lens);
        }
        let _ = std::fs::remove_dir_all(&cfg.trace_dir);
    }

    #[test]
    fn batched_serving_is_token_identical_to_sequential() {
        // The serving-layer claim: max_batch (under either admission
        // policy) only changes how launches are grouped, never what is
        // decoded — record-for-record token equality against the
        // sequential path.
        let cfg1 = base_cfg("batch_seq");
        let seq = run_workload(&cfg1).unwrap();
        for (tag, policy) in [
            ("batch_cont", AdmissionPolicy::Continuous),
            ("batch_chunk", AdmissionPolicy::Chunked),
        ] {
            let mut cfg4 = base_cfg(tag);
            cfg4.max_batch = 4;
            cfg4.scheduling = policy;
            let bat = run_workload(&cfg4).unwrap();
            assert_eq!(seq.len(), bat.len(), "{tag}");
            for (a, b) in seq.iter().zip(&bat) {
                assert_eq!(a.conversation_id, b.conversation_id, "{tag}");
                assert_eq!(a.turn_idx, b.turn_idx, "{tag}");
                assert_eq!(a.kind, b.kind, "{tag}");
                assert_eq!(
                    a.output_len, b.output_len,
                    "{tag}: conv {} turn {}", a.conversation_id, a.turn_idx
                );
                assert_eq!(a.accept_lens, b.accept_lens, "{tag}");
                assert_eq!(a.teacher_calls, b.teacher_calls, "{tag}");
                assert_eq!(a.rounds, b.rounds, "{tag}");
            }
            let _ = std::fs::remove_dir_all(&cfg4.trace_dir);
        }
        let _ = std::fs::remove_dir_all(&cfg1.trace_dir);
    }

    #[test]
    fn zero_max_batch_is_a_config_contract_error() {
        let mut cfg = base_cfg("batch_zero");
        cfg.max_batch = 0;
        let err = run_workload(&cfg).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("max_batch"), "error must name the contract: {msg}");
        // the run must not have produced any trace directory content
        assert!(
            !cfg.trace_dir.join("run_manifest.json").exists(),
            "rejected run must not write a manifest"
        );
        let _ = std::fs::remove_dir_all(&cfg.trace_dir);
    }

    #[test]
    fn manifest_written_with_config() {
        let cfg = base_cfg("manifest");
        run_workload(&cfg).unwrap();
        let text =
            std::fs::read_to_string(cfg.trace_dir.join("run_manifest.json")).unwrap();
        let j = crate::json::parse(&text).unwrap();
        assert_eq!(j.get("world_size").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("max_batch").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("scheduling").unwrap().as_str(), Some("continuous"));
        assert!(j.at("run.tree_budget").is_some());
        let _ = std::fs::remove_dir_all(&cfg.trace_dir);
    }
}
