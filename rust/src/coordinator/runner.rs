//! Multi-worker workload runner.
//!
//! Mirrors the paper's distributed evaluation protocol (§4.4):
//! * conversations are sharded deterministically by
//!   `conversation_id % world_size` (the paper's `prompt_id mod
//!   world_size` on 8 NPUs — here: worker threads, each owning its own
//!   PJRT client/executables, since PJRT handles are not Send);
//! * each rank writes an independent `trace_rank{r}.jsonl`;
//! * rank 0 merges them into a globally sorted `trace_merged.jsonl`.
//!
//! Each conversation is decoded under the requested kinds ("baseline",
//! "ea") on **warmed, reused engines**: constructing a fresh engine per
//! conversation re-allocated both multi-MB KV cache buffers, every
//! scratch arena and the incremental mask slots, which dominated
//! short-turn serving cost. `Engine::reset` between conversations
//! restores bit-identical fresh-engine behaviour (asserted by the
//! engine's reuse-equivalence test), so the records are unchanged.
//!
//! With `max_batch > 1` a worker holds that many conversations resident
//! (one engine each) and the EA kind decodes them **concurrently**: each
//! tick fuses the group's tree verifications into one padded teacher
//! launch through the [`BatchScheduler`] (the batching contract in
//! `docs/ARCHITECTURE.md`). Token-level records are bit-identical to the
//! sequential path — only wall-clock changes (asserted by a test below) —
//! so `max_batch` is purely a throughput knob. Memory cost: one teacher +
//! draft KV cache pair per slot.
//!
//! Two-turn conversations keep cache state across turns and materialize
//! follow-up prompts from the live context (MT-Bench protocol). Abnormal
//! turns produce a failure dump and the run continues (§4.3); in a
//! batched group the dump granularity is the group (the fused launch is
//! shared), each member conversation receiving a dump that names the
//! error.

use crate::backend::{sim::SimBackend, ModelBackend};
use crate::config::RunConfig;
use crate::coordinator::batch::BatchScheduler;
use crate::engine::Engine;
use crate::json::Json;
use crate::runtime::PjrtBackend;
use crate::trace::{merge_rank_files, FailureDump, TraceWriter, TurnRecord};
use crate::workload::{ConversationSpec, WorkloadSpec};
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

/// How each worker constructs its backend (built *inside* the worker
/// thread — PJRT handles are !Send).
#[derive(Clone, Debug)]
pub enum BackendSpec {
    /// Deterministic simulator (tests, CI, harness dry runs).
    Sim {
        /// Draft/teacher top-1 agreement percentage.
        agree_pct: u64,
    },
    /// Real AOT artifacts through PJRT.
    Pjrt {
        /// Directory holding `manifest.json` + `*.hlo.txt` artifacts.
        artifact_dir: PathBuf,
    },
}

impl BackendSpec {
    fn build(&self) -> Result<Box<dyn ModelBackend>> {
        Ok(match self {
            BackendSpec::Sim { agree_pct } => Box::new(SimBackend::new(*agree_pct)),
            BackendSpec::Pjrt { artifact_dir } => Box::new(PjrtBackend::load(artifact_dir)?),
        })
    }

    /// Human-readable description for manifests and logs.
    pub fn describe(&self) -> String {
        match self {
            BackendSpec::Sim { agree_pct } => format!("sim(agree={agree_pct})"),
            BackendSpec::Pjrt { artifact_dir } => format!("pjrt({})", artifact_dir.display()),
        }
    }
}

/// Everything a coordinator run needs to know.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker thread count (the paper's world size).
    pub world_size: usize,
    /// Per-engine decode configuration.
    pub run: RunConfig,
    /// The conversation workload to decode.
    pub workload: WorkloadSpec,
    /// Backend each worker builds.
    pub backend: BackendSpec,
    /// Directory receiving trace files + run manifest.
    pub trace_dir: PathBuf,
    /// Decode every conversation with teacher-only greedy ("baseline").
    pub run_baseline: bool,
    /// Decode every conversation with tree speculation ("ea").
    pub run_ea: bool,
    /// Conversations resident per worker; EA verification is fused
    /// across them per tick when > 1 (token-identical, faster wall).
    pub max_batch: usize,
    /// Print progress lines to stderr.
    pub verbose: bool,
}

impl CoordinatorConfig {
    /// The run-manifest fragment written next to the traces.
    pub fn manifest(&self) -> Json {
        let mut o = Json::obj();
        o.push("world_size", self.world_size)
            .push("backend", self.backend.describe())
            .push("run", self.run.to_json())
            .push("turns", self.workload.total_turns())
            .push("run_baseline", self.run_baseline)
            .push("run_ea", self.run_ea)
            .push("max_batch", self.max_batch)
            .push("workload_seed", self.workload.seed);
        o
    }
}

/// Run the workload across `world_size` workers; returns the merged,
/// globally sorted records.
pub fn run_workload(cfg: &CoordinatorConfig) -> Result<Vec<TurnRecord>> {
    anyhow::ensure!(cfg.world_size >= 1, "world_size must be >= 1");
    std::fs::create_dir_all(&cfg.trace_dir)?;
    crate::trace::writer::write_manifest(&cfg.trace_dir, cfg.manifest())?;
    let conversations = cfg.workload.conversations();
    let done = AtomicUsize::new(0);
    let total = conversations.len();

    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for rank in 0..cfg.world_size {
            let convs: Vec<ConversationSpec> = conversations
                .iter()
                .filter(|c| c.id % cfg.world_size == rank)
                .cloned()
                .collect();
            let cfg_ref = &*cfg;
            let done_ref = &done;
            handles.push(scope.spawn(move || -> Result<()> {
                worker(rank, cfg_ref, convs, done_ref, total)
            }));
        }
        for h in handles {
            h.join().expect("worker panicked")?;
        }
        Ok(())
    })?;

    merge_rank_files(&cfg.trace_dir)
}

fn worker(
    rank: usize,
    cfg: &CoordinatorConfig,
    convs: Vec<ConversationSpec>,
    done: &AtomicUsize,
    total: usize,
) -> Result<()> {
    let mut backend = cfg.backend.build().with_context(|| format!("rank {rank} backend"))?;
    // One engine per resident-conversation slot, reused across every
    // (conversation, kind): warmup absorbs lazy PJRT module compilation
    // AND brings every reusable buffer (KV caches, scratch arenas, mask
    // slots) to its high-water capacity before any timed turn.
    let slots = cfg.max_batch.max(1);
    let mut engines: Vec<Engine> =
        (0..slots).map(|_| Engine::new(&*backend, cfg.run.clone())).collect();
    for e in engines.iter_mut() {
        e.warmup(&mut *backend)?;
    }
    let mut sched = BatchScheduler::new(slots, backend.contract().cache_cap);
    let mut writer = TraceWriter::create(&cfg.trace_dir, rank)?;
    for chunk in convs.chunks(slots) {
        if cfg.run_baseline {
            for conv in chunk {
                engines[0].reset();
                if let Err(e) = run_conversation(
                    &mut *backend, &mut engines[0], cfg, conv, "baseline", rank, &mut writer)
                {
                    dump_failure(&writer, conv, "baseline", rank, cfg, &e);
                }
            }
        }
        if cfg.run_ea {
            if slots <= 1 {
                for conv in chunk {
                    engines[0].reset();
                    if let Err(e) = run_conversation(
                        &mut *backend, &mut engines[0], cfg, conv, "ea", rank, &mut writer)
                    {
                        dump_failure(&writer, conv, "ea", rank, cfg, &e);
                    }
                }
            } else if let Err(e) =
                run_group_ea(&mut *backend, &mut engines, &mut sched, cfg, chunk, rank, &mut writer)
            {
                // the fused launch is shared: dump the error for every
                // member so each conversation stays traceable
                for conv in chunk {
                    dump_failure(&writer, conv, "ea", rank, cfg, &e);
                }
            }
        }
        for _ in chunk {
            let n = done.fetch_add(1, Ordering::Relaxed) + 1;
            if cfg.verbose && (n % 10 == 0 || n == total) {
                eprintln!("[coordinator] {n}/{total} conversations done");
            }
        }
    }
    writer.flush()?;
    Ok(())
}

fn dump_failure(
    writer: &TraceWriter,
    conv: &ConversationSpec,
    kind: &str,
    rank: usize,
    cfg: &CoordinatorConfig,
    err: &anyhow::Error,
) {
    let dump = FailureDump {
        conversation_id: conv.id,
        turn_idx: 0,
        rank,
        error: format!("{err:#}"),
        prompt: conv.first_prompt(),
        context_len: 0,
        config: cfg.run.to_json(),
    };
    match writer.failure(&dump) {
        Ok(path) => eprintln!(
            "[rank {rank}] conversation {} ({kind}) failed: {err:#} (dump: {})",
            conv.id,
            path.display()
        ),
        Err(we) => eprintln!(
            "[rank {rank}] conversation {} ({kind}) failed: {err:#} (dump write failed: {we:#})",
            conv.id
        ),
    }
}

/// Decode one conversation (all turns) with one kind on one engine —
/// the sequential path.
fn run_conversation(
    backend: &mut dyn ModelBackend,
    engine: &mut Engine,
    cfg: &CoordinatorConfig,
    conv: &ConversationSpec,
    kind: &str,
    rank: usize,
    writer: &mut TraceWriter,
) -> Result<()> {
    // committed text so far (prompts + generations) for follow-up prompts
    let mut ctx: Vec<i32> = Vec::new();
    for turn in 0..conv.turns() {
        let prompt = if turn == 0 {
            conv.first_prompt()
        } else {
            let a = ctx[ctx.len() - 2];
            let b = ctx[ctx.len() - 1];
            conv.followup_prompt(turn, a, b)
        };
        let out = if kind == "baseline" {
            engine.generate_baseline(backend, &prompt, cfg.run.max_new_tokens)?
        } else {
            engine.generate_speculative(backend, &prompt, cfg.run.max_new_tokens)?
        };
        ctx.extend(&prompt);
        ctx.extend(&out.tokens);
        let rec = TurnRecord::from_gen(conv.id, turn, rank, conv.profile.as_str(), kind, &out);
        writer.write(&rec)?;
    }
    Ok(())
}

/// Decode a group of conversations concurrently under the EA kind:
/// turn-by-turn, each turn's speculative rounds fused across the group
/// by the scheduler. Token-level records are bit-identical to the
/// sequential path.
fn run_group_ea(
    backend: &mut dyn ModelBackend,
    engines: &mut [Engine],
    sched: &mut BatchScheduler,
    cfg: &CoordinatorConfig,
    convs: &[ConversationSpec],
    rank: usize,
    writer: &mut TraceWriter,
) -> Result<()> {
    let n = convs.len();
    debug_assert!(n <= engines.len());
    for e in engines[..n].iter_mut() {
        e.reset();
    }
    let mut ctxs: Vec<Vec<i32>> = vec![Vec::new(); n];
    let max_turns = convs.iter().map(ConversationSpec::turns).max().unwrap_or(0);
    for turn in 0..max_turns {
        let mut active: Vec<usize> = Vec::new();
        for (i, conv) in convs.iter().enumerate() {
            if turn >= conv.turns() {
                continue; // shorter conversation: slot idles this turn
            }
            let prompt = if turn == 0 {
                conv.first_prompt()
            } else {
                let c = &ctxs[i];
                conv.followup_prompt(turn, c[c.len() - 2], c[c.len() - 1])
            };
            engines[i].begin_speculative(backend, &prompt, cfg.run.max_new_tokens)?;
            ctxs[i].extend(&prompt);
            active.push(i);
        }
        // engines without an in-flight generation are skipped by the
        // scheduler, so driving the whole slice is safe
        sched.run(backend, &mut engines[..n])?;
        for &i in &active {
            let out = engines[i].take_output()?;
            ctxs[i].extend(&out.tokens);
            let rec = TurnRecord::from_gen(
                convs[i].id, turn, rank, convs[i].profile.as_str(), "ea", &out);
            writer.write(&rec)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{pair_turns, ThroughputReport};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("eagle_coord_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn base_cfg(tag: &str) -> CoordinatorConfig {
        let mut run = RunConfig::default();
        run.max_new_tokens = 12;
        CoordinatorConfig {
            world_size: 2,
            run,
            workload: WorkloadSpec::smoke(),
            backend: BackendSpec::Sim { agree_pct: 90 },
            trace_dir: tmpdir(tag),
            run_baseline: true,
            run_ea: true,
            max_batch: 1,
            verbose: false,
        }
    }

    #[test]
    fn smoke_workload_produces_paired_records() {
        let cfg = base_cfg("smoke");
        let records = run_workload(&cfg).unwrap();
        // 3 code (1 turn) + 3 chat (2 turns) = 9 turns x 2 kinds
        assert_eq!(records.len(), 18);
        let pairs = pair_turns(&records);
        assert_eq!(pairs.len(), 9);
        let rep = ThroughputReport::from_pairs(&pairs);
        assert_eq!(rep.turns, 9);
        // the sim is fast in both modes; just sanity-check shapes
        assert!(rep.accept_l.n > 0);
        let _ = std::fs::remove_dir_all(&cfg.trace_dir);
    }

    #[test]
    fn sharding_is_deterministic_and_disjoint() {
        let mut cfg = base_cfg("shard1");
        let r1 = run_workload(&cfg).unwrap();
        cfg.trace_dir = tmpdir("shard2");
        cfg.world_size = 3;
        let r3 = run_workload(&cfg).unwrap();
        // same records regardless of world size (rank differs, data equal)
        assert_eq!(r1.len(), r3.len());
        for (a, b) in r1.iter().zip(&r3) {
            assert_eq!(a.conversation_id, b.conversation_id);
            assert_eq!(a.turn_idx, b.turn_idx);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.output_len, b.output_len);
            assert_eq!(a.accept_lens, b.accept_lens);
        }
        let _ = std::fs::remove_dir_all(&cfg.trace_dir);
    }

    #[test]
    fn batched_serving_is_token_identical_to_sequential() {
        // The serving-layer claim: max_batch only fuses launches, it
        // never changes what is decoded — record-for-record token
        // equality against the sequential path.
        let cfg1 = base_cfg("batch_seq");
        let seq = run_workload(&cfg1).unwrap();
        let mut cfg4 = base_cfg("batch_fused");
        cfg4.max_batch = 4;
        let bat = run_workload(&cfg4).unwrap();
        assert_eq!(seq.len(), bat.len());
        for (a, b) in seq.iter().zip(&bat) {
            assert_eq!(a.conversation_id, b.conversation_id);
            assert_eq!(a.turn_idx, b.turn_idx);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.output_len, b.output_len, "conv {} turn {}", a.conversation_id,
                       a.turn_idx);
            assert_eq!(a.accept_lens, b.accept_lens);
            assert_eq!(a.teacher_calls, b.teacher_calls);
            assert_eq!(a.rounds, b.rounds);
        }
        let _ = std::fs::remove_dir_all(&cfg1.trace_dir);
        let _ = std::fs::remove_dir_all(&cfg4.trace_dir);
    }

    #[test]
    fn manifest_written_with_config() {
        let cfg = base_cfg("manifest");
        run_workload(&cfg).unwrap();
        let text =
            std::fs::read_to_string(cfg.trace_dir.join("run_manifest.json")).unwrap();
        let j = crate::json::parse(&text).unwrap();
        assert_eq!(j.get("world_size").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("max_batch").unwrap().as_usize(), Some(1));
        assert!(j.at("run.tree_budget").is_some());
        let _ = std::fs::remove_dir_all(&cfg.trace_dir);
    }
}
