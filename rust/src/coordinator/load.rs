//! Serving-load evaluation: latency under an arrival process.
//!
//! The paper's conclusion claims validation "under both single-request
//! and serving-like batching conditions". This module provides the
//! serving-like side: a trace-driven queueing evaluation in which
//! *service times are really measured* (every request is decoded through
//! the engine) and *arrivals are simulated* (seeded exponential
//! inter-arrival times), composed by an M/G/k-style queue replay over k
//! servers — the standard methodology when the testbed has fewer cores
//! than the modeled deployment.
//!
//! Reported: queue wait, TTFT (wait + measured prefill), TPOT, end-to-end
//! latency, server utilization and sustained throughput.

use crate::config::RunConfig;
use crate::coordinator::BackendSpec;
use crate::engine::Engine;
use crate::util::stats::Summary;
use crate::util::SplitMix64;
use crate::workload::{Grammar, Profile};
use anyhow::Result;

/// Configuration of one serving-load evaluation.
#[derive(Clone, Debug)]
pub struct LoadSpec {
    /// Total requests to decode and replay.
    pub requests: usize,
    /// Offered load, requests/second (Poisson arrivals).
    pub arrival_rate: f64,
    /// Number of simulated servers (each = one engine + artifact set).
    pub servers: usize,
    /// Prompt length per request (tokens).
    pub prompt_len: usize,
    /// Tokens generated per request.
    pub max_new: usize,
    /// Arrival-process / prompt-sampling seed.
    pub seed: u64,
}

impl Default for LoadSpec {
    fn default() -> Self {
        Self { requests: 16, arrival_rate: 0.5, servers: 2, prompt_len: 48,
               max_new: 48, seed: 0 }
    }
}

/// Latency/throughput report of one load evaluation.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Queue wait per request, seconds.
    pub queue_wait: Summary,
    /// Time to first token (wait + measured prefill), seconds.
    pub ttft: Summary,
    /// Time per output token, milliseconds.
    pub tpot_ms: Summary,
    /// End-to-end latency (wait + service), seconds.
    pub e2e: Summary,
    /// Fraction of busy server-time over the makespan.
    pub utilization: f64,
    /// Completed requests per second of simulated wall-clock.
    pub throughput_rps: f64,
    /// Mean measured decode throughput (tok/s) per request.
    pub tok_s: Summary,
}

impl LoadReport {
    /// Human-readable table of the report.
    pub fn render(&self) -> String {
        format!(
            "serving-load report\n\
             | metric        |     mean |      p50 |      p90 |      p99 |\n\
             |---------------|----------|----------|----------|----------|\n\
             | queue wait s  | {} |\n\
             | TTFT s        | {} |\n\
             | TPOT ms       | {} |\n\
             | e2e latency s | {} |\n\
             | Tok/s         | {} |\n\
             utilization {:.2}  throughput {:.2} req/s\n",
            self.queue_wait.row().trim().replace("   ", " | "),
            self.ttft.row().trim().replace("   ", " | "),
            self.tpot_ms.row().trim().replace("   ", " | "),
            self.e2e.row().trim().replace("   ", " | "),
            self.tok_s.row().trim().replace("   ", " | "),
            self.utilization,
            self.throughput_rps,
        )
    }
}

/// Run the load evaluation. Service times are measured by actually
/// decoding each request (speculative path) on one engine; the queue is
/// then replayed over `spec.servers` simulated servers.
pub fn run_load(backend: &BackendSpec, run: &RunConfig, spec: &LoadSpec) -> Result<LoadReport> {
    // -------- measured phase: real decodes --------
    let mut b = backend_build(backend)?;
    let mut run_cfg = run.clone();
    run_cfg.instrument = true; // prefill timing feeds TTFT
    let mut engine = Engine::new(&*b, run_cfg.clone());
    engine.warmup(&mut *b)?;
    let mut rng = SplitMix64::new(spec.seed ^ 0x10AD);
    struct Served {
        arrival: f64,
        service: f64,
        prefill: f64,
        tokens: usize,
    }
    let mut served = Vec::with_capacity(spec.requests);
    let mut t_arrival = 0.0f64;
    for i in 0..spec.requests {
        // exponential inter-arrival
        t_arrival += -(1.0 - rng.f64_unit()).ln() / spec.arrival_rate.max(1e-9);
        let profile = if i % 2 == 0 { Profile::Code } else { Profile::Chat };
        let prompt = Grammar::new(profile).sample_sequence(
            spec.prompt_len, spec.seed ^ i as u64, None);
        engine.reset();
        let out = engine.generate_speculative(&mut *b, &prompt, spec.max_new)?;
        served.push(Served {
            arrival: t_arrival,
            service: out.wall_secs,
            prefill: out.timers.seconds.get("prefill").copied().unwrap_or(0.0),
            tokens: out.tokens.len(),
        });
    }

    // -------- replay phase: M/G/k queue over measured service times ----
    let mut free_at = vec![0.0f64; spec.servers.max(1)];
    let mut waits = Vec::new();
    let mut ttfts = Vec::new();
    let mut e2es = Vec::new();
    let mut tpots = Vec::new();
    let mut toks = Vec::new();
    let mut busy = 0.0f64;
    let mut makespan: f64 = 0.0;
    for s in &served {
        // earliest-free server (free_at is never empty: servers.max(1);
        // total_cmp keeps the comparator total even for NaN timings)
        let idx = free_at
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map_or(0, |(i, _)| i);
        let start = s.arrival.max(free_at[idx]);
        let wait = start - s.arrival;
        free_at[idx] = start + s.service;
        busy += s.service;
        makespan = makespan.max(free_at[idx]);
        waits.push(wait);
        ttfts.push(wait + s.prefill);
        e2es.push(wait + s.service);
        tpots.push(s.service / s.tokens.max(1) as f64 * 1e3);
        toks.push(s.tokens as f64 / s.service.max(1e-9));
    }
    let makespan = makespan.max(1e-9);
    Ok(LoadReport {
        queue_wait: Summary::from(&waits),
        ttft: Summary::from(&ttfts),
        tpot_ms: Summary::from(&tpots),
        e2e: Summary::from(&e2es),
        utilization: busy / (makespan * spec.servers.max(1) as f64),
        throughput_rps: served.len() as f64 / makespan,
        tok_s: Summary::from(&toks),
    })
}

fn backend_build(spec: &BackendSpec) -> Result<Box<dyn crate::backend::ModelBackend>> {
    spec.build_boxed()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_spec(rate: f64) -> LoadSpec {
        LoadSpec { requests: 12, arrival_rate: rate, servers: 2,
                   prompt_len: 16, max_new: 8, seed: 3 }
    }

    fn sim() -> BackendSpec {
        BackendSpec::Sim { agree_pct: 85 }
    }

    #[test]
    fn low_load_has_negligible_queueing() {
        let r = run_load(&sim(), &RunConfig::default(), &base_spec(0.01)).unwrap();
        assert!(r.queue_wait.p99 < r.e2e.mean * 0.5 + 1e-6,
                "waits should be small at low load: {:?}", r.queue_wait);
        assert!(r.utilization < 0.9);
    }

    #[test]
    fn overload_grows_queue_waits() {
        let lo = run_load(&sim(), &RunConfig::default(), &base_spec(0.01)).unwrap();
        let hi = run_load(&sim(), &RunConfig::default(), &base_spec(1e6)).unwrap();
        assert!(hi.queue_wait.mean > lo.queue_wait.mean,
                "overload must queue: {} vs {}", hi.queue_wait.mean, lo.queue_wait.mean);
        assert!(hi.utilization > 0.6);
    }

    #[test]
    fn report_renders_all_rows() {
        let r = run_load(&sim(), &RunConfig::default(), &base_spec(0.5)).unwrap();
        let text = r.render();
        for key in ["TTFT", "TPOT", "queue wait", "utilization"] {
            assert!(text.contains(key), "{text}");
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = run_load(&sim(), &RunConfig::default(), &base_spec(0.5)).unwrap();
        let b = run_load(&sim(), &RunConfig::default(), &base_spec(0.5)).unwrap();
        // arrivals identical; service times are wall-clock measured so we
        // only require matching token counts / arrival structure
        assert_eq!(a.queue_wait.n, b.queue_wait.n);
    }
}
