//! The engine worker: one serving thread owning a backend, its engine
//! slots, the per-worker cache pools and a [`ContinuousScheduler`],
//! driven entirely through typed channel RPC ([`crate::rpc`]).
//!
//! A worker is spawned by the coordinator front end
//! ([`crate::coordinator::front::Coordinator`]) with a command receiver
//! and an event sender; it never shares memory with the coordinator —
//! every message crosses as serialized bytes. The worker serves
//! *batches*: it buffers [`wire::Submit`] commands until one arrives
//! with `last: true`, then replays the buffered arrivals on its own
//! virtual clock (the exact protocol of `harness::replay`, per shard),
//! streaming [`wire::TokenDelta`]s after every tick and reporting each
//! finished turn as a [`wire::Park`] or [`wire::Completion`].
//!
//! # Determinism
//!
//! Two rules make a worker's behavior a pure function of its command
//! sequence, independent of thread scheduling and channel timing:
//!
//! 1. **Batch buffering** — no tick runs until the batch is complete,
//!    so the virtual clock never observes *when* commands arrived, only
//!    the `arrival_ms` they carry.
//! 2. **Synchronous park resolution** — after any tick that parked
//!    conversations, the worker blocks until every park's
//!    [`wire::Resume`] has arrived before ticking again, so the tick at
//!    which a resumed conversation re-enters the queue is fixed by the
//!    protocol, not by how fast the coordinator answered.
//!
//! This is what makes `--workers N` token streams bit-identical to
//! `--workers 1` per conversation (property-tested in
//! `tests/multiworker.rs`).
//!
//! # Shutdown
//!
//! Command-channel hangup is the shutdown signal. The worker stops
//! where it is, aborts in-flight work, and sends one final
//! [`wire::WorkerStats`] (`is_final: true`) carrying its cumulative
//! counters and — the part that used to be silently lost — every shed
//! notice still undrained at abort time
//! ([`ContinuousScheduler::abort_all`] returns them since the
//! multi-worker split; see the regression test in
//! `tests/multiworker.rs`).

use crate::cache::CachePools;
use crate::config::RunConfig;
use crate::coordinator::batch::{Completion, ContinuousScheduler, Disposition, SlotRequest};
use crate::coordinator::runner::BackendSpec;
use crate::engine::Engine;
use crate::rpc::envelope as wire;
use crate::rpc::{ChannelError, Codec, Envelope, WireReceiver, WireSender};
use anyhow::{bail, Context, Result};
use std::collections::{HashMap, HashSet};

/// Everything a worker thread needs to build itself (the coordinator
/// passes this by value — workers share no construction state).
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// This worker's rank in `0..workers`.
    pub rank: usize,
    /// Engine slots (fused launch width) of this worker's scheduler.
    pub slots: usize,
    /// Backend to build in-thread (PJRT handles are `!Send` — the spec
    /// crosses the thread boundary, the backend never does).
    pub backend: BackendSpec,
    /// Per-slot engine configuration.
    pub run: RunConfig,
    /// Virtual milliseconds charged per scheduler tick (host half).
    pub tick_host_ms: f64,
    /// Virtual milliseconds charged per fused launch (device half).
    pub launch_ms: f64,
}

/// How a batch replay ended.
enum BatchEnd {
    /// Every buffered conversation completed or shed.
    Done,
    /// The command channel hung up mid-batch (coordinator shutdown).
    Hangup,
}

/// One serving thread's owned state: backend, slot engines, a
/// sequential retry/baseline engine, shared per-worker cache pools and
/// the continuous scheduler. Built and driven entirely on the worker
/// thread by [`run_worker`]; the `Send` bound on
/// [`crate::cache::KvStore`] (and `Arc`-based [`crate::cache::SharedPool`])
/// is what lets the pieces be assembled here at all.
pub struct EngineWorker {
    rank: usize,
    backend: Box<dyn crate::backend::ModelBackend>,
    engines: Vec<Engine>,
    /// Dedicated engine for synchronous service: baseline-kind requests
    /// and `isolated` retries never touch the scheduler's slot engines.
    seq_engine: Engine,
    sched: ContinuousScheduler,
    tick_host_ms: f64,
    launch_ms: f64,
    /// Whether each conversation's *current* turn parks on completion
    /// (set by its `Submit`, refreshed by every `Resume`).
    park_next: HashMap<u64, bool>,
    /// Zero-based index of each conversation's current turn.
    turn_of: HashMap<u64, usize>,
    /// Tokens of the current turn already streamed as deltas.
    sent: HashMap<u64, usize>,
}

impl EngineWorker {
    /// Build the worker's full serving stack (backend, warmed engines,
    /// pools, scheduler) in the calling thread.
    pub fn build(cfg: &WorkerConfig) -> Result<Self> {
        anyhow::ensure!(cfg.slots >= 1, "worker {}: slots must be >= 1", cfg.rank);
        let mut backend =
            cfg.backend.build_boxed().with_context(|| format!("worker {} backend", cfg.rank))?;
        let pools = CachePools::new(backend.contract());
        let mut engines: Vec<Engine> = (0..cfg.slots)
            .map(|_| Engine::with_pools(&*backend, cfg.run.clone(), &pools))
            .collect();
        let mut seq_engine = Engine::with_pools(&*backend, cfg.run.clone(), &pools);
        for e in engines.iter_mut() {
            e.warmup(&mut *backend)?;
        }
        seq_engine.warmup(&mut *backend)?;
        let mut sched = ContinuousScheduler::new(cfg.slots, backend.contract().cache_cap);
        sched.set_pipelining(cfg.run.pipelining);
        Ok(Self {
            rank: cfg.rank,
            backend,
            engines,
            seq_engine,
            sched,
            tick_host_ms: cfg.tick_host_ms,
            launch_ms: cfg.launch_ms,
            park_next: HashMap::new(),
            turn_of: HashMap::new(),
            sent: HashMap::new(),
        })
    }

    /// Serve command batches until hangup (clean shutdown). `Ok(())`
    /// means shutdown; `Err` is a protocol or engine failure the caller
    /// reports in the final stats message.
    fn serve<C: Codec>(
        &mut self,
        commands: &WireReceiver<Envelope, C>,
        events: &WireSender<Envelope, C>,
    ) -> Result<()> {
        loop {
            // Phase A: buffer one batch of submissions.
            let mut batch: Vec<wire::Submit> = Vec::new();
            loop {
                match commands.recv() {
                    Ok(Envelope::Submit(s)) => {
                        let last = s.last;
                        batch.push(s);
                        if last {
                            break;
                        }
                    }
                    Ok(Envelope::Abort(wire::Abort { id: None })) => batch.clear(),
                    Ok(Envelope::Abort(wire::Abort { id: Some(id) })) => {
                        batch.retain(|s| s.id != id)
                    }
                    Ok(other) => bail!(
                        "worker {}: unexpected '{}' command outside a batch",
                        self.rank,
                        other.kind_str()
                    ),
                    // Hangup between batches: clean shutdown. A partial
                    // batch (no `last` marker yet) was never fully
                    // submitted — the coordinator contract is to flush
                    // before shutting down — so it is dropped, not run.
                    Err(ChannelError::Disconnected) => return Ok(()),
                    Err(e) => return Err(e.into()),
                }
            }
            match self.replay_batch(batch, commands, events)? {
                BatchEnd::Done => {}
                BatchEnd::Hangup => return Ok(()),
            }
        }
    }

    /// Replay one buffered batch on the virtual clock — the worker-side
    /// replica of the single-threaded `harness::replay` loop, tick for
    /// tick, plus event streaming and synchronous park resolution.
    fn replay_batch<C: Codec>(
        &mut self,
        batch: Vec<wire::Submit>,
        commands: &WireReceiver<Envelope, C>,
        events: &WireSender<Envelope, C>,
    ) -> Result<BatchEnd> {
        debug_assert!(
            batch.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms),
            "batch arrivals must be in trace order"
        );
        let n = batch.len();
        let mut next = 0usize;
        let mut done = 0usize;
        let mut releases: Vec<Completion> = Vec::new();
        let mut parks: Vec<Completion> = Vec::new();
        let mut safety = 0u32;
        while done < n {
            // Admit every arrival due at the current virtual time.
            while next < n && batch[next].arrival_ms <= self.sched.now_ms() {
                let s = &batch[next];
                if s.kind == wire::RequestKind::Baseline || s.isolated {
                    if self.serve_sequential(s, events)? {
                        done += 1;
                    } else {
                        return Ok(BatchEnd::Hangup);
                    }
                } else {
                    self.park_next.insert(s.id, s.park_on_complete);
                    self.turn_of.insert(s.id, 0);
                    self.sent.insert(s.id, 0);
                    self.sched.submit(SlotRequest {
                        id: s.id,
                        prompt: s.prompt.clone(),
                        max_new: s.max_new,
                        cfg: None,
                        slo: s.slo,
                    });
                }
                next += 1;
            }
            if done >= n {
                break;
            }
            // Drained before the next arrival: jump the clock to it.
            if self.sched.is_idle() && next < n {
                let gap = batch[next].arrival_ms - self.sched.now_ms();
                self.sched.advance_clock(gap.max(0.0) + 1e-9);
                continue;
            }
            if self.sched.is_idle() {
                bail!("worker {}: scheduler idle with {} terminals pending", self.rank, n - done);
            }
            let launches_before = self.sched.stats.fused_launches;
            let shed_before = self.sched.stats.shed;
            releases.clear();
            parks.clear();
            let park_next = &self.park_next;
            self.sched.tick(&mut *self.backend, &mut self.engines, &mut |c: Completion| {
                if park_next.get(&c.id).copied().unwrap_or(false) {
                    parks.push(c);
                    Disposition::Park
                } else {
                    releases.push(c);
                    Disposition::Release
                }
            })?;
            let launches = self.sched.stats.fused_launches - launches_before;
            self.sched
                .advance_clock(self.tick_host_ms + launches as f64 * self.launch_ms);
            done += (self.sched.stats.shed - shed_before) as usize;
            done += releases.len();
            if !self.stream_deltas(events)? {
                return Ok(BatchEnd::Hangup);
            }
            for c in releases.drain(..) {
                let turn = self.finish_turn(&c, events)?;
                match turn {
                    Some(td) => {
                        if events.send(&Envelope::Completion(wire::Completion { done: td })).is_err()
                        {
                            return Ok(BatchEnd::Hangup);
                        }
                    }
                    None => return Ok(BatchEnd::Hangup),
                }
            }
            let mut awaiting: HashSet<u64> = HashSet::new();
            for c in parks.drain(..) {
                let id = c.id;
                match self.finish_turn(&c, events)? {
                    Some(td) => {
                        if events.send(&Envelope::Park(wire::Park { done: td })).is_err() {
                            return Ok(BatchEnd::Hangup);
                        }
                        awaiting.insert(id);
                    }
                    None => return Ok(BatchEnd::Hangup),
                }
            }
            // Block until every park is answered: the resume tick is
            // part of the protocol, never a race (see module docs).
            while !awaiting.is_empty() {
                match commands.recv() {
                    Ok(Envelope::Resume(r)) => {
                        anyhow::ensure!(
                            awaiting.remove(&r.id),
                            "worker {}: resume for conversation {} which is not awaiting one",
                            self.rank,
                            r.id
                        );
                        self.park_next.insert(r.id, r.park_on_complete);
                        *self.turn_of.entry(r.id).or_insert(0) += 1;
                        self.sent.insert(r.id, 0);
                        self.sched.resume(r.id, r.prompt, r.max_new)?;
                    }
                    Ok(other) => bail!(
                        "worker {}: unexpected '{}' command while awaiting resumes",
                        self.rank,
                        other.kind_str()
                    ),
                    Err(ChannelError::Disconnected) => return Ok(BatchEnd::Hangup),
                    Err(e) => return Err(e.into()),
                }
            }
            safety += 1;
            if safety >= 1_000_000 {
                bail!("worker {}: batch replay failed to converge after {safety} ticks", self.rank);
            }
        }
        // Surface the batch's shed outcomes and cumulative counters.
        for notice in self.sched.drain_shed() {
            if events
                .send(&Envelope::ShedNotice(wire::ShedNotice { rank: self.rank, notice }))
                .is_err()
            {
                return Ok(BatchEnd::Hangup);
            }
        }
        let stats = wire::WorkerStats {
            rank: self.rank,
            stats: self.sched.stats,
            shed: Vec::new(),
            is_final: false,
            error: None,
        };
        if events.send(&Envelope::WorkerStats(stats)).is_err() {
            return Ok(BatchEnd::Hangup);
        }
        Ok(BatchEnd::Done)
    }

    /// Serve a baseline-kind or isolated request synchronously on the
    /// dedicated sequential engine, charging the virtual clock one tick
    /// plus one launch per teacher call. Returns `Ok(false)` on event
    /// hangup.
    fn serve_sequential<C: Codec>(
        &mut self,
        s: &wire::Submit,
        events: &WireSender<Envelope, C>,
    ) -> Result<bool> {
        anyhow::ensure!(
            !s.park_on_complete,
            "worker {}: sequential request {} cannot park (single-turn lane)",
            self.rank,
            s.id
        );
        self.seq_engine.reset();
        let out = match s.kind {
            wire::RequestKind::Baseline => {
                self.seq_engine.generate_baseline(&mut *self.backend, &s.prompt, s.max_new)?
            }
            wire::RequestKind::Ea => {
                self.seq_engine.generate_speculative(&mut *self.backend, &s.prompt, s.max_new)?
            }
        };
        let tick = self.sched.current_tick();
        self.sched
            .advance_clock(self.tick_host_ms + out.teacher_calls as f64 * self.launch_ms);
        let delta = wire::TokenDelta { id: s.id, turn: 0, tokens: out.tokens.clone() };
        if events.send(&Envelope::TokenDelta(delta)).is_err() {
            return Ok(false);
        }
        let td = wire::TurnDone {
            id: s.id,
            rank: self.rank,
            turn: 0,
            out,
            submitted_tick: tick,
            admitted_tick: tick,
            finished_tick: tick,
            waited_ticks: 0,
            finished_ms: self.sched.now_ms(),
        };
        Ok(events.send(&Envelope::Completion(wire::Completion { done: td })).is_ok())
    }

    /// Stream the tokens every active conversation committed this tick.
    /// Returns `Ok(false)` on event-channel hangup.
    fn stream_deltas<C: Codec>(&mut self, events: &WireSender<Envelope, C>) -> Result<bool> {
        for (slot, id) in self.sched.active_ids() {
            let Some(toks) = self.engines[slot].inflight_tokens() else { continue };
            let sent = self.sent.entry(id).or_insert(0);
            if toks.len() > *sent {
                let delta = wire::TokenDelta {
                    id,
                    turn: self.turn_of.get(&id).copied().unwrap_or(0),
                    tokens: toks[*sent..].to_vec(),
                };
                *sent = toks.len();
                if events.send(&Envelope::TokenDelta(delta)).is_err() {
                    return Ok(false);
                }
            }
        }
        Ok(true)
    }

    /// Close out a retired turn: flush its tail token delta and build
    /// the [`wire::TurnDone`] record. `Ok(None)` on event hangup.
    fn finish_turn<C: Codec>(
        &mut self,
        c: &Completion,
        events: &WireSender<Envelope, C>,
    ) -> Result<Option<wire::TurnDone>> {
        let turn = self.turn_of.get(&c.id).copied().unwrap_or(0);
        let sent = self.sent.get(&c.id).copied().unwrap_or(0);
        if c.out.tokens.len() > sent {
            let delta = wire::TokenDelta {
                id: c.id,
                turn,
                tokens: c.out.tokens[sent..].to_vec(),
            };
            if events.send(&Envelope::TokenDelta(delta)).is_err() {
                return Ok(None);
            }
        }
        Ok(Some(wire::TurnDone {
            id: c.id,
            rank: self.rank,
            turn,
            out: c.out.clone(),
            submitted_tick: c.submitted_tick,
            admitted_tick: c.admitted_tick,
            finished_tick: c.finished_tick,
            waited_ticks: c.waited_ticks,
            finished_ms: self.sched.now_ms(),
        }))
    }
}

/// Thread entry point: build the worker, serve until shutdown or
/// failure, and always attempt one final [`wire::WorkerStats`]
/// (`is_final: true`) — the coordinator's drain barrier. The final
/// message carries the shed notices [`ContinuousScheduler::abort_all`]
/// returned, so sheds raised after the coordinator stopped reading
/// per-tick events are surfaced in aggregated stats instead of being
/// dropped with the epoch that raised them.
pub fn run_worker<C: Codec>(
    cfg: WorkerConfig,
    commands: WireReceiver<Envelope, C>,
    events: WireSender<Envelope, C>,
) {
    let rank = cfg.rank;
    let final_stats = match EngineWorker::build(&cfg) {
        Err(e) => wire::WorkerStats {
            rank,
            stats: Default::default(),
            shed: Vec::new(),
            is_final: true,
            error: Some(format!("{e:#}")),
        },
        Ok(mut w) => {
            let error = w.serve(&commands, &events).err().map(|e| format!("{e:#}"));
            wire::WorkerStats {
                rank,
                stats: w.sched.stats,
                shed: w.sched.abort_all(),
                is_final: true,
                error,
            }
        }
    };
    // Best effort: if the coordinator is gone entirely, there is no one
    // left to report to.
    let _ = events.send(&Envelope::WorkerStats(final_stats));
}
