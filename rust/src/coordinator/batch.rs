//! Cross-request batched verification: the serving-layer scheduler that
//! fuses several conversations' tree-verification calls into **one**
//! padded teacher launch.
//!
//! The paper amortizes teacher invocations across *speculated tokens*
//! (one call verifies a whole tree); this module amortizes them across
//! *requests* as well — the dominant remaining lever once per-step
//! allocation is gone, and the batching mode SpecInfer-style serving
//! systems rely on. Per tick the scheduler:
//!
//! 1. gathers up to `max_batch` **ready** conversations (engines whose
//!    in-flight generation wants another round);
//! 2. has each run its *per-request* draft half
//!    ([`Engine::prepare_verify`]: chain refresh, tree expansion,
//!    tensorize, incremental mask);
//! 3. pads every request to the group's largest compiled variant
//!    `S_max`, assembles the fused `[B, S_max, cap + S_max]` mask block
//!    ([`BatchMask`]) and `[B * S_max]` token/position rows, and launches
//!    **one** [`ModelBackend::teacher_step_batch`];
//! 4. scatters each request's output rows back into its engine's own
//!    scratch ([`Engine::scatter_verify`]) and finishes the round
//!    per-request ([`Engine::finish_verify`]: acceptance + commit).
//!
//! Acceptance and cache commits never cross requests, so batched decoding
//! is **bit-identical** to sequential decoding — `tests/batched.rs`
//! property-tests this over random ragged batches (mixed tree budgets,
//! context lengths and `max_new`, including one-token stragglers).
//! Conversations that finish simply drop out of the ready set, so the
//! batch shrinks naturally (ragged completion).
//!
//! All gather/scatter staging (`tokens`, `positions`, the mask block and
//! the fused output scratch) lives in the scheduler and only ever grows,
//! keeping steady-state batched rounds allocation-free (asserted by
//! `tests/alloc_regression.rs`).

use crate::backend::{BatchRequest, BatchStepArgs, ModelBackend, StepScratch};
use crate::engine::{Engine, GenOut};
use crate::tree::BatchMask;
use anyhow::Result;
use std::time::Instant;

/// Fuses up to `max_batch` ready conversations' verification steps per
/// tick (see the module docs for the full protocol).
pub struct BatchScheduler {
    max_batch: usize,
    /// Fused `[B * S_max]` token staging.
    tokens: Vec<i32>,
    /// Fused `[B * S_max]` position staging.
    positions: Vec<i32>,
    /// Fused `[B, S_max, cap + S_max]` mask block.
    mask: BatchMask,
    /// Fused teacher outputs, scattered per-request after the launch.
    out: StepScratch,
}

impl BatchScheduler {
    /// A scheduler fusing up to `max_batch` requests per launch, for
    /// caches of capacity `cache_cap`.
    pub fn new(max_batch: usize, cache_cap: usize) -> Self {
        Self {
            max_batch: max_batch.max(1),
            tokens: Vec::new(),
            positions: Vec::new(),
            mask: BatchMask::new(cache_cap),
            out: StepScratch::new(),
        }
    }

    /// The configured fusion width.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Drive every engine with an in-flight generation to completion,
    /// fusing up to `max_batch` verifications per tick. Engines without
    /// an in-flight generation (or already done) are skipped, so ragged
    /// groups shrink naturally. On return, every previously in-flight
    /// engine is ready for [`Engine::take_output`].
    pub fn run(&mut self, backend: &mut dyn ModelBackend, engines: &mut [Engine]) -> Result<()> {
        loop {
            // ready set of this tick (tiny: <= engines.len() indices)
            let ready: Vec<usize> =
                (0..engines.len()).filter(|&i| engines[i].needs_more()).collect();
            if ready.is_empty() {
                return Ok(());
            }
            for group in ready.chunks(self.max_batch) {
                for &i in group {
                    engines[i].prepare_verify(backend)?;
                }
                self.fused_verify(backend, engines, group)?;
                for &i in group {
                    engines[i].finish_verify()?;
                }
            }
        }
    }

    /// One fused verification over `group` (indices into `engines`), all
    /// of which must have a prepared round: pad to the group's largest
    /// (S, ctx), launch once, scatter per-request logits/features/KV rows
    /// back into each engine's scratch.
    fn fused_verify(
        &mut self,
        backend: &mut dyn ModelBackend,
        engines: &mut [Engine],
        group: &[usize],
    ) -> Result<()> {
        debug_assert!(!group.is_empty());
        let mode = engines[group[0]].cfg.mode;
        // pad to the largest compiled variant in the group (variants come
        // from one contract, so the max is itself a compiled variant)
        let mut s_max = 0usize;
        for &i in group {
            s_max = s_max.max(engines[i].verify_payload()?.s);
        }
        let b = group.len();
        self.tokens.clear();
        self.tokens.resize(b * s_max, 0);
        self.positions.clear();
        self.positions.resize(b * s_max, 0);
        self.mask.begin(b, s_max);
        let mut reqs: Vec<BatchRequest> = Vec::with_capacity(b);
        for (bi, &i) in group.iter().enumerate() {
            anyhow::ensure!(engines[i].cfg.mode == mode, "mixed exec modes in one batch");
            let p = engines[i].verify_payload()?;
            self.tokens[bi * s_max..bi * s_max + p.s].copy_from_slice(p.tokens);
            self.positions[bi * s_max..bi * s_max + p.s].copy_from_slice(p.positions);
            self.mask.fill_request(bi, p.mask, p.s);
            reqs.push(BatchRequest { kv: p.kv, live: p.s });
        }
        let t0 = Instant::now();
        backend.teacher_step_batch(
            mode,
            BatchStepArgs {
                s_max,
                tokens: &self.tokens,
                positions: &self.positions,
                mask: self.mask.as_slice(),
                reqs: &reqs,
            },
            &mut self.out,
        )?;
        // attribute the fused launch evenly across the group (timers are
        // instrumentation, not accounting — see docs/ARCHITECTURE.md)
        let secs = t0.elapsed().as_secs_f64() / b as f64;
        drop(reqs);
        for (bi, &i) in group.iter().enumerate() {
            engines[i].scatter_verify(&self.out, bi)?;
            engines[i].add_stage_time("verify", secs);
        }
        Ok(())
    }
}

/// Convenience driver: begin a speculative generation on every engine
/// (engine `i` decodes `prompts[i]`), drive them to completion with fused
/// verification, and return the per-request outputs in input order.
///
/// For per-request `max_new` (ragged deadlines), call
/// [`Engine::begin_speculative`] yourself, then [`BatchScheduler::run`]
/// and [`Engine::take_output`] — this helper is the uniform-deadline
/// common case.
pub fn decode_speculative_batch(
    backend: &mut dyn ModelBackend,
    engines: &mut [Engine],
    prompts: &[Vec<i32>],
    max_new: usize,
    sched: &mut BatchScheduler,
) -> Result<Vec<GenOut>> {
    anyhow::ensure!(
        engines.len() == prompts.len(),
        "engines ({}) and prompts ({}) must pair up",
        engines.len(),
        prompts.len()
    );
    for (e, p) in engines.iter_mut().zip(prompts) {
        e.begin_speculative(backend, p, max_new)?;
    }
    sched.run(backend, engines)?;
    engines.iter_mut().map(Engine::take_output).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::sim::SimBackend;
    use crate::config::RunConfig;
    use crate::util::SplitMix64;

    fn prompt(n: usize, seed: u64) -> Vec<i32> {
        let mut rng = SplitMix64::new(seed);
        let mut p = vec![1i32]; // BOS
        for _ in 1..n {
            p.push(rng.range(2, 512) as i32);
        }
        p
    }

    fn sequential(cfgs: &[RunConfig], prompts: &[Vec<i32>], max_new: usize, agree: u64)
        -> Vec<GenOut> {
        prompts
            .iter()
            .zip(cfgs)
            .map(|(p, cfg)| {
                let mut b = SimBackend::new(agree);
                let mut e = Engine::new(&b, cfg.clone());
                e.generate_speculative(&mut b, p, max_new).unwrap()
            })
            .collect()
    }

    fn batched(cfgs: &[RunConfig], prompts: &[Vec<i32>], max_new: usize, agree: u64,
               max_batch: usize) -> Vec<GenOut> {
        let mut b = SimBackend::new(agree);
        let mut engines: Vec<Engine> =
            cfgs.iter().map(|cfg| Engine::new(&b, cfg.clone())).collect();
        let cap = b.contract().cache_cap;
        let mut sched = BatchScheduler::new(max_batch, cap);
        decode_speculative_batch(&mut b, &mut engines, prompts, max_new, &mut sched).unwrap()
    }

    #[test]
    fn batched_matches_sequential_uniform_group() {
        let cfgs = vec![RunConfig::default(); 4];
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| prompt(10 + i * 3, 40 + i as u64)).collect();
        let seq = sequential(&cfgs, &prompts, 20, 85);
        let bat = batched(&cfgs, &prompts, 20, 85, 4);
        for (s, b) in seq.iter().zip(&bat) {
            assert_eq!(s.tokens, b.tokens, "batched tokens diverged");
            assert_eq!(s.accept_lens, b.accept_lens, "accept shape diverged");
            assert_eq!(s.teacher_calls, b.teacher_calls, "per-request call accounting");
        }
    }

    #[test]
    fn batched_matches_sequential_ragged_budgets() {
        // mixed tree budgets -> mixed padded variants within one fused
        // launch (the ragged-batch case of the batching contract)
        let mut cfgs = Vec::new();
        for budget in [1usize, 5, 16, 40] {
            let mut c = RunConfig::default();
            c.tree.budget = budget;
            cfgs.push(c);
        }
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| prompt(8 + i * 7, 60 + i as u64)).collect();
        let seq = sequential(&cfgs, &prompts, 16, 90);
        let bat = batched(&cfgs, &prompts, 16, 90, 4);
        for (s, b) in seq.iter().zip(&bat) {
            assert_eq!(s.tokens, b.tokens);
            assert_eq!(s.accept_lens, b.accept_lens);
        }
    }

    #[test]
    fn scheduler_amortizes_teacher_launches() {
        let cfgs = vec![RunConfig::default(); 4];
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| prompt(12, 70 + i as u64)).collect();

        let mut b_seq = SimBackend::new(90);
        for (p, cfg) in prompts.iter().zip(&cfgs) {
            let mut e = Engine::new(&b_seq, cfg.clone());
            e.generate_speculative(&mut b_seq, p, 16).unwrap();
        }
        let seq_launches = b_seq.teacher_calls;

        let mut b_bat = SimBackend::new(90);
        let mut engines: Vec<Engine> =
            cfgs.iter().map(|cfg| Engine::new(&b_bat, cfg.clone())).collect();
        let cap = b_bat.contract().cache_cap;
        let mut sched = BatchScheduler::new(4, cap);
        decode_speculative_batch(&mut b_bat, &mut engines, &prompts, 16, &mut sched).unwrap();
        let bat_launches = b_bat.teacher_calls;

        assert!(
            bat_launches * 2 < seq_launches,
            "fusion must amortize launches: {bat_launches} vs {seq_launches}"
        );
    }

    #[test]
    fn run_with_no_inflight_generations_is_a_noop() {
        let b = SimBackend::new(90);
        let mut engines = vec![Engine::new(&b, RunConfig::default())];
        let cap = b.contract().cache_cap;
        let mut sched = BatchScheduler::new(2, cap);
        let mut b = b;
        sched.run(&mut b, &mut engines).unwrap();
        assert!(engines[0].take_output().is_err(), "nothing was in flight");
    }

    #[test]
    fn singleton_batches_equal_plain_generation() {
        // max_batch = 1 drives each request through the fused path alone;
        // output must still equal generate_speculative exactly.
        let cfgs = vec![RunConfig::default(); 2];
        let prompts = vec![prompt(9, 91), prompt(14, 92)];
        let seq = sequential(&cfgs, &prompts, 12, 80);
        let bat = batched(&cfgs, &prompts, 12, 80, 1);
        for (s, b) in seq.iter().zip(&bat) {
            assert_eq!(s.tokens, b.tokens);
        }
    }
}
