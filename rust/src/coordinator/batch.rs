//! Continuous cross-request batching: the serving-layer scheduler that
//! fuses several conversations' tree-verification calls into **one**
//! padded teacher launch per tick, and — unlike a fixed group — admits
//! newly-ready conversations into the *running* group whenever a slot
//! frees up.
//!
//! The paper amortizes teacher invocations across *speculated tokens*
//! (one call verifies a whole tree); this module amortizes them across
//! *requests* as well, and keeps that amortization high under ragged real
//! traffic: a one-token straggler retiring early no longer shrinks the
//! launch width for the rest of its group, because the next queued
//! conversation takes its slot at the very next tick (SpecInfer-style
//! continuous batching).
//!
//! # Slot lifecycle
//!
//! A [`ContinuousScheduler`] drives `E` resident engine *slots* (one
//! conversation per slot) plus a FIFO admission queue:
//!
//! ```text
//!  submit ──> [queue] ──admit──> [active] ──retire──> Completion
//!                ^                  │  ^                   │
//!                │                  │  └── Continue ───────┤ (next turn,
//!                └──────────────────┘      (same slot,     │  context kept)
//!                ^   slot freed by Release <───────────────┤
//!                │                                         │ Park (slot freed,
//!             resume <──────── [parked] <──────────────────┘  context resident
//!         (next turn, no                                       as block tables)
//!          re-prefill)
//! ```
//!
//! Per [`ContinuousScheduler::tick`]:
//!
//! 1. **Retire** — every active slot whose engine no longer wants a round
//!    (deadline reached *or* out of cache headroom, i.e. stalled) is
//!    closed: `take_output` produces a [`Completion`] handed to the
//!    caller, whose [`Disposition`] either releases the slot or begins
//!    the conversation's next turn on the same engine (context kept —
//!    multi-turn residency).
//! 2. **Admit** — freed slots are filled from the queue in FIFO order:
//!    no admission ever overtakes an earlier one (property-tested), so
//!    a queued conversation's wait is bounded by the total remaining
//!    turns of the conversations ahead of it. A [`Disposition::Continue`]
//!    deliberately holds its slot across turns (context residency), so a
//!    caller that continues a conversation forever starves the queue by
//!    construction — finite-turn workloads (the runner's) cannot.
//!    Admission resets the slot engine (or applies the request's own
//!    [`RunConfig`] via [`Engine::set_config`] first) and prefills the
//!    prompt.
//! 3. **Verify** — one fused verification round over every ready slot:
//!    each runs its per-request draft half ([`Engine::prepare_verify`]),
//!    the group is padded to its largest compiled variant `S_max`, ONE
//!    [`ModelBackend::teacher_step_batch`] launch runs, and each
//!    request's output rows are scattered back
//!    ([`Engine::scatter_verify`]) and finished per-request
//!    ([`Engine::finish_verify`]).
//!
//! A conversation admitted at tick `T` joins tick `T`'s fused launch —
//! the group is re-padded every tick ([`BatchMask::begin`] closes the
//! whole block before requests are copied in), so membership changes
//! mid-flight never leak padding (checked every tick, in release builds
//! too, by [`BatchMask::check_padding_closed`]).
//!
//! Acceptance and cache commits never cross requests, so continuous
//! batched decoding is **bit-identical** to sequential decoding no matter
//! when a conversation was admitted or who its slot-mates were —
//! `tests/continuous.rs` property-tests this over randomized arrival
//! schedules, and `tests/batched.rs` over random ragged groups.
//!
//! All gather/scatter staging (`tokens`, `positions`, the mask block and
//! the fused output scratch, owned by the inner [`FusedVerifier`]) only
//! ever grows, keeping steady-state batched rounds allocation-free
//! (asserted by `tests/alloc_regression.rs`).
//!
//! # Software pipelining (half-ticks)
//!
//! With [`ContinuousScheduler::set_pipelining`] on (the default,
//! [`crate::config::RunConfig::pipelining`]), one fused verification
//! round is split into two half-ticks that can be in flight
//! simultaneously: [`FusedVerifier::stage`] (plan → gather → pad into a
//! ping-pong buffer) and [`FusedVerifier::launch`] /
//! [`FusedVerifier::resolve`] (begin / await + scatter). The scheduler
//! partitions each tick's ready set into *waves*: while wave N's launch
//! is in flight on the device, wave N+1 runs its host half — retire,
//! admit, draft expansion ([`Engine::prepare_verify`]) and staging — and
//! the in-flight launch is carried **across the tick boundary**, so the
//! next tick's host work overlaps it too. Slots in an in-flight launch
//! are *pinned* (never retired, admitted over, or re-drafted) from stage
//! to resolve; everything staged is copied, so membership changes among
//! unpinned slots can never corrupt a launch already in flight. Ordering
//! within each conversation is untouched — acceptance and commits never
//! cross requests — so the pipelined path is bit-identical to the
//! synchronous one by construction (property-tested in
//! `tests/continuous.rs`; `--pipelining off` keeps the depth-synchronous
//! reference). See `docs/ARCHITECTURE.md` §12 for the timeline diagram.

use crate::backend::{
    BatchRequest, BatchStepArgs, KvView, LaunchPlan, LaunchToken, ModelBackend, ModuleLayout,
    PlanError, PlanRequest, SessionTicket, StepScratch,
};
use crate::cache::KvGuard;
use crate::config::{CacheLayout, RunConfig};
use crate::engine::{Engine, GenOut, ParkedConversation};
use crate::tree::BatchMask;
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use crate::util::timer::Stopwatch;

/// One ping-pong staging buffer of the verifier: the fused input block
/// (tokens/positions/mask), the output scratch its launch lands in, and
/// the per-request bookkeeping the resolve half scatters by. Two of
/// these alternate ([`FusedVerifier::stage`] flips between them), so
/// launch N's outputs can still be in flight while launch N+1 stages —
/// with no steady-state allocations on either path.
struct StageBuf {
    /// Fused `[B_key * S_key]` token staging.
    tokens: Vec<i32>,
    /// Fused `[B_key * S_key]` position staging.
    positions: Vec<i32>,
    /// Fused `[B_key, S_key, cap + S_key]` mask block.
    mask: BatchMask,
    /// Fused teacher outputs, scattered per-request at resolve.
    out: StepScratch,
    /// Per-request padded variants (0 for group-padding slots).
    s_reqs: Vec<usize>,
    /// Per-request session tickets.
    tickets: Vec<Option<SessionTicket>>,
    /// Engine indices of the staged group (resolve scatters to these).
    group: Vec<usize>,
}

impl StageBuf {
    fn new(cache_cap: usize) -> Self {
        Self {
            tokens: Vec::new(),
            positions: Vec::new(),
            mask: BatchMask::new(cache_cap),
            out: StepScratch::new(),
            s_reqs: Vec::new(),
            tickets: Vec::new(),
            group: Vec::new(),
        }
    }
}

/// A fully staged fused launch, ready to begin. Self-contained (every
/// input was *copied* into its ping-pong buffer at staging; it holds no
/// borrows), so the scheduler may retire/admit/draft *other* slots
/// between staging and launching — its own members are pinned by the
/// scheduler until resolve.
pub struct StagedLaunch {
    /// Ping-pong buffer index holding the staging.
    buf: usize,
    /// The negotiated launch plan.
    plan: LaunchPlan,
    /// Live group members (`<= plan.key.b`; the rest is padding).
    b: usize,
}

/// An in-flight fused launch: the [`LaunchToken`] to await plus the
/// timing needed to attribute host-blocked and host-hidden launch time
/// at resolve. Holds no borrows, so it can be carried **across a tick
/// boundary** — the cross-tick half of the software pipeline.
pub struct InFlightLaunch {
    buf: usize,
    token: LaunchToken,
    begin_secs: f64,
    launched_at: Stopwatch,
    b: usize,
}

/// Outcome of [`FusedVerifier::stage`].
pub enum StageOutcome {
    /// The group was staged; begin it with [`FusedVerifier::launch`].
    Staged(StagedLaunch),
    /// No fused variant covers the whole group ([`PlanError::SplitRequired`]):
    /// nothing was staged — re-stage in chunks of at most `max_batch`.
    Split {
        /// Widest compiled fused batch covering the group's rows.
        max_batch: usize,
    },
}

/// The plan → gather → pad → launch → scatter half of one fused
/// verification round, split into the pipeline's two half-ticks:
/// [`FusedVerifier::stage`] (host: plan + gather + pad into a ping-pong
/// [`StageBuf`]) and [`FusedVerifier::launch`] /
/// [`FusedVerifier::resolve`] (device: begin / await + scatter).
/// [`FusedVerifier::verify_group`] is the synchronous composition of the
/// three — the depth-synchronous reference path.
///
/// All *sized* staging (the fused token/position rows, the mask blocks,
/// the output scratches) lives in the two [`StageBuf`]s and only ever
/// grows; the only per-round allocations left are the two `B`-element
/// `Vec`s of borrowed per-request cache guards/views inside `launch`
/// (pointer-sized entries, far below the alloc-regression gate's
/// vocab/cap-sized threshold — they cannot be hoisted without
/// self-borrowing the engines).
pub struct FusedVerifier {
    /// Ping-pong staging buffers ([`FusedVerifier::stage`] alternates).
    bufs: [StageBuf; 2],
    /// Buffer index the most recent `stage` wrote into.
    cur: usize,
    /// Cumulative fused launches issued (splits count each sub-launch).
    pub launches: u64,
}

/// Empty cache view handed to group-padding requests (their mask block
/// is fully closed, so no row is ever resolved through it).
const EMPTY_KV: &[f32] = &[];

impl FusedVerifier {
    /// A verifier for caches of capacity `cache_cap`.
    pub fn new(cache_cap: usize) -> Self {
        Self {
            bufs: [StageBuf::new(cache_cap), StageBuf::new(cache_cap)],
            cur: 0,
            launches: 0,
        }
    }

    /// Stage one fused verification over `group` (indices into `engines`,
    /// all of which must have a prepared round): negotiate the launch
    /// plan, then gather + pad every member's payload into the *other*
    /// ping-pong buffer (the one not owned by a possibly in-flight
    /// launch).
    ///
    /// Launch-plan negotiation replaces the old pad-to-group-max rule:
    /// the verifier asks the backend for the smallest compiled `(B, S)`
    /// variant covering the group's live rows
    /// ([`ModelBackend::plan_step`]); when the negotiation answers
    /// [`PlanError::SplitRequired`] (no fused variant spans the whole
    /// group) nothing is staged and [`StageOutcome::Split`] tells the
    /// caller to re-stage in `max_batch`-wide sub-groups — launches stay
    /// as wide as the artifact set allows, and sub-launches pipeline
    /// within the pass. Requests beyond the group
    /// (`plan.key.b > group.len()`) are padding: zero tokens, fully
    /// closed mask rows, an empty cache view, and no live rows to
    /// scatter back ([`BatchMask::check_padding_closed`] runs after the
    /// gather — in release builds too — so interleaved membership changes
    /// can never leak an open padding row).
    pub fn stage(
        &mut self,
        backend: &dyn ModelBackend,
        engines: &[Engine],
        group: &[usize],
    ) -> Result<StageOutcome> {
        debug_assert!(!group.is_empty());
        let mode = engines[group[0]].cfg.mode;
        let mut s_max = 0usize;
        for &i in group {
            anyhow::ensure!(engines[i].cfg.mode == mode, "mixed exec modes in one batch");
            s_max = s_max.max(engines[i].verify_payload()?.s);
        }
        let b = group.len();
        // heterogeneous layouts may share a group: any paged member makes
        // the request paged (flat-only artifact sets then resolve a flat
        // module + host gather, per-request, exactly as before)
        let layout = if group.iter().any(|&i| engines[i].cfg.cache_layout == CacheLayout::Paged)
        {
            ModuleLayout::Paged
        } else {
            ModuleLayout::Flat
        };
        let plan = match backend.plan_step(&PlanRequest::teacher_batch(mode, s_max, b, layout)) {
            Ok(plan) => plan,
            Err(PlanError::SplitRequired { max_batch, .. }) => {
                anyhow::ensure!(
                    max_batch >= 1 && max_batch < b,
                    "split negotiation returned non-splitting width {max_batch} for group {b}"
                );
                return Ok(StageOutcome::Split { max_batch });
            }
            Err(e) => {
                return Err(
                    anyhow::Error::from(e).context("planning the fused verification launch")
                )
            }
        };
        let (bk, sk) = (plan.key.b, plan.key.s);
        debug_assert!(bk >= b && sk >= s_max, "plan must cover the group");
        self.cur ^= 1;
        let buf = &mut self.bufs[self.cur];
        buf.tokens.clear();
        buf.tokens.resize(bk * sk, 0);
        buf.positions.clear();
        buf.positions.resize(bk * sk, 0);
        buf.mask.begin(bk, sk);
        buf.s_reqs.clear();
        buf.tickets.clear();
        buf.group.clear();
        for (bi, &i) in group.iter().enumerate() {
            let p = engines[i].verify_payload()?;
            buf.tokens[bi * sk..bi * sk + p.s].copy_from_slice(p.tokens);
            buf.positions[bi * sk..bi * sk + p.s].copy_from_slice(p.positions);
            buf.mask.fill_request(bi, p.mask, p.s);
            buf.s_reqs.push(p.s);
            buf.tickets.push(p.session);
            buf.group.push(i);
        }
        for _ in b..bk {
            buf.s_reqs.push(0);
            buf.tickets.push(None);
        }
        // membership changed or shrank since last round? re-padding must
        // still leave every padding row/column closed ("padding is never
        // attended" — the invariant continuous admission leans on). Checked
        // in release builds too: the scan cost scales with the padded
        // region only (zero for homogeneous groups), and a leak here would
        // silently corrupt a co-batched conversation.
        buf.mask
            .check_padding_closed(&buf.s_reqs)
            .map_err(|leak| anyhow::anyhow!("fused mask block leaked open padding: {leak}"))?;
        Ok(StageOutcome::Staged(StagedLaunch { buf: self.cur, plan, b }))
    }

    /// Begin a staged launch on the backend and return the in-flight
    /// handle to [`FusedVerifier::resolve`] it with.
    ///
    /// Every group member's cache guard lives exactly as long as the
    /// `begin` call: the backend contract says all borrowed inputs are
    /// consumed (copied or uploaded) before
    /// [`ModelBackend::begin_execute_batch`] returns, so no guard
    /// outlives the host half of the launch and cache mutation by
    /// *other* slots (retire/admit/prepare while this launch flies) may
    /// resume immediately.
    pub fn launch(
        &mut self,
        backend: &mut dyn ModelBackend,
        engines: &[Engine],
        staged: StagedLaunch,
    ) -> Result<InFlightLaunch> {
        let StagedLaunch { buf: which, plan, b } = staged;
        let (bk, sk) = (plan.key.b, plan.key.s);
        let buf = &mut self.bufs[which];
        debug_assert_eq!(buf.group.len(), b, "staged launch does not match its buffer");
        let mut guards: Vec<KvGuard> = Vec::with_capacity(b);
        for &i in buf.group.iter() {
            guards.push(engines[i].verify_payload()?.kv);
        }
        let mut reqs: Vec<BatchRequest> = guards
            .iter()
            .enumerate()
            .map(|(bi, g)| BatchRequest {
                kv: g.view(),
                live: buf.s_reqs[bi],
                session: buf.tickets[bi],
            })
            .collect();
        for _ in b..bk {
            let kv = KvView::flat(EMPTY_KV, EMPTY_KV, 0);
            reqs.push(BatchRequest { kv, live: 0, session: None });
        }
        let launched_at = Stopwatch::start();
        let token = backend.begin_execute_batch(
            &plan,
            BatchStepArgs {
                s_max: sk,
                tokens: &buf.tokens,
                positions: &buf.positions,
                mask: buf.mask.as_slice(),
                reqs: &reqs,
            },
            &mut buf.out,
        )?;
        self.launches += 1;
        let begin_secs = launched_at.elapsed_secs();
        drop(reqs);
        drop(guards);
        Ok(InFlightLaunch { buf: which, token, begin_secs, launched_at, b })
    }

    /// Await an in-flight launch and scatter its outputs back to the
    /// group's engines. The caller still owes each member a
    /// [`Engine::finish_verify`].
    ///
    /// Timer attribution (per member, its share of *this sub-launch
    /// only*): `"verify"` is the host-blocked launch time (begin +
    /// await), `"verify_hidden"` the in-flight window the host spent on
    /// other slots' work instead of waiting — pipelining's measured
    /// overlap, zero on the synchronous path where begin completes the
    /// launch eagerly.
    pub fn resolve(
        &mut self,
        backend: &mut dyn ModelBackend,
        engines: &mut [Engine],
        launch: InFlightLaunch,
    ) -> Result<()> {
        let InFlightLaunch { buf: which, token, begin_secs, launched_at, b } = launch;
        let overlapped = !token.is_completed();
        let buf = &mut self.bufs[which];
        let await_start = Stopwatch::start();
        backend.await_batch(token, &mut buf.out)?;
        let await_secs = await_start.elapsed_secs();
        let busy = (begin_secs + await_secs) / b as f64;
        let hidden = (await_start.secs_since(&launched_at) - begin_secs)
            .max(0.0)
            / b as f64;
        for (bi, &i) in buf.group.iter().enumerate() {
            engines[i].scatter_verify(&buf.out, bi)?;
            engines[i].add_stage_time("verify", busy);
            if overlapped {
                engines[i].add_stage_time("verify_hidden", hidden);
            }
        }
        Ok(())
    }

    /// One fused verification over `group`, synchronously: stage, begin,
    /// await, scatter — the depth-synchronous composition of the
    /// pipeline's half-ticks (and the `--pipelining off` reference
    /// path). A [`StageOutcome::Split`] recurses over `max_batch`-wide
    /// chunks, each sub-launch attributed to its own members only.
    pub fn verify_group(
        &mut self,
        backend: &mut dyn ModelBackend,
        engines: &mut [Engine],
        group: &[usize],
    ) -> Result<()> {
        match self.stage(backend, engines, group)? {
            StageOutcome::Split { max_batch } => {
                for chunk in group.chunks(max_batch) {
                    self.verify_group(backend, engines, chunk)?;
                }
                Ok(())
            }
            StageOutcome::Staged(staged) => {
                let fl = self.launch(backend, engines, staged)?;
                self.resolve(backend, engines, fl)
            }
        }
    }
}

/// What to do with a request whose SLO deadline expires while it is
/// still waiting in the admission queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SloAction {
    /// Drop the request with a typed [`ShedNotice`] (never silently):
    /// under overload, shedding over-deadline work protects the latency
    /// of the requests that can still meet theirs.
    Shed,
    /// Keep the request queued no matter how late it is (FIFO position
    /// preserved — the existing bounded-wait property still holds); the
    /// deadline is advisory and the caller judges it from the
    /// [`Completion`] timeline.
    Queue,
}

impl SloAction {
    /// Stable string form (flags, manifests).
    pub fn as_str(&self) -> &'static str {
        match self {
            SloAction::Shed => "shed",
            SloAction::Queue => "queue",
        }
    }

    /// Parse the string form (`shed` | `queue`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "shed" => Ok(SloAction::Shed),
            "queue" => Ok(SloAction::Queue),
            other => anyhow::bail!("unknown SLO action '{other}' (expected shed|queue)"),
        }
    }
}

/// Per-request service-level objective carried on
/// [`ContinuousScheduler::submit`]: a latency target against the
/// scheduler's virtual clock ([`ContinuousScheduler::advance_clock`])
/// and the overload action taken when the target expires pre-admission.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloPolicy {
    /// Latency target in virtual milliseconds, measured from submit.
    pub target_ms: f64,
    /// What to do when the target expires while still queued.
    pub action: SloAction,
}

impl SloPolicy {
    /// Reject degenerate targets before they reach a scheduler.
    pub fn validate(&self) -> Result<()> {
        if !self.target_ms.is_finite() || self.target_ms <= 0.0 {
            anyhow::bail!(
                "config contract: --slo-ms must be a positive finite \
                 millisecond target, got {}",
                self.target_ms
            );
        }
        Ok(())
    }
}

/// A request dropped by its [`SloAction::Shed`] policy before admission:
/// the typed overload outcome (a shed request is *never* silently
/// dropped — every one is accounted here and in
/// [`SchedulerStats::shed`]). Drain with
/// [`ContinuousScheduler::drain_shed`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShedNotice {
    /// The id given at [`ContinuousScheduler::submit`].
    pub id: u64,
    /// Tick at which the request was submitted.
    pub submitted_tick: u64,
    /// Tick at which the shed decision was taken.
    pub shed_tick: u64,
    /// Virtual milliseconds the request had waited when shed.
    pub waited_ms: f64,
    /// The expired latency target.
    pub target_ms: f64,
}

/// One conversation handed to [`ContinuousScheduler::submit`], awaiting a
/// free slot.
pub struct SlotRequest {
    /// Caller-chosen id, echoed back in the [`Completion`].
    pub id: u64,
    /// First-turn prompt tokens.
    pub prompt: Vec<i32>,
    /// Soft output-token deadline of the first turn.
    pub max_new: usize,
    /// Per-request run configuration applied to the slot engine at
    /// admission ([`Engine::set_config`]); `None` keeps the slot engine's
    /// current configuration (plain [`Engine::reset`]). Heterogeneous
    /// configs may coexist in one running group — a fused launch must be
    /// execution-mode-uniform, so the scheduler stable-partitions each
    /// tick's ready set by mode (full-width fusion per mode) instead of
    /// rejecting mixed modes.
    pub cfg: Option<RunConfig>,
    /// Per-request SLO deadline (`None` = no deadline, the existing
    /// behavior: the request waits however long FIFO admission takes).
    /// With a policy attached, the tick's admission pass sheds or keeps
    /// queueing over-deadline requests per [`SloAction`] — deadlines are
    /// judged against the virtual clock, which never advances unless the
    /// driver calls [`ContinuousScheduler::advance_clock`], so the
    /// no-SLO path is bit-identical to before.
    pub slo: Option<SloPolicy>,
}

struct Pending {
    id: u64,
    prompt: Vec<i32>,
    max_new: usize,
    cfg: Option<RunConfig>,
    /// A previously parked conversation being resumed: admission restores
    /// its full decode state instead of resetting the slot engine, so the
    /// turn continues on the preserved context without re-prefill.
    parked: Option<ParkedConversation>,
    arrived_tick: u64,
    arrived_ms: f64,
    slo: Option<SloPolicy>,
}

/// Per-slot lifecycle state (admit → active → retire).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Slot {
    /// No conversation resident; admission resets the engine.
    Free,
    /// A conversation is resident and decoding.
    Active { id: u64, admitted_tick: u64, waited_ticks: u64, submitted_tick: u64 },
}

/// A retired conversation turn: the output plus its admission timeline.
pub struct Completion {
    /// The id given at [`ContinuousScheduler::submit`].
    pub id: u64,
    /// Slot index the conversation decoded on (its engine still holds the
    /// conversation context — a [`Disposition::Continue`] keeps using it).
    pub slot: usize,
    /// The turn's generation output.
    pub out: GenOut,
    /// Tick at which the conversation was submitted to the queue.
    pub submitted_tick: u64,
    /// Tick at which the conversation was admitted into the group.
    pub admitted_tick: u64,
    /// Tick at which this turn retired.
    pub finished_tick: u64,
    /// Ticks the conversation waited in the admission queue (0 when a
    /// slot was free on arrival; bounded by FIFO admission — see the
    /// fairness property in `tests/continuous.rs`).
    pub waited_ticks: u64,
    /// The SLO the request carried, echoed back so the driver can judge
    /// the completion against its own clock (`None` = no deadline).
    pub slo: Option<SloPolicy>,
}

/// What to do with a slot after a [`Completion`].
pub enum Disposition {
    /// The conversation is done: free the slot for the admission queue.
    Release,
    /// Begin the conversation's next turn on the same slot (engine
    /// context preserved — MT-Bench-style multi-turn residency). Right
    /// when the follow-up prompt is already known; holds the slot.
    Continue {
        /// Follow-up prompt tokens of the next turn.
        prompt: Vec<i32>,
        /// Soft output-token deadline of the next turn.
        max_new: usize,
    },
    /// The conversation's next turn is not ready yet (user think-time):
    /// lift it off the engine ([`Engine::park`]) and free the slot for
    /// the admission queue, keeping the conversation resident — under the
    /// paged layout this means its mapped KV blocks only, while the slot
    /// serves other traffic. [`ContinuousScheduler::resume`] re-queues it
    /// (FIFO, no overtaking) and its next turn continues on the preserved
    /// context without re-prefill.
    Park,
}

/// Scheduler counters (cumulative over the scheduler's lifetime).
#[derive(Clone, Copy, Debug, Default)]
pub struct SchedulerStats {
    /// Conversations submitted to the admission queue.
    pub submitted: u64,
    /// Conversations admitted into a slot.
    pub admitted: u64,
    /// Turn completions retired (multi-turn conversations retire once per
    /// turn).
    pub retired: u64,
    /// Conversations parked off their slot ([`Disposition::Park`]).
    pub parked: u64,
    /// Parked conversations resumed ([`ContinuousScheduler::resume`]).
    pub resumed: u64,
    /// Scheduler ticks executed.
    pub ticks: u64,
    /// Fused verification launches issued.
    pub fused_launches: u64,
    /// Largest queue wait (ticks between submit and admission) observed.
    pub max_wait_ticks: u64,
    /// Requests shed pre-admission by their [`SloAction::Shed`] policy.
    pub shed: u64,
    /// Prefill-phase teacher calls summed over retired turns (each turn
    /// contributes `teacher_calls - rounds`, since every decode round is
    /// exactly one teacher call). Under `--prefix-sharing`, admissions
    /// that adopt a resident frozen run skip the shared rows' prefill
    /// chunks, so this drops relative to sharing-off on the same trace —
    /// the shared-prefix bench gates on it per admitted conversation.
    pub prefill_teacher_calls: u64,
}

/// Slot-based continuous-batching scheduler (see the module docs for the
/// lifecycle and tick protocol).
///
/// Two driving styles share the same fused-verification core:
///
/// * **continuous** — [`ContinuousScheduler::submit`] conversations, then
///   [`ContinuousScheduler::tick`] (or
///   [`ContinuousScheduler::run_to_idle`]); the scheduler owns admission,
///   retirement and multi-turn continuation via [`Disposition`]s;
/// * **externally begun** — the caller runs
///   [`Engine::begin_speculative`] itself and
///   [`ContinuousScheduler::drive`] fuses every in-flight engine to
///   completion (the PR-2 fixed-group protocol; callers then
///   [`Engine::take_output`] themselves).
pub struct ContinuousScheduler {
    fuse_width: usize,
    verifier: FusedVerifier,
    queue: VecDeque<Pending>,
    slots: Vec<Slot>,
    /// Conversations lifted off their slots ([`Disposition::Park`]),
    /// keyed by submission id, awaiting [`ContinuousScheduler::resume`].
    parked: HashMap<u64, ParkedConversation>,
    tick_now: u64,
    /// Reusable ready-set staging of the current tick.
    ready: Vec<usize>,
    /// Reusable staging for the mode partition: the current same-mode
    /// group being launched, and the remainder carried to the next pass.
    group_buf: Vec<usize>,
    ready_alt: Vec<usize>,
    /// Software pipelining on/off ([`RunConfig::pipelining`]; on by
    /// default, off = the depth-synchronous A/B reference path).
    pipelining: bool,
    /// The launch currently in flight on the device (pipelined path
    /// only; carried across tick boundaries).
    inflight: Option<InFlightLaunch>,
    /// Slot indices pinned by `inflight` — excluded from retire, admit
    /// and draft expansion until the launch resolves.
    inflight_members: Vec<usize>,
    /// Virtual clock in milliseconds: SLO deadlines are judged against
    /// this, never against wall time. It advances only when the driver
    /// calls [`ContinuousScheduler::advance_clock`] — a driver that
    /// never does (every pre-SLO caller) gets a frozen clock and
    /// bit-identical scheduling.
    now_ms: f64,
    /// Per-slot SLO of the resident conversation (parallel to `slots`;
    /// kept outside [`Slot`] so the slot state stays `Copy + Eq`).
    slot_slo: Vec<Option<SloPolicy>>,
    /// Shed outcomes awaiting [`ContinuousScheduler::drain_shed`].
    shed_notices: Vec<ShedNotice>,
    /// Cumulative scheduler counters.
    pub stats: SchedulerStats,
}

impl ContinuousScheduler {
    /// A scheduler fusing up to `max_batch` requests per launch, for
    /// caches of capacity `cache_cap`.
    ///
    /// # Panics
    ///
    /// Panics if `max_batch == 0` — a zero-width group is a config
    /// contract violation (the serve path rejects it with a proper error
    /// before constructing a scheduler).
    pub fn new(max_batch: usize, cache_cap: usize) -> Self {
        assert!(max_batch >= 1, "config contract: max_batch must be >= 1");
        Self {
            fuse_width: max_batch,
            verifier: FusedVerifier::new(cache_cap),
            queue: VecDeque::new(),
            slots: Vec::new(),
            parked: HashMap::new(),
            tick_now: 0,
            ready: Vec::new(),
            group_buf: Vec::new(),
            ready_alt: Vec::new(),
            pipelining: true,
            inflight: None,
            inflight_members: Vec::new(),
            now_ms: 0.0,
            slot_slo: Vec::new(),
            shed_notices: Vec::new(),
            stats: SchedulerStats::default(),
        }
    }

    /// Advance the virtual clock by `delta_ms` milliseconds. SLO
    /// deadlines are judged against this clock only — the scheduler
    /// never reads wall time for admission decisions, so replay drivers
    /// that model time deterministically stay deterministic. Negative
    /// deltas are ignored (the clock is monotone).
    pub fn advance_clock(&mut self, delta_ms: f64) {
        if delta_ms > 0.0 {
            self.now_ms += delta_ms;
        }
    }

    /// The virtual clock, in milliseconds since scheduler construction.
    pub fn now_ms(&self) -> f64 {
        self.now_ms
    }

    /// Take the accumulated [`ShedNotice`]s (typed overload outcomes of
    /// [`SloAction::Shed`] requests dropped pre-admission).
    pub fn drain_shed(&mut self) -> Vec<ShedNotice> {
        std::mem::take(&mut self.shed_notices)
    }

    /// The configured fusion width (largest request count per launch).
    pub fn max_batch(&self) -> usize {
        self.fuse_width
    }

    /// Toggle the software pipeline ([`RunConfig::pipelining`]; on by
    /// default). Off keeps the depth-synchronous reference path —
    /// bit-identical outputs by construction, no overlap.
    ///
    /// # Panics
    ///
    /// Panics if a launch is in flight: toggle only between full drains
    /// (the runner sets this once, right after construction).
    pub fn set_pipelining(&mut self, on: bool) {
        assert!(
            self.inflight.is_none(),
            "cannot toggle pipelining with a launch in flight"
        );
        self.pipelining = on;
    }

    /// Whether the software pipeline is enabled.
    pub fn pipelining(&self) -> bool {
        self.pipelining
    }

    /// Queue a conversation for admission (FIFO).
    pub fn submit(&mut self, req: SlotRequest) {
        self.stats.submitted += 1;
        self.queue.push_back(Pending {
            id: req.id,
            prompt: req.prompt,
            max_new: req.max_new,
            cfg: req.cfg,
            parked: None,
            arrived_tick: self.tick_now,
            arrived_ms: self.now_ms,
            slo: req.slo,
        });
    }

    /// Re-queue a parked conversation's next turn (FIFO, same line as
    /// fresh submissions — no overtaking). Admission restores its parked
    /// state onto the freed slot and prefills only `prompt`; the
    /// conversation's prior context is already resident (paged: its
    /// mapped blocks never left the pool), so there is **no re-prefill**.
    /// Errors if `id` was never parked (or was already resumed).
    pub fn resume(&mut self, id: u64, prompt: Vec<i32>, max_new: usize) -> Result<()> {
        let parked = self
            .parked
            .remove(&id)
            .with_context(|| format!("resume: conversation {id} is not parked"))?;
        self.stats.resumed += 1;
        self.queue.push_back(Pending {
            id,
            prompt,
            max_new,
            cfg: None,
            parked: Some(parked),
            arrived_tick: self.tick_now,
            arrived_ms: self.now_ms,
            slo: None,
        });
        Ok(())
    }

    /// Conversations waiting in the admission queue.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Conversations currently parked off their slots (resident block
    /// tables awaiting [`ContinuousScheduler::resume`]).
    pub fn parked_count(&self) -> usize {
        self.parked.len()
    }

    /// Slots currently holding an active conversation.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| matches!(s, Slot::Active { .. })).count()
    }

    /// `(slot index, conversation id)` of every active slot, in slot
    /// order. The worker's per-tick streaming loop uses this to map
    /// slot engines back to the conversation ids it reports
    /// `TokenDelta`s under.
    pub fn active_ids(&self) -> Vec<(usize, u64)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| match s {
                Slot::Active { id, .. } => Some((i, *id)),
                Slot::Free => None,
            })
            .collect()
    }

    /// Whether the scheduler has nothing queued, nothing active and
    /// nothing in flight on the device. Parked conversations do **not**
    /// block idleness — they are dormant until the caller resumes them
    /// (so `run_to_idle` returns between a park and its resume).
    pub fn is_idle(&self) -> bool {
        self.inflight.is_none()
            && self.queue.is_empty()
            && self.slots.iter().all(|s| *s == Slot::Free)
    }

    /// The current tick index (starts at 0, advances once per
    /// [`ContinuousScheduler::tick`]).
    pub fn current_tick(&self) -> u64 {
        self.tick_now
    }

    /// Error recovery after a failed drive: drop every queued and parked
    /// conversation and free every slot *without* retiring them (no
    /// outputs are produced; dropped parked caches return their blocks
    /// to the pool). Slot engines are left as-is — reset them before
    /// reusing the scheduler, or their stale in-flight state will poison
    /// the next drive. A device launch still in flight is abandoned
    /// (its token is dropped un-awaited — the backend keeps the pending
    /// entry, which a reused backend tolerates; outputs are discarded
    /// along with the conversations that wanted them). Undrained shed
    /// notices are **returned**, not dropped — sheds are externally
    /// visible accounting (a request was refused service) and must
    /// survive the teardown of the epoch that raised them; a worker
    /// folds them into its final `WorkerStats` so a shed raised after
    /// the coordinator stopped reading per-tick events still lands in
    /// the aggregated report. A post-abort
    /// [`ContinuousScheduler::drain_shed`] starts empty.
    #[must_use = "returned shed notices are externally visible accounting; dropping them loses sheds"]
    pub fn abort_all(&mut self) -> Vec<ShedNotice> {
        self.queue.clear();
        self.parked.clear();
        self.inflight = None;
        self.inflight_members.clear();
        for s in self.slots.iter_mut() {
            *s = Slot::Free;
        }
        for s in self.slot_slo.iter_mut() {
            *s = None;
        }
        std::mem::take(&mut self.shed_notices)
    }

    fn ensure_slots(&mut self, n: usize) -> Result<()> {
        if self.slots.len() < n {
            self.slots.resize(n, Slot::Free);
        }
        if self.slot_slo.len() < self.slots.len() {
            self.slot_slo.resize(self.slots.len(), None);
        }
        anyhow::ensure!(
            self.slots.len() == n,
            "engine slice shrank under the scheduler: {} slots tracked, {} engines",
            self.slots.len(),
            n
        );
        Ok(())
    }

    /// Drop every queued [`SloAction::Shed`] request whose deadline has
    /// expired on the virtual clock, each accounted by a typed
    /// [`ShedNotice`]. FIFO order among the survivors is untouched, so
    /// no admission ever overtakes an earlier surviving submission.
    /// [`SloAction::Queue`] requests are never dropped here.
    fn shed_expired(&mut self) {
        if self.queue.is_empty() {
            return;
        }
        let had = self.queue.len();
        let q = std::mem::take(&mut self.queue);
        for p in q {
            let expired_target = match p.slo {
                Some(SloPolicy { target_ms, action: SloAction::Shed })
                    if self.now_ms - p.arrived_ms > target_ms =>
                {
                    Some(target_ms)
                }
                _ => None,
            };
            if let Some(target_ms) = expired_target {
                self.shed_notices.push(ShedNotice {
                    id: p.id,
                    submitted_tick: p.arrived_tick,
                    shed_tick: self.tick_now,
                    waited_ms: self.now_ms - p.arrived_ms,
                    target_ms,
                });
                self.stats.shed += 1;
            } else {
                self.queue.push_back(p);
            }
        }
        debug_assert!(self.queue.len() <= had, "shed sweep must not grow the queue");
    }

    /// One scheduler tick: retire finished/stalled conversations (calling
    /// `on_done` for each), admit queued conversations into freed slots,
    /// then run one fused verification round over every ready slot.
    ///
    /// `engines[i]` is slot `i`'s resident engine; the slice must keep
    /// its length across ticks.
    pub fn tick(
        &mut self,
        backend: &mut dyn ModelBackend,
        engines: &mut [Engine],
        on_done: &mut dyn FnMut(Completion) -> Disposition,
    ) -> Result<()> {
        self.ensure_slots(engines.len())?;
        anyhow::ensure!(
            !(engines.is_empty() && !self.queue.is_empty()),
            "queued conversations but no engine slots"
        );
        // 1. Retire: close every active slot whose engine no longer wants
        // a round (deadline reached or stalled out of cache headroom).
        // Slots pinned by an in-flight launch are untouchable until it
        // resolves — their engines have a round pending, so neither
        // `needs_more` nor retirement may be consulted here; this
        // retire/admit work is exactly the host half the in-flight
        // launch is hiding.
        for si in 0..self.slots.len() {
            if self.inflight_members.contains(&si) {
                continue;
            }
            let Slot::Active { id, admitted_tick, waited_ticks, submitted_tick } = self.slots[si]
            else {
                continue;
            };
            if engines[si].needs_more() {
                continue;
            }
            anyhow::ensure!(
                engines[si].has_inflight(),
                "slot {si} lost its in-flight generation (engine driven outside the scheduler?)"
            );
            let out = engines[si].take_output()?;
            self.stats.retired += 1;
            self.stats.prefill_teacher_calls += out.teacher_calls.saturating_sub(out.rounds);
            let comp = Completion {
                id,
                slot: si,
                out,
                submitted_tick,
                admitted_tick,
                finished_tick: self.tick_now,
                waited_ticks,
                slo: self.slot_slo[si],
            };
            match on_done(comp) {
                Disposition::Release => {
                    self.slots[si] = Slot::Free;
                    self.slot_slo[si] = None;
                }
                Disposition::Continue { prompt, max_new } => {
                    // next turn of the same conversation: context (both KV
                    // caches) is preserved, so no reset — the slot stays
                    // active under the same id.
                    engines[si].begin_speculative(backend, &prompt, max_new)?;
                }
                Disposition::Park => {
                    // lift the conversation off the engine (paged: its
                    // blocks stay mapped in the pool) and free the slot
                    // for the admission queue.
                    let parked = engines[si].park()?;
                    self.parked.insert(id, parked);
                    self.stats.parked += 1;
                    self.slots[si] = Slot::Free;
                    self.slot_slo[si] = None;
                }
            }
        }
        // 2. Shed: drop queued Shed-policy requests whose deadline has
        // expired on the virtual clock (typed ShedNotice per drop), then
        // admit — filling freed slots from the surviving queue, FIFO.
        self.shed_expired();
        for si in 0..self.slots.len() {
            if self.queue.is_empty() {
                break;
            }
            if self.slots[si] != Slot::Free {
                continue;
            }
            let Some(mut p) = self.queue.pop_front() else {
                break;
            };
            match (p.parked.take(), p.cfg.take()) {
                // resumed turn: restore the parked state wholesale (no
                // reset, no config application — the conversation brings
                // its own)
                (Some(parked), _) => engines[si].resume(parked)?,
                (None, Some(cfg)) => engines[si].set_config(cfg),
                (None, None) => engines[si].reset(),
            }
            // name the request in the error chain: an invalid config or
            // an over-long prompt fails *here*, after the pop, and the
            // caller needs to know which submission was consumed
            engines[si]
                .begin_speculative(backend, &p.prompt, p.max_new)
                .with_context(|| format!("admitting conversation {}", p.id))?;
            let waited = self.tick_now - p.arrived_tick;
            self.stats.admitted += 1;
            self.stats.max_wait_ticks = self.stats.max_wait_ticks.max(waited);
            self.slot_slo[si] = p.slo;
            self.slots[si] = Slot::Active {
                id: p.id,
                admitted_tick: self.tick_now,
                waited_ticks: waited,
                submitted_tick: p.arrived_tick,
            };
        }
        // 2b. Occupancy feed: tell every active engine how full the batch
        // is, so an occupancy-aware adaptive controller can cap its next
        // round's tree budget. Inert (a field write behind two off-by-
        // default flags) for every other configuration.
        let live = self.active();
        let total = self.slots.len();
        for si in 0..self.slots.len() {
            if matches!(self.slots[si], Slot::Active { .. }) {
                engines[si].note_occupancy(live, total);
            }
        }
        // 3. One verification round over every ready slot — a
        // conversation admitted in step 2 joins this very round.
        // Pipelined: launch waves overlapping the in-flight one and
        // carry the last wave across the tick boundary. Synchronous:
        // one depth-synchronous fused round.
        if self.pipelining {
            self.pipelined_round(backend, engines)?;
        } else {
            self.fused_round(backend, engines)?;
        }
        self.stats.ticks += 1;
        self.tick_now += 1;
        Ok(())
    }

    /// Tick until the queue is empty and every slot is free. `on_done`
    /// decides per completion whether the conversation continues (next
    /// turn, same slot) or releases its slot.
    pub fn run_to_idle(
        &mut self,
        backend: &mut dyn ModelBackend,
        engines: &mut [Engine],
        on_done: &mut dyn FnMut(Completion) -> Disposition,
    ) -> Result<()> {
        loop {
            self.tick(backend, engines, on_done)?;
            if self.is_idle() {
                return Ok(());
            }
        }
    }

    /// Drive every engine with an in-flight generation to completion,
    /// fusing up to `max_batch` verifications per tick — the
    /// externally-begun protocol: the caller ran
    /// [`Engine::begin_speculative`] and calls [`Engine::take_output`]
    /// itself. Engines without an in-flight generation are skipped, so
    /// ragged groups shrink naturally (no admission happens here; use
    /// [`ContinuousScheduler::submit`] + [`ContinuousScheduler::tick`]
    /// for continuous admission).
    pub fn drive(&mut self, backend: &mut dyn ModelBackend, engines: &mut [Engine]) -> Result<()> {
        loop {
            let progressed = if self.pipelining {
                self.pipelined_round(backend, engines)?
            } else {
                self.fused_round(backend, engines)?
            };
            if !progressed {
                return Ok(());
            }
        }
    }

    /// Collect the ready set and run one fused verification round over
    /// it, chunked by the fusion width. Returns whether any engine was
    /// ready.
    fn fused_round(
        &mut self,
        backend: &mut dyn ModelBackend,
        engines: &mut [Engine],
    ) -> Result<bool> {
        self.ready.clear();
        for (i, e) in engines.iter().enumerate() {
            if e.needs_more() {
                self.ready.push(i);
            }
        }
        if self.ready.is_empty() {
            return Ok(false);
        }
        // heterogeneous per-request configs may mix fused/eager execution,
        // and a launch must be mode-uniform — stable-partition the ready
        // set by mode (order preserved within a mode, so outputs are
        // unchanged): same-mode slots fuse at full width no matter how
        // the modes interleave across slots.
        while !self.ready.is_empty() {
            let mode = engines[self.ready[0]].cfg.mode;
            self.group_buf.clear();
            self.ready_alt.clear();
            for &i in &self.ready {
                if engines[i].cfg.mode == mode {
                    self.group_buf.push(i);
                } else {
                    self.ready_alt.push(i);
                }
            }
            for group in self.group_buf.chunks(self.fuse_width) {
                for &i in group {
                    engines[i].prepare_verify(backend)?;
                }
                let before = self.verifier.launches;
                self.verifier.verify_group(backend, engines, group)?;
                // a split group issues several sub-launches; count what
                // actually went to the accelerator
                self.stats.fused_launches += self.verifier.launches - before;
                for &i in group {
                    engines[i].finish_verify()?;
                }
            }
            std::mem::swap(&mut self.ready, &mut self.ready_alt);
        }
        Ok(true)
    }

    /// One *pipelined* verification round: partition the unpinned ready
    /// set into waves and, for each wave, run its host half (draft
    /// expansion + staging) **while the previous wave's launch is still
    /// in flight**, then resolve the previous launch and immediately
    /// begin this wave's. The final wave's launch is left in flight
    /// across the tick boundary, so the *next* tick's retire/admit/draft
    /// work overlaps it too. Returns whether anything progressed (a
    /// launch begun or resolved).
    ///
    /// Wave sizing: chunks of the fusion width, except that a chunk
    /// staged with **nothing in flight** (pipeline cold — first tick, or
    /// right after a drain) is halved to prime the pipeline; otherwise a
    /// full-width wave would pin every slot and leave no host work to
    /// overlap its own flight.
    ///
    /// When no unpinned slot is ready, the in-flight launch (if any) is
    /// resolved and the ready set re-collected — freshly resolved slots
    /// usually want another round, so a drain never wastes a tick.
    fn pipelined_round(
        &mut self,
        backend: &mut dyn ModelBackend,
        engines: &mut [Engine],
    ) -> Result<bool> {
        let mut progressed = false;
        loop {
            self.ready.clear();
            for (i, e) in engines.iter().enumerate() {
                if !self.inflight_members.contains(&i) && e.needs_more() {
                    self.ready.push(i);
                }
            }
            if self.ready.is_empty() {
                if self.inflight.is_some() {
                    self.resolve_inflight(backend, engines)?;
                    progressed = true;
                    // the slots just resolved may want another round in
                    // this very tick — re-collect instead of returning
                    continue;
                }
                return Ok(progressed);
            }
            // mode-uniform launches: stable-partition the ready set by
            // execution mode, exactly as in the synchronous round
            while !self.ready.is_empty() {
                let mode = engines[self.ready[0]].cfg.mode;
                self.group_buf.clear();
                self.ready_alt.clear();
                for &i in &self.ready {
                    if engines[i].cfg.mode == mode {
                        self.group_buf.push(i);
                    } else {
                        self.ready_alt.push(i);
                    }
                }
                let n = self.group_buf.len();
                let mut start = 0;
                while start < n {
                    let room = self.fuse_width.min(n - start);
                    let take = if self.inflight.is_none() && room > 1 {
                        room.div_ceil(2)
                    } else {
                        room
                    };
                    let end = start + take;
                    // the host half of this wave — overlapped by the
                    // launch currently in flight (if any)
                    for idx in start..end {
                        engines[self.group_buf[idx]].prepare_verify(backend)?;
                    }
                    self.stage_launch_range(backend, engines, start, end)?;
                    start = end;
                }
                std::mem::swap(&mut self.ready, &mut self.ready_alt);
            }
            return Ok(true);
        }
    }

    /// Stage `group_buf[start..end]`, resolve the previous in-flight
    /// launch, and begin this one (which becomes the new in-flight
    /// launch, its members pinned). A [`StageOutcome::Split`] recurses
    /// over sub-ranges, so split sub-launches pipeline within the pass —
    /// each sub-launch overlaps the previous one's flight.
    fn stage_launch_range(
        &mut self,
        backend: &mut dyn ModelBackend,
        engines: &mut [Engine],
        start: usize,
        end: usize,
    ) -> Result<()> {
        let outcome = self.verifier.stage(backend, engines, &self.group_buf[start..end])?;
        match outcome {
            StageOutcome::Split { max_batch } => {
                anyhow::ensure!(
                    max_batch >= 1 && max_batch < end - start,
                    "split negotiation returned non-splitting width {max_batch} for group {}",
                    end - start
                );
                let mut s = start;
                while s < end {
                    let e = (s + max_batch).min(end);
                    self.stage_launch_range(backend, engines, s, e)?;
                    s = e;
                }
                Ok(())
            }
            StageOutcome::Staged(staged) => {
                // everything this launch needs was copied at stage —
                // resolving the previous launch (scatter + per-request
                // commits) cannot corrupt it
                self.resolve_inflight(backend, engines)?;
                let fl = self.verifier.launch(backend, engines, staged)?;
                self.inflight_members.clear();
                self.inflight_members.extend_from_slice(&self.group_buf[start..end]);
                self.inflight = Some(fl);
                self.stats.fused_launches += 1;
                Ok(())
            }
        }
    }

    /// Await + scatter the in-flight launch (if any) and finish every
    /// member's round, unpinning its slots. No-op when nothing is in
    /// flight.
    fn resolve_inflight(
        &mut self,
        backend: &mut dyn ModelBackend,
        engines: &mut [Engine],
    ) -> Result<()> {
        let Some(fl) = self.inflight.take() else {
            return Ok(());
        };
        self.verifier.resolve(backend, engines, fl)?;
        for i in self.inflight_members.drain(..) {
            engines[i].finish_verify()?;
        }
        Ok(())
    }
}

/// Convenience driver: begin a speculative generation on every engine
/// (engine `i` decodes `prompts[i]`), drive them to completion with fused
/// verification, and return the per-request outputs in input order.
///
/// For per-request `max_new` (ragged deadlines), call
/// [`Engine::begin_speculative`] yourself, then
/// [`ContinuousScheduler::drive`] and [`Engine::take_output`] — and for
/// conversations that *arrive over time*, use
/// [`ContinuousScheduler::submit`] + [`ContinuousScheduler::tick`]
/// (continuous admission). This helper is the uniform-deadline,
/// all-present common case.
pub fn decode_speculative_batch(
    backend: &mut dyn ModelBackend,
    engines: &mut [Engine],
    prompts: &[Vec<i32>],
    max_new: usize,
    sched: &mut ContinuousScheduler,
) -> Result<Vec<GenOut>> {
    anyhow::ensure!(
        engines.len() == prompts.len(),
        "engines ({}) and prompts ({}) must pair up",
        engines.len(),
        prompts.len()
    );
    for (e, p) in engines.iter_mut().zip(prompts) {
        e.begin_speculative(backend, p, max_new)?;
    }
    sched.drive(backend, engines)?;
    engines.iter_mut().map(Engine::take_output).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::sim::SimBackend;
    use crate::config::RunConfig;
    use crate::util::SplitMix64;

    fn prompt(n: usize, seed: u64) -> Vec<i32> {
        let mut rng = SplitMix64::new(seed);
        let mut p = vec![1i32]; // BOS
        for _ in 1..n {
            p.push(rng.range(2, 512) as i32);
        }
        p
    }

    fn sequential(cfgs: &[RunConfig], prompts: &[Vec<i32>], max_new: usize, agree: u64)
        -> Vec<GenOut> {
        prompts
            .iter()
            .zip(cfgs)
            .map(|(p, cfg)| {
                let mut b = SimBackend::new(agree);
                let mut e = Engine::new(&b, cfg.clone());
                e.generate_speculative(&mut b, p, max_new).unwrap()
            })
            .collect()
    }

    fn batched(cfgs: &[RunConfig], prompts: &[Vec<i32>], max_new: usize, agree: u64,
               max_batch: usize) -> Vec<GenOut> {
        let mut b = SimBackend::new(agree);
        let mut engines: Vec<Engine> =
            cfgs.iter().map(|cfg| Engine::new(&b, cfg.clone())).collect();
        let cap = b.contract().cache_cap;
        let mut sched = ContinuousScheduler::new(max_batch, cap);
        decode_speculative_batch(&mut b, &mut engines, prompts, max_new, &mut sched).unwrap()
    }

    #[test]
    fn batched_matches_sequential_uniform_group() {
        let cfgs = vec![RunConfig::default(); 4];
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| prompt(10 + i * 3, 40 + i as u64)).collect();
        let seq = sequential(&cfgs, &prompts, 20, 85);
        let bat = batched(&cfgs, &prompts, 20, 85, 4);
        for (s, b) in seq.iter().zip(&bat) {
            assert_eq!(s.tokens, b.tokens, "batched tokens diverged");
            assert_eq!(s.accept_lens, b.accept_lens, "accept shape diverged");
            assert_eq!(s.teacher_calls, b.teacher_calls, "per-request call accounting");
        }
    }

    #[test]
    fn batched_matches_sequential_ragged_budgets() {
        // mixed tree budgets -> mixed padded variants within one fused
        // launch (the ragged-batch case of the batching contract)
        let mut cfgs = Vec::new();
        for budget in [1usize, 5, 16, 40] {
            let mut c = RunConfig::default();
            c.tree.budget = budget;
            cfgs.push(c);
        }
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| prompt(8 + i * 7, 60 + i as u64)).collect();
        let seq = sequential(&cfgs, &prompts, 16, 90);
        let bat = batched(&cfgs, &prompts, 16, 90, 4);
        for (s, b) in seq.iter().zip(&bat) {
            assert_eq!(s.tokens, b.tokens);
            assert_eq!(s.accept_lens, b.accept_lens);
        }
    }

    #[test]
    fn scheduler_amortizes_teacher_launches() {
        let cfgs = vec![RunConfig::default(); 4];
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| prompt(12, 70 + i as u64)).collect();

        let mut b_seq = SimBackend::new(90);
        for (p, cfg) in prompts.iter().zip(&cfgs) {
            let mut e = Engine::new(&b_seq, cfg.clone());
            e.generate_speculative(&mut b_seq, p, 16).unwrap();
        }
        let seq_launches = b_seq.teacher_calls;

        // synchronous path: one full-width fused launch per round, so
        // fusion amortizes launches by at least the strict 2x the
        // original contract promised
        let mut b_bat = SimBackend::new(90);
        let mut engines: Vec<Engine> =
            cfgs.iter().map(|cfg| Engine::new(&b_bat, cfg.clone())).collect();
        let cap = b_bat.contract().cache_cap;
        let mut sched = ContinuousScheduler::new(4, cap);
        sched.set_pipelining(false);
        decode_speculative_batch(&mut b_bat, &mut engines, &prompts, 16, &mut sched).unwrap();
        let bat_launches = b_bat.teacher_calls;

        assert!(
            bat_launches * 2 < seq_launches,
            "fusion must amortize launches: {bat_launches} vs {seq_launches}"
        );

        // pipelined path (the default): waves are half-width, trading
        // some launch amortization for overlap — it must still issue
        // strictly fewer launches than sequential
        let mut b_pipe = SimBackend::new(90);
        let mut engines: Vec<Engine> =
            cfgs.iter().map(|cfg| Engine::new(&b_pipe, cfg.clone())).collect();
        let mut sched = ContinuousScheduler::new(4, cap);
        assert!(sched.pipelining(), "pipelining must default on");
        decode_speculative_batch(&mut b_pipe, &mut engines, &prompts, 16, &mut sched).unwrap();
        assert!(
            b_pipe.teacher_calls < seq_launches,
            "pipelined fusion must still amortize launches: {} vs {seq_launches}",
            b_pipe.teacher_calls
        );
    }

    #[test]
    fn pipelined_scheduler_matches_synchronous_reference() {
        // the bit-identity A/B: same traffic driven with pipelining on
        // and off must produce identical tokens, accept shapes and
        // per-request call accounting (ragged deadlines force mid-drive
        // retirement while a launch is in flight)
        let cfgs = vec![RunConfig::default(); 4];
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| prompt(9 + i * 4, 300 + i as u64)).collect();
        let deadlines = [3usize, 17, 9, 14];

        let run = |pipelining: bool| -> Vec<GenOut> {
            let mut bk = SimBackend::new(87);
            let mut engines: Vec<Engine> =
                cfgs.iter().map(|cfg| Engine::new(&bk, cfg.clone())).collect();
            let cap = bk.contract().cache_cap;
            let mut sched = ContinuousScheduler::new(4, cap);
            sched.set_pipelining(pipelining);
            for (e, (p, m)) in engines.iter_mut().zip(prompts.iter().zip(deadlines)) {
                e.begin_speculative(&mut bk, p, m).unwrap();
            }
            sched.drive(&mut bk, &mut engines).unwrap();
            engines.iter_mut().map(|e| e.take_output().unwrap()).collect()
        };

        let sync = run(false);
        let pipe = run(true);
        for (s, p) in sync.iter().zip(&pipe) {
            assert_eq!(s.tokens, p.tokens, "pipelined tokens diverged");
            assert_eq!(s.accept_lens, p.accept_lens, "accept shape diverged");
            assert_eq!(s.teacher_calls, p.teacher_calls, "per-request call accounting");
        }
    }

    #[test]
    fn drive_with_no_inflight_generations_is_a_noop() {
        let b = SimBackend::new(90);
        let mut engines = vec![Engine::new(&b, RunConfig::default())];
        let cap = b.contract().cache_cap;
        let mut sched = ContinuousScheduler::new(2, cap);
        let mut b = b;
        sched.drive(&mut b, &mut engines).unwrap();
        assert!(engines[0].take_output().is_err(), "nothing was in flight");
    }

    #[test]
    fn singleton_batches_equal_plain_generation() {
        // max_batch = 1 drives each request through the fused path alone;
        // output must still equal generate_speculative exactly.
        let cfgs = vec![RunConfig::default(); 2];
        let prompts = vec![prompt(9, 91), prompt(14, 92)];
        let seq = sequential(&cfgs, &prompts, 12, 80);
        let bat = batched(&cfgs, &prompts, 12, 80, 1);
        for (s, b) in seq.iter().zip(&bat) {
            assert_eq!(s.tokens, b.tokens);
        }
    }

    #[test]
    #[should_panic(expected = "max_batch must be >= 1")]
    fn zero_width_scheduler_is_rejected() {
        let _ = ContinuousScheduler::new(0, 64);
    }

    #[test]
    fn continuous_admission_refills_straggler_slots() {
        // 2 slots, 4 conversations, one a 1-token straggler: the queue
        // must refill the freed slot without restarting the group, every
        // output bit-identical to sequential, and the scheduler stats
        // must account every admission and retirement.
        let agree = 85u64;
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| prompt(8 + i * 3, 700 + i as u64)).collect();
        let deadlines = [1usize, 18, 18, 12];

        let seq: Vec<GenOut> = prompts
            .iter()
            .zip(deadlines)
            .map(|(p, m)| {
                let mut b = SimBackend::new(agree);
                let mut e = Engine::new(&b, RunConfig::default());
                e.generate_speculative(&mut b, p, m).unwrap()
            })
            .collect();

        let mut bk = SimBackend::new(agree);
        let mut engines: Vec<Engine> =
            (0..2).map(|_| Engine::new(&bk, RunConfig::default())).collect();
        let cap = bk.contract().cache_cap;
        let mut sched = ContinuousScheduler::new(2, cap);
        for (i, (p, m)) in prompts.iter().zip(deadlines).enumerate() {
            sched.submit(SlotRequest {
                id: i as u64,
                prompt: p.clone(),
                max_new: m,
                cfg: None,
                slo: None,
            });
        }
        let mut outs: Vec<Option<GenOut>> = (0..4).map(|_| None).collect();
        sched
            .run_to_idle(&mut bk, &mut engines, &mut |c: Completion| {
                outs[c.id as usize] = Some(c.out);
                Disposition::Release
            })
            .unwrap();

        for (i, s) in seq.iter().enumerate() {
            let got = outs[i].as_ref().expect("every conversation completes");
            assert_eq!(got.tokens, s.tokens, "conversation {i} diverged");
            assert_eq!(got.accept_lens, s.accept_lens);
        }
        assert_eq!(sched.stats.submitted, 4);
        assert_eq!(sched.stats.admitted, 4);
        assert_eq!(sched.stats.retired, 4);
        assert!(sched.is_idle());
        assert!(sched.stats.fused_launches > 0);
    }

    #[test]
    fn tick_on_idle_scheduler_is_a_noop() {
        let mut b = SimBackend::new(90);
        let cap = b.contract().cache_cap;
        let mut engines = vec![Engine::new(&b, RunConfig::default())];
        let mut sched = ContinuousScheduler::new(1, cap);
        sched
            .tick(&mut b, &mut engines, &mut |_c| Disposition::Release)
            .unwrap();
        assert!(sched.is_idle());
        assert_eq!(sched.stats.retired, 0);
        assert_eq!(sched.current_tick(), 1);
    }

    #[test]
    fn per_request_config_is_applied_at_admission() {
        // a request carrying its own RunConfig must decode exactly like a
        // fresh engine built with that config, even though the slot
        // engine was constructed (and previously used) with another one.
        let agree = 90u64;
        let p = prompt(11, 900);
        let mut want_cfg = RunConfig::default();
        want_cfg.tree.budget = 3;
        want_cfg.tree.depth_max = 4;
        // a cache-strategy change must rebuild the slot's managed caches
        want_cfg.cache_strategy = crate::config::CacheStrategy::DeepCopy;

        let mut rb = SimBackend::new(agree);
        let mut re = Engine::new(&rb, want_cfg.clone());
        let want = re.generate_speculative(&mut rb, &p, 16).unwrap();

        let mut bk = SimBackend::new(agree);
        let mut engines = vec![Engine::new(&bk, RunConfig::default())];
        // burn a first conversation under the slot's default config
        engines[0]
            .generate_speculative(&mut bk, &prompt(7, 901), 6)
            .unwrap();
        let cap = bk.contract().cache_cap;
        let mut sched = ContinuousScheduler::new(1, cap);
        sched.submit(SlotRequest { id: 0, prompt: p, max_new: 16, cfg: Some(want_cfg), slo: None });
        let mut got: Option<GenOut> = None;
        sched
            .run_to_idle(&mut bk, &mut engines, &mut |c: Completion| {
                got = Some(c.out);
                Disposition::Release
            })
            .unwrap();
        let got = got.unwrap();
        assert_eq!(got.tokens, want.tokens);
        assert_eq!(got.accept_lens, want.accept_lens);
        assert_eq!(
            got.teacher_cache, want.teacher_cache,
            "cache strategy change must rebuild the slot caches"
        );
        assert!(got.teacher_cache.replicate_bytes > 0, "DeepCopy must replicate");
    }

    #[test]
    fn slo_policy_validates_targets() {
        assert!(SloPolicy { target_ms: 5.0, action: SloAction::Shed }.validate().is_ok());
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let err = SloPolicy { target_ms: bad, action: SloAction::Queue }
                .validate()
                .unwrap_err()
                .to_string();
            assert!(err.contains("--slo-ms"), "error must name the flag: {err}");
        }
        assert_eq!(SloAction::parse("shed").unwrap(), SloAction::Shed);
        assert_eq!(SloAction::parse("queue").unwrap(), SloAction::Queue);
        assert!(SloAction::parse("drop").is_err());
        assert_eq!(SloAction::Shed.as_str(), "shed");
    }

    #[test]
    fn frozen_clock_never_sheds() {
        // no advance_clock call => deadlines can never expire, even with
        // an aggressive Shed policy: the no-SLO/no-clock path is inert.
        let mut bk = SimBackend::new(90);
        let mut engines = vec![Engine::new(&bk, RunConfig::default())];
        let cap = bk.contract().cache_cap;
        let mut sched = ContinuousScheduler::new(1, cap);
        for i in 0..3u64 {
            sched.submit(SlotRequest {
                id: i,
                prompt: prompt(8, 4400 + i),
                max_new: 4,
                cfg: None,
                slo: Some(SloPolicy { target_ms: 0.001, action: SloAction::Shed }),
            });
        }
        let mut done = 0usize;
        sched
            .run_to_idle(&mut bk, &mut engines, &mut |_c| {
                done += 1;
                Disposition::Release
            })
            .unwrap();
        assert_eq!(done, 3, "every request completes when the clock is frozen");
        assert_eq!(sched.stats.shed, 0);
        assert!(sched.drain_shed().is_empty());
    }

    #[test]
    fn expired_shed_requests_are_dropped_with_typed_notices() {
        // one slot, three submissions: the first is admitted immediately;
        // the other two wait. Advancing the clock past their target must
        // shed exactly the queued ones, each with a ShedNotice.
        let mut bk = SimBackend::new(90);
        let mut engines = vec![Engine::new(&bk, RunConfig::default())];
        let cap = bk.contract().cache_cap;
        let mut sched = ContinuousScheduler::new(1, cap);
        for i in 0..3u64 {
            sched.submit(SlotRequest {
                id: i,
                prompt: prompt(8, 4500 + i),
                max_new: 6,
                cfg: None,
                slo: Some(SloPolicy { target_ms: 10.0, action: SloAction::Shed }),
            });
        }
        // first tick admits request 0 (clock at 0 — nothing expired)
        let mut done: Vec<u64> = Vec::new();
        sched
            .tick(&mut bk, &mut engines, &mut |c| {
                done.push(c.id);
                Disposition::Release
            })
            .unwrap();
        sched.advance_clock(50.0);
        sched
            .run_to_idle(&mut bk, &mut engines, &mut |c| {
                done.push(c.id);
                Disposition::Release
            })
            .unwrap();
        assert_eq!(done, vec![0], "only the admitted request completes");
        assert_eq!(sched.stats.shed, 2);
        let shed = sched.drain_shed();
        assert_eq!(shed.len(), 2);
        let ids: Vec<u64> = shed.iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![1, 2]);
        for s in &shed {
            assert!(s.waited_ms > s.target_ms, "shed only past the target");
            assert_eq!(s.target_ms, 10.0);
        }
        assert!(sched.drain_shed().is_empty(), "drain empties the notices");
        assert!(sched.is_idle());
    }

    #[test]
    fn queue_policy_requests_survive_deadline_expiry() {
        let mut bk = SimBackend::new(90);
        let mut engines = vec![Engine::new(&bk, RunConfig::default())];
        let cap = bk.contract().cache_cap;
        let mut sched = ContinuousScheduler::new(1, cap);
        for i in 0..3u64 {
            sched.submit(SlotRequest {
                id: i,
                prompt: prompt(8, 4600 + i),
                max_new: 4,
                cfg: None,
                slo: Some(SloPolicy { target_ms: 0.5, action: SloAction::Queue }),
            });
        }
        sched.advance_clock(100.0); // everything long past its target
        let mut done: Vec<u64> = Vec::new();
        sched
            .run_to_idle(&mut bk, &mut engines, &mut |c| {
                done.push(c.id);
                assert!(c.slo.is_some(), "completion echoes the SLO policy");
                Disposition::Release
            })
            .unwrap();
        assert_eq!(done, vec![0, 1, 2], "Queue action keeps FIFO order, drops nothing");
        assert_eq!(sched.stats.shed, 0);
    }

    #[test]
    fn completion_timeline_includes_submit_tick() {
        let mut bk = SimBackend::new(90);
        let mut engines = vec![Engine::new(&bk, RunConfig::default())];
        let cap = bk.contract().cache_cap;
        let mut sched = ContinuousScheduler::new(1, cap);
        // run a first request so tick_now > 0 when the second is submitted
        sched.submit(SlotRequest {
            id: 0,
            prompt: prompt(8, 4700),
            max_new: 3,
            cfg: None,
            slo: None,
        });
        sched
            .run_to_idle(&mut bk, &mut engines, &mut |_c| Disposition::Release)
            .unwrap();
        let submit_at = sched.current_tick();
        assert!(submit_at > 0);
        sched.submit(SlotRequest {
            id: 1,
            prompt: prompt(8, 4701),
            max_new: 3,
            cfg: None,
            slo: None,
        });
        let mut seen = false;
        sched
            .run_to_idle(&mut bk, &mut engines, &mut |c| {
                assert_eq!(c.submitted_tick, submit_at);
                assert!(c.admitted_tick >= c.submitted_tick);
                assert!(c.finished_tick >= c.admitted_tick);
                assert!(c.slo.is_none());
                seen = true;
                Disposition::Release
            })
            .unwrap();
        assert!(seen);
    }
}
