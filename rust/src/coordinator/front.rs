//! The coordinator front end: admission, consistent-hash routing and
//! event aggregation over N engine workers.
//!
//! This is the other half of the serving split whose worker side lives
//! in [`crate::coordinator::worker`]: a [`Coordinator`] owns one
//! command channel per worker plus a single merged event channel, all
//! typed [`crate::rpc`] channels whose codec is a type parameter
//! (default [`JsonCodec`]). It shares no memory with its workers —
//! conversations go out as [`wire::Submit`]/[`wire::Resume`] commands,
//! tokens come back as [`wire::TokenDelta`] streams.
//!
//! # Routing
//!
//! Conversations shard by **consistent hash** of the conversation id
//! over a [`HashRing`] with virtual replicas — not `id % workers` — so
//! a conversation's home worker is a stable function of its id alone,
//! and changing the worker count moves only `~1/N` of the id space.
//! Both the channel-RPC path here and the direct-drive workload runner
//! ([`crate::coordinator::run_workload`]) route through the same ring,
//! so a conversation lands on the same shard in either serving mode.
//!
//! # Shutdown and drain
//!
//! [`Coordinator::shutdown`] drops every command sender — channel
//! hangup **is** the shutdown signal; there is no poison message to
//! race with — then keeps draining events until each worker's final
//! [`wire::WorkerStats`] (`is_final: true`) arrives, and only then
//! joins the threads. The final stats carry whatever shed notices the
//! worker's scheduler still held when it aborted, so sheds raised after
//! the coordinator stopped reading per-tick events still reach the
//! aggregated [`ShutdownReport`] instead of vanishing with the worker.

use crate::coordinator::batch::{SchedulerStats, ShedNotice as SchedShedNotice, SloPolicy};
use crate::coordinator::runner::BackendSpec;
use crate::coordinator::worker::{run_worker, WorkerConfig};
use crate::config::RunConfig;
use crate::rpc::envelope as wire;
use crate::rpc::{wire_channel, ChannelError, Codec, Envelope, JsonCodec, WireReceiver, WireSender};
use crate::util::rng::{splitmix64, SplitMix64};
use crate::workload::TraceRequest;
use anyhow::{bail, ensure, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::thread::JoinHandle;

/// Virtual replicas per worker on the [`HashRing`]. More replicas
/// smooth the shard sizes; 64 keeps the spread within a few percent at
/// the worker counts this crate serves (1–16).
const RING_REPLICAS: usize = 64;

/// A consistent-hash ring: each worker owns [`RING_REPLICAS`] pseudo-
/// random points on the `u64` circle, and an id routes to the owner of
/// the first point at or after its hash (wrapping). Deterministic —
/// the points depend only on the worker count.
#[derive(Clone, Debug)]
pub struct HashRing {
    /// `(point, rank)` pairs sorted by point.
    points: Vec<(u64, usize)>,
}

impl HashRing {
    /// Build the ring for `workers` ranks (`workers >= 1`).
    pub fn new(workers: usize) -> Self {
        assert!(workers >= 1, "a hash ring needs at least one worker");
        let mut points = Vec::with_capacity(workers * RING_REPLICAS);
        for rank in 0..workers {
            let mut rng = SplitMix64::new(0x9e37_79b9_7f4a_7c15 ^ rank as u64);
            for _ in 0..RING_REPLICAS {
                points.push((rng.next_u64(), rank));
            }
        }
        points.sort_unstable();
        Self { points }
    }

    /// The rank serving conversation `id`.
    pub fn route(&self, id: u64) -> usize {
        let h = splitmix64(id);
        let i = self.points.partition_point(|&(p, _)| p < h);
        if i < self.points.len() {
            self.points[i].1
        } else {
            self.points[0].1
        }
    }

    /// Number of ranks on the ring.
    pub fn workers(&self) -> usize {
        self.points.iter().map(|&(_, r)| r).max().map_or(0, |r| r + 1)
    }
}

/// Configuration of a coordinator/worker serving topology.
#[derive(Clone, Debug)]
pub struct FrontConfig {
    /// Number of engine workers (threads). `1` reproduces the
    /// single-worker path bit-identically.
    pub workers: usize,
    /// Engine slots (fused launch width) per worker.
    pub slots: usize,
    /// Backend each worker builds in-thread.
    pub backend: BackendSpec,
    /// Per-slot engine configuration.
    pub run: RunConfig,
    /// Virtual milliseconds charged per scheduler tick.
    pub tick_host_ms: f64,
    /// Virtual milliseconds charged per fused launch.
    pub launch_ms: f64,
    /// Command-channel depth per worker (backpressure bound).
    pub cmd_depth: usize,
    /// Merged event-channel depth (backpressure bound).
    pub event_depth: usize,
}

impl FrontConfig {
    /// A topology with the replay harness's default virtual-cost model
    /// and channel depths.
    pub fn new(workers: usize, slots: usize, backend: BackendSpec, run: RunConfig) -> Self {
        Self {
            workers,
            slots,
            backend,
            run,
            tick_host_ms: 1.0,
            launch_ms: 2.0,
            cmd_depth: 64,
            event_depth: 256,
        }
    }

    /// Reject degenerate topologies before any thread spawns.
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            bail!(
                "config contract: --workers must be >= 1 (got 0) — \
                 one worker is the single-engine serving path"
            );
        }
        if self.slots == 0 {
            bail!("config contract: --slots must be >= 1 (got 0) — one slot is sequential replay");
        }
        if self.cmd_depth == 0 || self.event_depth == 0 {
            bail!("config contract: channel depths must be >= 1 (a zero-depth channel deadlocks)");
        }
        self.run.validate()?;
        Ok(())
    }
}

/// Everything one conversation produced across its turns.
#[derive(Clone, Debug)]
pub struct ConversationOutcome {
    /// Conversation id from the trace.
    pub id: u64,
    /// The worker rank that served it (consistent-hash routed).
    pub rank: usize,
    /// All generated tokens, turns concatenated in order — the stream
    /// the client saw, reassembled from [`wire::TokenDelta`]s and
    /// verified against each turn's completion record.
    pub tokens: Vec<i32>,
    /// Per-turn completion records.
    pub turns: Vec<wire::TurnDone>,
    /// Present when the conversation was shed pre-admission instead of
    /// served.
    pub shed: Option<SchedShedNotice>,
}

/// Aggregated result of [`Coordinator::run_trace`].
#[derive(Clone, Debug)]
pub struct TraceOutcome {
    /// One outcome per trace request, in trace order.
    pub outcomes: Vec<ConversationOutcome>,
    /// Per-rank scheduler counters at the end of the batch (default for
    /// ranks the ring gave no conversations).
    pub stats: Vec<SchedulerStats>,
}

/// Aggregated result of [`Coordinator::shutdown`].
#[derive(Clone, Debug)]
pub struct ShutdownReport {
    /// Final per-rank scheduler counters (from the drain handshake).
    pub stats: Vec<SchedulerStats>,
    /// Shed notices still undrained when workers aborted — raised after
    /// the coordinator stopped reading per-tick events, surfaced here
    /// instead of being dropped (`(rank, notice)` pairs).
    pub undrained_shed: Vec<(usize, SchedShedNotice)>,
    /// Per-rank failure message, if the worker exited with an error.
    pub errors: Vec<Option<String>>,
}

impl ShutdownReport {
    /// Total sheds across ranks, served batches and undrained remainder
    /// alike.
    pub fn total_shed(&self) -> u64 {
        self.stats.iter().map(|s| s.shed).sum()
    }
}

/// Per-conversation bookkeeping during [`Coordinator::run_trace`].
struct ConvState {
    rank: usize,
    max_new: usize,
    /// Tokens reassembled from deltas, per turn index.
    streamed: Vec<Vec<i32>>,
    turns: Vec<wire::TurnDone>,
    shed: Option<SchedShedNotice>,
    released: bool,
}

/// The routing front end over N engine workers (see module docs). The
/// codec type parameter picks the wire format of every channel in the
/// topology; [`JsonCodec`] is the default.
pub struct Coordinator<C: Codec = JsonCodec> {
    cmd: Vec<WireSender<Envelope, C>>,
    events: WireReceiver<Envelope, C>,
    handles: Vec<JoinHandle<()>>,
    ring: HashRing,
    /// Events drained by [`Coordinator::pump`] while a command send was
    /// waiting for channel capacity, replayed before live receives.
    buffered: VecDeque<Envelope>,
}

impl<C: Codec> Coordinator<C> {
    /// Validate the topology, spawn its worker threads and connect the
    /// channels. Workers build their backends lazily on their own
    /// threads; a backend that fails to build reports through its final
    /// stats message, not a panic.
    pub fn start(cfg: &FrontConfig) -> Result<Self> {
        cfg.validate()?;
        let (event_tx, events) = wire_channel::<Envelope, C>(cfg.event_depth);
        let mut cmd = Vec::with_capacity(cfg.workers);
        let mut handles = Vec::with_capacity(cfg.workers);
        for rank in 0..cfg.workers {
            let (cmd_tx, cmd_rx) = wire_channel::<Envelope, C>(cfg.cmd_depth);
            let wcfg = WorkerConfig {
                rank,
                slots: cfg.slots,
                backend: cfg.backend.clone(),
                run: cfg.run.clone(),
                tick_host_ms: cfg.tick_host_ms,
                launch_ms: cfg.launch_ms,
            };
            let worker_events = event_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("engine-worker-{rank}"))
                .spawn(move || run_worker::<C>(wcfg, cmd_rx, worker_events))
                .with_context(|| format!("spawning engine worker {rank}"))?;
            cmd.push(cmd_tx);
            handles.push(handle);
        }
        // The coordinator holds no event sender: once every worker
        // exits, `events.recv()` reports hangup instead of blocking.
        drop(event_tx);
        Ok(Self { cmd, events, handles, ring: HashRing::new(cfg.workers), buffered: VecDeque::new() })
    }

    /// Number of workers in the topology.
    pub fn world_size(&self) -> usize {
        self.cmd.len()
    }

    /// The rank that serves conversation `id` (consistent hash).
    pub fn route(&self, id: u64) -> usize {
        self.ring.route(id)
    }

    /// Serve one trace as a batch: route every request to its shard,
    /// drive `turns` turns per conversation (deterministic follow-up
    /// prompts, park/resume across turns), and reassemble each
    /// conversation's token stream. Returns outcomes in trace order.
    ///
    /// The token stream of every conversation is a function of the
    /// trace alone — independent of the worker count — because each
    /// conversation decodes on exactly one worker and the per-worker
    /// replay protocol is deterministic (see `coordinator::worker`).
    pub fn run_trace(
        &mut self,
        trace: &[TraceRequest],
        slo: Option<SloPolicy>,
        turns: usize,
    ) -> Result<TraceOutcome> {
        ensure!(
            turns >= 1,
            "config contract: --turns must be >= 1 (got 0) — a conversation has at least one turn"
        );
        ensure!(!trace.is_empty(), "config contract: --requests must be >= 1 (an empty trace replays nothing)");
        let world = self.world_size();
        // Shard in trace order; per-rank arrival order is inherited.
        let mut per_rank: Vec<Vec<wire::Submit>> = vec![Vec::new(); world];
        let mut st: HashMap<u64, ConvState> = HashMap::new();
        for r in trace {
            let rank = self.ring.route(r.id);
            ensure!(
                st.insert(
                    r.id,
                    ConvState {
                        rank,
                        max_new: r.max_new,
                        streamed: Vec::new(),
                        turns: Vec::new(),
                        shed: None,
                        released: false,
                    },
                )
                .is_none(),
                "duplicate conversation id {} in trace",
                r.id
            );
            per_rank[rank].push(wire::Submit {
                id: r.id,
                prompt: r.prompt.clone(),
                max_new: r.max_new,
                arrival_ms: r.arrival_ms,
                kind: wire::RequestKind::Ea,
                park_on_complete: turns > 1,
                slo,
                last: false,
                isolated: false,
            });
        }
        let mut participants = 0usize;
        for shard in per_rank.iter_mut() {
            if let Some(last) = shard.last_mut() {
                last.last = true;
                participants += 1;
            }
        }
        // Submit every shard. Workers buffer until their `last` marker,
        // so cross-rank interleaving is irrelevant to the outcome; the
        // pump inside `send_cmd` keeps draining events so a worker that
        // already started replaying cannot deadlock us.
        for (rank, shard) in per_rank.iter().enumerate() {
            for s in shard {
                self.send_cmd(rank, &Envelope::Submit(s.clone()))?;
            }
        }
        // Event loop: a batch is over when every participating rank has
        // sent its end-of-batch (non-final) stats report, which each
        // worker emits strictly after its last completion and shed
        // notice of the batch.
        let mut stats: Vec<Option<SchedulerStats>> = vec![None; world];
        let mut pending = participants;
        while pending > 0 {
            match self.next_event()? {
                Envelope::TokenDelta(d) => {
                    let c = st
                        .get_mut(&d.id)
                        .with_context(|| format!("token delta for unknown conversation {}", d.id))?;
                    while c.streamed.len() <= d.turn {
                        c.streamed.push(Vec::new());
                    }
                    c.streamed[d.turn].extend_from_slice(&d.tokens);
                }
                Envelope::Park(p) => {
                    let id = p.done.id;
                    let next_turn = p.done.turn + 1;
                    let (rank, prompt, max_new) = {
                        let c = st
                            .get_mut(&id)
                            .with_context(|| format!("park for unknown conversation {id}"))?;
                        Self::record_turn(c, p.done)?;
                        let ctx: Vec<i32> =
                            c.turns.iter().flat_map(|t| t.out.tokens.iter().copied()).collect();
                        (c.rank, followup_prompt(&ctx), c.max_new)
                    };
                    ensure!(next_turn < turns, "conversation {id} parked after its final turn");
                    let resume = wire::Resume {
                        id,
                        prompt,
                        max_new,
                        park_on_complete: next_turn < turns - 1,
                    };
                    self.send_cmd(rank, &Envelope::Resume(resume))?;
                }
                Envelope::Completion(cm) => {
                    let id = cm.done.id;
                    let c = st
                        .get_mut(&id)
                        .with_context(|| format!("completion for unknown conversation {id}"))?;
                    Self::record_turn(c, cm.done)?;
                    c.released = true;
                }
                Envelope::ShedNotice(sn) => {
                    let c = st.get_mut(&sn.notice.id).with_context(|| {
                        format!("shed notice for unknown conversation {}", sn.notice.id)
                    })?;
                    c.shed = Some(sn.notice);
                }
                Envelope::WorkerStats(ws) if !ws.is_final => {
                    stats[ws.rank] = Some(ws.stats);
                    pending -= 1;
                }
                Envelope::WorkerStats(ws) => {
                    bail!(
                        "worker {} exited mid-batch: {}",
                        ws.rank,
                        ws.error.as_deref().unwrap_or("shutdown")
                    );
                }
                other => bail!("protocol violation: '{}' on the event channel", other.kind_str()),
            }
        }
        let outcomes = trace
            .iter()
            .map(|r| {
                let Some(c) = st.remove(&r.id) else {
                    bail!("trace id {} was never registered", r.id);
                };
                ensure!(
                    c.released || c.shed.is_some(),
                    "conversation {} reached no terminal state (batch reported complete)",
                    r.id
                );
                ensure!(
                    c.shed.is_none() || c.turns.is_empty(),
                    "conversation {} both served and shed",
                    r.id
                );
                Ok(ConversationOutcome {
                    id: r.id,
                    rank: c.rank,
                    tokens: c.turns.iter().flat_map(|t| t.out.tokens.iter().copied()).collect(),
                    turns: c.turns,
                    shed: c.shed,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TraceOutcome {
            outcomes,
            stats: stats.into_iter().map(Option::unwrap_or_default).collect(),
        })
    }

    /// Drop the command channels (hangup is the shutdown signal), drain
    /// events until every worker's final stats handshake arrives, then
    /// join the threads.
    pub fn shutdown(mut self) -> Result<ShutdownReport> {
        self.cmd.clear();
        let world = self.handles.len();
        let mut stats = vec![SchedulerStats::default(); world];
        let mut errors: Vec<Option<String>> = vec![None; world];
        let mut undrained: Vec<(usize, SchedShedNotice)> = Vec::new();
        let mut finals = 0usize;
        while finals < world {
            let env = match self.buffered.pop_front() {
                Some(e) => e,
                None => match self.events.recv() {
                    Ok(e) => e,
                    Err(ChannelError::Disconnected) => break,
                    Err(e) => return Err(e).context("draining events during shutdown"),
                },
            };
            match env {
                Envelope::WorkerStats(ws) if ws.is_final => {
                    undrained.extend(ws.shed.into_iter().map(|n| (ws.rank, n)));
                    stats[ws.rank] = ws.stats;
                    errors[ws.rank] = ws.error;
                    finals += 1;
                }
                Envelope::ShedNotice(sn) => undrained.push((sn.rank, sn.notice)),
                // Late deltas/completions of an interrupted batch: the
                // run that wanted them already returned.
                _ => {}
            }
        }
        for h in self.handles.drain(..) {
            h.join().map_err(|_| anyhow::anyhow!("an engine worker panicked"))?;
        }
        ensure!(
            finals == world,
            "only {finals}/{world} workers completed the shutdown handshake"
        );
        Ok(ShutdownReport { stats, undrained_shed: undrained, errors })
    }

    /// Verify a turn's reassembled delta stream against its completion
    /// record, then file the record.
    fn record_turn(c: &mut ConvState, td: wire::TurnDone) -> Result<()> {
        ensure!(
            td.turn == c.turns.len(),
            "conversation {}: turn {} completed out of order (expected {})",
            td.id,
            td.turn,
            c.turns.len()
        );
        let streamed = c.streamed.get(td.turn).map_or(&[][..], Vec::as_slice);
        ensure!(
            streamed == td.out.tokens.as_slice(),
            "conversation {}: turn {} token stream diverged from its completion record",
            td.id,
            td.turn
        );
        c.turns.push(td);
        Ok(())
    }

    /// Send a command, pumping the event channel while the command
    /// channel is at capacity (a blocking send from both ends of two
    /// bounded channels is the classic two-party deadlock).
    fn send_cmd(&mut self, rank: usize, env: &Envelope) -> Result<()> {
        loop {
            match self.cmd[rank].try_send(env) {
                Ok(true) => return Ok(()),
                Ok(false) => {
                    self.pump()?;
                    std::thread::yield_now();
                }
                Err(e) => {
                    return Err(e).with_context(|| format!("command channel to worker {rank}"))
                }
            }
        }
    }

    /// Drain every queued event into the replay buffer without blocking.
    fn pump(&mut self) -> Result<()> {
        while let Some(e) = self.events.try_recv()? {
            self.buffered.push_back(e);
        }
        Ok(())
    }

    /// Next event: replay the pump buffer first, then receive live.
    fn next_event(&mut self) -> Result<Envelope> {
        if let Some(e) = self.buffered.pop_front() {
            return Ok(e);
        }
        self.events.recv().context("waiting for worker events")
    }
}

/// The deterministic follow-up prompt of a multi-turn conversation: a
/// pure function of the tokens generated so far, so every topology
/// (and the sequential reference) asks the same questions.
pub fn followup_prompt(generated: &[i32]) -> Vec<i32> {
    match generated {
        [] => vec![1],
        [only] => vec![*only, *only],
        [.., a, b] => vec![*b, *a],
    }
}
