//! The decode engine: prefill, baseline greedy decoding and EAGLE-style
//! tree-speculative decoding over any [`crate::backend::ModelBackend`].

pub mod decode;
pub mod output;

pub use decode::{Engine, ParkedConversation, VerifyPayload};
pub use output::GenOut;
