//! The decode engine: wires backend calls, the cache manager, tree
//! tensorization, mask construction and acceptance into the paper's
//! decode loop.
//!
//! Round structure (speculative path):
//!
//! ```text
//!  r0 = argmax(pending_logits)            # the pending root token
//!  draft chain-refresh over newly committed tokens (incl. r0)
//!  tree expansion: depth-synchronous draft calls, top-k per node,
//!                  global top-M by cumulative draft log-prob
//!  tensorize (+ §3.2 invariants)  ->  tree mask  ->  teacher verify
//!  acceptance walk (greedy/stochastic)  ->  bonus token
//!  commit: teacher cache adopts [root] + accepted path rows
//! ```
//!
//! Every round commits `1 + accept_L` tokens against exactly one teacher
//! call; under greedy acceptance the committed text is bit-identical to
//! teacher-only greedy decoding (asserted in tests — the paper's "matched
//! decoding configuration" claim).
//!
//! # Backend decoupling and the split round
//!
//! The engine holds **no backend reference**: [`Engine::new`] reads the
//! shape [`Contract`] once, and every decoding entry point takes
//! `&mut dyn ModelBackend` per call. This is what makes multi-request
//! residency possible — a coordinator worker owns *one* backend and `B`
//! engines (one per resident conversation), and the
//! [`crate::coordinator::ContinuousScheduler`] fuses their verification
//! steps into one launch — and, under continuous admission, swaps which
//! conversation a slot engine serves at any tick ([`Engine::reset`] /
//! [`Engine::set_config`] + [`Engine::begin_speculative`]).
//!
//! For that, the speculative round is split into externally drivable
//! phases (the single-request [`Engine::generate_speculative`] is built
//! on exactly the same pieces, so the two paths cannot drift):
//!
//! ```text
//!  begin_speculative(backend, prompt, max_new)     # prefill
//!  while needs_more():
//!      prepare_verify(backend)     # draft expand + tensorize + mask,
//!                                  # leaves a pending round
//!      -- either --
//!      (internal single-request teacher call)      # generate_speculative
//!      -- or --
//!      verify_payload() -> gathered by the scheduler into one fused
//!      launch; scatter_verify(fused, b) copies this request's rows back
//!      -- then --
//!      finish_verify()             # acceptance + commit (per-request)
//!  take_output() -> GenOut
//! ```
//!
//! Acceptance and commit stay strictly per-request; only the teacher
//! launch is shared. Batched decoding is therefore bit-identical to
//! sequential decoding (property-tested in `tests/batched.rs`).
//!
//! # Zero-allocation steady state
//!
//! After warmup, a speculative round performs no vocab- or cap-sized heap
//! allocation (asserted by `tests/alloc_regression.rs`):
//!
//! * backend outputs land in reusable [`StepScratch`] arenas — two draft
//!   scratches ping-pong across expansion depths because a frontier
//!   call's feature inputs are the *previous* call's hidden rows;
//! * `pending_logits`/`feat_last` are copied into fixed buffers instead
//!   of `.to_vec()`-cloned; the `uncharted` chain-refresh queue is a
//!   [`FeatRing`] with inline feature storage;
//! * masks come from the incremental [`MaskBuilder`] slots
//!   (`O(S * Δt + S * S)` per round instead of `O(S * (cap + S))`);
//! * commits use the prefix-relative [`ManagedCache::commit_path_tail`]
//!   fast path — no `(0..t).collect()` identity vector, no gather
//!   scratch;
//! * token/position/feature staging buffers and the candidate pool are
//!   engine fields reused across rounds, and [`Engine::reset`] restores a
//!   fresh-engine state *without* dropping any of these capacities, so
//!   the coordinator reuses warmed engines across conversations.

use crate::backend::{
    argmax, log_softmax_at, topk, KvSession, KvView, ModelBackend, ModuleRole, PlanError,
    SessionTicket, StepArgs,
};
use crate::cache::{pool_write, CachePools, KvGuard, KvStore, ManagedCache, PagedCache, PrefixMatch};
use crate::config::contract::NEG_INF;
use crate::config::{CacheLayout, CacheStrategy, CommitMode, Contract, Dims, ExecMode, RunConfig};
use crate::engine::output::{attention_distance_buckets, GenOut};
use crate::spec::{greedy_walk, select_children, stochastic_walk, AdaptiveBudget, Candidate};
use crate::tree::{MaskBuilder, MaskStream, SpecTree, Tensorized};
use crate::util::arena::{FeatRing, StepScratch};
use crate::util::stats::{AcceptPos, Histogram};
use crate::util::{SplitMix64, StageTimer};
use anyhow::{bail, Context, Result};
use crate::util::timer::Stopwatch;

/// Largest draft frontier evaluated in one call.
const FRONTIER_CAP: usize = 64;

/// Running statistics of one generation call.
#[derive(Default)]
struct RunStats {
    teacher_calls: u64,
    draft_calls: u64,
    rounds: u64,
    accept_lens: Vec<usize>,
    accept_pos: AcceptPos,
}

/// A prepared-but-uncommitted speculative round (between
/// [`Engine::prepare_verify`] and [`Engine::finish_verify`]).
struct RoundState {
    /// The pending root token riding along at depth 0.
    r0: i32,
    /// The speculative tree the draft expanded this round.
    tree: SpecTree,
    /// Its tensorized (padded, gather-safe) form.
    tens: Tensorized,
    /// Padded teacher variant holding the tree (`tens.s`).
    s_pad: usize,
    /// Committed teacher context length when the round was prepared.
    t_len: usize,
    /// Node budget offered this round (adaptive-budget bookkeeping).
    round_budget: usize,
    /// Whether `t_scratch` holds this round's teacher outputs (written by
    /// the internal verify step or by [`Engine::scatter_verify`]).
    verified: bool,
}

/// One in-flight generation (between [`Engine::begin_speculative`] and
/// [`Engine::take_output`]).
struct InFlight {
    stats: RunStats,
    out_tokens: Vec<i32>,
    prompt_len: usize,
    wall0: Stopwatch,
    max_new: usize,
    round: Option<RoundState>,
}

/// Borrowed view of a prepared round's verification inputs — what the
/// [`crate::coordinator::ContinuousScheduler`] gathers into one fused
/// launch.
pub struct VerifyPayload<'e> {
    /// `[s]` padded token ids of the tensorized tree.
    pub tokens: &'e [i32],
    /// `[s]` RoPE positions (committed length + node depth).
    pub positions: &'e [i32],
    /// `[s, cap + s]` additive tree mask.
    pub mask: &'e [f32],
    /// Live borrow of this request's committed-prefix teacher cache
    /// (flat buffers or a shared-pool block-table view — see
    /// [`KvGuard`]). The scheduler keeps the guards of a whole group
    /// alive across its fused launch, then drops them before any cache
    /// mutation.
    pub kv: KvGuard<'e>,
    /// Padded slot count (this request's compiled teacher variant).
    pub s: usize,
    /// Live tree slots (root + nodes); `live <= s`.
    pub live: usize,
    /// Committed teacher context length of this request (logical rows).
    pub ctx_len: usize,
    /// Resident-session ticket for `kv` (the engine's bound teacher
    /// session plus the cache's dirty watermark); `None` when the
    /// backend keeps no sessions or sessions are configured off — the
    /// fused launch then uploads the full view.
    pub session: Option<SessionTicket>,
}

/// A conversation lifted off its slot engine with all decode state
/// intact ([`Engine::park`]): both KV stores (for the paged layout, just
/// block tables — the rows stay in the shared pool), the pending
/// logits/feature rows, the chain-refresh queue, and every
/// config-derived stream (rng, adaptive budget, attention histogram).
/// [`Engine::resume`] restores it onto any engine sharing the same
/// pools, bit-identically to a conversation that never left its slot
/// (tested in `tests/paged.rs`).
pub struct ParkedConversation {
    cfg: RunConfig,
    t_cache: Box<dyn KvStore>,
    d_cache: Box<dyn KvStore>,
    pending_logits: Vec<f32>,
    feat_last: Vec<f32>,
    uncharted: FeatRing,
    rng: SplitMix64,
    adaptive: Option<AdaptiveBudget>,
    attn_hist: Histogram,
    d_cur: usize,
    history: Vec<i32>,
    block_feats: Vec<Vec<f32>>,
}

impl ParkedConversation {
    /// Bytes of KV memory the parked conversation keeps resident (mapped
    /// blocks for the paged layout, full buffers for flat).
    pub fn kv_bytes_resident(&self) -> u64 {
        self.t_cache.bytes_resident() + self.d_cache.bytes_resident()
    }
}

/// The decode engine: all per-conversation state (KV caches, scratch
/// arenas, mask slots, pending logits), with the model backend passed
/// into each call.
pub struct Engine {
    /// Run configuration (public: harnesses tweak and inspect it).
    pub cfg: RunConfig,
    contract: Contract,
    /// Per-worker KV block pools (shared across slot engines; unused by
    /// the flat layout but kept so a `set_config` layout switch can
    /// rebuild paged caches against the worker's pools).
    pools: CachePools,
    t_cache: Box<dyn KvStore>,
    d_cache: Box<dyn KvStore>,
    mb: MaskBuilder,
    /// Teacher step outputs (prefill, baseline decode, verification).
    t_scratch: StepScratch,
    /// Draft step outputs, ping-ponged across expansion depths: the
    /// frontier at depth d reads rows from `d_scratch[d_cur]` while the
    /// depth d+1 call writes `d_scratch[1 - d_cur]`.
    d_scratch: [StepScratch; 2],
    d_cur: usize,
    /// Teacher logits row predicting the next token (fixed vocab-sized
    /// buffer, copied into — never reallocated in steady state).
    pending_logits: Vec<f32>,
    /// Teacher feature of the last committed token (feat_prev of the next).
    feat_last: Vec<f32>,
    /// Committed tokens not yet present in the draft cache, with the
    /// feature of their *predecessor* position (EAGLE input contract).
    uncharted: FeatRing,
    /// Reusable step-staging buffers.
    tok_buf: Vec<i32>,
    pos_buf: Vec<i32>,
    feats_buf: Vec<f32>,
    /// Reusable candidate pool for tree expansion.
    cand_pool: Vec<Candidate>,
    /// Reusable accepted-tail buffer for prefix-relative commits.
    path_tail: Vec<usize>,
    /// Per-stage timers of the current generation (instrumented runs).
    pub timers: StageTimer,
    attn_hist: Histogram,
    rng: SplitMix64,
    /// Baseline runs skip all draft-side work.
    use_draft: bool,
    /// Adaptive budget controller (None when `cfg.adaptive_budget` is off).
    adaptive: Option<AdaptiveBudget>,
    /// Backend-resident teacher KV session bound to this slot (None:
    /// backend has no session support, or sessions configured off).
    t_session: Option<KvSession>,
    /// Backend-resident draft KV session bound to this slot.
    d_session: Option<KvSession>,
    /// Committed token at every logical row (prefix-sharing bookkeeping:
    /// the prefix index is keyed on this exact sequence). Maintained only
    /// while [`Engine::sharing_active`]; empty otherwise.
    history: Vec<i32>,
    /// Teacher feature at every committed block-end row
    /// (`block_feats[j]` = feature of row `(j + 1) * block_size - 1`) —
    /// the chain feature a partial prefill resumes from after adopting
    /// `j + 1` shared blocks. Maintained only while
    /// [`Engine::sharing_active`].
    block_feats: Vec<Vec<f32>>,
    /// The bound sessions mirror a *previous* conversation's cache (set
    /// by reset/park/resume/config changes): the next prefill re-syncs
    /// them wholesale before any step ships a delta ticket.
    sessions_stale: bool,
    /// The in-flight generation, when one is active.
    inflight: Option<InFlight>,
}

/// Copy a row into a reusable buffer without reallocating in steady state.
fn copy_into(dst: &mut Vec<f32>, src: &[f32]) {
    dst.clear();
    dst.extend_from_slice(src);
}

/// Build a cache of the requested layout: flat buffers, or a paged cache
/// drawing blocks from `pool`.
fn build_cache(
    layout: CacheLayout,
    dims: Dims,
    cap: usize,
    strategy: CacheStrategy,
    fast_reorder: bool,
    pool: &crate::cache::SharedPool,
) -> Box<dyn KvStore> {
    match layout {
        CacheLayout::Flat => Box::new(ManagedCache::new(dims, cap, strategy, fast_reorder)),
        CacheLayout::Paged => {
            Box::new(PagedCache::new(dims, cap, strategy, fast_reorder, pool.clone()))
        }
    }
}

/// Bind (or wholesale re-sync) one cache's backend-resident session.
/// `stale` → the bound mirror belongs to a previous conversation: rebind
/// from row 0, reusing its storage; an unknown-session answer (backend
/// swapped under the slot) falls through to a fresh bind. A backend
/// without session support leaves `slot` empty — callers then send no
/// tickets and the backend uploads full views.
fn ensure_session(
    backend: &mut dyn ModelBackend,
    role: ModuleRole,
    cache: &mut dyn KvStore,
    slot: &mut Option<KvSession>,
    stale: bool,
) -> Result<()> {
    let rows = cache.view_rows();
    if let Some(sess) = slot.as_ref() {
        if !stale {
            return Ok(()); // same conversation: tickets keep the mirror current
        }
        let res = {
            let guard = cache.kv_guard();
            backend.rebind_kv(sess, guard.view(), rows)
        };
        match res {
            Ok(()) => {
                cache.mark_synced();
                return Ok(());
            }
            Err(PlanError::UnknownSession { .. }) => {} // bind fresh below
            Err(e) => return Err(e.into()),
        }
    }
    let res = {
        let guard = cache.kv_guard();
        backend.bind_kv(role, guard.view(), rows)
    };
    match res {
        Ok(s) => {
            *slot = Some(s);
            cache.mark_synced();
        }
        Err(PlanError::SessionUnsupported { .. }) => {
            *slot = None;
        }
        Err(e) => return Err(e.into()),
    }
    Ok(())
}

impl Engine {
    /// Construct an engine for `backend`'s shape contract with its own
    /// (unshared) block pools. The backend is only *read* here (contract
    /// clone); every decoding call takes it again as `&mut`, so one
    /// backend can serve many engines. Workers that hold several resident
    /// slots should use [`Engine::with_pools`] so all slots draw from the
    /// same KV arenas.
    pub fn new(backend: &dyn ModelBackend, cfg: RunConfig) -> Self {
        let pools = CachePools::new(backend.contract());
        Self::with_pools(backend, cfg, &pools)
    }

    /// Construct an engine whose paged caches draw from the caller's
    /// shared per-worker [`CachePools`] (no-op for the flat layout, but
    /// the pools are retained for config-driven layout switches).
    pub fn with_pools(backend: &dyn ModelBackend, mut cfg: RunConfig, pools: &CachePools) -> Self {
        let contract = backend.contract().clone();
        // The verification call holds 1 root + M nodes; clamp M so it fits
        // the largest compiled variant (e.g. the paper's M=256 sweep point
        // runs as 255 nodes + root here).
        let max_nodes = contract.teacher_s.iter().copied().max().unwrap_or(8) - 1;
        cfg.tree.budget = cfg.tree.budget.min(max_nodes);
        let t_cache = build_cache(
            cfg.cache_layout,
            contract.teacher,
            contract.cache_cap,
            cfg.cache_strategy,
            cfg.fast_reorder,
            &pools.teacher,
        );
        let d_cache = build_cache(
            cfg.cache_layout,
            contract.draft,
            contract.cache_cap,
            cfg.cache_strategy,
            cfg.fast_reorder,
            &pools.draft,
        );
        let mb = MaskBuilder::new(contract.cache_cap);
        let timers = StageTimer::new(cfg.instrument);
        let rng = SplitMix64::new(cfg.seed ^ 0xE151);
        let adaptive = Self::make_adaptive(&cfg);
        let uncharted = FeatRing::with_capacity(contract.cache_cap, contract.feat_dim);
        Self {
            cfg,
            contract,
            pools: pools.clone(),
            t_cache,
            d_cache,
            mb,
            t_scratch: StepScratch::new(),
            d_scratch: [StepScratch::new(), StepScratch::new()],
            d_cur: 0,
            pending_logits: Vec::new(),
            feat_last: Vec::new(),
            uncharted,
            tok_buf: Vec::new(),
            pos_buf: Vec::new(),
            feats_buf: Vec::new(),
            cand_pool: Vec::new(),
            path_tail: Vec::new(),
            timers,
            attn_hist: attention_distance_buckets(),
            rng,
            use_draft: true,
            adaptive,
            t_session: None,
            d_session: None,
            history: Vec::new(),
            block_feats: Vec::new(),
            sessions_stale: true,
            inflight: None,
        }
    }

    /// Whether this engine tracks prefix-sharing state (token history,
    /// block-end features, index registration/adoption): sharing
    /// configured on, a speculative run, and no drafter-window truncation
    /// (a windowed drafter's cache rows depend on the window, so they are
    /// not safely shareable across configs).
    fn sharing_active(&self) -> bool {
        self.cfg.prefix_sharing && self.use_draft && self.cfg.draft_window.is_none()
    }

    /// Session ticket for the next step through `cache`: the bound
    /// session's id plus the cache's dirty watermark and readable rows.
    fn ticket(cache: &dyn KvStore, session: &Option<KvSession>) -> Option<SessionTicket> {
        session.as_ref().map(|s| SessionTicket {
            id: s.id,
            dirty_lo: cache.dirty_lo(),
            rows: cache.view_rows(),
        })
    }

    /// Bind or refresh the engine's backend-resident KV sessions (the
    /// *bind* phase of the plan → bind → execute protocol), called once
    /// per conversation turn at prefill:
    ///
    /// * sessions wanted (`cfg.kv_sessions` and the fused path — the
    ///   eager/debug path stays full-upload by the paper's two-mode
    ///   design): bind fresh sessions, or re-sync the existing ones
    ///   wholesale when they mirror a previous conversation
    ///   (`sessions_stale`) — an admission-boundary cost that reuses the
    ///   mirror storage ([`ModelBackend::rebind_kv`]);
    /// * backend without session support: noted once per conversation
    ///   (typed [`PlanError::SessionUnsupported`]), every step falls
    ///   back to full-view upload;
    /// * sessions configured off: any bound sessions are released.
    fn ensure_sessions(&mut self, backend: &mut dyn ModelBackend) -> Result<()> {
        let want = self.cfg.kv_sessions && self.cfg.mode == ExecMode::Fused;
        if !want {
            if let Some(s) = self.t_session.take() {
                backend.unbind_kv(s);
            }
            if let Some(s) = self.d_session.take() {
                backend.unbind_kv(s);
            }
            return Ok(());
        }
        let stale = self.sessions_stale;
        ensure_session(
            backend,
            ModuleRole::Teacher,
            self.t_cache.as_mut(),
            &mut self.t_session,
            stale,
        )?;
        ensure_session(
            backend,
            ModuleRole::Draft,
            self.d_cache.as_mut(),
            &mut self.d_session,
            stale,
        )?;
        self.sessions_stale = false;
        Ok(())
    }

    fn make_adaptive(cfg: &RunConfig) -> Option<AdaptiveBudget> {
        cfg.adaptive_budget.then(|| {
            // growth headroom up to the largest compiled tree variant
            let max = (cfg.tree.budget * 4).clamp(cfg.tree.budget, 255);
            let a = AdaptiveBudget::new(cfg.tree.budget, 4, max);
            if cfg.adaptive_occupancy {
                a.with_occupancy()
            } else {
                a
            }
        })
    }

    /// Feed the scheduler's occupancy signal (`live` decoding slots out
    /// of `slots` total) into this engine's adaptive controller. Inert
    /// unless the config enables both `adaptive_budget` and
    /// `adaptive_occupancy`, so the default serve path is untouched.
    pub fn note_occupancy(&mut self, live: usize, slots: usize) {
        if let Some(adaptive) = &mut self.adaptive {
            adaptive.observe_occupancy(live, slots);
        }
    }

    /// Current tree node budget (adaptive or configured).
    pub fn current_budget(&self) -> usize {
        self.adaptive.as_ref().map_or(self.cfg.tree.budget, AdaptiveBudget::budget)
    }

    /// Largest budget this configuration can ever use.
    fn max_budget(&self) -> usize {
        self.adaptive.as_ref().map_or(self.cfg.tree.budget, |a| a.max_budget)
    }

    /// Pre-execute every (role, mode, S) variant this config will touch,
    /// with dummy inputs. PJRT compiles modules lazily (~seconds per
    /// module for 13 MB HLO text); timed runs call this first so compile
    /// cost never lands inside a measured turn. Also brings every scratch
    /// arena to its high-water capacity.
    pub fn warmup(&mut self, backend: &mut dyn ModelBackend) -> Result<()> {
        let c = self.contract.clone();
        // Paged layout: reserve pool storage for one full-capacity
        // conversation per role so this engine's steady-state block
        // mapping never allocates (the zero-allocation contract,
        // asserted single-resident). A multi-slot worker's shared pool
        // instead grows to its combined-residency high-water mark the
        // first time peak load is reached, then stays allocation-free —
        // the warm-to-peak behaviour of every other arena.
        if self.cfg.cache_layout == CacheLayout::Paged {
            pool_write(&self.pools.teacher).ensure_headroom(c.cache_cap);
            pool_write(&self.pools.draft).ensure_headroom(c.cache_cap);
        }
        let kzero = vec![0.0f32; c.teacher.cache_elems(c.cache_cap)];
        // Any variant <= prefill_chunk can appear (prompt-tail chunks),
        // plus the tree-verification variant for the largest budget this
        // config can reach (adaptive growth included).
        let verify_s = c.teacher_variant(1 + self.max_budget())?;
        let mut teacher_sizes: Vec<usize> = c
            .teacher_s
            .iter()
            .copied()
            .filter(|s| *s <= c.prefill_chunk() || *s == verify_s)
            .collect();
        teacher_sizes.sort_unstable();
        teacher_sizes.dedup();
        for s in teacher_sizes {
            let tokens = vec![0i32; s];
            let positions = vec![0i32; s];
            let mask = vec![NEG_INF; s * (c.cache_cap + s)];
            backend.teacher_step(self.cfg.mode, StepArgs {
                tokens: &tokens,
                positions: &positions,
                mask: &mask,
                kv: KvView::flat(&kzero, &kzero, c.cache_cap),
                feats_in: None,
                probe: false,
                session: None,
            }, &mut self.t_scratch)?;
        }
        let dzero = vec![0.0f32; c.draft.cache_elems(c.cache_cap)];
        for &s in &c.draft_s {
            let tokens = vec![0i32; s];
            let positions = vec![0i32; s];
            let mask = vec![NEG_INF; s * (c.cache_cap + s)];
            let feats = vec![0.0f32; s * c.feat_dim];
            backend.draft_step(StepArgs {
                tokens: &tokens,
                positions: &positions,
                mask: &mask,
                kv: KvView::flat(&dzero, &dzero, c.cache_cap),
                feats_in: Some(&feats),
                probe: false,
                session: None,
            }, &mut self.d_scratch[0])?;
        }
        // Bring the second (ping-pong) draft scratch to capacity too.
        let d = c.draft;
        let s_max = c.max_draft_s();
        self.d_scratch[1].prepare(s_max, c.vocab, c.feat_dim, d.layers, d.heads, d.d_head, false);
        // Pre-create every incremental mask slot this config can reach and
        // pre-size the staging buffers: a rarer S variant appearing for
        // the first time mid-run must not allocate in a steady-state round.
        for &s in &c.teacher_s {
            if s <= c.prefill_chunk() || s == verify_s {
                self.mb.incremental(MaskStream::TeacherChain, s);
            }
            if s <= verify_s {
                self.mb.incremental(MaskStream::TeacherTree, s);
            }
        }
        for &s in &c.draft_s {
            self.mb.incremental(MaskStream::DraftChain, s);
            self.mb.incremental(MaskStream::DraftFrontier, s);
        }
        let stage_max = c.prefill_chunk().max(verify_s).max(s_max);
        self.tok_buf.reserve(stage_max);
        self.pos_buf.reserve(stage_max);
        self.feats_buf.reserve(s_max * c.feat_dim);
        Ok(())
    }

    /// Reset all decode state (new conversation), keeping every buffer
    /// capacity: the warmed engine is reused instead of reconstructed —
    /// and with it both multi-MB KV cache buffers, the scratch arenas and
    /// the incremental mask slots. After `reset`, decoding is
    /// bit-identical to a freshly constructed engine (asserted by
    /// `tests/alloc_regression.rs`). Any in-flight generation is dropped.
    pub fn reset(&mut self) {
        self.t_cache.reset();
        self.d_cache.reset();
        self.pending_logits.clear();
        self.feat_last.clear();
        self.uncharted.clear();
        self.history.clear();
        self.block_feats.clear();
        self.attn_hist = attention_distance_buckets();
        self.rng = SplitMix64::new(self.cfg.seed ^ 0xE151);
        self.timers = StageTimer::new(self.cfg.instrument);
        self.adaptive = Self::make_adaptive(&self.cfg);
        self.d_cur = 0;
        // bound sessions now mirror a dead conversation; the next
        // prefill re-syncs them wholesale (storage reused)
        self.sessions_stale = true;
        self.inflight = None;
    }

    /// Committed teacher context length (prompt + generated).
    pub fn context_len(&self) -> usize {
        self.t_cache.len()
    }

    /// Whether a generation is in flight (between
    /// [`Engine::begin_speculative`] and [`Engine::take_output`]).
    /// Schedulers use this to tell a resident conversation from a slot
    /// whose engine was driven (and drained) outside of them.
    pub fn has_inflight(&self) -> bool {
        self.inflight.is_some()
    }

    /// Replace this engine's run configuration and reset it — continuous
    /// serving admits requests with *heterogeneous* configs onto
    /// long-lived slot engines. Applies the same tree-budget clamp as
    /// [`Engine::new`] and re-derives every config-dependent state (rng
    /// stream, adaptive-budget controller, and — when the cache strategy
    /// or fast-reorder flag changed — the managed caches themselves), so
    /// the admitted request decodes bit-identically to a freshly
    /// constructed engine with the same config. Buffer capacities are
    /// kept (warmed slots stay warm): a strategy/fast-reorder change
    /// swaps the flags in place ([`KvStore::reconfigure`]), and only a
    /// cache-*layout* change rebuilds the two stores against the worker
    /// pools (an admission-boundary cost, never a per-round one). Any
    /// in-flight generation is dropped.
    pub fn set_config(&mut self, mut cfg: RunConfig) {
        let max_nodes = self.contract.teacher_s.iter().copied().max().unwrap_or(8) - 1;
        cfg.tree.budget = cfg.tree.budget.min(max_nodes);
        if cfg.cache_layout != self.cfg.cache_layout {
            // layout switch: rebuild against the worker's pools (the old
            // caches drop, returning any mapped blocks)
            self.t_cache = build_cache(
                cfg.cache_layout,
                self.contract.teacher,
                self.contract.cache_cap,
                cfg.cache_strategy,
                cfg.fast_reorder,
                &self.pools.teacher,
            );
            self.d_cache = build_cache(
                cfg.cache_layout,
                self.contract.draft,
                self.contract.cache_cap,
                cfg.cache_strategy,
                cfg.fast_reorder,
                &self.pools.draft,
            );
        } else if cfg.cache_strategy != self.cfg.cache_strategy
            || cfg.fast_reorder != self.cfg.fast_reorder
        {
            // same layout: swap the strategy in place, keeping the
            // buffers/blocks warm (admission-boundary optimization;
            // behaviourally identical to a rebuild since reset empties
            // the committed state)
            self.t_cache.reconfigure(cfg.cache_strategy, cfg.fast_reorder);
            self.d_cache.reconfigure(cfg.cache_strategy, cfg.fast_reorder);
        }
        self.cfg = cfg;
        self.reset();
    }

    /// Add `secs` to a stage timer (instrumented runs only). Public so
    /// the batch scheduler can attribute fused-launch time per request.
    pub fn add_stage_time(&mut self, stage: &str, secs: f64) {
        self.timers.add(stage, secs);
    }

    /// Bytes of KV memory this engine's conversation keeps resident
    /// (both roles): mapped blocks under the paged layout, full-capacity
    /// buffers under flat. The end-to-end bench sums this across slots
    /// into `kv_bytes_resident`, which the CI memory gate compares
    /// between layouts.
    pub fn kv_bytes_resident(&self) -> u64 {
        self.t_cache.bytes_resident() + self.d_cache.bytes_resident()
    }

    /// Lift the resident conversation off this engine: both KV stores
    /// (paged: just block tables — the rows stay put in the worker pool),
    /// the pending logits/feature rows, the chain-refresh queue and every
    /// config-derived stream move into the returned
    /// [`ParkedConversation`]; the engine itself is reset to a fresh
    /// state so the slot can admit another conversation immediately.
    ///
    /// Must be called between turns (no generation in flight). Under the
    /// paged layout this is the multi-resident story: a parked multi-turn
    /// conversation keeps only its mapped blocks while its slot serves
    /// other traffic, and [`Engine::resume`] continues it without
    /// re-prefilling its context. Under the *flat* layout the replacement
    /// stores are fresh full-capacity buffers, so each park costs a
    /// multi-MB allocation — parking is designed for (and cheap under)
    /// `--cache-layout paged`.
    pub fn park(&mut self) -> Result<ParkedConversation> {
        anyhow::ensure!(self.inflight.is_none(), "cannot park with a generation in flight");
        let c = &self.contract;
        let fresh_t = build_cache(
            self.cfg.cache_layout,
            c.teacher,
            c.cache_cap,
            self.cfg.cache_strategy,
            self.cfg.fast_reorder,
            &self.pools.teacher,
        );
        let fresh_d = build_cache(
            self.cfg.cache_layout,
            c.draft,
            c.cache_cap,
            self.cfg.cache_strategy,
            self.cfg.fast_reorder,
            &self.pools.draft,
        );
        let parked = ParkedConversation {
            cfg: self.cfg.clone(),
            t_cache: std::mem::replace(&mut self.t_cache, fresh_t),
            d_cache: std::mem::replace(&mut self.d_cache, fresh_d),
            pending_logits: std::mem::take(&mut self.pending_logits),
            feat_last: std::mem::take(&mut self.feat_last),
            uncharted: std::mem::replace(
                &mut self.uncharted,
                FeatRing::with_capacity(c.cache_cap, c.feat_dim),
            ),
            rng: self.rng.clone(),
            adaptive: self.adaptive.clone(),
            attn_hist: self.attn_hist.clone(),
            d_cur: self.d_cur,
            history: std::mem::take(&mut self.history),
            block_feats: std::mem::take(&mut self.block_feats),
        };
        self.reset();
        Ok(parked)
    }

    /// Restore a parked conversation onto this engine (the inverse of
    /// [`Engine::park`]): installs its config and every piece of decode
    /// state, after which [`Engine::begin_speculative`] starts its next
    /// turn on the preserved context — bit-identical to a conversation
    /// that held its slot the whole time. The engine must share the
    /// worker pools the conversation's blocks live in (any engine of the
    /// same worker does); its previous caches drop here, returning their
    /// blocks.
    pub fn resume(&mut self, parked: ParkedConversation) -> Result<()> {
        anyhow::ensure!(self.inflight.is_none(), "cannot resume over a generation in flight");
        let ParkedConversation {
            cfg,
            t_cache,
            d_cache,
            pending_logits,
            feat_last,
            uncharted,
            rng,
            adaptive,
            attn_hist,
            d_cur,
            history,
            block_feats,
        } = parked;
        self.cfg = cfg;
        self.t_cache = t_cache;
        self.d_cache = d_cache;
        self.pending_logits = pending_logits;
        self.feat_last = feat_last;
        self.uncharted = uncharted;
        self.rng = rng;
        self.adaptive = adaptive;
        self.attn_hist = attn_hist;
        self.d_cur = d_cur;
        self.history = history;
        self.block_feats = block_feats;
        self.timers = StageTimer::new(self.cfg.instrument);
        // the restored caches are a different conversation than the
        // bound session mirrors — resync at the next prefill
        self.sessions_stale = true;
        self.inflight = None;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Prefill
    // ------------------------------------------------------------------

    /// Feed `prompt` tokens through the teacher (chunked) and, for
    /// speculative runs, mirror them into the draft cache with their
    /// teacher features. Leaves `pending_logits` predicting the next
    /// token. Works both for a fresh conversation and for appending a
    /// later chat turn to existing context.
    ///
    /// Under `--prefix-sharing` a fresh conversation first consults the
    /// worker's prefix index: when a resident frozen run matches a
    /// block-aligned prefix of `prompt`, both caches adopt those blocks
    /// directly (refcounted, copy-on-write on divergence) and the chunk
    /// loop runs only over the unmatched tail — prefill for the shared
    /// run is skipped entirely, dropping its teacher calls. Teacher-step
    /// outputs are chunk-partition-invariant (the chain mask opens
    /// `[0, t+i]` per row regardless of how rows were grouped into
    /// calls), so the partial prefill is bit-identical to a full one.
    /// At the end, the conversation's own committed block-aligned prefix
    /// is registered back into the index so later admissions (and its
    /// own park/resume or multi-turn continuations on a different slot)
    /// can share it.
    fn prefill(
        &mut self,
        backend: &mut dyn ModelBackend,
        prompt: &[i32],
        stats: &mut RunStats,
    ) -> Result<()> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        self.ensure_sessions(backend)?;
        let chunk_max = self.contract.prefill_chunk();
        let f = self.contract.feat_dim;
        if self.feat_last.len() != f {
            // fresh conversation: zero predecessor feature
            self.feat_last.clear();
            self.feat_last.resize(f, 0.0);
        }
        let t0 = Stopwatch::start();
        let share_bs = if self.sharing_active() { self.t_cache.block_size() } else { None };
        let mut rest = prompt;
        if share_bs.is_some() && self.t_cache.is_empty() {
            if let Some(hit) = self.pools.lookup_prefix(prompt, prompt.len() - 1) {
                let PrefixMatch { rows, t_blocks, d_blocks, feats } = hit;
                self.t_cache.adopt_shared_blocks(&t_blocks, rows)?;
                self.d_cache.adopt_shared_blocks(&d_blocks, rows)?;
                self.history.clear();
                self.history.extend_from_slice(&prompt[..rows]);
                self.block_feats = feats;
                // the boundary feature: feat of row `rows - 1`, which the
                // first tail token chains from (EAGLE input contract)
                let Some(boundary) = self.block_feats.last() else {
                    bail!("prefix match covered {rows} rows but carried no block features");
                };
                copy_into(&mut self.feat_last, boundary);
                rest = &prompt[rows..];
            }
        }
        for chunk in rest.chunks(chunk_max) {
            let n = chunk.len();
            let s = self.contract.teacher_variant(n)?;
            let t = self.t_cache.len();
            if t + n > self.contract.cache_cap {
                bail!("prompt overflows cache capacity at {t}+{n}");
            }
            self.tok_buf.clear();
            self.tok_buf.resize(s, 0);
            self.tok_buf[..n].copy_from_slice(chunk);
            self.pos_buf.clear();
            self.pos_buf.extend((0..s).map(|i| (t + i.min(n.saturating_sub(1))) as i32));
            let mask = self.mb.chain_incremental(MaskStream::TeacherChain, s, n, t, None);
            let session = Self::ticket(self.t_cache.as_ref(), &self.t_session);
            let guard = self.t_cache.kv_guard();
            backend.teacher_step(self.cfg.mode, StepArgs {
                tokens: &self.tok_buf,
                positions: &self.pos_buf,
                mask,
                kv: guard.view(),
                feats_in: None,
                probe: false,
                session,
            }, &mut self.t_scratch)?;
            drop(guard);
            if session.is_some() {
                self.t_cache.mark_synced();
            }
            stats.teacher_calls += 1;
            self.t_cache.append_committed(&self.t_scratch.k_new, &self.t_scratch.v_new, s, n)?;
            if self.use_draft {
                for (i, tok) in chunk.iter().enumerate() {
                    if i == 0 {
                        self.uncharted.push(*tok, &self.feat_last);
                    } else {
                        self.uncharted.push(*tok, self.t_scratch.feat_row(i - 1));
                    }
                }
            }
            if let Some(bs) = share_bs {
                for (i, tok) in chunk.iter().enumerate() {
                    self.history.push(*tok);
                    if (t + i + 1) % bs == 0 {
                        self.block_feats.push(self.t_scratch.feat_row(i).to_vec());
                    }
                }
            }
            copy_into(&mut self.feat_last, self.t_scratch.feat_row(n - 1));
            copy_into(&mut self.pending_logits, self.t_scratch.logits_row(n - 1));
        }
        if self.use_draft {
            self.drain_uncharted(backend, stats)?;
        }
        if let Some(bs) = share_bs {
            // Freeze this conversation's committed block-aligned prefix
            // into the worker index. The history-length check skips runs
            // whose early rows were committed without sharing bookkeeping
            // (e.g. a baseline turn on the same engine).
            let run = self.block_feats.len() * bs;
            if run > 0 && self.history.len() == self.t_cache.len() {
                if let (Some(tb), Some(db)) = (
                    self.t_cache.committed_block_run(run),
                    self.d_cache.committed_block_run(run),
                ) {
                    self.pools.register_prefix(
                        &self.history[..run],
                        &tb,
                        &db,
                        &self.block_feats[..run / bs],
                    )?;
                }
            }
        }
        self.timers.add("prefill", t0.elapsed_secs());
        Ok(())
    }

    // ------------------------------------------------------------------
    // Draft-side cache refresh (chain calls)
    // ------------------------------------------------------------------

    /// Flush `uncharted` committed tokens into the draft cache. Returns
    /// the scratch row (in `d_scratch[d_cur]`) of the *last* flushed
    /// token — the root expansion signal — when anything was flushed.
    fn drain_uncharted(
        &mut self,
        backend: &mut dyn ModelBackend,
        stats: &mut RunStats,
    ) -> Result<Option<usize>> {
        let mut last = None;
        let max_take = self.contract.max_draft_s();
        while !self.uncharted.is_empty() {
            let take = self.uncharted.len().min(max_take);
            let s = self.contract.draft_variant(take)?;
            let d = self.d_cache.len();
            if d + take > self.contract.cache_cap {
                bail!("draft cache overflow at {d}+{take}");
            }
            let f = self.contract.feat_dim;
            self.tok_buf.clear();
            self.tok_buf.resize(s, 0);
            self.feats_buf.clear();
            self.feats_buf.resize(s * f, 0.0);
            for i in 0..take {
                let Some((tok, feat)) = self.uncharted.pop_front() else {
                    bail!("draft ring drained early at {i}/{take}");
                };
                self.tok_buf[i] = tok;
                self.feats_buf[i * f..(i + 1) * f].copy_from_slice(feat);
            }
            self.pos_buf.clear();
            self.pos_buf.extend((0..s).map(|i| (d + i.min(take - 1)) as i32));
            let mask =
                self.mb.chain_incremental(MaskStream::DraftChain, s, take, d, self.cfg.draft_window);
            let session = Self::ticket(self.d_cache.as_ref(), &self.d_session);
            let guard = self.d_cache.kv_guard();
            backend.draft_step(StepArgs {
                tokens: &self.tok_buf,
                positions: &self.pos_buf,
                mask,
                kv: guard.view(),
                feats_in: Some(&self.feats_buf),
                probe: self.cfg.attention_stats,
                session,
            }, &mut self.d_scratch[self.d_cur])?;
            drop(guard);
            if session.is_some() {
                self.d_cache.mark_synced();
            }
            stats.draft_calls += 1;
            self.d_cache.append_committed(
                &self.d_scratch[self.d_cur].k_new,
                &self.d_scratch[self.d_cur].v_new,
                s,
                take,
            )?;
            if let Some(top1) = self.d_scratch[self.d_cur].attn_top1() {
                Self::record_attention(
                    &mut self.attn_hist,
                    self.contract.cache_cap,
                    top1,
                    take,
                    d,
                    self.contract.draft.heads,
                );
            }
            last = Some(take - 1);
        }
        Ok(last)
    }

    /// Fig-7 evidence: bucket top-1 attention columns by token distance.
    fn record_attention(
        hist: &mut Histogram,
        cap: usize,
        top1: &[i32],
        live: usize,
        d_len: usize,
        heads: usize,
    ) {
        for i in 0..live {
            let pos = d_len + i;
            for h in 0..heads {
                let col = top1[i * heads + h] as usize;
                let col_pos = if col < cap { col } else { d_len + (col - cap) };
                let dist = pos.saturating_sub(col_pos);
                hist.add(dist as f64);
            }
        }
    }

    // ------------------------------------------------------------------
    // Baseline: teacher-only greedy decoding
    // ------------------------------------------------------------------

    /// Teacher-only greedy decoding (the paper's baseline): one teacher
    /// call per committed token.
    pub fn generate_baseline(
        &mut self,
        backend: &mut dyn ModelBackend,
        prompt: &[i32],
        max_new: usize,
    ) -> Result<GenOut> {
        anyhow::ensure!(self.inflight.is_none(), "a generation is already in flight");
        self.use_draft = false;
        let wall0 = Stopwatch::start();
        let mut stats = RunStats::default();
        self.prefill(backend, prompt, &mut stats)?;
        let mut out_tokens = Vec::with_capacity(max_new);
        let s = self.contract.min_teacher_s();
        while out_tokens.len() < max_new && self.t_cache.headroom() > s {
            let r0 = argmax(&self.pending_logits) as i32;
            let t = self.t_cache.len();
            self.tok_buf.clear();
            self.tok_buf.resize(s, 0);
            self.tok_buf[0] = r0;
            self.pos_buf.clear();
            self.pos_buf.resize(s, t as i32);
            let tm = Stopwatch::start();
            let mask = self.mb.chain_incremental(MaskStream::TeacherChain, s, 1, t, None);
            self.timers.add("mask_build", tm.elapsed_secs());
            let tv = Stopwatch::start();
            let session = Self::ticket(self.t_cache.as_ref(), &self.t_session);
            let guard = self.t_cache.kv_guard();
            backend.teacher_step(self.cfg.mode, StepArgs {
                tokens: &self.tok_buf,
                positions: &self.pos_buf,
                mask,
                kv: guard.view(),
                feats_in: None,
                probe: false,
                session,
            }, &mut self.t_scratch)?;
            drop(guard);
            if session.is_some() {
                self.t_cache.mark_synced();
            }
            self.timers.add("verify", tv.elapsed_secs());
            stats.teacher_calls += 1;
            stats.rounds += 1;
            let tc = Stopwatch::start();
            self.t_cache.append_committed(&self.t_scratch.k_new, &self.t_scratch.v_new, s, 1)?;
            self.timers.add("commit", tc.elapsed_secs());
            copy_into(&mut self.pending_logits, self.t_scratch.logits_row(0));
            copy_into(&mut self.feat_last, self.t_scratch.feat_row(0));
            out_tokens.push(r0);
        }
        Ok(self.finish(out_tokens, prompt.len(), stats, wall0))
    }

    // ------------------------------------------------------------------
    // Speculative decoding
    // ------------------------------------------------------------------

    /// Tree-speculative decoding of one turn: prefill + rounds until
    /// `max_new` tokens are committed (soft cap — a round commits
    /// `1 + accept_L` tokens atomically, so EA may overshoot by at most
    /// `depth_max`; the committed text stays a prefix-exact teacher-greedy
    /// stream, so multi-turn context remains consistent).
    pub fn generate_speculative(
        &mut self,
        backend: &mut dyn ModelBackend,
        prompt: &[i32],
        max_new: usize,
    ) -> Result<GenOut> {
        self.begin_speculative(backend, prompt, max_new)?;
        while self.needs_more() {
            self.prepare_verify(backend)?;
            self.verify_own(backend)?;
            self.finish_verify()?;
        }
        self.take_output()
    }

    /// Start a speculative generation: validate the config, prefill
    /// `prompt`, and leave the engine ready for rounds
    /// ([`Engine::prepare_verify`] / [`Engine::finish_verify`]). In
    /// batched serving the per-request wall clock reported by
    /// [`Engine::take_output`] spans the whole co-scheduled drive, peers
    /// included — it is honest arrival-to-completion latency, not pure
    /// compute time.
    pub fn begin_speculative(
        &mut self,
        backend: &mut dyn ModelBackend,
        prompt: &[i32],
        max_new: usize,
    ) -> Result<()> {
        anyhow::ensure!(self.inflight.is_none(), "a generation is already in flight");
        self.use_draft = true;
        self.cfg.validate()?;
        let wall0 = Stopwatch::start();
        let mut stats = RunStats::default();
        self.prefill(backend, prompt, &mut stats)?;
        self.inflight = Some(InFlight {
            stats,
            out_tokens: Vec::with_capacity(max_new + self.cfg.tree.depth_max),
            prompt_len: prompt.len(),
            wall0,
            max_new,
            round: None,
        });
        Ok(())
    }

    /// Whether the in-flight generation wants another speculative round
    /// (tokens still owed and cache headroom for one more tree). False if
    /// no generation is in flight. Must not be called with a round
    /// pending (prepare/finish pairs are atomic as far as scheduling is
    /// concerned).
    pub fn needs_more(&self) -> bool {
        let Some(fl) = &self.inflight else { return false };
        let reserve = 1 + self.max_budget();
        fl.out_tokens.len() < fl.max_new
            && self.t_cache.headroom() > reserve
            && self.d_cache.headroom() > reserve
    }

    /// Run the draft-side half of one speculative round: root + chain
    /// refresh, tree expansion, tensorization, tree-mask build, position
    /// staging, and opening the teacher cache branch. Leaves a pending
    /// round whose verification inputs are exposed by
    /// [`Engine::verify_payload`].
    pub fn prepare_verify(&mut self, backend: &mut dyn ModelBackend) -> Result<()> {
        let mut fl = self.inflight.take().context("prepare_verify without begin_speculative")?;
        let r = self.prepare_verify_inner(backend, &mut fl);
        self.inflight = Some(fl);
        r
    }

    fn prepare_verify_inner(
        &mut self,
        backend: &mut dyn ModelBackend,
        fl: &mut InFlight,
    ) -> Result<()> {
        anyhow::ensure!(fl.round.is_none(), "prepare_verify with a round already pending");
        fl.stats.rounds += 1;

        // 1. Pending root token + draft chain refresh.
        let r0 = argmax(&self.pending_logits) as i32;
        self.uncharted.push(r0, &self.feat_last);
        let td = Stopwatch::start();
        let root_row = self
            .drain_uncharted(backend, &mut fl.stats)?
            .context("drain_uncharted returned nothing despite pending root")?;

        // 2. Tree expansion (depth-synchronous, global top-M).
        let mut tree = SpecTree::with_root(r0);
        self.d_cache.begin_branch()?;
        // tree slot -> draft branch row (for ancestor visibility); the root
        // lives in the committed draft cache at d_len - 1.
        let mut branch_row_of: Vec<Option<usize>> = vec![None];
        // (tree slot, row in d_scratch[d_cur]) per frontier node
        let mut frontier: Vec<(usize, usize)> = vec![(0, root_row)];
        let mut new_slots: Vec<usize> = Vec::new();
        let round_budget = self.current_budget();
        let mut budget_left = round_budget;
        let mut depth = 0usize;
        while budget_left > 0 && depth < self.cfg.tree.depth_max && !frontier.is_empty() {
            depth += 1;
            self.cand_pool.clear();
            {
                let read = &self.d_scratch[self.d_cur];
                for (row_i, &(slot, row)) in frontier.iter().enumerate() {
                    let base_lp = tree.slots()[slot].logprob;
                    let logits = read.logits_row(row);
                    for (tok, _) in topk(logits, self.cfg.tree.topk) {
                        self.cand_pool.push(Candidate {
                            parent: slot,
                            token: tok as i32,
                            cum_logprob: base_lp + log_softmax_at(logits, tok),
                            parent_row: row_i,
                        });
                    }
                }
            }
            select_children(&mut self.cand_pool, budget_left, FRONTIER_CAP);
            if self.cand_pool.is_empty() {
                break;
            }
            new_slots.clear();
            for c in &self.cand_pool {
                let slot = tree.add_child(c.parent, c.token, c.cum_logprob);
                branch_row_of.push(None);
                new_slots.push(slot);
            }
            budget_left -= self.cand_pool.len();
            if budget_left == 0 || depth == self.cfg.tree.depth_max {
                break; // leaves don't need a draft evaluation
            }
            self.eval_frontier(
                backend,
                &tree,
                &new_slots,
                &frontier,
                &mut branch_row_of,
                depth,
                &mut fl.stats,
            )?;
            frontier.clear();
            frontier.extend(new_slots.iter().enumerate().map(|(i, &slot)| (slot, i)));
        }
        self.timers.add("draft_expand", td.elapsed_secs());

        // 3. Tensorize + §3.2 invariants.
        let tt = Stopwatch::start();
        let s_pad = self.contract.teacher_variant(tree.num_slots())?;
        let tens = Tensorized::from_tree(&tree, s_pad, self.cfg.check_invariants)
            .map_err(|e| anyhow::anyhow!("tree invariant violation: {e}"))?;
        self.timers.add("tensorize", tt.elapsed_secs());

        // 4. Tree mask (incremental: prefix delta + spec block rewrite),
        // built into the persistent (TeacherTree, s_pad) slot that
        // `verify_payload` re-borrows.
        let tm = Stopwatch::start();
        let t_len = self.t_cache.len();
        let _ = self.mb.tree_incremental(MaskStream::TeacherTree, &tens, t_len, None);
        self.timers.add("mask_build", tm.elapsed_secs());

        // 5. Stage positions + open the teacher branch; verification may
        // now run (fused or single) against `verify_payload`.
        tens.positions_into(t_len, &mut self.pos_buf);
        self.t_cache.begin_branch()?;
        fl.round = Some(RoundState {
            r0,
            tree,
            tens,
            s_pad,
            t_len,
            round_budget,
            verified: false,
        });
        Ok(())
    }

    /// Borrowed verification inputs of the pending round (tokens,
    /// positions, mask, cache view). The batch scheduler gathers these
    /// across engines into one fused launch.
    pub fn verify_payload(&self) -> Result<VerifyPayload<'_>> {
        let fl = self.inflight.as_ref().context("no generation in flight")?;
        let round = fl.round.as_ref().context("verify_payload without a prepared round")?;
        let mask = self
            .mb
            .peek(MaskStream::TeacherTree, round.s_pad)
            .context("teacher tree mask slot missing")?
            .as_slice();
        Ok(VerifyPayload {
            tokens: &round.tens.tokens,
            positions: &self.pos_buf,
            mask,
            kv: self.t_cache.kv_guard(),
            s: round.s_pad,
            live: round.tens.live,
            ctx_len: round.t_len,
            session: Self::ticket(self.t_cache.as_ref(), &self.t_session),
        })
    }

    /// Single-request verification: one teacher call on the pending
    /// round's payload, outputs into the engine's own scratch.
    fn verify_own(&mut self, backend: &mut dyn ModelBackend) -> Result<()> {
        let tv = Stopwatch::start();
        let session = Self::ticket(self.t_cache.as_ref(), &self.t_session);
        {
            let fl = self.inflight.as_ref().context("no generation in flight")?;
            let round = fl.round.as_ref().context("verify without a prepared round")?;
            let mask = self
                .mb
                .peek(MaskStream::TeacherTree, round.s_pad)
                .context("teacher tree mask slot missing")?
                .as_slice();
            let guard = self.t_cache.kv_guard();
            backend.teacher_step(self.cfg.mode, StepArgs {
                tokens: &round.tens.tokens,
                positions: &self.pos_buf,
                mask,
                kv: guard.view(),
                feats_in: None,
                probe: false,
                session,
            }, &mut self.t_scratch)?;
        }
        if session.is_some() {
            self.t_cache.mark_synced();
        }
        self.timers.add("verify", tv.elapsed_secs());
        if let Some(fl) = self.inflight.as_mut() {
            if let Some(r) = fl.round.as_mut() {
                r.verified = true;
            }
        }
        Ok(())
    }

    /// Copy this request's rows out of a fused batched scratch into the
    /// engine's own verification scratch (`b` = this request's index in
    /// the fused launch). Marks the pending round as verified.
    pub fn scatter_verify(&mut self, fused: &StepScratch, b: usize) -> Result<()> {
        let s_pad = {
            let fl = self.inflight.as_ref().context("no generation in flight")?;
            let round = fl.round.as_ref().context("scatter_verify without a prepared round")?;
            round.s_pad
        };
        anyhow::ensure!(
            s_pad <= fused.s(),
            "fused scratch rows {} cannot hold request variant {s_pad}",
            fused.s()
        );
        self.t_scratch.scatter_from(fused, b, s_pad);
        // the fused launch consumed this request's session ticket (the
        // verifier passes verify_payload().session straight through)
        if self.t_session.is_some() {
            self.t_cache.mark_synced();
        }
        if let Some(fl) = self.inflight.as_mut() {
            if let Some(r) = fl.round.as_mut() {
                r.verified = true;
            }
        }
        Ok(())
    }

    /// Per-request second half of a round: adopt the verified KV rows
    /// into the teacher branch, run the acceptance walk, and commit
    /// `1 + accept_L` tokens. Requires verification outputs in the
    /// engine's scratch (via the internal step or
    /// [`Engine::scatter_verify`]).
    pub fn finish_verify(&mut self) -> Result<()> {
        let mut fl = self.inflight.take().context("finish_verify without begin_speculative")?;
        let r = self.finish_verify_inner(&mut fl);
        self.inflight = Some(fl);
        r
    }

    fn finish_verify_inner(&mut self, fl: &mut InFlight) -> Result<()> {
        {
            let round = fl.round.as_ref().context("finish_verify without a prepared round")?;
            anyhow::ensure!(
                round.verified,
                "finish_verify before verification outputs were written"
            );
        }
        // The round stays in place on the error paths above; from here
        // on it is consumed.
        let Some(round) = fl.round.take() else {
            bail!("round state lost between check and take");
        };
        let RoundState { r0, tree, tens, s_pad, t_len, round_budget, .. } = round;
        fl.stats.teacher_calls += 1;

        let tv = Stopwatch::start();
        self.t_cache.append_branch(&self.t_scratch.k_new, &self.t_scratch.v_new, s_pad, tens.live)?;
        self.timers.add("verify", tv.elapsed_secs());

        // 6. Acceptance (over borrowed scratch rows — no cloning).
        let ta = Stopwatch::start();
        let acc = {
            let scratch = &self.t_scratch;
            let logits_of = |slot: usize| scratch.logits_row(slot);
            if self.cfg.temperature == 0.0 {
                greedy_walk(&tree, &logits_of)
            } else {
                stochastic_walk(&tree, &logits_of, self.cfg.temperature, &mut self.rng)
            }
        };
        fl.stats.accept_lens.push(acc.accept_len());
        fl.stats.accept_pos.record(acc.accept_len(), acc.offered);
        if let Some(adaptive) = &mut self.adaptive {
            adaptive.observe(acc.accept_len(), round_budget);
        }
        self.timers.add("accept", ta.elapsed_secs());

        // 7. Commit.
        let tc = Stopwatch::start();
        let a = acc.accept_len();
        let contiguous = acc.path.iter().enumerate().all(|(i, s)| *s == i + 1);
        match self.cfg.commit_mode {
            CommitMode::Length if contiguous => {
                // root (branch row 0) + accepted rows 1..=A
                self.t_cache.commit_length(1 + a)?;
            }
            _ if self.cfg.fast_reorder => {
                // Prefix-relative fast commit: branch row r holds tree
                // slot r, so the accepted tail is [0 (root)] ++ path —
                // strictly increasing by BFS construction. The committed
                // prefix is implicit: no identity vector, no gather
                // scratch.
                self.path_tail.clear();
                self.path_tail.push(0);
                self.path_tail.extend_from_slice(&acc.path);
                self.t_cache.commit_path_tail(&self.path_tail)?;
            }
            _ => {
                // §3.1 ablation path: absolute path indices through the
                // general commit (measured, intentionally expensive).
                let mut path: Vec<usize> = Vec::with_capacity(t_len + 1 + a);
                path.extend(0..t_len);
                path.push(t_len); // root slot 0
                path.extend(acc.path.iter().map(|s| t_len + s));
                self.t_cache.commit_path(&path)?;
            }
        }
        // Features of newly committed tokens feed the next chain refresh.
        // Prefix-sharing bookkeeping rides along: committed row `t_len`
        // holds r0 (its own teacher feature is scratch row 0), and row
        // `t_len + 1 + i` holds the i-th accepted path token (feature at
        // its tree slot); block-end features feed later partial prefills.
        let share_bs = if self.sharing_active() { self.t_cache.block_size() } else { None };
        if let Some(bs) = share_bs {
            self.history.push(r0);
            if (t_len + 1) % bs == 0 {
                self.block_feats.push(self.t_scratch.feat_row(0).to_vec());
            }
        }
        fl.out_tokens.push(r0);
        let mut prev_slot = 0usize;
        for (i, &slot) in acc.path.iter().enumerate() {
            let tok = tree.slots()[slot].token;
            self.uncharted.push(tok, self.t_scratch.feat_row(prev_slot));
            if let Some(bs) = share_bs {
                self.history.push(tok);
                if (t_len + 2 + i) % bs == 0 {
                    self.block_feats.push(self.t_scratch.feat_row(slot).to_vec());
                }
            }
            fl.out_tokens.push(tok);
            prev_slot = slot;
        }
        copy_into(&mut self.feat_last, self.t_scratch.feat_row(acc.bonus_slot));
        copy_into(&mut self.pending_logits, self.t_scratch.logits_row(acc.bonus_slot));
        self.d_cache.rollback();
        self.timers.add("commit", tc.elapsed_secs());
        Ok(())
    }

    /// Tokens committed so far by the in-flight generation (`None` when
    /// no generation is open). The worker's token-streaming surface:
    /// after each scheduler tick it diffs this against what it already
    /// sent and emits the suffix as a `TokenDelta` — without closing the
    /// generation the way [`Engine::take_output`] does.
    pub fn inflight_tokens(&self) -> Option<&[i32]> {
        self.inflight.as_ref().map(|fl| fl.out_tokens.as_slice())
    }

    /// Close the in-flight generation and return its [`GenOut`]. Call
    /// only with no round pending.
    pub fn take_output(&mut self) -> Result<GenOut> {
        let fl = self.inflight.take().context("take_output without an active generation")?;
        anyhow::ensure!(fl.round.is_none(), "take_output with a round still pending");
        Ok(self.finish(fl.out_tokens, fl.prompt_len, fl.stats, fl.wall0))
    }

    /// Evaluate the freshly selected frontier (the candidates currently in
    /// `cand_pool`) with one draft call: feature inputs chain from parent
    /// hidden rows in the read scratch, the mask opens committed prefix
    /// (optionally windowed), ancestor branch rows and the self slot.
    /// Outputs land in the write scratch, which then becomes the read
    /// scratch for the next depth.
    #[allow(clippy::too_many_arguments)]
    fn eval_frontier(
        &mut self,
        backend: &mut dyn ModelBackend,
        tree: &SpecTree,
        new_slots: &[usize],
        frontier: &[(usize, usize)],
        branch_row_of: &mut [Option<usize>],
        depth: usize,
        stats: &mut RunStats,
    ) -> Result<()> {
        let n = self.cand_pool.len();
        let s = self.contract.draft_variant(n)?;
        let f = self.contract.feat_dim;
        let cap = self.contract.cache_cap;
        let d_len = self.d_cache.len();
        if d_len + self.d_cache.branch_rows() + n > cap {
            bail!("draft branch overflow during expansion");
        }
        self.tok_buf.clear();
        self.tok_buf.resize(s, 0);
        self.feats_buf.clear();
        self.feats_buf.resize(s * f, 0.0);
        {
            let read = &self.d_scratch[self.d_cur];
            for (i, c) in self.cand_pool.iter().enumerate() {
                self.tok_buf[i] = c.token;
                let parent_row = frontier[c.parent_row].1;
                self.feats_buf[i * f..(i + 1) * f].copy_from_slice(read.feat_row(parent_row));
            }
        }
        // every frontier node of this depth sits at the same position
        let pos = (d_len - 1 + depth) as i32;
        self.pos_buf.clear();
        self.pos_buf.resize(s, pos);
        // mask: committed prefix (windowed) + ancestor branch rows (cache
        // columns past d_len) + the self slot — built on the persistent
        // frontier slot with exact-revert bookkeeping. All columns are
        // logical rows; the paged layout resolves them through the block
        // table inside the backend read.
        let lo = self.cfg.draft_window.map_or(0, |win| d_len.saturating_sub(win));
        {
            let slot_mask = self.mb.incremental(MaskStream::DraftFrontier, s);
            slot_mask.clear_spec();
            for i in 0..s {
                if i < n {
                    slot_mask.set_prefix(i, lo, d_len);
                } else {
                    slot_mask.set_prefix(i, 0, 0);
                }
            }
            for (i, c) in self.cand_pool.iter().enumerate() {
                for &anc in &tree.ancestors(c.parent) {
                    if anc == 0 {
                        continue; // root = last committed token, already open
                    }
                    let br = branch_row_of[anc]
                        .with_context(|| format!("ancestor slot {anc} has no draft row"))?;
                    slot_mask.open_col(i, d_len + br);
                }
                slot_mask.open_spec(i, i); // self
            }
        }
        let write_idx = 1 - self.d_cur;
        let mask = self.mb.incremental(MaskStream::DraftFrontier, s).as_slice();
        let session = Self::ticket(self.d_cache.as_ref(), &self.d_session);
        let guard = self.d_cache.kv_guard();
        backend.draft_step(StepArgs {
            tokens: &self.tok_buf,
            positions: &self.pos_buf,
            mask,
            kv: guard.view(),
            feats_in: Some(&self.feats_buf),
            probe: false,
            session,
        }, &mut self.d_scratch[write_idx])?;
        drop(guard);
        if session.is_some() {
            self.d_cache.mark_synced();
        }
        stats.draft_calls += 1;
        let base_row = self.d_cache.branch_rows();
        self.d_cache.append_branch(
            &self.d_scratch[write_idx].k_new,
            &self.d_scratch[write_idx].v_new,
            s,
            n,
        )?;
        for (i, &slot) in new_slots.iter().enumerate() {
            branch_row_of[slot] = Some(base_row + i);
        }
        self.d_cur = write_idx;
        Ok(())
    }

    fn finish(&mut self, tokens: Vec<i32>, prompt_len: usize, stats: RunStats,
              wall0: Stopwatch) -> GenOut {
        GenOut {
            tokens,
            wall_secs: wall0.elapsed_secs(),
            teacher_calls: stats.teacher_calls,
            draft_calls: stats.draft_calls,
            rounds: stats.rounds,
            accept_lens: stats.accept_lens,
            accept_pos: stats.accept_pos,
            timers: std::mem::replace(&mut self.timers, StageTimer::new(self.cfg.instrument)),
            attn_hist: std::mem::replace(&mut self.attn_hist, attention_distance_buckets()),
            teacher_cache: self.t_cache.stats().clone(),
            draft_cache: self.d_cache.stats().clone(),
            prompt_len,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::sim::SimBackend;
    use crate::config::{CacheStrategy, ExecMode};

    fn prompt(n: usize, seed: u64) -> Vec<i32> {
        let mut rng = SplitMix64::new(seed);
        let mut p = vec![1i32]; // BOS
        for _ in 1..n {
            p.push(rng.range(2, 512) as i32);
        }
        p
    }

    fn run_baseline(cfg: &RunConfig, p: &[i32], max_new: usize) -> GenOut {
        let mut b = SimBackend::new(90);
        let mut e = Engine::new(&b, cfg.clone());
        e.generate_baseline(&mut b, p, max_new).unwrap()
    }

    fn run_spec(cfg: &RunConfig, p: &[i32], max_new: usize, agree: u64) -> GenOut {
        let mut b = SimBackend::new(agree);
        let mut e = Engine::new(&b, cfg.clone());
        e.generate_speculative(&mut b, p, max_new).unwrap()
    }

    #[test]
    fn baseline_produces_deterministic_tokens() {
        let cfg = RunConfig::default();
        let p = prompt(12, 1);
        let a = run_baseline(&cfg, &p, 20);
        let b = run_baseline(&cfg, &p, 20);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.tokens.len(), 20);
        assert_eq!(a.teacher_calls as usize, 20 + 1); // +1 prefill chunk
    }

    #[test]
    fn speculative_output_equals_baseline_greedy() {
        // The paper's core quality claim: EA with greedy acceptance commits
        // exactly the teacher-greedy sequence, across every cache config.
        let p = prompt(17, 2);
        let base = run_baseline(&RunConfig::default(), &p, 48);
        for strategy in [CacheStrategy::SegmentShare, CacheStrategy::DeepCopy] {
            for commit in [CommitMode::PathIndex, CommitMode::Length] {
                for fast in [true, false] {
                    for agree in [0, 60, 100] {
                        let mut cfg = RunConfig::default();
                        cfg.cache_strategy = strategy;
                        cfg.commit_mode = commit;
                        cfg.fast_reorder = fast;
                        let ea = run_spec(&cfg, &p, 32, agree);
                        assert!(ea.tokens.len() >= 32);
                        assert_eq!(
                            ea.tokens[..],
                            base.tokens[..ea.tokens.len()],
                            "strategy={strategy:?} commit={commit:?} fast={fast} agree={agree}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn eager_and_fused_modes_agree() {
        let p = prompt(9, 3);
        let mut cfg = RunConfig::default();
        cfg.mode = ExecMode::Fused;
        let a = run_spec(&cfg, &p, 16, 85);
        cfg.mode = ExecMode::Eager;
        let b = run_spec(&cfg, &p, 16, 85);
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn high_agreement_yields_long_accepts_and_fewer_calls() {
        let p = prompt(10, 4);
        let cfg = RunConfig::default();
        let hi = run_spec(&cfg, &p, 48, 100);
        let lo = run_spec(&cfg, &p, 48, 0);
        assert!(hi.mean_accept_len() > 1.5, "hi accept {}", hi.mean_accept_len());
        assert!(lo.mean_accept_len() < 0.5, "lo accept {}", lo.mean_accept_len());
        assert!(
            hi.teacher_calls < lo.teacher_calls,
            "accepts must reduce teacher calls: {} vs {}",
            hi.teacher_calls,
            lo.teacher_calls
        );
        // speculation must never change the committed text
        let n = hi.tokens.len().min(lo.tokens.len());
        assert_eq!(hi.tokens[..n], lo.tokens[..n]);
    }

    #[test]
    fn drafter_truncation_reduces_acceptance() {
        // E4 shape: a windowed drafter loses far context (the sim's context
        // hash changes), so its proposals diverge from the teacher's.
        let p = prompt(40, 5);
        let mut cfg = RunConfig::default();
        let full = run_spec(&cfg, &p, 40, 100);
        cfg.draft_window = Some(8);
        let trunc = run_spec(&cfg, &p, 40, 100);
        assert!(
            trunc.mean_accept_len() < full.mean_accept_len() * 0.6,
            "truncation should collapse acceptance: {} vs {}",
            trunc.mean_accept_len(),
            full.mean_accept_len()
        );
        let n = trunc.tokens.len().min(full.tokens.len());
        assert_eq!(trunc.tokens[..n], full.tokens[..n], "output must stay teacher-greedy");
    }

    #[test]
    fn accept_pos_rates_populated_and_decaying_shape() {
        let p = prompt(12, 6);
        let out = run_spec(&RunConfig::default(), &p, 64, 90);
        let rates = out.accept_pos.rates();
        assert!(!rates.is_empty());
        assert!(rates[0] > 0.5, "depth-1 acceptance should be high: {rates:?}");
    }

    #[test]
    fn multi_turn_continuation_keeps_cache() {
        let mut b = SimBackend::new(90);
        let mut e = Engine::new(&b, RunConfig::default());
        let p1 = prompt(10, 7);
        let o1 = e.generate_speculative(&mut b, &p1, 12).unwrap();
        let len_after_t1 = e.context_len();
        assert!(len_after_t1 >= 10 + 12);
        let p2 = prompt(6, 8);
        let o2 = e.generate_speculative(&mut b, &p2, 12).unwrap();
        assert!(e.context_len() > len_after_t1);
        assert!(o1.tokens.len() >= 12);
        assert!(o2.tokens.len() >= 12);
        // reset clears everything
        e.reset();
        assert_eq!(e.context_len(), 0);
    }

    #[test]
    fn multi_turn_equals_concatenated_context() {
        // Decoding turn 2 after turn 1 must equal baseline decoding over
        // the concatenated context (cache-commit equivalence end-to-end).
        let p1 = prompt(8, 9);
        let max1 = 10;
        let mut b1 = SimBackend::new(90);
        let mut e1 = Engine::new(&b1, RunConfig::default());
        let o1 = e1.generate_speculative(&mut b1, &p1, max1).unwrap();
        let p2 = prompt(5, 10);
        let o2 = e1.generate_speculative(&mut b1, &p2, 10).unwrap();

        let mut ctx: Vec<i32> = p1.clone();
        ctx.extend(&o1.tokens);
        ctx.extend(&p2);
        let mut b2 = SimBackend::new(90);
        let mut e2 = Engine::new(&b2, RunConfig::default());
        let base = e2.generate_baseline(&mut b2, &ctx, o2.tokens.len()).unwrap();
        assert_eq!(o2.tokens, base.tokens);
    }

    #[test]
    fn reused_engine_after_reset_matches_fresh_engine() {
        // The coordinator reuses warmed engines per worker; reset must
        // restore exact fresh-engine behaviour (tokens AND accept shape).
        let p1 = prompt(14, 21);
        let p2 = prompt(9, 22);
        let mut b = SimBackend::new(85);
        let mut e = Engine::new(&b, RunConfig::default());
        let first = e.generate_speculative(&mut b, &p1, 24).unwrap();
        e.reset();
        let second = e.generate_speculative(&mut b, &p2, 24).unwrap();
        e.reset();
        let first_again = e.generate_speculative(&mut b, &p1, 24).unwrap();

        let mut fb = SimBackend::new(85);
        let mut fe = Engine::new(&fb, RunConfig::default());
        let fresh2 = fe.generate_speculative(&mut fb, &p2, 24).unwrap();

        assert_eq!(second.tokens, fresh2.tokens, "reused engine diverged from fresh");
        assert_eq!(second.accept_lens, fresh2.accept_lens);
        assert_eq!(first.tokens, first_again.tokens, "reset is not idempotent");
        assert_eq!(first.accept_lens, first_again.accept_lens);
    }

    #[test]
    fn budget_one_degenerates_to_linear_speculation() {
        let p = prompt(8, 11);
        let mut cfg = RunConfig::default();
        cfg.tree.budget = 1;
        let out = run_spec(&cfg, &p, 16, 100);
        let base = run_baseline(&RunConfig::default(), &p, 18);
        assert_eq!(out.tokens[..], base.tokens[..out.tokens.len()]);
        assert!(out.accept_lens.iter().all(|a| *a <= 1));
    }

    #[test]
    fn instrumented_run_records_all_stages() {
        let p = prompt(8, 12);
        let mut cfg = RunConfig::default();
        cfg.instrument = true;
        let mut b = SimBackend::new(90);
        let mut e = Engine::new(&b, cfg);
        let out = e.generate_speculative(&mut b, &p, 16).unwrap();
        for stage in ["prefill", "draft_expand", "tensorize", "mask_build", "verify",
                      "accept", "commit"] {
            assert!(out.timers.seconds.contains_key(stage), "missing stage {stage}");
        }
    }

    #[test]
    fn attention_stats_histogram_fills_on_probe_runs() {
        let p = prompt(80, 13);
        let mut cfg = RunConfig::default();
        cfg.attention_stats = true;
        let out = run_spec(&cfg, &p, 16, 90);
        assert!(out.attn_hist.total > 0);
        // the sim's even heads always attend to the earliest visible token,
        // so the far bucket must be populated (Fig-7 shape).
        assert!(out.attn_hist.counts[2] + out.attn_hist.counts[3] > 0);
    }

    #[test]
    fn adaptive_budget_tracks_draft_quality() {
        // good draft -> budget grows; bad draft -> budget shrinks; output
        // stays teacher-greedy either way.
        let p = prompt(12, 15);
        let mut cfg = RunConfig::default();
        cfg.adaptive_budget = true;
        cfg.tree.budget = 8;
        let mut good = SimBackend::new(100);
        let mut e = Engine::new(&good, cfg.clone());
        let out_good = e.generate_speculative(&mut good, &p, 120).unwrap();
        let grown = e.current_budget();
        assert!(grown > 8, "high acceptance should grow the budget: {grown}");

        let mut bad = SimBackend::new(0);
        let mut e2 = Engine::new(&bad, cfg.clone());
        let out_bad = e2.generate_speculative(&mut bad, &p, 120).unwrap();
        assert!(e2.current_budget() < 8,
                "zero acceptance should shrink the budget: {}", e2.current_budget());
        let n = out_good.tokens.len().min(out_bad.tokens.len());
        assert_eq!(out_good.tokens[..n], out_bad.tokens[..n]);
    }

    #[test]
    fn adaptive_budget_restored_by_reset() {
        let p = prompt(12, 16);
        let mut cfg = RunConfig::default();
        cfg.adaptive_budget = true;
        cfg.tree.budget = 8;
        let mut b = SimBackend::new(100);
        let mut e = Engine::new(&b, cfg);
        e.generate_speculative(&mut b, &p, 120).unwrap();
        assert!(e.current_budget() > 8);
        e.reset();
        assert_eq!(e.current_budget(), 8, "reset must restore the initial budget");
    }

    #[test]
    fn cache_stats_reflect_strategy() {
        let p = prompt(8, 14);
        let mut cfg = RunConfig::default();
        cfg.cache_strategy = CacheStrategy::DeepCopy;
        let dc = run_spec(&cfg, &p, 12, 90);
        assert!(dc.teacher_cache.replicate_bytes > 0);
        cfg.cache_strategy = CacheStrategy::SegmentShare;
        let ss = run_spec(&cfg, &p, 12, 90);
        assert_eq!(ss.teacher_cache.replicate_bytes, 0);
    }

    #[test]
    fn split_round_api_guards_misuse() {
        let mut b = SimBackend::new(90);
        let mut e = Engine::new(&b, RunConfig::default());
        // no generation in flight
        assert!(e.prepare_verify(&mut b).is_err());
        assert!(e.finish_verify().is_err());
        assert!(e.take_output().is_err());
        assert!(e.verify_payload().is_err());
        assert!(!e.needs_more());
        // begin, then finishing without preparing must fail
        let p = prompt(8, 30);
        e.begin_speculative(&mut b, &p, 8).unwrap();
        assert!(e.needs_more());
        assert!(e.finish_verify().is_err(), "no round prepared");
        // preparing twice must fail; finishing before verification too
        e.prepare_verify(&mut b).unwrap();
        assert!(e.prepare_verify(&mut b).is_err(), "round already pending");
        assert!(e.finish_verify().is_err(), "round not verified yet");
        assert!(e.take_output().is_err(), "round still pending");
        // double-begin is rejected while in flight
        assert!(e.begin_speculative(&mut b, &p, 8).is_err());
        e.reset();
        assert!(!e.needs_more());
    }
}
