//! Generation outputs + per-turn statistics (the signals every paper
//! table/figure aggregates).

use crate::cache::CacheStats;
use crate::util::stats::{AcceptPos, Histogram};
use crate::util::StageTimer;

/// Far-history buckets for the Fig-7 attention-evidence histogram
/// (token distance from the current position).
pub fn attention_distance_buckets() -> Histogram {
    Histogram::new(vec![15.0, 63.0, 255.0])
}

/// Stable labels of the Fig-7 attention-distance buckets.
pub const ATTN_BUCKET_LABELS: &[&str] = &["0_15", "16_63", "64_255", "256_plus"];

/// Result of one generation call (one turn).
#[derive(Clone, Debug)]
pub struct GenOut {
    /// Committed output tokens (prompt excluded).
    pub tokens: Vec<i32>,
    /// Wall-clock of the full generation call, seconds.
    pub wall_secs: f64,
    /// Teacher verification/prefill steps this request consumed.
    pub teacher_calls: u64,
    /// Draft steps (chain refresh + frontier expansion).
    pub draft_calls: u64,
    /// Verification rounds (speculative) or decode steps (baseline).
    pub rounds: u64,
    /// accept_L samples, one per verification round.
    pub accept_lens: Vec<usize>,
    /// Position-wise acceptance counters (Fig 3).
    pub accept_pos: AcceptPos,
    /// Per-stage timing (instrumented runs only).
    pub timers: StageTimer,
    /// Draft attention top-1 distance histogram (probe runs only).
    pub attn_hist: Histogram,
    /// Teacher-cache movement counters for this generation.
    pub teacher_cache: CacheStats,
    /// Draft-cache movement counters for this generation.
    pub draft_cache: CacheStats,
    /// Prompt length (tokens) for trace records.
    pub prompt_len: usize,
}

impl GenOut {
    /// Decode throughput, output tokens per second.
    pub fn tok_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.tokens.len() as f64 / self.wall_secs
        }
    }

    /// Mean accept_L across this generation's verification rounds.
    pub fn mean_accept_len(&self) -> f64 {
        if self.accept_lens.is_empty() {
            0.0
        } else {
            self.accept_lens.iter().sum::<usize>() as f64 / self.accept_lens.len() as f64
        }
    }

    /// Time per output token (TPOT), seconds.
    pub fn tpot(&self) -> f64 {
        if self.tokens.is_empty() {
            0.0
        } else {
            self.wall_secs / self.tokens.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blank() -> GenOut {
        GenOut {
            tokens: vec![1, 2, 3, 4],
            wall_secs: 2.0,
            teacher_calls: 2,
            draft_calls: 3,
            rounds: 2,
            accept_lens: vec![1, 3],
            accept_pos: AcceptPos::default(),
            timers: StageTimer::new(false),
            attn_hist: attention_distance_buckets(),
            teacher_cache: CacheStats::default(),
            draft_cache: CacheStats::default(),
            prompt_len: 10,
        }
    }

    #[test]
    fn throughput_metrics() {
        let g = blank();
        assert!((g.tok_per_sec() - 2.0).abs() < 1e-12);
        assert!((g.tpot() - 0.5).abs() < 1e-12);
        assert!((g.mean_accept_len() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn attention_buckets_match_labels() {
        let h = attention_distance_buckets();
        assert_eq!(h.counts.len(), ATTN_BUCKET_LABELS.len());
    }
}
