//! Structured traces and debug artifacts (paper §4.3): run manifests,
//! per-turn JSONL records, failure dumps, and the rank-0 merge (§4.4).

pub mod record;
pub mod writer;

pub use record::TurnRecord;
pub use writer::{merge_rank_files, FailureDump, TraceWriter};
