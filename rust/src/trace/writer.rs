//! Trace writing, failure dumps, and the rank-0 merge (paper §4.3/§4.4).
//!
//! Each rank writes `trace_rank{r}.jsonl` independently; after the run,
//! rank 0 merges them into a globally ordered `trace_merged.jsonl`. Every
//! run directory also carries a `run_manifest.json` (hyperparameters,
//! execution flags, backend id, seed) so any number can be traced back to
//! its exact configuration.

use super::record::TurnRecord;
use crate::json::{self, Json};
use anyhow::{Context, Result};
use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

/// Buffered per-rank JSONL trace writer (`trace_rank{r}.jsonl`).
pub struct TraceWriter {
    dir: PathBuf,
    rank: usize,
    file: BufWriter<File>,
    /// Records written so far.
    pub records_written: u64,
}

impl TraceWriter {
    /// Create (truncate) this rank's trace file under `dir`.
    pub fn create(dir: impl AsRef<Path>, rank: usize) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)?;
        let path = dir.join(format!("trace_rank{rank}.jsonl"));
        let file = BufWriter::new(File::create(&path).with_context(|| format!("{path:?}"))?);
        Ok(Self { dir, rank, file, records_written: 0 })
    }

    /// The rank this writer serves.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Append one turn record as a JSONL line.
    pub fn write(&mut self, rec: &TurnRecord) -> Result<()> {
        writeln!(self.file, "{}", rec.to_json().to_string())?;
        self.records_written += 1;
        Ok(())
    }

    /// Flush buffered records to disk.
    pub fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }

    /// Compact failure dump (paper §4.3): enough context to reproduce.
    pub fn failure(&self, dump: &FailureDump) -> Result<PathBuf> {
        let path = self
            .dir
            .join(format!("failure_rank{}_{}.json", self.rank, dump.conversation_id));
        fs::write(&path, dump.to_json().to_string_pretty())?;
        Ok(path)
    }
}

/// Write the run manifest (config + environment identifiers).
pub fn write_manifest(dir: impl AsRef<Path>, fields: Json) -> Result<PathBuf> {
    let dir = dir.as_ref();
    fs::create_dir_all(dir)?;
    let path = dir.join("run_manifest.json");
    fs::write(&path, fields.to_string_pretty())?;
    Ok(path)
}

/// Minimal reproduction context emitted on abnormal termination.
#[derive(Clone, Debug)]
pub struct FailureDump {
    /// Conversation that failed.
    pub conversation_id: usize,
    /// Turn index at failure.
    pub turn_idx: usize,
    /// Worker rank.
    pub rank: usize,
    /// Rendered error chain.
    pub error: String,
    /// The turn's prompt tokens (reproduction input).
    pub prompt: Vec<i32>,
    /// Committed context length at failure.
    pub context_len: usize,
    /// The run configuration in effect.
    pub config: Json,
}

impl FailureDump {
    /// Serialize the dump for `failure_rank{r}_{conv}.json`.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("conversation_id", self.conversation_id)
            .push("turn_idx", self.turn_idx)
            .push("rank", self.rank)
            .push("error", self.error.as_str())
            .push("prompt", Json::Arr(self.prompt.iter().map(|t| Json::Num(*t as f64)).collect()))
            .push("context_len", self.context_len)
            .push("config", self.config.clone());
        o
    }
}

/// Rank-0 merge: read every `trace_rank*.jsonl` in `dir`, sort globally by
/// (conversation_id, turn_idx, kind) and write `trace_merged.jsonl`.
/// Returns the merged records.
pub fn merge_rank_files(dir: impl AsRef<Path>) -> Result<Vec<TurnRecord>> {
    let dir = dir.as_ref();
    let mut records: Vec<TurnRecord> = Vec::new();
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if !(name.starts_with("trace_rank") && name.ends_with(".jsonl")) {
            continue;
        }
        let text = fs::read_to_string(&path)?;
        for (ln, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let v = json::parse(line)
                .map_err(|e| anyhow::anyhow!("{path:?}:{}: {e}", ln + 1))?;
            records.push(
                TurnRecord::from_json(&v)
                    .with_context(|| format!("{path:?}:{} malformed record", ln + 1))?,
            );
        }
    }
    records.sort_by_key(|r| (r.conversation_id, r.turn_idx, r.kind.clone()));
    let merged = dir.join("trace_merged.jsonl");
    let mut f = BufWriter::new(File::create(&merged)?);
    for r in &records {
        writeln!(f, "{}", r.to_json().to_string())?;
    }
    f.flush()?;
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn rec(conv: usize, turn: usize, rank: usize, kind: &str) -> TurnRecord {
        TurnRecord {
            conversation_id: conv,
            turn_idx: turn,
            rank,
            profile: "code".into(),
            kind: kind.into(),
            prompt_len: 8,
            output_len: 4,
            wall_secs: 0.5,
            tok_s: 8.0,
            teacher_calls: 4,
            draft_calls: 6,
            rounds: 4,
            accept_lens: vec![1],
            accept_offered: vec![1],
            accept_accepted: vec![1],
            stage_seconds: BTreeMap::new(),
            attn_buckets: vec![],
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("eagle_trace_test_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn write_and_merge_across_ranks() {
        let dir = tmpdir("merge");
        {
            let mut w0 = TraceWriter::create(&dir, 0).unwrap();
            w0.write(&rec(2, 0, 0, "ea")).unwrap();
            w0.write(&rec(0, 0, 0, "ea")).unwrap();
            w0.flush().unwrap();
            let mut w1 = TraceWriter::create(&dir, 1).unwrap();
            w1.write(&rec(1, 1, 1, "ea")).unwrap();
            w1.write(&rec(1, 0, 1, "baseline")).unwrap();
            w1.flush().unwrap();
        }
        let merged = merge_rank_files(&dir).unwrap();
        assert_eq!(merged.len(), 4);
        let keys: Vec<(usize, usize)> =
            merged.iter().map(|r| (r.conversation_id, r.turn_idx)).collect();
        assert_eq!(keys, vec![(0, 0), (1, 0), (1, 1), (2, 0)]);
        assert!(dir.join("trace_merged.jsonl").exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn failure_dump_written_and_parsable() {
        let dir = tmpdir("fail");
        let w = TraceWriter::create(&dir, 0).unwrap();
        let dump = FailureDump {
            conversation_id: 7,
            turn_idx: 0,
            rank: 0,
            error: "tree invariant violation: range".into(),
            prompt: vec![1, 2, 3],
            context_len: 42,
            config: Json::obj(),
        };
        let path = w.failure(&dump).unwrap();
        let parsed = json::parse(&fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("conversation_id").unwrap().as_usize(), Some(7));
        assert!(parsed.get("error").unwrap().as_str().unwrap().contains("invariant"));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_written() {
        let dir = tmpdir("manifest");
        let mut j = Json::obj();
        j.push("mode", "fused").push("seed", 7u64);
        let p = write_manifest(&dir, j).unwrap();
        let v = json::parse(&fs::read_to_string(&p).unwrap()).unwrap();
        assert_eq!(v.get("mode").unwrap().as_str(), Some("fused"));
        let _ = fs::remove_dir_all(&dir);
    }
}
