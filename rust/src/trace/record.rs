//! The per-turn trace record — the unit every experiment aggregates.
//! Captures the execution-facing signals of paper §4.3: decoding config
//! linkage, speculative-tree statistics, acceptance summaries and
//! per-stage timing.

use crate::engine::GenOut;
use crate::json::Json;
use std::collections::BTreeMap;

/// One decoded turn, as serialized into `trace_rank{r}.jsonl` (the full
/// schema, field by field, is documented in `docs/TRACE_FORMAT.md`).
#[derive(Clone, Debug, PartialEq)]
pub struct TurnRecord {
    /// Conversation this turn belongs to.
    pub conversation_id: usize,
    /// Zero-based turn index within the conversation.
    pub turn_idx: usize,
    /// Worker rank that decoded the turn.
    pub rank: usize,
    /// Workload profile (`code` | `chat`).
    pub profile: String,
    /// "baseline" or "ea".
    pub kind: String,
    /// Prompt length of this turn, tokens.
    pub prompt_len: usize,
    /// Generated tokens this turn.
    pub output_len: usize,
    /// Wall-clock of the generation call, seconds.
    pub wall_secs: f64,
    /// Output tokens per second.
    pub tok_s: f64,
    /// Teacher steps consumed.
    pub teacher_calls: u64,
    /// Draft steps consumed.
    pub draft_calls: u64,
    /// Verification rounds (EA) or decode steps (baseline).
    pub rounds: u64,
    /// accept_L per verification round (EA only).
    pub accept_lens: Vec<usize>,
    /// Fig-3 denominators: rounds offering a depth-(i+1) candidate.
    pub accept_offered: Vec<u64>,
    /// Fig-3 numerators: rounds accepting through depth i+1.
    pub accept_accepted: Vec<u64>,
    /// Per-stage seconds (instrumented runs; else empty).
    pub stage_seconds: BTreeMap<String, f64>,
    /// Fig-7 attention-distance bucket counts (probe runs; else empty).
    pub attn_buckets: Vec<u64>,
}

impl TurnRecord {
    /// Build a record from one generation's [`GenOut`].
    pub fn from_gen(
        conversation_id: usize,
        turn_idx: usize,
        rank: usize,
        profile: &str,
        kind: &str,
        out: &GenOut,
    ) -> Self {
        Self {
            conversation_id,
            turn_idx,
            rank,
            profile: profile.to_string(),
            kind: kind.to_string(),
            prompt_len: out.prompt_len,
            output_len: out.tokens.len(),
            wall_secs: out.wall_secs,
            tok_s: out.tok_per_sec(),
            teacher_calls: out.teacher_calls,
            draft_calls: out.draft_calls,
            rounds: out.rounds,
            accept_lens: out.accept_lens.clone(),
            accept_offered: out.accept_pos.offered.clone(),
            accept_accepted: out.accept_pos.accepted.clone(),
            stage_seconds: out.timers.seconds.clone(),
            attn_buckets: if out.attn_hist.total > 0 { out.attn_hist.counts.clone() } else { vec![] },
        }
    }

    /// Mean accept_L of this turn (0 for baseline records).
    pub fn mean_accept(&self) -> f64 {
        if self.accept_lens.is_empty() {
            0.0
        } else {
            self.accept_lens.iter().sum::<usize>() as f64 / self.accept_lens.len() as f64
        }
    }

    /// Serialize to the JSONL object form (`docs/TRACE_FORMAT.md`).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("conversation_id", self.conversation_id)
            .push("turn_idx", self.turn_idx)
            .push("rank", self.rank)
            .push("profile", self.profile.as_str())
            .push("kind", self.kind.as_str())
            .push("prompt_len", self.prompt_len)
            .push("output_len", self.output_len)
            .push("wall_secs", self.wall_secs)
            .push("tok_s", self.tok_s)
            .push("teacher_calls", self.teacher_calls)
            .push("draft_calls", self.draft_calls)
            .push("rounds", self.rounds)
            .push("accept_lens",
                  Json::Arr(self.accept_lens.iter().map(|a| Json::Num(*a as f64)).collect()))
            .push("accept_offered", Json::from_u64_slice(&self.accept_offered))
            .push("accept_accepted", Json::from_u64_slice(&self.accept_accepted))
            .push("stage_seconds", Json::from_str_map(&self.stage_seconds))
            .push("attn_buckets", Json::from_u64_slice(&self.attn_buckets));
        o
    }

    /// Parse a record back from its JSON object form (None when a
    /// required field is missing or mistyped).
    pub fn from_json(j: &Json) -> Option<Self> {
        let u = |k: &str| j.get(k).and_then(Json::as_usize);
        let f = |k: &str| j.get(k).and_then(Json::as_f64);
        let s = |k: &str| j.get(k).and_then(Json::as_str).map(str::to_string);
        let arr_u64 = |k: &str| -> Vec<u64> {
            j.get(k)
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|x| x.as_f64().map(|v| v as u64)).collect())
                .unwrap_or_default()
        };
        let stage_seconds = j
            .get("stage_seconds")
            .and_then(Json::as_obj)
            .map(|pairs| {
                pairs
                    .iter()
                    .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
                    .collect::<BTreeMap<_, _>>()
            })
            .unwrap_or_default();
        Some(Self {
            conversation_id: u("conversation_id")?,
            turn_idx: u("turn_idx")?,
            rank: u("rank")?,
            profile: s("profile")?,
            kind: s("kind")?,
            prompt_len: u("prompt_len")?,
            output_len: u("output_len")?,
            wall_secs: f("wall_secs")?,
            tok_s: f("tok_s")?,
            teacher_calls: f("teacher_calls")? as u64,
            draft_calls: f("draft_calls")? as u64,
            rounds: f("rounds")? as u64,
            accept_lens: arr_u64("accept_lens").into_iter().map(|x| x as usize).collect(),
            accept_offered: arr_u64("accept_offered"),
            accept_accepted: arr_u64("accept_accepted"),
            stage_seconds,
            attn_buckets: arr_u64("attn_buckets"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn sample() -> TurnRecord {
        let mut stage = BTreeMap::new();
        stage.insert("verify".into(), 1.25);
        TurnRecord {
            conversation_id: 3,
            turn_idx: 1,
            rank: 2,
            profile: "chat".into(),
            kind: "ea".into(),
            prompt_len: 96,
            output_len: 224,
            wall_secs: 10.0,
            tok_s: 22.4,
            teacher_calls: 70,
            draft_calls: 400,
            rounds: 70,
            accept_lens: vec![3, 2, 4],
            accept_offered: vec![3, 3, 2],
            accept_accepted: vec![3, 2, 1],
            stage_seconds: stage,
            attn_buckets: vec![1, 2, 3, 4],
        }
    }

    #[test]
    fn json_roundtrip_exact() {
        let r = sample();
        let text = r.to_json().to_string();
        let back = TurnRecord::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn mean_accept() {
        assert!((sample().mean_accept() - 3.0).abs() < 1e-12);
    }
}
