//! Seeded arrival traces for the load-replay harness: Poisson and bursty
//! (Markov-modulated Poisson) arrival processes over the mixed
//! MT-Bench/HumanEval grammar prompt sets of [`super::prompts`].
//!
//! Everything here is a pure function of the [`TraceSpec`] seed — arrival
//! times are virtual milliseconds, never wall-clock readings — so a trace
//! replayed twice through [`crate::harness::replay`] produces identical
//! latency distributions (property-tested in `tests/trace_replay.rs`).
//! The paper's headline is a p99 number; deterministic traces are what
//! let CI hold a p99 floor without flaking.

use super::grammar::{Grammar, Profile};
use crate::util::SplitMix64;
use anyhow::{bail, Result};

/// The arrival process shape.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalKind {
    /// Memoryless arrivals at a fixed rate (requests per second).
    Poisson {
        /// Mean arrival rate in requests per second.
        rate_rps: f64,
    },
    /// Two-state Markov-modulated Poisson process: arrivals alternate
    /// between a calm and a burst rate, switching state after each
    /// arrival with probability `switch_p` (geometric sojourn lengths).
    Bursty {
        /// Calm-state arrival rate (requests per second).
        rate_lo_rps: f64,
        /// Burst-state arrival rate (requests per second).
        rate_hi_rps: f64,
        /// Per-arrival probability of switching state, in (0, 1].
        switch_p: f64,
    },
}

/// Which prompt family a trace synthesizes its requests from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PromptFamily {
    /// Alternate HumanEval-style code and MT-Bench-style chat grammar
    /// prompts (the paper's §5.1 workload mix).
    Mixed,
    /// Every request extends one common grammar-sampled system-prompt
    /// prefix of the given length with a per-request continuation suffix
    /// (the [`super::prompts::SharedPrefixSpec`] shape — what
    /// `--prefix-sharing` exploits; `--shared-prefix N` selects it).
    SharedPrefix {
        /// Common-prefix length in tokens (incl. BOS).
        prefix_len: usize,
    },
}

/// A seeded arrival-trace specification (see the module docs).
#[derive(Clone, Debug)]
pub struct TraceSpec {
    /// Number of requests in the trace.
    pub requests: usize,
    /// Arrival-process shape and rate(s).
    pub kind: ArrivalKind,
    /// Prompt synthesis family (mixed grammars, or shared-prefix).
    pub family: PromptFamily,
    /// Mean prompt length in tokens (lengths jitter ±~40% like
    /// [`super::prompts::WorkloadSpec`]); under
    /// [`PromptFamily::SharedPrefix`] this is the mean *suffix* length
    /// past the common prefix.
    pub prompt_mean: usize,
    /// Output-token deadline ceiling; per-request deadlines jitter in
    /// `[max(1, max_new/2), max_new]`.
    pub max_new: usize,
    /// Trace seed (arrival times, prompt contents, deadlines).
    pub seed: u64,
}

impl TraceSpec {
    /// A smoke-sized Poisson trace (tests, CI).
    pub fn smoke_poisson(seed: u64) -> Self {
        Self {
            requests: 24,
            kind: ArrivalKind::Poisson { rate_rps: 40.0 },
            family: PromptFamily::Mixed,
            prompt_mean: 16,
            max_new: 6,
            seed,
        }
    }

    /// A smoke-sized bursty trace (tests, CI).
    pub fn smoke_bursty(seed: u64) -> Self {
        Self {
            requests: 24,
            kind: ArrivalKind::Bursty { rate_lo_rps: 10.0, rate_hi_rps: 120.0, switch_p: 0.25 },
            family: PromptFamily::Mixed,
            prompt_mean: 16,
            max_new: 6,
            seed,
        }
    }

    /// Reject degenerate traces with config-contract errors naming the
    /// offending flag (the `--batch 0` precedent).
    pub fn validate(&self) -> Result<()> {
        if self.requests == 0 {
            bail!("config contract: --requests must be >= 1 (an empty trace replays nothing)");
        }
        if self.prompt_mean < 4 {
            bail!("config contract: --prompt-mean must be >= 4, got {}", self.prompt_mean);
        }
        if self.max_new == 0 {
            bail!("config contract: --max-new must be >= 1, got 0");
        }
        if let PromptFamily::SharedPrefix { prefix_len } = self.family {
            if prefix_len < 8 {
                bail!(
                    "config contract: --shared-prefix must be >= 8 tokens \
                     (shorter shares less than one KV block), got {prefix_len}"
                );
            }
        }
        match self.kind {
            ArrivalKind::Poisson { rate_rps } => {
                if !rate_rps.is_finite() || rate_rps <= 0.0 {
                    bail!(
                        "config contract: --rate must be a positive finite \
                         arrival rate in requests/sec, got {rate_rps}"
                    );
                }
            }
            ArrivalKind::Bursty { rate_lo_rps, rate_hi_rps, switch_p } => {
                if !rate_lo_rps.is_finite() || rate_lo_rps <= 0.0 {
                    bail!(
                        "config contract: --rate must be a positive finite \
                         arrival rate in requests/sec, got {rate_lo_rps}"
                    );
                }
                if !rate_hi_rps.is_finite() || rate_hi_rps < rate_lo_rps {
                    bail!(
                        "config contract: --rate-hi must be a finite burst rate \
                         >= --rate ({rate_lo_rps}), got {rate_hi_rps}"
                    );
                }
                if !(switch_p > 0.0 && switch_p <= 1.0) {
                    bail!(
                        "config contract: --switch-p must be in (0, 1], got {switch_p}"
                    );
                }
            }
        }
        Ok(())
    }

    /// Materialize the trace: one [`TraceRequest`] per arrival, sorted by
    /// arrival time by construction. Deterministic in `seed` — two calls
    /// yield identical traces.
    pub fn generate(&self) -> Result<Vec<TraceRequest>> {
        self.validate()?;
        let mut rng = SplitMix64::new(self.seed ^ 0x7ACE);
        let mut out = Vec::with_capacity(self.requests);
        let mut now_ms = 0.0f64;
        // bursty state: false = calm, true = burst
        let mut burst = false;
        // shared-prefix family: the common system prompt, sampled once
        let prefix = match self.family {
            PromptFamily::Mixed => None,
            PromptFamily::SharedPrefix { prefix_len } => Some(
                Grammar::new(Profile::Chat).sample_sequence(prefix_len, self.seed ^ 0x51F1, None),
            ),
        };
        for i in 0..self.requests {
            let rate = match self.kind {
                ArrivalKind::Poisson { rate_rps } => rate_rps,
                ArrivalKind::Bursty { rate_lo_rps, rate_hi_rps, switch_p } => {
                    if rng.f64_unit() < switch_p {
                        burst = !burst;
                    }
                    if burst {
                        rate_hi_rps
                    } else {
                        rate_lo_rps
                    }
                }
            };
            // exponential inter-arrival, in virtual milliseconds
            let gap_ms = -(1.0 - rng.f64_unit()).ln() / rate * 1000.0;
            now_ms += gap_ms;
            let lo = ((self.prompt_mean as f64 * 0.6) as u64).max(4);
            let hi = ((self.prompt_mean as f64 * 1.5) as u64).max(lo + 1);
            let len = rng.range(lo, hi) as usize;
            let (profile, prompt) = match &prefix {
                // mixed prompt set: alternate HumanEval-style code and
                // MT-Bench-style chat grammars
                None => {
                    let profile = if i % 2 == 0 { Profile::Code } else { Profile::Chat };
                    (profile, Grammar::new(profile).sample_sequence(len, rng.next_u64(), None))
                }
                // shared-prefix set: the common prefix plus a grammar
                // continuation suffix of the jittered length
                Some(pre) => {
                    let g = Grammar::new(Profile::Chat);
                    let suffix = g.continue_from(
                        pre[pre.len() - 2],
                        pre[pre.len() - 1],
                        pre[1],
                        len,
                        rng.next_u64(),
                    );
                    let mut p = pre.clone();
                    p.extend_from_slice(&suffix);
                    (Profile::Chat, p)
                }
            };
            let max_new =
                rng.range((self.max_new as u64 / 2).max(1), self.max_new as u64 + 1) as usize;
            out.push(TraceRequest { id: i as u64, arrival_ms: now_ms, prompt, max_new, profile });
        }
        Ok(out)
    }
}

/// One request of a materialized trace.
#[derive(Clone, Debug)]
pub struct TraceRequest {
    /// Trace-order request id (also the submission id at replay).
    pub id: u64,
    /// Arrival time in virtual milliseconds from trace start.
    pub arrival_ms: f64,
    /// Prompt tokens (grammar-sampled, profile-mixed).
    pub prompt: Vec<i32>,
    /// Output-token deadline of the request.
    pub max_new: usize,
    /// Benchmark-family profile the prompt was sampled from.
    pub profile: Profile,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_trace_is_deterministic_in_seed() {
        let a = TraceSpec::smoke_poisson(7).generate().unwrap();
        let b = TraceSpec::smoke_poisson(7).generate().unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_ms, y.arrival_ms, "arrival schedule must be bit-identical");
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new, y.max_new);
        }
        let c = TraceSpec::smoke_poisson(8).generate().unwrap();
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.arrival_ms != y.arrival_ms),
            "a different seed must move the arrivals"
        );
    }

    #[test]
    fn arrivals_are_sorted_and_positive() {
        for trace in [
            TraceSpec::smoke_poisson(3).generate().unwrap(),
            TraceSpec::smoke_bursty(3).generate().unwrap(),
        ] {
            let mut prev = 0.0;
            for r in &trace {
                assert!(r.arrival_ms > prev || (prev == 0.0 && r.arrival_ms > 0.0));
                assert!(r.arrival_ms.is_finite());
                prev = r.arrival_ms;
                assert!(r.prompt.len() >= 4);
                assert!(r.max_new >= 1);
            }
        }
    }

    #[test]
    fn bursty_trace_mixes_two_rates() {
        // burst gaps must be visibly shorter than calm gaps: compare the
        // spread of inter-arrival gaps against a fixed-rate trace
        let t = TraceSpec::smoke_bursty(11).generate().unwrap();
        let gaps: Vec<f64> = t
            .windows(2)
            .map(|w| w[1].arrival_ms - w[0].arrival_ms)
            .collect();
        let min = gaps.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = gaps.iter().cloned().fold(0.0f64, f64::max);
        assert!(
            max / min.max(1e-9) > 4.0,
            "bursty gaps should span the two rates: min {min}, max {max}"
        );
    }

    #[test]
    fn degenerate_specs_are_rejected_by_name() {
        let mut s = TraceSpec::smoke_poisson(0);
        s.requests = 0;
        assert!(s.validate().unwrap_err().to_string().contains("--requests"));

        let mut s = TraceSpec::smoke_poisson(0);
        s.kind = ArrivalKind::Poisson { rate_rps: 0.0 };
        assert!(s.validate().unwrap_err().to_string().contains("--rate"));

        let mut s = TraceSpec::smoke_bursty(0);
        s.kind = ArrivalKind::Bursty { rate_lo_rps: 10.0, rate_hi_rps: 5.0, switch_p: 0.2 };
        assert!(s.validate().unwrap_err().to_string().contains("--rate-hi"));

        let mut s = TraceSpec::smoke_bursty(0);
        s.kind = ArrivalKind::Bursty { rate_lo_rps: 10.0, rate_hi_rps: 50.0, switch_p: 0.0 };
        assert!(s.validate().unwrap_err().to_string().contains("--switch-p"));

        let mut s = TraceSpec::smoke_poisson(0);
        s.max_new = 0;
        assert!(s.validate().unwrap_err().to_string().contains("--max-new"));
    }

    #[test]
    fn shared_prefix_traces_share_exactly_the_prefix() {
        let mut s = TraceSpec::smoke_poisson(5);
        s.family = PromptFamily::SharedPrefix { prefix_len: 32 };
        let t = s.generate().unwrap();
        let prefix = t[0].prompt[..32].to_vec();
        for r in &t {
            assert_eq!(&r.prompt[..32], &prefix[..], "every request starts with the prefix");
            assert!(r.prompt.len() > 32, "every request carries its own suffix");
            assert_eq!(r.profile, Profile::Chat);
        }
        assert!(
            t.iter().any(|r| r.prompt[32..] != t[0].prompt[32..]),
            "per-request suffixes must differ"
        );
        // deterministic in the seed, like the mixed family
        let u = s.generate().unwrap();
        assert!(t.iter().zip(&u).all(|(a, b)| a.prompt == b.prompt));

        s.family = PromptFamily::SharedPrefix { prefix_len: 4 };
        assert!(s.validate().unwrap_err().to_string().contains("--shared-prefix"));
    }

    #[test]
    fn profiles_are_mixed() {
        let t = TraceSpec::smoke_poisson(1).generate().unwrap();
        assert!(t.iter().any(|r| r.profile == Profile::Code));
        assert!(t.iter().any(|r| r.profile == Profile::Chat));
    }
}
