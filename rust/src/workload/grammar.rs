//! Rust mirror of `python/compile/grammar.py` — the seeded stochastic
//! grammar the teacher was trained on. Must stay bit-for-bit identical to
//! the python side: prompts sampled here are in-distribution for the
//! trained checkpoint, and the parity vectors in `artifacts/manifest.json`
//! are asserted by integration tests.

use crate::config::contract::{BOS_ID, FIRST_TOKEN, VOCAB};
use crate::util::rng::splitmix64;

/// Number of grammar topics (conversation flavors).
pub const NUM_TOPICS: u64 = 8;

/// Benchmark-family profile (paper §5.1): `Code` = HumanEval-style
/// (mostly deterministic), `Chat` = MT-Bench-style (broader branching).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Profile {
    Code,
    Chat,
}

impl Profile {
    /// Profile-specific seed offset (keeps the two grammars disjoint).
    pub fn seed(&self) -> u64 {
        match self {
            Profile::Code => 0x9E37_79B9_7F4A_7C15,
            Profile::Chat => 0xC2B2_AE3D_27D4_EB4F,
        }
    }

    fn branch_w64(&self) -> [u64; 4] {
        match self {
            Profile::Code => [44, 16, 4, 0],
            Profile::Chat => [22, 22, 13, 7],
        }
    }

    /// Stable string form (trace records, flags).
    pub fn as_str(&self) -> &'static str {
        match self {
            Profile::Code => "code",
            Profile::Chat => "chat",
        }
    }

    /// Parse the string form (`code` | `chat`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "code" => Some(Profile::Code),
            "chat" => Some(Profile::Chat),
            _ => None,
        }
    }
}

const PROB_W256: [&[u64]; 4] = [&[256], &[204, 52], &[179, 51, 26], &[153, 51, 31, 21]];

/// The seeded stochastic grammar (order-2 Markov with topic rotation).
#[derive(Clone, Copy, Debug)]
pub struct Grammar {
    /// Which benchmark family this grammar mimics.
    pub profile: Profile,
}

impl Grammar {
    /// A grammar for `profile`.
    pub fn new(profile: Profile) -> Self {
        Self { profile }
    }

    /// The HumanEval-style (code) grammar.
    pub fn code() -> Self {
        Self::new(Profile::Code)
    }

    /// The MT-Bench-style (chat) grammar.
    pub fn chat() -> Self {
        Self::new(Profile::Chat)
    }

    /// Topic id of a topic token.
    pub fn topic_of(topic_token: i32) -> u64 {
        topic_token as u64 % NUM_TOPICS
    }

    fn context_hash(&self, b: i32, topic_id: u64) -> u64 {
        splitmix64(
            (b as u64)
                .wrapping_mul(0x0000_0100_0000_01B3)
                ^ topic_id.wrapping_mul(0x0100_0193)
                ^ self.profile.seed(),
        )
    }

    /// Unrotated candidate set for context (b, topic).
    pub fn base_candidates(&self, b: i32, topic_id: u64) -> Vec<i32> {
        let h = self.context_hash(b, topic_id);
        let sel = h & 63;
        let mut n = 1usize;
        let mut acc = 0u64;
        for (i, w) in self.profile.branch_w64().iter().enumerate() {
            acc += w;
            if sel < acc {
                n = i + 1;
                break;
            }
        }
        let span = (VOCAB - FIRST_TOKEN as usize) as u64;
        let mut toks: Vec<i32> = Vec::with_capacity(n);
        let mut hh = h;
        for i in 0..n {
            hh = splitmix64(hh ^ (i as u64 + 1));
            let mut t = FIRST_TOKEN + (hh % span) as i32;
            while toks.contains(&t) {
                t = FIRST_TOKEN + ((t - FIRST_TOKEN + 1) % span as i32);
            }
            toks.push(t);
        }
        toks
    }

    /// Candidates in preference order (rotated by `a mod n`) + weights/256.
    pub fn dist(&self, a: i32, b: i32, topic_id: u64) -> (Vec<i32>, &'static [u64]) {
        let toks = self.base_candidates(b, topic_id);
        let n = toks.len();
        let rot = (a as usize) % n;
        let rotated: Vec<i32> = toks[rot..].iter().chain(&toks[..rot]).copied().collect();
        (rotated, PROB_W256[n - 1])
    }

    /// The grammar's most-likely continuation of context `(a, b)`.
    pub fn greedy_next(&self, a: i32, b: i32, topic_id: u64) -> i32 {
        self.dist(a, b, topic_id).0[0]
    }

    /// Sample one continuation; returns `(token, next_state)`.
    pub fn sample_next(&self, a: i32, b: i32, topic_id: u64, state: u64) -> (i32, u64) {
        let (toks, w256) = self.dist(a, b, topic_id);
        let state = splitmix64(state);
        let r = state & 255;
        let mut acc = 0u64;
        for (t, w) in toks.iter().zip(w256) {
            acc += w;
            if r < acc {
                return (*t, state);
            }
        }
        (*toks.last().unwrap(), state)
    }

    /// Sample a topic token; returns `(token, next_state)`.
    pub fn sample_topic_token(state: u64) -> (i32, u64) {
        let state = splitmix64(state);
        (FIRST_TOKEN + (state % (VOCAB - FIRST_TOKEN as usize) as u64) as i32, state)
    }

    /// `[BOS, topic, ...]` of `length` tokens (parity with python).
    pub fn sample_sequence(&self, length: usize, seed: u64, topic_token: Option<i32>) -> Vec<i32> {
        let mut state = splitmix64(seed ^ self.profile.seed());
        let mut out = vec![BOS_ID];
        let topic = match topic_token {
            Some(t) => t,
            None => {
                let (t, s) = Self::sample_topic_token(state);
                state = s;
                t
            }
        };
        if length > 1 {
            out.push(topic);
        }
        let tid = Self::topic_of(topic);
        let (mut a, mut b) = (BOS_ID, topic);
        while out.len() < length {
            let (t, s) = self.sample_next(a, b, tid, state);
            state = s;
            out.push(t);
            a = b;
            b = t;
        }
        out
    }

    /// Sample `n` more tokens continuing a context whose last two tokens
    /// are `(a, b)` under `topic_token` (parity with python
    /// `continue_sequence`, generalized to any context tail).
    pub fn continue_from(&self, a: i32, b: i32, topic_token: i32, n: usize, seed: u64) -> Vec<i32> {
        let tid = Self::topic_of(topic_token);
        let mut state = splitmix64(seed ^ 0xA5A5_A5A5);
        let (mut a, mut b) = (a, b);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let (t, s) = self.sample_next(a, b, tid, state);
            state = s;
            out.push(t);
            a = b;
            b = t;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_parity_with_python() {
        assert_eq!(splitmix64(0), 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn sequences_are_deterministic_and_bos_prefixed() {
        let g = Grammar::chat();
        let a = g.sample_sequence(32, 7, None);
        let b = g.sample_sequence(32, 7, None);
        assert_eq!(a, b);
        assert_eq!(a[0], BOS_ID);
        assert_eq!(a.len(), 32);
        assert!(a[1..].iter().all(|t| (FIRST_TOKEN..VOCAB as i32).contains(t)));
    }

    #[test]
    fn topic_token_respected() {
        let g = Grammar::code();
        let s = g.sample_sequence(16, 3, Some(100));
        assert_eq!(s[1], 100);
    }

    #[test]
    fn rotation_gives_order2_dependence() {
        let g = Grammar::chat();
        let mut found = false;
        for b in 2..200 {
            if g.base_candidates(b, 0).len() >= 2 {
                assert_ne!(g.greedy_next(0, b, 0), g.greedy_next(1, b, 0));
                found = true;
                break;
            }
        }
        assert!(found);
    }

    #[test]
    fn profiles_differ_in_branching() {
        let mean = |g: Grammar| {
            let mut n = 0usize;
            let mut c = 0usize;
            for b in 2..200 {
                for tid in 0..8 {
                    n += g.base_candidates(b, tid).len();
                    c += 1;
                }
            }
            n as f64 / c as f64
        };
        assert!(mean(Grammar::chat()) > mean(Grammar::code()) + 0.2);
    }

    #[test]
    fn continue_from_consistent_with_dist() {
        let g = Grammar::chat();
        let seq = g.sample_sequence(16, 9, None);
        let topic = seq[1];
        let cont = g.continue_from(seq[14], seq[15], topic, 10, 3);
        let tid = Grammar::topic_of(topic);
        let (mut a, mut b) = (seq[14], seq[15]);
        for t in cont {
            assert!(g.dist(a, b, tid).0.contains(&t));
            a = b;
            b = t;
        }
    }
}
