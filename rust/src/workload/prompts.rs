//! Prompt-set generation — the evaluation workload of paper §5.1:
//! 80 HumanEval-style single-turn prompts + 80 MT-Bench-style two-turn
//! conversations = 240 turns. Lengths are drawn from seeded distributions;
//! the default is CPU-scaled (the paper's absolute lengths — mean prompt
//! ~501, output ~891 — exceed this build's C=1024 cache with generation,
//! so the *shape* is preserved at ~1/4 scale; see DESIGN.md §1).
//!
//! Turn-1 prompts are sampled from the grammar directly. Follow-up turn
//! prompts must continue the *live* conversation context (which includes
//! generated tokens), so they are materialized at run time by the
//! coordinator via [`ConversationSpec::followup_prompt`].

use super::grammar::{Grammar, Profile};
use crate::util::SplitMix64;

/// Workload-level configuration.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    /// Single-turn code-profile conversations (HumanEval-style).
    pub code_conversations: usize,
    /// Two-turn chat-profile conversations (MT-Bench-style).
    pub chat_conversations: usize,
    /// Mean turn-1 prompt length (tokens); actual lengths jitter ±~40%.
    pub prompt_mean: usize,
    /// Mean follow-up prompt length.
    pub followup_mean: usize,
    /// Workload sampling seed (prompt lengths + contents).
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        // 80 + 80 conversations -> 240 turns, matching the paper's count.
        // Lengths sized so a two-turn conversation (2 prompts + 2
        // generations + tree headroom) fits the C=512 artifact cache.
        Self {
            code_conversations: 80,
            chat_conversations: 80,
            prompt_mean: 64,
            followup_mean: 32,
            seed: 0,
        }
    }
}

impl WorkloadSpec {
    /// A small smoke-sized workload (tests, examples).
    pub fn smoke() -> Self {
        Self { code_conversations: 3, chat_conversations: 3, prompt_mean: 32,
               followup_mean: 16, seed: 0 }
    }

    /// Total turns across every conversation (code = 1, chat = 2).
    pub fn total_turns(&self) -> usize {
        self.code_conversations + 2 * self.chat_conversations
    }

    /// Materialize the conversation specs (deterministic in `seed`).
    pub fn conversations(&self) -> Vec<ConversationSpec> {
        let mut out = Vec::with_capacity(self.code_conversations + self.chat_conversations);
        let mut id = 0usize;
        for i in 0..self.code_conversations {
            out.push(self.spec(id, Profile::Code, 1, self.seed ^ (0xC0DE + i as u64)));
            id += 1;
        }
        for i in 0..self.chat_conversations {
            out.push(self.spec(id, Profile::Chat, 2, self.seed ^ (0xCAA7 + i as u64)));
            id += 1;
        }
        out
    }

    fn spec(&self, id: usize, profile: Profile, turns: usize, seed: u64) -> ConversationSpec {
        let mut rng = SplitMix64::new(seed);
        let jitter = |rng: &mut SplitMix64, mean: usize| -> usize {
            let lo = (mean as f64 * 0.6) as u64;
            let hi = (mean as f64 * 1.5) as u64;
            rng.range(lo.max(4), hi.max(lo + 1)) as usize
        };
        let mut prompt_lens = vec![jitter(&mut rng, self.prompt_mean)];
        for _ in 1..turns {
            prompt_lens.push(jitter(&mut rng, self.followup_mean));
        }
        ConversationSpec { id, profile, prompt_lens, seed: rng.next_u64() }
    }
}

/// One conversation: 1 turn (code) or 2 turns (chat).
#[derive(Clone, Debug)]
pub struct ConversationSpec {
    /// Globally unique conversation id (the sharding key).
    pub id: usize,
    /// Benchmark-family profile of every turn.
    pub profile: Profile,
    /// Prompt length per turn.
    pub prompt_lens: Vec<usize>,
    /// Per-conversation sampling seed.
    pub seed: u64,
}

impl ConversationSpec {
    /// Number of turns (1 for code, 2 for chat).
    pub fn turns(&self) -> usize {
        self.prompt_lens.len()
    }

    /// The grammar this conversation's prompts come from.
    pub fn grammar(&self) -> Grammar {
        Grammar::new(self.profile)
    }

    /// Turn-1 prompt: `[BOS, topic, ...]`.
    pub fn first_prompt(&self) -> Vec<i32> {
        self.grammar().sample_sequence(self.prompt_lens[0], self.seed, None)
    }

    /// The conversation topic token (position 1 of turn 1).
    pub fn topic_token(&self) -> i32 {
        self.first_prompt()[1]
    }

    /// A follow-up turn prompt continuing the live context whose last two
    /// tokens are `(a, b)` (committed prompt+generation so far).
    pub fn followup_prompt(&self, turn: usize, a: i32, b: i32) -> Vec<i32> {
        assert!(turn >= 1 && turn < self.turns());
        self.grammar().continue_from(
            a,
            b,
            self.topic_token(),
            self.prompt_lens[turn],
            self.seed ^ (turn as u64).wrapping_mul(0x7EA7),
        )
    }
}

/// Shared-prefix prompt family — the production traffic shape prefix
/// sharing targets: every conversation's prompt is one common
/// grammar-sampled "system prompt" prefix followed by a per-conversation
/// grammar continuation suffix, so admissions after the first share a
/// long block-aligned run of identical KV rows (`--prefix-sharing`
/// adopts it; the sharing bench and `bench_gate` rule replay exactly
/// this family).
#[derive(Clone, Debug)]
pub struct SharedPrefixSpec {
    /// Number of conversations drawing on the common prefix.
    pub conversations: usize,
    /// Length of the common system-prompt prefix (tokens, incl. BOS).
    pub prefix_len: usize,
    /// Mean per-conversation suffix length; actual lengths jitter ±~40%
    /// like [`WorkloadSpec`].
    pub suffix_mean: usize,
    /// Grammar family of the prefix and every suffix.
    pub profile: Profile,
    /// Sampling seed (prefix contents + every suffix).
    pub seed: u64,
}

impl Default for SharedPrefixSpec {
    fn default() -> Self {
        // Prefix sized past one 128-token prefill chunk so adopting it
        // provably drops teacher calls; suffixes stay short so B
        // conversations + generation fit the C=1024 cache.
        Self { conversations: 8, prefix_len: 160, suffix_mean: 24, profile: Profile::Chat, seed: 0 }
    }
}

impl SharedPrefixSpec {
    /// The common system-prompt prefix (`[BOS, topic, ...]`),
    /// deterministic in the seed.
    pub fn prefix(&self) -> Vec<i32> {
        assert!(self.prefix_len >= 2, "prefix needs BOS + topic");
        Grammar::new(self.profile).sample_sequence(self.prefix_len, self.seed ^ 0x51F1, None)
    }

    /// Materialize every conversation's full prompt (common prefix +
    /// per-conversation suffix). Suffixes are grammar-valid
    /// continuations of the prefix, so the whole prompt stays
    /// in-distribution for the trained checkpoint.
    pub fn prompts(&self) -> Vec<Vec<i32>> {
        let prefix = self.prefix();
        let g = Grammar::new(self.profile);
        let topic = prefix[1];
        let (a, b) = (prefix[prefix.len() - 2], prefix[prefix.len() - 1]);
        let mut rng = SplitMix64::new(self.seed ^ 0x5F5F);
        (0..self.conversations)
            .map(|i| {
                let lo = ((self.suffix_mean as f64 * 0.6) as u64).max(2);
                let hi = ((self.suffix_mean as f64 * 1.5) as u64).max(lo + 1);
                let n = rng.range(lo, hi) as usize;
                let suffix = g.continue_from(a, b, topic, n, self.seed ^ (0x5FF1 + i as u64));
                let mut p = prefix.clone();
                p.extend_from_slice(&suffix);
                p
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_turn_count() {
        let w = WorkloadSpec::default();
        assert_eq!(w.total_turns(), 240);
        let convs = w.conversations();
        assert_eq!(convs.len(), 160);
        assert_eq!(convs.iter().filter(|c| c.profile == Profile::Code).count(), 80);
        assert!(convs.iter().filter(|c| c.profile == Profile::Chat).all(|c| c.turns() == 2));
        assert!(convs.iter().filter(|c| c.profile == Profile::Code).all(|c| c.turns() == 1));
    }

    #[test]
    fn deterministic_in_seed() {
        let a = WorkloadSpec::default().conversations();
        let b = WorkloadSpec::default().conversations();
        assert_eq!(a[17].first_prompt(), b[17].first_prompt());
        let mut w = WorkloadSpec::default();
        w.seed = 1;
        let c = w.conversations();
        assert_ne!(a[17].first_prompt(), c[17].first_prompt());
    }

    #[test]
    fn prompt_lengths_jitter_around_mean() {
        let w = WorkloadSpec::default();
        let lens: Vec<usize> =
            w.conversations().iter().map(|c| c.prompt_lens[0]).collect();
        let mean = lens.iter().sum::<usize>() as f64 / lens.len() as f64;
        assert!((mean - w.prompt_mean as f64).abs() < w.prompt_mean as f64 * 0.25,
                "mean {mean}");
        assert!(lens.iter().any(|l| *l != lens[0]), "lengths must vary");
    }

    #[test]
    fn shared_prefix_family_shares_exactly_the_prefix() {
        let spec = SharedPrefixSpec::default();
        let prompts = spec.prompts();
        assert_eq!(prompts.len(), spec.conversations);
        let prefix = spec.prefix();
        assert_eq!(prefix.len(), spec.prefix_len);
        for p in &prompts {
            assert_eq!(&p[..spec.prefix_len], &prefix[..], "every prompt starts with the prefix");
            assert!(p.len() > spec.prefix_len, "every prompt carries its own suffix");
        }
        // suffixes diverge across conversations (not all identical)
        assert!(
            prompts.iter().any(|p| p[spec.prefix_len..] != prompts[0][spec.prefix_len..]),
            "per-conversation suffixes must differ"
        );
        // deterministic in the seed
        assert_eq!(SharedPrefixSpec::default().prompts(), prompts);
        // suffixes are grammar-valid continuations
        let g = Grammar::new(spec.profile);
        let tid = Grammar::topic_of(prefix[1]);
        for p in &prompts {
            let (mut a, mut b) = (p[spec.prefix_len - 2], p[spec.prefix_len - 1]);
            for &t in &p[spec.prefix_len..] {
                assert!(g.dist(a, b, tid).0.contains(&t));
                a = b;
                b = t;
            }
        }
    }

    #[test]
    fn followup_continues_topic() {
        let w = WorkloadSpec::smoke();
        let conv = w.conversations().into_iter().find(|c| c.turns() == 2).unwrap();
        let p1 = conv.first_prompt();
        let f = conv.followup_prompt(1, p1[p1.len() - 2], p1[p1.len() - 1]);
        assert_eq!(f.len(), conv.prompt_lens[1]);
        // every follow-up token is a grammar-valid continuation
        let g = conv.grammar();
        let tid = Grammar::topic_of(conv.topic_token());
        let (mut a, mut b) = (p1[p1.len() - 2], p1[p1.len() - 1]);
        for t in f {
            assert!(g.dist(a, b, tid).0.contains(&t));
            a = b;
            b = t;
        }
    }
}
