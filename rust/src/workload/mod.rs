//! Synthetic workload substrate: the rust mirror of the python grammar
//! (bit-for-bit parity) and the MT-Bench/HumanEval-style prompt generator
//! used by every experiment (paper §5.1, Fig 1).

pub mod grammar;
pub mod prompts;
pub mod trace;

pub use grammar::{Grammar, Profile};
pub use prompts::{ConversationSpec, SharedPrefixSpec, WorkloadSpec};
pub use trace::{ArrivalKind, PromptFamily, TraceRequest, TraceSpec};
